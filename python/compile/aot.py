"""AOT lowering: JAX programs → HLO text artifacts for the Rust runtime.

HLO *text* (not serialized HloModuleProto) is the interchange format: jax
>= 0.5 emits protos with 64-bit instruction ids which the runtime's XLA
(xla_extension 0.5.1) rejects; the text parser reassigns ids and
round-trips cleanly (see /opt/xla-example/README.md).

One artifact per (op, capacity class); `manifest.txt` (simple key=value
lines, one artifact per line) tells the Rust runtime what exists. Run:

    python -m compile.aot --out-dir ../artifacts

Python runs ONCE at build time; the Rust binary is self-contained after
`make artifacts`.
"""

import argparse
import os

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp  # noqa: E402
from jax._src.lib import xla_client as xc  # noqa: E402

from . import model  # noqa: E402
from .kernels import common as C  # noqa: E402

DEFAULT_CLASSES = (1024, 4096, 16384, 65536)


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def spec(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def lower_ops(n_buckets: int, batch: int, k_batch: int, max_ev: int):
    """Lower the five programs for one capacity class. Returns
    {op_name: hlo_text}."""
    b_spec = spec((n_buckets, C.SLOTS), jnp.uint64)
    m_spec = spec((4,), jnp.uint32)
    k_spec = spec((batch,), jnp.uint32)
    v_spec = spec((batch,), jnp.uint32)

    out = {}
    out["lookup"] = to_hlo_text(
        jax.jit(model.lookup_fn(n_buckets, batch)).lower(b_spec, m_spec, k_spec)
    )
    out["insert"] = to_hlo_text(
        jax.jit(model.insert_fn(n_buckets, batch, max_ev), donate_argnums=(0,)).lower(
            b_spec, m_spec, k_spec, v_spec
        )
    )
    out["delete"] = to_hlo_text(
        jax.jit(model.delete_fn(n_buckets, batch), donate_argnums=(0,)).lower(
            b_spec, m_spec, k_spec
        )
    )
    out["split"] = to_hlo_text(
        jax.jit(model.split_fn(n_buckets, k_batch), donate_argnums=(0,)).lower(b_spec, m_spec)
    )
    out["merge"] = to_hlo_text(
        jax.jit(model.merge_fn(n_buckets, k_batch), donate_argnums=(0,)).lower(b_spec, m_spec)
    )
    return out


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--classes", default=",".join(str(c) for c in DEFAULT_CLASSES),
                    help="comma-separated physical bucket counts")
    ap.add_argument("--batch", type=int, default=model.DEFAULT_BATCH)
    ap.add_argument("--resize-k", type=int, default=model.DEFAULT_RESIZE_K)
    ap.add_argument("--max-evictions", type=int, default=model.DEFAULT_MAX_EVICTIONS)
    args = ap.parse_args()

    classes = [int(c) for c in args.classes.split(",")]
    os.makedirs(args.out_dir, exist_ok=True)
    manifest = []
    for n in classes:
        assert n & (n - 1) == 0, f"capacity class {n} must be a power of two"
        k_batch = min(args.resize_k, n // 4)
        print(f"[aot] lowering capacity class {n} (batch={args.batch}, k={k_batch}) ...")
        ops = lower_ops(n, args.batch, k_batch, args.max_evictions)
        for op, text in ops.items():
            fname = f"{op}_{n}.hlo.txt"
            with open(os.path.join(args.out_dir, fname), "w") as f:
                f.write(text)
            manifest.append(
                f"op={op} n_buckets={n} batch={args.batch} k_batch={k_batch} "
                f"max_evictions={args.max_evictions} slots={C.SLOTS} file={fname}"
            )
            print(f"  wrote {fname} ({len(text)} chars)")
    with open(os.path.join(args.out_dir, "manifest.txt"), "w") as f:
        f.write("\n".join(manifest) + "\n")
    print(f"[aot] manifest with {len(manifest)} artifacts -> {args.out_dir}/manifest.txt")


if __name__ == "__main__":
    main()
