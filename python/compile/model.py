"""Layer 2 — the bulk table-operation programs.

Composes the L1 Pallas kernels into the five jittable programs the Rust
runtime loads per capacity class (DESIGN.md §7):

* ``lookup``  (buckets, meta, keys)          -> (values, found)
* ``insert``  (buckets, meta, keys, vals)    -> (buckets', status, overflow)
* ``delete``  (buckets, meta, keys)          -> (buckets', deleted)
* ``split``   (buckets, meta)                -> (buckets', meta', moved)
* ``merge``   (buckets, meta)                -> (buckets', meta', merged)

Table state = ``buckets u64[N,32]`` + ``meta u32[4]`` =
``[index_mask, split_ptr, 0, 0]``. All shapes are static per artifact;
short batches are padded with the EMPTY key (kernels skip them).

Python never runs at serving time: ``aot.py`` lowers each program to HLO
text once, and the Rust coordinator executes the artifacts via PJRT.
"""

import functools

import jax
import jax.numpy as jnp

from .kernels import common as C
from .kernels import insert as insert_k
from .kernels import migrate, probe

jax.config.update("jax_enable_x64", True)

DEFAULT_BATCH = 4096
DEFAULT_RESIZE_K = 256
DEFAULT_MAX_EVICTIONS = 16


def new_table(n_buckets: int):
    """Fresh (buckets, meta) for a capacity class of `n_buckets` physical
    buckets, starting with the full class addressable (mask = N-1)."""
    buckets = jnp.full((n_buckets, C.SLOTS), C.EMPTY_WORD, dtype=jnp.uint64)
    meta = jnp.array([n_buckets - 1, 0, 0, 0], dtype=jnp.uint32)
    return buckets, meta


def new_table_at_round(n_buckets: int, index_mask: int, split_ptr: int = 0):
    """Fresh table addressed at a smaller round (room to split upward)."""
    assert index_mask < n_buckets
    buckets = jnp.full((n_buckets, C.SLOTS), C.EMPTY_WORD, dtype=jnp.uint64)
    meta = jnp.array([index_mask, split_ptr, 0, 0], dtype=jnp.uint32)
    return buckets, meta


# ---------------------------------------------------------------------------
# The five programs. Each is a plain jax function of concrete arrays with
# static (n_buckets, batch, ...) baked in via the factory functions below.
# ---------------------------------------------------------------------------


def lookup_fn(n_buckets: int, batch: int):
    """Bulk Search program."""
    kernel = probe.make_lookup(n_buckets, batch)

    def f(buckets, meta, keys):
        values, found = kernel(meta, keys, buckets)
        return values, found

    return f


def insert_fn(n_buckets: int, batch: int, max_evictions: int = DEFAULT_MAX_EVICTIONS):
    """Bulk four-step Insert program (buckets donated)."""
    kernel = insert_k.make_insert(n_buckets, batch, max_evictions)

    def f(buckets, meta, keys, vals):
        buckets, status, overflow = kernel(meta, keys, vals, buckets)
        return buckets, status, overflow

    return f


def delete_fn(n_buckets: int, batch: int):
    """Bulk Delete program (buckets donated)."""
    kernel = probe.make_delete(n_buckets, batch)

    def f(buckets, meta, keys):
        buckets, deleted = kernel(meta, keys, buckets)
        return buckets, deleted

    return f


def split_fn(n_buckets: int, k_batch: int):
    """Expansion program: split `k_batch` buckets and advance the round
    state (meta update is pure jnp around the migration kernel).

    The caller guarantees `split_ptr + k_batch <= 2^m` and physical room;
    the coordinator chunks requests at round boundaries (DESIGN.md §7).
    """
    kernel = migrate.make_split(n_buckets, k_batch)

    def f(buckets, meta):
        buckets, moved = kernel(meta, buckets)
        index_mask = meta[0]
        split_ptr = meta[1] + jnp.uint32(k_batch)
        m_base = index_mask + jnp.uint32(1)
        wrap = split_ptr == m_base
        new_mask = jnp.where(wrap, (index_mask << 1) | jnp.uint32(1), index_mask)
        new_sp = jnp.where(wrap, jnp.uint32(0), split_ptr)
        new_meta = jnp.stack([new_mask, new_sp, meta[2], meta[3]])
        return buckets, new_meta, moved

    return f


def merge_fn(n_buckets: int, k_batch: int):
    """Contraction program: merge up to `k_batch` pairs (last-split-first)
    and regress split_ptr by the number actually merged.

    The caller must present a mid-round state (split_ptr >= 1); round
    regression across `split_ptr == 0` is the coordinator's chunking job.
    """
    kernel = migrate.make_merge(n_buckets, k_batch)

    def f(buckets, meta):
        buckets, merged = kernel(meta, buckets)
        new_sp = meta[1] - merged[0]
        new_meta = jnp.stack([meta[0], new_sp, meta[2], meta[3]])
        return buckets, new_meta, merged

    return f


# ---------------------------------------------------------------------------
# Convenience jitted bundle (used by python tests and notebooks; the Rust
# runtime uses the AOT artifacts instead).
# ---------------------------------------------------------------------------


@functools.lru_cache(maxsize=None)
def ops_bundle(n_buckets: int, batch: int, k_batch: int = DEFAULT_RESIZE_K,
               max_evictions: int = DEFAULT_MAX_EVICTIONS):
    """All five programs, jitted, for one capacity class."""
    return {
        "lookup": jax.jit(lookup_fn(n_buckets, batch)),
        "insert": jax.jit(insert_fn(n_buckets, batch, max_evictions), donate_argnums=(0,)),
        "delete": jax.jit(delete_fn(n_buckets, batch), donate_argnums=(0,)),
        "split": jax.jit(split_fn(n_buckets, k_batch), donate_argnums=(0,)),
        "merge": jax.jit(merge_fn(n_buckets, k_batch), donate_argnums=(0,)),
    }


def pad_keys(keys, batch: int):
    """Pad a short key array to `batch` with the EMPTY sentinel."""
    keys = jnp.asarray(keys, dtype=jnp.uint32)
    assert keys.shape[0] <= batch, "batch overflow"
    pad = batch - keys.shape[0]
    return jnp.pad(keys, (0, pad), constant_values=int(C.EMPTY_KEY))


def pad_vals(vals, batch: int):
    """Pad a short value array to `batch` with zeros."""
    vals = jnp.asarray(vals, dtype=jnp.uint32)
    pad = batch - vals.shape[0]
    return jnp.pad(vals, (0, pad), constant_values=0)
