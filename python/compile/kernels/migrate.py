"""Linear-hashing split / merge migration kernels (paper §IV-C).

Split (§IV-C1): bucket ``b_src = split_ptr + g`` pairs with
``b_dst = b_src + 2^m``. Each lane decides stay-vs-move from the next
round's hash bit; movers are compacted into the partner with the
ballot + prefix-rank (``__popc(move_mask & ((1<<lane)-1))``) pattern —
here an exclusive cumulative sum over the lane axis, the vector-ISA
equivalent.

Merge (§IV-C2): the inverse; each mover takes the r-th free slot of the
destination (``select_nth_one`` prefix-rank mapping). A merge aborts if
the destination lacks room; because ``split_ptr`` must stay contiguous,
an abort also cancels all later merges in the batch (carried flag).

Both kernels donate the bucket array and run one pair per loop step —
the warp-parallel K-bucket batch of the paper with the batch serialized
on one core (multi-core sharding happens at the coordinator level).
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import common as C


def _which_hash_home(keys, b_src, index_mask):
    """For each lane's key, the raw hash that addressed it to b_src.

    The placement invariant guarantees h1 or h2 maps each live entry to
    its bucket under the current mask; empty lanes return 0.
    """
    h1 = C.bithash1(keys)
    h2 = C.bithash2(keys)
    use1 = (h1 & index_mask) == b_src
    return jnp.where(use1, h1, h2)


def make_split_kernel(k_batch: int):
    """Split `k_batch` buckets starting at split_ptr (statically bounded)."""

    def split_kernel(meta_ref, buckets_in_ref, buckets_ref, moved_ref):
        index_mask = meta_ref[0]
        split_ptr = meta_ref[1]
        m_base = index_mask + jnp.uint32(1)  # 2^m
        next_mask = (index_mask << 1) | jnp.uint32(1)
        buckets_ref[...] = buckets_in_ref[...]

        def split_one(g, total_moved):
            b_src = split_ptr + jnp.uint32(g)
            b_dst = b_src + m_base
            row = buckets_ref[pl.ds(b_src.astype(jnp.int32), 1), :]
            keys = C.unpack_key(row[0])
            live = keys != C.EMPTY_KEY
            h = _which_hash_home(keys, b_src, index_mask)
            should_move = live & ((h & next_mask) == b_dst)
            # ballot + prefix rank -> compacted placement. Formulated as a
            # gather (collision-free on a vector ISA): dst lane r takes the
            # source lane whose exclusive rank equals r.
            my_rank = jnp.cumsum(should_move.astype(jnp.int32)) - should_move.astype(jnp.int32)
            n_movers = should_move.sum().astype(jnp.int32)
            lane_idx = jnp.arange(C.SLOTS, dtype=jnp.int32)
            is_rank = (my_rank[None, :] == lane_idx[:, None]) & should_move[None, :]
            has = is_rank.any(axis=1)
            src_lane = jnp.argmax(is_rank, axis=1)
            dst_row = jnp.where(has, row[0][src_lane], jnp.uint64(C.EMPTY_WORD))
            new_src = jnp.where(should_move, jnp.uint64(C.EMPTY_WORD), row[0])
            buckets_ref[pl.ds(b_src.astype(jnp.int32), 1), :] = new_src[None, :]
            buckets_ref[pl.ds(b_dst.astype(jnp.int32), 1), :] = dst_row[None, :]
            return total_moved + n_movers

        moved = jax.lax.fori_loop(0, k_batch, split_one, jnp.int32(0))
        moved_ref[0] = moved.astype(jnp.uint32)

    return split_kernel


def make_merge_kernel(k_batch: int):
    """Merge up to `k_batch` pairs, last-split-first; aborts stay contiguous."""

    def merge_kernel(meta_ref, buckets_in_ref, buckets_ref, merged_ref):
        index_mask = meta_ref[0]
        split_ptr = meta_ref[1]  # > 0: mid-round state expected by caller
        m_base = index_mask + jnp.uint32(1)
        buckets_ref[...] = buckets_in_ref[...]

        def merge_one(g, carry):
            merged, alive = carry
            # merge pair g: dst = split_ptr - 1 - g, src = dst + 2^m
            b_dst = split_ptr - jnp.uint32(1) - jnp.uint32(g)
            b_src = b_dst + m_base
            in_range = split_ptr > jnp.uint32(g)
            ok = alive & in_range
            srow = buckets_ref[pl.ds(b_src.astype(jnp.int32), 1), :]
            drow = buckets_ref[pl.ds(b_dst.astype(jnp.int32), 1), :]
            skeys = C.unpack_key(srow[0])
            movers = skeys != C.EMPTY_KEY
            dfree = C.unpack_key(drow[0]) == C.EMPTY_KEY
            n_move = movers.sum()
            n_free = dfree.sum()
            fits = n_move <= n_free
            do = ok & fits
            # mover r takes the r-th free slot of dst (select_nth_one)
            mrank = jnp.cumsum(movers.astype(jnp.int32)) - movers.astype(jnp.int32)
            frank = jnp.cumsum(dfree.astype(jnp.int32)) - dfree.astype(jnp.int32)
            lane_idx = jnp.arange(C.SLOTS, dtype=jnp.int32)
            # for each dst lane: if free with rank r and r < n_move, take
            # the source lane whose mover-rank == r
            take = dfree & (frank < n_move)
            src_sel = (mrank[None, :] == frank[:, None]) & movers[None, :]
            src_lane = jnp.argmax(src_sel, axis=1)
            new_dst = jnp.where(do & take, srow[0][src_lane], drow[0])
            new_src = jnp.where(do, jnp.full((C.SLOTS,), C.EMPTY_WORD, jnp.uint64), srow[0])
            buckets_ref[pl.ds(b_dst.astype(jnp.int32), 1), :] = new_dst[None, :]
            buckets_ref[pl.ds(b_src.astype(jnp.int32), 1), :] = new_src[None, :]
            return (merged + do.astype(jnp.uint32), alive & fits & in_range)

        merged, _ = jax.lax.fori_loop(
            0, k_batch, merge_one, (jnp.uint32(0), jnp.bool_(True))
        )
        merged_ref[0] = merged

    return merge_kernel


def make_split(n_buckets: int, k_batch: int):
    """Jittable split of `k_batch` buckets (buckets donated).

    Caller must guarantee `split_ptr + k_batch <= 2^m` (no round crossing
    inside one artifact call — the coordinator chunks batches at round
    boundaries) and `2^m + split_ptr + k_batch <= n_buckets` physical room.
    Returns `(buckets', moved[1])`.
    """
    return pl.pallas_call(
        make_split_kernel(k_batch),
        out_shape=(
            jax.ShapeDtypeStruct((n_buckets, C.SLOTS), jnp.uint64),
            jax.ShapeDtypeStruct((1,), jnp.uint32),
        ),
        input_output_aliases={1: 0},
        interpret=True,
    )


def make_merge(n_buckets: int, k_batch: int):
    """Jittable merge of up to `k_batch` pairs (buckets donated).

    Returns `(buckets', merged[1])`; the caller regresses split_ptr by
    `merged` (and handles round regression before calling).
    """
    return pl.pallas_call(
        make_merge_kernel(k_batch),
        out_shape=(
            jax.ShapeDtypeStruct((n_buckets, C.SLOTS), jnp.uint64),
            jax.ShapeDtypeStruct((1,), jnp.uint32),
        ),
        input_output_aliases={1: 0},
        interpret=True,
    )
