"""WCME probe kernels: lookup and delete (paper §III-F, Algorithm 4).

The CUDA formulation: 32 lanes coalesced-load one packed KV each,
ballot on key match, ``__ffs`` elects the winner lane.

TPU adaptation (DESIGN.md §3): the 32-slot bucket row *is* the trailing
vector dimension; ballot+ffs become a lane-mask ``argmax``; the
data-dependent bucket gather a GPU warp issues directly becomes a dynamic
row slice of the bucket ref. The grid walks the key batch; grid steps are
sequential on a TPU core, which also gives delete its linearization order.

Kernels run ``interpret=True`` — CPU PJRT cannot execute Mosaic
custom-calls; on a real TPU the same kernel lowers via Mosaic with a
``(1, 32)`` row resident in VMEM per step.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import common as C


def _wcme_match(row, key):
    """Match-and-elect on one (1, 32) bucket row.

    Returns (found, lane): ballot = per-lane key equality; the elected
    winner is the first set lane (ffs == argmax over the bool mask).
    """
    match = C.unpack_key(row[0]) == key
    found = match.any()
    lane = jnp.argmax(match).astype(jnp.int32)
    return found, lane


def lookup_kernel(meta_ref, keys_ref, buckets_ref, values_ref, found_ref):
    """Batched Search(k) (§III-D): WCME over both candidate buckets."""
    index_mask = meta_ref[0]
    split_ptr = meta_ref[1]

    def body(i, _):
        k = keys_ref[i]
        valid = k != C.EMPTY_KEY  # sentinel queries match empty slots
        b1, b2 = C.candidate_buckets(k, index_mask, split_ptr)
        row1 = buckets_ref[pl.ds(b1.astype(jnp.int32), 1), :]
        f1, l1 = _wcme_match(row1, k)
        row2 = buckets_ref[pl.ds(b2.astype(jnp.int32), 1), :]
        f2, l2 = _wcme_match(row2, k)
        v1 = C.unpack_value(row1[0, l1])
        v2 = C.unpack_value(row2[0, l2])
        value = jnp.where(f1, v1, jnp.where(f2, v2, jnp.uint32(0)))
        found = valid & (f1 | f2)
        values_ref[pl.ds(i, 1)] = jnp.where(found, value, jnp.uint32(0))[None]
        found_ref[pl.ds(i, 1)] = found[None].astype(jnp.uint32)
        return 0

    jax.lax.fori_loop(0, keys_ref.shape[0], body, 0)


def delete_kernel(meta_ref, keys_ref, buckets_in_ref, buckets_ref, deleted_ref):
    """Batched Delete(k) (Algorithm 4).

    ``buckets_in_ref`` is aliased to ``buckets_ref`` (donated); the winner
    lane's slot is cleared to EMPTY. The free-mask publication step of the
    paper is implicit here: slot freeness is derived from the EMPTY word
    (DESIGN.md §3 — metadata-free adaptation).
    """
    index_mask = meta_ref[0]
    split_ptr = meta_ref[1]
    buckets_ref[...] = buckets_in_ref[...]

    def clear(b, lane):
        bi = b.astype(jnp.int32)
        buckets_ref[pl.ds(bi, 1), pl.ds(lane, 1)] = jnp.uint64(C.EMPTY_WORD)[None, None]

    def body(i, _):
        k = keys_ref[i]
        valid = k != C.EMPTY_KEY
        b1, b2 = C.candidate_buckets(k, index_mask, split_ptr)
        row1 = buckets_ref[pl.ds(b1.astype(jnp.int32), 1), :]
        f1, l1 = _wcme_match(row1, k)
        row2 = buckets_ref[pl.ds(b2.astype(jnp.int32), 1), :]
        f2, l2 = _wcme_match(row2, k)
        # winner clears the slot with a single store (the CAS's exclusive
        # analogue under grid-sequential semantics)
        target_b = jnp.where(f1, b1, b2)
        target_l = jnp.where(f1, l1, l2)
        hit = valid & (f1 | f2)
        # always store: on miss, rewrite the (unchanged) probed word
        bi = target_b.astype(jnp.int32)
        old = buckets_ref[pl.ds(bi, 1), pl.ds(target_l, 1)]
        neww = jnp.where(hit, jnp.uint64(C.EMPTY_WORD), old[0, 0])
        buckets_ref[pl.ds(bi, 1), pl.ds(target_l, 1)] = neww[None, None]
        deleted_ref[pl.ds(i, 1)] = hit[None].astype(jnp.uint32)
        return 0

    _ = clear
    jax.lax.fori_loop(0, keys_ref.shape[0], body, 0)


def make_lookup(n_buckets: int, batch: int):
    """Build the jittable batched-lookup callable for one capacity class."""
    return pl.pallas_call(
        lookup_kernel,
        out_shape=(
            jax.ShapeDtypeStruct((batch,), jnp.uint32),  # values
            jax.ShapeDtypeStruct((batch,), jnp.uint32),  # found
        ),
        interpret=True,
    )


def make_delete(n_buckets: int, batch: int):
    """Build the jittable batched-delete callable (buckets donated)."""
    return pl.pallas_call(
        delete_kernel,
        out_shape=(
            jax.ShapeDtypeStruct((n_buckets, C.SLOTS), jnp.uint64),  # buckets'
            jax.ShapeDtypeStruct((batch,), jnp.uint32),  # deleted
        ),
        input_output_aliases={2: 0},
        interpret=True,
    )
