"""Shared constants and helpers for the Hive Pallas kernels.

Mirrors `rust/src/core/packed.rs` and `rust/src/hash/bithash.rs` bit for
bit: the packed 64-bit KV word (key low, value high), the EMPTY sentinels,
the BitHash1/BitHash2 mixers (the paper's default d=2 family, Listing 1)
and the linear-hashing address reduction (§IV-C).

Everything here is traced into the kernels and into the L2 model, so the
Rust runtime, the native table and the XLA artifacts all agree on layout.
"""

import jax
import jax.numpy as jnp

jax.config.update("jax_enable_x64", True)

# Paper constants (§III-A / §III-B). Plain ints so kernels don't close
# over module-level arrays (pallas rejects captured constants).
SLOTS = 32
EMPTY_KEY = 0xFFFFFFFF
EMPTY_WORD = 0xFFFFFFFFFFFFFFFF

# Insert status codes returned by the insert kernel (paper's four steps).
ST_REPLACED = 0
ST_CLAIMED = 1
ST_EVICTED = 2
ST_OVERFLOW = 3
ST_SKIPPED = 4  # padded slot in a short batch


def pack(key, value):
    """pair = (value << 32) | key (paper §III-A)."""
    return (value.astype(jnp.uint64) << 32) | key.astype(jnp.uint64)


def unpack_key(word):
    """key = pair & 0xFFFFFFFF."""
    return (word & 0xFFFFFFFF).astype(jnp.uint32)


def unpack_value(word):
    """value = pair >> 32."""
    return (word >> 32).astype(jnp.uint32)


def bithash1(key):
    """Thomas-Wang mixer — BitHash1 (Listing 1). uint32 in/out."""
    key = key.astype(jnp.uint32)
    key = (~key) + (key << 15)
    key = key ^ (key >> 12)
    key = key + (key << 2)
    key = key ^ (key >> 4)
    key = key * jnp.uint32(2057)
    key = key ^ (key >> 16)
    return key


def bithash2(key):
    """Bob-Jenkins 6-shift mixer — BitHash2 (Listing 1). uint32 in/out."""
    key = key.astype(jnp.uint32)
    key = (key + jnp.uint32(0x7ED55D16)) + (key << 12)
    key = (key ^ jnp.uint32(0xC761C23C)) ^ (key >> 19)
    key = (key + jnp.uint32(0x165667B1)) + (key << 5)
    key = (key + jnp.uint32(0xD3A2646C)) ^ (key << 9)
    key = (key + jnp.uint32(0xFD7046C5)) + (key << 3)
    key = (key ^ jnp.uint32(0xB55A4F09)) ^ (key >> 16)
    return key


def lh_address(h, index_mask, split_ptr):
    """Linear-hashing bucket address (§IV-C).

    b = h & index_mask; buckets below split_ptr (already split this round)
    re-reduce with the next round's mask.
    """
    b = h & index_mask
    next_mask = (index_mask << 1) | jnp.uint32(1)
    return jnp.where(b < split_ptr, h & next_mask, b)


def candidate_buckets(key, index_mask, split_ptr):
    """The two candidate buckets of `key` under the default family."""
    b1 = lh_address(bithash1(key), index_mask, split_ptr)
    b2 = lh_address(bithash2(key), index_mask, split_ptr)
    return b1, b2


def alt_bucket(key, current_b, index_mask, split_ptr):
    """Algorithm 3's AltBucket: the candidate != current_b (or b1)."""
    b1, b2 = candidate_buckets(key, index_mask, split_ptr)
    return jnp.where(b1 != current_b, b1, b2)
