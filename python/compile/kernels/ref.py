"""Pure-Python/numpy oracle for the Pallas kernels.

Implements the *identical* deterministic semantics — same candidate order,
same two-choice rule, same first-free-lane election, same lane-0 victim,
same alt-bucket rule — so pytest can assert exact equality between a
kernel run and the oracle on arbitrary inputs (hypothesis sweeps).
"""

import numpy as np

SLOTS = 32
EMPTY_KEY = np.uint32(0xFFFFFFFF)
EMPTY_WORD = np.uint64(0xFFFFFFFFFFFFFFFF)

ST_REPLACED, ST_CLAIMED, ST_EVICTED, ST_OVERFLOW, ST_SKIPPED = 0, 1, 2, 3, 4

_U32 = np.uint32
_MASK32 = np.uint64(0xFFFFFFFF)


def pack(key, value):
    """pair = (value << 32) | key."""
    return (np.uint64(value) << np.uint64(32)) | np.uint64(key)


def unpack_key(word):
    """key = pair & 0xFFFFFFFF."""
    return _U32(np.uint64(word) & _MASK32)


def unpack_value(word):
    """value = pair >> 32."""
    return _U32(np.uint64(word) >> np.uint64(32))


def bithash1(key):
    """BitHash1 (Thomas Wang), numpy uint32 wrapping arithmetic."""
    with np.errstate(over="ignore"):
        key = _U32(key)
        key = _U32(~key + (key << _U32(15)))
        key = _U32(key ^ (key >> _U32(12)))
        key = _U32(key + (key << _U32(2)))
        key = _U32(key ^ (key >> _U32(4)))
        key = _U32(key * _U32(2057))
        key = _U32(key ^ (key >> _U32(16)))
    return key


def bithash2(key):
    """BitHash2 (Bob Jenkins 6-shift)."""
    with np.errstate(over="ignore"):
        key = _U32(key)
        key = _U32((key + _U32(0x7ED55D16)) + (key << _U32(12)))
        key = _U32((key ^ _U32(0xC761C23C)) ^ (key >> _U32(19)))
        key = _U32((key + _U32(0x165667B1)) + (key << _U32(5)))
        key = _U32((key + _U32(0xD3A2646C)) ^ (key << _U32(9)))
        key = _U32((key + _U32(0xFD7046C5)) + (key << _U32(3)))
        key = _U32((key ^ _U32(0xB55A4F09)) ^ (key >> _U32(16)))
    return key


def lh_address(h, index_mask, split_ptr):
    """Linear-hashing address reduction."""
    b = _U32(h) & _U32(index_mask)
    if b < _U32(split_ptr):
        return _U32(h) & _U32((int(index_mask) << 1) | 1)
    return b


def candidates(key, index_mask, split_ptr):
    """Candidate buckets (b1, b2)."""
    return (
        int(lh_address(bithash1(key), index_mask, split_ptr)),
        int(lh_address(bithash2(key), index_mask, split_ptr)),
    )


def alt_bucket(key, current_b, index_mask, split_ptr):
    """AltBucket: the candidate != current_b, else b1."""
    b1, b2 = candidates(key, index_mask, split_ptr)
    return b1 if b1 != current_b else b2


def new_table(n_buckets):
    """An empty bucket array."""
    return np.full((n_buckets, SLOTS), EMPTY_WORD, dtype=np.uint64)


def lookup_batch(buckets, meta, keys):
    """Oracle for probe.lookup_kernel."""
    index_mask, split_ptr = int(meta[0]), int(meta[1])
    values = np.zeros(len(keys), dtype=np.uint32)
    found = np.zeros(len(keys), dtype=np.uint32)
    for i, k in enumerate(keys):
        if _U32(k) == EMPTY_KEY:
            continue
        for b in candidates(k, index_mask, split_ptr):
            row = buckets[b]
            match = unpack_key(row) == _U32(k)
            if match.any():
                lane = int(np.argmax(match))
                values[i] = unpack_value(row[lane])
                found[i] = 1
                break
    return values, found


def delete_batch(buckets, meta, keys):
    """Oracle for probe.delete_kernel (mutates a copy)."""
    buckets = buckets.copy()
    index_mask, split_ptr = int(meta[0]), int(meta[1])
    deleted = np.zeros(len(keys), dtype=np.uint32)
    for i, k in enumerate(keys):
        if _U32(k) == EMPTY_KEY:
            continue
        for b in candidates(k, index_mask, split_ptr):
            row = buckets[b]
            match = unpack_key(row) == _U32(k)
            if match.any():
                lane = int(np.argmax(match))
                buckets[b, lane] = EMPTY_WORD
                deleted[i] = 1
                break
    return buckets, deleted


def insert_batch(buckets, meta, keys, vals, max_evictions=16):
    """Oracle for insert.make_insert_kernel — identical decision rules."""
    buckets = buckets.copy()
    index_mask, split_ptr = int(meta[0]), int(meta[1])
    status = np.zeros(len(keys), dtype=np.uint32)
    overflow = np.full(len(keys), EMPTY_WORD, dtype=np.uint64)
    for i, (k, v) in enumerate(zip(keys, vals)):
        if _U32(k) == EMPTY_KEY:
            status[i] = ST_SKIPPED
            continue
        word = pack(k, v)
        b1, b2 = candidates(k, index_mask, split_ptr)
        # step 1: replace — b1 priority
        done = False
        for b in (b1, b2):
            match = unpack_key(buckets[b]) == _U32(k)
            if match.any():
                buckets[b, int(np.argmax(match))] = word
                status[i] = ST_REPLACED
                done = True
                break
        if done:
            continue
        # step 2: claim — two-choice (emptier first, ties -> b1), then other
        free1 = unpack_key(buckets[b1]) == EMPTY_KEY
        free2 = unpack_key(buckets[b2]) == EMPTY_KEY
        order = (b1, b2) if free1.sum() >= free2.sum() else (b2, b1)
        claimed = False
        for b in order:
            free = unpack_key(buckets[b]) == EMPTY_KEY
            if free.any():
                buckets[b, int(np.argmax(free))] = word
                status[i] = ST_CLAIMED
                claimed = True
                break
        if claimed:
            continue
        # step 3: bounded eviction starting at b1, lane-0 victim
        cur_word, cur_b = word, b1
        placed = False
        for _ in range(max_evictions):
            free = unpack_key(buckets[cur_b]) == EMPTY_KEY
            if free.any():
                buckets[cur_b, int(np.argmax(free))] = cur_word
                placed = True
                break
            victim = buckets[cur_b, 0]
            buckets[cur_b, 0] = cur_word
            cur_word = victim
            cur_b = alt_bucket(unpack_key(victim), cur_b, index_mask, split_ptr)
        if placed:
            status[i] = ST_EVICTED
        else:
            status[i] = ST_OVERFLOW
            overflow[i] = cur_word
    return buckets, status, overflow


def split_batch(buckets, meta, k_batch):
    """Oracle for migrate.make_split_kernel (no meta update)."""
    buckets = buckets.copy()
    index_mask, split_ptr = int(meta[0]), int(meta[1])
    m_base = index_mask + 1
    next_mask = (index_mask << 1) | 1
    moved = 0
    for g in range(k_batch):
        b_src = split_ptr + g
        b_dst = b_src + m_base
        dst_rank = 0
        for lane in range(SLOTS):
            w = buckets[b_src, lane]
            k = unpack_key(w)
            if k == EMPTY_KEY:
                continue
            h = bithash1(k) if (int(bithash1(k)) & index_mask) == b_src else bithash2(k)
            if (int(h) & next_mask) == b_dst:
                buckets[b_dst, dst_rank] = w
                buckets[b_src, lane] = EMPTY_WORD
                dst_rank += 1
                moved += 1
    return buckets, moved


def merge_batch(buckets, meta, k_batch):
    """Oracle for migrate.make_merge_kernel."""
    buckets = buckets.copy()
    index_mask, split_ptr = int(meta[0]), int(meta[1])
    m_base = index_mask + 1
    merged = 0
    for g in range(k_batch):
        if split_ptr - g <= 0:
            break
        b_dst = split_ptr - 1 - g
        b_src = b_dst + m_base
        movers = [lane for lane in range(SLOTS) if unpack_key(buckets[b_src, lane]) != EMPTY_KEY]
        frees = [lane for lane in range(SLOTS) if unpack_key(buckets[b_dst, lane]) == EMPTY_KEY]
        if len(movers) > len(frees):
            break  # abort: stays contiguous
        for r, src_lane in enumerate(movers):
            buckets[b_dst, frees[r]] = buckets[b_src, src_lane]
            buckets[b_src, src_lane] = EMPTY_WORD
        merged += 1
    return buckets, merged
