"""The four-step insert kernel (paper §IV-A, Algorithms 1–3).

Per key: (1) replace-if-present via WCME, (2) claim-then-commit into the
emptier candidate (bucketed two-choice + WABC), (3) bounded cuckoo
eviction, (4) overflow hand-off. The GPU's warp-level concurrency becomes
grid-sequential batch order (DESIGN.md §3): each key's four steps run to
completion before the next key — the same linearization the GPU reaches
through its atomics, without needing CAS.

Step 4 differs from CUDA by necessity: the overflow stash lives on the
*coordinator* (Rust) side, so the kernel returns each homeless packed word
in ``overflow[i]`` and the L3 stash absorbs it (and re-injects after the
next resize epoch, as in §IV-A).

WABC adaptation note: the free mask exists on the GPU to avoid reading 32
slots; on a vector core the row load is one VMEM vector, so freeness is
derived from the EMPTY key directly and the "claim" is the elected first
free lane of the row (metadata-free WABC — DESIGN.md §3).
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import common as C


def _free_lanes(row):
    """Per-lane freeness (bit i of the conceptual freeMask) + count."""
    free = C.unpack_key(row[0]) == C.EMPTY_KEY
    return free, free.sum()


def make_insert_kernel(max_evictions: int):
    """Kernel factory: `max_evictions` is baked statically (a config
    constant in the paper's global metadata)."""

    def insert_kernel(meta_ref, keys_ref, vals_ref, buckets_in_ref,
                      buckets_ref, status_ref, overflow_ref):
        index_mask = meta_ref[0]
        split_ptr = meta_ref[1]
        buckets_ref[...] = buckets_in_ref[...]

        def store_word(b, lane, word):
            buckets_ref[pl.ds(b.astype(jnp.int32), 1), pl.ds(lane, 1)] = (
                word[None, None]
            )

        def body(i, _):
            k = keys_ref[i]
            v = vals_ref[i]
            word = C.pack(k, v)
            valid = k != C.EMPTY_KEY
            b1, b2 = C.candidate_buckets(k, index_mask, split_ptr)

            # ---- Step 1: Replace (Algorithm 1) ----
            row1 = buckets_ref[pl.ds(b1.astype(jnp.int32), 1), :]
            m1 = C.unpack_key(row1[0]) == k
            row2 = buckets_ref[pl.ds(b2.astype(jnp.int32), 1), :]
            m2 = C.unpack_key(row2[0]) == k
            hit1 = m1.any()
            hit2 = m2.any()
            rep_b = jnp.where(hit1, b1, b2)
            rep_l = jnp.where(hit1, jnp.argmax(m1), jnp.argmax(m2)).astype(jnp.int32)
            replaced = valid & (hit1 | hit2)
            old = buckets_ref[pl.ds(rep_b.astype(jnp.int32), 1), pl.ds(rep_l, 1)]
            store_word(rep_b, rep_l, jnp.where(replaced, word, old[0, 0]))

            # ---- Step 2: Claim-then-commit (WABC, Algorithm 2) ----
            free1, n1 = _free_lanes(row1)
            free2, n2 = _free_lanes(row2)
            # bucketed two-choice: prefer the emptier candidate (§V)
            pick1 = n1 >= n2
            cl_b = jnp.where(pick1, b1, b2)
            cl_free = jnp.where(pick1, free1, free2)
            cl_other_b = jnp.where(pick1, b2, b1)
            cl_other_free = jnp.where(pick1, free2, free1)
            have1 = cl_free.any()
            have2 = cl_other_free.any()
            cl_tb = jnp.where(have1, cl_b, cl_other_b)
            cl_tfree = jnp.where(have1, cl_free, cl_other_free)
            claim_lane = jnp.argmax(cl_tfree).astype(jnp.int32)  # elect first free
            can_claim = valid & (~replaced) & (have1 | have2)
            oldc = buckets_ref[pl.ds(cl_tb.astype(jnp.int32), 1), pl.ds(claim_lane, 1)]
            store_word(cl_tb, claim_lane, jnp.where(can_claim, word, oldc[0, 0]))

            # ---- Step 3: bounded cuckoo eviction (Algorithm 3) ----
            need_evict = valid & (~replaced) & (~can_claim)

            def evict_round(_, carry):
                cur_word, cur_b, done = carry
                row = buckets_ref[pl.ds(cur_b.astype(jnp.int32), 1), :]
                free, nf = _free_lanes(row)
                has_free = free.any()
                lane = jnp.where(has_free, jnp.argmax(free), 0).astype(jnp.int32)
                # (i) free slot appeared: place without evicting
                # (ii) else displace the first occupied slot (lane 0)
                victim = row[0, lane]
                place = (~done)
                neww = jnp.where(place, cur_word, victim)
                store_word(cur_b, lane, neww)
                placed_no_evict = place & has_free
                evicted = place & (~has_free)
                vkey = C.unpack_key(victim)
                next_b = C.alt_bucket(vkey, cur_b, index_mask, split_ptr)
                new_word = jnp.where(evicted, victim, cur_word)
                new_b = jnp.where(evicted, next_b, cur_b)
                new_done = done | placed_no_evict
                return new_word, new_b, new_done

            ev_word0 = jnp.where(need_evict, word, jnp.uint64(C.EMPTY_WORD))
            # evictions start at the first candidate bucket
            ev_word, ev_b, ev_done = jax.lax.fori_loop(
                0, max_evictions, evict_round,
                (ev_word0, b1, ~need_evict),
            )
            evict_ok = need_evict & ev_done

            # ---- Step 4: overflow hand-off ----
            overflow = need_evict & (~ev_done)
            overflow_ref[pl.ds(i, 1)] = jnp.where(
                overflow, ev_word, jnp.uint64(C.EMPTY_WORD)
            )[None]

            status = jnp.where(
                ~valid,
                jnp.uint32(C.ST_SKIPPED),
                jnp.where(
                    replaced,
                    jnp.uint32(C.ST_REPLACED),
                    jnp.where(
                        can_claim,
                        jnp.uint32(C.ST_CLAIMED),
                        jnp.where(
                            evict_ok,
                            jnp.uint32(C.ST_EVICTED),
                            jnp.uint32(C.ST_OVERFLOW),
                        ),
                    ),
                ),
            )
            status_ref[pl.ds(i, 1)] = status[None]
            return 0

        jax.lax.fori_loop(0, keys_ref.shape[0], body, 0)

    return insert_kernel


def make_insert(n_buckets: int, batch: int, max_evictions: int = 16):
    """Build the jittable batched-insert callable (buckets donated).

    Returns ``(buckets', status[B], overflow_words[B])``.
    """
    return pl.pallas_call(
        make_insert_kernel(max_evictions),
        out_shape=(
            jax.ShapeDtypeStruct((n_buckets, C.SLOTS), jnp.uint64),
            jax.ShapeDtypeStruct((batch,), jnp.uint32),
            jax.ShapeDtypeStruct((batch,), jnp.uint64),
        ),
        input_output_aliases={3: 0},
        interpret=True,
    )
