"""Kernel-vs-oracle correctness: the CORE L1 signal.

Every Pallas kernel is asserted *bit-exact* against the pure numpy oracle
in `compile.kernels.ref` across randomized shapes, occupancies and round
states (the hypothesis-style sweep is seeded-random driven to keep the
dependency footprint at zero).
"""

import numpy as np
import jax.numpy as jnp
import pytest

from compile import model
from compile.kernels import common as C
from compile.kernels import ref

EMPTY_KEY = 0xFFFFFFFF


def rand_keys(rng, n, hi=2**31):
    return rng.choice(hi, size=n, replace=False).astype(np.uint32)


def make_filled(n_buckets, n_keys, seed, index_mask=None, split_ptr=0, batch=None):
    """A table pre-filled via the *oracle* (so kernels are tested against
    independent state), plus the keys/vals used."""
    rng = np.random.default_rng(seed)
    keys = rand_keys(rng, n_keys)
    vals = (keys ^ 0xABCD).astype(np.uint32)
    index_mask = n_buckets - 1 if index_mask is None else index_mask
    meta = np.array([index_mask, split_ptr, 0, 0], dtype=np.uint32)
    buckets, status, _ = ref.insert_batch(ref.new_table(n_buckets), meta, keys, vals)
    return buckets, meta, keys, vals, status


# ---------------------------------------------------------------------------
# bithash / addressing agreement (kernel helpers vs oracle)
# ---------------------------------------------------------------------------


def test_bithash_matches_ref():
    ks = np.arange(0, 200_000, 37, dtype=np.uint32)
    j1 = np.array(C.bithash1(jnp.asarray(ks)))
    j2 = np.array(C.bithash2(jnp.asarray(ks)))
    r1 = np.array([ref.bithash1(k) for k in ks], dtype=np.uint32)
    r2 = np.array([ref.bithash2(k) for k in ks], dtype=np.uint32)
    np.testing.assert_array_equal(j1, r1)
    np.testing.assert_array_equal(j2, r2)


def test_lh_address_matches_ref():
    rng = np.random.default_rng(0)
    hs = rng.integers(0, 2**32, size=2000, dtype=np.uint64).astype(np.uint32)
    for mask, sp in [(7, 0), (7, 3), (63, 17), (1023, 1023)]:
        j = np.array(
            C.lh_address(jnp.asarray(hs), jnp.uint32(mask), jnp.uint32(sp))
        )
        r = np.array([ref.lh_address(h, mask, sp) for h in hs], dtype=np.uint32)
        np.testing.assert_array_equal(j, r, err_msg=f"mask={mask} sp={sp}")


# ---------------------------------------------------------------------------
# lookup kernel
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n_buckets,n_keys,seed", [
    (16, 100, 0), (64, 1500, 1), (32, 900, 2), (128, 200, 3),
])
def test_lookup_matches_ref(n_buckets, n_keys, seed):
    buckets, meta, keys, vals, _ = make_filled(n_buckets, n_keys, seed)
    B = len(keys) + 32  # include misses
    rng = np.random.default_rng(seed + 99)
    miss = rand_keys(rng, 32, hi=2**31) | 0x8000_0000  # disjoint range
    queries = np.concatenate([keys, miss.astype(np.uint32)])
    ops = model.ops_bundle(n_buckets, B)
    v, f = ops["lookup"](jnp.asarray(buckets), jnp.asarray(meta), jnp.asarray(queries))
    rv, rf = ref.lookup_batch(buckets, meta, queries)
    np.testing.assert_array_equal(np.array(v), rv)
    np.testing.assert_array_equal(np.array(f), rf)
    assert rf[: len(keys)].all(), "all inserted keys must be found"


def test_lookup_mid_round_state():
    # partial linear-hashing round: mask=15, split_ptr=5 (21 logical)
    buckets, meta, keys, vals, _ = make_filled(
        64, 400, 7, index_mask=15, split_ptr=5
    )
    ops = model.ops_bundle(64, len(keys))
    v, f = ops["lookup"](jnp.asarray(buckets), jnp.asarray(meta), jnp.asarray(keys))
    rv, rf = ref.lookup_batch(buckets, meta, keys)
    np.testing.assert_array_equal(np.array(v), rv)
    np.testing.assert_array_equal(np.array(f), rf)


# ---------------------------------------------------------------------------
# insert kernel
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n_buckets,n_keys,seed,max_ev", [
    (16, 128, 10, 8),
    (16, 480, 11, 8),    # ~94% load factor: eviction + overflow exercised
    (64, 1800, 12, 16),
    (8, 250, 13, 4),     # tiny table, deep contention
])
def test_insert_matches_ref(n_buckets, n_keys, seed, max_ev):
    rng = np.random.default_rng(seed)
    keys = rand_keys(rng, n_keys)
    vals = (keys * 3).astype(np.uint32)
    meta = np.array([n_buckets - 1, 0, 0, 0], dtype=np.uint32)
    ops = model.ops_bundle(n_buckets, n_keys, max_evictions=max_ev)
    empty, _ = model.new_table(n_buckets)
    nb, st, ov = ops["insert"](
        empty, jnp.asarray(meta), jnp.asarray(keys), jnp.asarray(vals)
    )
    rb, rst, rov = ref.insert_batch(
        ref.new_table(n_buckets), meta, keys, vals, max_evictions=max_ev
    )
    np.testing.assert_array_equal(np.array(st), rst)
    np.testing.assert_array_equal(np.array(nb), rb)
    np.testing.assert_array_equal(np.array(ov), rov)


def test_insert_replace_semantics():
    n, B = 16, 64
    rng = np.random.default_rng(20)
    keys = rand_keys(rng, B)
    meta = np.array([n - 1, 0, 0, 0], dtype=np.uint32)
    ops = model.ops_bundle(n, B)
    empty, _ = model.new_table(n)
    nb, st, _ = ops["insert"](empty, jnp.asarray(meta), jnp.asarray(keys),
                              jnp.asarray(keys))
    # re-insert the same keys with new values: all must report REPLACED
    nb2, st2, _ = ops["insert"](nb, jnp.asarray(meta), jnp.asarray(keys),
                                jnp.asarray((keys + 1).astype(np.uint32)))
    assert (np.array(st2) == ref.ST_REPLACED).all()
    v, f = ops["lookup"](nb2, jnp.asarray(meta), jnp.asarray(keys))
    np.testing.assert_array_equal(np.array(v), (keys + 1).astype(np.uint32))
    assert np.array(f).all()


def test_insert_padded_batch_skips():
    n, B = 16, 32
    meta = np.array([n - 1, 0, 0, 0], dtype=np.uint32)
    ops = model.ops_bundle(n, B)
    empty, _ = model.new_table(n)
    keys = model.pad_keys(np.array([1, 2, 3], np.uint32), B)
    vals = model.pad_vals(np.array([10, 20, 30], np.uint32), B)
    nb, st, _ = ops["insert"](empty, jnp.asarray(meta), keys, vals)
    st = np.array(st)
    assert (st[:3] == ref.ST_CLAIMED).all()
    assert (st[3:] == ref.ST_SKIPPED).all()
    v, f = ops["lookup"](nb, jnp.asarray(meta), keys)
    assert np.array(f)[:3].all() and not np.array(f)[3:].any()


def test_insert_duplicate_keys_within_batch():
    # the second occurrence must replace the first (grid-sequential order)
    n, B = 16, 8
    meta = np.array([n - 1, 0, 0, 0], dtype=np.uint32)
    ops = model.ops_bundle(n, B)
    empty, _ = model.new_table(n)
    keys = np.array([5, 6, 5, 7, 5, 8, 9, 10], np.uint32)
    vals = np.array([1, 2, 3, 4, 5, 6, 7, 8], np.uint32)
    nb, st, _ = ops["insert"](empty, jnp.asarray(meta), jnp.asarray(keys), jnp.asarray(vals))
    st = np.array(st)
    assert st[0] == ref.ST_CLAIMED and st[2] == ref.ST_REPLACED and st[4] == ref.ST_REPLACED
    v, f = ops["lookup"](nb, jnp.asarray(meta), jnp.asarray(keys))
    assert np.array(v)[0] == 5  # last write wins


# ---------------------------------------------------------------------------
# delete kernel
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n_buckets,n_keys,seed", [(16, 300, 30), (64, 1500, 31)])
def test_delete_matches_ref(n_buckets, n_keys, seed):
    buckets, meta, keys, vals, _ = make_filled(n_buckets, n_keys, seed)
    rng = np.random.default_rng(seed)
    # delete half the keys + some misses, with duplicates
    half = rng.choice(keys, size=n_keys // 2, replace=False)
    miss = (rand_keys(rng, 16) | 0x8000_0000).astype(np.uint32)
    dup = half[:8]
    targets = np.concatenate([half, miss, dup])
    ops = model.ops_bundle(n_buckets, len(targets))
    nb, dl = ops["delete"](jnp.asarray(buckets), jnp.asarray(meta), jnp.asarray(targets))
    rb, rdl = ref.delete_batch(buckets, meta, targets)
    np.testing.assert_array_equal(np.array(dl), rdl)
    np.testing.assert_array_equal(np.array(nb), rb)
    # deleted keys are gone, kept keys remain
    kept = np.setdiff1d(keys, half)
    ops2 = model.ops_bundle(n_buckets, len(kept))
    _, f = ops2["lookup"](nb, jnp.asarray(meta), jnp.asarray(kept))
    assert np.array(f).all()


# ---------------------------------------------------------------------------
# split / merge kernels
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("seed,occupancy", [(40, 0.4), (41, 0.85)])
def test_split_full_round_matches_ref(seed, occupancy):
    n_phys, mask = 32, 7  # 8 logical buckets, room to double
    n_keys = int(8 * 32 * occupancy)
    buckets, meta, keys, vals, _ = make_filled(n_phys, n_keys, seed, index_mask=mask)
    k_batch = 8
    ops = model.ops_bundle(n_phys, n_keys, k_batch=k_batch)
    sb, smeta, moved = ops["split"](jnp.asarray(buckets), jnp.asarray(meta))
    rb, rmoved = ref.split_batch(buckets, meta, k_batch)
    np.testing.assert_array_equal(np.array(sb), rb)
    assert int(moved[0]) == rmoved
    assert list(np.array(smeta)[:2]) == [15, 0]  # round advanced
    # every key still findable under the new round state
    v, f = ops["lookup"](sb, smeta, jnp.asarray(keys))
    assert np.array(f).all()
    np.testing.assert_array_equal(np.array(v), vals)


def test_split_partial_round():
    n_phys, mask = 32, 7
    buckets, meta, keys, vals, _ = make_filled(n_phys, 120, 42, index_mask=mask)
    ops = model.ops_bundle(n_phys, 120, k_batch=3)
    sb, smeta, _ = ops["split"](jnp.asarray(buckets), jnp.asarray(meta))
    assert list(np.array(smeta)[:2]) == [7, 3]  # mid-round
    v, f = ops["lookup"](sb, smeta, jnp.asarray(keys))
    assert np.array(f).all()
    np.testing.assert_array_equal(np.array(v), vals)


def test_merge_roundtrip_preserves_entries():
    n_phys, mask = 32, 7
    buckets, meta, keys, vals, _ = make_filled(n_phys, 100, 43, index_mask=mask)
    ops = model.ops_bundle(n_phys, 100, k_batch=8)
    sb, smeta, _ = ops["split"](jnp.asarray(buckets), jnp.asarray(meta))
    sb_np = np.array(sb)
    # coordinator-style regress: (15,0) -> (7,8), then merge 8
    meta_mr = np.array([7, 8, 0, 0], np.uint32)
    mb, mmeta, merged = ops["merge"](sb, jnp.asarray(meta_mr))
    rb, rmerged = ref.merge_batch(sb_np, meta_mr, 8)
    np.testing.assert_array_equal(np.array(mb), rb)
    assert int(merged[0]) == rmerged == 8
    assert list(np.array(mmeta)[:2]) == [7, 0]
    v, f = ops["lookup"](mb, jnp.asarray(mmeta), jnp.asarray(keys))
    assert np.array(f).all()
    np.testing.assert_array_equal(np.array(v), vals)


def test_merge_aborts_when_pair_too_full():
    # fill bucket pair (0, 8) beyond 32 combined live entries via dense fill
    n_phys, mask = 32, 15  # 16 logical
    buckets, meta, keys, vals, _ = make_filled(n_phys, 15 * 32, 44, index_mask=mask)
    # regress to (7, 8): pairs (7,15), (6,14), ... all nearly full
    meta_mr = np.array([7, 8, 0, 0], np.uint32)
    ops = model.ops_bundle(n_phys, 15 * 32, k_batch=8)
    mb, mmeta, merged = ops["merge"](jnp.asarray(buckets), jnp.asarray(meta_mr))
    rb, rmerged = ref.merge_batch(buckets, meta_mr, 8)
    assert int(merged[0]) == rmerged
    assert rmerged < 8, "dense pairs must abort merging"
    np.testing.assert_array_equal(np.array(mb), rb)


# ---------------------------------------------------------------------------
# randomized sweep (hypothesis-style, seeded)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("trial", range(10))
def test_randomized_mixed_sequences(trial):
    rng = np.random.default_rng(1000 + trial)
    n_buckets = int(rng.choice([8, 16, 32]))
    if rng.random() < 0.5:
        # mid-round state: logical range [2^m, 2^(m+1)) must fit physically
        mask = n_buckets // 2 - 1
        sp = int(rng.integers(0, mask + 2))
    else:
        mask = n_buckets - 1
        sp = 0
    meta = np.array([mask, sp, 0, 0], np.uint32)
    B = int(rng.choice([32, 64, 128]))
    ops = model.ops_bundle(n_buckets, B, max_evictions=8)

    buckets_j, _ = model.new_table(n_buckets)
    buckets_r = ref.new_table(n_buckets)
    for _round in range(3):
        keys = rand_keys(rng, B)
        vals = rng.integers(0, 2**32, size=B, dtype=np.uint64).astype(np.uint32)
        bj, sj, oj = ops["insert"](buckets_j, jnp.asarray(meta), jnp.asarray(keys), jnp.asarray(vals))
        buckets_r, sr, orr = ref.insert_batch(buckets_r, meta, keys, vals, max_evictions=8)
        np.testing.assert_array_equal(np.array(sj), sr, err_msg=f"trial {trial}")
        np.testing.assert_array_equal(np.array(bj), buckets_r)
        np.testing.assert_array_equal(np.array(oj), orr)
        buckets_j = bj
        # delete a random subset
        dels = rng.choice(keys, size=B // 3, replace=False)
        dels = np.pad(dels, (0, B - len(dels)), constant_values=EMPTY_KEY)
        bj, dj = ops["delete"](buckets_j, jnp.asarray(meta), jnp.asarray(dels))
        buckets_r, dr = ref.delete_batch(buckets_r, meta, dels)
        np.testing.assert_array_equal(np.array(dj), dr)
        np.testing.assert_array_equal(np.array(bj), buckets_r)
        buckets_j = bj
