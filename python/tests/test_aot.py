"""AOT lowering smoke tests: every op lowers to parseable HLO text with
the expected parameter shapes (the contract the Rust runtime relies on)."""

import jax.numpy as jnp

from compile import aot, model
from compile.kernels import common as C


def test_lower_ops_produces_all_five_programs():
    ops = aot.lower_ops(n_buckets=64, batch=32, k_batch=8, max_ev=4)
    assert set(ops) == {"lookup", "insert", "delete", "split", "merge"}
    for name, text in ops.items():
        assert text.startswith("HloModule"), f"{name} not HLO text"
        assert "u64[64,32]" in text, f"{name} missing bucket param"
        assert "u32[4]" in text, f"{name} missing meta param"


def test_insert_hlo_mentions_batch_shape():
    ops = aot.lower_ops(n_buckets=16, batch=48, k_batch=4, max_ev=2)
    assert "u32[48]" in ops["insert"]
    assert "u32[48]" in ops["lookup"]


def test_manifest_line_format_roundtrip():
    # mirror of the Rust ArtifactSpec::parse contract
    line = (
        "op=insert n_buckets=1024 batch=4096 k_batch=256 "
        "max_evictions=16 slots=32 file=insert_1024.hlo.txt"
    )
    kv = dict(tok.split("=") for tok in line.split())
    assert kv["op"] == "insert"
    assert int(kv["n_buckets"]) == 1024
    assert kv["file"].endswith(".hlo.txt")


def test_pad_helpers():
    keys = model.pad_keys(jnp.array([1, 2], dtype=jnp.uint32), 8)
    assert keys.shape == (8,)
    assert int(keys[0]) == 1 and int(keys[-1]) == C.EMPTY_KEY
    vals = model.pad_vals(jnp.array([9], dtype=jnp.uint32), 4)
    assert vals.shape == (4,) and int(vals[1]) == 0
