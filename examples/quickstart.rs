//! Quickstart: the Hive hash table public API in two minutes.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```
//!
//! Covers: building a table, the four operations (§III-D), concurrent use
//! from many threads, load-aware resizing, and the operation statistics
//! behind the paper's Fig. 9 / lock-rate claims.

use hivehash::native::resize::ResizeEvent;
use hivehash::{HiveConfig, HiveTable};
use std::sync::Arc;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    // --- 1. Build a table -------------------------------------------------
    // 256 buckets × 32 slots = 8192 slot capacity, the paper's default
    // BitHash1 & BitHash2 two-choice family, eviction bound 16.
    let cfg = HiveConfig::default().with_buckets(256);
    let table = Arc::new(HiveTable::new(cfg)?);

    // --- 2. The four operations (§III-D) ----------------------------------
    table.insert(42, 4200)?; // Insert⟨k,v⟩
    table.insert(42, 4300)?; // Replace⟨k,v⟩ — same key, new value
    assert_eq!(table.lookup(42), Some(4300)); // Search(k)
    assert!(table.delete(42)); // Delete(k)
    assert_eq!(table.lookup(42), None);
    println!("single-key ops OK");

    // --- 3. Concurrent use -------------------------------------------------
    // OS threads play the paper's warps: all fast paths are lock-free.
    let threads: Vec<_> = (0..8u32)
        .map(|tid| {
            let t = Arc::clone(&table);
            std::thread::spawn(move || {
                for i in 0..1000 {
                    let k = tid * 10_000 + i + 1;
                    t.insert(k, k * 2).unwrap();
                }
            })
        })
        .collect();
    for th in threads {
        th.join().unwrap();
    }
    println!(
        "8 threads inserted {} keys, load factor {:.2}",
        table.len(),
        table.load_factor()
    );

    // --- 4. Load-aware resizing (§IV-C) ------------------------------------
    // The table grows in K-bucket batches via linear hashing — no global
    // rehash. maybe_resize() is what the coordinator calls between batches.
    while let Some(ev) = table.maybe_resize() {
        match ev {
            ResizeEvent::Grew { buckets_split } => {
                println!(
                    "grew: split {buckets_split} buckets -> {} logical",
                    table.logical_buckets()
                );
            }
            ResizeEvent::Shrank { buckets_merged } => {
                println!("shrank: merged {buckets_merged} buckets");
            }
        }
    }

    // every key survives resizing
    for tid in 0..8u32 {
        for i in (0..1000).step_by(111) {
            let k = tid * 10_000 + i + 1;
            assert_eq!(table.lookup(k), Some(k * 2));
        }
    }
    println!("all keys intact after resize, load factor {:.2}", table.load_factor());

    // --- 5. Operation statistics -------------------------------------------
    let s = table.stats();
    let (s1, s2, s3, s4) = s.step_fractions();
    println!(
        "insert steps: replace {:.1}% | claim {:.1}% | evict {:.1}% | stash {:.1}%",
        s1 * 100.0,
        s2 * 100.0,
        s3 * 100.0,
        s4 * 100.0
    );
    println!(
        "eviction-lock rate: {:.4}% of ops (paper bound: <0.85%)",
        s.lock_rate() * 100.0
    );
    Ok(())
}
