//! End-to-end driver: the full three-layer system on a real workload.
//!
//! ```bash
//! make artifacts && cargo run --release --example kv_service
//! ```
//!
//! Starts the L3 coordinator with **XLA-backend workers** — every table
//! operation executes as an AOT-compiled JAX/Pallas program through PJRT
//! (Python is not running) — then replays a 1M-op mixed workload
//! (paper Fig. 8 ratios 0.5:0.3:0.2) through the batching router, crossing
//! at least one resize epoch and a stash drain. Reports throughput and
//! latency; results are recorded in EXPERIMENTS.md §E2E.
//!
//! A native-backend pass runs afterwards as the throughput reference on
//! the same workload (the substrate the paper's absolute numbers map to).
//!
//! `--net` runs the RESP wire demo instead: a real `net::NetServer` on
//! loopback, a fleet of pipelined RESP clients hammering GET/SET/INCRBY
//! over actual TCP sockets, and the per-connection serving counters the
//! coordinator grew for it (see SERVING.md).

use hivehash::backend::{Backend, NativeBackend, XlaBackend};
use hivehash::coordinator::{start_native, BatchPolicy, Coordinator, CoordinatorConfig};
use hivehash::net::resp::{Frame, Parser};
use hivehash::net::{NetConfig, NetServer};
use hivehash::report::json::latency_obj;
use hivehash::report::{drive_service_pipelined, mops};
use hivehash::runtime::Runtime;
use hivehash::workload::{self, Mix, Op};
use hivehash::HiveConfig;
use std::io::{Read, Write};
use std::sync::Arc;
use std::time::{Duration, Instant};

const TOTAL_OPS: usize = 1_000_000;
const WINDOW: usize = 4096;

fn run_service<F>(label: &str, workers: usize, ops: &[Op], factory: F) -> f64
where
    F: Fn(usize) -> hivehash::core::error::Result<Box<dyn Backend>> + Send + Sync + 'static,
{
    let cfg = CoordinatorConfig {
        workers,
        batch: BatchPolicy { max_batch: WINDOW, deadline: Duration::from_micros(200) },
        resize_check_every: 4,
        cache_capacity: 4096,
        ring_capacity: 4096,
    };
    let (coord, h) = Coordinator::start(cfg, factory).expect("start service");

    // correctness canary: a fixed prefix whose lookups we can predict
    let canary: Vec<Op> = (1..=1000u32)
        .map(|k| Op::Insert { key: 0xF000_0000 + k, value: k })
        .collect();
    h.submit(&canary).unwrap();

    let t0 = Instant::now();
    let mut lookup_hits = 0usize;
    let mut lookups = 0usize;
    for window in ops.chunks(WINDOW) {
        let res = h.submit(window).unwrap();
        for r in &res {
            if let Some(v) = r.as_value() {
                lookups += 1;
                lookup_hits += v.is_some() as usize;
            }
        }
    }
    let elapsed = t0.elapsed();

    // canary must be intact across all resize epochs (skip the rare canary
    // keys the random workload itself inserted/deleted — it spans all u32)
    let touched: std::collections::HashSet<u32> = ops.iter().map(|o| o.key()).collect();
    let canary_keys: Vec<u32> = (1..=1000u32)
        .map(|k| 0xF000_0000 + k)
        .filter(|k| !touched.contains(k))
        .collect();
    let canary_q: Vec<Op> = canary_keys.iter().map(|&key| Op::Lookup { key }).collect();
    let res = h.submit(&canary_q).unwrap();
    for (i, r) in res.iter().enumerate() {
        assert_eq!(
            r.as_value().expect("lookup yields Value"),
            Some(canary_keys[i] - 0xF000_0000),
            "canary key {} corrupted",
            canary_keys[i]
        );
    }

    let stats = h.stats().unwrap();
    let throughput = mops(ops.len(), elapsed);
    println!("--- {label} ---");
    println!("  ops          : {} ({} windows)", ops.len(), ops.len() / WINDOW);
    println!("  wall time    : {:.2} s", elapsed.as_secs_f64());
    println!("  throughput   : {throughput:.2} MOPS");
    println!(
        "  lookups      : {lookups} ({:.1}% hit rate)",
        100.0 * lookup_hits as f64 / lookups.max(1) as f64
    );
    println!(
        "  batches      : {} (mean size {:.0})",
        stats.batches,
        stats.mean_batch()
    );
    println!(
        "  resize epochs: {} grows, {} shrinks (stash traffic: {})",
        stats.grows, stats.shrinks, stats.stashed
    );
    println!("  svc stats    : {}", stats.summary());
    println!("  latency      : {}", latency_obj(&stats.latency_ns).render());
    println!("  queue delay  : {}", latency_obj(&stats.queue_delay_ns).render());
    coord.shutdown();
    println!();
    throughput
}

/// The pipelined single-op plane on the native substrate: `clients`
/// threads each keep `window` completion tickets in flight — the serving
/// model one network front-end connection maps to.
fn run_pipelined(label: &str, workers: usize, ops: &[Op], clients: usize, window: usize) -> f64 {
    let cfg = CoordinatorConfig {
        workers,
        batch: BatchPolicy { max_batch: WINDOW, deadline: Duration::from_micros(200) },
        resize_check_every: 4,
        cache_capacity: 4096,
        ring_capacity: 4096,
    };
    let (coord, h) = Coordinator::start(cfg, |_w| {
        Ok(Box::new(NativeBackend::new(HiveConfig::default().with_buckets(64))?) as _)
    })
    .expect("start service");
    let elapsed = drive_service_pipelined(&h, ops, clients, window);
    let stats = h.stats().unwrap();
    let throughput = mops(ops.len(), elapsed);
    println!("--- {label} ---");
    println!("  ops          : {} ({clients} clients x window {window})", ops.len());
    println!("  wall time    : {:.2} s", elapsed.as_secs_f64());
    println!("  throughput   : {throughput:.2} MOPS");
    println!("  latency      : {}", latency_obj(&stats.latency_ns).render());
    println!("  queue delay  : {}", latency_obj(&stats.queue_delay_ns).render());
    println!(
        "  depth        : mean {:.1} (max {}) requests standing per dispatch",
        stats.inflight_depth.mean(),
        stats.inflight_depth.max()
    );
    coord.shutdown();
    println!();
    throughput
}

/// The typed-plane counter demo: concurrent clients hammer shared
/// counters through `Handle::fetch_add` (each a single CAS-retried RMW
/// on the packed word inside the table) and the final counts must be
/// *exact* — the workload class the old insert/lookup/delete API could
/// only express as racy read-modify-write round-trips.
fn run_counter_demo(workers: usize) {
    const COUNTERS: u32 = 16;
    const CLIENTS: u32 = 8;
    // a multiple of COUNTERS: each client walks whole counter cycles, so
    // the per-counter totals are exact by construction
    const ADDS_PER_CLIENT: u32 = 24_000;
    let cfg = CoordinatorConfig {
        workers,
        batch: BatchPolicy { max_batch: WINDOW, deadline: Duration::from_micros(200) },
        resize_check_every: 4,
        cache_capacity: 4096,
        ring_capacity: 4096,
    };
    let (coord, h) = Coordinator::start(cfg, |_w| {
        Ok(Box::new(NativeBackend::new(HiveConfig::default().with_buckets(64))?) as _)
    })
    .expect("start service");
    // Seed the counters so every client add is an existing-key RMW
    // (concurrent creation of the same absent key is insert-class racy;
    // existing-key fetch-add is exact).
    for c in 0..COUNTERS {
        h.insert(0xC0DE_0000 + c, 0).unwrap();
    }
    let t0 = Instant::now();
    std::thread::scope(|s| {
        for client in 0..CLIENTS {
            let h = h.clone();
            s.spawn(move || {
                for i in 0..ADDS_PER_CLIENT {
                    let c = (client + i) % COUNTERS;
                    let old = h.fetch_add(0xC0DE_0000 + c, 1).unwrap();
                    assert!(old.is_some(), "seeded counter vanished");
                }
            });
        }
    });
    let elapsed = t0.elapsed();
    let per_counter = CLIENTS * ADDS_PER_CLIENT / COUNTERS;
    for c in 0..COUNTERS {
        let got = h.lookup(0xC0DE_0000 + c).unwrap();
        assert_eq!(got, Some(per_counter), "counter {c} lost updates: {got:?}");
    }
    let total = (CLIENTS * ADDS_PER_CLIENT) as usize;
    println!("--- CAS-counter demo (typed RMW plane) ---");
    println!("  adds         : {total} fetch_adds, {CLIENTS} clients x {COUNTERS} counters");
    println!("  wall time    : {:.2} s", elapsed.as_secs_f64());
    println!("  throughput   : {:.2} MOPS", mops(total, elapsed));
    println!("  exactness    : every counter == {per_counter} (no lost updates)");
    let stats = h.stats().unwrap();
    println!("  svc stats    : {}", stats.summary());
    coord.shutdown();
    println!();
}

/// `--net`: the serving stack end to end — RESP over real loopback TCP.
///
/// Starts a native coordinator behind `net::NetServer`, then runs a
/// small fleet of pipelined wire clients (window of 64 commands in
/// flight each) speaking a 70/20/10 GET/SET/INCRBY mix. Every INCRBY
/// lands on one shared counter key, so the final GET doubles as an
/// exactness check across connections. Closes with the server's INFO
/// text and the coordinator's per-connection serving counters.
fn run_net_demo() {
    const CLIENTS: usize = 4;
    const PER_CLIENT: usize = 50_000;
    const WIRE_WINDOW: usize = 64;
    const COUNTER_KEY: u32 = 0xC0FF_EE;
    const KEYS: u32 = 1 << 14;

    println!("=== Hive KV service: RESP wire demo (--net) ===\n");
    let cfg = CoordinatorConfig { workers: 4, ..CoordinatorConfig::default() };
    let (coord, h) = start_native(cfg, HiveConfig::for_capacity(1 << 16, 0.8)).unwrap();
    let pairs: Vec<(u32, u32)> = (0..KEYS).map(|k| (k, k.wrapping_mul(3))).collect();
    for chunk in pairs.chunks(4096) {
        h.insert_batch(chunk).unwrap();
    }
    h.insert(COUNTER_KEY, 0).unwrap();
    let server = NetServer::start(
        NetConfig { pipeline_depth: WIRE_WINDOW, ..NetConfig::default() },
        h.clone(),
    )
    .expect("bind loopback RESP server");
    let addr = server.local_addr();
    println!("serving RESP on {addr} ({CLIENTS} clients x {PER_CLIENT} commands, window {WIRE_WINDOW})\n");

    let t0 = Instant::now();
    let incrs: usize = std::thread::scope(|s| {
        let handles: Vec<_> = (0..CLIENTS)
            .map(|c| {
                s.spawn(move || {
                    let mut sock = std::net::TcpStream::connect(addr).expect("connect");
                    sock.set_nodelay(true).unwrap();
                    let mut parser = Parser::new();
                    let mut rng = 0x5EED_u64.wrapping_add(c as u64);
                    let mut wbuf = Vec::with_capacity(64 * WIRE_WINDOW);
                    let mut rbuf = [0u8; 16 * 1024];
                    let (mut sent, mut recvd, mut incrs) = (0usize, 0usize, 0usize);
                    while recvd < PER_CLIENT {
                        wbuf.clear();
                        while sent < PER_CLIENT && sent - recvd < WIRE_WINDOW {
                            rng = rng.wrapping_mul(6364136223846793005).wrapping_add(1);
                            let r = rng >> 16;
                            let key = (r as u32 % KEYS).to_string();
                            let frame = match r % 10 {
                                0..=6 => Frame::command(&["GET", &key]),
                                7..=8 => Frame::command(&[
                                    "SET",
                                    &key,
                                    &((r >> 24) as u32 % 1000).to_string(),
                                ]),
                                _ => {
                                    incrs += 1;
                                    Frame::command(&["INCRBY", &COUNTER_KEY.to_string(), "1"])
                                }
                            };
                            frame.encode_into(&mut wbuf);
                            sent += 1;
                        }
                        if !wbuf.is_empty() {
                            sock.write_all(&wbuf).expect("write commands");
                        }
                        loop {
                            match parser.try_next().expect("well-formed reply") {
                                Some(Frame::Error(e)) => panic!("server error: {e}"),
                                Some(_) => {
                                    recvd += 1;
                                    if recvd == sent || sent - recvd < WIRE_WINDOW {
                                        break;
                                    }
                                }
                                None => {
                                    let n = sock.read(&mut rbuf).expect("read replies");
                                    assert!(n > 0, "server closed mid-demo");
                                    parser.feed(&rbuf[..n]);
                                }
                            }
                        }
                    }
                    incrs
                })
            })
            .collect();
        handles.into_iter().map(|t| t.join().expect("wire client")).sum()
    });
    let elapsed = t0.elapsed();

    // exactness across connections: the shared counter saw every INCRBY
    let counter = h.lookup(COUNTER_KEY).unwrap();
    assert_eq!(
        counter,
        Some(incrs as u32),
        "shared wire counter lost updates"
    );

    let total = CLIENTS * PER_CLIENT;
    let stats = server.stats();
    println!("--- wire fleet ---");
    println!("  commands     : {total} over {CLIENTS} connections");
    println!("  wall time    : {:.2} s", elapsed.as_secs_f64());
    println!("  throughput   : {:.0} req/s", total as f64 / elapsed.as_secs_f64());
    println!("  shared ctr   : {} INCRBYs, exact across connections", incrs);
    println!(
        "  cmd latency  : {}",
        latency_obj(&stats.net_cmd_latency_ns).render()
    );
    println!("  serving stats: {}", stats.summary());
    server.shutdown();
    coord.shutdown();
}

fn main() {
    if std::env::args().any(|a| a == "--net") {
        run_net_demo();
        return;
    }
    println!("=== Hive KV service: end-to-end driver ===\n");
    let ops = workload::mixed(TOTAL_OPS, Mix::PAPER_IMBALANCED, 4242);
    println!(
        "workload: {TOTAL_OPS} mixed ops (insert:lookup:delete = 0.5:0.3:0.2, Fig. 8)\n"
    );

    // --- XLA backend: the three-layer paper path -------------------------
    // The CPU-PJRT XLA path round-trips the table state per batch (see
    // EXPERIMENTS.md §Perf), so it runs a 100k-op slice of the same
    // workload; the native pass below covers the full 1M.
    let xla_ops = &ops[..(TOTAL_OPS / 10).min(100_000)];
    let xla_mops = match Runtime::open_default() {
        Ok(_) => {
            let t = run_service("XLA backend (AOT JAX/Pallas via PJRT)", 2, xla_ops, |_w| {
                let rt = Arc::new(Runtime::open_default()?);
                // start small within the smallest class: forces resize
                // epochs + stash drains during the run
                let class = rt.classes()[0];
                Ok(Box::new(XlaBackend::with_initial_buckets(rt, class, class / 4)?) as _)
            });
            Some(t)
        }
        Err(e) => {
            println!("XLA backend skipped: {e}\n");
            None
        }
    };

    // --- native backend: the throughput substrate -------------------------
    let native_mops = run_service("native backend (lock-free CPU)", 4, &ops, |_w| {
        Ok(Box::new(NativeBackend::new(HiveConfig::default().with_buckets(64))?) as _)
    });

    // --- pipelined single-op plane on the same substrate ------------------
    // The bulk pass above ships pre-batched windows; this one replays a
    // slice of the stream as pipelined *single* ops — what a network
    // front-end with per-connection completion queues would generate.
    let pipe_ops = &ops[..(TOTAL_OPS / 4).min(250_000)];
    let pipe_mops =
        run_pipelined("native backend, pipelined tickets", 4, pipe_ops, 4, 256);

    // --- typed RMW plane: exact concurrent counters ----------------------
    run_counter_demo(4);

    println!("=== summary ===");
    if let Some(x) = xla_mops {
        println!("  XLA path    : {x:.2} MOPS (bulk AOT programs, CPU PJRT)");
    }
    println!("  native path : {native_mops:.2} MOPS (pre-batched bulk windows)");
    println!("  pipelined   : {pipe_mops:.2} MOPS (single ops, 4 clients x 256 tickets)");
    println!("  (paper, RTX 4090: ~1796-2611 MOPS on this workload shape)");
}
