//! Dynamic-resizing walkthrough (paper §IV-C).
//!
//! ```bash
//! cargo run --release --example resize_demo
//! ```
//!
//! Drives the table through a full grow/shrink lifecycle and prints the
//! linear-hashing round state (`index_mask`, `split_ptr`, logical buckets)
//! after every K-bucket batch — the incremental behaviour that replaces
//! global rehashing. Ends with the §V-A-style resize throughput numbers.

use hivehash::{HiveConfig, HiveTable};
use std::time::Instant;

fn state_line(t: &HiveTable, label: &str) {
    println!(
        "{label:<26} buckets={:<6} entries={:<7} lf={:.3}",
        t.logical_buckets(),
        t.len(),
        t.load_factor()
    );
}

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let table = HiveTable::new(
        HiveConfig::default().with_buckets(64).with_thresholds(0.9, 0.25),
    )?;
    state_line(&table, "fresh");

    // Phase 1: fill toward the grow threshold
    let mut next_key = 1u32;
    for _ in 0..(64 * 32) * 88 / 100 {
        table.insert(next_key, next_key)?;
        next_key += 1;
    }
    state_line(&table, "filled to ~0.88");

    // Phase 2: keep inserting; the controller splits K-bucket batches
    println!("\n-- expansion (split phase) --");
    for burst in 0..6 {
        for _ in 0..800 {
            table.insert(next_key, next_key)?;
            next_key += 1;
        }
        while let Some(ev) = table.maybe_resize() {
            let _ = ev;
        }
        state_line(&table, &format!("after burst {burst}"));
    }

    // every key still reachable
    for k in (1..next_key).step_by(509) {
        assert_eq!(table.lookup(k), Some(k), "key {k} lost during growth");
    }
    println!("spot-check OK: keys reachable across {} splits", table.logical_buckets() - 64);

    // Phase 3: delete most entries; the controller merges back
    println!("\n-- contraction (merge phase) --");
    for k in 1..next_key {
        if k % 8 != 0 {
            table.delete(k);
        }
    }
    state_line(&table, "after deletes");
    let mut rounds = 0;
    while let Some(_ev) = table.maybe_resize() {
        rounds += 1;
        if rounds % 4 == 0 {
            state_line(&table, &format!("merge round {rounds}"));
        }
        if rounds > 200 {
            break;
        }
    }
    state_line(&table, "contracted");
    for k in (8..next_key).step_by(8 * 127) {
        assert_eq!(table.lookup(k), Some(k), "key {k} lost during contraction");
    }
    println!("spot-check OK after contraction");

    // Phase 4: §V-A-style resize throughput measurement
    println!("\n-- resize throughput (paper §V-A: 16.8/23.7 GOPS on 4090) --");
    let big = HiveTable::new(HiveConfig::default().with_buckets(1 << 15))?;
    let n = (1 << 15) * 32 / 2;
    for k in 1..=n as u32 {
        big.insert(k, k)?;
    }
    let t0 = Instant::now();
    let split = big.grow_buckets(1 << 15);
    let grow_dt = t0.elapsed();
    let t1 = Instant::now();
    let merged = big.shrink_buckets(1 << 15);
    let shrink_dt = t1.elapsed();
    println!(
        "split {split} buckets in {:.1} ms  ({:.2} Mbuckets/s)",
        grow_dt.as_secs_f64() * 1e3,
        split as f64 / grow_dt.as_secs_f64() / 1e6
    );
    println!(
        "merged {merged} buckets in {:.1} ms ({:.2} Mbuckets/s)",
        shrink_dt.as_secs_f64() * 1e3,
        merged as f64 / shrink_dt.as_secs_f64() / 1e6
    );
    Ok(())
}
