//! Particle tracking over a sparse 3-D grid — the paper's motivating
//! workload (§I: "particle tracking in computational fluid dynamics
//! requires monitoring active cells in a large 3D grid where most cells
//! remain empty").
//!
//! ```bash
//! cargo run --release --example particle_tracking
//! ```
//!
//! A 256³ grid (16.7M cells) would need 64 MiB as a dense u32 array; the
//! simulation keeps ~50k active cells in a Hive table instead, exercising
//! the dynamic behaviours the paper targets: bursts of inserts as vortices
//! form, deletes as they dissipate, and the load-aware resizer tracking
//! the active-set size in both directions.

use hivehash::core::rng::Xoshiro256;
use hivehash::{HiveConfig, HiveTable};
use std::time::Instant;

const GRID: u32 = 256; // 256^3 cells

/// Morton-style cell id from (x, y, z) — the key.
fn cell_id(x: u32, y: u32, z: u32) -> u32 {
    (x % GRID) * GRID * GRID + (y % GRID) * GRID + (z % GRID)
}

/// One tracked particle.
#[derive(Clone, Copy)]
struct Particle {
    x: f32,
    y: f32,
    z: f32,
    vx: f32,
    vy: f32,
    vz: f32,
}

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let mut rng = Xoshiro256::seeded(2026);
    // start small: the table will grow itself as the plume spreads
    let table = HiveTable::new(HiveConfig::default().with_buckets(64))?;

    // seed a dense particle plume in one corner
    let mut particles: Vec<Particle> = (0..60_000)
        .map(|_| Particle {
            x: rng.f64() as f32 * 32.0,
            y: rng.f64() as f32 * 32.0,
            z: rng.f64() as f32 * 32.0,
            vx: 0.5 + rng.f64() as f32,
            vy: 0.3 + rng.f64() as f32 * 0.5,
            vz: 0.2 + rng.f64() as f32 * 0.25,
        })
        .collect();

    println!("grid {GRID}^3 = {} cells; dense storage would be {} MiB", GRID.pow(3), GRID.pow(3) * 4 / (1 << 20));
    println!("tracking {} particles\n", particles.len());
    println!(
        "{:>5} {:>9} {:>9} {:>8} {:>9} {:>10}",
        "step", "active", "buckets", "lf", "grows", "step_ms"
    );

    let mut grows = 0u64;
    for step in 0..30 {
        let t0 = Instant::now();

        // clear last frame's active-cell counts (delete phase)
        let active_cells: Vec<(u32, u32)> = table.entries();
        for (cell, _) in &active_cells {
            table.delete(*cell);
        }

        // advect particles; occupancy histogram into the table
        for p in particles.iter_mut() {
            p.x += p.vx;
            p.y += p.vy;
            p.z += p.vz;
            // dissipation: particles fade after leaving the domain core
            let cell = cell_id(p.x as u32, p.y as u32, p.z as u32);
            let count = table.lookup(cell).unwrap_or(0);
            table.insert(cell, count + 1)?;
        }

        // the resize controller keeps occupancy in the paper's band
        while let Some(ev) = table.maybe_resize() {
            if matches!(ev, hivehash::native::resize::ResizeEvent::Grew { .. }) {
                grows += 1;
            }
        }

        // dissipate: drop 8% of particles each frame after step 15
        if step >= 15 {
            let keep = (particles.len() as f64 * 0.92) as usize;
            particles.truncate(keep);
        }

        println!(
            "{:>5} {:>9} {:>9} {:>8.3} {:>9} {:>10.1}",
            step,
            table.len(),
            table.logical_buckets(),
            table.load_factor(),
            grows,
            t0.elapsed().as_secs_f64() * 1e3,
        );
    }

    // final verification: occupancy histogram equals a reference count
    let mut reference = std::collections::HashMap::new();
    for p in &particles {
        *reference.entry(cell_id(p.x as u32, p.y as u32, p.z as u32)).or_insert(0u32) += 1;
    }
    // table holds the last frame's counts
    let mut checked = 0;
    for (&cell, &count) in reference.iter() {
        assert_eq!(table.lookup(cell), Some(count), "cell {cell} count mismatch");
        checked += 1;
    }
    println!("\nverified {checked} active cells against dense reference — OK");
    println!(
        "final: {} active cells in {} buckets (vs {} dense cells)",
        table.len(),
        table.logical_buckets(),
        GRID.pow(3)
    );
    Ok(())
}
