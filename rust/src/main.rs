//! `hivehash` CLI — the leader entry point.
//!
//! ```text
//! hivehash serve   [--workers N] [--backend native|xla|simt] [--config FILE]
//! hivehash bench   <fig3|fig5|fig6|fig7|fig8|fig9|resize|all>   (hints)
//! hivehash csr     [--m BUCKETS] [--n KEYS]
//! hivehash breakdown [--buckets N] [--lf X]
//! hivehash e2e     [--ops N]
//! hivehash info
//! ```
//!
//! (Dependency-free argument parsing; the registry has no clap.)

use hivehash::backend::{Backend, NativeBackend, SimtBackend, XlaBackend};
use hivehash::coordinator::{BatchPolicy, Coordinator, CoordinatorConfig};
use hivehash::hash::stats as hstats;
use hivehash::hash::HashKind;
use hivehash::report::{mops, Table};
use hivehash::simgpu::{SimHive, SimHiveConfig};
use hivehash::workload::{self, Mix};
use hivehash::HiveConfig;
use std::sync::Arc;
use std::time::{Duration, Instant};

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let cmd = args.first().map(String::as_str).unwrap_or("help");
    let flags = parse_flags(&args[1.min(args.len())..]);
    let code = match cmd {
        "serve" => cmd_serve(&flags),
        "bench" => cmd_bench(&args),
        "csr" => cmd_csr(&flags),
        "breakdown" => cmd_breakdown(&flags),
        "e2e" => cmd_e2e(&flags),
        "info" => cmd_info(),
        _ => {
            print_help();
            0
        }
    };
    std::process::exit(code);
}

fn print_help() {
    println!(
        "hivehash — warp-cooperative, dynamically resizable hash table (paper reproduction)\n\n\
         USAGE:\n  hivehash serve     [--workers N] [--backend native|xla|simt] [--config FILE] [--ops N]\n  \
         hivehash bench <fig3|fig5|fig6|fig7|fig8|fig9|resize|all>\n  \
         hivehash csr       [--m BUCKETS] [--n KEYS]\n  \
         hivehash breakdown [--buckets N] [--lf X]\n  \
         hivehash e2e       [--ops N]\n  \
         hivehash info"
    );
}

fn parse_flags(args: &[String]) -> std::collections::HashMap<String, String> {
    let mut map = std::collections::HashMap::new();
    let mut i = 0;
    while i < args.len() {
        if let Some(name) = args[i].strip_prefix("--") {
            let val = args.get(i + 1).cloned().unwrap_or_else(|| "true".into());
            map.insert(name.to_string(), val);
            i += 2;
        } else {
            i += 1;
        }
    }
    map
}

fn flag<T: std::str::FromStr>(
    flags: &std::collections::HashMap<String, String>,
    name: &str,
    default: T,
) -> T {
    flags.get(name).and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn make_factory(
    backend: String,
) -> impl Fn(usize) -> hivehash::core::error::Result<Box<dyn Backend>> + Send + Sync + 'static {
    move |_w| match backend.as_str() {
        "xla" => {
            let rt = Arc::new(hivehash::runtime::Runtime::open_default()?);
            let class = rt.classes()[0];
            Ok(Box::new(XlaBackend::with_initial_buckets(rt, class, class / 4)?) as _)
        }
        "simt" => Ok(Box::new(SimtBackend::new(SimHiveConfig {
            n_buckets: 4096,
            ..Default::default()
        })) as _),
        _ => Ok(Box::new(NativeBackend::new(HiveConfig::default().with_buckets(256))?) as _),
    }
}

fn cmd_serve(flags: &std::collections::HashMap<String, String>) -> i32 {
    let workers = flag(flags, "workers", 4usize);
    let backend: String = flag(flags, "backend", "native".to_string());
    let total: usize = flag(flags, "ops", 1_000_000usize);
    let mut table_cfg = HiveConfig::default().with_buckets(256);
    if let Some(path) = flags.get("config") {
        match HiveConfig::from_file(std::path::Path::new(path)) {
            Ok(cfg) => table_cfg = cfg,
            Err(e) => {
                eprintln!("config error: {e}");
                return 2;
            }
        }
    }
    let _ = table_cfg.apply_env();
    println!("starting coordinator: {workers} workers, backend={backend}");
    let cfg = CoordinatorConfig {
        workers,
        batch: BatchPolicy { max_batch: 4096, deadline: Duration::from_micros(200) },
        resize_check_every: 4,
        cache_capacity: 4096,
        ring_capacity: 4096,
    };
    let (coord, h) = match Coordinator::start(cfg, make_factory(backend)) {
        Ok(x) => x,
        Err(e) => {
            eprintln!("failed to start: {e}");
            return 1;
        }
    };
    // demo load (a real deployment would attach a network front here)
    println!("replaying {total} mixed ops (0.5:0.3:0.2) through the service...");
    let ops = workload::mixed(total, Mix::PAPER_IMBALANCED, 7);
    let t0 = Instant::now();
    for window in ops.chunks(4096) {
        if let Err(e) = h.submit(window) {
            eprintln!("submit failed: {e}");
            return 1;
        }
    }
    let dt = t0.elapsed();
    let stats = h.stats().unwrap();
    println!("done: {:.2} MOPS | {}", mops(total, dt), stats.summary());
    coord.shutdown();
    0
}

fn cmd_bench(args: &[String]) -> i32 {
    let which = args.get(1).map(String::as_str).unwrap_or("all");
    println!("benchmarks are cargo bench targets:\n");
    let benches = [
        ("fig3", "fig3_csr", "CSR of hash functions (Fig. 3)"),
        ("fig5", "fig5_hash_combos", "hash-combo insert throughput (Fig. 5)"),
        ("fig6", "fig6_bulk_insert", "bulk insert vs baselines (Fig. 6)"),
        ("fig7", "fig7_bulk_query", "bulk query vs baselines (Fig. 7)"),
        ("fig8", "fig8_mixed", "mixed workload vs baselines (Fig. 8)"),
        ("fig9", "fig9_step_breakdown", "insert step breakdown (Fig. 9)"),
        ("fig12", "fig12_rmw", "typed RMW mixes vs ShardedStd (Fig. 12)"),
        ("resize", "resize_throughput", "resize throughput (§V-A)"),
    ];
    for (short, target, desc) in benches {
        if which == "all" || which == short {
            println!("  cargo bench --bench {target:<22} # {desc}");
        }
    }
    0
}

fn cmd_csr(flags: &std::collections::HashMap<String, String>) -> i32 {
    let m = flag(flags, "m", 512usize * 512);
    let n = flag(flags, "n", 1u64 << 20);
    let mut table = Table::new(
        &format!("CSR at m={m}, n={n}"),
        &["hash", "observed_Y", "expected_Y", "CSR"],
    );
    for kind in HashKind::ALL {
        let loads = hstats::bucket_loads(kind, 0..n as u32, m);
        let obs = hstats::observed_collisions(&loads);
        let exp = hstats::expected_collisions(n, m as u64);
        table.row(vec![
            kind.name().into(),
            obs.to_string(),
            format!("{exp:.0}"),
            format!("{:.4}", exp / obs.max(1) as f64),
        ]);
    }
    table.emit(None);
    0
}

fn cmd_breakdown(flags: &std::collections::HashMap<String, String>) -> i32 {
    let n_buckets = flag(flags, "buckets", 4096usize);
    let lf: f64 = flag(flags, "lf", 0.9f64);
    let capacity = n_buckets * 32;
    let mut sim = SimHive::new(SimHiveConfig { n_buckets, ..Default::default() });
    let keys = workload::unique_uniform_keys((capacity as f64 * lf) as usize, 5);
    for &k in &keys {
        sim.insert(k, k);
    }
    let bd = sim.breakdown();
    let p = bd.percentages();
    println!("fill to lf={lf} over {n_buckets} buckets:");
    println!(
        "  replace {:.1}% | claim {:.1}% | evict {:.1}% | stash {:.1}%",
        p[0], p[1], p[2], p[3]
    );
    println!("  lock rate {:.3}% (paper <0.85%)", 100.0 * bd.lock_rate());
    let t = sim.mem_total();
    println!(
        "  memory: {} transactions, {} atomics ({:.2} trans/op, {:.2} atomics/op)",
        t.transactions,
        t.atomics,
        t.transactions as f64 / keys.len() as f64,
        t.atomics as f64 / keys.len() as f64
    );
    0
}

fn cmd_e2e(flags: &std::collections::HashMap<String, String>) -> i32 {
    let total: usize = flag(flags, "ops", 200_000usize);
    println!("(short alias of examples/kv_service.rs — run that for the full driver)");
    let ops = workload::mixed(total, Mix::PAPER_IMBALANCED, 4242);
    let cfg = CoordinatorConfig::default();
    let (coord, h) = Coordinator::start(cfg, make_factory("native".into())).unwrap();
    let t0 = Instant::now();
    for w in ops.chunks(4096) {
        h.submit(w).unwrap();
    }
    println!("native service: {:.2} MOPS", mops(total, t0.elapsed()));
    coord.shutdown();
    0
}

fn cmd_info() -> i32 {
    println!("hivehash {} — paper: Hive Hash Table (CS.DC 2025)", env!("CARGO_PKG_VERSION"));
    println!("slots/bucket: 32 | packed 64-bit KV words | linear-hashing resize");
    match hivehash::runtime::Runtime::open_default() {
        Ok(rt) => println!("artifacts: classes {:?}", rt.classes()),
        Err(e) => println!("artifacts: unavailable ({e})"),
    }
    println!(
        "threads available: {}",
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
    );
    0
}
