//! XLA backend — bulk operations through the AOT artifacts (the paper's
//! L1/L2 path, PJRT-executed, Python-free).

use crate::backend::{group_ops, Backend, BatchResult};
use crate::core::error::Result;
use crate::native::resize::ResizeEvent;
use crate::runtime::{Runtime, XlaTable};
use crate::workload::Op;
use std::sync::Arc;

/// Backend over an [`XlaTable`].
pub struct XlaBackend {
    table: XlaTable,
}

impl XlaBackend {
    /// Backend at the given capacity class.
    pub fn new(rt: Arc<Runtime>, class: usize) -> Result<Self> {
        Ok(XlaBackend { table: XlaTable::new(rt, class)? })
    }

    /// Backend starting at `logical` addressable buckets within `class`.
    pub fn with_initial_buckets(rt: Arc<Runtime>, class: usize, logical: usize) -> Result<Self> {
        Ok(XlaBackend { table: XlaTable::with_initial_buckets(rt, class, logical)? })
    }

    /// The underlying table.
    pub fn table(&self) -> &XlaTable {
        &self.table
    }

    /// Mutable access (bulk drivers use the table directly).
    pub fn table_mut(&mut self) -> &mut XlaTable {
        &mut self.table
    }
}

impl Backend for XlaBackend {
    fn execute(&mut self, ops: &[Op]) -> Result<BatchResult> {
        let (ins, del, luk) = group_ops(ops);
        let mut res = BatchResult::default();
        if !ins.is_empty() {
            let keys: Vec<u32> = ins.iter().map(|&(_, k, _)| k).collect();
            let vals: Vec<u32> = ins.iter().map(|&(_, _, v)| v).collect();
            // A window can outgrow capacity + stash between resize checks:
            // grow a full round and retry (re-running a partially applied
            // chunk is safe — replays become replaces).
            let report = loop {
                match self.table.insert_batch(&keys, &vals) {
                    Ok(r) => break r,
                    Err(crate::core::error::HiveError::TableFull) => {
                        let logical = self.table.logical_buckets();
                        if self.table.grow_buckets(logical)? == 0 {
                            return Err(crate::core::error::HiveError::TableFull);
                        }
                    }
                    Err(e) => return Err(e),
                }
            };
            res.inserted = report.inserted;
            res.replaced = report.replaced;
            res.stashed = report.stashed;
        }
        if !del.is_empty() {
            let keys: Vec<u32> = del.iter().map(|&(_, k)| k).collect();
            res.deletes = self.table.delete_batch(&keys)?;
        }
        if !luk.is_empty() {
            let keys: Vec<u32> = luk.iter().map(|&(_, k)| k).collect();
            res.lookups = self.table.lookup_batch(&keys)?;
        }
        Ok(res)
    }

    fn len(&self) -> usize {
        self.table.len()
    }

    fn load_factor(&self) -> f64 {
        self.table.load_factor()
    }

    fn maybe_resize(&mut self) -> Result<Option<ResizeEvent>> {
        self.table.maybe_resize()
    }

    fn name(&self) -> &'static str {
        "xla"
    }
}
