//! XLA backend — bulk operations through the AOT artifacts (the paper's
//! L1/L2 path, PJRT-executed, Python-free).
//!
//! The HLO programs expose the three bulk primitives (insert / lookup /
//! delete); the typed plane's conditional and RMW classes are *composed*
//! from them here: each class does one bulk lookup for the current
//! values, folds the class's ops sequentially in host code (so
//! duplicate keys inside one window chain correctly), and ships the
//! per-key final values as one bulk insert. The worker owns its shard
//! exclusively, so the composition is exact window-level linearization.
//! Placement outcomes are coarse on this substrate — `Replaced` when the
//! key existed, `Inserted` otherwise (the HLO report has no per-op step
//! attribution).

use crate::backend::{group_ops, Backend};
use crate::core::error::{HiveError, Result};
use crate::core::packed::EMPTY_KEY;
use crate::native::resize::ResizeEvent;
use crate::native::table::InsertOutcome;
use crate::runtime::{Runtime, XlaTable};
use crate::workload::{Op, OpResult};
use std::collections::HashMap;
use std::sync::Arc;

/// Backend over an [`XlaTable`].
pub struct XlaBackend {
    table: XlaTable,
}

impl XlaBackend {
    /// Backend at the given capacity class.
    pub fn new(rt: Arc<Runtime>, class: usize) -> Result<Self> {
        Ok(XlaBackend { table: XlaTable::new(rt, class)? })
    }

    /// Backend starting at `logical` addressable buckets within `class`.
    pub fn with_initial_buckets(rt: Arc<Runtime>, class: usize, logical: usize) -> Result<Self> {
        Ok(XlaBackend { table: XlaTable::with_initial_buckets(rt, class, logical)? })
    }

    /// The underlying table.
    pub fn table(&self) -> &XlaTable {
        &self.table
    }

    /// Mutable access (bulk drivers use the table directly).
    pub fn table_mut(&mut self) -> &mut XlaTable {
        &mut self.table
    }

    /// Bulk insert with the grow-and-retry loop: a window can outgrow
    /// capacity + stash between resize checks, so grow a full round and
    /// retry (re-running a partially applied chunk is safe — replays
    /// become replaces).
    fn insert_with_grow(&mut self, keys: &[u32], vals: &[u32]) -> Result<()> {
        if keys.is_empty() {
            return Ok(());
        }
        loop {
            match self.table.insert_batch(keys, vals) {
                Ok(_) => return Ok(()),
                Err(HiveError::TableFull) => {
                    let logical = self.table.logical_buckets();
                    if self.table.grow_buckets(logical)? == 0 {
                        return Err(HiveError::TableFull);
                    }
                }
                Err(e) => return Err(e),
            }
        }
    }

    /// Current values of the non-sentinel keys among `keys`, re-aligned
    /// to `keys` (sentinel positions read as absent without touching the
    /// HLO path).
    fn current_values(&mut self, keys: &[u32]) -> Result<Vec<Option<u32>>> {
        let real: Vec<u32> = keys.iter().copied().filter(|&k| k != EMPTY_KEY).collect();
        let found =
            if real.is_empty() { Vec::new() } else { self.table.lookup_batch(&real)? };
        let mut found = found.into_iter();
        Ok(keys
            .iter()
            .map(|&k| if k == EMPTY_KEY { None } else { found.next().flatten() })
            .collect())
    }
}

/// Coarse outcome attribution for a substrate without per-op steps.
fn coarse_outcome(old: Option<u32>) -> InsertOutcome {
    if old.is_some() {
        InsertOutcome::Replaced
    } else {
        InsertOutcome::Inserted
    }
}

impl Backend for XlaBackend {
    fn execute(&mut self, ops: &[Op]) -> Result<Vec<OpResult>> {
        crate::backend::validate_insert_keys(ops)?;
        let g = group_ops(ops);
        let mut out: Vec<Option<OpResult>> = vec![None; ops.len()];

        if !g.upserts.is_empty() {
            let keys: Vec<u32> = g.upserts.iter().map(|&(_, k, _)| k).collect();
            let olds = self.current_values(&keys)?;
            let mut overlay: HashMap<u32, u32> = HashMap::new();
            for (&(i, key, value), old0) in g.upserts.iter().zip(&olds) {
                let old = overlay.get(&key).copied().or(*old0);
                out[i] = Some(OpResult::Upserted { outcome: coarse_outcome(old), old });
                overlay.insert(key, value);
            }
            let ks: Vec<u32> = overlay.keys().copied().collect();
            let vs: Vec<u32> = ks.iter().map(|k| overlay[k]).collect();
            self.insert_with_grow(&ks, &vs)?;
        }

        if !g.if_absents.is_empty() {
            let keys: Vec<u32> = g.if_absents.iter().map(|&(_, k, _)| k).collect();
            let olds = self.current_values(&keys)?;
            let mut overlay: HashMap<u32, u32> = HashMap::new();
            for (&(i, key, value), old0) in g.if_absents.iter().zip(&olds) {
                let existing = overlay.get(&key).copied().or(*old0);
                out[i] = Some(match existing {
                    Some(_) => OpResult::InsertedIfAbsent { outcome: None, existing },
                    None => {
                        overlay.insert(key, value);
                        OpResult::InsertedIfAbsent {
                            outcome: Some(InsertOutcome::Inserted),
                            existing: None,
                        }
                    }
                });
            }
            let ks: Vec<u32> = overlay.keys().copied().collect();
            let vs: Vec<u32> = ks.iter().map(|k| overlay[k]).collect();
            self.insert_with_grow(&ks, &vs)?;
        }

        if !g.updates.is_empty() {
            let keys: Vec<u32> = g.updates.iter().map(|&(_, k, _)| k).collect();
            let olds = self.current_values(&keys)?;
            let mut overlay: HashMap<u32, u32> = HashMap::new();
            for (&(i, key, value), old0) in g.updates.iter().zip(&olds) {
                let old = overlay.get(&key).copied().or(*old0);
                if old.is_some() {
                    overlay.insert(key, value);
                }
                out[i] = Some(OpResult::Updated { old });
            }
            let ks: Vec<u32> = overlay.keys().copied().collect();
            let vs: Vec<u32> = ks.iter().map(|k| overlay[k]).collect();
            self.insert_with_grow(&ks, &vs)?;
        }

        if !g.cas.is_empty() {
            let keys: Vec<u32> = g.cas.iter().map(|&(_, k, _, _)| k).collect();
            let olds = self.current_values(&keys)?;
            let mut overlay: HashMap<u32, u32> = HashMap::new();
            for (&(i, key, expected, new), old0) in g.cas.iter().zip(&olds) {
                let actual = overlay.get(&key).copied().or(*old0);
                let ok = actual == Some(expected);
                if ok {
                    overlay.insert(key, new);
                }
                out[i] = Some(OpResult::Cas { ok, actual });
            }
            let ks: Vec<u32> = overlay.keys().copied().collect();
            let vs: Vec<u32> = ks.iter().map(|k| overlay[k]).collect();
            self.insert_with_grow(&ks, &vs)?;
        }

        if !g.fetch_adds.is_empty() {
            let keys: Vec<u32> = g.fetch_adds.iter().map(|&(_, k, _)| k).collect();
            let olds = self.current_values(&keys)?;
            let mut overlay: HashMap<u32, u32> = HashMap::new();
            for (&(i, key, delta), old0) in g.fetch_adds.iter().zip(&olds) {
                let old = overlay.get(&key).copied().or(*old0);
                overlay.insert(key, old.unwrap_or(0).wrapping_add(delta));
                let outcome = if old.is_none() { Some(InsertOutcome::Inserted) } else { None };
                out[i] = Some(OpResult::FetchAdded { outcome, old });
            }
            let ks: Vec<u32> = overlay.keys().copied().collect();
            let vs: Vec<u32> = ks.iter().map(|k| overlay[k]).collect();
            self.insert_with_grow(&ks, &vs)?;
        }

        if !g.deletes.is_empty() {
            let keys: Vec<u32> = g.deletes.iter().map(|&(_, k)| k).collect();
            for (&(i, _), hit) in g.deletes.iter().zip(self.table.delete_batch(&keys)?) {
                out[i] = Some(OpResult::Deleted(hit));
            }
        }
        if !g.lookups.is_empty() {
            let keys: Vec<u32> = g.lookups.iter().map(|&(_, k)| k).collect();
            for (&(i, _), v) in g.lookups.iter().zip(self.table.lookup_batch(&keys)?) {
                out[i] = Some(OpResult::Value(v));
            }
        }
        Ok(out.into_iter().map(|r| r.expect("every op yields exactly one result")).collect())
    }

    fn len(&self) -> usize {
        self.table.len()
    }

    fn load_factor(&self) -> f64 {
        self.table.load_factor()
    }

    fn maybe_resize(&mut self) -> Result<Option<ResizeEvent>> {
        self.table.maybe_resize()
    }

    fn name(&self) -> &'static str {
        "xla"
    }
}
