//! SIMT backend — the warp simulator behind the `Backend` trait. Used by
//! the metrics benches (Fig. 9, lock-rate, transaction counts) through the
//! same coordinator machinery as the other substrates.

use crate::backend::{group_ops, Backend, BatchResult};
use crate::core::error::Result;
use crate::native::resize::ResizeEvent;
use crate::simgpu::{SimHive, SimHiveConfig, StepBreakdown};
use crate::workload::Op;

/// Backend over the simulated warp-cooperative table.
pub struct SimtBackend {
    table: SimHive,
}

impl SimtBackend {
    /// Backend with a fresh simulated table.
    pub fn new(cfg: SimHiveConfig) -> Self {
        SimtBackend { table: SimHive::new(cfg) }
    }

    /// Per-step insertion breakdown (Fig. 9 raw data).
    pub fn breakdown(&self) -> StepBreakdown {
        self.table.breakdown()
    }

    /// Memory-traffic counters.
    pub fn mem_total(&self) -> crate::simt::MemStats {
        self.table.mem_total()
    }

    /// The simulated table.
    pub fn table_mut(&mut self) -> &mut SimHive {
        &mut self.table
    }
}

impl Backend for SimtBackend {
    fn execute(&mut self, ops: &[Op]) -> Result<BatchResult> {
        let (ins, del, luk) = group_ops(ops);
        let mut res = BatchResult::default();
        for (_, key, value) in ins {
            use crate::native::stats::Step;
            match self.table.insert(key, value) {
                Some(Step::Replace) => res.replaced += 1,
                Some(Step::Stash) => res.stashed += 1,
                Some(_) => res.inserted += 1,
                None => res.stashed += 1, // pending; counted as stash traffic
            }
        }
        for (_, key) in del {
            res.deletes.push(self.table.delete(key));
        }
        for (_, key) in luk {
            res.lookups.push(self.table.lookup(key));
        }
        Ok(res)
    }

    fn len(&self) -> usize {
        self.table.len()
    }

    fn load_factor(&self) -> f64 {
        self.table.load_factor()
    }

    fn maybe_resize(&mut self) -> Result<Option<ResizeEvent>> {
        Ok(None) // fixed-capacity simulation; resize measured on native
    }

    fn name(&self) -> &'static str {
        "simt"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::{bulk_insert, bulk_lookup};

    #[test]
    fn sim_backend_roundtrip() {
        let mut b = SimtBackend::new(SimHiveConfig { n_buckets: 64, ..Default::default() });
        let ops = bulk_insert(800, 3);
        b.execute(&ops).unwrap();
        assert_eq!(b.len(), 800);
        let keys: Vec<u32> = ops.iter().map(|o| o.key()).collect();
        let res = b.execute(&bulk_lookup(&keys)).unwrap();
        assert!(res.lookups.iter().all(Option::is_some));
        assert!(b.breakdown().inserts == 800);
    }
}
