//! SIMT backend — the warp simulator behind the `Backend` trait. Used by
//! the metrics benches (Fig. 9, lock-rate, transaction counts) through the
//! same coordinator machinery as the other substrates.
//!
//! Typed-plane note: the simulator's insert reports its completion step
//! but not the displaced value, so the upsert/conditional classes here
//! are composed as lookup-then-write per op. That charges one extra
//! modeled probe per RMW-class op — the metrics substrate prioritizes
//! per-step cost fidelity, and a conditional op *does* pay a probe
//! before its write on real hardware. Fig. 9 drives `SimHive` directly
//! and is unaffected.

use crate::backend::{group_ops, Backend};
use crate::core::error::Result;
use crate::core::packed::EMPTY_KEY;
use crate::native::resize::ResizeEvent;
use crate::native::stats::Step;
use crate::native::table::InsertOutcome;
use crate::simgpu::{SimHive, SimHiveConfig, StepBreakdown};
use crate::workload::{Op, OpResult};

/// Backend over the simulated warp-cooperative table.
pub struct SimtBackend {
    table: SimHive,
}

impl SimtBackend {
    /// Backend with a fresh simulated table.
    pub fn new(cfg: SimHiveConfig) -> Self {
        SimtBackend { table: SimHive::new(cfg) }
    }

    /// Per-step insertion breakdown (Fig. 9 raw data).
    pub fn breakdown(&self) -> StepBreakdown {
        self.table.breakdown()
    }

    /// Memory-traffic counters.
    pub fn mem_total(&self) -> crate::simt::MemStats {
        self.table.mem_total()
    }

    /// The simulated table.
    pub fn table_mut(&mut self) -> &mut SimHive {
        &mut self.table
    }
}

/// Map the simulator's completion step onto the plane's outcome. `None`
/// (both table and stash full, word parked pending) is reported as
/// `Stashed` — it is stash-class traffic.
fn outcome_of(step: Option<Step>) -> InsertOutcome {
    match step {
        Some(Step::Replace) => InsertOutcome::Replaced,
        Some(Step::Claim) => InsertOutcome::Inserted,
        Some(Step::Evict) => InsertOutcome::Evicted,
        Some(Step::Stash) | None => InsertOutcome::Stashed,
    }
}

impl Backend for SimtBackend {
    fn execute(&mut self, ops: &[Op]) -> Result<Vec<OpResult>> {
        crate::backend::validate_insert_keys(ops)?;
        let g = group_ops(ops);
        let mut out: Vec<Option<OpResult>> = vec![None; ops.len()];
        for &(i, key, value) in &g.upserts {
            let old = self.table.lookup(key);
            let outcome = outcome_of(self.table.insert(key, value));
            out[i] = Some(OpResult::Upserted { outcome, old });
        }
        for &(i, key, value) in &g.if_absents {
            out[i] = Some(match self.table.lookup(key) {
                Some(v) => OpResult::InsertedIfAbsent { outcome: None, existing: Some(v) },
                None => OpResult::InsertedIfAbsent {
                    outcome: Some(outcome_of(self.table.insert(key, value))),
                    existing: None,
                },
            });
        }
        for &(i, key, value) in &g.updates {
            // sentinel guard: the sim's probe matches EMPTY_KEY against
            // vacant slots, so never let the sentinel reach it — report
            // the miss the other substrates report
            let old = if key == EMPTY_KEY { None } else { self.table.lookup(key) };
            if old.is_some() {
                self.table.insert(key, value);
            }
            out[i] = Some(OpResult::Updated { old });
        }
        for &(i, key, expected, new) in &g.cas {
            let actual = if key == EMPTY_KEY { None } else { self.table.lookup(key) };
            let ok = actual == Some(expected);
            if ok {
                self.table.insert(key, new);
            }
            out[i] = Some(OpResult::Cas { ok, actual });
        }
        for &(i, key, delta) in &g.fetch_adds {
            let old = self.table.lookup(key);
            let new = old.unwrap_or(0).wrapping_add(delta);
            let step = self.table.insert(key, new);
            let outcome = if old.is_none() { Some(outcome_of(step)) } else { None };
            out[i] = Some(OpResult::FetchAdded { outcome, old });
        }
        for &(i, key) in &g.deletes {
            let hit = key != EMPTY_KEY && self.table.delete(key);
            out[i] = Some(OpResult::Deleted(hit));
        }
        for &(i, key) in &g.lookups {
            let v = if key == EMPTY_KEY { None } else { self.table.lookup(key) };
            out[i] = Some(OpResult::Value(v));
        }
        Ok(out.into_iter().map(|r| r.expect("every op yields exactly one result")).collect())
    }

    fn len(&self) -> usize {
        self.table.len()
    }

    fn load_factor(&self) -> f64 {
        self.table.load_factor()
    }

    fn maybe_resize(&mut self) -> Result<Option<ResizeEvent>> {
        Ok(None) // fixed-capacity simulation; resize measured on native
    }

    fn name(&self) -> &'static str {
        "simt"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::{bulk_insert, bulk_lookup};

    #[test]
    fn sim_backend_roundtrip() {
        let mut b = SimtBackend::new(SimHiveConfig { n_buckets: 64, ..Default::default() });
        let ops = bulk_insert(800, 3);
        b.execute(&ops).unwrap();
        assert_eq!(b.len(), 800);
        let keys: Vec<u32> = ops.iter().map(|o| o.key()).collect();
        let res = b.execute(&bulk_lookup(&keys)).unwrap();
        assert!(res.iter().all(|r| matches!(r, OpResult::Value(Some(_)))));
        assert!(b.breakdown().inserts == 800);
    }

    #[test]
    fn sim_backend_sentinel_keys_miss_all_classes() {
        // the sim's probe matches EMPTY_KEY against vacant slots, so the
        // backend must short-circuit sentinels like the other substrates
        let mut b = SimtBackend::new(SimHiveConfig { n_buckets: 16, ..Default::default() });
        let res = b
            .execute(&[
                Op::Update { key: EMPTY_KEY, value: 1 },
                Op::Cas { key: EMPTY_KEY, expected: 0, new: 1 },
                Op::Lookup { key: EMPTY_KEY },
                Op::Delete { key: EMPTY_KEY },
            ])
            .unwrap();
        assert_eq!(res[0], OpResult::Updated { old: None });
        assert_eq!(res[1], OpResult::Cas { ok: false, actual: None });
        assert_eq!(res[2], OpResult::Value(None));
        assert_eq!(res[3], OpResult::Deleted(false));
        assert_eq!(b.len(), 0, "a sentinel op mutated the simulated table");
        assert!(b.execute(&[Op::FetchAdd { key: EMPTY_KEY, delta: 1 }]).is_err());
    }

    #[test]
    fn sim_backend_rmw_classes_compose() {
        let mut b = SimtBackend::new(SimHiveConfig { n_buckets: 64, ..Default::default() });
        let res = b
            .execute(&[
                Op::Upsert { key: 1, value: 10 },
                Op::FetchAdd { key: 1, delta: 5 },
                Op::Cas { key: 1, expected: 15, new: 20 },
                Op::Update { key: 2, value: 9 },
                Op::InsertIfAbsent { key: 1, value: 99 },
                Op::Lookup { key: 1 },
            ])
            .unwrap();
        // class order: upsert(1→10) → if_absent(sees 10) → update(2 absent)
        // → cas(sees 10, misses 15) → fetch_add(10+5) → lookup(15)
        assert!(matches!(res[0], OpResult::Upserted { old: None, .. }));
        assert_eq!(res[1], OpResult::FetchAdded { outcome: None, old: Some(10) });
        assert_eq!(res[2], OpResult::Cas { ok: false, actual: Some(10) });
        assert_eq!(res[3], OpResult::Updated { old: None });
        assert_eq!(res[4], OpResult::InsertedIfAbsent { outcome: None, existing: Some(10) });
        assert_eq!(res[5], OpResult::Value(Some(15)));
    }
}
