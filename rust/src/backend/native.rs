//! Native backend — the lock-free `HiveTable` behind the `Backend` trait.

use crate::backend::{group_ops, Backend, BatchResult};
use crate::core::config::HiveConfig;
use crate::core::error::Result;
use crate::native::resize::ResizeEvent;
use crate::native::table::HiveTable;
use crate::workload::Op;
use std::sync::Arc;

/// Backend over the native concurrent table. Holding an `Arc` lets other
/// threads (and direct users) share the same table.
pub struct NativeBackend {
    table: Arc<HiveTable>,
}

impl NativeBackend {
    /// Backend with a fresh table from `cfg`.
    pub fn new(cfg: HiveConfig) -> Result<Self> {
        Ok(NativeBackend { table: Arc::new(HiveTable::new(cfg)?) })
    }

    /// Backend over an existing shared table.
    ///
    /// Coherence caveat: the stamp this backend vouches with
    /// ([`Backend::coherence_stamp`]) moves on reallocation and stash
    /// drains, not on individual key writes. A coordinator layering its
    /// hot-key cache over a *shared* table therefore stays coherent
    /// only if every key write for the shard flows through the
    /// coordinator itself; external sharers must confine themselves to
    /// migration-type operations (`maybe_resize`, `grow_buckets`,
    /// `shrink_buckets` — the shape `tests/test_cache.rs` exercises) or
    /// the cache must be disabled (`cache_capacity: 0`).
    pub fn shared(table: Arc<HiveTable>) -> Self {
        NativeBackend { table }
    }

    /// The underlying table.
    pub fn table(&self) -> &Arc<HiveTable> {
        &self.table
    }
}

impl Backend for NativeBackend {
    fn execute(&mut self, ops: &[Op]) -> Result<BatchResult> {
        use crate::native::table::InsertOutcome;
        let (ins, del, luk) = group_ops(ops);
        let mut res = BatchResult::default();
        // Forward each op class to the table's bulk fast path: one epoch
        // pin per class instead of one per op. Incremental migration runs
        // concurrently with these windows; only a physical reallocation
        // (capacity-class crossing) waits for the pin to drain.
        if !ins.is_empty() {
            let pairs: Vec<(u32, u32)> = ins.iter().map(|&(_, k, v)| (k, v)).collect();
            // `insert_batch` validates keys up front and never fails
            // mid-batch: a window that outgrows capacity parks words
            // pending the next resize epoch (§IV-A step 4) instead of
            // erroring, and the between-batch resize controller grows the
            // table. Errors here are therefore pre-mutation and safe to
            // propagate without retry logic.
            let outcomes = self.table.insert_batch(&pairs)?;
            for outcome in outcomes {
                match outcome {
                    InsertOutcome::Replaced => res.replaced += 1,
                    InsertOutcome::Stashed => res.stashed += 1,
                    _ => res.inserted += 1,
                }
            }
        }
        if !del.is_empty() {
            let keys: Vec<u32> = del.iter().map(|&(_, k)| k).collect();
            res.deletes = self.table.delete_batch(&keys);
        }
        if !luk.is_empty() {
            let keys: Vec<u32> = luk.iter().map(|&(_, k)| k).collect();
            res.lookups = self.table.lookup_batch(&keys);
        }
        Ok(res)
    }

    fn len(&self) -> usize {
        self.table.len()
    }

    fn load_factor(&self) -> f64 {
        self.table.load_factor()
    }

    fn maybe_resize(&mut self) -> Result<Option<ResizeEvent>> {
        Ok(self.table.maybe_resize())
    }

    fn name(&self) -> &'static str {
        "native"
    }

    fn coherence_stamp(&self) -> Option<u64> {
        Some(self.table.coherence_stamp())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::{bulk_insert, bulk_lookup, Op};

    #[test]
    fn executes_mixed_batches() {
        let mut b = NativeBackend::new(HiveConfig::default().with_buckets(64)).unwrap();
        let inserts = bulk_insert(1000, 1);
        b.execute(&inserts).unwrap();
        assert_eq!(b.len(), 1000);
        let keys: Vec<u32> = inserts.iter().map(|o| o.key()).collect();
        let res = b.execute(&bulk_lookup(&keys)).unwrap();
        assert_eq!(res.lookups.len(), 1000);
        assert!(res.lookups.iter().all(Option::is_some));
        // delete half
        let dels: Vec<Op> = keys[..500].iter().map(|&key| Op::Delete { key }).collect();
        let res = b.execute(&dels).unwrap();
        assert!(res.deletes.iter().all(|&d| d));
        assert_eq!(b.len(), 500);
    }

    #[test]
    fn resize_triggers_through_backend() {
        let cfg = HiveConfig::default().with_buckets(4);
        let mut b = NativeBackend::new(cfg).unwrap();
        let n = (4 * 32) as f64 * 0.92;
        let ops = bulk_insert(n as usize, 2);
        b.execute(&ops).unwrap();
        assert!(b.load_factor() > 0.9);
        let ev = b.maybe_resize().unwrap();
        assert!(matches!(ev, Some(ResizeEvent::Grew { .. })));
    }
}
