//! Native backend — the lock-free `HiveTable` behind the `Backend` trait.

use crate::backend::Backend;
use crate::core::config::HiveConfig;
use crate::core::error::Result;
use crate::native::resize::ResizeEvent;
use crate::native::table::HiveTable;
use crate::workload::{Op, OpResult};
use std::sync::Arc;

/// Backend over the native concurrent table. Holding an `Arc` lets other
/// threads (and direct users) share the same table.
pub struct NativeBackend {
    table: Arc<HiveTable>,
}

impl NativeBackend {
    /// Backend with a fresh table from `cfg`.
    pub fn new(cfg: HiveConfig) -> Result<Self> {
        Ok(NativeBackend { table: Arc::new(HiveTable::new(cfg)?) })
    }

    /// Backend over an existing shared table.
    ///
    /// Coherence caveat: the stamp this backend vouches with
    /// ([`Backend::coherence_stamp`]) moves on reallocation and stash
    /// drains, not on individual key writes. A coordinator layering its
    /// hot-key cache over a *shared* table therefore stays coherent
    /// only if every key write for the shard flows through the
    /// coordinator itself; external sharers must confine themselves to
    /// migration-type operations (`maybe_resize`, `grow_buckets`,
    /// `shrink_buckets` — the shape `tests/test_cache.rs` exercises) or
    /// the cache must be disabled (`cache_capacity: 0`).
    pub fn shared(table: Arc<HiveTable>) -> Self {
        NativeBackend { table }
    }

    /// The underlying table.
    pub fn table(&self) -> &Arc<HiveTable> {
        &self.table
    }
}

impl Backend for NativeBackend {
    fn execute(&mut self, ops: &[Op]) -> Result<Vec<OpResult>> {
        // Forward the window to the table's grouped bulk fast path: one
        // epoch pin per op class instead of one per op. Incremental
        // migration runs concurrently with these windows; only a
        // physical reallocation (capacity-class crossing) waits for the
        // pin to drain. The inserting classes validate keys up front and
        // never fail mid-batch — a window that outgrows capacity parks
        // words pending the next resize epoch (§IV-A step 4) instead of
        // erroring, so errors here are pre-mutation and safe to
        // propagate without retry logic.
        self.table.execute_ops(ops)
    }

    fn len(&self) -> usize {
        self.table.len()
    }

    fn load_factor(&self) -> f64 {
        self.table.load_factor()
    }

    fn maybe_resize(&mut self) -> Result<Option<ResizeEvent>> {
        Ok(self.table.maybe_resize())
    }

    fn name(&self) -> &'static str {
        "native"
    }

    fn coherence_stamp(&self) -> Option<u64> {
        Some(self.table.coherence_stamp())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::{bulk_insert, bulk_lookup, Op};

    #[test]
    fn executes_mixed_batches() {
        let mut b = NativeBackend::new(HiveConfig::default().with_buckets(64)).unwrap();
        let inserts = bulk_insert(1000, 1);
        b.execute(&inserts).unwrap();
        assert_eq!(b.len(), 1000);
        let keys: Vec<u32> = inserts.iter().map(|o| o.key()).collect();
        let res = b.execute(&bulk_lookup(&keys)).unwrap();
        assert_eq!(res.len(), 1000);
        assert!(res.iter().all(|r| matches!(r, OpResult::Value(Some(_)))));
        // delete half
        let dels: Vec<Op> = keys[..500].iter().map(|&key| Op::Delete { key }).collect();
        let res = b.execute(&dels).unwrap();
        assert!(res.iter().all(|r| *r == OpResult::Deleted(true)));
        assert_eq!(b.len(), 500);
    }

    #[test]
    fn resize_triggers_through_backend() {
        let cfg = HiveConfig::default().with_buckets(4);
        let mut b = NativeBackend::new(cfg).unwrap();
        let n = (4 * 32) as f64 * 0.92;
        let ops = bulk_insert(n as usize, 2);
        b.execute(&ops).unwrap();
        assert!(b.load_factor() > 0.9);
        let ev = b.maybe_resize().unwrap();
        assert!(matches!(ev, Some(ResizeEvent::Grew { .. })));
    }
}
