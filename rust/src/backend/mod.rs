//! Execution backends: one table interface, three substrates.
//!
//! The coordinator's workers drive a [`Backend`]; which substrate executes
//! the operations is a config choice:
//!
//! * [`NativeBackend`] — the lock-free CPU table (`native::HiveTable`),
//!   the throughput substrate;
//! * [`XlaBackend`] — bulk AOT-compiled XLA programs via PJRT
//!   (`runtime::XlaTable`), the L1/L2 paper path;
//! * [`SimtBackend`] — the warp simulator (`simgpu::SimHive`), the
//!   microarchitectural-metrics substrate.
//!
//! Within one dispatch window the batcher groups operations by type
//! (insert → delete → lookup). Requests in one window are concurrent —
//! they carry no cross-ordering guarantee — so the grouped execution is a
//! legal linearization (standard batched-serving semantics; see
//! `coordinator::batcher`).

use crate::core::error::Result;
use crate::native::resize::ResizeEvent;
use crate::workload::Op;

/// Result of one executed batch.
#[derive(Debug, Default, Clone)]
pub struct BatchResult {
    /// One entry per lookup op, in submission order.
    pub lookups: Vec<Option<u32>>,
    /// One entry per delete op: did it remove a key?
    pub deletes: Vec<bool>,
    /// Inserted (new) key count.
    pub inserted: usize,
    /// Replaced key count.
    pub replaced: usize,
    /// Overflowed-to-stash count.
    pub stashed: usize,
}

/// A pluggable table substrate driven by the coordinator.
///
/// Deliberately NOT `Send`: the PJRT client behind [`XlaBackend`] is
/// single-threaded (`Rc` internals), so each coordinator worker
/// *constructs* its backend inside its own thread (see
/// `coordinator::service::Coordinator::start`).
pub trait Backend {
    /// Execute one batch of operations (grouped-by-type semantics).
    fn execute(&mut self, ops: &[Op]) -> Result<BatchResult>;
    /// Live entries.
    fn len(&self) -> usize;
    /// Current load factor.
    fn load_factor(&self) -> f64;
    /// Run the load-aware resize policy once (between batches).
    fn maybe_resize(&mut self) -> Result<Option<ResizeEvent>>;
    /// Substrate name for logs/stats.
    fn name(&self) -> &'static str;
    /// Stamp consumed by read-through caches layered above this backend
    /// (`coordinator::cache`): any change means cached entries may no
    /// longer reflect table state that moved outside the caller's own
    /// operation stream (reallocation, stash drain) and must be dropped
    /// wholesale. `None` — the default — means the substrate cannot
    /// vouch for cached entries at all and the caching layer must stay
    /// disabled for it.
    fn coherence_stamp(&self) -> Option<u64> {
        None
    }
}

pub mod native;
pub mod xla;
pub mod simt;

pub use native::NativeBackend;
pub use simt::SimtBackend;
pub use xla::XlaBackend;

/// Split a window of ops into (inserts, deletes, lookups) preserving
/// intra-class order; returns the ops plus their original indices.
pub(crate) fn group_ops(
    ops: &[Op],
) -> (Vec<(usize, u32, u32)>, Vec<(usize, u32)>, Vec<(usize, u32)>) {
    let mut ins = Vec::new();
    let mut del = Vec::new();
    let mut luk = Vec::new();
    for (i, op) in ops.iter().enumerate() {
        match *op {
            Op::Insert { key, value } => ins.push((i, key, value)),
            Op::Delete { key } => del.push((i, key)),
            Op::Lookup { key } => luk.push((i, key)),
        }
    }
    (ins, del, luk)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grouping_preserves_order_and_indices() {
        let ops = vec![
            Op::Lookup { key: 1 },
            Op::Insert { key: 2, value: 20 },
            Op::Delete { key: 3 },
            Op::Insert { key: 4, value: 40 },
            Op::Lookup { key: 5 },
        ];
        let (ins, del, luk) = group_ops(&ops);
        assert_eq!(ins, vec![(1, 2, 20), (3, 4, 40)]);
        assert_eq!(del, vec![(2, 3)]);
        assert_eq!(luk, vec![(0, 1), (4, 5)]);
    }
}
