//! Execution backends: one table interface, three substrates.
//!
//! The coordinator's workers drive a [`Backend`] — one per worker, and
//! under the sharded plane one per *shard*, each wrapping its own
//! independent table instance (own epoch domain, stash, coherence
//! stamp, counters; see `coordinator::shard`). Which substrate executes
//! the operations is a config choice:
//!
//! * [`NativeBackend`] — the lock-free CPU table (`native::HiveTable`),
//!   the throughput substrate;
//! * [`XlaBackend`] — bulk AOT-compiled XLA programs via PJRT
//!   (`runtime::XlaTable`), the L1/L2 paper path;
//! * [`SimtBackend`] — the warp simulator (`simgpu::SimHive`), the
//!   microarchitectural-metrics substrate.
//!
//! ## Grouped execution of the typed operation plane
//!
//! [`Backend::execute`] takes a window of [`Op`]s and returns one typed
//! [`OpResult`] **per op, in submission order** — found values, previous
//! values, CAS verdicts and placement outcomes all ride the same vector,
//! so callers never re-correlate type-segregated result arrays (the old
//! `BatchResult` shape this replaced). Within one dispatch window the
//! backends group operations by class and execute the classes in a fixed
//! order:
//!
//! ```text
//!   upserts (Insert|Upsert) → insert-if-absents → updates → CAS →
//!   fetch-adds → deletes → lookups
//! ```
//!
//! Requests in one window are concurrent — they carry no cross-ordering
//! guarantee — so the grouped execution is a legal linearization
//! (standard batched-serving semantics; see `coordinator::batcher`).
//! Callers needing read-your-write order between two ops put them in
//! different windows (or wait the first ticket).

use crate::core::error::Result;
use crate::native::resize::ResizeEvent;
use crate::workload::Op;

/// A pluggable table substrate driven by the coordinator.
///
/// Deliberately NOT `Send`: the PJRT client behind [`XlaBackend`] is
/// single-threaded (`Rc` internals), so each coordinator worker
/// *constructs* its backend inside its own thread (see
/// `coordinator::service::Coordinator::start`).
pub trait Backend {
    /// Execute one window of operations (grouped-by-class semantics —
    /// module docs), returning one typed [`OpResult`] per op in
    /// submission order. Inserting classes (`Insert`/`Upsert`/
    /// `InsertIfAbsent`/`FetchAdd`) validate keys up front: a sentinel
    /// key fails the window before any mutation.
    fn execute(&mut self, ops: &[Op]) -> Result<Vec<OpResult>>;
    /// Live entries.
    fn len(&self) -> usize;
    /// Current load factor.
    fn load_factor(&self) -> f64;
    /// Run the load-aware resize policy once (between batches).
    fn maybe_resize(&mut self) -> Result<Option<ResizeEvent>>;
    /// Substrate name for logs/stats.
    fn name(&self) -> &'static str;
    /// Stamp consumed by read-through caches layered above this backend
    /// (`coordinator::cache`): any change means cached entries may no
    /// longer reflect table state that moved outside the caller's own
    /// operation stream (reallocation, stash drain) and must be dropped
    /// wholesale. `None` — the default — means the substrate cannot
    /// vouch for cached entries at all and the caching layer must stay
    /// disabled for it.
    fn coherence_stamp(&self) -> Option<u64> {
        None
    }
}

pub mod native;
pub mod xla;
pub mod simt;

pub use native::NativeBackend;
pub use simt::SimtBackend;
pub use xla::XlaBackend;

// Re-exported beside the trait that consumes it: `Backend::execute` is
// the plane's chokepoint, so backend-facing code can import the result
// type from here.
pub use crate::workload::OpResult;

/// A window of ops split by class, each entry tagged with its original
/// submission index so per-class results scatter back into submission
/// order. Class vectors preserve intra-class order.
#[derive(Debug, Default)]
pub(crate) struct OpClasses {
    /// `Insert` | `Upsert`: `(index, key, value)`.
    pub upserts: Vec<(usize, u32, u32)>,
    /// `InsertIfAbsent`: `(index, key, value)`.
    pub if_absents: Vec<(usize, u32, u32)>,
    /// `Update`: `(index, key, value)`.
    pub updates: Vec<(usize, u32, u32)>,
    /// `Cas`: `(index, key, expected, new)`.
    pub cas: Vec<(usize, u32, u32, u32)>,
    /// `FetchAdd`: `(index, key, delta)`.
    pub fetch_adds: Vec<(usize, u32, u32)>,
    /// `Delete`: `(index, key)`.
    pub deletes: Vec<(usize, u32)>,
    /// `Lookup`: `(index, key)`.
    pub lookups: Vec<(usize, u32)>,
}

/// Pre-mutation key validation shared by every `Backend::execute` and
/// `HiveTable::execute_ops`: the inserting classes (`Insert`/`Upsert`/
/// `InsertIfAbsent`/`FetchAdd`) reject the reserved EMPTY sentinel for
/// the whole window before anything executes. Non-inserting classes
/// handle the sentinel inline as a miss.
pub(crate) fn validate_insert_keys(ops: &[Op]) -> Result<()> {
    for op in ops {
        match *op {
            Op::Insert { key, .. }
            | Op::Upsert { key, .. }
            | Op::InsertIfAbsent { key, .. }
            | Op::FetchAdd { key, .. }
                if key == crate::core::packed::EMPTY_KEY =>
            {
                return Err(crate::core::error::HiveError::InvalidKey(key));
            }
            _ => {}
        }
    }
    Ok(())
}

/// Split a window of ops into per-class vectors (class execution order:
/// module docs), preserving intra-class order and original indices.
pub(crate) fn group_ops(ops: &[Op]) -> OpClasses {
    let mut g = OpClasses::default();
    for (i, op) in ops.iter().enumerate() {
        match *op {
            Op::Insert { key, value } | Op::Upsert { key, value } => {
                g.upserts.push((i, key, value));
            }
            Op::InsertIfAbsent { key, value } => g.if_absents.push((i, key, value)),
            Op::Update { key, value } => g.updates.push((i, key, value)),
            Op::Cas { key, expected, new } => g.cas.push((i, key, expected, new)),
            Op::FetchAdd { key, delta } => g.fetch_adds.push((i, key, delta)),
            Op::Delete { key } => g.deletes.push((i, key)),
            Op::Lookup { key } => g.lookups.push((i, key)),
        }
    }
    g
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grouping_preserves_order_and_indices() {
        let ops = vec![
            Op::Lookup { key: 1 },
            Op::Insert { key: 2, value: 20 },
            Op::Delete { key: 3 },
            Op::Upsert { key: 4, value: 40 },
            Op::Lookup { key: 5 },
            Op::Cas { key: 6, expected: 1, new: 2 },
            Op::FetchAdd { key: 7, delta: 3 },
            Op::Update { key: 8, value: 80 },
            Op::InsertIfAbsent { key: 9, value: 90 },
        ];
        let g = group_ops(&ops);
        assert_eq!(g.upserts, vec![(1, 2, 20), (3, 4, 40)], "Insert and Upsert share a class");
        assert_eq!(g.deletes, vec![(2, 3)]);
        assert_eq!(g.lookups, vec![(0, 1), (4, 5)]);
        assert_eq!(g.cas, vec![(5, 6, 1, 2)]);
        assert_eq!(g.fetch_adds, vec![(6, 7, 3)]);
        assert_eq!(g.updates, vec![(7, 8, 80)]);
        assert_eq!(g.if_absents, vec![(8, 9, 90)]);
    }
}
