//! Benchmark reporting: aligned tables, CSV + JSON emission, MOPS
//! arithmetic, paper-comparison rows, and the per-op / batched parallel
//! drivers shared by `cargo bench` harnesses and the CLI.

pub mod json;

use std::fmt::Write as _;
use std::time::Duration;

/// Million operations per second for `ops` completed in `dur`.
pub fn mops(ops: usize, dur: Duration) -> f64 {
    if dur.as_secs_f64() == 0.0 {
        return f64::INFINITY;
    }
    ops as f64 / dur.as_secs_f64() / 1e6
}

/// A simple fixed-width table printer for bench output.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
    title: String,
}

impl Table {
    /// New table with a title and column headers.
    pub fn new(title: &str, headers: &[&str]) -> Self {
        Table {
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
            title: title.to_string(),
        }
    }

    /// Append one row (stringified cells).
    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len());
        self.rows.push(cells);
    }

    /// Render as an aligned text table.
    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(String::len).collect();
        for row in &self.rows {
            for (w, cell) in widths.iter_mut().zip(row) {
                *w = (*w).max(cell.len());
            }
        }
        let mut out = String::new();
        let _ = writeln!(out, "\n=== {} ===", self.title);
        let line: String = self
            .headers
            .iter()
            .zip(&widths)
            .map(|(h, w)| format!("{h:>w$}  "))
            .collect();
        let _ = writeln!(out, "{}", line.trim_end());
        let _ = writeln!(out, "{}", "-".repeat(line.trim_end().len()));
        for row in &self.rows {
            let line: String =
                row.iter().zip(&widths).map(|(c, w)| format!("{c:>w$}  ")).collect();
            let _ = writeln!(out, "{}", line.trim_end());
        }
        out
    }

    /// Render as CSV (for plotting).
    pub fn to_csv(&self) -> String {
        let mut out = String::new();
        let _ = writeln!(out, "{}", self.headers.join(","));
        for row in &self.rows {
            let _ = writeln!(out, "{}", row.join(","));
        }
        out
    }

    /// Print to stdout and optionally save CSV next to the bench outputs.
    pub fn emit(&self, csv_path: Option<&str>) {
        print!("{}", self.render());
        if let Some(path) = csv_path {
            if let Some(dir) = std::path::Path::new(path).parent() {
                let _ = std::fs::create_dir_all(dir);
            }
            if let Err(e) = std::fs::write(path, self.to_csv()) {
                eprintln!("warn: could not write {path}: {e}");
            } else {
                println!("(csv saved to {path})");
            }
        }
    }
}

/// A paper-vs-measured comparison row for EXPERIMENTS.md.
pub fn compare_row(what: &str, paper: &str, measured: &str, holds: bool) -> String {
    format!(
        "| {what} | {paper} | {measured} | {} |",
        if holds { "✓" } else { "✗" }
    )
}

/// Drive `ops` through a [`ConcurrentMap`](crate::baselines::ConcurrentMap)
/// from `threads` OS threads (the benchmark's "warps"), returning the wall
/// time. Ops are sharded round-robin so every thread gets an even mix.
pub fn drive_parallel(
    map: std::sync::Arc<dyn crate::baselines::ConcurrentMap>,
    ops: &[crate::workload::Op],
    threads: usize,
) -> Duration {
    use crate::workload::Op;
    let shards: Vec<Vec<Op>> = (0..threads)
        .map(|t| ops.iter().skip(t).step_by(threads).copied().collect())
        .collect();
    let start = std::time::Instant::now();
    std::thread::scope(|s| {
        for shard in &shards {
            let map = std::sync::Arc::clone(&map);
            s.spawn(move || {
                for op in shard {
                    match *op {
                        Op::Insert { key, value } => {
                            let _ = map.insert(key, value);
                        }
                        Op::Lookup { key } => {
                            let _ = map.lookup(key);
                        }
                        Op::Delete { key } => {
                            let _ = map.delete(key);
                        }
                        Op::Upsert { key, value } => {
                            let _ = map.upsert(key, value);
                        }
                        Op::InsertIfAbsent { key, value } => {
                            let _ = map.insert_if_absent(key, value);
                        }
                        Op::Update { key, value } => {
                            let _ = map.update(key, value);
                        }
                        Op::Cas { key, expected, new } => {
                            let _ = map.cas(key, expected, new);
                        }
                        Op::FetchAdd { key, delta } => {
                            let _ = map.fetch_add(key, delta);
                        }
                    }
                }
            });
        }
    });
    start.elapsed()
}

/// Batched counterpart of [`drive_parallel`]: each thread splits its
/// round-robin shard into `batch`-sized windows and drives every window
/// through the [`ConcurrentMap`](crate::baselines::ConcurrentMap) batch
/// methods (inserts, then RMW-class ops, then deletes, then lookups —
/// the same grouped-window linearization shape the coordinator's
/// backend applies). The conditional/RMW classes ride `execute_ops`, so
/// tables with a typed bulk fast path (HiveTable) use it and the rest
/// fall back to the trait's default loop — the same driver compares all
/// baselines fairly.
pub fn drive_parallel_batched(
    map: std::sync::Arc<dyn crate::baselines::ConcurrentMap>,
    ops: &[crate::workload::Op],
    threads: usize,
    batch: usize,
) -> Duration {
    use crate::workload::Op;
    assert!(batch > 0, "batch size must be positive");
    let shards: Vec<Vec<Op>> = (0..threads)
        .map(|t| ops.iter().skip(t).step_by(threads).copied().collect())
        .collect();
    let start = std::time::Instant::now();
    std::thread::scope(|s| {
        for shard in &shards {
            let map = std::sync::Arc::clone(&map);
            s.spawn(move || {
                let mut ins: Vec<(u32, u32)> = Vec::with_capacity(batch);
                let mut rmw: Vec<Op> = Vec::with_capacity(batch);
                let mut del: Vec<u32> = Vec::with_capacity(batch);
                let mut luk: Vec<u32> = Vec::with_capacity(batch);
                for window in shard.chunks(batch) {
                    ins.clear();
                    rmw.clear();
                    del.clear();
                    luk.clear();
                    for op in window {
                        match *op {
                            Op::Insert { key, value } => ins.push((key, value)),
                            Op::Delete { key } => del.push(key),
                            Op::Lookup { key } => luk.push(key),
                            Op::Upsert { .. }
                            | Op::InsertIfAbsent { .. }
                            | Op::Update { .. }
                            | Op::Cas { .. }
                            | Op::FetchAdd { .. } => rmw.push(*op),
                        }
                    }
                    if !ins.is_empty() {
                        let _ = map.insert_batch(&ins);
                    }
                    if !rmw.is_empty() {
                        let _ = map.execute_ops(&rmw);
                    }
                    if !del.is_empty() {
                        let _ = map.delete_batch(&del);
                    }
                    if !luk.is_empty() {
                        let _ = map.lookup_batch(&luk);
                    }
                }
            });
        }
    });
    start.elapsed()
}

/// Closed-loop service driver: `clients` threads each issue their
/// round-robin shard of `ops` one at a time through the blocking
/// [`Handle`](crate::coordinator::Handle) API — exactly one op in
/// flight per client, the pre-pipeline serving model (and fig11's
/// baseline mode).
pub fn drive_service_closed(
    handle: &crate::coordinator::Handle,
    ops: &[crate::workload::Op],
    clients: usize,
) -> Duration {
    use crate::workload::Op;
    assert!(clients > 0, "need at least one client");
    let shards: Vec<Vec<Op>> = (0..clients)
        .map(|c| ops.iter().skip(c).step_by(clients).copied().collect())
        .collect();
    let start = std::time::Instant::now();
    std::thread::scope(|s| {
        for shard in &shards {
            let h = handle.clone();
            s.spawn(move || {
                for op in shard {
                    match *op {
                        Op::Insert { key, value } => {
                            let _ = h.insert(key, value);
                        }
                        Op::Lookup { key } => {
                            let _ = h.lookup(key);
                        }
                        Op::Delete { key } => {
                            let _ = h.delete(key);
                        }
                        Op::Upsert { key, value } => {
                            let _ = h.upsert(key, value);
                        }
                        Op::InsertIfAbsent { key, value } => {
                            let _ = h.insert_if_absent(key, value);
                        }
                        Op::Update { key, value } => {
                            let _ = h.update(key, value);
                        }
                        Op::Cas { key, expected, new } => {
                            let _ = h.cas(key, expected, new);
                        }
                        Op::FetchAdd { key, delta } => {
                            let _ = h.fetch_add(key, delta);
                        }
                    }
                }
            });
        }
    });
    start.elapsed()
}

/// Pipelined service driver: `clients` threads each keep up to `window`
/// ops in flight through a [`Pipeline`](crate::coordinator::Pipeline),
/// retiring the oldest ticket once the window is full (fig11's
/// pipelined mode). With `window == 1` this degenerates to the
/// closed-loop model.
pub fn drive_service_pipelined(
    handle: &crate::coordinator::Handle,
    ops: &[crate::workload::Op],
    clients: usize,
    window: usize,
) -> Duration {
    use crate::workload::Op;
    assert!(clients > 0, "need at least one client");
    let window = window.max(1);
    let shards: Vec<Vec<Op>> = (0..clients)
        .map(|c| ops.iter().skip(c).step_by(clients).copied().collect())
        .collect();
    let start = std::time::Instant::now();
    std::thread::scope(|s| {
        for shard in &shards {
            let h = handle.clone();
            s.spawn(move || {
                let pipe = h.pipeline(window);
                let mut inflight = std::collections::VecDeque::with_capacity(window);
                for op in shard {
                    if inflight.len() == window {
                        let ticket: crate::coordinator::Ticket =
                            inflight.pop_front().expect("window non-empty");
                        let _ = ticket.wait();
                    }
                    match pipe.submit(*op) {
                        Ok(t) => inflight.push_back(t),
                        Err(_) => break, // service shut down underneath us
                    }
                }
                for t in inflight {
                    let _ = t.wait();
                }
            });
        }
    });
    start.elapsed()
}

/// Per-thread batch window for the batched driver: `HIVE_BENCH_BATCH`,
/// default 4096 ops (big enough to amortize the phase guard, small enough
/// to keep the candidate table cache-resident).
pub fn bench_batch() -> usize {
    std::env::var("HIVE_BENCH_BATCH")
        .ok()
        .and_then(|s| s.parse().ok())
        .filter(|&b| b > 0)
        .unwrap_or(4096)
}

/// Benchmark scale from the environment: `HIVE_BENCH_SCALE` ∈
/// {smoke, small, paper}; defaults to `small`. Returns the max log2 key
/// count per figure (the paper sweeps 2^20..2^25 on a 4090; CPU defaults
/// are scaled down but the *shape* comparisons are preserved).
pub fn bench_max_pow(default_small: u32, paper: u32) -> u32 {
    match std::env::var("HIVE_BENCH_SCALE").as_deref() {
        Ok("paper") => paper,
        Ok("smoke") => default_small.saturating_sub(3).max(14),
        _ => default_small,
    }
}

/// Bench thread count: `HIVE_BENCH_THREADS` or available parallelism.
pub fn bench_threads() -> usize {
    std::env::var("HIVE_BENCH_THREADS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or_else(|| {
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4)
        })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mops_math() {
        assert!((mops(1_000_000, Duration::from_secs(1)) - 1.0).abs() < 1e-9);
        assert!((mops(3_000_000, Duration::from_millis(500)) - 6.0).abs() < 1e-9);
    }

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new("demo", &["keys", "MOPS"]);
        t.row(vec!["1048576".into(), "123.4".into()]);
        t.row(vec!["64".into(), "9.1".into()]);
        let s = t.render();
        assert!(s.contains("=== demo ==="));
        assert!(s.contains("keys"));
        assert!(s.contains("1048576"));
        let csv = t.to_csv();
        assert_eq!(csv.lines().count(), 3);
        assert!(csv.starts_with("keys,MOPS"));
    }

    #[test]
    #[should_panic]
    fn row_width_mismatch_panics() {
        let mut t = Table::new("x", &["a", "b"]);
        t.row(vec!["1".into()]);
    }

    #[test]
    fn batched_driver_executes_all_ops() {
        use crate::baselines::ConcurrentMap;
        use std::sync::Arc;
        let t = Arc::new(crate::native::table::HiveTable::with_capacity(4096, 0.8).unwrap());
        let ops = crate::workload::bulk_insert(2048, 42);
        let map: Arc<dyn ConcurrentMap> = Arc::clone(&t) as Arc<dyn ConcurrentMap>;
        drive_parallel_batched(map, &ops, 4, 128);
        assert_eq!(t.len(), 2048);
        let keys: Vec<u32> = ops.iter().map(|o| o.key()).collect();
        assert!(t.lookup_batch(&keys).iter().all(Option::is_some));
    }
}
