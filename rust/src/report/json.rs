//! Minimal machine-readable JSON emission for bench outputs — no external
//! deps (the registry has no serde). Benches write one `bench_out/*.json`
//! next to each CSV so future PRs can track the perf trajectory
//! automatically.

use std::fmt::Write as _;

/// A JSON value. Construct with the helper fns ([`obj`], [`arr`], and the
/// `From` impls) and render with [`JsonVal::render`].
#[derive(Debug, Clone)]
pub enum JsonVal {
    /// `null`.
    Null,
    /// Boolean.
    Bool(bool),
    /// Integer (emitted without a decimal point).
    Int(i64),
    /// Float; non-finite values render as `null`.
    Num(f64),
    /// String (escaped on render).
    Str(String),
    /// Array.
    Arr(Vec<JsonVal>),
    /// Object with insertion-ordered keys.
    Obj(Vec<(String, JsonVal)>),
}

impl From<bool> for JsonVal {
    fn from(v: bool) -> Self {
        JsonVal::Bool(v)
    }
}
impl From<i64> for JsonVal {
    fn from(v: i64) -> Self {
        JsonVal::Int(v)
    }
}
impl From<usize> for JsonVal {
    fn from(v: usize) -> Self {
        JsonVal::Int(v as i64)
    }
}
impl From<u32> for JsonVal {
    fn from(v: u32) -> Self {
        JsonVal::Int(v as i64)
    }
}
impl From<u64> for JsonVal {
    fn from(v: u64) -> Self {
        // latency quantiles are u64 nanoseconds; the i64 range covers
        // ~292 years of them
        JsonVal::Int(v as i64)
    }
}
impl From<f64> for JsonVal {
    fn from(v: f64) -> Self {
        JsonVal::Num(v)
    }
}
impl From<&str> for JsonVal {
    fn from(v: &str) -> Self {
        JsonVal::Str(v.to_string())
    }
}
impl From<String> for JsonVal {
    fn from(v: String) -> Self {
        JsonVal::Str(v)
    }
}
impl From<Vec<JsonVal>> for JsonVal {
    fn from(v: Vec<JsonVal>) -> Self {
        JsonVal::Arr(v)
    }
}

/// Object literal helper: `obj(vec![("keys", 42.into()), ...])`.
pub fn obj(pairs: Vec<(&str, JsonVal)>) -> JsonVal {
    JsonVal::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

/// Array literal helper.
pub fn arr(items: Vec<JsonVal>) -> JsonVal {
    JsonVal::Arr(items)
}

/// One standard per-system bench row: `{<size_field>, system, driver,
/// mops}` — the schema the Perf log tooling reads (`size_field` is
/// `"keys"` for fig6/fig7, `"ops"` for fig8).
pub fn bench_row(size_field: &str, n: usize, system: &str, driver: &str, mops: f64) -> JsonVal {
    obj(vec![
        (size_field, n.into()),
        ("system", system.into()),
        ("driver", driver.into()),
        ("mops", mops.into()),
    ])
}

/// One per-mix bench row: `{mix, system, driver, mops}` — the schema of
/// the RMW figure (`fig12_rmw`), keyed by mix name instead of size.
pub fn mix_row(mix: &str, system: &str, driver: &str, mops: f64) -> JsonVal {
    obj(vec![
        ("mix", mix.into()),
        ("system", system.into()),
        ("driver", driver.into()),
        ("mops", mops.into()),
    ])
}

/// One per-shard stats row: `{shard, ops, batches, hit_rate, forwarded,
/// moving_ops, keys_migrated, moves_completed, latency}` — the shard
/// breakdown the sharded-coordinator figures (fig13) publish next to the
/// merged aggregate, so per-shard imbalance and move traffic stay
/// visible instead of washing out in the merge.
pub fn shard_row(shard: usize, s: &crate::coordinator::ServiceStats) -> JsonVal {
    obj(vec![
        ("shard", shard.into()),
        ("ops", s.ops.into()),
        ("batches", s.batches.into()),
        ("hit_rate", s.cache_hit_rate().into()),
        ("forwarded", s.forwarded.into()),
        ("moving_ops", s.moving_ops.into()),
        ("keys_migrated", s.keys_migrated.into()),
        ("moves_completed", s.moves_completed.into()),
        ("latency", latency_obj(&s.latency_ns)),
    ])
}

/// The full per-shard breakdown of one run as a JSON array of
/// [`shard_row`]s plus an imbalance summary object:
/// `{imbalance, max_ops, mean_ops, shards: [...]}`. Wired into the
/// skew figures so Zipf-driven load imbalance across the bulk
/// sub-batch scatter is quantified next to the merged aggregate
/// instead of washing out in the merge. `imbalance` is
/// `max(ops) / mean(ops)` over shards — 1.0 is a perfectly even
/// scatter.
pub fn shard_breakdown(per_shard: &[crate::coordinator::ServiceStats]) -> JsonVal {
    let ops: Vec<u64> = per_shard.iter().map(|s| s.ops).collect();
    let max = ops.iter().copied().max().unwrap_or(0);
    let mean = if ops.is_empty() {
        0.0
    } else {
        ops.iter().sum::<u64>() as f64 / ops.len() as f64
    };
    let imbalance = if mean > 0.0 { max as f64 / mean } else { 0.0 };
    obj(vec![
        ("imbalance", imbalance.into()),
        ("max_ops", max.into()),
        ("mean_ops", mean.into()),
        (
            "shards",
            arr(per_shard.iter().enumerate().map(|(i, s)| shard_row(i, s)).collect()),
        ),
    ])
}

/// Latency quantiles of a histogram as a JSON object:
/// `{p50_ns, p99_ns, p999_ns, mean_ns, max_ns, count}` — the standard
/// latency fields the service figures (fig11) and the `kv_service`
/// example publish.
pub fn latency_obj(h: &crate::core::histogram::Histogram) -> JsonVal {
    obj(vec![
        ("p50_ns", h.quantile(0.50).into()),
        ("p99_ns", h.quantile(0.99).into()),
        ("p999_ns", h.quantile(0.999).into()),
        ("mean_ns", h.mean().into()),
        ("max_ns", h.max().into()),
        ("count", h.count().into()),
    ])
}

/// Wrap bench rows in the standard figure envelope and save to
/// `bench_out/<figure>.json`.
pub fn save_figure(figure: &str, threads: usize, batch: usize, rows: Vec<JsonVal>) {
    obj(vec![
        ("figure", figure.into()),
        ("threads", threads.into()),
        ("batch", batch.into()),
        ("rows", arr(rows)),
    ])
    .save(&format!("bench_out/{figure}.json"));
}

fn escape_into(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

impl JsonVal {
    fn write_into(&self, out: &mut String) {
        match self {
            JsonVal::Null => out.push_str("null"),
            JsonVal::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            JsonVal::Int(i) => {
                let _ = write!(out, "{i}");
            }
            JsonVal::Num(x) => {
                if x.is_finite() {
                    let _ = write!(out, "{x}");
                } else {
                    out.push_str("null");
                }
            }
            JsonVal::Str(s) => escape_into(out, s),
            JsonVal::Arr(items) => {
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    item.write_into(out);
                }
                out.push(']');
            }
            JsonVal::Obj(pairs) => {
                out.push('{');
                for (i, (k, v)) in pairs.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    escape_into(out, k);
                    out.push(':');
                    v.write_into(out);
                }
                out.push('}');
            }
        }
    }

    /// Render to a compact JSON string.
    pub fn render(&self) -> String {
        let mut out = String::new();
        self.write_into(&mut out);
        out
    }

    /// Write to `path` (creating parent dirs), logging like
    /// [`super::Table::emit`].
    pub fn save(&self, path: &str) {
        if let Some(dir) = std::path::Path::new(path).parent() {
            let _ = std::fs::create_dir_all(dir);
        }
        if let Err(e) = std::fs::write(path, self.render()) {
            eprintln!("warn: could not write {path}: {e}");
        } else {
            println!("(json saved to {path})");
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_scalars_and_nesting() {
        let v = obj(vec![
            ("figure", "fig6".into()),
            ("threads", 8usize.into()),
            ("mops", 123.5f64.into()),
            ("ok", true.into()),
            ("missing", JsonVal::Null),
            ("rows", arr(vec![obj(vec![("keys", 1048576usize.into())])])),
        ]);
        assert_eq!(
            v.render(),
            r#"{"figure":"fig6","threads":8,"mops":123.5,"ok":true,"missing":null,"rows":[{"keys":1048576}]}"#
        );
    }

    #[test]
    fn escapes_strings_and_handles_nonfinite() {
        let v = obj(vec![
            ("s", "a\"b\\c\nd".into()),
            ("inf", f64::INFINITY.into()),
            ("nan", f64::NAN.into()),
        ]);
        assert_eq!(v.render(), r#"{"s":"a\"b\\c\nd","inf":null,"nan":null}"#);
    }

    #[test]
    fn integers_have_no_decimal_point() {
        assert_eq!(JsonVal::Int(3).render(), "3");
        assert_eq!(JsonVal::Num(3.0).render(), "3");
    }

    #[test]
    fn mix_row_has_the_fig12_schema() {
        assert_eq!(
            mix_row("rmw_heavy", "HiveHash", "batched", 12.5).render(),
            r#"{"mix":"rmw_heavy","system":"HiveHash","driver":"batched","mops":12.5}"#
        );
    }

    #[test]
    fn shard_row_has_the_breakdown_schema() {
        let mut s = crate::coordinator::ServiceStats::default();
        s.ops = 100;
        s.batches = 4;
        s.forwarded = 2;
        s.moving_ops = 5;
        s.keys_migrated = 30;
        s.moves_completed = 1;
        let r = shard_row(3, &s).render();
        assert!(r.starts_with(r#"{"shard":3,"ops":100,"batches":4"#), "{r}");
        assert!(r.contains(r#""forwarded":2"#), "{r}");
        assert!(r.contains(r#""moving_ops":5"#), "{r}");
        assert!(r.contains(r#""keys_migrated":30"#), "{r}");
        assert!(r.contains(r#""moves_completed":1"#), "{r}");
        assert!(r.contains(r#""latency":{"#), "{r}");
    }

    #[test]
    fn shard_breakdown_quantifies_imbalance() {
        let mut hot = crate::coordinator::ServiceStats::default();
        hot.ops = 300;
        let mut cold = crate::coordinator::ServiceStats::default();
        cold.ops = 100;
        let r = shard_breakdown(&[hot, cold]).render();
        assert!(r.contains(r#""imbalance":1.5"#), "{r}");
        assert!(r.contains(r#""max_ops":300"#), "{r}");
        assert!(r.contains(r#""mean_ops":200"#), "{r}");
        assert!(r.contains(r#""shards":[{"shard":0"#), "{r}");
        // empty shard lists degrade to zeros, not NaN/panic
        assert!(shard_breakdown(&[]).render().contains(r#""imbalance":0"#));
    }

    #[test]
    fn latency_obj_surfaces_quantiles() {
        let mut h = crate::core::histogram::Histogram::new();
        for v in 1..=1000u64 {
            h.record(v);
        }
        let s = latency_obj(&h).render();
        assert!(s.contains("\"p50_ns\":"), "{s}");
        assert!(s.contains("\"p99_ns\":"), "{s}");
        assert!(s.contains("\"p999_ns\":"), "{s}");
        assert!(s.contains("\"count\":1000"), "{s}");
    }
}
