//! # Hive Hash Table
//!
//! A reproduction of *"Hive Hash Table: A Warp-Cooperative, Dynamically
//! Resizable Hash Table for GPUs"* (Polak, Troendle, Jang — CS.DC 2025) as a
//! three-layer Rust + JAX + Pallas system:
//!
//! * **Layer 3 (this crate)** — the coordinator: a **sharded**
//!   batching/routing service. Keys hash into routing partitions and a
//!   seqlock-validated directory ([`coordinator::shard`]) maps each
//!   partition to one of N independent `HiveTable` shards — its own
//!   epoch domain, overflow stash, coherence stamp and striped counters,
//!   so cross-shard traffic never shares a cache line, and
//!   `Handle::reshard` moves partitions between shards *online* (flip →
//!   fence → dual-table serve → settle, never stop-the-world). Worker
//!   threads, their bounded submission rings and their hot-key caches
//!   pin to shards via a placement policy (round-robin or NUMA-aware
//!   from `/sys` topology). The request plane is pipelined (completion
//!   tickets, so one client thread keeps hundreds of ops in flight —
//!   [`coordinator::pipeline`]) and runs a resize controller per shard,
//!   over three execution substrates (native lock-free CPU, SIMT warp
//!   simulator, XLA/PJRT bulk backend) plus the baseline hash tables
//!   the paper compares against. Operations ride one typed plane
//!   end-to-end: a [`workload::Op`] — including the conditional and
//!   read-modify-write classes `InsertIfAbsent` / `Update` / `Upsert` /
//!   `Cas` / `FetchAdd`, each a single CAS on the packed 64-bit word —
//!   yields exactly one [`workload::OpResult`] in submission order
//!   through direct table calls, `ConcurrentMap` batches,
//!   `Backend::execute`, and the coordinator's `Handle`/`Pipeline`.
//!   In front of the plane sits a **network front door** ([`net`]): a
//!   RESP2-compatible TCP server (std-only — bounded acceptor,
//!   per-connection reader/writer threads) that maps `GET`/`SET`/
//!   `SETNX`/`DEL`/`INCRBY`/`CAS`/`MGET`/`MSET` onto the same typed
//!   ops, multiplexing each connection's pipelined commands onto a
//!   bounded-depth `Pipeline` window, so any RESP client (redis-cli,
//!   memtier) drives the table over a real socket (see `SERVING.md`).
//! * **Layer 2 (python/compile/model.py)** — JAX bulk formulations of the
//!   table operations, AOT-lowered to HLO artifacts.
//! * **Layer 1 (python/compile/kernels/)** — Pallas kernels for the probe /
//!   hash / migration hot spots (interpret=True on CPU PJRT).
//!
//! The paper's three contributions map onto modules:
//!
//! 1. *Cache-aligned packed buckets* → [`core::packed`] + the bucket arrays
//!    in [`native::table`] / [`simgpu`].
//! 2. *Warp-cooperative protocols (WABC / WCME)* → lane-accurate versions in
//!    [`simgpu`] over the [`simt`] simulator, atomic-CAS versions in
//!    [`native::table`] (single-op) and [`native::batch`] (bulk,
//!    kernel-launch-shaped), and vectorized bulk versions in the Pallas
//!    kernels.
//! 3. *Load-aware linear-hashing resize* → [`native::resize`] and the
//!    coordinator's [`coordinator::resize_ctl`]. Migration is incremental
//!    and operation-concurrent: operations pin an epoch
//!    ([`core::epoch`]) instead of taking a phase lock, buckets in
//!    flight carry migration markers, and physical reallocation swaps
//!    the state pointer after a grace period.
//!
//! ## Bucket layouts
//!
//! The native table supports three bucket layouts, selected per table via
//! [`core::config::Layout`]:
//!
//! * [`Layout::PackedAos`](core::config::Layout::PackedAos) — the paper's
//!   layout: 32 slots per bucket, each slot one packed 64-bit
//!   `(value << 32) | key` word mutated by a single CAS. Two 128-byte
//!   cache lines per bucket row.
//! * [`Layout::CompactQuotient`](core::config::Layout::CompactQuotient) —
//!   the quotiented layout ([`core::quotient`]): slots store a 2-bit
//!   candidate tag plus the hash *remainder* instead of the key, so a
//!   bucket row is 16 slots — exactly one cache line. Keys are
//!   reconstructed by inverting the tagged hash function
//!   ([`hash::HashKind::invert`]); resize re-quotients remainders in
//!   place as the bucket width changes. Fewer lines touched per probe at
//!   high load factor (the `fig14_compact` bench quantifies it).
//! * [`Layout::SplitSoa`](core::config::Layout::SplitSoa) — the split
//!   key/value-array ablation ([`native::soa`]) the paper argues against:
//!   two memory transactions per update and a consistency window.
//!
//! ## Probe engine
//!
//! All native probe cores (lookup, placement's replace check, delete,
//! and the conditional/RMW find phase) scan a bucket through one
//! primitive: the [`core::lanes`] ballot. One call scans the whole
//! 16/32-slot row and returns a candidate bitmask — the CPU image of
//! the paper's warp ballot — and `elect_match` picks the lowest lane
//! with an atomically re-validated ffs. Three interchangeable engines
//! produce the mask (per-slot scalar reference, portable SWAR on `u64`,
//! and `core::arch` SSE2/NEON behind the `simd` cargo feature), all
//! differentially tested to ballot identically. The bulk entry points
//! in [`native::batch`] add AMAC-style interleaving on top: G probe
//! state machines in flight per thread (default 8, see
//! [`HiveConfig::batch_interleave`](core::config::HiveConfig::batch_interleave)),
//! each issuing a real prefetch hint (`native::prefetch`) for the
//! bucket line it will touch G ops from now, so a batch overlaps G
//! cache misses where a per-op loop overlaps none. The `fig15_probe`
//! bench quantifies both halves.
//!
//! See `DESIGN.md` for the full system inventory and the CUDA→TPU hardware
//! adaptation, and `EXPERIMENTS.md` for paper-vs-measured results.
//!
//! ## Verification
//!
//! `TESTING.md` describes the verification tiers — unit batteries,
//! differential oracles, the `HIVE_TEST_SEED` stress matrix, bounded
//! loom-style model checking of the lock-free protocols
//! (`tests/model_*.rs` over [`core::model`] / [`core::sync`]), and
//! history-based linearizability checking ([`testutil::linearize`]) —
//! and how to run and bound each locally.

pub mod core;
pub mod hash;
pub mod native;
pub mod simt;
pub mod simgpu;
pub mod baselines;
pub mod runtime;
pub mod backend;
pub mod coordinator;
pub mod net;
pub mod workload;
pub mod report;
pub mod testutil;

pub use crate::core::config::{HiveConfig, Layout};
pub use crate::core::packed::{pack, unpack, unpack_key, unpack_value, EMPTY_KEY, EMPTY_WORD};
pub use crate::native::table::{HiveTable, InsertOutcome};
pub use crate::workload::{Op, OpResult};
