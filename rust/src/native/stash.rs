//! Overflow stash — a lock-free bounded pool of packed KV words
//! (paper §IV-A step 4).
//!
//! Insertions that exhaust both candidate buckets *and* the eviction bound
//! are redirected here; the stash is drained and its entries reinserted at
//! the next resize epoch. A slot is claimed by CASing the word directly
//! into it (EMPTY ⇒ free), so a slot is never reserved-but-unpublished:
//! scans, removals and the concurrent drain all race safely against
//! pushes, and a removed slot is immediately reusable. A padded live
//! counter gates the probe fast path (`is_quiescent`).
//!
//! (Earlier revisions used a head/tail ring; with the operation-concurrent
//! drain the head could never advance safely past a reserved slot, so the
//! window degenerated to permanently-full. The pool has no window at all.)

use crate::core::packed::{unpack_key, unpack_value, EMPTY_WORD};
use crate::core::sync::atomic::{AtomicU64, AtomicUsize, Ordering};

/// Lock-free bounded overflow stash.
#[derive(Debug)]
pub struct OverflowStash {
    slots: Box<[AtomicU64]>,
    /// Number of live (non-EMPTY) slots. Zero ⇒ probes may skip the stash.
    live: AtomicUsize,
}

impl OverflowStash {
    /// A stash with room for `capacity` entries (min 8, rounded to pow2 to
    /// keep sizing identical to the earlier ring).
    pub fn new(capacity: usize) -> Self {
        let cap = capacity.max(8).next_power_of_two();
        let slots = (0..cap).map(|_| AtomicU64::new(EMPTY_WORD)).collect::<Vec<_>>();
        OverflowStash { slots: slots.into_boxed_slice(), live: AtomicUsize::new(0) }
    }

    /// Physical capacity.
    pub fn capacity(&self) -> usize {
        self.slots.len()
    }

    /// `true` if the stash holds no entries. (Cheap gate so the probe fast
    /// path skips the stash entirely.)
    #[inline]
    pub fn is_quiescent(&self) -> bool {
        self.live.load(Ordering::Acquire) == 0
    }

    /// Number of live entries.
    pub fn window_len(&self) -> usize {
        self.live.load(Ordering::Acquire)
    }

    /// Try to push a packed word. Returns `false` if every slot is
    /// occupied (the operation is then flagged pending for the next
    /// resize — paper §IV-A).
    pub fn push(&self, word: u64) -> bool {
        debug_assert_ne!(word, EMPTY_WORD);
        // Start the scan at a key-derived offset so concurrent pushers
        // spread across the pool instead of all racing slot 0.
        let cap = self.slots.len();
        let start = unpack_key(word) as usize & (cap - 1);
        for i in 0..cap {
            let slot = &self.slots[(start + i) & (cap - 1)];
            if slot.load(Ordering::Relaxed) == EMPTY_WORD
                && slot
                    .compare_exchange(EMPTY_WORD, word, Ordering::AcqRel, Ordering::Relaxed)
                    .is_ok()
            {
                self.live.fetch_add(1, Ordering::Release);
                return true;
            }
        }
        false
    }

    /// Linear-scan lookup. O(capacity) — the stash is 1–2 % of table
    /// capacity and usually empty, so this is off the fast path (guarded
    /// by [`Self::is_quiescent`]).
    pub fn lookup(&self, key: u32) -> Option<u32> {
        for slot in self.slots.iter() {
            let w = slot.load(Ordering::Acquire);
            if w != EMPTY_WORD && unpack_key(w) == key {
                return Some(unpack_value(w));
            }
        }
        None
    }

    /// Replace the value of `key` if present. Returns true on success.
    /// Thin wrapper over [`OverflowStash::rmw`] so exactly one CAS-scan
    /// mutation path exists.
    pub fn replace(&self, key: u32, new_word: u64) -> bool {
        debug_assert_eq!(unpack_key(new_word), key, "replace word must carry its own key");
        let value = unpack_value(new_word);
        matches!(self.rmw(key, &|_| Some(value)), Some((_, true)))
    }

    /// Atomically read-modify-write the value of `key` if present:
    /// `f(old)` returns the replacement value, or `None` to leave the
    /// word untouched. Returns `Some((old, written))` when a slot
    /// holding `key` was found. The per-slot CAS retries in place while
    /// the slot still holds `key` (a concurrent replace just changes
    /// the value), and falls through to the rest of the scan when the
    /// word moves away (delete / drain retraction) — the caller's
    /// table-level retry logic covers that window.
    pub fn rmw(&self, key: u32, f: &dyn Fn(u32) -> Option<u32>) -> Option<(u32, bool)> {
        for slot in self.slots.iter() {
            let mut w = slot.load(Ordering::Acquire);
            while w != EMPTY_WORD && unpack_key(w) == key {
                let old = unpack_value(w);
                let Some(new) = f(old) else {
                    return Some((old, false));
                };
                match slot.compare_exchange(
                    w,
                    crate::core::packed::pack(key, new),
                    Ordering::AcqRel,
                    Ordering::Acquire,
                ) {
                    Ok(_) => return Some((old, true)),
                    Err(cur) => w = cur,
                }
            }
        }
        None
    }

    /// Delete `key` from the stash; its slot is immediately reusable.
    pub fn delete(&self, key: u32) -> bool {
        for slot in self.slots.iter() {
            let w = slot.load(Ordering::Acquire);
            if w != EMPTY_WORD
                && unpack_key(w) == key
                && slot.compare_exchange(w, EMPTY_WORD, Ordering::AcqRel, Ordering::Relaxed).is_ok()
            {
                self.live.fetch_sub(1, Ordering::Release);
                return true;
            }
        }
        false
    }

    /// Remove the *exact* `word` from the stash (one copy), returning
    /// `true` if this call retired it. The concurrent stash drain uses
    /// this to retract a word it has just republished in the main table
    /// without disturbing a concurrently-replaced (different-valued) copy
    /// of the same key.
    pub fn remove_word(&self, word: u64) -> bool {
        for slot in self.slots.iter() {
            if slot.load(Ordering::Acquire) == word
                && slot
                    .compare_exchange(word, EMPTY_WORD, Ordering::AcqRel, Ordering::Relaxed)
                    .is_ok()
            {
                self.live.fetch_sub(1, Ordering::Release);
                return true;
            }
        }
        false
    }

    /// Racy snapshot of live words (diagnostics and the drain's worklist).
    pub fn peek_window(&self) -> Vec<u64> {
        let mut out = Vec::new();
        for slot in self.slots.iter() {
            let w = slot.load(Ordering::Acquire);
            if w != EMPTY_WORD {
                out.push(w);
            }
        }
        out
    }

    /// Drain all live entries at once. Unlike the per-word concurrent
    /// drain (`remove_word`), this assumes no racing pushes — callers
    /// holding the table exclusively (tests, teardown paths) only.
    pub fn drain_exclusive(&self) -> Vec<u64> {
        let mut out = Vec::new();
        for slot in self.slots.iter() {
            let w = slot.swap(EMPTY_WORD, Ordering::AcqRel);
            if w != EMPTY_WORD {
                self.live.fetch_sub(1, Ordering::Release);
                out.push(w);
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::packed::pack;
    use std::sync::Arc;

    #[test]
    fn push_lookup_delete() {
        let s = OverflowStash::new(16);
        assert!(s.is_quiescent());
        assert!(s.push(pack(7, 70)));
        assert!(!s.is_quiescent());
        assert_eq!(s.lookup(7), Some(70));
        assert_eq!(s.lookup(8), None);
        assert!(s.replace(7, pack(7, 71)));
        assert_eq!(s.lookup(7), Some(71));
        assert!(s.delete(7));
        assert_eq!(s.lookup(7), None);
        assert!(!s.delete(7));
        assert!(s.is_quiescent(), "deleting the last entry re-quiesces the pool");
    }

    #[test]
    fn fills_up_and_rejects_then_reuses_holes() {
        let s = OverflowStash::new(8);
        for i in 0..8u32 {
            assert!(s.push(pack(i, i)));
        }
        assert!(!s.push(pack(99, 99)), "pool must reject when full");
        assert_eq!(s.window_len(), 8);
        // a deleted slot is immediately reusable (no ring-window pinning)
        assert!(s.delete(3));
        assert!(s.push(pack(99, 99)), "freed slot must be claimable");
        assert_eq!(s.lookup(99), Some(99));
        assert_eq!(s.window_len(), 8);
    }

    #[test]
    fn rmw_transforms_in_place() {
        let s = OverflowStash::new(16);
        assert!(s.rmw(5, &|_| Some(1)).is_none(), "absent key must miss");
        s.push(pack(5, 10));
        // decline: value untouched (the CAS-condition-failed shape)
        assert_eq!(s.rmw(5, &|old| if old == 99 { Some(1) } else { None }), Some((10, false)));
        assert_eq!(s.lookup(5), Some(10));
        // apply: the fetch-add shape
        assert_eq!(s.rmw(5, &|old| Some(old + 7)), Some((10, true)));
        assert_eq!(s.lookup(5), Some(17));
        assert_eq!(s.window_len(), 1, "rmw must not change occupancy");
    }

    #[test]
    fn remove_word_is_exact() {
        let s = OverflowStash::new(8);
        s.push(pack(5, 50));
        assert!(!s.remove_word(pack(5, 51)), "different value must not match");
        assert!(s.remove_word(pack(5, 50)));
        assert!(s.is_quiescent());
    }

    #[test]
    fn drain_returns_live_entries_and_resets() {
        let s = OverflowStash::new(16);
        for i in 0..10u32 {
            s.push(pack(i, i * 2));
        }
        s.delete(3);
        s.delete(7);
        let drained = s.drain_exclusive();
        assert_eq!(drained.len(), 8);
        assert!(s.is_quiescent());
        assert_eq!(s.lookup(1), None);
        // pool is reusable after drain
        assert!(s.push(pack(100, 1)));
        assert_eq!(s.lookup(100), Some(1));
    }

    #[test]
    fn concurrent_pushes_land_exactly_once() {
        let s = Arc::new(OverflowStash::new(1024));
        let threads: Vec<_> = (0..8)
            .map(|t| {
                let s = Arc::clone(&s);
                std::thread::spawn(move || {
                    for i in 0..128u32 {
                        assert!(s.push(pack(t * 1000 + i, i)));
                    }
                })
            })
            .collect();
        for t in threads {
            t.join().unwrap();
        }
        let drained = s.drain_exclusive();
        assert_eq!(drained.len(), 8 * 128);
        let mut keys: Vec<u32> = drained.iter().map(|&w| unpack_key(w)).collect();
        keys.sort_unstable();
        keys.dedup();
        assert_eq!(keys.len(), 8 * 128, "duplicate or lost stash entries");
    }

    #[test]
    fn concurrent_push_full_never_overcommits() {
        let s = Arc::new(OverflowStash::new(64));
        let threads: Vec<_> = (0..8)
            .map(|t| {
                let s = Arc::clone(&s);
                std::thread::spawn(move || {
                    let mut ok = 0;
                    for i in 0..64u32 {
                        if s.push(pack(t * 100 + i + 1, i)) {
                            ok += 1;
                        }
                    }
                    ok
                })
            })
            .collect();
        let total: usize = threads.into_iter().map(|t| t.join().unwrap()).sum();
        assert_eq!(total, 64, "exactly capacity pushes must succeed");
        assert_eq!(s.drain_exclusive().len(), 64);
    }
}
