//! Overflow stash — a lock-free bounded ring of packed KV words
//! (paper §IV-A step 4).
//!
//! Insertions that exhaust both candidate buckets *and* the eviction bound
//! are redirected here; the stash is drained and its entries reinserted at
//! the next resize epoch. Producers reserve a slot with one `fetch_add` on
//! `tail`; lookups/deletes scan the live window racily (entries are
//! self-describing packed words, EMPTY marks holes).

use crate::core::packed::{unpack_key, unpack_value, EMPTY_WORD};
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};

/// Lock-free bounded overflow stash.
#[derive(Debug)]
pub struct OverflowStash {
    slots: Box<[AtomicU64]>,
    /// Oldest potentially-live index (advanced only by the exclusive drain).
    head: AtomicUsize,
    /// Next index to reserve (monotonically increasing; `% capacity` maps
    /// to a physical slot).
    tail: AtomicUsize,
}

impl OverflowStash {
    /// A stash with room for `capacity` entries (min 8, rounded to pow2 so
    /// the ring index is a mask).
    pub fn new(capacity: usize) -> Self {
        let cap = capacity.max(8).next_power_of_two();
        let slots = (0..cap).map(|_| AtomicU64::new(EMPTY_WORD)).collect::<Vec<_>>();
        OverflowStash {
            slots: slots.into_boxed_slice(),
            head: AtomicUsize::new(0),
            tail: AtomicUsize::new(0),
        }
    }

    /// Physical capacity.
    pub fn capacity(&self) -> usize {
        self.slots.len()
    }

    /// `true` if no entries have ever been pushed since the last drain.
    /// (Cheap gate so the probe fast path skips the stash entirely.)
    #[inline]
    pub fn is_quiescent(&self) -> bool {
        self.head.load(Ordering::Acquire) == self.tail.load(Ordering::Acquire)
    }

    /// Number of reserved (possibly deleted) entries in the live window.
    pub fn window_len(&self) -> usize {
        self.tail.load(Ordering::Acquire) - self.head.load(Ordering::Acquire)
    }

    /// Try to push a packed word. Returns `false` if the ring is full (the
    /// operation is then flagged pending for the next resize — paper §IV-A).
    pub fn push(&self, word: u64) -> bool {
        debug_assert_ne!(word, EMPTY_WORD);
        loop {
            let tail = self.tail.load(Ordering::Relaxed);
            let head = self.head.load(Ordering::Acquire);
            if tail - head >= self.slots.len() {
                return false;
            }
            // Reserve the slot; CAS (not fetch_add) so a full ring never
            // over-reserves and tears the window invariant.
            if self
                .tail
                .compare_exchange_weak(tail, tail + 1, Ordering::AcqRel, Ordering::Relaxed)
                .is_ok()
            {
                self.slots[tail & (self.slots.len() - 1)].store(word, Ordering::Release);
                return true;
            }
        }
    }

    /// Linear-scan lookup over the live window. O(window) — the stash is
    /// 1–2 % of table capacity and usually empty, so this is off the fast
    /// path (guarded by [`Self::is_quiescent`]).
    pub fn lookup(&self, key: u32) -> Option<u32> {
        let head = self.head.load(Ordering::Acquire);
        let tail = self.tail.load(Ordering::Acquire);
        for i in head..tail {
            let w = self.slots[i & (self.slots.len() - 1)].load(Ordering::Acquire);
            if unpack_key(w) == key {
                return Some(unpack_value(w));
            }
        }
        None
    }

    /// Replace the value of `key` if present. Returns true on success.
    pub fn replace(&self, key: u32, new_word: u64) -> bool {
        let head = self.head.load(Ordering::Acquire);
        let tail = self.tail.load(Ordering::Acquire);
        for i in head..tail {
            let slot = &self.slots[i & (self.slots.len() - 1)];
            let w = slot.load(Ordering::Acquire);
            if unpack_key(w) == key
                && slot.compare_exchange(w, new_word, Ordering::AcqRel, Ordering::Relaxed).is_ok()
            {
                return true;
            }
        }
        false
    }

    /// Delete `key` from the stash (leaves a hole skipped on drain).
    pub fn delete(&self, key: u32) -> bool {
        let head = self.head.load(Ordering::Acquire);
        let tail = self.tail.load(Ordering::Acquire);
        for i in head..tail {
            let slot = &self.slots[i & (self.slots.len() - 1)];
            let w = slot.load(Ordering::Acquire);
            if unpack_key(w) == key
                && slot.compare_exchange(w, EMPTY_WORD, Ordering::AcqRel, Ordering::Relaxed).is_ok()
            {
                return true;
            }
        }
        false
    }

    /// Racy snapshot of live words in the window (diagnostics only).
    pub fn peek_window(&self) -> Vec<u64> {
        let head = self.head.load(Ordering::Acquire);
        let tail = self.tail.load(Ordering::Acquire);
        let mut out = Vec::new();
        for i in head..tail {
            let w = self.slots[i & (self.slots.len() - 1)].load(Ordering::Acquire);
            if w != EMPTY_WORD {
                out.push(w);
            }
        }
        out
    }

    /// Drain all live entries, resetting the window. **Caller must hold the
    /// table's exclusive (resize) guard** — this is the "reprocessed during
    /// table expansion" step of §IV-A.
    pub fn drain_exclusive(&self) -> Vec<u64> {
        let head = self.head.load(Ordering::Relaxed);
        let tail = self.tail.load(Ordering::Relaxed);
        let mut out = Vec::with_capacity(tail - head);
        for i in head..tail {
            let slot = &self.slots[i & (self.slots.len() - 1)];
            let w = slot.swap(EMPTY_WORD, Ordering::Relaxed);
            if w != EMPTY_WORD {
                out.push(w);
            }
        }
        self.head.store(tail, Ordering::Release);
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::packed::pack;
    use std::sync::Arc;

    #[test]
    fn push_lookup_delete() {
        let s = OverflowStash::new(16);
        assert!(s.is_quiescent());
        assert!(s.push(pack(7, 70)));
        assert!(!s.is_quiescent());
        assert_eq!(s.lookup(7), Some(70));
        assert_eq!(s.lookup(8), None);
        assert!(s.replace(7, pack(7, 71)));
        assert_eq!(s.lookup(7), Some(71));
        assert!(s.delete(7));
        assert_eq!(s.lookup(7), None);
        assert!(!s.delete(7));
    }

    #[test]
    fn fills_up_and_rejects() {
        let s = OverflowStash::new(8);
        for i in 0..8u32 {
            assert!(s.push(pack(i, i)));
        }
        assert!(!s.push(pack(99, 99)), "ring must reject when full");
        assert_eq!(s.window_len(), 8);
    }

    #[test]
    fn drain_returns_live_entries_and_resets() {
        let s = OverflowStash::new(16);
        for i in 0..10u32 {
            s.push(pack(i, i * 2));
        }
        s.delete(3);
        s.delete(7);
        let mut drained = s.drain_exclusive();
        drained.sort_unstable();
        assert_eq!(drained.len(), 8);
        assert!(s.is_quiescent());
        assert_eq!(s.lookup(1), None);
        // ring is reusable after drain
        assert!(s.push(pack(100, 1)));
        assert_eq!(s.lookup(100), Some(1));
    }

    #[test]
    fn concurrent_pushes_land_exactly_once() {
        let s = Arc::new(OverflowStash::new(1024));
        let threads: Vec<_> = (0..8)
            .map(|t| {
                let s = Arc::clone(&s);
                std::thread::spawn(move || {
                    for i in 0..128u32 {
                        assert!(s.push(pack(t * 1000 + i, i)));
                    }
                })
            })
            .collect();
        for t in threads {
            t.join().unwrap();
        }
        let drained = s.drain_exclusive();
        assert_eq!(drained.len(), 8 * 128);
        let mut keys: Vec<u32> = drained.iter().map(|&w| unpack_key(w)).collect();
        keys.sort_unstable();
        keys.dedup();
        assert_eq!(keys.len(), 8 * 128, "duplicate or lost stash entries");
    }

    #[test]
    fn concurrent_push_full_never_overcommits() {
        let s = Arc::new(OverflowStash::new(64));
        let threads: Vec<_> = (0..8)
            .map(|t| {
                let s = Arc::clone(&s);
                std::thread::spawn(move || {
                    let mut ok = 0;
                    for i in 0..64u32 {
                        if s.push(pack(t * 100 + i + 1, i)) {
                            ok += 1;
                        }
                    }
                    ok
                })
            })
            .collect();
        let total: usize = threads.into_iter().map(|t| t.join().unwrap()).sum();
        assert_eq!(total, 64, "exactly capacity pushes must succeed");
        assert_eq!(s.drain_exclusive().len(), 64);
    }
}
