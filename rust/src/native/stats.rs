//! Always-on operation counters for the native table.
//!
//! These back two of the paper's measurements: the per-step insertion
//! breakdown (Fig. 9 — counts here, cycle-accurate timing in
//! [`crate::simgpu`]) and the "<0.85 % of operations take the eviction
//! lock" claim (§III-B). Counters are `Relaxed` and padded to avoid false
//! sharing on the hot path.

use std::sync::atomic::{AtomicU64, Ordering};

/// Insert path steps (paper §IV-A).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Step {
    /// Step 1 — key already present, value replaced (WCME + CAS).
    Replace,
    /// Step 2 — free slot claimed and committed (WABC).
    Claim,
    /// Step 3 — placed via bounded cuckoo eviction.
    Evict,
    /// Step 4 — redirected to the overflow stash.
    Stash,
}

/// Cache-line padded atomic counter.
#[repr(align(64))]
#[derive(Debug, Default)]
struct Padded(AtomicU64);

/// Operation statistics, shared by all threads operating on a table.
#[derive(Debug, Default)]
pub struct OpStats {
    inserts: Padded,
    replaces: Padded,
    claims: Padded,
    evict_placements: Padded,
    evict_rounds: Padded,
    stash_pushes: Padded,
    stash_full: Padded,
    lock_acquisitions: Padded,
    lookups: Padded,
    lookup_hits: Padded,
    deletes: Padded,
    delete_hits: Padded,
    cas_retries: Padded,
    probes: Padded,
    probe_buckets: Padded,
    probe_lines: Padded,
    prefetches: Padded,
}

impl OpStats {
    /// Record which step completed an insert.
    #[inline]
    pub fn record_insert(&self, step: Step) {
        self.inserts.0.fetch_add(1, Ordering::Relaxed);
        match step {
            Step::Replace => &self.replaces,
            Step::Claim => &self.claims,
            Step::Evict => &self.evict_placements,
            Step::Stash => &self.stash_pushes,
        }
        .0
        .fetch_add(1, Ordering::Relaxed);
    }

    /// Record one cuckoo displacement round.
    #[inline]
    pub fn record_evict_round(&self) {
        self.evict_rounds.0.fetch_add(1, Ordering::Relaxed);
    }

    /// Record an eviction-lock acquisition (the §III-B rarity claim).
    #[inline]
    pub fn record_lock(&self) {
        self.lock_acquisitions.0.fetch_add(1, Ordering::Relaxed);
    }

    /// Record a rejected stash push (table truly full).
    #[inline]
    pub fn record_stash_full(&self) {
        self.stash_full.0.fetch_add(1, Ordering::Relaxed);
    }

    /// Record a lookup and whether it hit.
    #[inline]
    pub fn record_lookup(&self, hit: bool) {
        self.lookups.0.fetch_add(1, Ordering::Relaxed);
        if hit {
            self.lookup_hits.0.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// Record a delete and whether it removed an entry.
    #[inline]
    pub fn record_delete(&self, hit: bool) {
        self.deletes.0.fetch_add(1, Ordering::Relaxed);
        if hit {
            self.delete_hits.0.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// Record a CAS retry (contention indicator).
    #[inline]
    pub fn record_cas_retry(&self) {
        self.cas_retries.0.fetch_add(1, Ordering::Relaxed);
    }

    /// Record one completed lookup probe: how many candidate buckets it
    /// scanned and how many cache lines it touched (mask word + the slot
    /// rows actually read). Backs the fig14 lines-per-probe comparison
    /// between the AoS and compact layouts.
    #[inline]
    pub fn record_probe(&self, buckets: u64, lines: u64) {
        self.probes.0.fetch_add(1, Ordering::Relaxed);
        self.probe_buckets.0.fetch_add(buckets, Ordering::Relaxed);
        self.probe_lines.0.fetch_add(lines, Ordering::Relaxed);
    }

    /// Record `n` bucket-line prefetch hints issued by a bulk batch path
    /// (one per op under the AMAC interleave — [`crate::native::batch`]).
    /// One add per batch, not per op, so the hot loop stays untaxed.
    #[inline]
    pub fn record_prefetches(&self, n: u64) {
        self.prefetches.0.fetch_add(n, Ordering::Relaxed);
    }

    /// Coherent-enough snapshot of all counters.
    pub fn snapshot(&self) -> StatsSnapshot {
        StatsSnapshot {
            inserts: self.inserts.0.load(Ordering::Relaxed),
            replaces: self.replaces.0.load(Ordering::Relaxed),
            claims: self.claims.0.load(Ordering::Relaxed),
            evict_placements: self.evict_placements.0.load(Ordering::Relaxed),
            evict_rounds: self.evict_rounds.0.load(Ordering::Relaxed),
            stash_pushes: self.stash_pushes.0.load(Ordering::Relaxed),
            stash_full: self.stash_full.0.load(Ordering::Relaxed),
            lock_acquisitions: self.lock_acquisitions.0.load(Ordering::Relaxed),
            lookups: self.lookups.0.load(Ordering::Relaxed),
            lookup_hits: self.lookup_hits.0.load(Ordering::Relaxed),
            deletes: self.deletes.0.load(Ordering::Relaxed),
            delete_hits: self.delete_hits.0.load(Ordering::Relaxed),
            cas_retries: self.cas_retries.0.load(Ordering::Relaxed),
            probes: self.probes.0.load(Ordering::Relaxed),
            probe_buckets: self.probe_buckets.0.load(Ordering::Relaxed),
            probe_lines: self.probe_lines.0.load(Ordering::Relaxed),
            prefetches: self.prefetches.0.load(Ordering::Relaxed),
        }
    }
}

/// Point-in-time view of [`OpStats`].
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct StatsSnapshot {
    pub inserts: u64,
    pub replaces: u64,
    pub claims: u64,
    pub evict_placements: u64,
    pub evict_rounds: u64,
    pub stash_pushes: u64,
    pub stash_full: u64,
    pub lock_acquisitions: u64,
    pub lookups: u64,
    pub lookup_hits: u64,
    pub deletes: u64,
    pub delete_hits: u64,
    pub cas_retries: u64,
    pub probes: u64,
    pub probe_buckets: u64,
    pub probe_lines: u64,
    pub prefetches: u64,
}

impl StatsSnapshot {
    /// Fraction of *all operations* that acquired the eviction lock — the
    /// quantity behind the paper's "<0.85 % of cases" claim.
    pub fn lock_rate(&self) -> f64 {
        let ops = self.inserts + self.lookups + self.deletes;
        if ops == 0 {
            0.0
        } else {
            self.lock_acquisitions as f64 / ops as f64
        }
    }

    /// Mean cache lines touched per lookup probe — the fig14 layout
    /// line-efficiency metric.
    pub fn lines_per_probe(&self) -> f64 {
        if self.probes == 0 {
            0.0
        } else {
            self.probe_lines as f64 / self.probes as f64
        }
    }

    /// Mean candidate buckets scanned per lookup probe.
    pub fn buckets_per_probe(&self) -> f64 {
        if self.probes == 0 {
            0.0
        } else {
            self.probe_buckets as f64 / self.probes as f64
        }
    }

    /// Fraction of inserts resolved per step `(s1, s2, s3, s4)` — the
    /// count-based companion to Fig. 9.
    pub fn step_fractions(&self) -> (f64, f64, f64, f64) {
        let n = self.inserts.max(1) as f64;
        (
            self.replaces as f64 / n,
            self.claims as f64 / n,
            self.evict_placements as f64 / n,
            self.stash_pushes as f64 / n,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_accumulate() {
        let s = OpStats::default();
        s.record_insert(Step::Claim);
        s.record_insert(Step::Claim);
        s.record_insert(Step::Replace);
        s.record_insert(Step::Evict);
        s.record_evict_round();
        s.record_evict_round();
        s.record_lock();
        s.record_lookup(true);
        s.record_lookup(false);
        s.record_delete(true);
        s.record_probe(2, 5);
        s.record_probe(1, 2);
        s.record_prefetches(3);
        let snap = s.snapshot();
        assert_eq!(snap.inserts, 4);
        assert_eq!(snap.claims, 2);
        assert_eq!(snap.replaces, 1);
        assert_eq!(snap.evict_placements, 1);
        assert_eq!(snap.evict_rounds, 2);
        assert_eq!(snap.lock_acquisitions, 1);
        assert_eq!(snap.lookups, 2);
        assert_eq!(snap.lookup_hits, 1);
        assert_eq!(snap.deletes, 1);
        assert_eq!(snap.probes, 2);
        assert_eq!(snap.probe_buckets, 3);
        assert_eq!(snap.probe_lines, 7);
        assert_eq!(snap.prefetches, 3);
        assert!((snap.lines_per_probe() - 3.5).abs() < 1e-9);
        assert!((snap.buckets_per_probe() - 1.5).abs() < 1e-9);
    }

    #[test]
    fn lock_rate_and_fractions() {
        let s = OpStats::default();
        for _ in 0..99 {
            s.record_insert(Step::Claim);
        }
        s.record_insert(Step::Evict);
        s.record_lock();
        let snap = s.snapshot();
        assert!((snap.lock_rate() - 0.01).abs() < 1e-9);
        let (s1, s2, s3, s4) = snap.step_fractions();
        assert_eq!(s1, 0.0);
        assert!((s2 - 0.99).abs() < 1e-9);
        assert!((s3 - 0.01).abs() < 1e-9);
        assert_eq!(s4, 0.0);
    }
}
