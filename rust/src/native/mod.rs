//! The production (native CPU) Hive hash table.
//!
//! This is the paper's data structure with GPU atomics mapped onto Rust
//! `AtomicU64`/`AtomicU32` (DESIGN.md §2): packed 64-bit KV words published
//! with a single CAS, a 32-bit free mask claimed with one `fetch_and`
//! (WABC), match-and-elect probes (WCME), the four-step insert strategy
//! with bounded cuckoo eviction and an overflow stash, and warp-parallel
//! linear-hashing resize executed in K-bucket batches.
//!
//! OS threads play the role of concurrent warps: the *inter-warp*
//! concurrency protocol is identical (same atomics, same linearization
//! points); the *intra-warp* 32-lane cooperation becomes a 32-slot scan the
//! compiler vectorizes. The lane-accurate version lives in [`crate::simgpu`].

pub mod batch;
pub(crate) mod prefetch;
pub mod stash;
pub mod stats;
pub mod table;
pub mod resize;
pub mod soa;

pub use stash::OverflowStash;
pub use stats::{OpStats, StatsSnapshot, Step};
pub use table::{HiveTable, InsertOutcome};
