//! `HiveTable` — the native concurrent Hive hash table.
//!
//! Concurrency model (DESIGN.md §2): GPU warps → OS threads. All operation
//! fast paths are lock-free and match the paper's protocols instruction for
//! instruction at the atomic level:
//!
//! * **WCME** (lookup / replace / delete): probe all 32 slots of each
//!   candidate bucket, elect the first match, winner performs exactly one
//!   64-bit CAS (replace/delete) or returns the value (lookup).
//! * **WABC** (claim-then-commit): read the 32-bit free mask, elect the
//!   lowest free bit, claim it with one `fetch_and`, publish the packed KV
//!   with a release store.
//! * **Bounded cuckoo eviction** under a short per-bucket spin lock, at most
//!   `max_evictions` rounds, then the overflow stash.
//!
//! Resize (linear hashing, §IV-C) and physical reallocation run under the
//! table's exclusive phase guard — the analogue of the GPU running resize
//! as its own kernel launch between operation batches.
//!
//! ### Batched operations
//! [`crate::native::batch`] adds `insert_batch` / `lookup_batch` /
//! `delete_batch`: one phase read-guard acquisition per batch (not per
//! op), candidate buckets hashed for the whole batch up front, and a
//! software-pipelined probe loop that touches op *i+1*'s bucket row while
//! probing op *i* — the CPU analogue of the paper's bulk kernel launches.
//! The single-op paths below delegate to the same `*_locked` bodies, so
//! batched and per-op execution are behaviourally identical. Occupancy is
//! tracked by a cache-line-padded [`StripedCounter`] so concurrent batches
//! do not serialize on one `count` cache line.
//!
//! ### Deviation from the paper
//! Algorithm 2 line 15 restores a failed claim bit with `fetch_or`. With
//! `fetch_and(!bit)`, a lost race means the bit was *already* zero, so the
//! failed claimer changed nothing; restoring it would mark a slot free
//! while its winner occupies it. We therefore simply retry with a fresh
//! mask (no restore). See DESIGN.md §6.

use crate::core::config::{HiveConfig, Layout};
use crate::core::counter::StripedCounter;
use crate::core::error::{HiveError, Result};
use crate::core::packed::{is_empty, pack, unpack_key, unpack_value, EMPTY_KEY, EMPTY_WORD};
use crate::core::{FULL_FREE_MASK, SLOTS_PER_BUCKET};
use crate::hash::HashFamily;
use crate::native::stash::OverflowStash;
use crate::native::stats::{OpStats, StatsSnapshot, Step};
use std::sync::atomic::{AtomicU32, AtomicU64, AtomicUsize, Ordering};
use std::sync::RwLock;

/// Outcome of [`HiveTable::insert`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum InsertOutcome {
    /// New key, committed via WABC claim (step 2).
    Inserted,
    /// Key existed; value replaced in place (step 1).
    Replaced,
    /// Placed after one or more cuckoo displacements (step 3).
    Evicted,
    /// Redirected to the overflow stash (step 4).
    Stashed,
}

/// Bucket/metadata arrays. Swapped wholesale on physical reallocation, so
/// everything lives behind the phase `RwLock`; operations only ever take
/// the read side.
pub(crate) struct State {
    /// Packed KV words, `phys_buckets * 32` of them, bucket-major. A bucket
    /// row is 256 B — the paper's two 128 B cache lines.
    pub(crate) buckets: Box<[AtomicU64]>,
    /// Per-bucket 32-bit free masks (bit i set ⇒ slot i free).
    pub(crate) free_mask: Box<[AtomicU32]>,
    /// Per-bucket eviction locks (0 = free). Only step 3 touches these.
    pub(crate) locks: Box<[AtomicU32]>,
    /// Linear-hashing round mask `2^m - 1`. Mutated only under the write
    /// guard (resize), read under the read guard.
    pub(crate) index_mask: u32,
    /// Buckets of the current round already split.
    pub(crate) split_ptr: u32,
}

impl State {
    fn with_buckets(phys: usize, index_mask: u32, split_ptr: u32) -> Self {
        State {
            buckets: (0..phys * SLOTS_PER_BUCKET).map(|_| AtomicU64::new(EMPTY_WORD)).collect(),
            free_mask: (0..phys).map(|_| AtomicU32::new(FULL_FREE_MASK)).collect(),
            locks: (0..phys).map(|_| AtomicU32::new(0)).collect(),
            index_mask,
            split_ptr,
        }
    }

    /// Logical bucket count `2^m + split_ptr`.
    #[inline]
    pub(crate) fn logical_buckets(&self) -> usize {
        (self.index_mask as usize + 1) + self.split_ptr as usize
    }

    #[inline]
    pub(crate) fn phys_buckets(&self) -> usize {
        self.free_mask.len()
    }

    /// Slot index of `(bucket, lane)` in the flat word array.
    #[inline(always)]
    pub(crate) fn slot(&self, bucket: u32, lane: usize) -> usize {
        bucket as usize * SLOTS_PER_BUCKET + lane
    }
}

/// The native concurrent Hive hash table (paper §III–§IV).
pub struct HiveTable {
    pub(crate) state: RwLock<State>,
    pub(crate) family: HashFamily,
    pub(crate) cfg: HiveConfig,
    pub(crate) stash: OverflowStash,
    /// Live-entry tally. Striped + cache-line padded: a single shared
    /// `AtomicUsize` here bounces one line between every inserting and
    /// deleting thread, which caps batch throughput (§Perf log).
    pub(crate) count: StripedCounter,
    /// Words flagged *pending* because both the table and the stash were
    /// full (paper §IV-A step 4: "the operation is flagged as pending for
    /// deferred reinsertion during the next resize epoch"). Rare path —
    /// guarded by `pending_len` so the fast path never takes the lock.
    pub(crate) pending: std::sync::Mutex<Vec<u64>>,
    pub(crate) pending_len: AtomicUsize,
    pub(crate) stats: OpStats,
    /// Minimum round mask — the table never shrinks below its initial size.
    pub(crate) min_index_mask: u32,
}

impl HiveTable {
    /// Create a table from `cfg` (validated).
    pub fn new(cfg: HiveConfig) -> Result<Self> {
        cfg.validate()?;
        if cfg.layout == Layout::SplitSoa {
            // The SoA ablation lives in `native::soa`; HiveTable is AoS.
            return Err(HiveError::Config(
                "HiveTable is the packed-AoS table; use native::soa::SoaTable for the ablation"
                    .into(),
            ));
        }
        let buckets = cfg.initial_buckets.next_power_of_two().max(4);
        let index_mask = (buckets - 1) as u32;
        let stash_cap =
            ((buckets * SLOTS_PER_BUCKET) as f64 * cfg.stash_fraction).ceil().max(8.0) as usize;
        Ok(HiveTable {
            state: RwLock::new(State::with_buckets(buckets, index_mask, 0)),
            family: HashFamily::new(cfg.hash_kinds.clone()),
            stash: OverflowStash::new(stash_cap),
            count: StripedCounter::new(),
            pending: std::sync::Mutex::new(Vec::new()),
            pending_len: AtomicUsize::new(0),
            stats: OpStats::default(),
            min_index_mask: index_mask,
            cfg,
        })
    }

    /// Convenience: table sized for `n` keys at `target_lf` load factor.
    pub fn with_capacity(n: usize, target_lf: f64) -> Result<Self> {
        Self::new(HiveConfig::for_capacity(n, target_lf))
    }

    /// Number of live entries (approximate under concurrency).
    pub fn len(&self) -> usize {
        self.count.sum()
    }

    /// `true` if the table holds no entries.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Current logical bucket count `2^m + split_ptr`.
    pub fn logical_buckets(&self) -> usize {
        self.state.read().unwrap().logical_buckets()
    }

    /// Slot capacity = logical buckets × 32.
    pub fn capacity(&self) -> usize {
        self.logical_buckets() * SLOTS_PER_BUCKET
    }

    /// Load factor `len / capacity` (§IV-C's resize trigger input).
    pub fn load_factor(&self) -> f64 {
        self.len() as f64 / self.capacity() as f64
    }

    /// Snapshot of the operation counters.
    pub fn stats(&self) -> StatsSnapshot {
        self.stats.snapshot()
    }

    /// Words parked past the stash (pending the next resize epoch).
    pub fn pending_full(&self) -> usize {
        self.pending_len.load(Ordering::Relaxed)
    }

    /// Park a word on the pending list (both table and stash full).
    fn park_pending(&self, word: u64) {
        self.pending.lock().unwrap().push(word);
        self.pending_len.fetch_add(1, Ordering::Release);
        self.stats.record_stash_full();
    }

    fn pending_lookup(&self, key: u32) -> Option<u32> {
        if self.pending_len.load(Ordering::Acquire) == 0 {
            return None;
        }
        let guard = self.pending.lock().unwrap();
        guard.iter().rev().find(|&&w| unpack_key(w) == key).map(|&w| unpack_value(w))
    }

    fn pending_replace(&self, key: u32, word: u64) -> bool {
        if self.pending_len.load(Ordering::Acquire) == 0 {
            return false;
        }
        let mut guard = self.pending.lock().unwrap();
        for w in guard.iter_mut() {
            if unpack_key(*w) == key {
                *w = word;
                return true;
            }
        }
        false
    }

    fn pending_delete(&self, key: u32) -> bool {
        if self.pending_len.load(Ordering::Acquire) == 0 {
            return false;
        }
        let mut guard = self.pending.lock().unwrap();
        if let Some(pos) = guard.iter().position(|&w| unpack_key(w) == key) {
            guard.remove(pos);
            self.pending_len.fetch_sub(1, Ordering::Release);
            true
        } else {
            false
        }
    }

    /// The configured hash family.
    pub fn family(&self) -> &HashFamily {
        &self.family
    }

    /// The configuration this table was built with.
    pub fn config(&self) -> &HiveConfig {
        &self.cfg
    }

    // ------------------------------------------------------------------
    // WCME probe helpers
    // ------------------------------------------------------------------

    /// WCME match: scan the 32 slots of `bucket` for `key`; return the
    /// matching lane and its cached word. The scan is the CPU analogue of
    /// the warp's coalesced 32-lane load + ballot + ffs.
    ///
    /// Perf (§Perf log): slots are scanned with `Relaxed` loads — one
    /// `Acquire` fence on a hit establishes the publish ordering — which
    /// removes 32 acquire barriers per probe on weakly-ordered targets and
    /// lets the compiler keep the loop tight on x86. Used by lookup/delete,
    /// whose operating point is a well-filled table where a mask pre-load
    /// is pure overhead.
    #[inline]
    pub(crate) fn wcme_match(state: &State, bucket: u32, key: u32) -> Option<(usize, u64)> {
        let base = bucket as usize * SLOTS_PER_BUCKET;
        let key64 = key as u64;
        for lane in 0..SLOTS_PER_BUCKET {
            let w = state.buckets[base + lane].load(Ordering::Relaxed);
            if w & 0xFFFF_FFFF == key64 {
                std::sync::atomic::fence(Ordering::Acquire);
                return Some((lane, w));
            }
        }
        None
    }

    /// Mask-guided WCME variant for the insert replace-check (§Perf log):
    /// one free-mask load selects the occupied lanes so only those are
    /// compared — during a fill most buckets are part-empty, cutting the
    /// replace probe sharply (insert +25 % measured). A lane whose claim
    /// is mid-publish reads EMPTY and is skipped; a completed insert's
    /// `fetch_and` happens-before any later mask load, so committed
    /// entries are always scanned.
    #[inline]
    fn wcme_match_masked(state: &State, bucket: u32, key: u32) -> Option<(usize, u64)> {
        let base = bucket as usize * SLOTS_PER_BUCKET;
        let key64 = key as u64;
        let mut occupied =
            !(state.free_mask[bucket as usize].load(Ordering::Acquire)) & FULL_FREE_MASK;
        while occupied != 0 {
            let lane = occupied.trailing_zeros() as usize;
            occupied &= occupied - 1;
            let w = state.buckets[base + lane].load(Ordering::Relaxed);
            if w & 0xFFFF_FFFF == key64 {
                std::sync::atomic::fence(Ordering::Acquire);
                return Some((lane, w));
            }
        }
        None
    }

    // ------------------------------------------------------------------
    // Public operations
    // ------------------------------------------------------------------

    /// Candidate buckets `{h_1(k) .. h_d(k)}` under the current round
    /// state. Only the first `family.d()` entries are meaningful.
    #[inline]
    pub(crate) fn candidates(&self, state: &State, key: u32) -> [u32; 4] {
        let (mask, sp) = (state.index_mask, state.split_ptr);
        let mut c = [0u32; 4];
        for (i, slot) in c.iter_mut().enumerate().take(self.family.d()) {
            *slot = self.family.bucket(i, key, mask, sp);
        }
        c
    }

    /// Search(k): value of `key`, or `None` (paper §III-D).
    pub fn lookup(&self, key: u32) -> Option<u32> {
        if key == EMPTY_KEY {
            return None;
        }
        let state = self.state.read().unwrap();
        let cands = self.candidates(&state, key);
        self.lookup_locked(&state, key, &cands)
    }

    /// Lookup body, called with the phase read guard held and the
    /// candidate buckets already hashed (shared with the batch layer).
    pub(crate) fn lookup_locked(&self, state: &State, key: u32, cands: &[u32; 4]) -> Option<u32> {
        for &b in &cands[..self.family.d()] {
            if let Some((_, w)) = Self::wcme_match(state, b, key) {
                self.stats.record_lookup(true);
                return Some(unpack_value(w));
            }
        }
        // Overflow stash participates in lookups for correctness (§IV-A).
        if !self.stash.is_quiescent() {
            if let Some(v) = self.stash.lookup(key) {
                self.stats.record_lookup(true);
                return Some(v);
            }
        }
        if let Some(v) = self.pending_lookup(key) {
            self.stats.record_lookup(true);
            return Some(v);
        }
        self.stats.record_lookup(false);
        None
    }

    /// Delete(k): remove `key`, returning `true` if it was present
    /// (Algorithm 4: winner CAS to EMPTY, then publish the free bit).
    pub fn delete(&self, key: u32) -> bool {
        if key == EMPTY_KEY {
            return false;
        }
        let state = self.state.read().unwrap();
        let cands = self.candidates(&state, key);
        self.delete_locked(&state, key, &cands)
    }

    /// Delete body, called with the phase read guard held and the
    /// candidate buckets already hashed (shared with the batch layer).
    pub(crate) fn delete_locked(&self, state: &State, key: u32, cands: &[u32; 4]) -> bool {
        for &b in &cands[..self.family.d()] {
            // Retry the CAS a bounded number of times: a failed CAS means a
            // concurrent replace updated the value — rescan and retry.
            for _attempt in 0..4 {
                match Self::wcme_match(state, b, key) {
                    None => break,
                    Some((lane, w)) => {
                        let slot = state.slot(b, lane);
                        if state.buckets[slot]
                            .compare_exchange(w, EMPTY_WORD, Ordering::AcqRel, Ordering::Relaxed)
                            .is_ok()
                        {
                            // Publish the vacancy (Algorithm 4 line 14).
                            state.free_mask[b as usize]
                                .fetch_or(1u32 << lane, Ordering::AcqRel);
                            self.count.decr();
                            self.stats.record_delete(true);
                            return true;
                        }
                        self.stats.record_cas_retry();
                    }
                }
            }
        }
        if !self.stash.is_quiescent() && self.stash.delete(key) {
            self.count.decr();
            self.stats.record_delete(true);
            return true;
        }
        if self.pending_delete(key) {
            self.count.decr();
            self.stats.record_delete(true);
            return true;
        }
        self.stats.record_delete(false);
        false
    }

    /// Insert(⟨k,v⟩) / Replace(⟨k,v⟩) — the four-step strategy (§IV-A).
    pub fn insert(&self, key: u32, value: u32) -> Result<InsertOutcome> {
        if key == EMPTY_KEY {
            return Err(HiveError::InvalidKey(key));
        }
        let state = self.state.read().unwrap();
        let cands = self.candidates(&state, key);
        let outcome = self.insert_locked(&state, key, value, &cands)?;
        self.record_insert_outcome(outcome);
        Ok(outcome)
    }

    /// Bump the per-step insert counters (shared with the batch layer).
    #[inline]
    pub(crate) fn record_insert_outcome(&self, outcome: InsertOutcome) {
        match outcome {
            InsertOutcome::Replaced => self.stats.record_insert(Step::Replace),
            InsertOutcome::Inserted => self.stats.record_insert(Step::Claim),
            InsertOutcome::Evicted => self.stats.record_insert(Step::Evict),
            InsertOutcome::Stashed => self.stats.record_insert(Step::Stash),
        }
    }

    /// Insert body, called with the phase read guard held and the
    /// candidate buckets already hashed (shared with the batch layer).
    pub(crate) fn insert_locked(
        &self,
        state: &State,
        key: u32,
        value: u32,
        cands: &[u32; 4],
    ) -> Result<InsertOutcome> {
        let d = self.family.d();
        let new_word = pack(key, value);

        // ---- Step 1: Replace (Algorithm 1) ----
        for &b in &cands[..d] {
            for _attempt in 0..4 {
                match Self::wcme_match_masked(state, b, key) {
                    None => break,
                    Some((lane, old)) => {
                        let slot = state.slot(b, lane);
                        if state.buckets[slot]
                            .compare_exchange(old, new_word, Ordering::AcqRel, Ordering::Relaxed)
                            .is_ok()
                        {
                            return Ok(InsertOutcome::Replaced);
                        }
                        self.stats.record_cas_retry();
                    }
                }
            }
        }
        // Key may be parked in the stash or pending list; replace it there
        // so the eventual drain does not resurrect a stale value.
        if !self.stash.is_quiescent() && self.stash.replace(key, new_word) {
            return Ok(InsertOutcome::Replaced);
        }
        if self.pending_replace(key, new_word) {
            return Ok(InsertOutcome::Replaced);
        }

        // ---- Step 2: Claim-then-commit (Algorithm 2 / WABC) ----
        // Bucketed two-choice: attempt the candidate with the most free
        // slots first (§V: "bucketed two-choice placement policy").
        let mut order = [0usize; 4];
        for (i, o) in order.iter_mut().enumerate().take(d) {
            *o = i;
        }
        if d == 2 {
            let f0 = state.free_mask[cands[0] as usize].load(Ordering::Relaxed).count_ones();
            let f1 = state.free_mask[cands[1] as usize].load(Ordering::Relaxed).count_ones();
            if f1 > f0 {
                order.swap(0, 1);
            }
        }
        for &i in &order[..d] {
            if let Some(_lane) = self.wabc_claim_commit(state, cands[i], new_word) {
                self.count.incr();
                return Ok(InsertOutcome::Inserted);
            }
        }

        // ---- Step 3: bounded cuckoo eviction (Algorithm 3) ----
        match self.cuckoo_evict_insert(state, cands[0], new_word) {
            Some(()) => {
                self.count.incr();
                Ok(InsertOutcome::Evicted)
            }
            None => {
                // ---- Step 4: overflow stash ----
                // Stash full ⇒ the word is *flagged pending* for the next
                // resize epoch (§IV-A) — never dropped, never an error.
                if !self.stash.push(new_word) {
                    self.park_pending(new_word);
                }
                self.count.incr();
                Ok(InsertOutcome::Stashed)
            }
        }
    }

    /// WABC claim + immediate commit (Algorithm 2). Returns the claimed
    /// lane on success, `None` if the bucket is full.
    #[inline]
    fn wabc_claim_commit(&self, state: &State, bucket: u32, word: u64) -> Option<usize> {
        let fm = &state.free_mask[bucket as usize];
        loop {
            // Lane 0's relaxed load + broadcast.
            let mask = fm.load(Ordering::Relaxed) & FULL_FREE_MASK;
            if mask == 0 {
                return None; // bucket full — early warp exit
            }
            // Winner = lowest free lane (ballot + ffs).
            let lane = mask.trailing_zeros() as usize;
            let bit = 1u32 << lane;
            // One atomic RMW claims the slot.
            let old = fm.fetch_and(!bit, Ordering::AcqRel);
            if old & bit != 0 {
                // Ownership confirmed: publish the packed entry.
                state.buckets[state.slot(bucket, lane)].store(word, Ordering::Release);
                return Some(lane);
            }
            // Lost the race — the bit was already claimed; *no restore*
            // (see module docs) — re-read the mask and retry.
            self.stats.record_cas_retry();
        }
    }

    /// Bounded cuckoo eviction (Algorithm 3). Returns `Some(())` once the
    /// newcomer (and every displaced victim) is placed, `None` if the
    /// eviction bound is exhausted (→ stash).
    fn cuckoo_evict_insert(&self, state: &State, start_bucket: u32, start_word: u64) -> Option<()> {
        let mut word = start_word;
        let mut bucket = start_bucket;
        for _kick in 0..self.cfg.max_evictions {
            self.stats.record_evict_round();
            // Lock-free fast path: a slot may have freed up.
            if self.wabc_claim_commit(state, bucket, word).is_some() {
                return Some(());
            }
            // Short critical section on this bucket only (lane 0's lock).
            let lock = &state.locks[bucket as usize];
            if lock.compare_exchange(0, 1, Ordering::Acquire, Ordering::Relaxed).is_err() {
                // Someone else is evicting here; spin briefly then retry
                // the round (bounded overall by max_evictions).
                std::hint::spin_loop();
                continue;
            }
            self.stats.record_lock();

            let outcome = (|| {
                let fm = &state.free_mask[bucket as usize];
                let mask = fm.load(Ordering::Relaxed) & FULL_FREE_MASK;
                if mask != 0 {
                    // (i) a free bit exists: claim it under the lock.
                    let lane = mask.trailing_zeros() as usize;
                    let bit = 1u32 << lane;
                    let old = fm.fetch_and(!bit, Ordering::AcqRel);
                    if old & bit != 0 {
                        state.buckets[state.slot(bucket, lane)].store(word, Ordering::Release);
                        return EvictOutcome::Placed;
                    }
                    return EvictOutcome::Retry;
                }
                // (ii) displace the first occupied slot.
                let occ = !mask; // all occupied here
                let lane = occ.trailing_zeros() as usize;
                let slot = state.slot(bucket, lane);
                let victim = state.buckets[slot].load(Ordering::Acquire);
                if is_empty(victim) {
                    // Concurrent delete cleared it between mask read and
                    // now; its free bit will appear — retry the round.
                    return EvictOutcome::Retry;
                }
                // Swap newcomer in; CAS so a racing replace/delete of the
                // victim is detected rather than silently overwritten.
                if state.buckets[slot]
                    .compare_exchange(victim, word, Ordering::AcqRel, Ordering::Relaxed)
                    .is_ok()
                {
                    EvictOutcome::Evicted(victim)
                } else {
                    EvictOutcome::Retry
                }
            })();

            lock.store(0, Ordering::Release);

            match outcome {
                EvictOutcome::Placed => return Some(()),
                EvictOutcome::Retry => continue,
                EvictOutcome::Evicted(victim) => {
                    // Re-route the victim to its alternate bucket.
                    let vkey = unpack_key(victim);
                    bucket = self.alt_bucket(state, vkey, bucket);
                    word = victim;
                }
            }
        }
        // Bound exceeded. If a victim is in hand (word != start_word) the
        // newcomer was already placed and the *victim* needs the fallback;
        // it must never be dropped — stash it, or park it pending.
        if word != start_word {
            if !self.stash.push(word) {
                self.park_pending(word);
            }
            return Some(());
        }
        None
    }

    /// Alternate candidate bucket for `key` given it currently sits in (or
    /// targets) `bucket` (Algorithm 3's `AltBucket`).
    #[inline]
    fn alt_bucket(&self, state: &State, key: u32, bucket: u32) -> u32 {
        let (mask, sp) = (state.index_mask, state.split_ptr);
        let d = self.family.d();
        // First candidate that differs from the current bucket; fall back
        // to rotating through the family.
        for i in 0..d {
            let b = self.family.bucket(i, key, mask, sp);
            if b != bucket {
                return b;
            }
        }
        self.family.bucket(0, key, mask, sp)
    }

    // ------------------------------------------------------------------
    // Introspection used by resize, tests and the coordinator
    // ------------------------------------------------------------------

    /// Snapshot all live `(key, value)` pairs (table + stash). Takes the
    /// read guard; concurrent mutations may or may not be observed.
    pub fn entries(&self) -> Vec<(u32, u32)> {
        let state = self.state.read().unwrap();
        let logical = state.logical_buckets();
        let mut out = Vec::with_capacity(self.len());
        for b in 0..logical {
            for lane in 0..SLOTS_PER_BUCKET {
                let w = state.buckets[b * SLOTS_PER_BUCKET + lane].load(Ordering::Acquire);
                if !is_empty(w) {
                    out.push((unpack_key(w), unpack_value(w)));
                }
            }
        }
        if !self.stash.is_quiescent() {
            for w in self.stash_words() {
                out.push((unpack_key(w), unpack_value(w)));
            }
        }
        if self.pending_len.load(Ordering::Acquire) > 0 {
            for &w in self.pending.lock().unwrap().iter() {
                out.push((unpack_key(w), unpack_value(w)));
            }
        }
        out
    }

    /// Live stash words (racy snapshot, diagnostics only).
    pub(crate) fn stash_words(&self) -> Vec<u64> {
        self.stash.peek_window()
    }

    /// Occupancy of each logical bucket (used by CSR-style diagnostics and
    /// resize decisions in tests).
    pub fn bucket_loads(&self) -> Vec<u32> {
        let state = self.state.read().unwrap();
        (0..state.logical_buckets())
            .map(|b| {
                SLOTS_PER_BUCKET as u32
                    - (state.free_mask[b].load(Ordering::Relaxed) & FULL_FREE_MASK).count_ones()
            })
            .collect()
    }
}

enum EvictOutcome {
    Placed,
    Retry,
    Evicted(u64),
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hash::HashKind;
    use std::sync::Arc;

    fn small_table(buckets: usize) -> HiveTable {
        HiveTable::new(HiveConfig::default().with_buckets(buckets)).unwrap()
    }

    #[test]
    fn insert_lookup_roundtrip() {
        let t = small_table(16);
        for k in 0..500u32 {
            assert!(matches!(
                t.insert(k, k.wrapping_mul(3)).unwrap(),
                InsertOutcome::Inserted | InsertOutcome::Evicted | InsertOutcome::Stashed
            ));
        }
        assert_eq!(t.len(), 500);
        for k in 0..500u32 {
            assert_eq!(t.lookup(k), Some(k.wrapping_mul(3)), "key {k}");
        }
        assert_eq!(t.lookup(10_000), None);
    }

    #[test]
    fn replace_updates_in_place() {
        let t = small_table(16);
        assert_eq!(t.insert(5, 50).unwrap(), InsertOutcome::Inserted);
        assert_eq!(t.insert(5, 51).unwrap(), InsertOutcome::Replaced);
        assert_eq!(t.len(), 1, "replace must not grow the table");
        assert_eq!(t.lookup(5), Some(51));
    }

    #[test]
    fn delete_frees_slots_for_reuse() {
        let t = small_table(4);
        for k in 0..100u32 {
            t.insert(k, k).unwrap();
        }
        for k in 0..100u32 {
            assert!(t.delete(k), "delete {k}");
        }
        assert_eq!(t.len(), 0);
        for k in 0..100u32 {
            assert_eq!(t.lookup(k), None);
        }
        // slots are immediately reusable (paper: "immediate slot reuse")
        for k in 200..300u32 {
            t.insert(k, k).unwrap();
        }
        assert_eq!(t.len(), 100);
    }

    #[test]
    fn rejects_sentinel_key() {
        let t = small_table(4);
        assert!(matches!(t.insert(EMPTY_KEY, 1), Err(HiveError::InvalidKey(_))));
        assert_eq!(t.lookup(EMPTY_KEY), None);
        assert!(!t.delete(EMPTY_KEY));
    }

    #[test]
    fn fills_to_high_load_factor() {
        // 8 buckets * 32 slots = 256 capacity; fill to 95%.
        let t = small_table(8);
        let n = (256.0 * 0.95) as u32;
        let mut stashed = 0;
        for k in 1..=n {
            match t.insert(k, k).unwrap() {
                InsertOutcome::Stashed => stashed += 1,
                _ => {}
            }
        }
        assert_eq!(t.len(), n as usize);
        assert!(t.load_factor() > 0.94, "lf {}", t.load_factor());
        for k in 1..=n {
            assert_eq!(t.lookup(k), Some(k), "key {k} lost at high lf");
        }
        // stash should absorb only a small minority
        assert!(stashed < n / 10, "too many stashed: {stashed}");
    }

    #[test]
    fn eviction_path_executes() {
        let t = HiveTable::new(
            HiveConfig::default().with_buckets(4).with_max_evictions(8),
        )
        .unwrap();
        // Craft keys whose *both* candidate buckets fall in {0, 1}: their
        // combined capacity is 64 slots, so the 66th insert must evict (and
        // eventually stash, since victims re-route within {0, 1}).
        let fam = t.family().clone();
        let keys: Vec<u32> = (1..200_000u32)
            .filter(|&k| {
                let b0 = fam.bucket(0, k, 3, 0);
                let b1 = fam.bucket(1, k, 3, 0);
                b0 <= 1 && b1 <= 1
            })
            .take(66)
            .collect();
        assert_eq!(keys.len(), 66);
        for &k in &keys {
            t.insert(k, k).unwrap();
        }
        let snap = t.stats();
        assert!(
            snap.evict_rounds > 0 || snap.stash_pushes > 0,
            "eviction path never ran: {snap:?}"
        );
        for &k in &keys {
            assert_eq!(t.lookup(k), Some(k));
        }
    }

    #[test]
    fn lock_rate_is_rare_at_moderate_load() {
        // §III-B: the eviction lock is used in <0.85% of cases below ~0.85
        // load factor.
        let t = small_table(64);
        let n = (64 * SLOTS_PER_BUCKET) as u32 * 80 / 100;
        for k in 1..=n {
            t.insert(k, k).unwrap();
        }
        for k in 1..=n {
            t.lookup(k);
        }
        let rate = t.stats().lock_rate();
        assert!(rate < 0.0085, "lock rate {rate} exceeds paper bound");
    }

    #[test]
    fn concurrent_inserts_then_lookups() {
        let t = Arc::new(small_table(512));
        let per = 2000u32;
        let threads: Vec<_> = (0..8u32)
            .map(|tid| {
                let t = Arc::clone(&t);
                std::thread::spawn(move || {
                    for i in 0..per {
                        let k = tid * per + i + 1;
                        t.insert(k, k ^ 0xABCD).unwrap();
                    }
                })
            })
            .collect();
        for th in threads {
            th.join().unwrap();
        }
        assert_eq!(t.len(), 8 * per as usize);
        for k in 1..=8 * per {
            assert_eq!(t.lookup(k), Some(k ^ 0xABCD), "key {k}");
        }
    }

    #[test]
    fn concurrent_mixed_workload_is_consistent() {
        // Disjoint key ranges per thread: each thread's view must be
        // perfectly consistent regardless of interleaving.
        let t = Arc::new(small_table(256));
        let threads: Vec<_> = (0..8u32)
            .map(|tid| {
                let t = Arc::clone(&t);
                std::thread::spawn(move || {
                    let base = tid * 10_000 + 1;
                    for i in 0..1000 {
                        let k = base + i;
                        t.insert(k, k).unwrap();
                        assert_eq!(t.lookup(k), Some(k));
                        if i % 3 == 0 {
                            assert!(t.delete(k));
                            assert_eq!(t.lookup(k), None);
                        } else if i % 3 == 1 {
                            t.insert(k, k + 1).unwrap();
                            assert_eq!(t.lookup(k), Some(k + 1));
                        }
                    }
                })
            })
            .collect();
        for th in threads {
            th.join().unwrap();
        }
    }

    #[test]
    fn concurrent_same_key_replaces_converge() {
        let t = Arc::new(small_table(16));
        t.insert(42, 0).unwrap();
        let threads: Vec<_> = (0..8u32)
            .map(|tid| {
                let t = Arc::clone(&t);
                std::thread::spawn(move || {
                    for i in 0..500 {
                        t.insert(42, tid * 1000 + i).unwrap();
                    }
                })
            })
            .collect();
        for th in threads {
            th.join().unwrap();
        }
        // exactly one copy of the key, value is one of the written values
        assert_eq!(t.len(), 1);
        let v = t.lookup(42).unwrap();
        assert!(v < 8000);
        assert!(t.delete(42));
        assert_eq!(t.len(), 0);
    }

    #[test]
    fn three_hash_family_works() {
        let cfg = HiveConfig::default().with_buckets(8).with_hashes(vec![
            HashKind::BitHash1,
            HashKind::BitHash2,
            HashKind::City32,
        ]);
        let t = HiveTable::new(cfg).unwrap();
        for k in 1..=200u32 {
            t.insert(k, k * 7).unwrap();
        }
        for k in 1..=200u32 {
            assert_eq!(t.lookup(k), Some(k * 7));
        }
    }

    #[test]
    fn soa_layout_rejected_by_aos_table() {
        let cfg = HiveConfig::default().with_layout(Layout::SplitSoa);
        assert!(HiveTable::new(cfg).is_err());
    }
}
