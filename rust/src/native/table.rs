//! `HiveTable` — the native concurrent Hive hash table.
//!
//! Concurrency model (DESIGN.md §2): GPU warps → OS threads. All operation
//! fast paths are lock-free and match the paper's protocols instruction for
//! instruction at the atomic level:
//!
//! * **WCME** (lookup / replace / delete): scan the whole bucket row with
//!   one [`crate::core::lanes`] ballot (SWAR or `core::arch` SIMD — the
//!   CPU analogue of the warp's coalesced loads + ballot), elect the
//!   lowest matching lane with an atomically re-validated ffs, winner
//!   performs exactly one 64-bit CAS (replace/delete) or returns the
//!   value (lookup).
//! * **WABC** (claim-then-commit): read the free mask, elect the lowest
//!   free bit, claim it with one `fetch_and`, publish the packed KV with a
//!   release store.
//! * **Bounded cuckoo eviction** under a short per-bucket spin lock, at most
//!   `max_evictions` rounds, then the overflow stash.
//!
//! ### Conditional / read-modify-write operations
//! The packed 64-bit word makes every mutation of a *present* key a
//! single CAS, which the typed operation plane exploits beyond replace:
//! [`HiveTable::update`] (write-if-present), [`HiveTable::cas`]
//! (write-if-value-matches) and [`HiveTable::fetch_add`] (CAS-retried
//! add) all run through one shared probe body (`rmw_core`) that commits
//! with exactly one CAS per applied write and validates misses exactly
//! like `lookup`. [`HiveTable::upsert`] returns the value its replace
//! CAS displaced, and [`HiveTable::insert_if_absent`] reuses the
//! four-step placement fallback for the inserting case. Concurrent RMW
//! ops on an *existing* key are exact (every committed CAS applies its
//! transform to the then-current value once); two racing creators of
//! the same *absent* key share plain insert's pre-existing duplication
//! window.
//!
//! ### Epoch scheme (no phase lock)
//! There is no reader-writer phase guard. An operation *pins an epoch*
//! ([`crate::core::epoch::EpochDomain`]): one RMW on its own padded pin
//! stripe plus one plain load of the epoch word — never an RMW on a shared
//! cache line — and then works directly against the current [`State`]
//! allocation behind an `AtomicPtr`.
//!
//! Linear-hashing resize ([`crate::native::resize`]) migrates K buckets at
//! a time **concurrently with operations**:
//!
//! * The round state (`index_mask`, `split_ptr`) is one packed atomic
//!   *round word* inside `State`; operations snapshot it, route, and
//!   re-validate the snapshot on the miss path.
//! * A bucket being migrated carries a **migration marker** — a reserved
//!   bit (bit 32) in its 64-bit free-mask word. Claims detect the marker
//!   in the `fetch_and` return value (same word ⇒ totally ordered with the
//!   marker), hand back any won slot, and retry with fresh routing; probes
//!   that miss while a marker is (or was) set re-route and retry. Only
//!   operations touching the one or two buckets in flight ever wait — the
//!   rest of the table proceeds at full speed during a resize.
//! * Physical reallocation builds a new `State`, publishes it with a
//!   pointer swap inside the epoch's exclusive phase, and frees the old
//!   allocation after the grace period (all pins drained — quiescent-state
//!   reclamation).
//!
//! ### Batched operations
//! [`crate::native::batch`] adds `insert_batch` / `lookup_batch` /
//! `delete_batch`: one epoch pin per batch (not per op), raw hashes
//! computed for the whole batch up front, and a software-pipelined probe
//! loop that touches op *i+1*'s bucket row while probing op *i* — the CPU
//! analogue of the paper's bulk kernel launches. The single-op paths below
//! delegate to the same `*_core` bodies, so batched and per-op execution
//! are behaviourally identical. Occupancy is tracked by a
//! cache-line-padded [`StripedCounter`] so concurrent batches do not
//! serialize on one `count` cache line.
//!
//! ### Quotiented compact layout (`Layout::CompactQuotient`)
//! The packed word stays 64-bit, but the key half stores a *quotient*
//! ([`crate::core::quotient`]) instead of the key:
//!
//! ```text
//!  63            32 31  30 29                          0
//! +----------------+------+-----------------------------+
//! |     value      | tag  |  rem = h_tag(key) >> w(b)   |
//! +----------------+------+-----------------------------+
//! ```
//!
//! `w(b)` is the number of hash bits the bucket index implies (`m`, or
//! `m + 1` once the bucket has split this round) and `tag` names the
//! family function that produced the hash. Buckets shrink to 16 slots, so
//! a bucket row is one 128-byte cache line instead of two. **The
//! single-CAS invariant survives** because nothing about the word's shape
//! changed: replace/RMW/delete still CAS the one word, WABC still claims
//! a mask bit and release-stores the word, migration markers still live
//! in the mask word, and a live half can never equal the `EMPTY_KEY`
//! sentinel (tag ≤ 2). What *does* change is that half-equality is key
//! equality only while the bucket's stored width matches the width the
//! probe encoded with, so compact probes add two checks around the
//! existing marker/sequence machinery:
//!
//! * the probe half is encoded from a round word read *after* the
//!   candidate's marker check, and a hit is validated against that same
//!   mask word (marker clear, migration sequence unchanged) before it is
//!   believed — a bucket migrated mid-probe re-quotients its entries, so
//!   the probe re-routes instead;
//! * WABC re-reads the round between the mask load and the claim and
//!   re-validates the sequence returned by the claim `fetch_and` itself,
//!   so a word encoded under a stale width is never published.
//!
//! Split re-quotients in place (`rem >>= 1`; the dropped bit is the move
//! decision), merge restores it (`rem = rem << 1 | from_image`) — see
//! `native::resize`. The stash and pending list always store plain
//! full-key words: quotients are only meaningful relative to a bucket.
//! Like every CAS protocol, the compact hit path assumes a 64-bit word is
//! not recycled into a bit-identical word of different identity within
//! one probe's instruction window (here: a full bucket migration *plus*
//! an exact 64-bit refill); the AoS layout is immune because its key
//! half is width-independent.
//!
//! ### Deviation from the paper
//! Algorithm 2 line 15 restores a failed claim bit with `fetch_or`. With
//! `fetch_and(!bit)`, a lost race means the bit was *already* zero, so the
//! failed claimer changed nothing; restoring it would mark a slot free
//! while its winner occupies it. We therefore simply retry with a fresh
//! mask (no restore). A claimer that *won* its bit but cannot publish
//! (migration marker, or the bucket stopped being a candidate) owns the
//! slot and may safely hand the bit back with `fetch_or`. See DESIGN.md §6.

use crate::core::config::{HiveConfig, Layout};
use crate::core::counter::StripedCounter;
use crate::core::epoch::{EpochDomain, EpochGuard};
use crate::core::error::{HiveError, Result};
use crate::core::lanes;
use crate::core::packed::{is_empty, pack, unpack_key, unpack_value, EMPTY_KEY, EMPTY_WORD};
use crate::core::{quotient, FULL_FREE_MASK};
use crate::hash::HashFamily;
use crate::native::stash::OverflowStash;
use crate::native::stats::{OpStats, StatsSnapshot, Step};
use crate::core::sync::atomic::{AtomicPtr, AtomicU32, AtomicU64, AtomicUsize, Ordering};
use crate::core::sync::Mutex;

/// Migration marker: bit 32 of a bucket's 64-bit free-mask word. Set while
/// that bucket is being split or merged; the low 32 bits stay the per-slot
/// free mask.
pub(crate) const MIGRATING: u64 = 1 << 32;

/// The free-mask bits of a mask word (low 32).
pub(crate) const FREE_BITS: u64 = FULL_FREE_MASK as u64;

/// Bits 33+ of a mask word hold the bucket's *migration sequence*: bumped
/// once for every completed split/merge touching the bucket (before the
/// marker clears). Miss-path validation compares it across a probe, which
/// defeats round-word ABA — a split+merge pair that restores an identical
/// `(index_mask, split_ptr)` while a probe is preempted still leaves both
/// buckets' sequences advanced.
pub(crate) const MIGRATION_SEQ_SHIFT: u32 = 33;

/// Pack the linear-hashing round state into one word (high 32 =
/// `index_mask`, low 32 = `split_ptr`) so operations snapshot both with a
/// single load.
#[inline(always)]
pub(crate) fn pack_round(index_mask: u32, split_ptr: u32) -> u64 {
    ((index_mask as u64) << 32) | split_ptr as u64
}

/// Inverse of [`pack_round`].
#[inline(always)]
pub(crate) fn unpack_round(r: u64) -> (u32, u32) {
    ((r >> 32) as u32, r as u32)
}

/// Outcome of [`HiveTable::insert`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum InsertOutcome {
    /// New key, committed via WABC claim (step 2).
    Inserted,
    /// Key existed; value replaced in place (step 1).
    Replaced,
    /// Placed after one or more cuckoo displacements (step 3).
    Evicted,
    /// Redirected to the overflow stash (step 4).
    Stashed,
}

/// Result shape of the inserting RMW classes ([`HiveTable::insert_if_absent`],
/// [`HiveTable::fetch_add`]): the placement step when this call created
/// the key, and the pre-existing/pre-add value when it did not. Exactly
/// one side is `Some`.
pub type RmwInsert = (Option<InsertOutcome>, Option<u32>);

/// Bucket/metadata arrays. Swapped wholesale on physical reallocation via
/// the table's `AtomicPtr` (inside the epoch's exclusive phase); all
/// mutation in the stable phase is per-word atomic.
pub(crate) struct State {
    /// Packed KV words, `phys_buckets * spb` of them, bucket-major. A
    /// bucket row is two 128 B cache lines for the 32-slot AoS layout,
    /// one line for the 16-slot compact layout.
    pub(crate) buckets: Box<[AtomicU64]>,
    /// Per-bucket mask words: low 32 bits are the free mask (bit i set ⇒
    /// slot i free), bit 32 is the [`MIGRATING`] marker.
    pub(crate) masks: Box<[AtomicU64]>,
    /// Per-bucket eviction locks (0 = free). Step 3 and the migrator take
    /// these; operation fast paths never do.
    pub(crate) locks: Box<[AtomicU32]>,
    /// Packed round word (see [`pack_round`]). Stored by the migrator after
    /// each bucket migration, loaded (once per routing decision) by every
    /// operation.
    pub(crate) round: AtomicU64,
    /// Slots per bucket (32 AoS, 16 compact) — fixed per table.
    pub(crate) spb: usize,
    /// Free-mask word with every slot of this geometry available (low
    /// `spb` bits set).
    pub(crate) full_free: u64,
    /// Word codec this table was built with.
    pub(crate) layout: Layout,
}

impl State {
    pub(crate) fn with_buckets(
        phys: usize,
        index_mask: u32,
        split_ptr: u32,
        layout: Layout,
    ) -> Self {
        let spb = layout.slots_per_bucket();
        let full_free = (1u64 << spb) - 1;
        State {
            buckets: (0..phys * spb).map(|_| AtomicU64::new(EMPTY_WORD)).collect(),
            masks: (0..phys).map(|_| AtomicU64::new(full_free)).collect(),
            locks: (0..phys).map(|_| AtomicU32::new(0)).collect(),
            round: AtomicU64::new(pack_round(index_mask, split_ptr)),
            spb,
            full_free,
            layout,
        }
    }

    /// One-load snapshot of `(index_mask, split_ptr)`.
    #[inline(always)]
    pub(crate) fn round(&self) -> (u32, u32) {
        unpack_round(self.round.load(Ordering::Acquire))
    }

    /// Logical bucket count `2^m + split_ptr`.
    #[inline]
    pub(crate) fn logical_buckets(&self) -> usize {
        let (mask, sp) = self.round();
        (mask as usize + 1) + sp as usize
    }

    #[inline]
    pub(crate) fn phys_buckets(&self) -> usize {
        self.masks.len()
    }

    /// Slot index of `(bucket, lane)` in the flat word array.
    #[inline(always)]
    pub(crate) fn slot(&self, bucket: u32, lane: usize) -> usize {
        bucket as usize * self.spb + lane
    }

    /// The 32-bit free mask of `bucket` (marker bit stripped).
    #[inline(always)]
    pub(crate) fn free_mask_of(&self, bucket: u32, order: Ordering) -> u32 {
        (self.masks[bucket as usize].load(order) & FREE_BITS) as u32
    }
}

/// Result of one WABC claim attempt against a bucket.
pub(crate) enum ClaimOutcome {
    /// Word published; the claimed lane is recorded in stats only.
    Placed,
    /// Bucket has no free slot.
    Full,
    /// A migration marker (or a routing change) was detected; the caller
    /// must re-snapshot the round word and retry.
    Restart,
}

/// Result of a bounded cuckoo eviction chain.
enum EvictResult {
    /// The newcomer (and any displaced victim) found a home.
    Placed,
    /// Routing moved under us before any displacement; retry the insert.
    Restart,
    /// Eviction bound exhausted with the newcomer still homeless.
    Bound,
}

enum EvictOutcome {
    Placed,
    Retry,
    Rerouted,
    /// A victim was displaced; carries its *logical* `(key, value)` —
    /// decoded under the bucket lock so the compact layout's stored half
    /// never travels across a width change.
    Evicted(u32, u32),
}

/// The native concurrent Hive hash table (paper §III–§IV).
pub struct HiveTable {
    /// Current state allocation. Only [`crate::native::resize`] swaps it,
    /// inside `epoch`'s exclusive phase.
    pub(crate) state: AtomicPtr<State>,
    /// Epoch domain guarding `state` (pin on every op; exclusive phase +
    /// grace period around pointer swaps).
    pub(crate) epoch: EpochDomain,
    /// Serializes resize passes (migration batches and reallocation).
    /// Never taken on the lookup/insert/delete fast paths.
    pub(crate) resize_mutex: Mutex<()>,
    pub(crate) family: HashFamily,
    pub(crate) cfg: HiveConfig,
    pub(crate) stash: OverflowStash,
    /// Live-entry tally. Striped + cache-line padded: a single shared
    /// `AtomicUsize` here bounces one line between every inserting and
    /// deleting thread, which caps batch throughput (§Perf log).
    pub(crate) count: StripedCounter,
    /// Words flagged *pending* because both the table and the stash were
    /// full (paper §IV-A step 4: "the operation is flagged as pending for
    /// deferred reinsertion during the next resize epoch"). Rare path —
    /// guarded by `pending_len` so the fast path never takes the lock.
    pub(crate) pending: Mutex<Vec<u64>>,
    pub(crate) pending_len: AtomicUsize,
    /// Seqlock-style stash-drain epoch: odd while a drain is republishing
    /// words into the table (the one window where a key can have a table
    /// copy *and* a stash/pending shadow, and where entries move
    /// stash→table against the probes' table→stash scan order).
    /// Delete/replace gate the shadow purge on "odd", and every miss path
    /// re-probes unless the word was even and unchanged across its scan.
    pub(crate) drain_epoch: AtomicU64,
    pub(crate) stats: OpStats,
    /// Minimum round mask — the table never shrinks below its initial size.
    pub(crate) min_index_mask: u32,
}

impl Drop for HiveTable {
    fn drop(&mut self) {
        // SAFETY: `state` always holds the unique pointer produced by
        // `Box::into_raw`, and `&mut self` proves no guard can be live.
        unsafe { drop(Box::from_raw(self.state.load(Ordering::Acquire))) };
    }
}

impl HiveTable {
    /// Create a table from `cfg` (validated).
    pub fn new(cfg: HiveConfig) -> Result<Self> {
        cfg.validate()?;
        if cfg.layout == Layout::SplitSoa {
            // The SoA ablation lives in `native::soa`; HiveTable is AoS.
            return Err(HiveError::Config(
                "HiveTable is the packed-AoS table; use native::soa::SoaTable for the ablation"
                    .into(),
            ));
        }
        let buckets = cfg.initial_buckets.next_power_of_two().max(4);
        let index_mask = (buckets - 1) as u32;
        let spb = cfg.layout.slots_per_bucket();
        let stash_cap = ((buckets * spb) as f64 * cfg.stash_fraction).ceil().max(8.0) as usize;
        let state = Box::new(State::with_buckets(buckets, index_mask, 0, cfg.layout));
        Ok(HiveTable {
            state: AtomicPtr::new(Box::into_raw(state)),
            epoch: EpochDomain::new(),
            resize_mutex: Mutex::new(()),
            family: HashFamily::new(cfg.hash_kinds.clone()),
            stash: OverflowStash::new(stash_cap),
            count: StripedCounter::new(),
            pending: Mutex::new(Vec::new()),
            pending_len: AtomicUsize::new(0),
            drain_epoch: AtomicU64::new(0),
            stats: OpStats::default(),
            min_index_mask: index_mask,
            cfg,
        })
    }

    /// Convenience: table sized for `n` keys at `target_lf` load factor.
    pub fn with_capacity(n: usize, target_lf: f64) -> Result<Self> {
        Self::new(HiveConfig::for_capacity(n, target_lf))
    }

    /// Dereference the current state under a live pin. The returned
    /// reference is valid for the guard's lifetime: reallocation frees a
    /// state only after every pin of the old epoch has dropped.
    #[inline(always)]
    pub(crate) fn state_ref<'g>(&self, _guard: &'g EpochGuard<'_>) -> &'g State {
        // SAFETY: the pointer is always a live Box::into_raw allocation;
        // the pin (witnessed by `_guard`) blocks the grace period that
        // precedes its deallocation.
        unsafe { &*self.state.load(Ordering::Acquire) }
    }

    /// Number of live entries (approximate under concurrency).
    pub fn len(&self) -> usize {
        self.count.sum()
    }

    /// `true` if the table holds no entries.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Current logical bucket count `2^m + split_ptr`.
    pub fn logical_buckets(&self) -> usize {
        let guard = self.epoch.pin();
        self.state_ref(&guard).logical_buckets()
    }

    /// Slot capacity = logical buckets × slots per bucket (32 AoS, 16
    /// compact).
    pub fn capacity(&self) -> usize {
        self.logical_buckets() * self.cfg.layout.slots_per_bucket()
    }

    /// Load factor `len / capacity` (§IV-C's resize trigger input).
    pub fn load_factor(&self) -> f64 {
        self.len() as f64 / self.capacity() as f64
    }

    /// Snapshot of the operation counters.
    pub fn stats(&self) -> StatsSnapshot {
        self.stats.snapshot()
    }

    /// Coherence stamp for read-through caches layered above the table
    /// (the coordinator's hot-key cache). The stamp moves whenever table
    /// state can change outside the caller's own operation stream: a
    /// physical reallocation phase (the epoch word, odd while in flight)
    /// or a stash drain republishing words (the drain epoch, odd while
    /// draining). K-bucket migration between those events relocates
    /// entries but never changes a key's logical value, so it
    /// deliberately does not move the stamp. Both halves are monotonic,
    /// and a stamp sampled mid-phase is odd in that half — it can never
    /// equal a quiescent stamp, so a cache validated against it flushes
    /// again once the phase completes.
    ///
    /// The stamp is strictly per table, and therefore per *shard* in the
    /// sharded coordinator: it says nothing about keys that moved to a
    /// different table via a partition reshard (the service handles that
    /// window by clearing the destination's cache at move activation —
    /// see `coordinator::cache`).
    pub fn coherence_stamp(&self) -> u64 {
        (self.epoch.current() << 32) | (self.drain_epoch.load(Ordering::SeqCst) & 0xFFFF_FFFF)
    }

    /// Words parked past the stash (pending the next resize epoch).
    pub fn pending_full(&self) -> usize {
        self.pending_len.load(Ordering::Relaxed)
    }

    /// Park a word on the pending list (both table and stash full).
    pub(crate) fn park_pending(&self, word: u64) {
        self.pending.lock().unwrap().push(word);
        self.pending_len.fetch_add(1, Ordering::Release);
        self.stats.record_stash_full();
    }

    fn pending_lookup(&self, key: u32) -> Option<u32> {
        if self.pending_len.load(Ordering::Acquire) == 0 {
            return None;
        }
        let guard = self.pending.lock().unwrap();
        guard.iter().rev().find(|&&w| unpack_key(w) == key).map(|&w| unpack_value(w))
    }

    /// Read-modify-write against the pending list (both table and stash
    /// were full when the word was parked). Same contract as
    /// [`OverflowStash::rmw`]; exact because the list is mutex-guarded.
    fn pending_rmw(&self, key: u32, f: &dyn Fn(u32) -> Option<u32>) -> Option<(u32, bool)> {
        if self.pending_len.load(Ordering::Acquire) == 0 {
            return None;
        }
        let mut guard = self.pending.lock().unwrap();
        for w in guard.iter_mut() {
            if unpack_key(*w) == key {
                let old = unpack_value(*w);
                return match f(old) {
                    Some(new) => {
                        *w = pack(key, new);
                        Some((old, true))
                    }
                    None => Some((old, false)),
                };
            }
        }
        None
    }

    fn pending_delete(&self, key: u32) -> bool {
        if self.pending_len.load(Ordering::Acquire) == 0 {
            return false;
        }
        let mut guard = self.pending.lock().unwrap();
        if let Some(pos) = guard.iter().position(|&w| unpack_key(w) == key) {
            guard.remove(pos);
            self.pending_len.fetch_sub(1, Ordering::Release);
            true
        } else {
            false
        }
    }

    /// Remove any shadow copy of `key` from the stash/pending list after a
    /// table-resident copy was updated or removed. During a stash drain the
    /// word is briefly duplicated (table copy published *before* the stash
    /// copy is retracted, so lookups never observe a hole); replace/delete
    /// purge the shadow so the duplicate can never resurrect a key. No
    /// count adjustment: a shadow is a physical duplicate, not an entry.
    ///
    /// Gated on the drain epoch being odd: outside a drain no shadow can
    /// exist, and the drain flips the epoch odd before publishing its
    /// first table copy, so any op that can observe a duplicate also
    /// observes the odd epoch.
    fn purge_shadow(&self, key: u32) {
        if self.drain_epoch.load(Ordering::Acquire) & 1 == 0 {
            return;
        }
        if !self.stash.is_quiescent() {
            self.stash.delete(key);
        }
        if self.pending_len.load(Ordering::Acquire) > 0 {
            self.pending_delete(key);
        }
    }

    /// The configured hash family.
    pub fn family(&self) -> &HashFamily {
        &self.family
    }

    /// The configuration this table was built with.
    pub fn config(&self) -> &HiveConfig {
        &self.cfg
    }

    // ------------------------------------------------------------------
    // Routing
    // ------------------------------------------------------------------

    /// Raw (round-independent) hashes of `key` under the family. Only the
    /// first `family.d()` entries are meaningful. Batch layers hoist this
    /// per-batch; the round reduction stays per-attempt because the round
    /// word can move mid-operation.
    #[inline]
    pub(crate) fn raw_hashes(&self, key: u32) -> [u32; 4] {
        let mut r = [0u32; 4];
        for (slot, i) in r.iter_mut().zip(0..self.family.d()) {
            *slot = self.family.raw(i, key);
        }
        r
    }

    /// Reduce raw hashes to candidate buckets under a round snapshot.
    #[inline(always)]
    pub(crate) fn route(raws: &[u32; 4], d: usize, mask: u32, sp: u32) -> [u32; 4] {
        let mut c = [0u32; 4];
        for (slot, &h) in c.iter_mut().zip(raws.iter()).take(d) {
            *slot = HashFamily::address(h, mask, sp);
        }
        c
    }

    /// `true` while `bucket` is a candidate of `key` under the *current*
    /// round word.
    #[inline]
    fn still_candidate(&self, state: &State, key: u32, bucket: u32) -> bool {
        let (mask, sp) = state.round();
        (0..self.family.d()).any(|i| self.family.bucket(i, key, mask, sp) == bucket)
    }

    /// The key half a probe must match in candidate `i`'s bucket `b`: the
    /// key itself for AoS, the quotiented tag+remainder for compact.
    ///
    /// For compact the encode width must be coherent with `b`'s stored
    /// width, so the round word is (re-)read here — *after* the caller's
    /// marker check on `b`'s mask word — and the subsequent
    /// [`HiveTable::hit_valid`] seq check brackets it. `None` means the
    /// current round no longer routes `h_i(key)` to `b` at all (a split
    /// completed under the probe): the caller re-routes.
    #[inline(always)]
    fn probe_half(
        &self,
        state: &State,
        raws: &[u32; 4],
        i: usize,
        b: u32,
        key: u32,
    ) -> Option<u32> {
        if state.layout != Layout::CompactQuotient {
            return Some(key);
        }
        let (rm, rs) = state.round();
        if HashFamily::address(raws[i], rm, rs) != b {
            return None;
        }
        Some(quotient::encode_half(raws[i], i, b, rm, rs))
    }

    /// Compact-layout hit validation: a half-word match is exact key
    /// equality only while the bucket's stored halves use the width the
    /// probe encoded with. `pre` is the bucket's mask word from the
    /// pre-probe marker check; a marker or sequence change since then
    /// means the bucket re-quotiented mid-probe — the match is void and
    /// the caller must re-route (markers are waited out here). Always
    /// true for AoS, whose key half is width-independent.
    #[inline]
    pub(crate) fn hit_valid(&self, state: &State, bucket: u32, pre: u64) -> bool {
        if state.layout != Layout::CompactQuotient {
            return true;
        }
        // Mutation-smoke seed (`--cfg hive_mutant`, never set in real
        // builds): skip the migration-sequence recheck so a probe that
        // raced a re-quotienting split accepts its stale half-word match.
        // Both the `model_migration` loom model and the linearizability
        // harness must reject this build — CI asserts they do.
        #[cfg(hive_mutant)]
        {
            let _ = (bucket, pre);
            true
        }
        #[cfg(not(hive_mutant))]
        {
            crate::core::sync::atomic::fence(Ordering::SeqCst);
            let now = state.masks[bucket as usize].load(Ordering::SeqCst);
            if now & MIGRATING != 0
                || (now >> MIGRATION_SEQ_SHIFT) != (pre >> MIGRATION_SEQ_SHIFT)
            {
                Self::wait_unmarked(state, bucket);
                return false;
            }
            true
        }
    }

    /// `true` if no stash drain ran or is running since `since` was
    /// sampled from `drain_epoch` — i.e. a probe's table→stash scan order
    /// could not have raced a drain's stash→table move, so its miss is
    /// authoritative.
    #[inline]
    fn stash_stable(&self, since: u64) -> bool {
        since & 1 == 0 && self.drain_epoch.load(Ordering::SeqCst) == since
    }

    /// Park until any in-flight stash drain finishes, instead of
    /// hot-looping full table+stash re-scans against it (the drain can
    /// span many bounded eviction chains).
    #[inline]
    fn wait_drain_quiesced(&self) {
        while self.drain_epoch.load(Ordering::Acquire) & 1 == 1 {
            crate::core::sync::hint::spin_loop();
        }
    }

    /// Spin until `bucket`'s migration marker clears. Migrating one bucket
    /// is O(32) slot moves, so the wait is short and bounded.
    #[inline]
    pub(crate) fn wait_unmarked(state: &State, bucket: u32) {
        while state.masks[bucket as usize].load(Ordering::SeqCst) & MIGRATING != 0 {
            crate::core::sync::hint::spin_loop();
        }
    }

    /// Shared miss-path validation for lookup/delete/insert-replace and
    /// the drain's exact-word retraction. `pre` holds each candidate's
    /// mask word as loaded at the pre-probe marker check. Returns `true`
    /// only if the probe's routing was authoritative end to end:
    ///
    /// * re-routing under the *current* round still yields `cands` — this
    ///   catches a split that completed between the caller's round
    ///   snapshot and its first mask load (the probe would have scanned a
    ///   bucket the key had already left);
    /// * no candidate's marker is set *now* and no candidate's migration
    ///   sequence (mask-word bits 33+) moved across the probe — the
    ///   sequences, unlike the round word, are monotonic, so a preempted
    ///   probe spanning a split+merge pair cannot be fooled by an
    ///   identically restored round (ABA).
    ///
    /// The `SeqCst` fence orders the probe's relaxed slot loads before
    /// the re-loads here (a migrator's marker RMW is a full barrier
    /// before its copy-then-clear stores), so a probe that observed a
    /// migrator's clear also observes its marker or sequence bump. On
    /// `false`, markers have been waited out; the caller re-routes and
    /// re-probes.
    #[inline]
    pub(crate) fn validate_miss(
        &self,
        state: &State,
        raws: &[u32; 4],
        cands: &[u32; 4],
        pre: &[u64; 4],
    ) -> bool {
        let d = self.family.d();
        crate::core::sync::atomic::fence(Ordering::SeqCst);
        let mut stale = false;
        for (&b, &before) in cands[..d].iter().zip(pre[..d].iter()) {
            let now = state.masks[b as usize].load(Ordering::SeqCst);
            let seq_moved = (now >> MIGRATION_SEQ_SHIFT) != (before >> MIGRATION_SEQ_SHIFT);
            if now & MIGRATING != 0 || seq_moved {
                stale = true;
            }
        }
        let (mask_now, sp_now) = state.round();
        if Self::route(raws, d, mask_now, sp_now) != *cands {
            stale = true;
        }
        if stale {
            for &b in &cands[..d] {
                Self::wait_unmarked(state, b);
            }
            return false;
        }
        true
    }

    // ------------------------------------------------------------------
    // WCME probe helpers
    // ------------------------------------------------------------------

    /// The slot-word row of `bucket` — the unit the [`lanes`] ballot
    /// scans (one 128-byte line compact, two lines AoS).
    #[inline(always)]
    fn row_of(state: &State, bucket: u32) -> &[AtomicU64] {
        let base = bucket as usize * state.spb;
        &state.buckets[base..base + state.spb]
    }

    /// WCME match: ballot-scan the whole row of `bucket` for the stored
    /// key half `half` (the key itself for AoS, a [`quotient`] encoding
    /// for compact) via [`lanes::elect_match`] — the CPU analogue of the
    /// warp's coalesced per-lane load + ballot + ffs, vectorized (SWAR
    /// by default, `core::arch` SIMD under `--features simd`). Returns
    /// the elected lane and its atomically re-validated word.
    ///
    /// Perf (§Perf log): the scan uses `Relaxed` loads — one `Acquire`
    /// fence on a hit establishes the publish ordering — so the row scan
    /// stays barrier-free and vectorizable. Used by lookup/delete, whose
    /// operating point is a well-filled table where a mask pre-load is
    /// pure overhead.
    #[inline]
    pub(crate) fn wcme_match(state: &State, bucket: u32, half: u32) -> Option<(usize, u64)> {
        let hit = lanes::elect_match(Self::row_of(state, bucket), half);
        if hit.is_some() {
            crate::core::sync::atomic::fence(Ordering::Acquire);
        }
        hit
    }

    /// Mask-guided WCME variant for the insert replace-check (§Perf
    /// log): one mask-word load selects the occupied lanes and the
    /// ballot's election is restricted to them — during a fill most
    /// buckets are part-empty, cutting the replace probe sharply (insert
    /// +25 % measured; the vector scan reads the full row regardless
    /// since the row *is* the cache-line unit, so the pruning now saves
    /// election work rather than loads). A lane whose claim is
    /// mid-publish reads EMPTY and is excluded; a completed insert's
    /// `fetch_and` happens-before any later mask load, so committed
    /// entries are always scanned.
    #[inline]
    fn wcme_match_masked(state: &State, bucket: u32, half: u32) -> Option<(usize, u64)> {
        let occupied = !state.free_mask_of(bucket, Ordering::Acquire) & state.full_free as u32;
        let hit = lanes::elect_match_in(Self::row_of(state, bucket), half, occupied);
        if hit.is_some() {
            crate::core::sync::atomic::fence(Ordering::Acquire);
        }
        hit
    }

    // ------------------------------------------------------------------
    // Public operations
    // ------------------------------------------------------------------

    /// Search(k): value of `key`, or `None` (paper §III-D).
    pub fn lookup(&self, key: u32) -> Option<u32> {
        if key == EMPTY_KEY {
            return None;
        }
        let guard = self.epoch.pin();
        let state = self.state_ref(&guard);
        let raws = self.raw_hashes(key);
        self.lookup_core(state, key, &raws)
    }

    /// Cache lines one bucket probe touched: the mask-word line plus the
    /// 64-bit-word row lines covering the `lanes` slots scanned. The
    /// ballot engine scans the whole row per step, so callers pass
    /// `state.spb` — hit or miss, the row's full line footprint moved
    /// through the cache (compact: 1 row line, AoS: 2).
    #[inline(always)]
    fn probe_lines(lanes: usize) -> u64 {
        1 + (lanes as u64 * 8).div_ceil(128)
    }

    /// Lookup body, called with an epoch pin held and the raw hashes
    /// already computed (shared with the batch layer).
    pub(crate) fn lookup_core(&self, state: &State, key: u32, raws: &[u32; 4]) -> Option<u32> {
        let d = self.family.d();
        // Line-efficiency accounting (fig14): buckets and cache lines this
        // one logical probe touched, across retries.
        let mut pbuckets = 0u64;
        let mut plines = 0u64;
        'retry: loop {
            // A concurrent stash drain moves entries stash→table, opposite
            // to this probe's table→stash order; a miss below is only
            // authoritative if no drain overlapped the whole scan.
            let de = self.drain_epoch.load(Ordering::SeqCst);
            let (mask, sp) = state.round();
            let cands = Self::route(raws, d, mask, sp);
            let mut pre = [0u64; 4];
            for (i, &b) in cands[..d].iter().enumerate() {
                let mw = state.masks[b as usize].load(Ordering::SeqCst);
                if mw & MIGRATING != 0 {
                    Self::wait_unmarked(state, b);
                    continue 'retry;
                }
                pre[i] = mw;
                let Some(half) = self.probe_half(state, raws, i, b, key) else {
                    continue 'retry;
                };
                pbuckets += 1;
                plines += Self::probe_lines(state.spb);
                if let Some((_lane, w)) = Self::wcme_match(state, b, half) {
                    if !self.hit_valid(state, b, mw) {
                        continue 'retry;
                    }
                    self.stats.record_probe(pbuckets, plines);
                    self.stats.record_lookup(true);
                    return Some(unpack_value(w));
                }
            }
            // Miss: confirm no candidate migrated under the probe.
            if !self.validate_miss(state, raws, &cands, &pre) {
                continue 'retry;
            }
            // Overflow stash participates in lookups for correctness
            // (§IV-A).
            if !self.stash.is_quiescent() {
                if let Some(v) = self.stash.lookup(key) {
                    self.stats.record_probe(pbuckets, plines);
                    self.stats.record_lookup(true);
                    return Some(v);
                }
            }
            if let Some(v) = self.pending_lookup(key) {
                self.stats.record_probe(pbuckets, plines);
                self.stats.record_lookup(true);
                return Some(v);
            }
            if self.stash_stable(de) {
                self.stats.record_probe(pbuckets, plines);
                self.stats.record_lookup(false);
                return None;
            }
            // a drain overlapped the scan — wait it out, then re-probe
            self.wait_drain_quiesced();
        }
    }

    /// Delete(k): remove `key`, returning `true` if it was present
    /// (Algorithm 4: winner CAS to EMPTY, then publish the free bit).
    pub fn delete(&self, key: u32) -> bool {
        if key == EMPTY_KEY {
            return false;
        }
        let guard = self.epoch.pin();
        let state = self.state_ref(&guard);
        let raws = self.raw_hashes(key);
        self.delete_core(state, key, &raws)
    }

    /// Delete body, called with an epoch pin held and the raw hashes
    /// already computed (shared with the batch layer).
    pub(crate) fn delete_core(&self, state: &State, key: u32, raws: &[u32; 4]) -> bool {
        let d = self.family.d();
        // Line-efficiency accounting (fig14/fig15): deletes report probe
        // footprints like lookups do, so `lines_per_probe` covers every
        // probing class, batched or per-op. Counted once per candidate
        // bucket visit — the bounded CAS-retry rescans hit lines already
        // resident in L1.
        let mut pbuckets = 0u64;
        let mut plines = 0u64;
        'retry: loop {
            // drain-overlap guard: see lookup_core
            let de = self.drain_epoch.load(Ordering::SeqCst);
            let (mask, sp) = state.round();
            let cands = Self::route(raws, d, mask, sp);
            let mut pre = [0u64; 4];
            for (i, &b) in cands[..d].iter().enumerate() {
                let mw = state.masks[b as usize].load(Ordering::SeqCst);
                if mw & MIGRATING != 0 {
                    Self::wait_unmarked(state, b);
                    continue 'retry;
                }
                pre[i] = mw;
                let Some(half) = self.probe_half(state, raws, i, b, key) else {
                    continue 'retry;
                };
                pbuckets += 1;
                plines += Self::probe_lines(state.spb);
                // Retry the CAS a bounded number of times: a failed CAS
                // means a concurrent replace updated the value — rescan.
                for _attempt in 0..4 {
                    match Self::wcme_match(state, b, half) {
                        None => break,
                        Some((lane, w)) => {
                            if !self.hit_valid(state, b, mw) {
                                continue 'retry;
                            }
                            let slot = state.slot(b, lane);
                            if state.buckets[slot]
                                .compare_exchange(
                                    w,
                                    EMPTY_WORD,
                                    Ordering::AcqRel,
                                    Ordering::Relaxed,
                                )
                                .is_ok()
                            {
                                // Publish the vacancy (Algorithm 4 line 14).
                                // RMW, so it composes with the migrator's
                                // concurrent mask updates. If a migrator
                                // already copied this word to its partner
                                // bucket, its clear-CAS will fail against
                                // our EMPTY and it retracts the copy.
                                state.masks[b as usize]
                                    .fetch_or(1u64 << lane, Ordering::AcqRel);
                                self.count.decr();
                                self.purge_shadow(key);
                                self.stats.record_probe(pbuckets, plines);
                                self.stats.record_delete(true);
                                return true;
                            }
                            self.stats.record_cas_retry();
                        }
                    }
                }
            }
            // Miss: confirm no candidate migrated under the probe.
            if !self.validate_miss(state, raws, &cands, &pre) {
                continue 'retry;
            }
            if !self.stash.is_quiescent() && self.stash.delete(key) {
                self.count.decr();
                self.stats.record_probe(pbuckets, plines);
                self.stats.record_delete(true);
                return true;
            }
            if self.pending_delete(key) {
                self.count.decr();
                self.stats.record_probe(pbuckets, plines);
                self.stats.record_delete(true);
                return true;
            }
            if self.stash_stable(de) {
                self.stats.record_probe(pbuckets, plines);
                self.stats.record_delete(false);
                return false;
            }
            // a drain overlapped the scan — wait it out, then re-probe
            self.wait_drain_quiesced();
        }
    }

    /// Insert(⟨k,v⟩) / Replace(⟨k,v⟩) — the four-step strategy (§IV-A).
    /// Alias of [`HiveTable::upsert`] that discards the previous value.
    pub fn insert(&self, key: u32, value: u32) -> Result<InsertOutcome> {
        self.upsert(key, value).map(|(outcome, _)| outcome)
    }

    /// Insert or replace `key → value`, returning the placement step and
    /// the previous value (`None` ⇒ the key was fresh). The packed
    /// 64-bit word makes the replace a single CAS, so the old value
    /// comes for free — the typed plane surfaces it instead of
    /// discarding it.
    pub fn upsert(&self, key: u32, value: u32) -> Result<(InsertOutcome, Option<u32>)> {
        if key == EMPTY_KEY {
            return Err(HiveError::InvalidKey(key));
        }
        let guard = self.epoch.pin();
        let state = self.state_ref(&guard);
        let raws = self.raw_hashes(key);
        let (outcome, old) = self.upsert_core(state, key, value, &raws)?;
        self.record_insert_outcome(outcome);
        Ok((outcome, old))
    }

    /// Bump the per-step insert counters (shared with the batch layer).
    #[inline]
    pub(crate) fn record_insert_outcome(&self, outcome: InsertOutcome) {
        match outcome {
            InsertOutcome::Replaced => self.stats.record_insert(Step::Replace),
            InsertOutcome::Inserted => self.stats.record_insert(Step::Claim),
            InsertOutcome::Evicted => self.stats.record_insert(Step::Evict),
            InsertOutcome::Stashed => self.stats.record_insert(Step::Stash),
        }
    }

    /// Upsert body, called with an epoch pin held and the raw hashes
    /// already computed (shared with the batch layer). Step 1 (Replace,
    /// Algorithm 1) runs here and reports the value it replaced; the
    /// claim/evict/stash fallback is [`HiveTable::place_core`].
    pub(crate) fn upsert_core(
        &self,
        state: &State,
        key: u32,
        value: u32,
        raws: &[u32; 4],
    ) -> Result<(InsertOutcome, Option<u32>)> {
        let d = self.family.d();
        // Probe accounting for the replace scan (fig14/fig15): one
        // record per logical upsert, covering the match phase only (the
        // placement fallback is a write path, not a probe).
        let mut pbuckets = 0u64;
        let mut plines = 0u64;

        // ---- Step 1: Replace (Algorithm 1) ----
        'probe: loop {
            // drain-overlap guard: see lookup_core
            let de = self.drain_epoch.load(Ordering::SeqCst);
            let (mask, sp) = state.round();
            let cands = Self::route(raws, d, mask, sp);
            let mut pre = [0u64; 4];
            for (i, &b) in cands[..d].iter().enumerate() {
                let mw = state.masks[b as usize].load(Ordering::SeqCst);
                if mw & MIGRATING != 0 {
                    Self::wait_unmarked(state, b);
                    continue 'probe;
                }
                pre[i] = mw;
                let Some(half) = self.probe_half(state, raws, i, b, key) else {
                    continue 'probe;
                };
                pbuckets += 1;
                plines += Self::probe_lines(state.spb);
                // The replacement word reuses the matched half: same key,
                // same bucket, same width (hit_valid pins the width).
                let new_word = pack(half, value);
                for _attempt in 0..4 {
                    match Self::wcme_match_masked(state, b, half) {
                        None => break,
                        Some((lane, old)) => {
                            if !self.hit_valid(state, b, mw) {
                                continue 'probe;
                            }
                            let slot = state.slot(b, lane);
                            if state.buckets[slot]
                                .compare_exchange(
                                    old,
                                    new_word,
                                    Ordering::AcqRel,
                                    Ordering::Relaxed,
                                )
                                .is_ok()
                            {
                                // A migrator racing this bucket re-copies on
                                // clear-CAS failure, so the fresh value
                                // always reaches the partner bucket.
                                self.purge_shadow(key);
                                self.stats.record_probe(pbuckets, plines);
                                return Ok((InsertOutcome::Replaced, Some(unpack_value(old))));
                            }
                            self.stats.record_cas_retry();
                        }
                    }
                }
            }
            // Miss: confirm no candidate migrated under the probe.
            if !self.validate_miss(state, raws, &cands, &pre) {
                continue 'probe;
            }
            // Key may be parked in the stash or pending list; replace it
            // there so the eventual drain does not resurrect a stale value.
            if !self.stash.is_quiescent() {
                if let Some((old, true)) = self.stash.rmw(key, &|_| Some(value)) {
                    self.stats.record_probe(pbuckets, plines);
                    return Ok((InsertOutcome::Replaced, Some(old)));
                }
            }
            if let Some((old, true)) = self.pending_rmw(key, &|_| Some(value)) {
                self.stats.record_probe(pbuckets, plines);
                return Ok((InsertOutcome::Replaced, Some(old)));
            }
            if self.stash_stable(de) {
                break;
            }
            // A drain overlapped the replace scan: the key may have moved
            // stash→table behind the probe. Wait it out and re-probe
            // before claiming, or the drained copy would be silently
            // duplicated.
            self.wait_drain_quiesced();
        }

        self.stats.record_probe(pbuckets, plines);
        self.place_core(state, key, value, raws).map(|outcome| (outcome, None))
    }

    /// Steps 2–4 of the four-step strategy (claim / evict / stash) for a
    /// key the caller just established as absent: the shared placement
    /// fallback of every inserting operation class (`upsert`,
    /// `insert_if_absent`, `fetch_add` on a missing key). Takes the
    /// logical `(key, value)` — the stored word is encoded per target
    /// bucket inside the claim (quotients are bucket-relative), and the
    /// stash always receives a plain full-key word. Increments the live
    /// count on every path — stash overflow parks the word pending the
    /// next resize epoch, never drops it.
    pub(crate) fn place_core(
        &self,
        state: &State,
        key: u32,
        value: u32,
        raws: &[u32; 4],
    ) -> Result<InsertOutcome> {
        let d = self.family.d();
        'place: loop {
            let (mask, sp) = state.round();
            let cands = Self::route(raws, d, mask, sp);
            // Bucketed two-choice: attempt the candidate with the most free
            // slots first (§V: "bucketed two-choice placement policy").
            let mut order = [0usize; 4];
            for (i, o) in order.iter_mut().enumerate().take(d) {
                *o = i;
            }
            if d == 2 {
                let f0 = state.free_mask_of(cands[0], Ordering::Relaxed).count_ones();
                let f1 = state.free_mask_of(cands[1], Ordering::Relaxed).count_ones();
                if f1 > f0 {
                    order.swap(0, 1);
                }
            }
            // ---- Step 2: Claim-then-commit (Algorithm 2 / WABC) ----
            for &i in &order[..d] {
                match self.wabc_claim_commit(state, cands[i], key, value, raws) {
                    ClaimOutcome::Placed => {
                        self.count.incr();
                        return Ok(InsertOutcome::Inserted);
                    }
                    ClaimOutcome::Restart => continue 'place,
                    ClaimOutcome::Full => {}
                }
            }

            // ---- Step 3: bounded cuckoo eviction (Algorithm 3) ----
            match self.cuckoo_evict_insert(state, cands[0], key, value, raws) {
                EvictResult::Placed => {
                    self.count.incr();
                    return Ok(InsertOutcome::Evicted);
                }
                EvictResult::Restart => continue 'place,
                EvictResult::Bound => {
                    // ---- Step 4: overflow stash ----
                    // Stash full ⇒ the word is *flagged pending* for the
                    // next resize epoch (§IV-A) — never dropped, never an
                    // error. Stash/pending words are always plain AoS.
                    let word = pack(key, value);
                    if !self.stash.push(word) {
                        self.park_pending(word);
                    }
                    self.count.incr();
                    return Ok(InsertOutcome::Stashed);
                }
            }
        }
    }

    /// Shared probe/CAS body of the conditional and read-modify-write
    /// operations (`update`, `cas`, `fetch_add`, and the find phase of
    /// `insert_if_absent`): locate `key`, feed its current value to `f`,
    /// and commit `f`'s replacement (if any) with one 64-bit CAS on the
    /// packed word — the paper's single-CAS mutation property extended
    /// beyond replace. Returns `Some((old, written))` when the key was
    /// found (`written == false` ⇔ `f` declined) and `None` on an
    /// authoritative miss (validated against migration and stash drains
    /// exactly like `lookup_core`).
    ///
    /// Unlike delete's bounded CAS retry, the per-slot loop here retries
    /// while the slot still holds `key`: a hot fetch-add counter fails
    /// its CAS routinely under contention, and falling through to the
    /// miss path would fabricate an "absent" answer (and, for creating
    /// callers, a duplicate). Each failed CAS re-reads the slot; the
    /// loop exits to a full re-probe the moment the word moves away
    /// (concurrent delete or migration), so every committed CAS applies
    /// `f` to the then-current value exactly once — no lost updates.
    ///
    /// Stash-resident keys RMW in place through [`OverflowStash::rmw`],
    /// which shares the replace path's drain protocol (and therefore its
    /// documented transient corner — see the three-corner note in
    /// `native::resize`: a write that wins on the stash copy can leave
    /// the drain's just-published stale table copy readable for the
    /// instants until the drain's `remove_exact` undo).
    pub(crate) fn rmw_core(
        &self,
        state: &State,
        key: u32,
        raws: &[u32; 4],
        f: &dyn Fn(u32) -> Option<u32>,
    ) -> Option<(u32, bool)> {
        let d = self.family.d();
        // Probe accounting (fig14/fig15): the RMW classes (update / cas /
        // fetch-add / if-absent's find phase) report probe footprints
        // like lookups, so batched RMW drivers get `lines_per_probe`.
        let mut pbuckets = 0u64;
        let mut plines = 0u64;
        'retry: loop {
            // drain-overlap guard: see lookup_core
            let de = self.drain_epoch.load(Ordering::SeqCst);
            let (mask, sp) = state.round();
            let cands = Self::route(raws, d, mask, sp);
            let mut pre = [0u64; 4];
            for (i, &b) in cands[..d].iter().enumerate() {
                let mw = state.masks[b as usize].load(Ordering::SeqCst);
                if mw & MIGRATING != 0 {
                    Self::wait_unmarked(state, b);
                    continue 'retry;
                }
                pre[i] = mw;
                let Some(half) = self.probe_half(state, raws, i, b, key) else {
                    continue 'retry;
                };
                pbuckets += 1;
                plines += Self::probe_lines(state.spb);
                if let Some((lane, mut w)) = Self::wcme_match(state, b, half) {
                    if !self.hit_valid(state, b, mw) {
                        continue 'retry;
                    }
                    let slot = state.slot(b, lane);
                    loop {
                        let old = unpack_value(w);
                        let Some(new) = f(old) else {
                            self.stats.record_probe(pbuckets, plines);
                            return Some((old, false));
                        };
                        match state.buckets[slot].compare_exchange(
                            w,
                            pack(half, new),
                            Ordering::AcqRel,
                            Ordering::Acquire,
                        ) {
                            Ok(_) => {
                                // A racing migrator's clear-CAS fails
                                // against the fresh word and re-copies,
                                // same as the replace path.
                                self.purge_shadow(key);
                                self.stats.record_probe(pbuckets, plines);
                                return Some((old, true));
                            }
                            Err(cur) => {
                                self.stats.record_cas_retry();
                                // In-place value-churn retry is AoS-only:
                                // a compact half re-matched here could be
                                // a re-quotiented stranger — take the full
                                // re-probe, whose hit validation re-pins
                                // the width.
                                if state.layout != Layout::CompactQuotient
                                    && cur & 0xFFFF_FFFF == half as u64
                                {
                                    w = cur; // value churned: retry in place
                                } else {
                                    continue 'retry; // word moved: re-probe
                                }
                            }
                        }
                    }
                }
            }
            // Miss: confirm no candidate migrated under the probe.
            if !self.validate_miss(state, raws, &cands, &pre) {
                continue 'retry;
            }
            // The key may live in the stash or the pending list; the RMW
            // applies there with the same exactness (per-slot CAS /
            // mutex).
            if !self.stash.is_quiescent() {
                if let Some(hit) = self.stash.rmw(key, f) {
                    self.stats.record_probe(pbuckets, plines);
                    return Some(hit);
                }
            }
            if let Some(hit) = self.pending_rmw(key, f) {
                self.stats.record_probe(pbuckets, plines);
                return Some(hit);
            }
            if self.stash_stable(de) {
                self.stats.record_probe(pbuckets, plines);
                return None;
            }
            // a drain overlapped the scan — wait it out, then re-probe
            self.wait_drain_quiesced();
        }
    }

    /// Insert `key → value` only if absent. Returns `(outcome, existing)`:
    /// `existing == Some(v)` means the key was present with value `v`
    /// and nothing was written (`outcome` is `None`); otherwise the
    /// insert landed through the four-step placement path.
    pub fn insert_if_absent(&self, key: u32, value: u32) -> Result<RmwInsert> {
        if key == EMPTY_KEY {
            return Err(HiveError::InvalidKey(key));
        }
        let guard = self.epoch.pin();
        let state = self.state_ref(&guard);
        let raws = self.raw_hashes(key);
        self.insert_if_absent_core(state, key, value, &raws)
    }

    /// `insert_if_absent` body (shared with the batch layer).
    pub(crate) fn insert_if_absent_core(
        &self,
        state: &State,
        key: u32,
        value: u32,
        raws: &[u32; 4],
    ) -> Result<RmwInsert> {
        if let Some((existing, _)) = self.rmw_core(state, key, raws, &|_| None) {
            return Ok((None, Some(existing)));
        }
        let outcome = self.place_core(state, key, value, raws)?;
        self.record_insert_outcome(outcome);
        Ok((Some(outcome), None))
    }

    /// Replace the value of `key` only if present, returning the
    /// previous value (`None` ⇒ absent, nothing written). One CAS on the
    /// packed word.
    pub fn update(&self, key: u32, value: u32) -> Option<u32> {
        if key == EMPTY_KEY {
            return None;
        }
        let guard = self.epoch.pin();
        let state = self.state_ref(&guard);
        let raws = self.raw_hashes(key);
        self.update_core(state, key, value, &raws)
    }

    /// `update` body (shared with the batch layer).
    pub(crate) fn update_core(
        &self,
        state: &State,
        key: u32,
        value: u32,
        raws: &[u32; 4],
    ) -> Option<u32> {
        self.rmw_core(state, key, raws, &|_| Some(value)).map(|(old, _)| old)
    }

    /// Compare-and-swap: store `new` iff the current value of `key`
    /// equals `expected`. Returns `(ok, actual)` where `actual` is the
    /// value observed before the op (`None` ⇒ key absent, never a
    /// match). Lock-free single CAS on the packed word.
    pub fn cas(&self, key: u32, expected: u32, new: u32) -> (bool, Option<u32>) {
        if key == EMPTY_KEY {
            return (false, None);
        }
        let guard = self.epoch.pin();
        let state = self.state_ref(&guard);
        let raws = self.raw_hashes(key);
        self.cas_core(state, key, expected, new, &raws)
    }

    /// `cas` body (shared with the batch layer).
    pub(crate) fn cas_core(
        &self,
        state: &State,
        key: u32,
        expected: u32,
        new: u32,
        raws: &[u32; 4],
    ) -> (bool, Option<u32>) {
        match self.rmw_core(state, key, raws, &|old| (old == expected).then_some(new)) {
            Some((old, written)) => (written, Some(old)),
            None => (false, None),
        }
    }

    /// Add `delta` (wrapping) to the value of `key`, creating the key at
    /// value `delta` when absent. Returns `(outcome, old)`: `old` is the
    /// pre-add value when the key existed (`outcome` `None`), and
    /// `outcome` is the placement step when this call created the key
    /// (`old` `None`). CAS-retried on the packed word — concurrent adds
    /// to an existing key never lose updates.
    pub fn fetch_add(&self, key: u32, delta: u32) -> Result<RmwInsert> {
        if key == EMPTY_KEY {
            return Err(HiveError::InvalidKey(key));
        }
        let guard = self.epoch.pin();
        let state = self.state_ref(&guard);
        let raws = self.raw_hashes(key);
        self.fetch_add_core(state, key, delta, &raws)
    }

    /// `fetch_add` body (shared with the batch layer).
    pub(crate) fn fetch_add_core(
        &self,
        state: &State,
        key: u32,
        delta: u32,
        raws: &[u32; 4],
    ) -> Result<RmwInsert> {
        if let Some((old, _)) = self.rmw_core(state, key, raws, &|v| Some(v.wrapping_add(delta))) {
            return Ok((None, Some(old)));
        }
        // Authoritative miss: create the counter at `delta` through the
        // placement path. (Two racing creators of the same absent key
        // can still both place — the same pre-existing window as two
        // racing plain inserts; exactness claims assume the key exists.)
        let outcome = self.place_core(state, key, delta, raws)?;
        self.record_insert_outcome(outcome);
        Ok((Some(outcome), None))
    }

    /// WABC claim + commit (Algorithm 2) with migration awareness. The
    /// claim `fetch_and` and the migrator's marker `fetch_or` hit the same
    /// mask word, so they are totally ordered: a claim that lands *after*
    /// the marker sees it in the returned value and backs out; a claim
    /// that lands *before* is seen by the migrator, which then waits for
    /// the publish store before migrating (settle phase). After winning a
    /// bit the claimer re-validates the routing — a split that completed
    /// between the round snapshot and the claim would otherwise strand the
    /// entry in a bucket lookups no longer probe.
    ///
    /// Takes the logical `(key, value)` and encodes the stored word here:
    /// for compact the encode width must be coherent with this bucket's
    /// stored width, so the round is read *after* the mask-word load, and
    /// the claim `fetch_and`'s returned migration sequence — same word,
    /// totally ordered — re-validates it. A sequence that moved between
    /// encode and claim means the width may be stale: hand the bit back
    /// and restart, never publish.
    #[inline]
    pub(crate) fn wabc_claim_commit(
        &self,
        state: &State,
        bucket: u32,
        key: u32,
        value: u32,
        raws: &[u32; 4],
    ) -> ClaimOutcome {
        let compact = state.layout == Layout::CompactQuotient;
        let fm = &state.masks[bucket as usize];
        loop {
            // Lane 0's relaxed load + broadcast.
            let mw = fm.load(Ordering::Relaxed);
            if mw & MIGRATING != 0 {
                Self::wait_unmarked(state, bucket);
                return ClaimOutcome::Restart;
            }
            let mask = (mw & FREE_BITS) as u32;
            if mask == 0 {
                return ClaimOutcome::Full; // bucket full — early warp exit
            }
            // Encode the publish word (round read after the mask word —
            // see the doc comment; the family function that routes to
            // this bucket becomes the stored tag).
            let word = if compact {
                let (rm, rs) = state.round();
                let d = self.family.d();
                let Some(cand) =
                    (0..d).find(|&i| HashFamily::address(raws[i], rm, rs) == bucket)
                else {
                    return ClaimOutcome::Restart; // bucket no longer ours
                };
                pack(quotient::encode_half(raws[cand], cand, bucket, rm, rs), value)
            } else {
                pack(key, value)
            };
            // Winner = lowest free lane (ballot + ffs).
            let lane = mask.trailing_zeros() as usize;
            let bit = 1u64 << lane;
            // One atomic RMW claims the slot.
            let old = fm.fetch_and(!bit, Ordering::AcqRel);
            if old & MIGRATING != 0 {
                // Migration began between the load and the claim. If we won
                // the bit we own an unpublished slot: hand it back (safe —
                // nothing was published) and re-route.
                if old & bit != 0 {
                    fm.fetch_or(bit, Ordering::AcqRel);
                }
                Self::wait_unmarked(state, bucket);
                return ClaimOutcome::Restart;
            }
            if compact && (old >> MIGRATION_SEQ_SHIFT) != (mw >> MIGRATION_SEQ_SHIFT) {
                // The bucket migrated (and re-quotiented) between the
                // encode and the claim: the word's width is stale.
                if old & bit != 0 {
                    fm.fetch_or(bit, Ordering::AcqRel);
                }
                return ClaimOutcome::Restart;
            }
            if old & bit == 0 {
                // Lost the race — the bit was already claimed; *no restore*
                // (see module docs) — re-read the mask and retry.
                self.stats.record_cas_retry();
                continue;
            }
            // Ownership confirmed. Validate routing before publishing: the
            // round store is ordered before the marker clear, and our
            // claim's Acquire synchronizes with that clear, so this load
            // sees any round that retired this bucket for `key`.
            if !self.still_candidate(state, key, bucket) {
                fm.fetch_or(bit, Ordering::AcqRel);
                return ClaimOutcome::Restart;
            }
            state.buckets[state.slot(bucket, lane)].store(word, Ordering::Release);
            return ClaimOutcome::Placed;
        }
    }

    /// First candidate bucket of `key` under the current round word.
    #[inline]
    fn current_bucket_of(&self, state: &State, key: u32) -> u32 {
        let (mask, sp) = state.round();
        self.family.bucket(0, key, mask, sp)
    }

    /// Bounded cuckoo eviction (Algorithm 3). Returns [`EvictResult`]; a
    /// displaced victim is *never* dropped — if the bound runs out with a
    /// victim in hand it goes to the stash (or the pending list).
    ///
    /// Carries the *logical* `(key, value)` rather than a packed word:
    /// under the compact layout the stored half is bucket- and
    /// width-relative, so each hop re-encodes for its destination bucket
    /// and decodes displaced victims while the per-bucket lock (which
    /// excludes migration, hence width changes) is still held.
    fn cuckoo_evict_insert(
        &self,
        state: &State,
        start_bucket: u32,
        key: u32,
        value: u32,
        raws: &[u32; 4],
    ) -> EvictResult {
        let compact = state.layout == Layout::CompactQuotient;
        let mut cur_key = key;
        let mut cur_val = value;
        let mut cur_raws = *raws;
        let mut carrying = false; // true once a displaced victim is in hand
        let mut bucket = start_bucket;
        for _kick in 0..self.cfg.max_evictions {
            self.stats.record_evict_round();
            // Lock-free fast path: a slot may have freed up.
            match self.wabc_claim_commit(state, bucket, cur_key, cur_val, &cur_raws) {
                ClaimOutcome::Placed => return EvictResult::Placed,
                ClaimOutcome::Restart => {
                    if !carrying {
                        return EvictResult::Restart;
                    }
                    // Carrying a displaced victim: re-route it under the
                    // fresh round word and keep going.
                    bucket = self.current_bucket_of(state, cur_key);
                    continue;
                }
                ClaimOutcome::Full => {}
            }
            // Short critical section on this bucket only (lane 0's lock).
            // The migrator takes this lock before marking the bucket, so
            // holding it excludes migration entirely.
            let lock = &state.locks[bucket as usize];
            if lock.compare_exchange(0, 1, Ordering::Acquire, Ordering::Relaxed).is_err() {
                // Someone else is evicting (or migrating) here; spin
                // briefly then retry the round (bounded overall by
                // max_evictions).
                crate::core::sync::hint::spin_loop();
                continue;
            }
            self.stats.record_lock();

            let outcome = (|| {
                // Re-validate routing under the lock: a split of this
                // bucket that completed before we locked may have moved
                // the entry's home. The check stays true until unlock.
                if !self.still_candidate(state, cur_key, bucket) {
                    return EvictOutcome::Rerouted;
                }
                // The lock excludes migration of this bucket, so this
                // round read stays width-coherent until unlock.
                let (rm, rs) = state.round();
                let word = if compact {
                    let d = self.family.d();
                    let Some(cand) =
                        (0..d).find(|&i| HashFamily::address(cur_raws[i], rm, rs) == bucket)
                    else {
                        return EvictOutcome::Rerouted;
                    };
                    pack(quotient::encode_half(cur_raws[cand], cand, bucket, rm, rs), cur_val)
                } else {
                    pack(cur_key, cur_val)
                };
                let fm = &state.masks[bucket as usize];
                let mask = (fm.load(Ordering::Relaxed) & FREE_BITS) as u32;
                if mask != 0 {
                    // (i) a free bit exists: claim it under the lock.
                    let lane = mask.trailing_zeros() as usize;
                    let bit = 1u64 << lane;
                    let old = fm.fetch_and(!bit, Ordering::AcqRel);
                    if old & bit != 0 {
                        state.buckets[state.slot(bucket, lane)].store(word, Ordering::Release);
                        return EvictOutcome::Placed;
                    }
                    return EvictOutcome::Retry;
                }
                // (ii) displace the first occupied slot.
                let occ = state.full_free as u32 & !mask; // all occupied here
                let lane = occ.trailing_zeros() as usize;
                let slot = state.slot(bucket, lane);
                let victim = state.buckets[slot].load(Ordering::Acquire);
                if is_empty(victim) {
                    // Concurrent delete cleared it between mask read and
                    // now; its free bit will appear — retry the round.
                    return EvictOutcome::Retry;
                }
                // Swap newcomer in; CAS so a racing replace/delete of the
                // victim is detected rather than silently overwritten.
                if state.buckets[slot]
                    .compare_exchange(victim, word, Ordering::AcqRel, Ordering::Relaxed)
                    .is_ok()
                {
                    // Decode the victim to logical form while the lock
                    // still pins this bucket's quotient width.
                    let vhalf = unpack_key(victim);
                    let vkey = if compact {
                        quotient::decode_key(&self.family, vhalf, bucket, rm, rs)
                    } else {
                        vhalf
                    };
                    EvictOutcome::Evicted(vkey, unpack_value(victim))
                } else {
                    EvictOutcome::Retry
                }
            })();

            lock.store(0, Ordering::Release);

            match outcome {
                EvictOutcome::Placed => return EvictResult::Placed,
                EvictOutcome::Retry => continue,
                EvictOutcome::Rerouted => {
                    if !carrying {
                        return EvictResult::Restart;
                    }
                    bucket = self.current_bucket_of(state, cur_key);
                    continue;
                }
                EvictOutcome::Evicted(vkey, vval) => {
                    // Re-route the victim to its alternate bucket.
                    bucket = self.alt_bucket(state, vkey, bucket);
                    cur_key = vkey;
                    cur_val = vval;
                    cur_raws = self.raw_hashes(vkey);
                    carrying = true;
                }
            }
        }
        // Bound exceeded. If a victim is in hand the newcomer was already
        // placed and the *victim* needs the fallback; it must never be
        // dropped — stash it, or park it pending. Stash and pending words
        // are always plain AoS `(key, value)`: no bucket, no width.
        if carrying {
            let word = pack(cur_key, cur_val);
            if !self.stash.push(word) {
                self.park_pending(word);
            }
            return EvictResult::Placed;
        }
        EvictResult::Bound
    }

    /// Alternate candidate bucket for `key` given it currently sits in (or
    /// targets) `bucket` (Algorithm 3's `AltBucket`).
    #[inline]
    fn alt_bucket(&self, state: &State, key: u32, bucket: u32) -> u32 {
        let (mask, sp) = state.round();
        let d = self.family.d();
        // First candidate that differs from the current bucket; fall back
        // to rotating through the family.
        for i in 0..d {
            let b = self.family.bucket(i, key, mask, sp);
            if b != bucket {
                return b;
            }
        }
        self.family.bucket(0, key, mask, sp)
    }

    /// Claim-only reinsertion used by the stash drain: the key is known to
    /// be absent from the main table, the word is already counted, and the
    /// caller keeps the stash copy alive until this returns `true` (so
    /// concurrent lookups never observe a hole). No stats, no count.
    pub(crate) fn reinsert_word(&self, state: &State, key: u32, word: u64) -> bool {
        let value = unpack_value(word);
        let raws = self.raw_hashes(key);
        let d = self.family.d();
        loop {
            let (mask, sp) = state.round();
            let cands = Self::route(raws, d, mask, sp);
            let mut restart = false;
            for &b in &cands[..d] {
                match self.wabc_claim_commit(state, b, key, value, &raws) {
                    ClaimOutcome::Placed => return true,
                    ClaimOutcome::Restart => {
                        restart = true;
                        break;
                    }
                    ClaimOutcome::Full => {}
                }
            }
            if restart {
                continue;
            }
            match self.cuckoo_evict_insert(state, cands[0], key, value, &raws) {
                EvictResult::Placed => return true,
                EvictResult::Restart => continue,
                EvictResult::Bound => return false,
            }
        }
    }

    // ------------------------------------------------------------------
    // Introspection used by resize, tests and the coordinator
    // ------------------------------------------------------------------

    /// Snapshot all live `(key, value)` pairs (table + stash). Pins an
    /// epoch; concurrent mutations may or may not be observed. Holds the
    /// resize mutex for the scan: under the compact layout a stored half
    /// is only meaningful together with its bucket's current quotient
    /// width, so migration must not run mid-decode.
    pub fn entries(&self) -> Vec<(u32, u32)> {
        let _resize = self.resize_mutex.lock().unwrap();
        let guard = self.epoch.pin();
        let state = self.state_ref(&guard);
        let compact = state.layout == Layout::CompactQuotient;
        let (rm, rs) = state.round();
        let logical = state.logical_buckets();
        let mut out = Vec::with_capacity(self.len());
        for b in 0..logical {
            for lane in 0..state.spb {
                let w = state.buckets[b * state.spb + lane].load(Ordering::Acquire);
                if !is_empty(w) {
                    let half = unpack_key(w);
                    let key = if compact {
                        quotient::decode_key(&self.family, half, b as u32, rm, rs)
                    } else {
                        half
                    };
                    out.push((key, unpack_value(w)));
                }
            }
        }
        if !self.stash.is_quiescent() {
            for w in self.stash_words() {
                out.push((unpack_key(w), unpack_value(w)));
            }
        }
        if self.pending_len.load(Ordering::Acquire) > 0 {
            for &w in self.pending.lock().unwrap().iter() {
                out.push((unpack_key(w), unpack_value(w)));
            }
        }
        out
    }

    /// Live stash words (racy snapshot, diagnostics only).
    pub(crate) fn stash_words(&self) -> Vec<u64> {
        self.stash.peek_window()
    }

    /// Occupancy of each logical bucket (used by CSR-style diagnostics and
    /// resize decisions in tests).
    pub fn bucket_loads(&self) -> Vec<u32> {
        let guard = self.epoch.pin();
        let state = self.state_ref(&guard);
        (0..state.logical_buckets())
            .map(|b| {
                let free = state.free_mask_of(b as u32, Ordering::Relaxed).count_ones();
                state.spb as u32 - free
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hash::HashKind;
    use std::sync::Arc;

    fn small_table(buckets: usize) -> HiveTable {
        HiveTable::new(HiveConfig::default().with_buckets(buckets)).unwrap()
    }

    #[test]
    fn insert_lookup_roundtrip() {
        let t = small_table(16);
        for k in 0..500u32 {
            assert!(matches!(
                t.insert(k, k.wrapping_mul(3)).unwrap(),
                InsertOutcome::Inserted | InsertOutcome::Evicted | InsertOutcome::Stashed
            ));
        }
        assert_eq!(t.len(), 500);
        for k in 0..500u32 {
            assert_eq!(t.lookup(k), Some(k.wrapping_mul(3)), "key {k}");
        }
        assert_eq!(t.lookup(10_000), None);
    }

    #[test]
    fn replace_updates_in_place() {
        let t = small_table(16);
        assert_eq!(t.insert(5, 50).unwrap(), InsertOutcome::Inserted);
        assert_eq!(t.insert(5, 51).unwrap(), InsertOutcome::Replaced);
        assert_eq!(t.len(), 1, "replace must not grow the table");
        assert_eq!(t.lookup(5), Some(51));
    }

    #[test]
    fn delete_frees_slots_for_reuse() {
        let t = small_table(4);
        for k in 0..100u32 {
            t.insert(k, k).unwrap();
        }
        for k in 0..100u32 {
            assert!(t.delete(k), "delete {k}");
        }
        assert_eq!(t.len(), 0);
        for k in 0..100u32 {
            assert_eq!(t.lookup(k), None);
        }
        // slots are immediately reusable (paper: "immediate slot reuse")
        for k in 200..300u32 {
            t.insert(k, k).unwrap();
        }
        assert_eq!(t.len(), 100);
    }

    #[test]
    fn rejects_sentinel_key() {
        let t = small_table(4);
        assert!(matches!(t.insert(EMPTY_KEY, 1), Err(HiveError::InvalidKey(_))));
        assert!(matches!(t.insert_if_absent(EMPTY_KEY, 1), Err(HiveError::InvalidKey(_))));
        assert!(matches!(t.fetch_add(EMPTY_KEY, 1), Err(HiveError::InvalidKey(_))));
        assert_eq!(t.lookup(EMPTY_KEY), None);
        assert!(!t.delete(EMPTY_KEY));
        assert_eq!(t.update(EMPTY_KEY, 1), None);
        assert_eq!(t.cas(EMPTY_KEY, 0, 1), (false, None));
    }

    #[test]
    fn upsert_reports_previous_value() {
        let t = small_table(16);
        assert_eq!(t.upsert(9, 90).unwrap(), (InsertOutcome::Inserted, None));
        assert_eq!(t.upsert(9, 91).unwrap(), (InsertOutcome::Replaced, Some(90)));
        assert_eq!(t.len(), 1);
        assert_eq!(t.lookup(9), Some(91));
    }

    #[test]
    fn insert_if_absent_never_overwrites() {
        let t = small_table(16);
        assert_eq!(t.insert_if_absent(3, 30).unwrap(), (Some(InsertOutcome::Inserted), None));
        assert_eq!(t.insert_if_absent(3, 99).unwrap(), (None, Some(30)));
        assert_eq!(t.lookup(3), Some(30), "present key overwritten");
        assert_eq!(t.len(), 1);
    }

    #[test]
    fn update_only_touches_present_keys() {
        let t = small_table(16);
        assert_eq!(t.update(5, 50), None);
        assert_eq!(t.lookup(5), None, "update must not create keys");
        assert_eq!(t.len(), 0);
        t.insert(5, 1).unwrap();
        assert_eq!(t.update(5, 50), Some(1));
        assert_eq!(t.lookup(5), Some(50));
        assert_eq!(t.len(), 1);
    }

    #[test]
    fn cas_applies_iff_expected_matches() {
        let t = small_table(16);
        assert_eq!(t.cas(7, 0, 1), (false, None), "absent key can never match");
        t.insert(7, 10).unwrap();
        assert_eq!(t.cas(7, 11, 12), (false, Some(10)), "mismatch must report actual");
        assert_eq!(t.lookup(7), Some(10));
        assert_eq!(t.cas(7, 10, 12), (true, Some(10)));
        assert_eq!(t.lookup(7), Some(12));
        assert_eq!(t.len(), 1);
    }

    #[test]
    fn fetch_add_creates_then_accumulates() {
        let t = small_table(16);
        assert_eq!(t.fetch_add(4, 5).unwrap(), (Some(InsertOutcome::Inserted), None));
        assert_eq!(t.fetch_add(4, 3).unwrap(), (None, Some(5)));
        assert_eq!(t.lookup(4), Some(8));
        // wrapping semantics
        t.insert(6, u32::MAX).unwrap();
        assert_eq!(t.fetch_add(6, 2).unwrap(), (None, Some(u32::MAX)));
        assert_eq!(t.lookup(6), Some(1));
        assert_eq!(t.len(), 2);
    }

    #[test]
    fn concurrent_fetch_add_is_exact_on_one_counter() {
        let t = Arc::new(small_table(16));
        t.insert(42, 0).unwrap();
        let per = 20_000u32;
        let threads: Vec<_> = (0..8)
            .map(|_| {
                let t = Arc::clone(&t);
                std::thread::spawn(move || {
                    for _ in 0..per {
                        let (outcome, old) = t.fetch_add(42, 1).unwrap();
                        assert!(outcome.is_none(), "seeded counter must never be re-created");
                        assert!(old.is_some());
                    }
                })
            })
            .collect();
        for th in threads {
            th.join().unwrap();
        }
        assert_eq!(t.lookup(42), Some(8 * per), "lost fetch-add updates");
        assert_eq!(t.len(), 1);
    }

    #[test]
    fn rmw_reaches_stash_resident_keys() {
        // Same two-bucket construction as eviction_path_executes: force
        // keys into the stash, then drive every RMW class against them.
        let t =
            HiveTable::new(HiveConfig::default().with_buckets(4).with_max_evictions(8)).unwrap();
        let fam = t.family().clone();
        let keys: Vec<u32> = (1..200_000u32)
            .filter(|&k| {
                let b0 = fam.bucket(0, k, 3, 0);
                let b1 = fam.bucket(1, k, 3, 0);
                b0 <= 1 && b1 <= 1
            })
            .take(66)
            .collect();
        for &k in &keys {
            t.insert(k, k).unwrap();
        }
        assert!(t.stats().stash_pushes > 0, "construction failed to stash anything");
        for &k in &keys {
            assert_eq!(t.update(k, k ^ 1), Some(k), "update lost key {k}");
            assert_eq!(t.cas(k, k ^ 1, k ^ 2), (true, Some(k ^ 1)), "cas lost key {k}");
            assert_eq!(t.fetch_add(k, 1).unwrap(), (None, Some(k ^ 2)), "fetch_add lost {k}");
            assert_eq!(t.lookup(k), Some((k ^ 2).wrapping_add(1)));
        }
        assert_eq!(t.len(), keys.len());
    }

    #[test]
    fn fills_to_high_load_factor() {
        // 8 buckets * 32 slots = 256 capacity; fill to 95%.
        let t = small_table(8);
        let n = (256.0 * 0.95) as u32;
        let mut stashed = 0;
        for k in 1..=n {
            if matches!(t.insert(k, k).unwrap(), InsertOutcome::Stashed) {
                stashed += 1;
            }
        }
        assert_eq!(t.len(), n as usize);
        assert!(t.load_factor() > 0.94, "lf {}", t.load_factor());
        for k in 1..=n {
            assert_eq!(t.lookup(k), Some(k), "key {k} lost at high lf");
        }
        // stash should absorb only a small minority
        assert!(stashed < n / 10, "too many stashed: {stashed}");
    }

    #[test]
    fn eviction_path_executes() {
        let t = HiveTable::new(
            HiveConfig::default().with_buckets(4).with_max_evictions(8),
        )
        .unwrap();
        // Craft keys whose *both* candidate buckets fall in {0, 1}: their
        // combined capacity is 64 slots, so the 66th insert must evict (and
        // eventually stash, since victims re-route within {0, 1}).
        let fam = t.family().clone();
        let keys: Vec<u32> = (1..200_000u32)
            .filter(|&k| {
                let b0 = fam.bucket(0, k, 3, 0);
                let b1 = fam.bucket(1, k, 3, 0);
                b0 <= 1 && b1 <= 1
            })
            .take(66)
            .collect();
        assert_eq!(keys.len(), 66);
        for &k in &keys {
            t.insert(k, k).unwrap();
        }
        let snap = t.stats();
        assert!(
            snap.evict_rounds > 0 || snap.stash_pushes > 0,
            "eviction path never ran: {snap:?}"
        );
        for &k in &keys {
            assert_eq!(t.lookup(k), Some(k));
        }
    }

    #[test]
    fn lock_rate_is_rare_at_moderate_load() {
        // §III-B: the eviction lock is used in <0.85% of cases below ~0.85
        // load factor.
        let t = small_table(64);
        let n = t.capacity() as u32 * 80 / 100;
        for k in 1..=n {
            t.insert(k, k).unwrap();
        }
        for k in 1..=n {
            t.lookup(k);
        }
        let rate = t.stats().lock_rate();
        assert!(rate < 0.0085, "lock rate {rate} exceeds paper bound");
    }

    #[test]
    fn concurrent_inserts_then_lookups() {
        let t = Arc::new(small_table(512));
        let per = 2000u32;
        let threads: Vec<_> = (0..8u32)
            .map(|tid| {
                let t = Arc::clone(&t);
                std::thread::spawn(move || {
                    for i in 0..per {
                        let k = tid * per + i + 1;
                        t.insert(k, k ^ 0xABCD).unwrap();
                    }
                })
            })
            .collect();
        for th in threads {
            th.join().unwrap();
        }
        assert_eq!(t.len(), 8 * per as usize);
        for k in 1..=8 * per {
            assert_eq!(t.lookup(k), Some(k ^ 0xABCD), "key {k}");
        }
    }

    #[test]
    fn concurrent_mixed_workload_is_consistent() {
        // Disjoint key ranges per thread: each thread's view must be
        // perfectly consistent regardless of interleaving.
        let t = Arc::new(small_table(256));
        let threads: Vec<_> = (0..8u32)
            .map(|tid| {
                let t = Arc::clone(&t);
                std::thread::spawn(move || {
                    let base = tid * 10_000 + 1;
                    for i in 0..1000 {
                        let k = base + i;
                        t.insert(k, k).unwrap();
                        assert_eq!(t.lookup(k), Some(k));
                        if i % 3 == 0 {
                            assert!(t.delete(k));
                            assert_eq!(t.lookup(k), None);
                        } else if i % 3 == 1 {
                            t.insert(k, k + 1).unwrap();
                            assert_eq!(t.lookup(k), Some(k + 1));
                        }
                    }
                })
            })
            .collect();
        for th in threads {
            th.join().unwrap();
        }
    }

    #[test]
    fn concurrent_same_key_replaces_converge() {
        let t = Arc::new(small_table(16));
        t.insert(42, 0).unwrap();
        let threads: Vec<_> = (0..8u32)
            .map(|tid| {
                let t = Arc::clone(&t);
                std::thread::spawn(move || {
                    for i in 0..500 {
                        t.insert(42, tid * 1000 + i).unwrap();
                    }
                })
            })
            .collect();
        for th in threads {
            th.join().unwrap();
        }
        // exactly one copy of the key, value is one of the written values
        assert_eq!(t.len(), 1);
        let v = t.lookup(42).unwrap();
        assert!(v < 8000);
        assert!(t.delete(42));
        assert_eq!(t.len(), 0);
    }

    #[test]
    fn three_hash_family_works() {
        let cfg = HiveConfig::default().with_buckets(8).with_hashes(vec![
            HashKind::BitHash1,
            HashKind::BitHash2,
            HashKind::City32,
        ]);
        let t = HiveTable::new(cfg).unwrap();
        for k in 1..=200u32 {
            t.insert(k, k * 7).unwrap();
        }
        for k in 1..=200u32 {
            assert_eq!(t.lookup(k), Some(k * 7));
        }
    }

    #[test]
    fn soa_layout_rejected_by_aos_table() {
        let cfg = HiveConfig::default().with_layout(Layout::SplitSoa);
        assert!(HiveTable::new(cfg).is_err());
    }

    #[test]
    fn round_word_packs_and_unpacks() {
        let r = pack_round(0x3F, 17);
        assert_eq!(unpack_round(r), (0x3F, 17));
        assert_eq!(unpack_round(pack_round(u32::MAX, 0)), (u32::MAX, 0));
    }

    #[test]
    fn no_lock_on_fast_path_smoke() {
        // The op fast paths must never touch the resize mutex: exercising
        // them while the mutex is held would deadlock if they did.
        let t = small_table(16);
        let _held = t.resize_mutex.lock().unwrap();
        t.insert(1, 10).unwrap();
        assert_eq!(t.lookup(1), Some(10));
        assert!(t.delete(1));
    }

    fn compact_table(buckets: usize) -> HiveTable {
        let cfg =
            HiveConfig::default().with_buckets(buckets).with_layout(Layout::CompactQuotient);
        HiveTable::new(cfg).unwrap()
    }

    #[test]
    fn compact_layout_geometry() {
        let t = compact_table(16);
        assert_eq!(t.capacity(), 16 * crate::core::COMPACT_SLOTS_PER_BUCKET);
    }

    #[test]
    fn compact_insert_lookup_delete_roundtrip() {
        let t = compact_table(32);
        for k in 1..=400u32 {
            t.insert(k, k.wrapping_mul(31)).unwrap();
        }
        assert_eq!(t.len(), 400);
        for k in 1..=400u32 {
            assert_eq!(t.lookup(k), Some(k.wrapping_mul(31)), "key {k}");
        }
        assert_eq!(t.lookup(100_000), None);
        for k in 1..=200u32 {
            assert!(t.delete(k), "delete {k}");
        }
        for k in 1..=200u32 {
            assert_eq!(t.lookup(k), None);
        }
        for k in 201..=400u32 {
            assert_eq!(t.lookup(k), Some(k.wrapping_mul(31)));
        }
    }

    #[test]
    fn compact_rmw_ops_work() {
        let t = compact_table(16);
        assert_eq!(t.upsert(9, 90).unwrap(), (InsertOutcome::Inserted, None));
        assert_eq!(t.upsert(9, 91).unwrap(), (InsertOutcome::Replaced, Some(90)));
        assert_eq!(t.update(9, 92), Some(91));
        assert_eq!(t.cas(9, 92, 93), (true, Some(92)));
        assert_eq!(t.fetch_add(9, 7).unwrap(), (None, Some(93)));
        assert_eq!(t.lookup(9), Some(100));
        assert_eq!(t.len(), 1);
    }

    #[test]
    fn compact_entries_returns_logical_keys() {
        let t = compact_table(16);
        for k in 1..=100u32 {
            t.insert(k, k + 5).unwrap();
        }
        let mut got = t.entries();
        got.sort_unstable();
        let want: Vec<(u32, u32)> = (1..=100u32).map(|k| (k, k + 5)).collect();
        assert_eq!(got, want, "entries must decode quotiented halves back to keys");
    }

    #[test]
    fn compact_matches_aos_differentially() {
        // Same deterministic op stream against both layouts; every
        // observable result must agree.
        let aos = small_table(64);
        let cq = compact_table(128); // equal slot capacity (16 vs 32 per bucket)
        let mut x = 0x2545_F491u32;
        for _ in 0..30_000 {
            x ^= x << 13;
            x ^= x >> 17;
            x ^= x << 5;
            let k = x % 1500 + 1;
            match x % 5 {
                0 => {
                    // Placement detail (Inserted/Evicted/Stashed) may differ
                    // across geometries; replaced-vs-new must not.
                    let a = aos.insert(k, x).unwrap() == InsertOutcome::Replaced;
                    let c = cq.insert(k, x).unwrap() == InsertOutcome::Replaced;
                    assert_eq!(a, c, "insert {k}");
                }
                1 => assert_eq!(aos.lookup(k), cq.lookup(k), "lookup {k}"),
                2 => assert_eq!(aos.delete(k), cq.delete(k), "delete {k}"),
                3 => assert_eq!(aos.update(k, x), cq.update(k, x), "update {k}"),
                _ => {
                    let a = aos.fetch_add(k, 3).unwrap();
                    let c = cq.fetch_add(k, 3).unwrap();
                    assert_eq!(a.0.is_some(), c.0.is_some(), "fetch_add created {k}");
                    assert_eq!(a.1, c.1, "fetch_add old value {k}");
                }
            }
        }
        assert_eq!(aos.len(), cq.len());
        for k in 1..=1500u32 {
            assert_eq!(aos.lookup(k), cq.lookup(k), "final state diverged at {k}");
        }
    }

    #[test]
    fn compact_fills_to_high_load_factor() {
        // 32 buckets * 16 slots = 512 capacity; fill to 95%.
        let t = compact_table(32);
        let n = (512.0 * 0.95) as u32;
        for k in 1..=n {
            t.insert(k, k).unwrap();
        }
        assert_eq!(t.len(), n as usize);
        for k in 1..=n {
            assert_eq!(t.lookup(k), Some(k), "key {k} lost at high lf");
        }
    }

    #[test]
    fn compact_concurrent_inserts_then_lookups() {
        let t = Arc::new(compact_table(1024));
        let per = 2000u32;
        let threads: Vec<_> = (0..8u32)
            .map(|tid| {
                let t = Arc::clone(&t);
                std::thread::spawn(move || {
                    for i in 0..per {
                        let k = tid * per + i + 1;
                        t.insert(k, k ^ 0xABCD).unwrap();
                    }
                })
            })
            .collect();
        for th in threads {
            th.join().unwrap();
        }
        assert_eq!(t.len(), 8 * per as usize);
        for k in 1..=8 * per {
            assert_eq!(t.lookup(k), Some(k ^ 0xABCD), "key {k}");
        }
    }

    #[test]
    fn compact_rejects_invalid_configs() {
        // Non-invertible hash kind in the family.
        let cfg = HiveConfig::default()
            .with_layout(Layout::CompactQuotient)
            .with_hashes(vec![HashKind::BitHash1, HashKind::City32]);
        assert!(HiveTable::new(cfg).is_err());
        // Family wider than the 2-bit tag.
        let cfg = HiveConfig::default().with_layout(Layout::CompactQuotient).with_hashes(vec![
            HashKind::BitHash1,
            HashKind::BitHash2,
            HashKind::Murmur3,
            HashKind::Murmur3,
        ]);
        assert!(HiveTable::new(cfg).is_err());
        // Fewer than 4 initial buckets (remainder needs bucket bits spare).
        let cfg = HiveConfig::default().with_layout(Layout::CompactQuotient).with_buckets(2);
        assert!(HiveTable::new(cfg).is_err());
    }
}
