//! Batched operations — the paper's bulk-kernel-launch shape on CPU.
//!
//! The GPU table gets its throughput from *batch-granularity dispatch*:
//! one kernel launch amortizes setup over millions of operations, and the
//! warps inside it overlap each other's memory latency. The per-op CPU
//! path pays the amortizable costs on **every** call — an epoch pin (one
//! striped RMW + one shared load) per op. The batch entry points here
//! restore the kernel-launch shape:
//!
//! 1. **One epoch pin per batch.** The pin is taken once and held across
//!    the whole batch. Incremental migration proceeds *concurrently* with
//!    the batch (only physical reallocation — a rare capacity-class
//!    crossing — waits for the pin to drain at the batch boundary, exactly
//!    like a GPU realloc kernel waits for the previous operation kernel).
//! 2. **Hash-ahead.** Raw hashes for the *entire* batch are computed up
//!    front into a dense table, separating the arithmetic (hashing) phase
//!    from the memory (probing) phase. Only the cheap round reduction
//!    stays per-op, because the round word can advance mid-batch.
//! 3. **Software-pipelined probes.** While op *i* probes, op *i+1*'s
//!    first bucket row is touched (mask word + first slot word), a
//!    prefetch-style hint that overlaps the next op's cache miss with the
//!    current op's compare loop — the CPU analogue of warp-level latency
//!    hiding.
//!
//! Batched and single-op execution share the same `*_core` bodies in
//! [`crate::native::table`], so their observable behaviour is identical;
//! a batch interleaved with concurrent single ops is a legal
//! linearization of both.

use crate::core::error::{HiveError, Result};
use crate::core::packed::EMPTY_KEY;
use crate::core::SLOTS_PER_BUCKET;
use crate::hash::HashFamily;
use crate::native::table::{HiveTable, InsertOutcome, State};
use std::sync::atomic::Ordering;

/// Prefetch-style touch of `bucket`'s metadata + first slot word. A plain
/// relaxed load is enough to pull both lines toward this core before the
/// pipelined probe for the next op lands on them.
#[inline(always)]
fn touch_bucket(state: &State, bucket: u32) {
    let _ = state.masks[bucket as usize].load(Ordering::Relaxed);
    let _ = state.buckets[bucket as usize * SLOTS_PER_BUCKET].load(Ordering::Relaxed);
}

/// Touch the next op's first candidate bucket under the current round.
#[inline(always)]
fn touch_next(state: &State, raw0: u32) {
    let (mask, sp) = state.round();
    touch_bucket(state, HashFamily::address(raw0, mask, sp));
}

impl HiveTable {
    /// Bulk Insert/Replace: one epoch pin, hash-ahead, and pipelined
    /// probes for the whole batch (module docs). Returns one
    /// [`InsertOutcome`] per pair, in submission order.
    ///
    /// Errors (without mutating the table) if any key is the reserved
    /// EMPTY sentinel — the batch analogue of the single-op
    /// `InvalidKey` check.
    pub fn insert_batch(&self, pairs: &[(u32, u32)]) -> Result<Vec<InsertOutcome>> {
        if let Some(&(bad, _)) = pairs.iter().find(|&&(k, _)| k == EMPTY_KEY) {
            return Err(HiveError::InvalidKey(bad));
        }
        let guard = self.epoch.pin();
        let state = self.state_ref(&guard);
        let raws: Vec<[u32; 4]> = pairs.iter().map(|&(k, _)| self.raw_hashes(k)).collect();
        let mut out = Vec::with_capacity(pairs.len());
        for (i, &(key, value)) in pairs.iter().enumerate() {
            if i + 1 < pairs.len() {
                touch_next(state, raws[i + 1][0]);
            }
            let outcome = self.insert_core(state, key, value, &raws[i])?;
            self.record_insert_outcome(outcome);
            out.push(outcome);
        }
        Ok(out)
    }

    /// Bulk Search: one `Option<u32>` per key, in submission order. Keys
    /// equal to the EMPTY sentinel yield `None`, as in the single-op path.
    pub fn lookup_batch(&self, keys: &[u32]) -> Vec<Option<u32>> {
        let guard = self.epoch.pin();
        let state = self.state_ref(&guard);
        let raws: Vec<[u32; 4]> = keys.iter().map(|&k| self.raw_hashes(k)).collect();
        let mut out = Vec::with_capacity(keys.len());
        for (i, &key) in keys.iter().enumerate() {
            if i + 1 < keys.len() {
                touch_next(state, raws[i + 1][0]);
            }
            out.push(if key == EMPTY_KEY {
                None
            } else {
                self.lookup_core(state, key, &raws[i])
            });
        }
        out
    }

    /// Bulk Delete: one hit flag per key, in submission order. Keys equal
    /// to the EMPTY sentinel yield `false`, as in the single-op path.
    pub fn delete_batch(&self, keys: &[u32]) -> Vec<bool> {
        let guard = self.epoch.pin();
        let state = self.state_ref(&guard);
        let raws: Vec<[u32; 4]> = keys.iter().map(|&k| self.raw_hashes(k)).collect();
        let mut out = Vec::with_capacity(keys.len());
        for (i, &key) in keys.iter().enumerate() {
            if i + 1 < keys.len() {
                touch_next(state, raws[i + 1][0]);
            }
            out.push(key != EMPTY_KEY && self.delete_core(state, key, &raws[i]));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use crate::core::config::HiveConfig;
    use crate::core::packed::EMPTY_KEY;
    use crate::native::table::{HiveTable, InsertOutcome};

    fn table(buckets: usize) -> HiveTable {
        HiveTable::new(HiveConfig::default().with_buckets(buckets)).unwrap()
    }

    #[test]
    fn batch_roundtrip() {
        let t = table(64);
        let pairs: Vec<(u32, u32)> = (1..=1000u32).map(|k| (k, k * 3)).collect();
        let outcomes = t.insert_batch(&pairs).unwrap();
        assert_eq!(outcomes.len(), 1000);
        assert!(outcomes.iter().all(|o| *o != InsertOutcome::Replaced));
        assert_eq!(t.len(), 1000);
        let keys: Vec<u32> = pairs.iter().map(|&(k, _)| k).collect();
        let vals = t.lookup_batch(&keys);
        for (i, v) in vals.iter().enumerate() {
            assert_eq!(*v, Some((i as u32 + 1) * 3), "key {}", i + 1);
        }
        let hits = t.delete_batch(&keys[..500]);
        assert!(hits.iter().all(|&h| h));
        assert_eq!(t.len(), 500);
        let vals = t.lookup_batch(&keys);
        assert!(vals[..500].iter().all(Option::is_none));
        assert!(vals[500..].iter().all(Option::is_some));
    }

    #[test]
    fn batch_replace_reports_replaced() {
        let t = table(16);
        t.insert_batch(&[(7, 70), (8, 80)]).unwrap();
        let outcomes = t.insert_batch(&[(7, 71), (9, 90)]).unwrap();
        assert_eq!(outcomes[0], InsertOutcome::Replaced);
        assert_ne!(outcomes[1], InsertOutcome::Replaced);
        assert_eq!(t.len(), 3);
        assert_eq!(t.lookup(7), Some(71));
    }

    #[test]
    fn empty_batches_are_noops() {
        let t = table(4);
        assert!(t.insert_batch(&[]).unwrap().is_empty());
        assert!(t.lookup_batch(&[]).is_empty());
        assert!(t.delete_batch(&[]).is_empty());
        assert_eq!(t.len(), 0);
    }

    #[test]
    fn sentinel_key_handling() {
        let t = table(4);
        assert!(t.insert_batch(&[(1, 1), (EMPTY_KEY, 2)]).is_err());
        // the failed batch must not have mutated the table
        assert_eq!(t.len(), 0);
        assert_eq!(t.lookup_batch(&[EMPTY_KEY, 1]), vec![None, None]);
        assert_eq!(t.delete_batch(&[EMPTY_KEY]), vec![false]);
    }
}
