//! Batched operations — the paper's bulk-kernel-launch shape on CPU.
//!
//! The GPU table gets its throughput from *batch-granularity dispatch*:
//! one kernel launch amortizes setup over millions of operations, and the
//! warps inside it overlap each other's memory latency. The per-op CPU
//! path pays the amortizable costs on **every** call — an epoch pin (one
//! striped RMW + one shared load) per op. The batch entry points here
//! restore the kernel-launch shape:
//!
//! 1. **One epoch pin per batch.** The pin is taken once and held across
//!    the whole batch. Incremental migration proceeds *concurrently* with
//!    the batch (only physical reallocation — a rare capacity-class
//!    crossing — waits for the pin to drain at the batch boundary, exactly
//!    like a GPU realloc kernel waits for the previous operation kernel).
//! 2. **Hash-ahead.** Raw hashes for the *entire* batch are computed up
//!    front into a dense table, separating the arithmetic (hashing) phase
//!    from the memory (probing) phase. Only the cheap round reduction
//!    stays per-op, because the round word can advance mid-batch.
//! 3. **AMAC-style interleaved probes.** G probe "state machines" are
//!    kept in flight per thread (G = [`HiveConfig::batch_interleave`],
//!    default 8, env-tunable via `HIVE_BATCH_INTERLEAVE`): before op *i*
//!    executes, op *i+G*'s first bucket line is prefetched through the
//!    shared [`crate::native::prefetch`] helper (a real
//!    `_mm_prefetch`/`prfm` where the target has one, a read touch
//!    otherwise). By the time the probe for op *i+G* runs, its miss has
//!    had G ops' worth of execution to resolve — the batch overlaps G
//!    cache misses where the old 1-deep pipeline overlapped one. This is
//!    the CPU analogue of warp-level latency hiding (group/AMAC
//!    prefetching from the in-memory-join literature); the GPU hides the
//!    same latency with warp oversubscription.
//!
//! Batched and single-op execution share the same `*_core` bodies in
//! [`crate::native::table`], so their observable behaviour is identical;
//! a batch interleaved with concurrent single ops is a legal
//! linearization of both. The interleave depth changes *when* a probe's
//! lines arrive, never what the probe does — the depth-{1,4,8} oracle in
//! `tests/test_probe_engine.rs` pins that.
//!
//! Every class of the typed operation plane has an interleaved bulk
//! entry point here (`upsert_batch`, `insert_if_absent_batch`,
//! `update_batch`, `cas_batch`, `fetch_add_batch`, `lookup_batch`,
//! `delete_batch`), and [`HiveTable::execute_ops`] runs a heterogeneous
//! [`Op`] window through them, returning typed [`OpResult`]s in
//! submission order — the engine behind `NativeBackend::execute` and the
//! `ConcurrentMap` batch plane.
//!
//! [`HiveConfig::batch_interleave`]: crate::core::config::HiveConfig::batch_interleave

use crate::backend::group_ops;
use crate::core::error::{HiveError, Result};
use crate::core::packed::EMPTY_KEY;
use crate::native::prefetch;
use crate::native::table::{HiveTable, InsertOutcome, RmwInsert, State};
use crate::workload::{Op, OpResult};

impl HiveTable {
    /// AMAC-style interleaved executor shared by every bulk class: prime
    /// the first `min(G, len)` ops' bucket lines, then keep the prefetch
    /// horizon G ops ahead of execution. `exec(i)` runs op *i* against
    /// the already-pinned `state`; `raws` is the hash-ahead table (one
    /// entry per op — its length is the batch length).
    ///
    /// Exactly one line hint is issued per op (prime fills the first G,
    /// the loop covers the rest), recorded once per batch on the
    /// `prefetches` counter.
    fn run_interleaved<R>(
        &self,
        state: &State,
        raws: &[[u32; 4]],
        mut exec: impl FnMut(usize) -> R,
    ) -> Vec<R> {
        let len = raws.len();
        let g = self.config().batch_interleave.max(1);
        for r in raws.iter().take(g.min(len)) {
            prefetch::prefetch_candidate(state, r[0]);
        }
        self.stats.record_prefetches(len as u64);
        let mut out = Vec::with_capacity(len);
        for i in 0..len {
            if i + g < len {
                prefetch::prefetch_candidate(state, raws[i + g][0]);
            }
            out.push(exec(i));
        }
        out
    }

    /// [`HiveTable::run_interleaved`] for fallible classes: stops at the
    /// first error like the per-op loop it replaced (ops before the
    /// error have executed; the error propagates). In practice the
    /// inserting cores only error on sentinel keys, which every caller
    /// rejects before starting the batch.
    fn try_run_interleaved<R>(
        &self,
        state: &State,
        raws: &[[u32; 4]],
        mut exec: impl FnMut(usize) -> Result<R>,
    ) -> Result<Vec<R>> {
        let len = raws.len();
        let g = self.config().batch_interleave.max(1);
        for r in raws.iter().take(g.min(len)) {
            prefetch::prefetch_candidate(state, r[0]);
        }
        self.stats.record_prefetches(len as u64);
        let mut out = Vec::with_capacity(len);
        for i in 0..len {
            if i + g < len {
                prefetch::prefetch_candidate(state, raws[i + g][0]);
            }
            out.push(exec(i)?);
        }
        Ok(out)
    }

    /// Bulk Insert/Replace: one epoch pin, hash-ahead, and G-deep
    /// interleaved probes for the whole batch (module docs). Returns one
    /// [`InsertOutcome`] per pair, in submission order. Alias of
    /// [`HiveTable::upsert_batch`] that discards the previous values.
    ///
    /// Errors (without mutating the table) if any key is the reserved
    /// EMPTY sentinel — the batch analogue of the single-op
    /// `InvalidKey` check.
    pub fn insert_batch(&self, pairs: &[(u32, u32)]) -> Result<Vec<InsertOutcome>> {
        Ok(self.upsert_batch(pairs)?.into_iter().map(|(outcome, _)| outcome).collect())
    }

    /// Bulk Upsert: like [`HiveTable::insert_batch`] but each entry also
    /// carries the value it replaced (`None` ⇒ fresh key).
    pub fn upsert_batch(&self, pairs: &[(u32, u32)]) -> Result<Vec<(InsertOutcome, Option<u32>)>> {
        if let Some(&(bad, _)) = pairs.iter().find(|&&(k, _)| k == EMPTY_KEY) {
            return Err(HiveError::InvalidKey(bad));
        }
        let guard = self.epoch.pin();
        let state = self.state_ref(&guard);
        let raws: Vec<[u32; 4]> = pairs.iter().map(|&(k, _)| self.raw_hashes(k)).collect();
        self.try_run_interleaved(state, &raws, |i| {
            let (key, value) = pairs[i];
            let (outcome, old) = self.upsert_core(state, key, value, &raws[i])?;
            self.record_insert_outcome(outcome);
            Ok((outcome, old))
        })
    }

    /// Bulk insert-if-absent (hash-ahead, one pin, G-deep interleave).
    /// One [`RmwInsert`] per pair, in submission order. Sentinel keys
    /// error pre-mutation like `insert_batch`.
    pub fn insert_if_absent_batch(&self, pairs: &[(u32, u32)]) -> Result<Vec<RmwInsert>> {
        if let Some(&(bad, _)) = pairs.iter().find(|&&(k, _)| k == EMPTY_KEY) {
            return Err(HiveError::InvalidKey(bad));
        }
        let guard = self.epoch.pin();
        let state = self.state_ref(&guard);
        let raws: Vec<[u32; 4]> = pairs.iter().map(|&(k, _)| self.raw_hashes(k)).collect();
        self.try_run_interleaved(state, &raws, |i| {
            let (key, value) = pairs[i];
            self.insert_if_absent_core(state, key, value, &raws[i])
        })
    }

    /// Bulk update (write-if-present): one previous value per pair, in
    /// submission order. Sentinel keys yield `None` like the single-op
    /// path.
    pub fn update_batch(&self, pairs: &[(u32, u32)]) -> Vec<Option<u32>> {
        let guard = self.epoch.pin();
        let state = self.state_ref(&guard);
        let raws: Vec<[u32; 4]> = pairs.iter().map(|&(k, _)| self.raw_hashes(k)).collect();
        self.run_interleaved(state, &raws, |i| {
            let (key, value) = pairs[i];
            if key == EMPTY_KEY {
                None
            } else {
                self.update_core(state, key, value, &raws[i])
            }
        })
    }

    /// Bulk compare-and-swap over `(key, expected, new)` triples: one
    /// `(ok, actual)` per triple, in submission order. Sentinel keys
    /// yield `(false, None)`.
    pub fn cas_batch(&self, items: &[(u32, u32, u32)]) -> Vec<(bool, Option<u32>)> {
        let guard = self.epoch.pin();
        let state = self.state_ref(&guard);
        let raws: Vec<[u32; 4]> = items.iter().map(|&(k, _, _)| self.raw_hashes(k)).collect();
        self.run_interleaved(state, &raws, |i| {
            let (key, expected, new) = items[i];
            if key == EMPTY_KEY {
                (false, None)
            } else {
                self.cas_core(state, key, expected, new, &raws[i])
            }
        })
    }

    /// Bulk fetch-add over `(key, delta)` pairs: one [`RmwInsert`] per
    /// pair, in submission order. Sentinel keys error pre-mutation.
    pub fn fetch_add_batch(&self, pairs: &[(u32, u32)]) -> Result<Vec<RmwInsert>> {
        if let Some(&(bad, _)) = pairs.iter().find(|&&(k, _)| k == EMPTY_KEY) {
            return Err(HiveError::InvalidKey(bad));
        }
        let guard = self.epoch.pin();
        let state = self.state_ref(&guard);
        let raws: Vec<[u32; 4]> = pairs.iter().map(|&(k, _)| self.raw_hashes(k)).collect();
        self.try_run_interleaved(state, &raws, |i| {
            let (key, delta) = pairs[i];
            self.fetch_add_core(state, key, delta, &raws[i])
        })
    }

    /// Execute a heterogeneous window of [`Op`]s through the per-class
    /// bulk paths, returning one typed [`OpResult`] per op **in
    /// submission order**. Classes execute grouped (upserts →
    /// insert-if-absents → updates → CAS → fetch-adds → deletes →
    /// lookups — see `backend::group_ops`); ops in one window are
    /// concurrent, so the grouping is a legal linearization. Inserting
    /// classes (`Insert`/`Upsert`/`InsertIfAbsent`/`FetchAdd`) validate
    /// their keys up front — a sentinel key errors the whole window
    /// before any mutation. Every class batch runs the G-deep
    /// interleaved scheduler.
    pub fn execute_ops(&self, ops: &[Op]) -> Result<Vec<OpResult>> {
        crate::backend::validate_insert_keys(ops)?;
        let g = group_ops(ops);
        let mut out: Vec<Option<OpResult>> = vec![None; ops.len()];
        if !g.upserts.is_empty() {
            let pairs: Vec<(u32, u32)> = g.upserts.iter().map(|&(_, k, v)| (k, v)).collect();
            for (&(i, _, _), (outcome, old)) in g.upserts.iter().zip(self.upsert_batch(&pairs)?) {
                out[i] = Some(OpResult::Upserted { outcome, old });
            }
        }
        if !g.if_absents.is_empty() {
            let pairs: Vec<(u32, u32)> = g.if_absents.iter().map(|&(_, k, v)| (k, v)).collect();
            let res = self.insert_if_absent_batch(&pairs)?;
            for (&(i, _, _), (outcome, existing)) in g.if_absents.iter().zip(res) {
                out[i] = Some(OpResult::InsertedIfAbsent { outcome, existing });
            }
        }
        if !g.updates.is_empty() {
            let pairs: Vec<(u32, u32)> = g.updates.iter().map(|&(_, k, v)| (k, v)).collect();
            for (&(i, _, _), old) in g.updates.iter().zip(self.update_batch(&pairs)) {
                out[i] = Some(OpResult::Updated { old });
            }
        }
        if !g.cas.is_empty() {
            let items: Vec<(u32, u32, u32)> =
                g.cas.iter().map(|&(_, k, e, n)| (k, e, n)).collect();
            for (&(i, _, _, _), (ok, actual)) in g.cas.iter().zip(self.cas_batch(&items)) {
                out[i] = Some(OpResult::Cas { ok, actual });
            }
        }
        if !g.fetch_adds.is_empty() {
            let pairs: Vec<(u32, u32)> = g.fetch_adds.iter().map(|&(_, k, d)| (k, d)).collect();
            let res = self.fetch_add_batch(&pairs)?;
            for (&(i, _, _), (outcome, old)) in g.fetch_adds.iter().zip(res) {
                out[i] = Some(OpResult::FetchAdded { outcome, old });
            }
        }
        if !g.deletes.is_empty() {
            let keys: Vec<u32> = g.deletes.iter().map(|&(_, k)| k).collect();
            for (&(i, _), hit) in g.deletes.iter().zip(self.delete_batch(&keys)) {
                out[i] = Some(OpResult::Deleted(hit));
            }
        }
        if !g.lookups.is_empty() {
            let keys: Vec<u32> = g.lookups.iter().map(|&(_, k)| k).collect();
            for (&(i, _), v) in g.lookups.iter().zip(self.lookup_batch(&keys)) {
                out[i] = Some(OpResult::Value(v));
            }
        }
        Ok(out.into_iter().map(|r| r.expect("every op yields exactly one result")).collect())
    }

    /// Bulk Search: one `Option<u32>` per key, in submission order. Keys
    /// equal to the EMPTY sentinel yield `None`, as in the single-op path.
    pub fn lookup_batch(&self, keys: &[u32]) -> Vec<Option<u32>> {
        let guard = self.epoch.pin();
        let state = self.state_ref(&guard);
        let raws: Vec<[u32; 4]> = keys.iter().map(|&k| self.raw_hashes(k)).collect();
        self.run_interleaved(state, &raws, |i| {
            let key = keys[i];
            if key == EMPTY_KEY {
                None
            } else {
                self.lookup_core(state, key, &raws[i])
            }
        })
    }

    /// Bulk Delete: one hit flag per key, in submission order. Keys equal
    /// to the EMPTY sentinel yield `false`, as in the single-op path.
    pub fn delete_batch(&self, keys: &[u32]) -> Vec<bool> {
        let guard = self.epoch.pin();
        let state = self.state_ref(&guard);
        let raws: Vec<[u32; 4]> = keys.iter().map(|&k| self.raw_hashes(k)).collect();
        self.run_interleaved(state, &raws, |i| {
            let key = keys[i];
            key != EMPTY_KEY && self.delete_core(state, key, &raws[i])
        })
    }
}

#[cfg(test)]
mod tests {
    use crate::core::config::HiveConfig;
    use crate::core::packed::EMPTY_KEY;
    use crate::native::table::{HiveTable, InsertOutcome};

    fn table(buckets: usize) -> HiveTable {
        HiveTable::new(HiveConfig::default().with_buckets(buckets)).unwrap()
    }

    #[test]
    fn batch_roundtrip() {
        let t = table(64);
        let pairs: Vec<(u32, u32)> = (1..=1000u32).map(|k| (k, k * 3)).collect();
        let outcomes = t.insert_batch(&pairs).unwrap();
        assert_eq!(outcomes.len(), 1000);
        assert!(outcomes.iter().all(|o| *o != InsertOutcome::Replaced));
        assert_eq!(t.len(), 1000);
        let keys: Vec<u32> = pairs.iter().map(|&(k, _)| k).collect();
        let vals = t.lookup_batch(&keys);
        for (i, v) in vals.iter().enumerate() {
            assert_eq!(*v, Some((i as u32 + 1) * 3), "key {}", i + 1);
        }
        let hits = t.delete_batch(&keys[..500]);
        assert!(hits.iter().all(|&h| h));
        assert_eq!(t.len(), 500);
        let vals = t.lookup_batch(&keys);
        assert!(vals[..500].iter().all(Option::is_none));
        assert!(vals[500..].iter().all(Option::is_some));
    }

    #[test]
    fn batch_replace_reports_replaced() {
        let t = table(16);
        t.insert_batch(&[(7, 70), (8, 80)]).unwrap();
        let outcomes = t.insert_batch(&[(7, 71), (9, 90)]).unwrap();
        assert_eq!(outcomes[0], InsertOutcome::Replaced);
        assert_ne!(outcomes[1], InsertOutcome::Replaced);
        assert_eq!(t.len(), 3);
        assert_eq!(t.lookup(7), Some(71));
    }

    #[test]
    fn empty_batches_are_noops() {
        let t = table(4);
        assert!(t.insert_batch(&[]).unwrap().is_empty());
        assert!(t.lookup_batch(&[]).is_empty());
        assert!(t.delete_batch(&[]).is_empty());
        assert_eq!(t.len(), 0);
    }

    #[test]
    fn sentinel_key_handling() {
        let t = table(4);
        assert!(t.insert_batch(&[(1, 1), (EMPTY_KEY, 2)]).is_err());
        assert!(t.insert_if_absent_batch(&[(1, 1), (EMPTY_KEY, 2)]).is_err());
        assert!(t.fetch_add_batch(&[(1, 1), (EMPTY_KEY, 2)]).is_err());
        // the failed batches must not have mutated the table
        assert_eq!(t.len(), 0);
        assert_eq!(t.lookup_batch(&[EMPTY_KEY, 1]), vec![None, None]);
        assert_eq!(t.delete_batch(&[EMPTY_KEY]), vec![false]);
        assert_eq!(t.update_batch(&[(EMPTY_KEY, 9)]), vec![None]);
        assert_eq!(t.cas_batch(&[(EMPTY_KEY, 0, 9)]), vec![(false, None)]);
    }

    #[test]
    fn rmw_batches_match_single_op_semantics() {
        use crate::native::table::RmwInsert;
        let t = table(64);
        t.insert_batch(&[(1, 10), (2, 20)]).unwrap();
        let ups = t.upsert_batch(&[(1, 11), (3, 30)]).unwrap();
        assert_eq!(ups[0], (InsertOutcome::Replaced, Some(10)));
        assert_eq!(ups[1].1, None, "fresh key must have no previous value");
        let ifa: Vec<RmwInsert> = t.insert_if_absent_batch(&[(2, 99), (4, 40)]).unwrap();
        assert_eq!(ifa[0], (None, Some(20)));
        assert!(ifa[1].0.is_some() && ifa[1].1.is_none());
        assert_eq!(t.update_batch(&[(2, 21), (5, 50)]), vec![Some(20), None]);
        assert_eq!(t.lookup(5), None, "update_batch created a key");
        assert_eq!(
            t.cas_batch(&[(2, 21, 22), (2, 99, 0), (5, 0, 1)]),
            vec![(true, Some(21)), (false, Some(22)), (false, None)]
        );
        let fa = t.fetch_add_batch(&[(2, 8), (6, 60)]).unwrap();
        assert_eq!(fa[0], (None, Some(22)));
        assert!(fa[1].0.is_some() && fa[1].1.is_none());
        assert_eq!(t.lookup(2), Some(30));
        assert_eq!(t.lookup(6), Some(60));
        assert_eq!(t.len(), 5); // keys 1,2,3,4,6
    }

    fn table_with_depth(buckets: usize, g: usize) -> HiveTable {
        HiveTable::new(HiveConfig::default().with_buckets(buckets).with_interleave(g)).unwrap()
    }

    #[test]
    fn interleave_depth_is_observationally_invisible() {
        // Same stream, depths 1 / 3 / 8: identical results and final
        // state — the scheduler only changes when lines are prefetched.
        let streams: Vec<Vec<(u32, u32)>> = vec![
            (1..=300u32).map(|k| (k * 7, k)).collect(),
            (1..=300u32).map(|k| (k * 7, k + 1)).collect(),
        ];
        let reference = table(32);
        let tables: Vec<HiveTable> =
            [1usize, 3, 8].iter().map(|&g| table_with_depth(32, g)).collect();
        for s in &streams {
            let want = reference.upsert_batch(s).unwrap();
            for t in &tables {
                assert_eq!(t.upsert_batch(s).unwrap(), want);
            }
        }
        let keys: Vec<u32> = streams[0].iter().map(|&(k, _)| k).collect();
        let want = reference.lookup_batch(&keys);
        for t in &tables {
            assert_eq!(t.lookup_batch(&keys), want);
        }
    }

    #[test]
    fn prefetch_counter_counts_one_hint_per_op() {
        let t = table(16);
        let pairs: Vec<(u32, u32)> = (1..=100u32).map(|k| (k, k)).collect();
        t.insert_batch(&pairs).unwrap();
        let before = t.stats().prefetches;
        let keys: Vec<u32> = pairs.iter().map(|&(k, _)| k).collect();
        t.lookup_batch(&keys);
        assert_eq!(t.stats().prefetches - before, 100, "one line hint per batched op");
        // per-op paths issue none
        let before = t.stats().prefetches;
        t.lookup(7);
        assert_eq!(t.stats().prefetches, before);
    }

    #[test]
    fn execute_ops_returns_typed_results_in_submission_order() {
        use crate::workload::{Op, OpResult};
        let t = table(64);
        let ops = vec![
            Op::Lookup { key: 1 },
            Op::Upsert { key: 1, value: 10 },
            Op::FetchAdd { key: 2, delta: 5 },
            Op::Delete { key: 3 },
            Op::Insert { key: 3, value: 30 },
            Op::Cas { key: 2, expected: 5, new: 6 },
            Op::Update { key: 9, value: 90 },
            Op::InsertIfAbsent { key: 1, value: 99 },
        ];
        let res = t.execute_ops(&ops).unwrap();
        assert_eq!(res.len(), ops.len());
        // grouped linearization (upserts → if-absents → updates → cas →
        // fetch-adds → deletes → lookups): writes land before the
        // window's lookups, deletes after the window's inserts
        assert_eq!(res[0], OpResult::Value(Some(10)));
        assert!(matches!(res[1], OpResult::Upserted { old: None, .. }));
        assert!(matches!(res[2], OpResult::FetchAdded { old: None, .. }));
        assert_eq!(res[3], OpResult::Deleted(true), "delete groups after the insert of key 3");
        assert!(matches!(res[4], OpResult::Upserted { old: None, .. }));
        // CAS groups *before* fetch-add in the class order: key 2 absent
        assert_eq!(res[5], OpResult::Cas { ok: false, actual: None });
        assert_eq!(res[6], OpResult::Updated { old: None });
        assert_eq!(res[7], OpResult::InsertedIfAbsent { outcome: None, existing: Some(10) });
        assert_eq!(t.lookup(2), Some(5));
        assert_eq!(t.lookup(3), None, "insert-then-delete window must end absent");
        // sentinel in an inserting class fails the window pre-mutation
        let t2 = table(4);
        assert!(t2
            .execute_ops(&[Op::Lookup { key: 1 }, Op::FetchAdd { key: EMPTY_KEY, delta: 1 }])
            .is_err());
        assert_eq!(t2.len(), 0);
    }
}
