//! Batched operations — the paper's bulk-kernel-launch shape on CPU.
//!
//! The GPU table gets its throughput from *batch-granularity dispatch*:
//! one kernel launch amortizes setup over millions of operations, and the
//! warps inside it overlap each other's memory latency. The per-op CPU
//! path pays the amortizable costs on **every** call — an epoch pin (one
//! striped RMW + one shared load) per op. The batch entry points here
//! restore the kernel-launch shape:
//!
//! 1. **One epoch pin per batch.** The pin is taken once and held across
//!    the whole batch. Incremental migration proceeds *concurrently* with
//!    the batch (only physical reallocation — a rare capacity-class
//!    crossing — waits for the pin to drain at the batch boundary, exactly
//!    like a GPU realloc kernel waits for the previous operation kernel).
//! 2. **Hash-ahead.** Raw hashes for the *entire* batch are computed up
//!    front into a dense table, separating the arithmetic (hashing) phase
//!    from the memory (probing) phase. Only the cheap round reduction
//!    stays per-op, because the round word can advance mid-batch.
//! 3. **Software-pipelined probes.** While op *i* probes, op *i+1*'s
//!    first bucket row is touched (mask word + first slot word), a
//!    prefetch-style hint that overlaps the next op's cache miss with the
//!    current op's compare loop — the CPU analogue of warp-level latency
//!    hiding.
//!
//! Batched and single-op execution share the same `*_core` bodies in
//! [`crate::native::table`], so their observable behaviour is identical;
//! a batch interleaved with concurrent single ops is a legal
//! linearization of both.
//!
//! Every class of the typed operation plane has a hash-ahead bulk entry
//! point here (`upsert_batch`, `insert_if_absent_batch`, `update_batch`,
//! `cas_batch`, `fetch_add_batch`), and [`HiveTable::execute_ops`] runs
//! a heterogeneous [`Op`] window through them, returning typed
//! [`OpResult`]s in submission order — the engine behind
//! `NativeBackend::execute` and the `ConcurrentMap` batch plane.

use crate::backend::group_ops;
use crate::core::error::{HiveError, Result};
use crate::core::config::Layout;
use crate::core::packed::EMPTY_KEY;
use crate::hash::HashFamily;
use crate::native::table::{HiveTable, InsertOutcome, RmwInsert, State};
use crate::workload::{Op, OpResult};
use std::sync::atomic::Ordering;

/// Prefetch-style touch of `bucket`'s first slot word (and, for the
/// two-line packed layout, its metadata word). A plain relaxed load is
/// enough to pull the line toward this core before the pipelined probe
/// for the next op lands on it.
///
/// Under [`Layout::CompactQuotient`] a 16-slot bucket row is one
/// 128-byte line, so touching the slot word alone covers the probe's
/// whole footprint — skipping the mask-word load halves the hash-ahead
/// traffic. (Mask words pack many buckets per line and stay hot in L1
/// across a batch regardless, so the wide layouts keep the extra touch
/// only because their slot rows genuinely span a second line.)
#[inline(always)]
fn touch_bucket(state: &State, bucket: u32) {
    if state.layout != Layout::CompactQuotient {
        let _ = state.masks[bucket as usize].load(Ordering::Relaxed);
    }
    let _ = state.buckets[bucket as usize * state.spb].load(Ordering::Relaxed);
}

/// Touch the next op's first candidate bucket under the current round.
#[inline(always)]
fn touch_next(state: &State, raw0: u32) {
    let (mask, sp) = state.round();
    touch_bucket(state, HashFamily::address(raw0, mask, sp));
}

impl HiveTable {
    /// Bulk Insert/Replace: one epoch pin, hash-ahead, and pipelined
    /// probes for the whole batch (module docs). Returns one
    /// [`InsertOutcome`] per pair, in submission order. Alias of
    /// [`HiveTable::upsert_batch`] that discards the previous values.
    ///
    /// Errors (without mutating the table) if any key is the reserved
    /// EMPTY sentinel — the batch analogue of the single-op
    /// `InvalidKey` check.
    pub fn insert_batch(&self, pairs: &[(u32, u32)]) -> Result<Vec<InsertOutcome>> {
        Ok(self.upsert_batch(pairs)?.into_iter().map(|(outcome, _)| outcome).collect())
    }

    /// Bulk Upsert: like [`HiveTable::insert_batch`] but each entry also
    /// carries the value it replaced (`None` ⇒ fresh key).
    pub fn upsert_batch(&self, pairs: &[(u32, u32)]) -> Result<Vec<(InsertOutcome, Option<u32>)>> {
        if let Some(&(bad, _)) = pairs.iter().find(|&&(k, _)| k == EMPTY_KEY) {
            return Err(HiveError::InvalidKey(bad));
        }
        let guard = self.epoch.pin();
        let state = self.state_ref(&guard);
        let raws: Vec<[u32; 4]> = pairs.iter().map(|&(k, _)| self.raw_hashes(k)).collect();
        let mut out = Vec::with_capacity(pairs.len());
        for (i, &(key, value)) in pairs.iter().enumerate() {
            if i + 1 < pairs.len() {
                touch_next(state, raws[i + 1][0]);
            }
            let (outcome, old) = self.upsert_core(state, key, value, &raws[i])?;
            self.record_insert_outcome(outcome);
            out.push((outcome, old));
        }
        Ok(out)
    }

    /// Bulk insert-if-absent (hash-ahead, one pin). One [`RmwInsert`]
    /// per pair, in submission order. Sentinel keys error pre-mutation
    /// like `insert_batch`.
    pub fn insert_if_absent_batch(&self, pairs: &[(u32, u32)]) -> Result<Vec<RmwInsert>> {
        if let Some(&(bad, _)) = pairs.iter().find(|&&(k, _)| k == EMPTY_KEY) {
            return Err(HiveError::InvalidKey(bad));
        }
        let guard = self.epoch.pin();
        let state = self.state_ref(&guard);
        let raws: Vec<[u32; 4]> = pairs.iter().map(|&(k, _)| self.raw_hashes(k)).collect();
        let mut out = Vec::with_capacity(pairs.len());
        for (i, &(key, value)) in pairs.iter().enumerate() {
            if i + 1 < pairs.len() {
                touch_next(state, raws[i + 1][0]);
            }
            out.push(self.insert_if_absent_core(state, key, value, &raws[i])?);
        }
        Ok(out)
    }

    /// Bulk update (write-if-present): one previous value per pair, in
    /// submission order. Sentinel keys yield `None` like the single-op
    /// path.
    pub fn update_batch(&self, pairs: &[(u32, u32)]) -> Vec<Option<u32>> {
        let guard = self.epoch.pin();
        let state = self.state_ref(&guard);
        let raws: Vec<[u32; 4]> = pairs.iter().map(|&(k, _)| self.raw_hashes(k)).collect();
        let mut out = Vec::with_capacity(pairs.len());
        for (i, &(key, value)) in pairs.iter().enumerate() {
            if i + 1 < pairs.len() {
                touch_next(state, raws[i + 1][0]);
            }
            out.push(if key == EMPTY_KEY {
                None
            } else {
                self.update_core(state, key, value, &raws[i])
            });
        }
        out
    }

    /// Bulk compare-and-swap over `(key, expected, new)` triples: one
    /// `(ok, actual)` per triple, in submission order. Sentinel keys
    /// yield `(false, None)`.
    pub fn cas_batch(&self, items: &[(u32, u32, u32)]) -> Vec<(bool, Option<u32>)> {
        let guard = self.epoch.pin();
        let state = self.state_ref(&guard);
        let raws: Vec<[u32; 4]> = items.iter().map(|&(k, _, _)| self.raw_hashes(k)).collect();
        let mut out = Vec::with_capacity(items.len());
        for (i, &(key, expected, new)) in items.iter().enumerate() {
            if i + 1 < items.len() {
                touch_next(state, raws[i + 1][0]);
            }
            out.push(if key == EMPTY_KEY {
                (false, None)
            } else {
                self.cas_core(state, key, expected, new, &raws[i])
            });
        }
        out
    }

    /// Bulk fetch-add over `(key, delta)` pairs: one [`RmwInsert`] per
    /// pair, in submission order. Sentinel keys error pre-mutation.
    pub fn fetch_add_batch(&self, pairs: &[(u32, u32)]) -> Result<Vec<RmwInsert>> {
        if let Some(&(bad, _)) = pairs.iter().find(|&&(k, _)| k == EMPTY_KEY) {
            return Err(HiveError::InvalidKey(bad));
        }
        let guard = self.epoch.pin();
        let state = self.state_ref(&guard);
        let raws: Vec<[u32; 4]> = pairs.iter().map(|&(k, _)| self.raw_hashes(k)).collect();
        let mut out = Vec::with_capacity(pairs.len());
        for (i, &(key, delta)) in pairs.iter().enumerate() {
            if i + 1 < pairs.len() {
                touch_next(state, raws[i + 1][0]);
            }
            out.push(self.fetch_add_core(state, key, delta, &raws[i])?);
        }
        Ok(out)
    }

    /// Execute a heterogeneous window of [`Op`]s through the per-class
    /// bulk paths, returning one typed [`OpResult`] per op **in
    /// submission order**. Classes execute grouped (upserts →
    /// insert-if-absents → updates → CAS → fetch-adds → deletes →
    /// lookups — see `backend::group_ops`); ops in one window are
    /// concurrent, so the grouping is a legal linearization. Inserting
    /// classes (`Insert`/`Upsert`/`InsertIfAbsent`/`FetchAdd`) validate
    /// their keys up front — a sentinel key errors the whole window
    /// before any mutation.
    pub fn execute_ops(&self, ops: &[Op]) -> Result<Vec<OpResult>> {
        crate::backend::validate_insert_keys(ops)?;
        let g = group_ops(ops);
        let mut out: Vec<Option<OpResult>> = vec![None; ops.len()];
        if !g.upserts.is_empty() {
            let pairs: Vec<(u32, u32)> = g.upserts.iter().map(|&(_, k, v)| (k, v)).collect();
            for (&(i, _, _), (outcome, old)) in g.upserts.iter().zip(self.upsert_batch(&pairs)?) {
                out[i] = Some(OpResult::Upserted { outcome, old });
            }
        }
        if !g.if_absents.is_empty() {
            let pairs: Vec<(u32, u32)> = g.if_absents.iter().map(|&(_, k, v)| (k, v)).collect();
            let res = self.insert_if_absent_batch(&pairs)?;
            for (&(i, _, _), (outcome, existing)) in g.if_absents.iter().zip(res) {
                out[i] = Some(OpResult::InsertedIfAbsent { outcome, existing });
            }
        }
        if !g.updates.is_empty() {
            let pairs: Vec<(u32, u32)> = g.updates.iter().map(|&(_, k, v)| (k, v)).collect();
            for (&(i, _, _), old) in g.updates.iter().zip(self.update_batch(&pairs)) {
                out[i] = Some(OpResult::Updated { old });
            }
        }
        if !g.cas.is_empty() {
            let items: Vec<(u32, u32, u32)> =
                g.cas.iter().map(|&(_, k, e, n)| (k, e, n)).collect();
            for (&(i, _, _, _), (ok, actual)) in g.cas.iter().zip(self.cas_batch(&items)) {
                out[i] = Some(OpResult::Cas { ok, actual });
            }
        }
        if !g.fetch_adds.is_empty() {
            let pairs: Vec<(u32, u32)> = g.fetch_adds.iter().map(|&(_, k, d)| (k, d)).collect();
            let res = self.fetch_add_batch(&pairs)?;
            for (&(i, _, _), (outcome, old)) in g.fetch_adds.iter().zip(res) {
                out[i] = Some(OpResult::FetchAdded { outcome, old });
            }
        }
        if !g.deletes.is_empty() {
            let keys: Vec<u32> = g.deletes.iter().map(|&(_, k)| k).collect();
            for (&(i, _), hit) in g.deletes.iter().zip(self.delete_batch(&keys)) {
                out[i] = Some(OpResult::Deleted(hit));
            }
        }
        if !g.lookups.is_empty() {
            let keys: Vec<u32> = g.lookups.iter().map(|&(_, k)| k).collect();
            for (&(i, _), v) in g.lookups.iter().zip(self.lookup_batch(&keys)) {
                out[i] = Some(OpResult::Value(v));
            }
        }
        Ok(out.into_iter().map(|r| r.expect("every op yields exactly one result")).collect())
    }

    /// Bulk Search: one `Option<u32>` per key, in submission order. Keys
    /// equal to the EMPTY sentinel yield `None`, as in the single-op path.
    pub fn lookup_batch(&self, keys: &[u32]) -> Vec<Option<u32>> {
        let guard = self.epoch.pin();
        let state = self.state_ref(&guard);
        let raws: Vec<[u32; 4]> = keys.iter().map(|&k| self.raw_hashes(k)).collect();
        let mut out = Vec::with_capacity(keys.len());
        for (i, &key) in keys.iter().enumerate() {
            if i + 1 < keys.len() {
                touch_next(state, raws[i + 1][0]);
            }
            out.push(if key == EMPTY_KEY {
                None
            } else {
                self.lookup_core(state, key, &raws[i])
            });
        }
        out
    }

    /// Bulk Delete: one hit flag per key, in submission order. Keys equal
    /// to the EMPTY sentinel yield `false`, as in the single-op path.
    pub fn delete_batch(&self, keys: &[u32]) -> Vec<bool> {
        let guard = self.epoch.pin();
        let state = self.state_ref(&guard);
        let raws: Vec<[u32; 4]> = keys.iter().map(|&k| self.raw_hashes(k)).collect();
        let mut out = Vec::with_capacity(keys.len());
        for (i, &key) in keys.iter().enumerate() {
            if i + 1 < keys.len() {
                touch_next(state, raws[i + 1][0]);
            }
            out.push(key != EMPTY_KEY && self.delete_core(state, key, &raws[i]));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use crate::core::config::HiveConfig;
    use crate::core::packed::EMPTY_KEY;
    use crate::native::table::{HiveTable, InsertOutcome};

    fn table(buckets: usize) -> HiveTable {
        HiveTable::new(HiveConfig::default().with_buckets(buckets)).unwrap()
    }

    #[test]
    fn batch_roundtrip() {
        let t = table(64);
        let pairs: Vec<(u32, u32)> = (1..=1000u32).map(|k| (k, k * 3)).collect();
        let outcomes = t.insert_batch(&pairs).unwrap();
        assert_eq!(outcomes.len(), 1000);
        assert!(outcomes.iter().all(|o| *o != InsertOutcome::Replaced));
        assert_eq!(t.len(), 1000);
        let keys: Vec<u32> = pairs.iter().map(|&(k, _)| k).collect();
        let vals = t.lookup_batch(&keys);
        for (i, v) in vals.iter().enumerate() {
            assert_eq!(*v, Some((i as u32 + 1) * 3), "key {}", i + 1);
        }
        let hits = t.delete_batch(&keys[..500]);
        assert!(hits.iter().all(|&h| h));
        assert_eq!(t.len(), 500);
        let vals = t.lookup_batch(&keys);
        assert!(vals[..500].iter().all(Option::is_none));
        assert!(vals[500..].iter().all(Option::is_some));
    }

    #[test]
    fn batch_replace_reports_replaced() {
        let t = table(16);
        t.insert_batch(&[(7, 70), (8, 80)]).unwrap();
        let outcomes = t.insert_batch(&[(7, 71), (9, 90)]).unwrap();
        assert_eq!(outcomes[0], InsertOutcome::Replaced);
        assert_ne!(outcomes[1], InsertOutcome::Replaced);
        assert_eq!(t.len(), 3);
        assert_eq!(t.lookup(7), Some(71));
    }

    #[test]
    fn empty_batches_are_noops() {
        let t = table(4);
        assert!(t.insert_batch(&[]).unwrap().is_empty());
        assert!(t.lookup_batch(&[]).is_empty());
        assert!(t.delete_batch(&[]).is_empty());
        assert_eq!(t.len(), 0);
    }

    #[test]
    fn sentinel_key_handling() {
        let t = table(4);
        assert!(t.insert_batch(&[(1, 1), (EMPTY_KEY, 2)]).is_err());
        assert!(t.insert_if_absent_batch(&[(1, 1), (EMPTY_KEY, 2)]).is_err());
        assert!(t.fetch_add_batch(&[(1, 1), (EMPTY_KEY, 2)]).is_err());
        // the failed batches must not have mutated the table
        assert_eq!(t.len(), 0);
        assert_eq!(t.lookup_batch(&[EMPTY_KEY, 1]), vec![None, None]);
        assert_eq!(t.delete_batch(&[EMPTY_KEY]), vec![false]);
        assert_eq!(t.update_batch(&[(EMPTY_KEY, 9)]), vec![None]);
        assert_eq!(t.cas_batch(&[(EMPTY_KEY, 0, 9)]), vec![(false, None)]);
    }

    #[test]
    fn rmw_batches_match_single_op_semantics() {
        use crate::native::table::RmwInsert;
        let t = table(64);
        t.insert_batch(&[(1, 10), (2, 20)]).unwrap();
        let ups = t.upsert_batch(&[(1, 11), (3, 30)]).unwrap();
        assert_eq!(ups[0], (InsertOutcome::Replaced, Some(10)));
        assert_eq!(ups[1].1, None, "fresh key must have no previous value");
        let ifa: Vec<RmwInsert> = t.insert_if_absent_batch(&[(2, 99), (4, 40)]).unwrap();
        assert_eq!(ifa[0], (None, Some(20)));
        assert!(ifa[1].0.is_some() && ifa[1].1.is_none());
        assert_eq!(t.update_batch(&[(2, 21), (5, 50)]), vec![Some(20), None]);
        assert_eq!(t.lookup(5), None, "update_batch created a key");
        assert_eq!(
            t.cas_batch(&[(2, 21, 22), (2, 99, 0), (5, 0, 1)]),
            vec![(true, Some(21)), (false, Some(22)), (false, None)]
        );
        let fa = t.fetch_add_batch(&[(2, 8), (6, 60)]).unwrap();
        assert_eq!(fa[0], (None, Some(22)));
        assert!(fa[1].0.is_some() && fa[1].1.is_none());
        assert_eq!(t.lookup(2), Some(30));
        assert_eq!(t.lookup(6), Some(60));
        assert_eq!(t.len(), 5); // keys 1,2,3,4,6
    }

    #[test]
    fn execute_ops_returns_typed_results_in_submission_order() {
        use crate::workload::{Op, OpResult};
        let t = table(64);
        let ops = vec![
            Op::Lookup { key: 1 },
            Op::Upsert { key: 1, value: 10 },
            Op::FetchAdd { key: 2, delta: 5 },
            Op::Delete { key: 3 },
            Op::Insert { key: 3, value: 30 },
            Op::Cas { key: 2, expected: 5, new: 6 },
            Op::Update { key: 9, value: 90 },
            Op::InsertIfAbsent { key: 1, value: 99 },
        ];
        let res = t.execute_ops(&ops).unwrap();
        assert_eq!(res.len(), ops.len());
        // grouped linearization (upserts → if-absents → updates → cas →
        // fetch-adds → deletes → lookups): writes land before the
        // window's lookups, deletes after the window's inserts
        assert_eq!(res[0], OpResult::Value(Some(10)));
        assert!(matches!(res[1], OpResult::Upserted { old: None, .. }));
        assert!(matches!(res[2], OpResult::FetchAdded { old: None, .. }));
        assert_eq!(res[3], OpResult::Deleted(true), "delete groups after the insert of key 3");
        assert!(matches!(res[4], OpResult::Upserted { old: None, .. }));
        // CAS groups *before* fetch-add in the class order: key 2 absent
        assert_eq!(res[5], OpResult::Cas { ok: false, actual: None });
        assert_eq!(res[6], OpResult::Updated { old: None });
        assert_eq!(res[7], OpResult::InsertedIfAbsent { outcome: None, existing: Some(10) });
        assert_eq!(t.lookup(2), Some(5));
        assert_eq!(t.lookup(3), None, "insert-then-delete window must end absent");
        // sentinel in an inserting class fails the window pre-mutation
        let t2 = table(4);
        assert!(t2
            .execute_ops(&[Op::Lookup { key: 1 }, Op::FetchAdd { key: EMPTY_KEY, delta: 1 }])
            .is_err());
        assert_eq!(t2.len(), 0);
    }
}
