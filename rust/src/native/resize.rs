//! Load-aware dynamic resizing via warp-parallel linear hashing
//! (paper §IV-C).
//!
//! The table grows/contracts in K-bucket batches. One *split* pairs source
//! bucket `b_src = split_ptr` with partner `b_dst = b_src + 2^m` and moves
//! every entry whose next-round hash bit selects the partner; movers are
//! compacted (the warp does this with ballot + prefix-rank — here a simple
//! compaction loop the compiler vectorizes). One *merge* is the inverse.
//! When all `2^m` low buckets are split the round advances
//! (`index_mask = (mask << 1) | 1; split_ptr = 0`); merging past
//! `split_ptr == 0` regresses the round.
//!
//! Resize runs under the table's exclusive phase guard — the analogue of a
//! dedicated GPU kernel launch between operation batches — so the bodies
//! use relaxed atomics freely. Physical bucket arrays are reallocated only
//! at power-of-two *capacity class* boundaries (DESIGN.md §7); a split
//! within a class moves exactly the K source buckets' entries, giving the
//! paper's O(K) migration cost.

use crate::core::packed::{is_empty, unpack_key, EMPTY_WORD};
use crate::core::{FULL_FREE_MASK, SLOTS_PER_BUCKET};
use crate::hash::HashFamily;
use crate::native::table::{HiveTable, State};
use std::sync::atomic::{AtomicU32, AtomicU64, Ordering};

/// What a resize pass did (returned by [`HiveTable::maybe_resize`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ResizeEvent {
    /// Split `n` buckets (expansion).
    Grew { buckets_split: usize },
    /// Merged `n` bucket pairs (contraction).
    Shrank { buckets_merged: usize },
}

impl HiveTable {
    /// Check the load-factor thresholds and, if crossed, run one K-bucket
    /// resize batch (plus a stash drain). Returns what happened.
    ///
    /// This is the entry point the coordinator's resize controller calls
    /// between operation batches; it is also safe to call from application
    /// threads (it takes the exclusive guard).
    pub fn maybe_resize(&self) -> Option<ResizeEvent> {
        let lf = self.load_factor();
        // Opportunistic pre-check without the write guard.
        if lf > self.cfg.grow_threshold || self.pending_full() > 0 {
            let split = self.grow_buckets(self.cfg.resize_batch);
            if split > 0 {
                return Some(ResizeEvent::Grew { buckets_split: split });
            }
            None
        } else if lf < self.cfg.shrink_threshold {
            let merged = self.shrink_buckets(self.cfg.resize_batch);
            if merged > 0 {
                return Some(ResizeEvent::Shrank { buckets_merged: merged });
            }
            None
        } else {
            None
        }
    }

    /// Split up to `k` buckets (expansion). Returns how many were split.
    /// Takes the exclusive phase guard; drains the stash afterwards.
    pub fn grow_buckets(&self, k: usize) -> usize {
        let mut state = self.state.write().unwrap();
        let mut split = 0;
        for _ in 0..k {
            let needed = state.logical_buckets() + 1;
            Self::ensure_physical(&mut state, needed);
            split_one(&mut state, &self.family);
            split += 1;
        }
        let drained = self.drain_stash_into(&state);
        drop(state);
        let _ = drained;
        split
    }

    /// Merge up to `k` bucket pairs (contraction). Stops early if a merge
    /// would overflow its destination or the table is at its minimum size.
    pub fn shrink_buckets(&self, k: usize) -> usize {
        let mut state = self.state.write().unwrap();
        let mut merged = 0;
        for _ in 0..k {
            // Never shrink below the initial round.
            if state.split_ptr == 0 && state.index_mask <= self.min_index_mask {
                break;
            }
            if !merge_one(&mut state) {
                break; // destination lacked room — abort (paper §IV-C2)
            }
            merged += 1;
        }
        if merged > 0 {
            Self::maybe_shrink_physical(&mut state);
            let _ = self.drain_stash_into(&state);
        }
        merged
    }

    /// Reinsert stashed entries into the (resized) table — §IV-A step 4's
    /// "reprocessed during table expansion". Called with the write guard
    /// held (exclusive), so plain probe/claim logic suffices.
    fn drain_stash_into(&self, state: &State) -> usize {
        use std::sync::atomic::Ordering as O;
        let mut words = Vec::new();
        if !self.stash.is_quiescent() {
            words.extend(self.stash.drain_exclusive());
        }
        if self.pending_len.load(O::Acquire) > 0 {
            let mut pending = self.pending.lock().unwrap();
            words.append(&mut pending);
            self.pending_len.store(0, O::Release);
        }
        let mut reinserted = 0;
        for word in words {
            let key = unpack_key(word);
            match exclusive_insert(state, &self.family, key, word, self.cfg.max_evictions) {
                None => reinserted += 1,
                Some(leftover) => {
                    // Still no room. `leftover` is whatever word is still
                    // homeless — the original, or a victim displaced along
                    // the eviction chain (never drop a victim!). Push back
                    // to the ring; overflow past it re-parks pending.
                    if !self.stash.push(leftover) {
                        self.pending.lock().unwrap().push(leftover);
                        self.pending_len.fetch_add(1, O::Release);
                    }
                }
            }
        }
        reinserted
    }

    /// Grow the physical arrays to the next capacity class if the logical
    /// bucket count is about to exceed them.
    fn ensure_physical(state: &mut State, needed_buckets: usize) {
        let phys = state.phys_buckets();
        if needed_buckets <= phys {
            return;
        }
        let new_phys = (phys * 2).max(needed_buckets.next_power_of_two());
        let mut buckets: Vec<AtomicU64> = Vec::with_capacity(new_phys * SLOTS_PER_BUCKET);
        let mut free_mask: Vec<AtomicU32> = Vec::with_capacity(new_phys);
        let mut locks: Vec<AtomicU32> = Vec::with_capacity(new_phys);
        for w in state.buckets.iter() {
            buckets.push(AtomicU64::new(w.load(Ordering::Relaxed)));
        }
        buckets.resize_with(new_phys * SLOTS_PER_BUCKET, || AtomicU64::new(EMPTY_WORD));
        for m in state.free_mask.iter() {
            free_mask.push(AtomicU32::new(m.load(Ordering::Relaxed)));
        }
        free_mask.resize_with(new_phys, || AtomicU32::new(FULL_FREE_MASK));
        locks.resize_with(new_phys, || AtomicU32::new(0));
        state.buckets = buckets.into_boxed_slice();
        state.free_mask = free_mask.into_boxed_slice();
        state.locks = locks.into_boxed_slice();
    }

    /// Halve the physical arrays when occupancy drops below a quarter of
    /// the capacity class (keeps memory proportional to the logical size).
    fn maybe_shrink_physical(state: &mut State) {
        let phys = state.phys_buckets();
        let logical = state.logical_buckets();
        if phys >= 8 && logical <= phys / 4 {
            let new_phys = phys / 2;
            let mut buckets: Vec<AtomicU64> = Vec::with_capacity(new_phys * SLOTS_PER_BUCKET);
            for w in state.buckets.iter().take(new_phys * SLOTS_PER_BUCKET) {
                buckets.push(AtomicU64::new(w.load(Ordering::Relaxed)));
            }
            let mut free_mask: Vec<AtomicU32> = Vec::with_capacity(new_phys);
            for m in state.free_mask.iter().take(new_phys) {
                free_mask.push(AtomicU32::new(m.load(Ordering::Relaxed)));
            }
            let mut locks: Vec<AtomicU32> = Vec::new();
            locks.resize_with(new_phys, || AtomicU32::new(0));
            state.buckets = buckets.into_boxed_slice();
            state.free_mask = free_mask.into_boxed_slice();
            state.locks = locks.into_boxed_slice();
        }
    }
}

/// Split the bucket at `split_ptr` into itself and its partner
/// `split_ptr + 2^m` (paper §IV-C1). Exclusive access assumed.
fn split_one(state: &mut State, family: &HashFamily) {
    let m_base = state.index_mask + 1; // 2^m
    let b_src = state.split_ptr;
    let b_dst = b_src + m_base;
    let next_mask = (state.index_mask << 1) | 1;

    debug_assert!((b_dst as usize) < state.phys_buckets());

    // Pass 1: each "lane" decides stay-vs-move for its slot; movers are
    // compacted into the (empty) partner bucket.
    let mut n_movers = 0usize;
    let src_base = b_src as usize * SLOTS_PER_BUCKET;
    let dst_base = b_dst as usize * SLOTS_PER_BUCKET;
    let mut src_freed_bits: u32 = 0;
    for lane in 0..SLOTS_PER_BUCKET {
        let w = state.buckets[src_base + lane].load(Ordering::Relaxed);
        if is_empty(w) {
            continue;
        }
        let key = unpack_key(w);
        // Which hash function addressed this entry here? Try each; the
        // placement invariant guarantees one matches.
        let mut should_move = false;
        let mut found_home = false;
        for i in 0..family.d() {
            let h = family.raw(i, key);
            if (h & state.index_mask) == b_src {
                found_home = true;
                should_move = (h & next_mask) == b_dst;
                break;
            }
        }
        debug_assert!(found_home, "entry {key} not addressed to its bucket {b_src}");
        if should_move {
            // compacted placement: dst->kv[rank] = kv
            state.buckets[dst_base + n_movers].store(w, Ordering::Relaxed);
            state.buckets[src_base + lane].store(EMPTY_WORD, Ordering::Relaxed);
            src_freed_bits |= 1 << lane;
            n_movers += 1;
        }
    }
    // Lane 0 updates both free masks: released slots in src, occupied
    // prefix in dst (paper: `src_mask |= move_mask; dst_mask &= ~((1<<n)-1)`).
    if n_movers > 0 {
        let src_mask = state.free_mask[b_src as usize].load(Ordering::Relaxed) | src_freed_bits;
        state.free_mask[b_src as usize].store(src_mask, Ordering::Relaxed);
        let dst_occupied = if n_movers >= 32 { u32::MAX } else { (1u32 << n_movers) - 1 };
        state.free_mask[b_dst as usize].store(FULL_FREE_MASK & !dst_occupied, Ordering::Relaxed);
    }

    // Advance the round pointer; when all 2^m low buckets are split the
    // table doubles its addressable range.
    state.split_ptr += 1;
    if state.split_ptr == m_base {
        state.index_mask = next_mask;
        state.split_ptr = 0;
    }
}

/// Merge the most recently split pair back together (paper §IV-C2).
/// Returns `false` (no state change) if the destination lacks room.
fn merge_one(state: &mut State) -> bool {
    // Regress the round if no bucket of this round has been split yet.
    let (m_base, sp) = if state.split_ptr == 0 {
        let prev_mask = state.index_mask >> 1;
        ((prev_mask + 1), prev_mask + 1) // state (m-1, sp = 2^(m-1))
    } else {
        (state.index_mask + 1, state.split_ptr)
    };
    let b_dst = sp - 1;
    let b_src = b_dst + m_base;

    let src_base = b_src as usize * SLOTS_PER_BUCKET;
    let dst_base = b_dst as usize * SLOTS_PER_BUCKET;

    // Count movers (all live entries of src) and free slots of dst.
    let src_free = state.free_mask[b_src as usize].load(Ordering::Relaxed);
    let dst_free = state.free_mask[b_dst as usize].load(Ordering::Relaxed);
    let n_move = SLOTS_PER_BUCKET as u32 - src_free.count_ones();
    let n_free = dst_free.count_ones();
    if n_move > n_free {
        return false; // abort early (paper: merge aborts if it can't fit)
    }

    // Each mover takes the r-th free slot of dst (prefix-rank mapping).
    let mut dst_mask = dst_free;
    for lane in 0..SLOTS_PER_BUCKET {
        let w = state.buckets[src_base + lane].load(Ordering::Relaxed);
        if is_empty(w) {
            continue;
        }
        let pos = dst_mask.trailing_zeros() as usize; // select_nth_one
        debug_assert!(pos < SLOTS_PER_BUCKET);
        state.buckets[dst_base + pos].store(w, Ordering::Relaxed);
        state.buckets[src_base + lane].store(EMPTY_WORD, Ordering::Relaxed);
        dst_mask &= !(1u32 << pos);
    }
    // Lane 0 publishes: src fully free, dst minus the used slots.
    state.free_mask[b_src as usize].store(FULL_FREE_MASK, Ordering::Relaxed);
    state.free_mask[b_dst as usize].store(dst_mask, Ordering::Relaxed);

    // Commit the regressed round state.
    if state.split_ptr == 0 {
        state.index_mask >>= 1;
        state.split_ptr = state.index_mask + 1; // == m_base of new round
    }
    state.split_ptr -= 1;
    true
}

/// Exclusive-mode insert used by the stash drain: plain (non-contended)
/// probe → claim → bounded eviction. Returns `None` when everything is
/// placed, or `Some(leftover_word)` — the still-homeless word (possibly a
/// displaced *victim*, which must not be dropped) when the bound runs out.
fn exclusive_insert(
    state: &State,
    family: &HashFamily,
    key: u32,
    word: u64,
    max_evictions: u32,
) -> Option<u64> {
    let (mask, sp) = (state.index_mask, state.split_ptr);
    // replace if present
    for i in 0..family.d() {
        let b = family.bucket(i, key, mask, sp);
        let base = b as usize * SLOTS_PER_BUCKET;
        for lane in 0..SLOTS_PER_BUCKET {
            let w = state.buckets[base + lane].load(Ordering::Relaxed);
            if unpack_key(w) == key {
                state.buckets[base + lane].store(word, Ordering::Relaxed);
                return None;
            }
        }
    }
    // claim
    let mut cur = word;
    let mut bucket = family.bucket(0, key, mask, sp);
    for _kick in 0..=max_evictions {
        let k = unpack_key(cur);
        for i in 0..family.d() {
            let b = family.bucket(i, k, mask, sp);
            let fm = state.free_mask[b as usize].load(Ordering::Relaxed);
            if fm != 0 {
                let lane = fm.trailing_zeros() as usize;
                state.buckets[b as usize * SLOTS_PER_BUCKET + lane].store(cur, Ordering::Relaxed);
                state.free_mask[b as usize].store(fm & !(1 << lane), Ordering::Relaxed);
                return None;
            }
        }
        // evict first occupied slot of the first candidate
        let b = if family.bucket(0, k, mask, sp) != bucket || family.d() == 1 {
            family.bucket(0, k, mask, sp)
        } else {
            family.bucket(1 % family.d(), k, mask, sp)
        };
        let base = b as usize * SLOTS_PER_BUCKET;
        let victim = state.buckets[base].load(Ordering::Relaxed);
        state.buckets[base].store(cur, Ordering::Relaxed);
        cur = victim;
        bucket = b;
        if is_empty(cur) {
            return None;
        }
    }
    Some(cur)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::config::HiveConfig;
    use crate::native::table::InsertOutcome;

    fn table(buckets: usize) -> HiveTable {
        HiveTable::new(HiveConfig::default().with_buckets(buckets)).unwrap()
    }

    #[test]
    fn split_preserves_all_entries() {
        let t = table(8);
        for k in 1..=200u32 {
            t.insert(k, k * 2).unwrap();
        }
        let before = t.logical_buckets();
        let split = t.grow_buckets(8); // full round: 8 -> 16 buckets
        assert_eq!(split, 8);
        assert_eq!(t.logical_buckets(), before + 8);
        for k in 1..=200u32 {
            assert_eq!(t.lookup(k), Some(k * 2), "key {k} lost after split");
        }
        assert_eq!(t.len(), 200);
    }

    #[test]
    fn partial_round_split_keeps_lookups_correct() {
        let t = table(8);
        for k in 1..=200u32 {
            t.insert(k, k).unwrap();
        }
        // split only 3 of 8 — mid-round state (split_ptr = 3)
        assert_eq!(t.grow_buckets(3), 3);
        assert_eq!(t.logical_buckets(), 11);
        for k in 1..=200u32 {
            assert_eq!(t.lookup(k), Some(k), "key {k} unreachable mid-round");
        }
        // inserts during a partial round must also be findable
        for k in 300..400u32 {
            t.insert(k, k).unwrap();
        }
        for k in 300..400u32 {
            assert_eq!(t.lookup(k), Some(k));
        }
    }

    #[test]
    fn multi_round_growth() {
        let t = table(4);
        for k in 1..=100u32 {
            t.insert(k, k).unwrap();
        }
        // 4 -> 8 -> 16 -> 32: three full rounds
        assert_eq!(t.grow_buckets(4 + 8 + 16), 28);
        assert_eq!(t.logical_buckets(), 32);
        for k in 1..=100u32 {
            assert_eq!(t.lookup(k), Some(k));
        }
    }

    #[test]
    fn merge_restores_entries() {
        let t = table(8);
        for k in 1..=100u32 {
            t.insert(k, k + 1).unwrap();
        }
        t.grow_buckets(8);
        assert_eq!(t.logical_buckets(), 16);
        let merged = t.shrink_buckets(8);
        assert_eq!(merged, 8, "merge back to 8 buckets");
        assert_eq!(t.logical_buckets(), 8);
        for k in 1..=100u32 {
            assert_eq!(t.lookup(k), Some(k + 1), "key {k} lost after merge");
        }
        assert_eq!(t.len(), 100);
    }

    #[test]
    fn shrink_stops_at_initial_size() {
        let t = table(8);
        assert_eq!(t.shrink_buckets(100), 0, "must not shrink below initial");
        assert_eq!(t.logical_buckets(), 8);
    }

    #[test]
    fn merge_aborts_when_destination_full() {
        let t = table(4);
        // Fill densely so merged pairs can't fit into one bucket.
        for k in 1..=120u32 {
            t.insert(k, k).unwrap();
        }
        t.grow_buckets(4); // 4 -> 8
        // Now each pair (b, b+4) holds ~30 entries total; merging two
        // 15-deep buckets fits, but filling more makes it abort.
        for k in 200..=330u32 {
            t.insert(k, k).unwrap();
        }
        let merged = t.shrink_buckets(4);
        // At ~56% of an 8-bucket table, most merges should abort.
        assert!(merged < 4, "expected aborted merges, merged {merged}");
        for k in 1..=120u32 {
            assert_eq!(t.lookup(k), Some(k));
        }
        for k in 200..=330u32 {
            assert_eq!(t.lookup(k), Some(k));
        }
    }

    #[test]
    fn maybe_resize_grows_past_threshold() {
        let t = HiveTable::new(
            HiveConfig::default().with_buckets(4).with_thresholds(0.9, 0.25),
        )
        .unwrap();
        let cap = t.capacity() as u32;
        let n = (cap as f64 * 0.93) as u32;
        for k in 1..=n {
            t.insert(k, k).unwrap();
        }
        assert!(t.load_factor() > 0.9);
        let ev = t.maybe_resize();
        assert!(matches!(ev, Some(ResizeEvent::Grew { .. })), "{ev:?}");
        assert!(t.load_factor() < 0.9);
        for k in 1..=n {
            assert_eq!(t.lookup(k), Some(k));
        }
    }

    #[test]
    fn maybe_resize_shrinks_when_sparse() {
        let t = HiveTable::new(
            HiveConfig::default().with_buckets(4).with_thresholds(0.9, 0.25),
        )
        .unwrap();
        // grow to 16 buckets first
        t.grow_buckets(12);
        assert_eq!(t.logical_buckets(), 16);
        for k in 1..=20u32 {
            t.insert(k, k).unwrap();
        }
        // lf = 20/512 << 0.25 -> shrink
        let ev = t.maybe_resize();
        assert!(matches!(ev, Some(ResizeEvent::Shrank { .. })), "{ev:?}");
        for k in 1..=20u32 {
            assert_eq!(t.lookup(k), Some(k));
        }
    }

    #[test]
    fn stash_drains_into_grown_table() {
        // Force stash traffic: keys confined to buckets {0,1} of a 4-bucket
        // table overflow their 64 combined slots.
        let t = HiveTable::new(
            HiveConfig::default().with_buckets(4).with_max_evictions(4),
        )
        .unwrap();
        let fam = t.family().clone();
        let keys: Vec<u32> = (1..400_000u32)
            .filter(|&k| fam.bucket(0, k, 3, 0) <= 1 && fam.bucket(1, k, 3, 0) <= 1)
            .take(70)
            .collect();
        assert_eq!(keys.len(), 70);
        let mut stashed = 0;
        for &k in &keys {
            if matches!(t.insert(k, k).unwrap(), InsertOutcome::Stashed) {
                stashed += 1;
            }
        }
        assert!(stashed > 0, "expected stash traffic when candidates overflow");
        t.grow_buckets(4); // full round: 4 -> 8 buckets, drains stash
        for &k in &keys {
            assert_eq!(t.lookup(k), Some(k), "key {k} lost across stash drain");
        }
        assert!(t.stash_words().is_empty(), "stash should be empty after drain");
    }

    #[test]
    fn growth_preserves_under_concurrent_reads() {
        use std::sync::atomic::AtomicBool;
        use std::sync::Arc;
        let t = Arc::new(table(8));
        for k in 1..=150u32 {
            t.insert(k, k).unwrap();
        }
        let stop = Arc::new(AtomicBool::new(false));
        let readers: Vec<_> = (0..4)
            .map(|_| {
                let t = Arc::clone(&t);
                let stop = Arc::clone(&stop);
                std::thread::spawn(move || {
                    while !stop.load(Ordering::Relaxed) {
                        for k in 1..=150u32 {
                            assert_eq!(t.lookup(k), Some(k));
                        }
                    }
                })
            })
            .collect();
        for _ in 0..3 {
            t.grow_buckets(8);
            std::thread::sleep(std::time::Duration::from_millis(5));
        }
        stop.store(true, Ordering::Relaxed);
        for r in readers {
            r.join().unwrap();
        }
    }
}
