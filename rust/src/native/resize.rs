//! Load-aware dynamic resizing via warp-parallel linear hashing
//! (paper §IV-C) — **incremental and operation-concurrent**.
//!
//! The table grows/contracts in K-bucket batches. One *split* pairs source
//! bucket `b_src = split_ptr` with partner `b_dst = b_src + 2^m` and moves
//! every entry whose next-round hash bit selects the partner; movers are
//! compacted (the warp does this with ballot + prefix-rank — here a simple
//! compaction loop the compiler vectorizes). One *merge* is the inverse.
//! When all `2^m` low buckets are split the round advances
//! (`index_mask = (mask << 1) | 1; split_ptr = 0`); merging past
//! `split_ptr == 0` regresses the round.
//!
//! ### Migration protocol (no stop-the-world)
//! Unlike the old exclusive phase guard, a migration batch runs while
//! operations continue on the rest of the table:
//!
//! 1. The migrator takes the two buckets' eviction locks (excluding cuckoo
//!    displacement) and sets their [`MIGRATING`] marker bits with RMWs on
//!    the mask words, totally ordering itself against concurrent claims.
//! 2. *Settle*: wait for claimed-but-unpublished slots (a claim that beat
//!    the marker will publish; one that lost backs out and re-routes).
//! 3. Entries move copy-then-clear: the word is stored in the destination
//!    *before* the source slot is CAS-cleared, so a concurrent probe
//!    always finds the entry in source or destination. A failed clear-CAS
//!    means a racing replace (re-copy the fresh word) or delete (retract
//!    the destination copy) — the migrator self-fixes and retries.
//! 4. The new round word is published, *then* the markers clear; stale
//!    operations waiting on a marker re-route through the fresh round.
//!
//! ### Re-quotienting (compact layout)
//! Under [`Layout::CompactQuotient`] a stored key half is
//! `tag | (hash >> w)` with `w` the bucket's index width, so migrating a
//! bucket changes every resident half: a *split* (width `w → w + 1`)
//! drops the remainder's low bit — which **is** the stay-or-move
//! decision — so movers land in the partner with `rem >> 1` and stayers
//! are rewritten in place the same way; a *merge* (width `w + 1 → w`)
//! re-enters the decision bit (`rem << 1 | from_image`). Both rewrites
//! happen under the buckets' markers + locks, CAS-guarded against racing
//! replaces/deletes exactly like the copy-then-clear move, and the value
//! forwarded on a clear-CAS failure is re-encoded for its destination
//! bucket. The migration-sequence bump that already orders probes against
//! migration doubles as the width-coherence signal probes validate
//! against (`native::table` module docs).
//!
//! Physical bucket arrays are reallocated only at power-of-two *capacity
//! class* boundaries (DESIGN.md §7). Reallocation is the one remaining
//! exclusive step: the epoch domain flips odd, the grace period drains all
//! pinned operations, the new `State` is published by pointer swap, and
//! the old allocation is freed immediately (no pin can outlive the drain).
//! A split within a class still moves exactly the K source buckets'
//! entries, giving the paper's O(K) migration cost.
//!
//! ### Stash drain vs. concurrent operations
//! Draining a stashed word back into the grown table publishes the table
//! copy *first* and retracts the stash copy second, so the key is always
//! in at least one place. Because the drain moves entries stash→table
//! while probes scan table→stash, a probe that misses in both places
//! revalidates the table's seqlock-style `drain_epoch` (odd while a drain
//! runs) and re-probes if a drain overlapped its scan. The transient
//! duplicate is benign: replace/delete purge shadow copies (see
//! `HiveTable::purge_shadow`), and if the stash copy vanishes mid-drain
//! (a racing delete or replace won) the drain retracts the table copy it
//! just published. Three corners remain approximate, as counts
//! already are under concurrency — all require a racing op on one stashed
//! key inside a single drain window: two racing deletes of the *same
//! stashed key* can both report a hit; a delete-then-reinsert of the same
//! key with the *bit-identical value* can be undone by the drain's
//! retraction (`remove_exact` cannot tell the fresh identical word from
//! the one it published); and a replace/delete that wins on the *stash*
//! copy leaves the drain's just-published stale table copy readable for
//! the microseconds until the drain's own `remove_word` failure triggers
//! `remove_exact`.

use crate::core::config::Layout;
use crate::core::packed::{is_empty, unpack_key, EMPTY_WORD};
use crate::core::quotient;
use crate::hash::HashFamily;
use crate::native::table::{
    pack_round, HiveTable, State, FREE_BITS, MIGRATING, MIGRATION_SEQ_SHIFT,
};
use crate::core::sync::atomic::{AtomicU32, AtomicU64, Ordering};

/// The value half of a packed word (bits 63..32).
const VALUE_BITS: u64 = 0xFFFF_FFFF_0000_0000;

/// What a resize pass did (returned by [`HiveTable::maybe_resize`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ResizeEvent {
    /// Split `n` buckets (expansion).
    Grew { buckets_split: usize },
    /// Merged `n` bucket pairs (contraction).
    Shrank { buckets_merged: usize },
}

/// Spin until `bucket`'s eviction lock is acquired.
fn lock_bucket(state: &State, bucket: u32) {
    let lock = &state.locks[bucket as usize];
    while lock.compare_exchange(0, 1, Ordering::Acquire, Ordering::Relaxed).is_err() {
        crate::core::sync::hint::spin_loop();
    }
}

fn unlock_bucket(state: &State, bucket: u32) {
    state.locks[bucket as usize].store(0, Ordering::Release);
}

/// Wait until no slot of `bucket` is claimed-but-unpublished: every lane
/// whose free bit is clear must hold a non-EMPTY word. Claims that beat
/// the marker publish promptly; claims that lost hand their bit back;
/// deletes publish their free bit right after clearing the word — all
/// wait-free, so this settles in bounded time.
fn settle_bucket(state: &State, bucket: u32) {
    let base = bucket as usize * state.spb;
    loop {
        let free = (state.masks[bucket as usize].load(Ordering::SeqCst) & FREE_BITS) as u32;
        let mut occ = !free & state.full_free as u32;
        let mut pending = false;
        while occ != 0 {
            let lane = occ.trailing_zeros() as usize;
            occ &= occ - 1;
            if state.buckets[base + lane].load(Ordering::Acquire) == EMPTY_WORD {
                pending = true;
                break;
            }
        }
        if !pending {
            return;
        }
        crate::core::sync::hint::spin_loop();
    }
}

/// Migrate one entry from `src_slot` into `dst_slot`, racing in-flight
/// replaces and deletes safely (module docs §3). On entry the migrator
/// has claimed `dst_bit` in `dst_mask`'s word and the dst slot is EMPTY,
/// so the initial publish cannot race anything (probes skip EMPTY words;
/// claims are blocked by the marker / the claimed bit). Everything after
/// that is CAS-only: a mutated copy is never overwritten blindly — if the
/// destination copy diverges under concurrent ops, ownership transfers to
/// them and the source copy is discarded instead. All resulting free-mask
/// bits are published here.
///
/// `dst_half` is the key half the destination bucket stores: the source
/// word's own half for AoS, the re-quotiented half for compact. Racing
/// replaces mutate only the value, so forwarding a refreshed source word
/// re-attaches `dst_half` to the fresh value.
fn migrate_word(
    state: &State,
    src_slot: usize,
    src_mask: usize,
    src_bit: u64,
    dst_slot: usize,
    dst_mask: usize,
    dst_bit: u64,
    src_word: u64,
    dst_half: u32,
) {
    let dst_word = (src_word & VALUE_BITS) | dst_half as u64;
    state.buckets[dst_slot].store(dst_word, Ordering::Release);
    let mut expect_src = src_word;
    let mut expect_dst = dst_word;
    loop {
        match state.buckets[src_slot].compare_exchange(
            expect_src,
            EMPTY_WORD,
            Ordering::AcqRel,
            Ordering::Acquire,
        ) {
            Ok(_) => {
                // moved: release the source slot
                state.masks[src_mask].fetch_or(src_bit, Ordering::AcqRel);
                return;
            }
            Err(cur) if is_empty(cur) => {
                // A racing delete consumed the source copy (and published
                // its free bit). Retract our duplicate if it is still
                // exactly ours; if not, a racing op took the destination
                // copy over (a deleter freed its bit, a replacer keeps the
                // slot occupied) and the mask/slot state is already
                // consistent without us.
                if state.buckets[dst_slot]
                    .compare_exchange(expect_dst, EMPTY_WORD, Ordering::AcqRel, Ordering::Relaxed)
                    .is_ok()
                {
                    state.masks[dst_mask].fetch_or(dst_bit, Ordering::AcqRel);
                }
                return;
            }
            Err(cur) => {
                // A racing replace refreshed the source copy: forward the
                // fresh value (re-encoded for the destination bucket) to
                // the destination copy, CAS-guarded...
                let fresh_dst = (cur & VALUE_BITS) | dst_half as u64;
                if state.buckets[dst_slot]
                    .compare_exchange(expect_dst, fresh_dst, Ordering::AcqRel, Ordering::Relaxed)
                    .is_ok()
                {
                    expect_src = cur; // ...and retry clearing the source
                    expect_dst = fresh_dst;
                } else {
                    // ...but the destination copy diverged under racing
                    // ops — it is canonical now. Discard the source copy;
                    // a racing delete that beats these CASes publishes the
                    // source free bit itself.
                    loop {
                        let s = state.buckets[src_slot].load(Ordering::Acquire);
                        if is_empty(s) {
                            return;
                        }
                        if state.buckets[src_slot]
                            .compare_exchange(s, EMPTY_WORD, Ordering::AcqRel, Ordering::Relaxed)
                            .is_ok()
                        {
                            state.masks[src_mask].fetch_or(src_bit, Ordering::AcqRel);
                            return;
                        }
                    }
                }
            }
        }
    }
}

/// Re-quotient a surviving slot in place (compact layout): CAS-loop the
/// half transform `f` onto the word, racing replaces (fresh value, same
/// half — recompute and retry) and deletes (slot emptied — nothing to do).
/// Runs only under the bucket's marker + lock.
fn requotient_slot(state: &State, slot: usize, f: impl Fn(u32) -> u32) {
    let mut cur = state.buckets[slot].load(Ordering::Acquire);
    loop {
        if is_empty(cur) {
            return;
        }
        let new = (cur & VALUE_BITS) | f(unpack_key(cur)) as u64;
        match state.buckets[slot].compare_exchange(cur, new, Ordering::AcqRel, Ordering::Acquire) {
            Ok(_) => return,
            Err(now) => cur = now,
        }
    }
}

impl HiveTable {
    /// Check the load-factor thresholds and, if crossed, run one K-bucket
    /// resize batch (plus a stash drain). Returns what happened.
    ///
    /// This is the entry point the coordinator's resize controller calls
    /// between operation batches; it is also safe to call from application
    /// threads at any time — migration runs concurrently with operations,
    /// and concurrent resize callers serialize on the resize mutex.
    pub fn maybe_resize(&self) -> Option<ResizeEvent> {
        let lf = self.load_factor();
        if lf > self.cfg.grow_threshold || self.pending_full() > 0 {
            let _g = self.resize_mutex.lock().unwrap();
            let split = self.grow_locked(self.cfg.resize_batch);
            if split > 0 {
                return Some(ResizeEvent::Grew { buckets_split: split });
            }
            None
        } else if lf < self.cfg.shrink_threshold {
            let _g = self.resize_mutex.lock().unwrap();
            let merged = self.shrink_locked(self.cfg.resize_batch);
            if merged > 0 {
                return Some(ResizeEvent::Shrank { buckets_merged: merged });
            }
            None
        } else {
            None
        }
    }

    /// Split up to `k` buckets (expansion). Returns how many were split.
    /// Operations keep running throughout; drains the stash afterwards.
    pub fn grow_buckets(&self, k: usize) -> usize {
        let _g = self.resize_mutex.lock().unwrap();
        self.grow_locked(k)
    }

    /// Merge up to `k` bucket pairs (contraction). Stops early if a merge
    /// would overflow its destination or the table is at its minimum size.
    pub fn shrink_buckets(&self, k: usize) -> usize {
        let _g = self.resize_mutex.lock().unwrap();
        self.shrink_locked(k)
    }

    fn grow_locked(&self, k: usize) -> usize {
        let mut split = 0;
        for _ in 0..k {
            self.ensure_physical_for_split();
            let guard = self.epoch.pin();
            let state = self.state_ref(&guard);
            self.split_one_concurrent(state);
            split += 1;
        }
        if split > 0 {
            self.drain_stash_concurrent();
        }
        split
    }

    fn shrink_locked(&self, k: usize) -> usize {
        let mut merged = 0;
        for _ in 0..k {
            let guard = self.epoch.pin();
            let state = self.state_ref(&guard);
            // Never shrink below the initial round.
            let (mask, sp) = state.round();
            if sp == 0 && mask <= self.min_index_mask {
                break;
            }
            let ok = self.merge_one_concurrent(state);
            drop(guard);
            if !ok {
                break; // destination lacked room — abort (paper §IV-C2)
            }
            merged += 1;
        }
        if merged > 0 {
            self.maybe_shrink_physical();
            self.drain_stash_concurrent();
        }
        merged
    }

    /// Grow the physical arrays to the next capacity class if the next
    /// split's partner bucket would not fit. Runs the epoch's exclusive
    /// phase (grace period + pointer swap); only resize-mutex holders get
    /// here, so exclusive phases never nest.
    fn ensure_physical_for_split(&self) {
        let (needed, phys) = {
            let guard = self.epoch.pin();
            let state = self.state_ref(&guard);
            (state.logical_buckets() + 1, state.phys_buckets())
        };
        if needed <= phys {
            return;
        }
        let new_phys = (phys * 2).max(needed.next_power_of_two());
        self.swap_physical(new_phys);
    }

    /// Halve the physical arrays when occupancy drops below a quarter of
    /// the capacity class (keeps memory proportional to the logical size).
    fn maybe_shrink_physical(&self) {
        let (phys, logical) = {
            let guard = self.epoch.pin();
            let state = self.state_ref(&guard);
            (state.phys_buckets(), state.logical_buckets())
        };
        if phys >= 8 && logical <= phys / 4 {
            self.swap_physical(phys / 2);
        }
    }

    /// Publish a new `State` with `new_phys` buckets: enter the exclusive
    /// phase (drains every pinned op — the grace period), copy the live
    /// prefix, swap the pointer, and free the old allocation.
    fn swap_physical(&self, new_phys: usize) {
        self.epoch.enter_exclusive();
        let old_ptr = self.state.load(Ordering::Acquire);
        // SAFETY: the pointer is the table's live allocation; we are inside
        // the exclusive phase, so no other thread dereferences it.
        let old = unsafe { &*old_ptr };
        let copy_buckets = old.phys_buckets().min(new_phys);
        let spb = old.spb;

        let mut buckets: Vec<AtomicU64> = Vec::with_capacity(new_phys * spb);
        for w in old.buckets.iter().take(copy_buckets * spb) {
            buckets.push(AtomicU64::new(w.load(Ordering::Relaxed)));
        }
        buckets.resize_with(new_phys * spb, || AtomicU64::new(EMPTY_WORD));

        let mut masks: Vec<AtomicU64> = Vec::with_capacity(new_phys);
        for m in old.masks.iter().take(copy_buckets) {
            let mw = m.load(Ordering::Relaxed);
            debug_assert_eq!(mw & MIGRATING, 0, "marker set during exclusive phase");
            // keep the migration-sequence bits: no probe spans a swap (the
            // grace period drains all pins), but preserving them costs
            // nothing and keeps the counters globally monotonic
            masks.push(AtomicU64::new(mw & !MIGRATING));
        }
        masks.resize_with(new_phys, || AtomicU64::new(old.full_free));

        let mut locks: Vec<AtomicU32> = Vec::new();
        locks.resize_with(new_phys, || AtomicU32::new(0));

        let new_state = Box::new(State {
            buckets: buckets.into_boxed_slice(),
            masks: masks.into_boxed_slice(),
            locks: locks.into_boxed_slice(),
            round: AtomicU64::new(old.round.load(Ordering::Relaxed)),
            spb,
            full_free: old.full_free,
            layout: old.layout,
        });
        self.state.store(Box::into_raw(new_state), Ordering::Release);
        self.epoch.exit_exclusive();
        // Grace period already elapsed (the drain): nothing can still hold
        // the old allocation.
        // SAFETY: unique Box::into_raw pointer, unreachable since the swap.
        unsafe { drop(Box::from_raw(old_ptr)) };
    }

    /// Split the bucket at `split_ptr` into itself and its partner
    /// `split_ptr + 2^m` (paper §IV-C1), concurrently with operations
    /// (module docs).
    fn split_one_concurrent(&self, state: &State) {
        let (index_mask, split_ptr) = state.round();
        let m_base = index_mask + 1; // 2^m
        let b_src = split_ptr;
        let b_dst = b_src + m_base;
        let next_mask = (index_mask << 1) | 1;
        debug_assert!((b_dst as usize) < state.phys_buckets());

        // 1. Exclude cuckoo displacement, then announce the migration.
        lock_bucket(state, b_src);
        lock_bucket(state, b_dst);
        state.masks[b_src as usize].fetch_or(MIGRATING, Ordering::SeqCst);
        state.masks[b_dst as usize].fetch_or(MIGRATING, Ordering::SeqCst);

        // 2. Settle claims that beat the marker — on *both* buckets. The
        //    partner is not addressable under the current round, but after
        //    a shrink regression an inserter still routing by the older
        //    (wider) round can transiently claim one of its bits; its
        //    publish validation cannot pass while the round pre-dates this
        //    split, so every such claim resolves by handing the bit back.
        settle_bucket(state, b_src);
        settle_bucket(state, b_dst);

        // 3. Move entries whose next-round hash selects the partner;
        //    movers are compacted into the (empty) partner bucket. Under
        //    the compact layout the stored remainder's low bit *is* the
        //    move decision (quotient::split_half), and both movers and
        //    stayers are re-quotiented to the post-split width `m + 1`.
        let compact = state.layout == Layout::CompactQuotient;
        let spb = state.spb;
        let src_base = b_src as usize * spb;
        let dst_base = b_dst as usize * spb;
        let mut n_movers = 0usize;
        for lane in 0..spb {
            let w = state.buckets[src_base + lane].load(Ordering::Acquire);
            if is_empty(w) {
                continue;
            }
            let (should_move, dst_half) = if compact {
                quotient::split_half(unpack_key(w))
            } else {
                let key = unpack_key(w);
                // Which hash function addressed this entry here? Try each;
                // the placement invariant guarantees one matches.
                let mut should_move = false;
                let mut found_home = false;
                for i in 0..self.family.d() {
                    let h = self.family.raw(i, key);
                    if (h & index_mask) == b_src {
                        found_home = true;
                        should_move = (h & next_mask) == b_dst;
                        break;
                    }
                }
                debug_assert!(found_home, "entry {key} not addressed to its bucket {b_src}");
                (should_move, key)
            };
            if !should_move {
                if compact {
                    // Stayer: rewrite the half in place for width m + 1
                    // (drop the decision bit — it is 0 for stayers).
                    requotient_slot(state, src_base + lane, |h| quotient::split_half(h).1);
                }
                continue;
            }
            // Compacted placement: dst->kv[rank] = kv. Claim the rank's
            // bit with the same flicker-tolerant loop as the merge path: a
            // stale-round claimer that lands after the marker hands its
            // bit straight back on seeing MIGRATING in its RMW return, so
            // the retry is short and bounded. `migrate_word` publishes all
            // mask bits, including handing slots back when a racing delete
            // wins.
            let dst_bit = 1u64 << n_movers;
            loop {
                let old = state.masks[b_dst as usize].fetch_and(!dst_bit, Ordering::AcqRel);
                if old & dst_bit != 0 {
                    break;
                }
                crate::core::sync::hint::spin_loop();
            }
            migrate_word(
                state,
                src_base + lane,
                b_src as usize,
                1u64 << lane,
                dst_base + n_movers,
                b_dst as usize,
                dst_bit,
                w,
                dst_half,
            );
            n_movers += 1;
        }

        // 4. Advance the round pointer (when all 2^m low buckets are split
        //    the table doubles its addressable range), *then* clear the
        //    markers: waiters re-route through the fresh round word.
        let (new_mask, new_sp) = if split_ptr + 1 == m_base {
            (next_mask, 0)
        } else {
            (index_mask, split_ptr + 1)
        };
        state.round.store(pack_round(new_mask, new_sp), Ordering::SeqCst);
        // Bump both buckets' migration sequences (defeats round-word ABA
        // in the miss-path validation), then clear the markers.
        state.masks[b_src as usize].fetch_add(1u64 << MIGRATION_SEQ_SHIFT, Ordering::SeqCst);
        state.masks[b_dst as usize].fetch_add(1u64 << MIGRATION_SEQ_SHIFT, Ordering::SeqCst);
        state.masks[b_src as usize].fetch_and(!MIGRATING, Ordering::SeqCst);
        state.masks[b_dst as usize].fetch_and(!MIGRATING, Ordering::SeqCst);
        unlock_bucket(state, b_dst);
        unlock_bucket(state, b_src);
    }

    /// Merge the most recently split pair back together (paper §IV-C2),
    /// concurrently with operations. Returns `false` (no state change) if
    /// the destination lacks room.
    fn merge_one_concurrent(&self, state: &State) -> bool {
        let (index_mask, split_ptr) = state.round();
        // Regress the round if no bucket of this round has been split yet.
        let (m_base, sp) = if split_ptr == 0 {
            let prev_mask = index_mask >> 1;
            (prev_mask + 1, prev_mask + 1) // state (m-1, sp = 2^(m-1))
        } else {
            (index_mask + 1, split_ptr)
        };
        let b_dst = sp - 1;
        let b_src = b_dst + m_base;

        lock_bucket(state, b_dst);
        lock_bucket(state, b_src);
        state.masks[b_dst as usize].fetch_or(MIGRATING, Ordering::SeqCst);
        state.masks[b_src as usize].fetch_or(MIGRATING, Ordering::SeqCst);
        settle_bucket(state, b_dst);
        settle_bucket(state, b_src);

        // Count movers (all live entries of src) vs free slots of dst. The
        // markers block new claims on both buckets and concurrent deletes
        // only add room, so a passing check stays valid until the markers
        // clear.
        let src_free = (state.masks[b_src as usize].load(Ordering::SeqCst) & FREE_BITS) as u32;
        let dst_free = (state.masks[b_dst as usize].load(Ordering::SeqCst) & FREE_BITS) as u32;
        let n_move = state.spb as u32 - src_free.count_ones();
        if n_move > dst_free.count_ones() {
            // abort early (paper: merge aborts if it can't fit)
            state.masks[b_src as usize].fetch_and(!MIGRATING, Ordering::SeqCst);
            state.masks[b_dst as usize].fetch_and(!MIGRATING, Ordering::SeqCst);
            unlock_bucket(state, b_src);
            unlock_bucket(state, b_dst);
            return false;
        }

        let compact = state.layout == Layout::CompactQuotient;
        let spb = state.spb;
        let src_base = b_src as usize * spb;
        let dst_base = b_dst as usize * spb;
        if compact {
            // Re-quotient the destination's surviving entries to the
            // post-merge width first (decision bit 0 — they never left),
            // before movers claim free destination slots: the sweep must
            // not touch words that are already merge-encoded.
            let occupied = !((state.masks[b_dst as usize].load(Ordering::SeqCst) & FREE_BITS)
                as u32)
                & state.full_free as u32;
            let mut occ = occupied;
            while occ != 0 {
                let lane = occ.trailing_zeros() as usize;
                occ &= occ - 1;
                requotient_slot(state, dst_base + lane, |h| quotient::merge_half(h, false));
            }
        }
        for lane in 0..spb {
            let w = state.buckets[src_base + lane].load(Ordering::Acquire);
            if is_empty(w) {
                continue;
            }
            // Movers come from the split image: decision bit 1.
            let dst_half =
                if compact { quotient::merge_half(unpack_key(w), true) } else { unpack_key(w) };
            // Claim the r-th free slot of dst (prefix-rank mapping). The
            // marker blocks *lasting* claims, but an insert that loaded the
            // mask just before the marker landed can transiently clear a
            // bit and then restore it on seeing MIGRATING in the RMW
            // return — so free bits can flicker and this claim must loop:
            // re-read on an empty snapshot, re-pick on a lost bit. The
            // capacity check above (taken after settle) guarantees enough
            // bits reappear once the flickering claimers back out.
            let pos = loop {
                let dst_mask =
                    (state.masks[b_dst as usize].load(Ordering::SeqCst) & FREE_BITS) as u32;
                if dst_mask == 0 {
                    crate::core::sync::hint::spin_loop();
                    continue;
                }
                let pos = dst_mask.trailing_zeros() as usize;
                let bit = 1u64 << pos;
                let old = state.masks[b_dst as usize].fetch_and(!bit, Ordering::AcqRel);
                if old & bit != 0 {
                    break pos;
                }
                // a backing-out claimer transiently holds it; it restores
                crate::core::sync::hint::spin_loop();
            };
            migrate_word(
                state,
                src_base + lane,
                b_src as usize,
                1u64 << lane,
                dst_base + pos,
                b_dst as usize,
                1u64 << pos,
                w,
                dst_half,
            );
        }

        // Commit the regressed round state, bump the migration sequences,
        // then clear the markers.
        let new_mask = if split_ptr == 0 { index_mask >> 1 } else { index_mask };
        state.round.store(pack_round(new_mask, sp - 1), Ordering::SeqCst);
        state.masks[b_src as usize].fetch_add(1u64 << MIGRATION_SEQ_SHIFT, Ordering::SeqCst);
        state.masks[b_dst as usize].fetch_add(1u64 << MIGRATION_SEQ_SHIFT, Ordering::SeqCst);
        state.masks[b_src as usize].fetch_and(!MIGRATING, Ordering::SeqCst);
        state.masks[b_dst as usize].fetch_and(!MIGRATING, Ordering::SeqCst);
        unlock_bucket(state, b_src);
        unlock_bucket(state, b_dst);
        true
    }

    /// Reinsert stashed/pending entries into the (resized) table — §IV-A
    /// step 4's "reprocessed during table expansion". Runs concurrently
    /// with operations: the table copy is published before the shadow copy
    /// is retracted (module docs). Returns how many words went home.
    fn drain_stash_concurrent(&self) -> usize {
        // Nothing parked ⇒ no drain, no epoch flip: the steady-state miss
        // paths never pay a re-probe. (A word pushed concurrently with
        // this check is simply left for the next resize epoch.)
        if self.stash.is_quiescent() && self.pending_len.load(Ordering::Acquire) == 0 {
            return 0;
        }
        let guard = self.epoch.pin();
        let state = self.state_ref(&guard);
        let mut reinserted = 0;

        // Flip the drain epoch odd before the first republish: the
        // delete/replace shadow purge activates, and every op miss path
        // re-probes instead of trusting a scan that raced the drain.
        let e = self.drain_epoch.fetch_add(1, Ordering::SeqCst);
        debug_assert_eq!(e & 1, 0, "stash drains must not nest");

        if !self.stash.is_quiescent() {
            for word in self.stash.peek_window() {
                let key = unpack_key(word);
                if !self.reinsert_word(state, key, word) {
                    continue; // still no room anywhere: stays in the stash
                }
                if self.stash.remove_word(word) {
                    reinserted += 1;
                } else {
                    // The stash copy vanished mid-drain: a delete or
                    // replace raced us and owns the key now. Retract the
                    // copy we just published unless it was already updated
                    // or removed.
                    self.remove_exact(state, key, word);
                }
            }
        }

        if self.pending_len.load(Ordering::Acquire) > 0 {
            let snapshot: Vec<u64> = self.pending.lock().unwrap().clone();
            for word in snapshot {
                let key = unpack_key(word);
                if !self.reinsert_word(state, key, word) {
                    continue; // stays pending
                }
                let removed = {
                    let mut pending = self.pending.lock().unwrap();
                    if let Some(pos) = pending.iter().position(|&w| w == word) {
                        pending.remove(pos);
                        self.pending_len.fetch_sub(1, Ordering::Release);
                        true
                    } else {
                        false
                    }
                };
                if removed {
                    reinserted += 1;
                } else {
                    self.remove_exact(state, key, word);
                }
            }
        }
        self.drain_epoch.fetch_add(1, Ordering::SeqCst);
        reinserted
    }

    /// Remove the exact `word` from `key`'s current candidate buckets, if
    /// it is still there (drain-undo path). `word` is the plain full-key
    /// word the drain reinserted; under the compact layout the table copy
    /// is its per-bucket re-encoding, so the needle is re-derived per
    /// candidate (round read after the marker check, hit validated before
    /// the CAS — the same width-coherence discipline as the probe cores).
    /// No count/stat updates — the logical entry was accounted elsewhere.
    fn remove_exact(&self, state: &State, key: u32, word: u64) {
        let compact = state.layout == Layout::CompactQuotient;
        let raws = self.raw_hashes(key);
        let d = self.family.d();
        'retry: loop {
            let (mask, sp) = state.round();
            let cands = HiveTable::route(&raws, d, mask, sp);
            let mut pre = [0u64; 4];
            for (i, &b) in cands[..d].iter().enumerate() {
                let mw = state.masks[b as usize].load(Ordering::SeqCst);
                if mw & MIGRATING != 0 {
                    HiveTable::wait_unmarked(state, b);
                    continue 'retry;
                }
                pre[i] = mw;
                let needle = if compact {
                    let (rm, rs) = state.round();
                    if HashFamily::address(raws[i], rm, rs) != b {
                        continue 'retry;
                    }
                    (word & VALUE_BITS) | quotient::encode_half(raws[i], i, b, rm, rs) as u64
                } else {
                    word
                };
                let base = b as usize * state.spb;
                for lane in 0..state.spb {
                    if state.buckets[base + lane].load(Ordering::Acquire) == needle {
                        if !self.hit_valid(state, b, mw) {
                            continue 'retry;
                        }
                        if state.buckets[base + lane]
                            .compare_exchange(
                                needle,
                                EMPTY_WORD,
                                Ordering::AcqRel,
                                Ordering::Relaxed,
                            )
                            .is_ok()
                        {
                            state.masks[b as usize].fetch_or(1u64 << lane, Ordering::AcqRel);
                            return;
                        }
                    }
                }
            }
            // Miss: confirm no candidate migrated under the probe.
            if !self.validate_miss(state, &raws, &cands, &pre) {
                continue 'retry;
            }
            // Not found: a concurrent replace/delete already owns it.
            return;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::config::HiveConfig;
    use crate::native::table::InsertOutcome;

    fn table(buckets: usize) -> HiveTable {
        HiveTable::new(HiveConfig::default().with_buckets(buckets)).unwrap()
    }

    #[test]
    fn split_preserves_all_entries() {
        let t = table(8);
        for k in 1..=200u32 {
            t.insert(k, k * 2).unwrap();
        }
        let before = t.logical_buckets();
        let split = t.grow_buckets(8); // full round: 8 -> 16 buckets
        assert_eq!(split, 8);
        assert_eq!(t.logical_buckets(), before + 8);
        for k in 1..=200u32 {
            assert_eq!(t.lookup(k), Some(k * 2), "key {k} lost after split");
        }
        assert_eq!(t.len(), 200);
    }

    #[test]
    fn partial_round_split_keeps_lookups_correct() {
        let t = table(8);
        for k in 1..=200u32 {
            t.insert(k, k).unwrap();
        }
        // split only 3 of 8 — mid-round state (split_ptr = 3)
        assert_eq!(t.grow_buckets(3), 3);
        assert_eq!(t.logical_buckets(), 11);
        for k in 1..=200u32 {
            assert_eq!(t.lookup(k), Some(k), "key {k} unreachable mid-round");
        }
        // inserts during a partial round must also be findable
        for k in 300..400u32 {
            t.insert(k, k).unwrap();
        }
        for k in 300..400u32 {
            assert_eq!(t.lookup(k), Some(k));
        }
    }

    #[test]
    fn multi_round_growth() {
        let t = table(4);
        for k in 1..=100u32 {
            t.insert(k, k).unwrap();
        }
        // 4 -> 8 -> 16 -> 32: three full rounds
        assert_eq!(t.grow_buckets(4 + 8 + 16), 28);
        assert_eq!(t.logical_buckets(), 32);
        for k in 1..=100u32 {
            assert_eq!(t.lookup(k), Some(k));
        }
    }

    #[test]
    fn merge_restores_entries() {
        let t = table(8);
        for k in 1..=100u32 {
            t.insert(k, k + 1).unwrap();
        }
        t.grow_buckets(8);
        assert_eq!(t.logical_buckets(), 16);
        let merged = t.shrink_buckets(8);
        assert_eq!(merged, 8, "merge back to 8 buckets");
        assert_eq!(t.logical_buckets(), 8);
        for k in 1..=100u32 {
            assert_eq!(t.lookup(k), Some(k + 1), "key {k} lost after merge");
        }
        assert_eq!(t.len(), 100);
    }

    #[test]
    fn shrink_stops_at_initial_size() {
        let t = table(8);
        assert_eq!(t.shrink_buckets(100), 0, "must not shrink below initial");
        assert_eq!(t.logical_buckets(), 8);
    }

    #[test]
    fn merge_aborts_when_destination_full() {
        let t = table(4);
        // Fill densely so merged pairs can't fit into one bucket.
        for k in 1..=120u32 {
            t.insert(k, k).unwrap();
        }
        t.grow_buckets(4); // 4 -> 8
        // Now each pair (b, b+4) holds ~30 entries total; merging two
        // 15-deep buckets fits, but filling more makes it abort.
        for k in 200..=330u32 {
            t.insert(k, k).unwrap();
        }
        let merged = t.shrink_buckets(4);
        // At ~56% of an 8-bucket table, most merges should abort.
        assert!(merged < 4, "expected aborted merges, merged {merged}");
        for k in 1..=120u32 {
            assert_eq!(t.lookup(k), Some(k));
        }
        for k in 200..=330u32 {
            assert_eq!(t.lookup(k), Some(k));
        }
    }

    #[test]
    fn maybe_resize_grows_past_threshold() {
        let t = HiveTable::new(
            HiveConfig::default().with_buckets(4).with_thresholds(0.9, 0.25),
        )
        .unwrap();
        let cap = t.capacity() as u32;
        let n = (cap as f64 * 0.93) as u32;
        for k in 1..=n {
            t.insert(k, k).unwrap();
        }
        assert!(t.load_factor() > 0.9);
        let ev = t.maybe_resize();
        assert!(matches!(ev, Some(ResizeEvent::Grew { .. })), "{ev:?}");
        assert!(t.load_factor() < 0.9);
        for k in 1..=n {
            assert_eq!(t.lookup(k), Some(k));
        }
    }

    #[test]
    fn maybe_resize_shrinks_when_sparse() {
        let t = HiveTable::new(
            HiveConfig::default().with_buckets(4).with_thresholds(0.9, 0.25),
        )
        .unwrap();
        // grow to 16 buckets first
        t.grow_buckets(12);
        assert_eq!(t.logical_buckets(), 16);
        for k in 1..=20u32 {
            t.insert(k, k).unwrap();
        }
        // lf = 20/512 << 0.25 -> shrink
        let ev = t.maybe_resize();
        assert!(matches!(ev, Some(ResizeEvent::Shrank { .. })), "{ev:?}");
        for k in 1..=20u32 {
            assert_eq!(t.lookup(k), Some(k));
        }
    }

    #[test]
    fn stash_drains_into_grown_table() {
        // Force stash traffic: keys confined to buckets {0,1} of a 4-bucket
        // table overflow their 64 combined slots.
        let t = HiveTable::new(
            HiveConfig::default().with_buckets(4).with_max_evictions(4),
        )
        .unwrap();
        let fam = t.family().clone();
        let keys: Vec<u32> = (1..400_000u32)
            .filter(|&k| fam.bucket(0, k, 3, 0) <= 1 && fam.bucket(1, k, 3, 0) <= 1)
            .take(70)
            .collect();
        assert_eq!(keys.len(), 70);
        let mut stashed = 0;
        for &k in &keys {
            if matches!(t.insert(k, k).unwrap(), InsertOutcome::Stashed) {
                stashed += 1;
            }
        }
        assert!(stashed > 0, "expected stash traffic when candidates overflow");
        t.grow_buckets(4); // full round: 4 -> 8 buckets, drains stash
        for &k in &keys {
            assert_eq!(t.lookup(k), Some(k), "key {k} lost across stash drain");
        }
        assert!(t.stash_words().is_empty(), "stash should be empty after drain");
    }

    #[test]
    fn growth_preserves_under_concurrent_reads() {
        use std::sync::atomic::AtomicBool;
        use std::sync::Arc;
        let t = Arc::new(table(8));
        for k in 1..=150u32 {
            t.insert(k, k).unwrap();
        }
        let stop = Arc::new(AtomicBool::new(false));
        let readers: Vec<_> = (0..4)
            .map(|_| {
                let t = Arc::clone(&t);
                let stop = Arc::clone(&stop);
                std::thread::spawn(move || {
                    while !stop.load(Ordering::Relaxed) {
                        for k in 1..=150u32 {
                            assert_eq!(t.lookup(k), Some(k));
                        }
                    }
                })
            })
            .collect();
        for _ in 0..3 {
            t.grow_buckets(8);
            std::thread::sleep(std::time::Duration::from_millis(5));
        }
        stop.store(true, Ordering::Relaxed);
        for r in readers {
            r.join().unwrap();
        }
    }

    fn compact_table(buckets: usize) -> HiveTable {
        let cfg =
            HiveConfig::default().with_buckets(buckets).with_layout(Layout::CompactQuotient);
        HiveTable::new(cfg).unwrap()
    }

    #[test]
    fn compact_split_requotients_and_preserves_entries() {
        let t = compact_table(8);
        for k in 1..=100u32 {
            t.insert(k, k * 2).unwrap();
        }
        assert_eq!(t.grow_buckets(8), 8); // full round: 8 -> 16 buckets
        for k in 1..=100u32 {
            assert_eq!(t.lookup(k), Some(k * 2), "key {k} lost after compact split");
        }
        // Mid-round splits too (mixed widths across the table).
        assert_eq!(t.grow_buckets(5), 5);
        for k in 1..=100u32 {
            assert_eq!(t.lookup(k), Some(k * 2), "key {k} lost mid-round");
        }
        assert_eq!(t.len(), 100);
    }

    #[test]
    fn compact_merge_restores_entries() {
        let t = compact_table(8);
        for k in 1..=60u32 {
            t.insert(k, k + 9).unwrap();
        }
        t.grow_buckets(8);
        assert_eq!(t.logical_buckets(), 16);
        assert_eq!(t.shrink_buckets(8), 8);
        assert_eq!(t.logical_buckets(), 8);
        for k in 1..=60u32 {
            assert_eq!(t.lookup(k), Some(k + 9), "key {k} lost after compact merge");
        }
        assert_eq!(t.len(), 60);
    }

    #[test]
    fn compact_multi_round_growth() {
        let t = compact_table(4);
        for k in 1..=50u32 {
            t.insert(k, k).unwrap();
        }
        assert_eq!(t.grow_buckets(4 + 8 + 16), 28); // 4 -> 32 buckets
        assert_eq!(t.logical_buckets(), 32);
        for k in 1..=50u32 {
            assert_eq!(t.lookup(k), Some(k));
        }
        let mut got = t.entries();
        got.sort_unstable();
        assert_eq!(got, (1..=50u32).map(|k| (k, k)).collect::<Vec<_>>());
    }

    #[test]
    fn compact_growth_preserves_under_concurrent_reads() {
        use std::sync::atomic::AtomicBool;
        use std::sync::Arc;
        let t = Arc::new(compact_table(8));
        for k in 1..=100u32 {
            t.insert(k, k).unwrap();
        }
        let stop = Arc::new(AtomicBool::new(false));
        let readers: Vec<_> = (0..4)
            .map(|_| {
                let t = Arc::clone(&t);
                let stop = Arc::clone(&stop);
                std::thread::spawn(move || {
                    while !stop.load(Ordering::Relaxed) {
                        for k in 1..=100u32 {
                            assert_eq!(t.lookup(k), Some(k));
                        }
                    }
                })
            })
            .collect();
        for _ in 0..3 {
            t.grow_buckets(8);
            std::thread::sleep(std::time::Duration::from_millis(5));
        }
        t.shrink_buckets(12);
        std::thread::sleep(std::time::Duration::from_millis(5));
        stop.store(true, Ordering::Relaxed);
        for r in readers {
            r.join().unwrap();
        }
        for k in 1..=100u32 {
            assert_eq!(t.lookup(k), Some(k));
        }
    }
}
