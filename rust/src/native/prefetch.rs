//! Shared bucket-line prefetch hint for the bulk batch paths.
//!
//! The AMAC-style interleaved scheduler in [`crate::native::batch`]
//! wants op *i+G*'s first bucket row moving toward this core while op
//! *i* executes. One helper owns how that hint is issued:
//!
//! * `x86_64` — `_mm_prefetch` with the T0 locality hint (SSE is
//!   baseline on the target, no feature gate needed);
//! * `aarch64` — `prfm pldl1keep` via inline asm;
//! * anywhere else, and under `--cfg loom` — a relaxed atomic read
//!   "touch", the PR-4 behaviour (under the model checker a real
//!   prefetch would be an untracked memory access; a shim load is a
//!   legal no-op the scheduler can see).
//!
//! A real prefetch beats the touch in exactly the case the batch paths
//! care about: it is *non-blocking* (the core does not stall for the
//! miss, the line streams in behind the in-flight ops) and *non-faulting*.
//! The touch, by contrast, is an architecturally required load — the
//! compiler must order it, and a cold line stalls retirement once the
//! load buffer fills.
//!
//! Layout gating lives here too (satellite of PR-6's one-line compact
//! bucket): under [`Layout::CompactQuotient`] a 16-slot row is a single
//! 128-byte line and mask words stay hot in L1 across a batch, so one
//! hint covers the probe's whole footprint; the 32-slot AoS row spans
//! two lines and gets its mask word plus both row lines.

use crate::core::config::Layout;
use crate::core::sync::atomic::AtomicU64;
#[cfg(not(all(
    not(loom),
    any(target_arch = "x86_64", target_arch = "aarch64")
)))]
use crate::core::sync::atomic::Ordering;
use crate::hash::HashFamily;
use crate::native::table::State;

/// Hint that the cache line holding `word` will be read soon. Real
/// prefetch intrinsic where the target has one, volatile-read-style
/// touch otherwise (module docs).
#[inline(always)]
pub(crate) fn line_hint(word: &AtomicU64) {
    #[cfg(all(not(loom), target_arch = "x86_64"))]
    // SAFETY: prefetch is non-faulting and has no architectural effect;
    // any address, even a dangling one, is allowed. `word` is a live
    // reference anyway.
    unsafe {
        use core::arch::x86_64::{_mm_prefetch, _MM_HINT_T0};
        _mm_prefetch::<{ _MM_HINT_T0 }>(word as *const AtomicU64 as *const i8);
    }
    #[cfg(all(not(loom), target_arch = "aarch64"))]
    // SAFETY: `prfm pldl1keep` is the architectural no-fault prefetch;
    // it reads no registers besides the address and writes none.
    unsafe {
        core::arch::asm!(
            "prfm pldl1keep, [{0}]",
            in(reg) (word as *const AtomicU64),
            options(nostack, preserves_flags, readonly)
        );
    }
    #[cfg(not(all(
        not(loom),
        any(target_arch = "x86_64", target_arch = "aarch64")
    )))]
    {
        let _ = word.load(Ordering::Relaxed);
    }
}

/// Prefetch the line(s) a probe of `bucket` will touch first: the slot
/// row (one line compact, two lines for the 32-slot AoS row) plus the
/// mask word for the wide layouts (compact skips it — module docs).
#[inline(always)]
pub(crate) fn prefetch_bucket(state: &State, bucket: u32) {
    let base = bucket as usize * state.spb;
    if state.layout != Layout::CompactQuotient {
        line_hint(&state.masks[bucket as usize]);
    }
    line_hint(&state.buckets[base]);
    if state.spb > 16 {
        // Second 128-byte line of the 32-slot row (16 × 8 B per line).
        line_hint(&state.buckets[base + 16]);
    }
}

/// Prefetch the first candidate bucket of the op whose primary raw hash
/// is `raw0`, routed under the current round word — the per-op entry
/// the interleaved scheduler calls G ops ahead.
#[inline(always)]
pub(crate) fn prefetch_candidate(state: &State, raw0: u32) {
    let (mask, sp) = state.round();
    prefetch_bucket(state, HashFamily::address(raw0, mask, sp));
}
