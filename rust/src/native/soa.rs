//! Split structure-of-arrays ablation (paper §III-A, Figure 1a).
//!
//! The classical SoA layout keeps distinct key and value arrays, forcing a
//! *two-phase update*: one 32-bit CAS to claim the key slot, then a relaxed
//! store to publish the value — extra global traffic and a key/value
//! consistency window. `SoaTable` implements exactly that scheme so the
//! benchmarks can quantify what the packed-AoS layout buys (DESIGN.md §6).
//!
//! The probing scheme (two-choice buckets of 32 slots, same hash family) is
//! kept identical to [`crate::native::table::HiveTable`] so the measured
//! difference isolates the layout.

use crate::core::config::HiveConfig;
use crate::core::error::{HiveError, Result};
use crate::core::packed::EMPTY_KEY;
use crate::core::{StripedCounter, SLOTS_PER_BUCKET};
use crate::hash::HashFamily;
use std::sync::atomic::{AtomicU32, Ordering};

/// SoA bucket table: `keys[i]` and `values[i]` live in separate arrays.
pub struct SoaTable {
    keys: Box<[AtomicU32]>,
    values: Box<[AtomicU32]>,
    family: HashFamily,
    n_buckets: usize,
    /// Striped like the native table's occupancy count: the ablation
    /// isolates the *layout* difference, so the baseline must not pay a
    /// contended single-line counter the AoS table no longer has.
    count: StripedCounter,
}

impl SoaTable {
    /// Fixed-capacity SoA table (the ablation does not resize).
    pub fn new(cfg: &HiveConfig) -> Result<Self> {
        let n_buckets = cfg.initial_buckets.next_power_of_two().max(4);
        if cfg.hash_kinds.len() < 2 {
            return Err(HiveError::Config("need >= 2 hash functions".into()));
        }
        let slots = n_buckets * SLOTS_PER_BUCKET;
        Ok(SoaTable {
            keys: (0..slots).map(|_| AtomicU32::new(EMPTY_KEY)).collect(),
            values: (0..slots).map(|_| AtomicU32::new(0)).collect(),
            family: HashFamily::new(cfg.hash_kinds.clone()),
            n_buckets,
            count: StripedCounter::new(),
        })
    }

    /// Live entries.
    pub fn len(&self) -> usize {
        self.count.sum()
    }

    /// `true` when empty.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    #[inline]
    fn bucket(&self, i: usize, key: u32) -> usize {
        (self.family.raw(i, key) as usize) & (self.n_buckets - 1)
    }

    /// Two-phase insert: CAS the key slot, then store the value.
    pub fn insert(&self, key: u32, value: u32) -> Result<()> {
        if key == EMPTY_KEY {
            return Err(HiveError::InvalidKey(key));
        }
        // replace path: find existing key, store value (second transaction)
        for i in 0..self.family.d() {
            let b = self.bucket(i, key);
            let base = b * SLOTS_PER_BUCKET;
            for lane in 0..SLOTS_PER_BUCKET {
                if self.keys[base + lane].load(Ordering::Acquire) == key {
                    self.values[base + lane].store(value, Ordering::Release);
                    return Ok(());
                }
            }
        }
        // claim path: CAS key slot EMPTY -> key, then publish value
        for i in 0..self.family.d() {
            let b = self.bucket(i, key);
            let base = b * SLOTS_PER_BUCKET;
            for lane in 0..SLOTS_PER_BUCKET {
                if self.keys[base + lane]
                    .compare_exchange(EMPTY_KEY, key, Ordering::AcqRel, Ordering::Relaxed)
                    .is_ok()
                {
                    // Phase 2: the separate value store — the extra memory
                    // transaction (and inconsistency window) AoS removes.
                    self.values[base + lane].store(value, Ordering::Release);
                    self.count.incr();
                    return Ok(());
                }
            }
        }
        Err(HiveError::TableFull)
    }

    /// Lookup — must read two arrays (two transactions per hit).
    pub fn lookup(&self, key: u32) -> Option<u32> {
        for i in 0..self.family.d() {
            let b = self.bucket(i, key);
            let base = b * SLOTS_PER_BUCKET;
            for lane in 0..SLOTS_PER_BUCKET {
                if self.keys[base + lane].load(Ordering::Acquire) == key {
                    return Some(self.values[base + lane].load(Ordering::Acquire));
                }
            }
        }
        None
    }

    /// Delete: CAS the key away; the stale value slot is simply abandoned.
    pub fn delete(&self, key: u32) -> bool {
        for i in 0..self.family.d() {
            let b = self.bucket(i, key);
            let base = b * SLOTS_PER_BUCKET;
            for lane in 0..SLOTS_PER_BUCKET {
                if self.keys[base + lane]
                    .compare_exchange(key, EMPTY_KEY, Ordering::AcqRel, Ordering::Relaxed)
                    .is_ok()
                {
                    self.count.decr();
                    return true;
                }
            }
        }
        false
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn table() -> SoaTable {
        SoaTable::new(&HiveConfig::default().with_buckets(64)).unwrap()
    }

    #[test]
    fn roundtrip() {
        let t = table();
        for k in 1..=1000u32 {
            t.insert(k, k * 2).unwrap();
        }
        for k in 1..=1000u32 {
            assert_eq!(t.lookup(k), Some(k * 2));
        }
        assert_eq!(t.len(), 1000);
    }

    #[test]
    fn replace_and_delete() {
        let t = table();
        t.insert(1, 10).unwrap();
        t.insert(1, 11).unwrap();
        assert_eq!(t.lookup(1), Some(11));
        assert_eq!(t.len(), 1);
        assert!(t.delete(1));
        assert!(!t.delete(1));
        assert_eq!(t.lookup(1), None);
    }

    #[test]
    fn concurrent_inserts() {
        use std::sync::Arc;
        let t = Arc::new(table());
        let handles: Vec<_> = (0..4u32)
            .map(|tid| {
                let t = Arc::clone(&t);
                std::thread::spawn(move || {
                    // ~49% load factor: two-choice without eviction still
                    // succeeds at this occupancy.
                    for i in 0..250 {
                        t.insert(tid * 1000 + i + 1, i).unwrap();
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(t.len(), 1000);
    }
}
