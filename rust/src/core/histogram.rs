//! Log-bucketed latency histogram (HdrHistogram-lite) used by the
//! coordinator's stats and the benchmark harness.

/// Power-of-two bucketed histogram over `u64` values (e.g. nanoseconds).
///
/// Each power-of-two range is subdivided into 16 linear sub-buckets, giving
/// ≤ ~6 % quantile error — plenty for p50/p99 reporting.
#[derive(Debug, Clone)]
pub struct Histogram {
    counts: Vec<u64>,
    total: u64,
    sum: u128,
    min: u64,
    max: u64,
}

const SUB: usize = 16;
const SUB_BITS: u32 = 4;

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

impl Histogram {
    /// New empty histogram covering the full `u64` range.
    pub fn new() -> Self {
        Histogram { counts: vec![0; 64 * SUB], total: 0, sum: 0, min: u64::MAX, max: 0 }
    }

    #[inline]
    fn index(value: u64) -> usize {
        if value < SUB as u64 {
            return value as usize;
        }
        let msb = 63 - value.leading_zeros();
        let bucket = msb as usize;
        let sub = ((value >> (msb - SUB_BITS)) & (SUB as u64 - 1)) as usize;
        bucket * SUB + sub
    }

    /// Record one observation.
    #[inline]
    pub fn record(&mut self, value: u64) {
        self.record_n(value, 1);
    }

    /// Record `n` observations of `value` in O(1) — the bulk-window
    /// path records one latency sample per op without n array walks.
    #[inline]
    pub fn record_n(&mut self, value: u64, n: u64) {
        if n == 0 {
            return;
        }
        self.counts[Self::index(value)] += n;
        self.total += n;
        self.sum += value as u128 * n as u128;
        self.min = self.min.min(value);
        self.max = self.max.max(value);
    }

    /// Representative value (lower edge) of histogram slot `i`.
    fn slot_value(i: usize) -> u64 {
        let bucket = i / SUB;
        let sub = (i % SUB) as u64;
        if bucket < SUB_BITS as usize + 1 && (i as u64) < SUB as u64 {
            return i as u64;
        }
        let base = 1u64 << bucket;
        base + (sub << (bucket as u32 - SUB_BITS))
    }

    /// Number of recorded observations.
    pub fn count(&self) -> u64 {
        self.total
    }

    /// Mean of observations (0 if empty).
    pub fn mean(&self) -> f64 {
        if self.total == 0 {
            0.0
        } else {
            self.sum as f64 / self.total as f64
        }
    }

    /// Minimum observation (0 if empty).
    pub fn min(&self) -> u64 {
        if self.total == 0 {
            0
        } else {
            self.min
        }
    }

    /// Maximum observation.
    pub fn max(&self) -> u64 {
        self.max
    }

    /// Approximate quantile `q ∈ [0,1]`.
    pub fn quantile(&self, q: f64) -> u64 {
        if self.total == 0 {
            return 0;
        }
        let target = ((q.clamp(0.0, 1.0)) * self.total as f64).ceil() as u64;
        let mut seen = 0;
        for (i, &c) in self.counts.iter().enumerate() {
            seen += c;
            if seen >= target.max(1) {
                return Self::slot_value(i);
            }
        }
        self.max
    }

    /// Merge another histogram into this one.
    pub fn merge(&mut self, other: &Histogram) {
        for (a, b) in self.counts.iter_mut().zip(&other.counts) {
            *a += b;
        }
        self.total += other.total;
        self.sum += other.sum;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }

    /// One-line summary: `count mean p50 p99 max`.
    pub fn summary(&self) -> String {
        format!(
            "n={} mean={:.0} p50={} p99={} max={}",
            self.total,
            self.mean(),
            self.quantile(0.50),
            self.quantile(0.99),
            self.max()
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_histogram() {
        let h = Histogram::new();
        assert_eq!(h.count(), 0);
        assert_eq!(h.quantile(0.5), 0);
        assert_eq!(h.mean(), 0.0);
    }

    #[test]
    fn exact_small_values() {
        let mut h = Histogram::new();
        for v in 0..16u64 {
            h.record(v);
        }
        assert_eq!(h.min(), 0);
        assert_eq!(h.max(), 15);
        assert_eq!(h.count(), 16);
    }

    #[test]
    fn quantiles_are_monotone_and_close() {
        let mut h = Histogram::new();
        for v in 1..=100_000u64 {
            h.record(v);
        }
        let p50 = h.quantile(0.5);
        let p90 = h.quantile(0.9);
        let p99 = h.quantile(0.99);
        assert!(p50 <= p90 && p90 <= p99);
        // within ~7% of the true quantile
        assert!((p50 as f64 - 50_000.0).abs() / 50_000.0 < 0.07, "p50={p50}");
        assert!((p99 as f64 - 99_000.0).abs() / 99_000.0 < 0.07, "p99={p99}");
    }

    #[test]
    fn record_n_equals_repeated_record() {
        let mut a = Histogram::new();
        let mut b = Histogram::new();
        for _ in 0..1000 {
            a.record(77);
        }
        a.record(5);
        b.record_n(77, 1000);
        b.record_n(5, 1);
        b.record_n(123, 0); // no-op
        assert_eq!(a.count(), b.count());
        assert_eq!(a.mean(), b.mean());
        assert_eq!(a.min(), b.min());
        assert_eq!(a.max(), b.max());
        assert_eq!(a.quantile(0.5), b.quantile(0.5));
        assert_eq!(a.quantile(0.999), b.quantile(0.999));
    }

    #[test]
    fn merge_equals_combined() {
        let mut a = Histogram::new();
        let mut b = Histogram::new();
        let mut c = Histogram::new();
        for v in 0..1000u64 {
            if v % 2 == 0 {
                a.record(v * 3);
            } else {
                b.record(v * 3);
            }
            c.record(v * 3);
        }
        a.merge(&b);
        assert_eq!(a.count(), c.count());
        assert_eq!(a.quantile(0.5), c.quantile(0.5));
        assert_eq!(a.max(), c.max());
    }
}
