//! Error and result types shared across the crate.

use std::fmt;

/// Errors surfaced by table operations and the coordinator.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum HiveError {
    /// Key equals the reserved EMPTY sentinel.
    InvalidKey(u32),
    /// Insert failed: table and overflow stash are both full; the operation
    /// is flagged pending for the next resize epoch (paper §IV-A step 4).
    TableFull,
    /// The requested capacity is not supported (e.g. not a power of two or
    /// below the minimum bucket count).
    BadCapacity(usize),
    /// Resize could not proceed (e.g. merge aborted: destination bucket has
    /// fewer free slots than the source has movers — paper §IV-C2).
    ResizeAborted(&'static str),
    /// Runtime/artifact failure in the XLA backend.
    Runtime(String),
    /// Configuration file / value error.
    Config(String),
    /// The coordinator is shutting down.
    Shutdown,
    /// A bulk operation attempted every element but `failed` of them
    /// errored; `first` is the first error observed. The rest of the batch
    /// was still executed.
    BatchErrors {
        /// How many individual operations failed.
        failed: usize,
        /// The first error observed in submission order.
        first: Box<HiveError>,
    },
}

impl fmt::Display for HiveError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            HiveError::InvalidKey(k) => write!(f, "invalid key {k:#x} (reserved sentinel)"),
            HiveError::TableFull => write!(f, "table and overflow stash full; pending resize"),
            HiveError::BadCapacity(c) => write!(f, "unsupported capacity {c}"),
            HiveError::ResizeAborted(why) => write!(f, "resize aborted: {why}"),
            HiveError::Runtime(msg) => write!(f, "runtime error: {msg}"),
            HiveError::Config(msg) => write!(f, "config error: {msg}"),
            HiveError::Shutdown => write!(f, "coordinator shut down"),
            HiveError::BatchErrors { failed, first } => {
                write!(f, "batch: {failed} ops failed; first error: {first}")
            }
        }
    }
}

impl std::error::Error for HiveError {}

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, HiveError>;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_messages() {
        assert!(HiveError::InvalidKey(0xFFFF_FFFF).to_string().contains("0xffffffff"));
        assert!(HiveError::TableFull.to_string().contains("stash"));
        assert!(HiveError::ResizeAborted("merge").to_string().contains("merge"));
        let batch = HiveError::BatchErrors { failed: 3, first: Box::new(HiveError::TableFull) };
        let msg = batch.to_string();
        assert!(msg.contains("3 ops failed") && msg.contains("stash"), "{msg}");
    }
}
