//! Synchronization facade for the concurrency-bearing modules.
//!
//! Normal builds re-export `std::sync::atomic` / `std::thread` /
//! `std::sync::Mutex` unchanged (zero cost). Under `--cfg loom` the same
//! paths resolve to shims that route every atomic access, mutex
//! acquisition and spin hint through the deterministic model checker in
//! [`crate::core::model`], so the `tests/model_*.rs` suites can exhaust
//! bounded interleavings of the real protocol code.
//!
//! Only the protocol modules go through this facade — `core/epoch.rs`,
//! `core/counter.rs`, `native/table.rs`, `native/resize.rs`,
//! `native/stash.rs`, `coordinator/shard.rs`. Everything else (stats,
//! baselines, the coordinator service plane) keeps plain `std` and stays
//! invisible to the scheduler, which keeps model state spaces small.
//!
//! Shim caveats, accepted deliberately (see `TESTING.md`):
//! * The explored memory model is sequential consistency: shims ignore
//!   the caller's `Ordering` and use `SeqCst`.
//! * Spin loops **must** go through [`hint::spin_loop`] (they all do) —
//!   under the model it parks the thread until another thread writes.
//! * [`thread_index`] replaces the per-module `thread_local!` first-use
//!   counters for stripe selection: dense model-assigned indices during a
//!   model run (replay-deterministic), a process-global first-use counter
//!   otherwise.

#[cfg(not(loom))]
mod imp {
    /// `std::sync::atomic`, unchanged.
    pub mod atomic {
        pub use std::sync::atomic::{
            fence, AtomicBool, AtomicI64, AtomicPtr, AtomicU32, AtomicU64, AtomicUsize,
            Ordering,
        };
    }

    /// `std::hint::spin_loop`, unchanged.
    pub mod hint {
        pub use std::hint::spin_loop;
    }

    /// `std::thread`, unchanged (the subset the facade guarantees).
    pub mod thread {
        pub use std::thread::{sleep, spawn, yield_now, JoinHandle};
    }

    pub use std::sync::{Mutex, MutexGuard};

    /// Dense-ish index for stripe selection: first-use round-robin over a
    /// process-global counter (the scheme `EpochDomain` and
    /// `StripedCounter` previously each kept privately — now shared, so
    /// both stripe families number threads identically).
    #[inline]
    pub fn thread_index() -> usize {
        use std::sync::atomic::{AtomicUsize, Ordering};
        static NEXT: AtomicUsize = AtomicUsize::new(0);
        thread_local! {
            static HOME: usize = NEXT.fetch_add(1, Ordering::Relaxed);
        }
        HOME.with(|h| *h)
    }
}

#[cfg(loom)]
mod imp {
    use crate::core::model;

    /// Shim atomics: every access is a scheduling point; stores and
    /// successful RMWs additionally wake model threads parked in a spin
    /// hint. Orderings are accepted and ignored (SeqCst everywhere).
    pub mod atomic {
        use crate::core::model;
        pub use std::sync::atomic::Ordering;

        macro_rules! int_shim {
            ($name:ident, $std:ident, $ty:ty) => {
                pub struct $name(std::sync::atomic::$std);

                impl $name {
                    pub const fn new(v: $ty) -> Self {
                        Self(std::sync::atomic::$std::new(v))
                    }

                    #[inline]
                    pub fn load(&self, _o: Ordering) -> $ty {
                        model::yield_point(concat!(stringify!($name), "::load"));
                        self.0.load(Ordering::SeqCst)
                    }

                    #[inline]
                    pub fn store(&self, v: $ty, _o: Ordering) {
                        model::yield_point(concat!(stringify!($name), "::store"));
                        self.0.store(v, Ordering::SeqCst);
                        model::record_write();
                    }

                    #[inline]
                    pub fn swap(&self, v: $ty, _o: Ordering) -> $ty {
                        model::yield_point(concat!(stringify!($name), "::swap"));
                        let r = self.0.swap(v, Ordering::SeqCst);
                        model::record_write();
                        r
                    }

                    #[inline]
                    pub fn compare_exchange(
                        &self,
                        current: $ty,
                        new: $ty,
                        _s: Ordering,
                        _f: Ordering,
                    ) -> Result<$ty, $ty> {
                        model::yield_point(concat!(stringify!($name), "::cas"));
                        let r = self
                            .0
                            .compare_exchange(current, new, Ordering::SeqCst, Ordering::SeqCst);
                        if r.is_ok() {
                            model::record_write();
                        }
                        r
                    }

                    #[inline]
                    pub fn compare_exchange_weak(
                        &self,
                        current: $ty,
                        new: $ty,
                        s: Ordering,
                        f: Ordering,
                    ) -> Result<$ty, $ty> {
                        self.compare_exchange(current, new, s, f)
                    }

                    #[inline]
                    pub fn fetch_add(&self, v: $ty, _o: Ordering) -> $ty {
                        model::yield_point(concat!(stringify!($name), "::fetch_add"));
                        let r = self.0.fetch_add(v, Ordering::SeqCst);
                        model::record_write();
                        r
                    }

                    #[inline]
                    pub fn fetch_sub(&self, v: $ty, _o: Ordering) -> $ty {
                        model::yield_point(concat!(stringify!($name), "::fetch_sub"));
                        let r = self.0.fetch_sub(v, Ordering::SeqCst);
                        model::record_write();
                        r
                    }

                    #[inline]
                    pub fn fetch_and(&self, v: $ty, _o: Ordering) -> $ty {
                        model::yield_point(concat!(stringify!($name), "::fetch_and"));
                        let r = self.0.fetch_and(v, Ordering::SeqCst);
                        model::record_write();
                        r
                    }

                    #[inline]
                    pub fn fetch_or(&self, v: $ty, _o: Ordering) -> $ty {
                        model::yield_point(concat!(stringify!($name), "::fetch_or"));
                        let r = self.0.fetch_or(v, Ordering::SeqCst);
                        model::record_write();
                        r
                    }
                }

                impl std::fmt::Debug for $name {
                    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
                        write!(f, "{:?}", self.0)
                    }
                }

                impl Default for $name {
                    fn default() -> Self {
                        Self::new(Default::default())
                    }
                }
            };
        }

        int_shim!(AtomicU64, AtomicU64, u64);
        int_shim!(AtomicU32, AtomicU32, u32);
        int_shim!(AtomicUsize, AtomicUsize, usize);
        int_shim!(AtomicI64, AtomicI64, i64);

        pub struct AtomicBool(std::sync::atomic::AtomicBool);

        impl AtomicBool {
            pub const fn new(v: bool) -> Self {
                Self(std::sync::atomic::AtomicBool::new(v))
            }

            #[inline]
            pub fn load(&self, _o: Ordering) -> bool {
                model::yield_point("AtomicBool::load");
                self.0.load(Ordering::SeqCst)
            }

            #[inline]
            pub fn store(&self, v: bool, _o: Ordering) {
                model::yield_point("AtomicBool::store");
                self.0.store(v, Ordering::SeqCst);
                model::record_write();
            }

            #[inline]
            pub fn compare_exchange(
                &self,
                current: bool,
                new: bool,
                _s: Ordering,
                _f: Ordering,
            ) -> Result<bool, bool> {
                model::yield_point("AtomicBool::cas");
                let r = self
                    .0
                    .compare_exchange(current, new, Ordering::SeqCst, Ordering::SeqCst);
                if r.is_ok() {
                    model::record_write();
                }
                r
            }
        }

        pub struct AtomicPtr<T>(std::sync::atomic::AtomicPtr<T>);

        impl<T> AtomicPtr<T> {
            pub const fn new(p: *mut T) -> Self {
                Self(std::sync::atomic::AtomicPtr::new(p))
            }

            #[inline]
            pub fn load(&self, _o: Ordering) -> *mut T {
                model::yield_point("AtomicPtr::load");
                self.0.load(Ordering::SeqCst)
            }

            #[inline]
            pub fn store(&self, p: *mut T, _o: Ordering) {
                model::yield_point("AtomicPtr::store");
                self.0.store(p, Ordering::SeqCst);
                model::record_write();
            }

            #[inline]
            pub fn swap(&self, p: *mut T, _o: Ordering) -> *mut T {
                model::yield_point("AtomicPtr::swap");
                let r = self.0.swap(p, Ordering::SeqCst);
                model::record_write();
                r
            }

            #[inline]
            pub fn compare_exchange(
                &self,
                current: *mut T,
                new: *mut T,
                _s: Ordering,
                _f: Ordering,
            ) -> Result<*mut T, *mut T> {
                model::yield_point("AtomicPtr::cas");
                let r = self
                    .0
                    .compare_exchange(current, new, Ordering::SeqCst, Ordering::SeqCst);
                if r.is_ok() {
                    model::record_write();
                }
                r
            }
        }

        /// A fence is only a scheduling point under the SC model.
        #[inline]
        pub fn fence(_o: Ordering) {
            model::yield_point("fence");
        }
    }

    pub mod hint {
        use crate::core::model;

        /// Inside a model run: park until another thread performs a
        /// write (a spin iteration that cannot make progress must not
        /// consume schedule steps). Outside: the real CPU hint.
        #[inline]
        pub fn spin_loop() {
            if model::active() {
                model::park_until_write();
            } else {
                std::hint::spin_loop();
            }
        }
    }

    pub mod thread {
        use crate::core::model;

        pub struct JoinHandle<T>(Inner<T>);

        enum Inner<T> {
            Os(std::thread::JoinHandle<T>),
            Model(model::JoinHandle<T>),
        }

        impl<T> JoinHandle<T> {
            pub fn join(self) -> std::thread::Result<T> {
                match self.0 {
                    Inner::Os(h) => h.join(),
                    Inner::Model(h) => Ok(h.join()),
                }
            }
        }

        pub fn spawn<F, T>(f: F) -> JoinHandle<T>
        where
            F: FnOnce() -> T + Send + 'static,
            T: Send + 'static,
        {
            if model::active() {
                JoinHandle(Inner::Model(model::spawn(f)))
            } else {
                JoinHandle(Inner::Os(std::thread::spawn(f)))
            }
        }

        pub fn yield_now() {
            if model::active() {
                model::park_until_write();
            } else {
                std::thread::yield_now();
            }
        }

        /// Model time has no clock: sleeping is just a scheduling point.
        pub fn sleep(d: std::time::Duration) {
            if model::active() {
                model::yield_point("sleep");
            } else {
                std::thread::sleep(d);
            }
        }
    }

    /// Scheduler-aware mutex: a CAS spin lock over a shim `AtomicBool`,
    /// so acquisition/release are scheduling points and contended waits
    /// park like any other spin loop. API-compatible with the
    /// `lock().unwrap()` idiom used by the table.
    pub struct Mutex<T> {
        locked: atomic::AtomicBool,
        value: std::cell::UnsafeCell<T>,
    }

    unsafe impl<T: Send> Send for Mutex<T> {}
    unsafe impl<T: Send> Sync for Mutex<T> {}

    /// Placeholder error type so `lock().unwrap()` typechecks; the shim
    /// never poisons.
    #[derive(Debug)]
    pub struct LockError;

    pub struct MutexGuard<'a, T> {
        m: &'a Mutex<T>,
    }

    impl<T> Mutex<T> {
        pub const fn new(v: T) -> Self {
            Self {
                locked: atomic::AtomicBool::new(false),
                value: std::cell::UnsafeCell::new(v),
            }
        }

        pub fn lock(&self) -> Result<MutexGuard<'_, T>, LockError> {
            use atomic::Ordering;
            while self
                .locked
                .compare_exchange(false, true, Ordering::SeqCst, Ordering::SeqCst)
                .is_err()
            {
                hint::spin_loop();
            }
            Ok(MutexGuard { m: self })
        }
    }

    impl<T> std::ops::Deref for MutexGuard<'_, T> {
        type Target = T;
        fn deref(&self) -> &T {
            unsafe { &*self.m.value.get() }
        }
    }

    impl<T> std::ops::DerefMut for MutexGuard<'_, T> {
        fn deref_mut(&mut self) -> &mut T {
            unsafe { &mut *self.m.value.get() }
        }
    }

    impl<T> Drop for MutexGuard<'_, T> {
        fn drop(&mut self) {
            self.m.locked.store(false, atomic::Ordering::SeqCst);
        }
    }

    /// Stripe-selection index: the model's dense per-run thread id when a
    /// check is running (replay-deterministic), else the same first-use
    /// global counter as the normal build.
    #[inline]
    pub fn thread_index() -> usize {
        if let Some(i) = model::thread_id() {
            return i;
        }
        use std::sync::atomic::{AtomicUsize, Ordering};
        static NEXT: AtomicUsize = AtomicUsize::new(0);
        thread_local! {
            static HOME: usize = NEXT.fetch_add(1, Ordering::Relaxed);
        }
        HOME.with(|h| *h)
    }
}

pub use imp::*;
