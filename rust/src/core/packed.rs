//! Packed 64-bit key-value words (paper §III-A, Figure 1b).
//!
//! Each entry is a single 64-bit word — key in the low 32 bits, value in the
//! high 32 — so one 64-bit CAS publishes or removes both fields atomically.
//! This is the "Packed Array-of-Structures" layout that eliminates the
//! CAS+store two-phase update of a split key/value (SoA) layout.

/// Reserved key denoting an empty slot. User keys must be `< EMPTY_KEY`.
pub const EMPTY_KEY: u32 = u32::MAX;

/// The word stored in an empty slot: `pack(EMPTY_KEY, u32::MAX)`.
pub const EMPTY_WORD: u64 = u64::MAX;

/// Pack a key-value pair into one 64-bit word (paper: `pair = (v << 32) | k`).
#[inline(always)]
pub const fn pack(key: u32, value: u32) -> u64 {
    ((value as u64) << 32) | (key as u64)
}

/// Extract the key: `pair & 0xFFFFFFFF`.
#[inline(always)]
pub const fn unpack_key(word: u64) -> u32 {
    (word & 0xFFFF_FFFF) as u32
}

/// Extract the value: `pair >> 32`.
#[inline(always)]
pub const fn unpack_value(word: u64) -> u32 {
    (word >> 32) as u32
}

/// Unpack into `(key, value)`.
#[inline(always)]
pub const fn unpack(word: u64) -> (u32, u32) {
    (unpack_key(word), unpack_value(word))
}

/// `true` if the word encodes an empty slot.
#[inline(always)]
pub const fn is_empty(word: u64) -> bool {
    unpack_key(word) == EMPTY_KEY
}

/// `true` if `key` is a legal user key (the top key is the empty sentinel).
#[inline(always)]
pub const fn key_is_valid(key: u32) -> bool {
    key != EMPTY_KEY
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pack_unpack_roundtrip() {
        for &(k, v) in &[(0u32, 0u32), (1, 2), (0xDEAD_BEEF, 0xCAFE_BABE), (u32::MAX - 1, u32::MAX)] {
            let w = pack(k, v);
            assert_eq!(unpack_key(w), k);
            assert_eq!(unpack_value(w), v);
            assert_eq!(unpack(w), (k, v));
        }
    }

    #[test]
    fn empty_sentinel() {
        assert!(is_empty(EMPTY_WORD));
        assert_eq!(unpack_key(EMPTY_WORD), EMPTY_KEY);
        assert!(!is_empty(pack(0, 0)));
        assert!(!key_is_valid(EMPTY_KEY));
        assert!(key_is_valid(0));
        // Any word whose low half is EMPTY_KEY is empty regardless of value.
        assert!(is_empty(pack(EMPTY_KEY, 123)));
    }

    #[test]
    fn bit_layout_matches_paper() {
        // key = pair & 0xFFFFFFFF, value = pair >> 32 (paper §III-A).
        let w = pack(0x1234_5678, 0x9ABC_DEF0);
        assert_eq!(w & 0xFFFF_FFFF, 0x1234_5678);
        assert_eq!(w >> 32, 0x9ABC_DEF0);
    }
}
