//! Vectorized match-and-elect bucket scans — the CPU ballot.
//!
//! The paper's WCME protocol owes its throughput to scanning a whole
//! packed bucket at once: 32 lanes each load one slot, a warp-wide
//! ballot turns the per-lane compares into one bitmask, and `ffs`
//! elects the winning lane. This module is that primitive for CPU rows:
//! [`match_mask`] scans a full 16/32-slot bucket row per step and
//! returns a candidate bitmask (bit *i* set ⇔ slot *i*'s stored key
//! half equals the probe half), [`empty_mask`] is the same ballot
//! against the EMPTY sentinel (claimable-slot discovery on the slot
//! image — the authoritative claim path stays the free-mask word), and
//! [`elect_match`] / [`elect_match_in`] do ballot + ffs + re-validate.
//!
//! Three engines produce the identical mask, selected at compile time:
//!
//! * **scalar** — one relaxed atomic load + compare-branch per slot;
//!   the reference semantics and the shape PR-6 shipped.
//! * **SWAR** (default) — two slot words per step: the low (key) halves
//!   are packed into one `u64` and a carry-free zero-detect tests both
//!   against the probe pattern branchlessly. Loads stay atomic, so this
//!   engine is also the one model-checked builds (`--cfg loom`) use.
//! * **SIMD** (`--features simd`, `x86_64` SSE2 / `aarch64` NEON) —
//!   four slot words per step through `core::arch`: gather the four low
//!   halves into one vector, one vector compare, one movemask. No new
//!   crates; other targets fall back to SWAR.
//!
//! ### Concurrent-memory caveat (why elect re-validates)
//!
//! Bucket rows mutate under the scan — that is the whole protocol. The
//! scalar/SWAR engines read each word with a relaxed *atomic* load, so
//! every tested half is some value the slot actually held. The SIMD
//! engine reads the row through vector loads that bypass the atomic
//! API; a concurrently-CASed word may tear across the vector read.
//! Every mask is therefore treated as a **heuristic filter**, never a
//! verdict: [`elect_match`] re-loads each elected lane with a real
//! atomic load and re-checks the half before reporting it, so a torn
//! false positive is dropped (and re-election continues with the next
//! candidate bit). A false *negative* — a slot published after its
//! word was scanned — is exactly the pre-existing race of the per-slot
//! loop, and the callers' `hit_valid` / `validate_miss` / CAS-commit
//! machinery already owns that window. Under `--cfg loom` the shim
//! `AtomicU64` is not layout-transparent, so the SIMD paths compile out
//! and the model checker exercises the SWAR engine.

use crate::core::packed::EMPTY_KEY;
use crate::core::sync::atomic::{AtomicU64, Ordering};

/// Low (key) half of a slot word.
#[inline(always)]
fn key_half(w: u64) -> u32 {
    w as u32
}

// ---------------------------------------------------------------------
// Scalar engine — reference semantics
// ---------------------------------------------------------------------

/// Per-slot reference scan: one relaxed load + compare per lane. Kept
/// unconditionally (all engines are differentially tested against it).
#[inline]
pub fn match_mask_scalar(row: &[AtomicU64], half: u32) -> u32 {
    let mut m = 0u32;
    for (lane, w) in row.iter().enumerate() {
        if key_half(w.load(Ordering::Relaxed)) == half {
            m |= 1 << lane;
        }
    }
    m
}

// ---------------------------------------------------------------------
// SWAR engine — two slots per step on one u64
// ---------------------------------------------------------------------

/// Per-half MSB and low-31 masks for the packed `[half | half]` word.
const SWAR_LOW31: u64 = 0x7FFF_FFFF_7FFF_FFFF;
const SWAR_HI: u64 = 0x8000_0000_8000_0000;

/// SWAR scan: the low halves of two consecutive slot words are packed
/// into one `u64` and both tested against the probe pattern with a
/// carry-free zero-in-half detect. The textbook `(v - 1s) & !v & hi`
/// trick is wrong here — its subtraction borrows *across* the 32-bit
/// half boundary — so the detect is formulated additively: a half is
/// zero iff neither its low 31 bits carry into the MSB position nor any
/// of its bits (MSB included) are set, and the add of `SWAR_LOW31`
/// cannot carry out of a half (0x7FFFFFFF + 0x7FFFFFFF < 2^32).
#[inline]
pub fn match_mask_swar(row: &[AtomicU64], half: u32) -> u32 {
    let pat = (half as u64) | ((half as u64) << 32);
    let mut m = 0u32;
    let mut lane = 0usize;
    while lane + 2 <= row.len() {
        let a = row[lane].load(Ordering::Relaxed);
        let b = row[lane + 1].load(Ordering::Relaxed);
        let packed = (a & 0xFFFF_FFFF) | (b << 32);
        let z = packed ^ pat; // a half is all-zero iff it matched
        let nz = ((z & SWAR_LOW31).wrapping_add(SWAR_LOW31)) | z;
        let zero = !nz & SWAR_HI; // bit 31 ⇔ lane, bit 63 ⇔ lane+1
        m |= (((zero >> 31) & 1) as u32) << lane;
        m |= (((zero >> 63) & 1) as u32) << (lane + 1);
        lane += 2;
    }
    if lane < row.len() && key_half(row[lane].load(Ordering::Relaxed)) == half {
        m |= 1 << lane;
    }
    m
}

// ---------------------------------------------------------------------
// SIMD engines — four slots per step through core::arch
// ---------------------------------------------------------------------

/// `x86_64` SSE2 scan (baseline on every x86_64 target — no runtime
/// dispatch needed). Two 128-bit loads cover four slot words;
/// `shuffle_ps` imm `0b10_00_10_00` gathers their four low dwords into
/// one vector for a single `pcmpeqd` + `movmskps`.
#[cfg(all(feature = "simd", not(loom), target_arch = "x86_64"))]
pub mod simd {
    use super::*;
    use core::arch::x86_64::{
        __m128i, _mm_castps_si128, _mm_castsi128_ps, _mm_cmpeq_epi32, _mm_loadu_si128,
        _mm_movemask_ps, _mm_set1_epi32, _mm_shuffle_ps,
    };

    /// Active engine label for bench/CI provenance.
    pub const ENGINE: &str = "simd-sse2";

    /// Vector scan of `row` for `half`. The loads bypass the atomic API
    /// (see module docs): the result is a heuristic filter the electors
    /// re-validate per lane.
    #[inline]
    pub fn match_mask_simd(row: &[AtomicU64], half: u32) -> u32 {
        let n = row.len();
        let ptr = row.as_ptr() as *const __m128i; // two u64 slots per vector
        let mut m = 0u32;
        let mut lane = 0usize;
        // SAFETY: `lane + 4 <= n` bounds both 16-byte loads inside the
        // row; `loadu` tolerates any alignment; `AtomicU64` has the same
        // in-memory representation as `u64` (std guarantee). Concurrent
        // writers make the values racy, not the access unsound at the
        // machine level — and every set bit is re-checked atomically.
        unsafe {
            let pat = _mm_set1_epi32(half as i32);
            while lane + 4 <= n {
                let a = _mm_loadu_si128(ptr.add(lane / 2)); // slots lane, lane+1
                let b = _mm_loadu_si128(ptr.add(lane / 2 + 1)); // slots lane+2, lane+3
                let lows = _mm_castps_si128(_mm_shuffle_ps(
                    _mm_castsi128_ps(a),
                    _mm_castsi128_ps(b),
                    0b10_00_10_00, // [a.dw0, a.dw2, b.dw0, b.dw2] = 4 key halves
                ));
                let eq = _mm_cmpeq_epi32(lows, pat);
                m |= (_mm_movemask_ps(_mm_castsi128_ps(eq)) as u32) << lane;
                lane += 4;
            }
        }
        while lane < n {
            if key_half(row[lane].load(Ordering::Relaxed)) == half {
                m |= 1 << lane;
            }
            lane += 1;
        }
        m
    }
}

/// `aarch64` NEON scan (NEON is baseline on aarch64). `vld2q_u32`
/// de-interleaves four slot words into a low-halves vector and a
/// high-halves vector in one structured load; one `vceqq` + a weighted
/// horizontal add extracts the four match bits.
#[cfg(all(feature = "simd", not(loom), target_arch = "aarch64"))]
pub mod simd {
    use super::*;
    use core::arch::aarch64::{vaddvq_u32, vandq_u32, vceqq_u32, vdupq_n_u32, vld1q_u32, vld2q_u32};

    /// Active engine label for bench/CI provenance.
    pub const ENGINE: &str = "simd-neon";

    /// Vector scan of `row` for `half`. Same heuristic-filter contract
    /// as the SSE2 engine (module docs).
    #[inline]
    pub fn match_mask_simd(row: &[AtomicU64], half: u32) -> u32 {
        let n = row.len();
        let ptr = row.as_ptr() as *const u32;
        let mut m = 0u32;
        let mut lane = 0usize;
        const WEIGHTS: [u32; 4] = [1, 2, 4, 8];
        // SAFETY: `lane + 4 <= n` bounds the 32-byte structured load
        // inside the row; `AtomicU64` is layout-identical to `u64`;
        // racy values are re-validated per elected lane (module docs).
        unsafe {
            let pat = vdupq_n_u32(half);
            let weights = vld1q_u32(WEIGHTS.as_ptr());
            while lane + 4 <= n {
                // [lo0,hi0,lo1,hi1,lo2,hi2,lo3,hi3] → .0 = the key halves
                let pairs = vld2q_u32(ptr.add(lane * 2));
                let eq = vceqq_u32(pairs.0, pat); // all-ones per matching half
                m |= vaddvq_u32(vandq_u32(eq, weights)) << lane;
                lane += 4;
            }
        }
        while lane < n {
            if key_half(row[lane].load(Ordering::Relaxed)) == half {
                m |= 1 << lane;
            }
            lane += 1;
        }
        m
    }
}

// ---------------------------------------------------------------------
// Compile-time dispatch
// ---------------------------------------------------------------------

/// Whether the vector engine is compiled in (feature + target + not a
/// model-checked build).
#[cfg(all(feature = "simd", not(loom), any(target_arch = "x86_64", target_arch = "aarch64")))]
const HAVE_SIMD: bool = true;
#[cfg(not(all(feature = "simd", not(loom), any(target_arch = "x86_64", target_arch = "aarch64"))))]
const HAVE_SIMD: bool = false;

/// Name of the engine [`match_mask`] dispatches to — stamped into bench
/// JSON and CI logs so a run's numbers carry their provenance.
pub fn engine_name() -> &'static str {
    #[cfg(all(feature = "simd", not(loom), any(target_arch = "x86_64", target_arch = "aarch64")))]
    {
        simd::ENGINE
    }
    #[cfg(not(all(
        feature = "simd",
        not(loom),
        any(target_arch = "x86_64", target_arch = "aarch64")
    )))]
    {
        "swar"
    }
}

/// Ballot: scan the whole bucket `row` and return the candidate bitmask
/// of lanes whose stored key half equals `half`. Engine selected at
/// compile time ([`engine_name`]); all engines agree on quiescent rows
/// (differentially tested), and electors re-validate under concurrency.
#[inline(always)]
pub fn match_mask(row: &[AtomicU64], half: u32) -> u32 {
    #[cfg(all(feature = "simd", not(loom), any(target_arch = "x86_64", target_arch = "aarch64")))]
    {
        simd::match_mask_simd(row, half)
    }
    #[cfg(not(all(
        feature = "simd",
        not(loom),
        any(target_arch = "x86_64", target_arch = "aarch64")
    )))]
    {
        match_mask_swar(row, half)
    }
}

/// Ballot against the EMPTY sentinel: bit *i* set ⇔ slot *i*'s word
/// reads as vacant in the slot image. Discovery only — claiming goes
/// through the bucket's free-mask word, whose RMWs totally order
/// claimers and migrators; a mid-publish claimed slot still reads EMPTY
/// here, exactly as it does for the free-mask-guided scans.
#[inline(always)]
pub fn empty_mask(row: &[AtomicU64]) -> u32 {
    match_mask(row, EMPTY_KEY)
}

/// Ballot + ffs + re-validate: elect the lowest candidate lane whose
/// *atomically re-loaded* word still matches `half`, returning the lane
/// and that word. Torn or stale mask bits are simply skipped; `None`
/// means no lane currently holds `half` (up to the scan race the
/// callers' miss validation owns). Memory-ordering note: loads here are
/// relaxed — callers needing publish ordering on a hit issue their own
/// `Acquire` fence, as the probe cores do.
#[inline]
pub fn elect_match(row: &[AtomicU64], half: u32) -> Option<(usize, u64)> {
    elect_match_in(row, half, u32::MAX)
}

/// [`elect_match`] restricted to the lanes of `allowed` — the
/// mask-guided WCME variant (insert's replace check feeds the occupied
/// lanes from the free-mask word). The vector scan reads the whole row
/// regardless (the row *is* the cache-line unit); `allowed` prunes the
/// election, preserving the guided scan's semantics: lanes claimed but
/// mid-publish are excluded even if their slot image momentarily
/// matches.
#[inline]
pub fn elect_match_in(row: &[AtomicU64], half: u32, allowed: u32) -> Option<(usize, u64)> {
    let mut m = match_mask(row, half) & allowed;
    while m != 0 {
        let lane = m.trailing_zeros() as usize;
        m &= m - 1;
        let w = row[lane].load(Ordering::Relaxed);
        if key_half(w) == half {
            return Some((lane, w));
        }
    }
    None
}

/// `true` when [`match_mask`] dispatches to a `core::arch` vector
/// engine (bench/CI provenance; also lets the differential battery know
/// whether a third engine exists to compare).
pub fn simd_active() -> bool {
    HAVE_SIMD
}

#[cfg(all(test, not(loom)))]
mod tests {
    use super::*;
    use crate::core::packed::{pack, EMPTY_WORD};

    fn row_of(halves: &[u32]) -> Vec<AtomicU64> {
        halves
            .iter()
            .map(|&h| {
                AtomicU64::new(if h == EMPTY_KEY { EMPTY_WORD } else { pack(h, h ^ 0xBEEF) })
            })
            .collect()
    }

    /// A named engine, uniformly callable.
    type Engine = (&'static str, fn(&[AtomicU64], u32) -> u32);

    /// Every engine the build carries.
    fn engines() -> Vec<Engine> {
        let mut v: Vec<Engine> = vec![
            ("scalar", match_mask_scalar),
            ("swar", match_mask_swar),
            ("dispatch", match_mask),
        ];
        #[cfg(all(feature = "simd", any(target_arch = "x86_64", target_arch = "aarch64")))]
        v.push((simd::ENGINE, simd::match_mask_simd));
        v
    }

    #[test]
    fn planted_matches_exact_mask() {
        for width in [16usize, 32] {
            let mut halves = vec![EMPTY_KEY; width];
            halves[0] = 7;
            halves[3] = 9;
            halves[width - 1] = 7;
            let row = row_of(&halves);
            let expect7: u32 = 1 | (1u32 << (width - 1));
            for (name, f) in engines() {
                assert_eq!(f(&row, 7), expect7, "{name} width {width} probe 7");
                assert_eq!(f(&row, 9), 1u32 << 3, "{name} width {width} probe 9");
                assert_eq!(f(&row, 1234), 0, "{name} width {width} absent probe");
            }
            let full: u32 = ((1u64 << width) - 1) as u32;
            assert_eq!(empty_mask(&row), !(expect7 | 1u32 << 3) & full);
        }
    }

    #[test]
    fn engines_agree_on_random_rows() {
        use crate::testutil::seed::{stream, test_seed};
        let mut x = stream(test_seed(0x1a), 0xe5) | 1;
        let mut rng = move || {
            x ^= x << 13;
            x ^= x >> 7;
            x ^= x << 17;
            x
        };
        for width in [16usize, 32] {
            for _ in 0..2000 {
                let halves: Vec<u32> = (0..width)
                    .map(|_| {
                        let r = rng();
                        if r & 3 == 0 {
                            EMPTY_KEY
                        } else {
                            // small alphabet ⇒ frequent multi-lane matches
                            (r >> 8) as u32 % 5
                        }
                    })
                    .collect();
                let row = row_of(&halves);
                let probe = (rng() % 6) as u32; // sometimes absent
                let reference = match_mask_scalar(&row, probe);
                for (name, f) in engines() {
                    assert_eq!(f(&row, probe), reference, "{name} diverged, width {width}");
                }
                // Elected lane: lowest set bit, word re-validated.
                let elected = elect_match(&row, probe);
                match reference {
                    0 => assert!(elected.is_none()),
                    m => {
                        let lane = m.trailing_zeros() as usize;
                        let (el, ew) = elected.expect("mask nonzero on quiescent row");
                        assert_eq!(el, lane);
                        assert_eq!(ew, row[lane].load(Ordering::Relaxed));
                    }
                }
            }
        }
    }

    #[test]
    fn swar_and_simd_handle_odd_tails() {
        // Off-width rows exercise the scalar tail of each stepped engine.
        for width in [1usize, 3, 5, 7, 15, 17] {
            let mut halves: Vec<u32> = (0..width as u32).collect();
            halves[width - 1] = 42;
            let row = row_of(&halves);
            for (name, f) in engines() {
                assert_eq!(f(&row, 42), 1 << (width - 1), "{name} tail, width {width}");
            }
        }
    }

    #[test]
    fn elect_respects_allowed_mask() {
        let row = row_of(&[5, 5, 5, EMPTY_KEY]);
        assert_eq!(elect_match(&row, 5).map(|(l, _)| l), Some(0));
        assert_eq!(elect_match_in(&row, 5, 0b0110).map(|(l, _)| l), Some(1));
        assert_eq!(elect_match_in(&row, 5, 0b1000), None, "allowed lane holds EMPTY");
        assert_eq!(elect_match_in(&row, 5, 0), None);
    }

    #[test]
    fn engine_name_is_coherent() {
        let name = engine_name();
        assert!(!name.is_empty());
        assert_eq!(simd_active(), name.starts_with("simd-"));
    }
}
