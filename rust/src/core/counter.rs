//! Cache-line-padded striped counter for hot shared tallies.
//!
//! The GPU table tracks occupancy with one global atomic that every warp
//! updates; the CPU analogue — a single `AtomicUsize` hit by every
//! insert/delete — becomes a coherence hot spot: one cache line ping-pongs
//! between all cores, and at batch op rates the `lock xadd` traffic
//! dominates the actual probe work. [`StripedCounter`] splits the tally
//! across [`STRIPES`] cache-line-padded cells; each thread is assigned a
//! home stripe at first use, so concurrent updates from different threads
//! land on distinct lines. Reads sum all stripes — exact when quiescent,
//! approximate under concurrent updates (the same contract as the single
//! atomic it replaces).

use crate::core::sync::atomic::{AtomicI64, Ordering};

/// Stripe count (power of two). 16 stripes × 128 B = 2 KiB per counter —
/// enough to spread realistic CPU thread counts with rare collisions.
pub const STRIPES: usize = 16;

/// One padded cell. 128-byte alignment keeps stripes on distinct lines
/// even with the x86 adjacent-line prefetcher pairing 64-byte lines.
#[repr(align(128))]
struct Stripe(AtomicI64);

/// A signed striped counter. Individual stripes may go negative (a thread
/// that only deletes drives its stripe below zero) even though the logical
/// total stays non-negative; [`StripedCounter::sum`] clamps at zero.
pub struct StripedCounter {
    stripes: [Stripe; STRIPES],
}

impl Default for StripedCounter {
    fn default() -> Self {
        Self::new()
    }
}

impl StripedCounter {
    /// New counter at zero.
    pub fn new() -> Self {
        StripedCounter { stripes: std::array::from_fn(|_| Stripe(AtomicI64::new(0))) }
    }

    /// This thread's home stripe: threads are numbered in first-use order
    /// and mapped round-robin (via the facade's shared
    /// [`crate::core::sync::thread_index`]), so up to [`STRIPES`]
    /// concurrent threads never share a line.
    #[inline]
    fn home() -> usize {
        crate::core::sync::thread_index() & (STRIPES - 1)
    }

    /// Add `delta` (possibly negative) to this thread's home stripe.
    #[inline]
    pub fn add(&self, delta: i64) {
        self.stripes[Self::home()].0.fetch_add(delta, Ordering::Relaxed);
    }

    /// Increment by one.
    #[inline]
    pub fn incr(&self) {
        self.add(1);
    }

    /// Decrement by one.
    #[inline]
    pub fn decr(&self) {
        self.add(-1);
    }

    /// Sum of all stripes, clamped at zero. Exact when no updates are in
    /// flight; otherwise approximate, like any concurrently-read counter.
    pub fn sum(&self) -> usize {
        let total: i64 = self.stripes.iter().map(|s| s.0.load(Ordering::Relaxed)).sum();
        total.max(0) as usize
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn starts_at_zero_and_counts() {
        let c = StripedCounter::new();
        assert_eq!(c.sum(), 0);
        c.incr();
        c.incr();
        c.decr();
        assert_eq!(c.sum(), 1);
        c.add(10);
        assert_eq!(c.sum(), 11);
    }

    #[test]
    fn concurrent_updates_sum_exactly() {
        let c = Arc::new(StripedCounter::new());
        let threads: Vec<_> = (0..8)
            .map(|_| {
                let c = Arc::clone(&c);
                std::thread::spawn(move || {
                    for _ in 0..10_000 {
                        c.incr();
                    }
                    for _ in 0..2_500 {
                        c.decr();
                    }
                })
            })
            .collect();
        for t in threads {
            t.join().unwrap();
        }
        assert_eq!(c.sum(), 8 * 7_500);
    }

    #[test]
    fn stripes_are_padded() {
        // each stripe occupies its own (pair of) cache line(s)
        assert_eq!(std::mem::align_of::<Stripe>(), 128);
        assert!(std::mem::size_of::<StripedCounter>() >= STRIPES * 128);
    }
}
