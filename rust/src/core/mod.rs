//! Shared foundation types: packed key-value codec, configuration, errors,
//! deterministic PRNG / samplers, and latency histograms.

pub mod packed;
pub mod config;
pub mod counter;
pub mod epoch;
pub mod error;
pub mod lanes;
pub mod model;
pub mod quotient;
pub mod rng;
pub mod histogram;
pub mod sync;

pub use counter::StripedCounter;
pub use epoch::{EpochDomain, EpochGuard};

/// Number of slots per bucket. One warp (32 lanes) probes one bucket with
/// one lane per slot (paper §III-A); a full bucket of 64-bit entries is
/// 256 bytes = two 128-byte cache lines.
pub const SLOTS_PER_BUCKET: usize = 32;

/// A free-mask word with every slot available (bit i == 1 ⇒ slot i free).
pub const FULL_FREE_MASK: u32 = u32::MAX;

/// Slots per bucket under [`config::Layout::CompactQuotient`]: quotienting
/// shrinks nothing per-entry (words stay 64-bit for the single-CAS
/// protocol) but halving the bucket to 16 slots makes one bucket row fit a
/// single 128-byte cache line instead of two, and probe success at equal
/// load factor is preserved by the reclaimed key bits' collision-free
/// remainder match.
pub const COMPACT_SLOTS_PER_BUCKET: usize = 16;

/// Default bound on cuckoo displacement chains (paper `max_evictions`).
pub const DEFAULT_MAX_EVICTIONS: u32 = 16;

/// Load factor above which the resize controller grows the table (§IV-C).
pub const DEFAULT_GROW_THRESHOLD: f64 = 0.90;

/// Load factor below which the resize controller shrinks the table (§IV-C).
pub const DEFAULT_SHRINK_THRESHOLD: f64 = 0.25;

/// Stash capacity as a fraction of main-table slot capacity (§IV-A step 4:
/// "typically 1-2% of the main table capacity").
pub const DEFAULT_STASH_FRACTION: f64 = 0.02;

/// Default number of in-flight probe state machines per thread in the
/// bulk batch paths ([`crate::native::batch`]): each in-flight op's next
/// bucket line is prefetched G ops ahead, so a batch overlaps G cache
/// misses where the per-op path overlaps one — the CPU analogue of the
/// GPU's warp-level latency hiding (AMAC-style group prefetching). G = 8
/// covers typical DRAM latency at per-op costs of a few dozen ns without
/// overrunning L1 with speculative lines.
pub const DEFAULT_BATCH_INTERLEAVE: usize = 8;
