//! Deterministic, dependency-free PRNG and samplers.
//!
//! Benchmarks and tests need reproducible key streams; the registry has no
//! `rand` crate, so we carry a SplitMix64 seeder + xoshiro256** generator
//! (public-domain algorithms) and a Zipf rejection sampler for skewed
//! workloads.

/// SplitMix64 — used to seed xoshiro and as a cheap standalone mixer.
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// xoshiro256** generator (Blackman & Vigna), deterministic from a seed.
#[derive(Debug, Clone)]
pub struct Xoshiro256 {
    s: [u64; 4],
}

impl Xoshiro256 {
    /// Seed all four lanes through SplitMix64 (never all-zero).
    pub fn seeded(seed: u64) -> Self {
        let mut sm = seed;
        Xoshiro256 {
            s: [splitmix64(&mut sm), splitmix64(&mut sm), splitmix64(&mut sm), splitmix64(&mut sm)],
        }
    }

    /// Next 64 random bits.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Next 32 random bits.
    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in `[0, bound)` via Lemire's multiply-shift (bound > 0).
    #[inline]
    pub fn below(&mut self, bound: u64) -> u64 {
        debug_assert!(bound > 0);
        ((self.next_u64() as u128 * bound as u128) >> 64) as u64
    }

    /// Uniform f64 in `[0, 1)`.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }
}

/// `H(x) = ∫x^{-θ}` for the rejection-inversion sampler: `x^{1-θ}/(1-θ)`,
/// degenerating to `ln x` at θ = 1. Single source for both the sampler
/// loop and the precomputed constants in [`Zipf::new`].
fn h_integral(x: f64, theta: f64) -> f64 {
    if (theta - 1.0).abs() < 1e-12 {
        x.ln()
    } else {
        x.powf(1.0 - theta) / (1.0 - theta)
    }
}

/// Inverse of [`h_integral`] at the same θ.
fn h_integral_inv(x: f64, theta: f64) -> f64 {
    if (theta - 1.0).abs() < 1e-12 {
        x.exp()
    } else {
        (x * (1.0 - theta)).powf(1.0 / (1.0 - theta))
    }
}

/// Zipf(θ) sampler over `{0, .., n-1}` using the rejection-inversion method
/// (Hörmann & Derflinger); θ = 0 degenerates to uniform.
#[derive(Debug, Clone)]
pub struct Zipf {
    n: u64,
    theta: f64,
    h_x1: f64,
    h_n: f64,
    s: f64,
}

impl Zipf {
    /// Build a sampler over `n` items with skew `theta ∈ [0, ~2]`.
    pub fn new(n: u64, theta: f64) -> Self {
        assert!(n > 0);
        let h_x1 = h_integral(1.5, theta) - 1.0f64.powf(-theta);
        let h_n = h_integral(n as f64 + 0.5, theta);
        // Hörmann–Derflinger rejection-inversion threshold: a draw whose
        // rounded rank k lies within `s` of the inverted point x is
        // accepted without evaluating the exact acceptance bound.
        // s = 2 - H⁻¹(H(2.5) - 2^{-θ}); see Hörmann & Derflinger,
        // "Rejection-inversion to generate variates from monotone
        // discrete distributions" (TOMACS 1996), eq. for x_m = 2.
        let s = 2.0 - h_integral_inv(h_integral(2.5, theta) - 2.0f64.powf(-theta), theta);
        Zipf { n, theta, h_x1, h_n, s }
    }

    fn h(&self, x: f64) -> f64 {
        h_integral(x, self.theta)
    }

    fn h_inv(&self, x: f64) -> f64 {
        h_integral_inv(x, self.theta)
    }

    /// Draw one sample (0-based rank; rank 0 is the hottest item).
    pub fn sample(&self, rng: &mut Xoshiro256) -> u64 {
        if self.theta < 1e-9 {
            return rng.below(self.n);
        }
        loop {
            let u = self.h_n + rng.f64() * (self.h_x1 - self.h_n);
            let x = self.h_inv(u);
            let k = (x + 0.5).floor().clamp(1.0, self.n as f64);
            // One-sided HD acceptance: k ≥ x - s short-circuits; otherwise
            // fall back to the exact bound H(k + ½) - k^{-θ}.
            if k - x <= self.s || u >= self.h(k + 0.5) - (k).powf(-self.theta) {
                return k as u64 - 1;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_streams() {
        let mut a = Xoshiro256::seeded(42);
        let mut b = Xoshiro256::seeded(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Xoshiro256::seeded(43);
        assert_ne!(a.next_u64(), c.next_u64());
    }

    #[test]
    fn below_is_in_range() {
        let mut rng = Xoshiro256::seeded(7);
        for bound in [1u64, 2, 3, 10, 1000, 1 << 40] {
            for _ in 0..200 {
                assert!(rng.below(bound) < bound);
            }
        }
    }

    #[test]
    fn f64_unit_interval() {
        let mut rng = Xoshiro256::seeded(9);
        let mut sum = 0.0;
        for _ in 0..10_000 {
            let x = rng.f64();
            assert!((0.0..1.0).contains(&x));
            sum += x;
        }
        let mean = sum / 10_000.0;
        assert!((mean - 0.5).abs() < 0.02, "mean {mean}");
    }

    #[test]
    fn zipf_uniform_degenerates() {
        let z = Zipf::new(100, 0.0);
        let mut rng = Xoshiro256::seeded(1);
        let mut counts = [0usize; 100];
        for _ in 0..100_000 {
            counts[z.sample(&mut rng) as usize] += 1;
        }
        // roughly uniform: every bin within 4x of the expectation
        for &c in &counts {
            assert!(c > 250 && c < 4000, "count {c}");
        }
    }

    #[test]
    fn zipf_skew_orders_ranks() {
        let z = Zipf::new(1000, 0.99);
        let mut rng = Xoshiro256::seeded(2);
        let mut counts = vec![0usize; 1000];
        for _ in 0..200_000 {
            counts[z.sample(&mut rng) as usize] += 1;
        }
        // hottest rank dominates the tail by a wide margin
        assert!(counts[0] > 10 * counts[500].max(1));
        assert!(counts[0] > counts[1]);
    }

    #[test]
    fn zipf_rank_frequency_monotone_at_high_skew() {
        // θ = 0.99, geometric rank buckets [1], [2,3], [4,7], ..: the mean
        // per-rank frequency must fall strictly bucket over bucket. The
        // dead `s = 1.0` placeholder skewed acceptance enough to flatten
        // the head; the real HD threshold restores the power law.
        let n = 1024u64;
        let z = Zipf::new(n, 0.99);
        let mut rng = Xoshiro256::seeded(0xF00D);
        let mut counts = vec![0u64; n as usize];
        let samples = 400_000;
        for _ in 0..samples {
            counts[z.sample(&mut rng) as usize] += 1;
        }
        let mut lo = 0usize;
        let mut width = 1usize;
        let mut prev = f64::INFINITY;
        while lo < n as usize {
            let hi = (lo + width).min(n as usize);
            let mean = counts[lo..hi].iter().sum::<u64>() as f64 / (hi - lo) as f64;
            assert!(
                mean < prev,
                "rank bucket [{lo}, {hi}) mean {mean} not below previous {prev}"
            );
            prev = mean;
            lo = hi;
            width *= 2;
        }
        // the head really dominates: rank 0 takes >~ 1/H_n of the mass
        assert!(counts[0] as f64 > 0.10 * samples as f64, "head too light: {}", counts[0]);
    }

    #[test]
    fn zipf_theta_zero_uniform_across_deciles() {
        // θ = 0 must be statistically uniform: every decile of the rank
        // space within 5% of the expected tenth of the mass.
        let n = 1000u64;
        let z = Zipf::new(n, 0.0);
        let mut rng = Xoshiro256::seeded(0xBEEF);
        let samples = 500_000usize;
        let mut deciles = [0u64; 10];
        for _ in 0..samples {
            deciles[(z.sample(&mut rng) / 100) as usize] += 1;
        }
        let expect = samples as f64 / 10.0;
        for (d, &c) in deciles.iter().enumerate() {
            let dev = (c as f64 - expect).abs() / expect;
            assert!(dev < 0.05, "decile {d} off by {:.1}% ({c} vs {expect})", dev * 100.0);
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Xoshiro256::seeded(3);
        let mut xs: Vec<u32> = (0..1000).collect();
        rng.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..1000).collect::<Vec<_>>());
        assert_ne!(xs, (0..1000).collect::<Vec<_>>());
    }
}
