//! Typed configuration for tables, the coordinator, and benchmarks.
//!
//! Configs can be built programmatically (builder-style setters), loaded
//! from a simple `key = value` file (comments with `#`), or overridden from
//! `HIVE_*` environment variables — a small, dependency-free analogue of the
//! config systems in serving frameworks.

use crate::core::error::{HiveError, Result};
use crate::core::{
    DEFAULT_BATCH_INTERLEAVE, DEFAULT_GROW_THRESHOLD, DEFAULT_MAX_EVICTIONS,
    DEFAULT_SHRINK_THRESHOLD, DEFAULT_STASH_FRACTION, SLOTS_PER_BUCKET,
};
use crate::hash::HashKind;
use std::collections::BTreeMap;
use std::path::Path;

/// Which bucket memory layout the native table uses. `PackedAos` is the
/// paper's contribution; `SplitSoa` is the two-phase-update ablation
/// (DESIGN.md §6); `CompactQuotient` trades stored key bits for cache-line
/// density at high load factors.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Layout {
    /// 64-bit packed key-value words, single-CAS publish (paper §III-A).
    /// 32 slots per bucket — one bucket row spans two 128-byte lines.
    PackedAos,
    /// Separate key / value arrays: CAS on key, relaxed store of value.
    SplitSoa,
    /// Quotiented keys ([`crate::core::quotient`]): the bucket index is
    /// the low bits of the key's hash, so the stored word keeps only the
    /// hash *remainder* plus a 2-bit candidate tag in the key half. Words
    /// stay 64-bit (the single-CAS publish, migration markers, and free
    /// masks are untouched) but buckets shrink to 16 slots, fitting one
    /// bucket row in a single 128-byte cache line — fewer lines per probe
    /// and a higher sustainable load factor at 0.85–0.97. Requires an
    /// invertible hash family of `d <= 3` (tags are 2 bits and the key
    /// must be reconstructible), which config validation enforces.
    CompactQuotient,
}

impl Layout {
    /// Slots per bucket this layout packs into one bucket row.
    #[inline]
    pub fn slots_per_bucket(self) -> usize {
        match self {
            Layout::CompactQuotient => crate::core::COMPACT_SLOTS_PER_BUCKET,
            Layout::PackedAos | Layout::SplitSoa => SLOTS_PER_BUCKET,
        }
    }
}

/// Top-level configuration for a Hive table instance.
#[derive(Debug, Clone)]
pub struct HiveConfig {
    /// Initial number of buckets (rounded up to a power of two).
    pub initial_buckets: usize,
    /// Hash family used to derive candidate buckets (d = len ≥ 2).
    pub hash_kinds: Vec<HashKind>,
    /// Bound on cuckoo displacement chains (paper `max_evictions`).
    pub max_evictions: u32,
    /// Load factor that triggers expansion (paper: 0.9).
    pub grow_threshold: f64,
    /// Load factor that triggers contraction (paper: 0.25).
    pub shrink_threshold: f64,
    /// Overflow-stash capacity as a fraction of slot capacity (1–2 %).
    pub stash_fraction: f64,
    /// Buckets split/merged per resize batch (paper K).
    pub resize_batch: usize,
    /// Bucket layout (packed AoS vs split SoA ablation).
    pub layout: Layout,
    /// In-flight probe state machines per thread in the bulk batch paths
    /// (AMAC-style interleave depth G): op *i*'s execution overlaps the
    /// prefetch of op *i+G*'s first bucket line. 1 disables the
    /// overlap (prefetch immediately precedes each probe); tunable via
    /// `HIVE_BATCH_INTERLEAVE`.
    pub batch_interleave: usize,
}

impl Default for HiveConfig {
    fn default() -> Self {
        HiveConfig {
            initial_buckets: 1024,
            hash_kinds: vec![HashKind::BitHash1, HashKind::BitHash2],
            max_evictions: DEFAULT_MAX_EVICTIONS,
            grow_threshold: DEFAULT_GROW_THRESHOLD,
            shrink_threshold: DEFAULT_SHRINK_THRESHOLD,
            stash_fraction: DEFAULT_STASH_FRACTION,
            resize_batch: 256,
            layout: Layout::PackedAos,
            batch_interleave: DEFAULT_BATCH_INTERLEAVE,
        }
    }
}

impl HiveConfig {
    /// Config sized so `n` keys fit at `target_lf` load factor.
    pub fn for_capacity(n: usize, target_lf: f64) -> Self {
        let slots = (n as f64 / target_lf).ceil() as usize;
        let buckets = (slots + SLOTS_PER_BUCKET - 1) / SLOTS_PER_BUCKET;
        HiveConfig { initial_buckets: buckets.next_power_of_two().max(4), ..Self::default() }
    }

    /// Builder-style setter for the initial bucket count.
    pub fn with_buckets(mut self, buckets: usize) -> Self {
        self.initial_buckets = buckets;
        self
    }

    /// Builder-style setter for the hash family.
    pub fn with_hashes(mut self, kinds: Vec<HashKind>) -> Self {
        self.hash_kinds = kinds;
        self
    }

    /// Builder-style setter for the eviction bound.
    pub fn with_max_evictions(mut self, bound: u32) -> Self {
        self.max_evictions = bound;
        self
    }

    /// Builder-style setter for the layout ablation.
    pub fn with_layout(mut self, layout: Layout) -> Self {
        self.layout = layout;
        self
    }

    /// Builder-style setter for resize thresholds.
    pub fn with_thresholds(mut self, grow: f64, shrink: f64) -> Self {
        self.grow_threshold = grow;
        self.shrink_threshold = shrink;
        self
    }

    /// Builder-style setter for the bulk interleave depth G.
    pub fn with_interleave(mut self, depth: usize) -> Self {
        self.batch_interleave = depth;
        self
    }

    /// Validate invariants (hash family size, thresholds ordered, ...).
    pub fn validate(&self) -> Result<()> {
        if self.hash_kinds.len() < 2 || self.hash_kinds.len() > 4 {
            return Err(HiveError::Config(format!(
                "hash family must have 2..=4 functions, got {}",
                self.hash_kinds.len()
            )));
        }
        if self.initial_buckets < 2 {
            return Err(HiveError::BadCapacity(self.initial_buckets));
        }
        if !(self.shrink_threshold < self.grow_threshold && self.grow_threshold <= 1.0) {
            return Err(HiveError::Config(format!(
                "thresholds must satisfy shrink < grow <= 1.0, got {} / {}",
                self.shrink_threshold, self.grow_threshold
            )));
        }
        if self.max_evictions == 0 {
            return Err(HiveError::Config("max_evictions must be >= 1".into()));
        }
        if !(0.0..=0.5).contains(&self.stash_fraction) {
            return Err(HiveError::Config("stash_fraction must be in [0, 0.5]".into()));
        }
        if !(1..=64).contains(&self.batch_interleave) {
            return Err(HiveError::Config(format!(
                "batch_interleave must be in 1..=64, got {}",
                self.batch_interleave
            )));
        }
        if self.layout == Layout::CompactQuotient {
            if self.hash_kinds.len() > 3 {
                return Err(HiveError::Config(format!(
                    "compact layout stores a 2-bit candidate tag, so d <= 3; got {}",
                    self.hash_kinds.len()
                )));
            }
            if let Some(k) = self.hash_kinds.iter().find(|k| !k.invertible()) {
                return Err(HiveError::Config(format!(
                    "compact layout must reconstruct keys from remainders; {} is not invertible",
                    k.name()
                )));
            }
            if self.initial_buckets < 4 {
                return Err(HiveError::Config(
                    "compact layout needs >= 4 buckets (remainders carry at most 30 bits)".into(),
                ));
            }
        }
        Ok(())
    }

    /// Parse a `key = value` config file (`#` comments, blank lines ok).
    pub fn from_file(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| HiveError::Config(format!("{}: {e}", path.display())))?;
        Self::from_kv_text(&text)
    }

    /// Parse config text in `key = value` form.
    pub fn from_kv_text(text: &str) -> Result<Self> {
        let mut map = BTreeMap::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = raw.split('#').next().unwrap_or("").trim();
            if line.is_empty() {
                continue;
            }
            let (k, v) = line.split_once('=').ok_or_else(|| {
                HiveError::Config(format!("line {}: expected key = value", lineno + 1))
            })?;
            map.insert(k.trim().to_string(), v.trim().to_string());
        }
        let mut cfg = HiveConfig::default();
        cfg.apply_kv(&map)?;
        cfg.validate()?;
        Ok(cfg)
    }

    /// Apply `HIVE_*` environment variable overrides (e.g. `HIVE_MAX_EVICTIONS`).
    pub fn apply_env(&mut self) -> Result<()> {
        let mut map = BTreeMap::new();
        for (k, v) in std::env::vars() {
            if let Some(stripped) = k.strip_prefix("HIVE_") {
                map.insert(stripped.to_ascii_lowercase(), v);
            }
        }
        self.apply_kv(&map)
    }

    fn apply_kv(&mut self, map: &BTreeMap<String, String>) -> Result<()> {
        fn parse<T: std::str::FromStr>(key: &str, v: &str) -> Result<T> {
            v.parse::<T>().map_err(|_| HiveError::Config(format!("bad value for {key}: {v}")))
        }
        for (k, v) in map {
            match k.as_str() {
                "initial_buckets" => self.initial_buckets = parse(k, v)?,
                "max_evictions" => self.max_evictions = parse(k, v)?,
                "grow_threshold" => self.grow_threshold = parse(k, v)?,
                "shrink_threshold" => self.shrink_threshold = parse(k, v)?,
                "stash_fraction" => self.stash_fraction = parse(k, v)?,
                "resize_batch" => self.resize_batch = parse(k, v)?,
                "batch_interleave" => self.batch_interleave = parse(k, v)?,
                "layout" => {
                    self.layout = match v.as_str() {
                        "packed_aos" | "aos" => Layout::PackedAos,
                        "split_soa" | "soa" => Layout::SplitSoa,
                        "compact" | "compact_quotient" => Layout::CompactQuotient,
                        other => return Err(HiveError::Config(format!("bad layout: {other}"))),
                    }
                }
                "hashes" => {
                    let kinds = v
                        .split(',')
                        .map(|s| HashKind::parse(s.trim()))
                        .collect::<Option<Vec<_>>>()
                        .ok_or_else(|| HiveError::Config(format!("bad hash list: {v}")))?;
                    self.hash_kinds = kinds;
                }
                other => return Err(HiveError::Config(format!("unknown config key: {other}"))),
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_is_valid() {
        HiveConfig::default().validate().unwrap();
    }

    #[test]
    fn capacity_sizing() {
        let cfg = HiveConfig::for_capacity(1 << 20, 0.9);
        // 2^20 keys at lf 0.9 needs ~36k buckets -> next pow2 = 65536.
        assert_eq!(cfg.initial_buckets, 65536);
        assert!(cfg.initial_buckets * SLOTS_PER_BUCKET >= (1 << 20));
    }

    #[test]
    fn kv_text_parsing() {
        let cfg = HiveConfig::from_kv_text(
            "# comment\ninitial_buckets = 2048\nmax_evictions = 8\nhashes = murmur3, crc32\nlayout = soa\n",
        )
        .unwrap();
        assert_eq!(cfg.initial_buckets, 2048);
        assert_eq!(cfg.max_evictions, 8);
        assert_eq!(cfg.hash_kinds, vec![HashKind::Murmur3, HashKind::Crc32]);
        assert_eq!(cfg.layout, Layout::SplitSoa);
    }

    #[test]
    fn interleave_knob() {
        assert_eq!(HiveConfig::default().batch_interleave, 8);
        let cfg = HiveConfig::from_kv_text("batch_interleave = 4").unwrap();
        assert_eq!(cfg.batch_interleave, 4);
        assert_eq!(HiveConfig::default().with_interleave(1).batch_interleave, 1);
        assert!(HiveConfig::from_kv_text("batch_interleave = 0").is_err());
        assert!(HiveConfig::default().with_interleave(65).validate().is_err());
    }

    #[test]
    fn rejects_bad_configs() {
        assert!(HiveConfig::from_kv_text("max_evictions = 0").is_err());
        assert!(HiveConfig::from_kv_text("grow_threshold = 0.1\nshrink_threshold = 0.5").is_err());
        assert!(HiveConfig::from_kv_text("hashes = murmur3").is_err());
        assert!(HiveConfig::from_kv_text("nonsense = 1").is_err());
        assert!(HiveConfig::from_kv_text("initial_buckets = banana").is_err());
    }

    #[test]
    fn compact_layout_rules() {
        // `compact` parses, and the default BitHash pair satisfies its rules.
        let cfg = HiveConfig::from_kv_text("layout = compact").unwrap();
        assert_eq!(cfg.layout, Layout::CompactQuotient);
        assert_eq!(cfg.layout.slots_per_bucket(), 16);
        assert_eq!(Layout::PackedAos.slots_per_bucket(), 32);
        // Non-invertible hashes are rejected for compact only.
        let crc = HiveConfig::from_kv_text("layout = compact_quotient\nhashes = murmur3, crc32");
        assert!(crc.is_err(), "crc32 cannot back a quotiented layout");
        assert!(HiveConfig::from_kv_text("hashes = murmur3, crc32").is_ok());
        // d = 4 overflows the 2-bit candidate tag.
        let wide = HiveConfig::default()
            .with_layout(Layout::CompactQuotient)
            .with_hashes(vec![
                HashKind::BitHash1,
                HashKind::BitHash2,
                HashKind::Murmur3,
                HashKind::Murmur3,
            ]);
        assert!(wide.validate().is_err());
        // d = 3 invertible family is fine.
        let three = HiveConfig::default()
            .with_layout(Layout::CompactQuotient)
            .with_hashes(vec![HashKind::BitHash1, HashKind::BitHash2, HashKind::Murmur3]);
        three.validate().unwrap();
    }

    #[test]
    fn builder_setters() {
        let cfg = HiveConfig::default()
            .with_buckets(512)
            .with_max_evictions(4)
            .with_thresholds(0.8, 0.2)
            .with_layout(Layout::SplitSoa);
        assert_eq!(cfg.initial_buckets, 512);
        assert_eq!(cfg.max_evictions, 4);
        assert_eq!(cfg.grow_threshold, 0.8);
        assert_eq!(cfg.layout, Layout::SplitSoa);
        cfg.validate().unwrap();
    }
}
