//! Deterministic schedule-exploring model checker — the engine behind the
//! `--cfg loom` build of [`crate::core::sync`].
//!
//! The container this repo builds in has no network registry, so the real
//! `loom` crate cannot be a dependency. This module implements the subset
//! we need in-tree, following the CHESS/loom approach:
//!
//! * Model threads are real OS threads, but a **token** serializes them:
//!   exactly one runs at a time, and every access to a
//!   [`crate::core::sync`] shim atomic is a *scheduling point* where the
//!   checker may hand the token to a different runnable thread.
//! * A run is fully described by the sequence of choices taken at those
//!   points. [`Builder::check`] replays runs under DFS: after each run it
//!   backtracks to the deepest choice with an unexplored alternative and
//!   re-executes, until the bounded schedule tree is exhausted.
//! * **Preemption bounding** (CHESS): switching away from a thread that
//!   could have continued costs one preemption; runs explore at most
//!   `LOOM_MAX_PREEMPTIONS` of them (voluntary hand-offs at blocking
//!   points are free). Most real lock-free bugs manifest within 2–3
//!   preemptions, which keeps the tree tractable.
//! * Spin loops must call [`crate::core::sync::hint::spin_loop`], which
//!   parks the thread until *some other thread performs a write* —
//!   otherwise a waiting loop would spin forever under the deterministic
//!   "keep running the current thread" default. A run in which every
//!   live thread is parked or blocked is reported as a deadlock.
//!
//! The explored memory model is **sequential consistency** (shim atomics
//! ignore the requested `Ordering` and use `SeqCst`). That is weaker
//! coverage than real loom's C11 exploration, but every protocol in this
//! crate is already written against `SeqCst`/`AcqRel` fences, and SC
//! interleaving exhaustion is exactly what the seed-matrix stress tests
//! cannot provide.
//!
//! The checker is plain std code and is compiled (and unit-tested) in
//! normal builds too: anything may call [`yield_point`] / [`spawn`]
//! explicitly; outside a [`check`] run they fall back to no-ops /
//! `std::thread`.

use std::cell::RefCell;
use std::panic::{self, AssertUnwindSafe};
use std::sync::{Arc, Condvar, Mutex, MutexGuard, Once};

const NO_THREAD: usize = usize::MAX;
/// Keep at most this many trace entries; the tail is what gets printed.
const TRACE_CAP: usize = 1 << 16;
const TRACE_TAIL: usize = 400;

/// Panic payload used to unwind model threads when a run is being torn
/// down (failure elsewhere, or deadlock). Suppressed by the panic hook.
struct ModelAbort;

#[derive(Clone, Copy, PartialEq, Eq, Debug)]
enum Status {
    /// May be granted the token.
    Runnable,
    /// Waiting for any other thread to perform a write (spin hint).
    Parked,
    /// Waiting for thread `.0` to finish.
    Joining(usize),
    Finished,
}

struct State {
    status: Vec<Status>,
    /// `write_count` at the moment each thread parked.
    parked_at: Vec<u64>,
    /// Thread currently holding the token (`NO_THREAD` when the run is over).
    cur: usize,
    /// Unfinished threads.
    live: usize,
    /// Total shim writes so far; parked threads wake when it advances.
    write_count: u64,
    /// Replay prefix: candidate index to take at each decision.
    plan: Vec<usize>,
    /// Candidate index actually taken at each decision this run.
    chosen: Vec<usize>,
    /// Candidate-list length at each decision this run.
    counts: Vec<usize>,
    preemptions: usize,
    steps: usize,
    trace: Vec<(usize, &'static str)>,
    abort: bool,
    failure: Option<String>,
}

struct Sched {
    m: Mutex<State>,
    cv: Condvar,
    max_preemptions: usize,
    max_steps: usize,
    handles: Mutex<Vec<std::thread::JoinHandle<()>>>,
}

thread_local! {
    static CTX: RefCell<Option<(Arc<Sched>, usize)>> = RefCell::new(None);
}

fn ctx() -> Option<(Arc<Sched>, usize)> {
    CTX.with(|c| c.borrow().clone())
}

/// True while the calling thread is a model thread inside a [`check`] run.
pub fn active() -> bool {
    CTX.with(|c| c.borrow().is_some())
}

/// Dense model-assigned index of the calling thread (spawn order within
/// the current run), if it is a model thread. Replay-deterministic, unlike
/// OS thread identity — stripe selection uses this under `cfg(loom)`.
pub fn thread_id() -> Option<usize> {
    CTX.with(|c| c.borrow().as_ref().map(|(_, t)| *t))
}

impl Sched {
    /// Poison-tolerant lock: a model thread may panic (that is the point
    /// of assertions in models) and we still need the state for the trace.
    fn lock(&self) -> MutexGuard<'_, State> {
        self.m.lock().unwrap_or_else(|p| p.into_inner())
    }

    fn fail(&self, st: &mut State, msg: String) {
        if st.failure.is_none() {
            st.failure = Some(msg);
        }
        st.abort = true;
        self.cv.notify_all();
    }

    /// One scheduling decision, taken by the thread holding the token.
    /// `running == false` means the caller just blocked (parked, joining)
    /// or finished: it hands the token off without being a candidate and
    /// returns immediately after the hand-off.
    fn step(&self, tid: usize, label: &'static str, running: bool) {
        let mut st = self.lock();
        if st.abort {
            drop(st);
            panic::panic_any(ModelAbort);
        }
        st.steps += 1;
        if st.trace.len() < TRACE_CAP {
            st.trace.push((tid, label));
        }
        if st.steps > self.max_steps {
            let msg = format!(
                "step bound {} exceeded (livelock? a spin loop must call sync::hint::spin_loop)",
                self.max_steps
            );
            self.fail(&mut st, msg);
            drop(st);
            if running {
                panic::panic_any(ModelAbort);
            }
            return;
        }
        // Wake spinners that have seen a write since they parked.
        let wc = st.write_count;
        for i in 0..st.status.len() {
            if st.status[i] == Status::Parked && wc > st.parked_at[i] {
                st.status[i] = Status::Runnable;
            }
        }
        // Candidate list. The current thread (when runnable) is candidate
        // 0, so plan index 0 is always the preemption-free continuation;
        // picking any other candidate while the current thread could have
        // continued costs one preemption.
        let mut cands: Vec<usize> = Vec::new();
        if running {
            cands.push(tid);
            if st.preemptions < self.max_preemptions {
                for i in 0..st.status.len() {
                    if i != tid && st.status[i] == Status::Runnable {
                        cands.push(i);
                    }
                }
            }
        } else {
            for i in 0..st.status.len() {
                if st.status[i] == Status::Runnable {
                    cands.push(i);
                }
            }
        }
        if cands.is_empty() {
            if st.live == 0 {
                st.cur = NO_THREAD;
                self.cv.notify_all();
                return;
            }
            self.fail(
                &mut st,
                format!("deadlock: {} live thread(s), none runnable", st.live),
            );
            drop(st);
            if running {
                panic::panic_any(ModelAbort);
            }
            return;
        }
        let d = st.chosen.len();
        let idx = if d < st.plan.len() { st.plan[d] } else { 0 };
        if idx >= cands.len() {
            self.fail(
                &mut st,
                format!(
                    "non-deterministic replay: decision {d} has {} candidates, plan wanted {idx}",
                    cands.len()
                ),
            );
            drop(st);
            if running {
                panic::panic_any(ModelAbort);
            }
            return;
        }
        st.chosen.push(idx);
        st.counts.push(cands.len());
        let next = cands[idx];
        if running && next != tid {
            st.preemptions += 1;
        }
        st.cur = next;
        if !running {
            self.cv.notify_all();
            return;
        }
        if next != tid {
            self.cv.notify_all();
            while !st.abort && st.cur != tid {
                st = self
                    .cv
                    .wait(st)
                    .unwrap_or_else(|p| p.into_inner());
            }
            if st.abort {
                drop(st);
                panic::panic_any(ModelAbort);
            }
        }
    }

    /// Block until the token comes back (used after a `running == false`
    /// hand-off from `join`/`park`).
    fn wait_token(&self, tid: usize) {
        let mut st = self.lock();
        while !st.abort && st.cur != tid {
            st = self.cv.wait(st).unwrap_or_else(|p| p.into_inner());
        }
        if st.abort {
            drop(st);
            panic::panic_any(ModelAbort);
        }
        st.status[tid] = Status::Runnable;
    }

    /// Mark a thread finished and hand the token onward.
    fn finish(&self, tid: usize, failure: Option<String>) {
        let mut st = self.lock();
        st.status[tid] = Status::Finished;
        st.live -= 1;
        for i in 0..st.status.len() {
            if st.status[i] == Status::Joining(tid) {
                st.status[i] = Status::Runnable;
            }
        }
        if let Some(msg) = failure {
            self.fail(&mut st, msg);
        }
        if st.abort || st.live == 0 {
            st.cur = NO_THREAD;
            self.cv.notify_all();
            return;
        }
        drop(st);
        self.step(tid, "exit", false);
    }
}

/// A scheduling point. No-op unless called from a model thread inside a
/// [`check`] run. The shim atomics call this immediately before each
/// access; between two of its returns only the calling thread runs, so
/// the access itself is atomic w.r.t. the model.
#[inline]
pub fn yield_point(label: &'static str) {
    if let Some((sched, tid)) = ctx() {
        sched.step(tid, label, true);
    }
}

/// Record that the calling thread just performed a write to shared state
/// (wakes threads parked in [`park_until_write`] at the next decision).
/// Called by the shims *after* a store/RMW, and after a successful CAS.
#[inline]
pub fn record_write() {
    if let Some((sched, _)) = ctx() {
        sched.lock().write_count += 1;
    }
}

/// Park the calling thread until some other thread performs a write.
/// This is what `sync::hint::spin_loop` / `sync::thread::yield_now` do
/// under the model; a spin loop that never observes a write deadlocks
/// the run and is reported as such.
pub fn park_until_write() {
    let Some((sched, tid)) = ctx() else { return };
    {
        let mut st = sched.lock();
        if st.abort {
            drop(st);
            panic::panic_any(ModelAbort);
        }
        st.parked_at[tid] = st.write_count;
        st.status[tid] = Status::Parked;
    }
    sched.step(tid, "spin", false);
    sched.wait_token(tid);
}

/// Handle to a model thread spawned with [`spawn`].
pub struct JoinHandle<T> {
    tid: usize,
    slot: Arc<Mutex<Option<T>>>,
}

impl<T> JoinHandle<T> {
    /// Block (in model time) until the thread finishes, then return its
    /// result. Panics in model threads abort the whole run, so there is
    /// no `Err` arm to surface here.
    pub fn join(self) -> T {
        let (sched, me) = ctx().expect("model join outside a check run");
        loop {
            {
                let mut st = sched.lock();
                if st.abort {
                    drop(st);
                    panic::panic_any(ModelAbort);
                }
                if st.status[self.tid] == Status::Finished {
                    break;
                }
                st.status[me] = Status::Joining(self.tid);
            }
            sched.step(me, "join", false);
            sched.wait_token(me);
        }
        self.slot
            .lock()
            .unwrap_or_else(|p| p.into_inner())
            .take()
            .expect("joined model thread left no result")
    }
}

/// Spawn a model thread. Must be called from inside a [`check`] run
/// (the `sync::thread` facade falls back to `std::thread::spawn` when no
/// run is active). The child becomes runnable immediately but only runs
/// when the scheduler grants it the token.
pub fn spawn<F, T>(f: F) -> JoinHandle<T>
where
    F: FnOnce() -> T + Send + 'static,
    T: Send + 'static,
{
    let (sched, _) = ctx().expect("model spawn outside a check run");
    let slot: Arc<Mutex<Option<T>>> = Arc::new(Mutex::new(None));
    let tid = {
        let mut st = sched.lock();
        let tid = st.status.len();
        st.status.push(Status::Runnable);
        st.parked_at.push(0);
        st.live += 1;
        tid
    };
    let s2 = Arc::clone(&sched);
    let slot2 = Arc::clone(&slot);
    let h = std::thread::Builder::new()
        .name(format!("model-{tid}"))
        .spawn(move || {
            thread_main(s2, tid, move || {
                let v = f();
                *slot2.lock().unwrap_or_else(|p| p.into_inner()) = Some(v);
            })
        })
        .expect("spawn model thread");
    sched
        .handles
        .lock()
        .unwrap_or_else(|p| p.into_inner())
        .push(h);
    JoinHandle { tid, slot }
}

fn panic_message(p: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = p.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = p.downcast_ref::<String>() {
        s.clone()
    } else {
        "<non-string panic payload>".to_string()
    }
}

/// Body shared by the root thread and every spawned model thread: wait
/// for the first token grant, run, and report the outcome to the
/// scheduler exactly once.
fn thread_main(sched: Arc<Sched>, tid: usize, f: impl FnOnce()) {
    CTX.with(|c| *c.borrow_mut() = Some((Arc::clone(&sched), tid)));
    let aborted_early = {
        let mut st = sched.lock();
        while !st.abort && st.cur != tid {
            st = sched.cv.wait(st).unwrap_or_else(|p| p.into_inner());
        }
        st.abort
    };
    if aborted_early {
        sched.finish(tid, None);
    } else {
        match panic::catch_unwind(AssertUnwindSafe(f)) {
            Ok(()) => sched.finish(tid, None),
            Err(p) if p.is::<ModelAbort>() => sched.finish(tid, None),
            Err(p) => sched.finish(tid, Some(format!("thread {tid} panicked: {}", panic_message(&*p)))),
        }
    }
    CTX.with(|c| *c.borrow_mut() = None);
}

/// Install a panic hook that silences the internal [`ModelAbort`] unwind
/// (real assertion failures still print through the previous hook).
fn install_hook() {
    static ONCE: Once = Once::new();
    ONCE.call_once(|| {
        let prev = panic::take_hook();
        panic::set_hook(Box::new(move |info| {
            if info.payload().downcast_ref::<ModelAbort>().is_some() {
                return;
            }
            prev(info);
        }));
    });
}

/// Outcome of a [`Builder::check`] exploration that found no failure.
#[derive(Debug, Clone, Copy)]
pub struct Report {
    /// Interleavings executed.
    pub iterations: u64,
    /// True when the schedule tree (under the preemption bound) was
    /// exhausted; false when `max_iterations` stopped exploration early.
    pub complete: bool,
}

/// Exploration bounds. `from_env` honours the same knobs the CI
/// `model-check` job sets: `LOOM_MAX_PREEMPTIONS` (default 2),
/// `LOOM_MAX_ITERATIONS` (default 250_000), `LOOM_MAX_STEPS`
/// (default 50_000 scheduling points per run).
#[derive(Debug, Clone, Copy)]
pub struct Builder {
    pub max_preemptions: usize,
    pub max_iterations: u64,
    pub max_steps: usize,
}

impl Default for Builder {
    fn default() -> Self {
        Self { max_preemptions: 2, max_iterations: 250_000, max_steps: 50_000 }
    }
}

fn env_usize(name: &str, default: usize) -> usize {
    std::env::var(name).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

impl Builder {
    pub fn from_env() -> Self {
        let d = Self::default();
        Self {
            max_preemptions: env_usize("LOOM_MAX_PREEMPTIONS", d.max_preemptions),
            max_iterations: env_usize("LOOM_MAX_ITERATIONS", d.max_iterations as usize) as u64,
            max_steps: env_usize("LOOM_MAX_STEPS", d.max_steps),
        }
    }

    /// Run `f` under every schedule in the bounded tree (DFS with replay).
    /// Panics — after printing the failing schedule trace — if any
    /// interleaving panics, deadlocks, or exceeds the step bound.
    pub fn check<F>(&self, f: F) -> Report
    where
        F: Fn() + Send + Sync + 'static,
    {
        assert!(!active(), "nested model::check is not supported");
        install_hook();
        let f = Arc::new(f);
        let mut plan: Vec<usize> = Vec::new();
        let mut iterations = 0u64;
        loop {
            let sched = Arc::new(Sched {
                m: Mutex::new(State {
                    status: vec![Status::Runnable],
                    parked_at: vec![0],
                    cur: 0,
                    live: 1,
                    write_count: 0,
                    plan: std::mem::take(&mut plan),
                    chosen: Vec::new(),
                    counts: Vec::new(),
                    preemptions: 0,
                    steps: 0,
                    trace: Vec::new(),
                    abort: false,
                    failure: None,
                }),
                cv: Condvar::new(),
                max_preemptions: self.max_preemptions,
                max_steps: self.max_steps,
                handles: Mutex::new(Vec::new()),
            });
            let fc = Arc::clone(&f);
            let s2 = Arc::clone(&sched);
            let root = std::thread::Builder::new()
                .name("model-0".into())
                .spawn(move || thread_main(s2, 0, move || fc()))
                .expect("spawn model root");
            {
                let mut st = sched.lock();
                while st.live > 0 {
                    st = sched.cv.wait(st).unwrap_or_else(|p| p.into_inner());
                }
            }
            let _ = root.join();
            loop {
                let h = sched.handles.lock().unwrap_or_else(|p| p.into_inner()).pop();
                match h {
                    Some(h) => {
                        let _ = h.join();
                    }
                    None => break,
                }
            }
            iterations += 1;
            let st = sched.lock();
            if let Some(msg) = &st.failure {
                let tail_from = st.trace.len().saturating_sub(TRACE_TAIL);
                eprintln!("=== model failure after {iterations} interleaving(s) ===");
                eprintln!("{msg}");
                eprintln!(
                    "--- schedule tail ({} of {} scheduling points) ---",
                    st.trace.len() - tail_from,
                    st.trace.len()
                );
                for (i, (t, label)) in st.trace.iter().enumerate().skip(tail_from) {
                    eprintln!("#{i:<6} t{t}  {label}");
                }
                panic!("model checking failed: {msg}");
            }
            let chosen = st.chosen.clone();
            let counts = st.counts.clone();
            drop(st);
            // Backtrack to the deepest decision with an unexplored branch.
            let mut i = chosen.len();
            let complete = loop {
                if i == 0 {
                    break true;
                }
                i -= 1;
                if chosen[i] + 1 < counts[i] {
                    break false;
                }
            };
            if complete {
                return Report { iterations, complete: true };
            }
            if iterations >= self.max_iterations {
                return Report { iterations, complete: false };
            }
            plan = chosen[..i].to_vec();
            plan.push(chosen[i] + 1);
        }
    }
}

/// [`Builder::check`] with bounds from the environment.
pub fn check<F>(f: F) -> Report
where
    F: Fn() + Send + Sync + 'static,
{
    Builder::from_env().check(f)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::HashSet;
    use std::sync::atomic::{AtomicU64, Ordering};

    fn small() -> Builder {
        Builder { max_preemptions: 3, max_iterations: 100_000, max_steps: 10_000 }
    }

    /// Two incrementers with a scheduling point between load and store
    /// race a lost update; with yield points at both accesses the checker
    /// must reach both the correct (2) and the lost-update (1) outcome.
    #[test]
    fn explores_lost_update_interleavings() {
        let outcomes: Arc<Mutex<HashSet<u64>>> = Arc::new(Mutex::new(HashSet::new()));
        let oc = Arc::clone(&outcomes);
        let report = small().check(move || {
            let x = Arc::new(AtomicU64::new(0));
            let hs: Vec<_> = (0..2)
                .map(|_| {
                    let x = Arc::clone(&x);
                    spawn(move || {
                        yield_point("load x");
                        let v = x.load(Ordering::SeqCst);
                        yield_point("store x");
                        x.store(v + 1, Ordering::SeqCst);
                        record_write();
                    })
                })
                .collect();
            for h in hs {
                h.join();
            }
            oc.lock().unwrap().insert(x.load(Ordering::SeqCst));
        });
        assert!(report.complete, "tiny model must exhaust");
        assert!(report.iterations > 1, "must explore more than one schedule");
        let seen = outcomes.lock().unwrap();
        assert!(seen.contains(&2), "sequential outcome reachable");
        assert!(seen.contains(&1), "lost-update interleaving reachable");
    }

    /// Store-buffering shape under SC: each thread writes its own flag
    /// then reads the other's. Sequential consistency forbids both
    /// threads reading 0; exhaustive SC exploration must see exactly the
    /// other three outcomes.
    #[test]
    fn store_buffering_is_sequentially_consistent() {
        let outcomes: Arc<Mutex<HashSet<(u64, u64)>>> = Arc::new(Mutex::new(HashSet::new()));
        let oc = Arc::clone(&outcomes);
        let report = small().check(move || {
            let x = Arc::new(AtomicU64::new(0));
            let y = Arc::new(AtomicU64::new(0));
            let (x1, y1) = (Arc::clone(&x), Arc::clone(&y));
            let a = spawn(move || {
                yield_point("store x");
                x1.store(1, Ordering::SeqCst);
                record_write();
                yield_point("load y");
                y1.load(Ordering::SeqCst)
            });
            let (x2, y2) = (Arc::clone(&x), Arc::clone(&y));
            let b = spawn(move || {
                yield_point("store y");
                y2.store(1, Ordering::SeqCst);
                record_write();
                yield_point("load x");
                x2.load(Ordering::SeqCst)
            });
            let ra = a.join();
            let rb = b.join();
            assert!(ra == 1 || rb == 1, "store buffering outcome is not SC");
            oc.lock().unwrap().insert((ra, rb));
        });
        assert!(report.complete);
        let seen = outcomes.lock().unwrap();
        assert_eq!(
            *seen,
            HashSet::from([(0, 1), (1, 0), (1, 1)]),
            "exhaustive SC exploration reaches exactly three outcomes"
        );
    }

    /// A spin loop waiting on a write that no thread will ever perform
    /// must be reported as a deadlock, not spin forever.
    #[test]
    #[should_panic(expected = "deadlock")]
    fn reports_spin_deadlock() {
        small().check(|| {
            let flag = Arc::new(AtomicU64::new(0));
            let f = Arc::clone(&flag);
            let h = spawn(move || {
                loop {
                    yield_point("load flag");
                    if f.load(Ordering::SeqCst) == 1 {
                        break;
                    }
                    park_until_write();
                }
            });
            h.join();
        });
    }

    /// Assertion failures inside a model thread surface as a check panic
    /// (with the schedule trace printed to stderr).
    #[test]
    #[should_panic(expected = "model checking failed")]
    fn surfaces_model_thread_panics() {
        small().check(|| {
            let h = spawn(|| {
                yield_point("boom");
                panic!("intentional model failure");
            });
            h.join();
        });
    }

    /// The spin-park protocol: a consumer parks until the producer's
    /// write, then must observe it. Exhausts without deadlock reports.
    #[test]
    fn park_wakes_on_write() {
        let report = small().check(|| {
            let flag = Arc::new(AtomicU64::new(0));
            let f = Arc::clone(&flag);
            let consumer = spawn(move || {
                loop {
                    yield_point("load flag");
                    if f.load(Ordering::SeqCst) == 1 {
                        break;
                    }
                    park_until_write();
                }
            });
            let f2 = Arc::clone(&flag);
            let producer = spawn(move || {
                yield_point("store flag");
                f2.store(1, Ordering::SeqCst);
                record_write();
            });
            producer.join();
            consumer.join();
        });
        assert!(report.complete);
    }
}
