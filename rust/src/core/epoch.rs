//! Epoch-based phase guard: a seqlock-style epoch word plus striped pin
//! slots, giving quiescent-state reclamation for swapped table state.
//!
//! The native table used to funnel every operation through a
//! `RwLock<State>` read acquisition — an atomic RMW on one shared cache
//! line per op, the NUMA-hostile pattern a reader-writer guard always
//! degenerates to on multi-socket hosts. [`EpochDomain`] replaces it:
//!
//! * **Pin (shared phase).** An operation announces itself with one RMW on
//!   its *own* cache-line-padded pin stripe and one plain load of the
//!   shared epoch word. The epoch word is written only when an exclusive
//!   phase begins or ends, so that load stays a read-shared cache hit —
//!   there is no RMW on a shared line anywhere on the fast path.
//! * **Exclusive phase (physical reallocation).** The writer flips the
//!   epoch word odd, then waits for every pin stripe to drain to zero —
//!   the grace period. Readers that race the flip detect the odd epoch
//!   right after announcing themselves, back their stripe out, and spin on
//!   parity without hammering the stripes. Once drained, the writer owns
//!   the state exclusively: it can swap the state pointer and free the old
//!   allocation immediately, because no thread can still hold a reference
//!   (quiescent-state reclamation with the drain as the grace period).
//!
//! Soundness of the drain: all epoch and stripe operations are `SeqCst`.
//! If a reader's post-announce epoch load returns the pre-flip (even)
//! value, that load — and therefore the reader's stripe increment
//! sequenced before it — precedes the writer's flip in the single total
//! order, so the writer's subsequent stripe scan observes the increment
//! and waits for the matching decrement. If the load returns the odd
//! value, the reader backs out and never touches the retired state.

use crate::core::sync::atomic::{AtomicU64, Ordering};

/// Number of pin stripes (power of two). Matches the striped counter: 16
/// stripes × 128 B keeps realistic thread counts on distinct lines.
pub const PIN_STRIPES: usize = 16;

/// One padded pin slot. 128-byte alignment defeats the x86 adjacent-line
/// prefetcher pairing 64-byte lines.
#[repr(align(128))]
struct PinSlot(AtomicU64);

/// This thread's home stripe: the facade's shared thread numbering
/// ([`crate::core::sync::thread_index`] — first-use round-robin normally,
/// the model's dense replay-deterministic id under `cfg(loom)`).
#[inline]
fn home_stripe() -> usize {
    crate::core::sync::thread_index() & (PIN_STRIPES - 1)
}

/// The epoch domain guarding one swappable state allocation.
pub struct EpochDomain {
    /// Seqlock-style epoch word: even = stable shared phase, odd = an
    /// exclusive phase (pointer swap) is in progress. Monotonic.
    epoch: AtomicU64,
    pins: [PinSlot; PIN_STRIPES],
}

/// An active pin. Holding it keeps the current state allocation alive;
/// dropping it is the quiescent point.
pub struct EpochGuard<'a> {
    domain: &'a EpochDomain,
    stripe: usize,
    epoch: u64,
}

impl Default for EpochDomain {
    fn default() -> Self {
        Self::new()
    }
}

impl EpochDomain {
    /// A fresh domain in the stable phase (epoch 0).
    pub fn new() -> Self {
        EpochDomain {
            epoch: AtomicU64::new(0),
            pins: std::array::from_fn(|_| PinSlot(AtomicU64::new(0))),
        }
    }

    /// The current epoch word (even in stable phases; odd while an
    /// exclusive phase runs).
    pub fn current(&self) -> u64 {
        self.epoch.load(Ordering::SeqCst)
    }

    /// Pin the current epoch. Spins only while an exclusive phase is in
    /// progress (physical reallocation — rare and short).
    ///
    /// **Not reentrant under writer pressure:** a thread must not pin
    /// while already holding a pin of this domain if an exclusive phase
    /// can begin concurrently — the inner pin would back out and spin on
    /// parity while the writer spins on the outer pin's stripe (mutual
    /// livelock). The table therefore pins exactly once per operation (or
    /// once per batch) and never nests across an op boundary.
    #[inline]
    pub fn pin(&self) -> EpochGuard<'_> {
        let stripe = home_stripe();
        let cell = &self.pins[stripe].0;
        loop {
            cell.fetch_add(1, Ordering::SeqCst);
            let e = self.epoch.load(Ordering::SeqCst);
            if e & 1 == 0 {
                return EpochGuard { domain: self, stripe, epoch: e };
            }
            // An exclusive phase is running: back the announce out and
            // wait on parity (no stripe traffic while waiting).
            cell.fetch_sub(1, Ordering::SeqCst);
            while self.epoch.load(Ordering::Acquire) & 1 == 1 {
                crate::core::sync::hint::spin_loop();
            }
        }
    }

    /// Begin the exclusive phase: flip the epoch odd, then wait out the
    /// grace period (every pin stripe drains to zero). The caller must
    /// serialize exclusive phases externally (the table's resize mutex)
    /// and must not hold a pin of this domain.
    pub fn enter_exclusive(&self) {
        let prev = self.epoch.fetch_add(1, Ordering::SeqCst);
        debug_assert_eq!(prev & 1, 0, "exclusive phases must not nest");
        for slot in &self.pins {
            while slot.0.load(Ordering::SeqCst) != 0 {
                crate::core::sync::hint::spin_loop();
            }
        }
    }

    /// End the exclusive phase: the epoch becomes even again and pinning
    /// resumes against whatever state pointer the writer published.
    pub fn exit_exclusive(&self) {
        let prev = self.epoch.fetch_add(1, Ordering::SeqCst);
        debug_assert_eq!(prev & 1, 1, "exit_exclusive without enter_exclusive");
    }
}

impl EpochGuard<'_> {
    /// The (even) epoch this guard pinned.
    pub fn epoch(&self) -> u64 {
        self.epoch
    }
}

impl Drop for EpochGuard<'_> {
    fn drop(&mut self) {
        self.domain.pins[self.stripe].0.fetch_sub(1, Ordering::SeqCst);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicBool;
    use std::sync::Arc;

    #[test]
    fn pin_unpin_is_balanced() {
        let d = EpochDomain::new();
        let g1 = d.pin();
        // Counters make nested pins *balance* correctly, but nesting is
        // forbidden when a writer may be waiting — see `pin`'s docs. No
        // writer runs here, so this only checks the bookkeeping.
        let g2 = d.pin();
        assert_eq!(g1.epoch(), 0);
        assert_eq!(g2.epoch(), 0);
        drop(g2);
        drop(g1);
        // all stripes drained: an exclusive phase must not block
        d.enter_exclusive();
        assert_eq!(d.current() & 1, 1);
        d.exit_exclusive();
        assert_eq!(d.current(), 2);
    }

    #[test]
    fn exclusive_phase_waits_for_pins_and_blocks_new_ones() {
        let d = Arc::new(EpochDomain::new());
        let entered = Arc::new(AtomicBool::new(false));
        let guard = d.pin();
        let writer = {
            let d = Arc::clone(&d);
            let entered = Arc::clone(&entered);
            std::thread::spawn(move || {
                d.enter_exclusive();
                entered.store(true, Ordering::SeqCst);
                d.exit_exclusive();
            })
        };
        // the writer cannot finish the grace period while we hold the pin
        std::thread::sleep(std::time::Duration::from_millis(20));
        assert!(!entered.load(Ordering::SeqCst), "grace period ignored a live pin");
        drop(guard);
        writer.join().unwrap();
        assert!(entered.load(Ordering::SeqCst));
        // epoch advanced by 2 and is even again; pinning works
        assert_eq!(d.current(), 2);
        let g = d.pin();
        assert_eq!(g.epoch(), 2);
    }

    #[test]
    fn pins_from_many_threads_all_drain() {
        let d = Arc::new(EpochDomain::new());
        let threads: Vec<_> = (0..8)
            .map(|_| {
                let d = Arc::clone(&d);
                std::thread::spawn(move || {
                    for _ in 0..10_000 {
                        let g = d.pin();
                        std::hint::black_box(g.epoch());
                    }
                })
            })
            .collect();
        for t in threads {
            t.join().unwrap();
        }
        d.enter_exclusive(); // must not hang: everything drained
        d.exit_exclusive();
    }
}
