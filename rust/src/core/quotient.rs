//! Quotiented-key codec for [`Layout::CompactQuotient`].
//!
//! Under linear hashing a bucket's index *is* the low `w` bits of the
//! key's hash (`w = m` for unsplit buckets, `m + 1` for buckets already
//! split this round, where `m = index_mask.count_ones()`). Those bits
//! carry no information once the entry sits in the bucket, so the compact
//! layout stores only the *remainder* `h >> w` plus a 2-bit *tag* naming
//! which hash function of the family produced `h`:
//!
//! ```text
//!  63            32 31  30 29                         0
//! +----------------+------+----------------------------+
//! |     value      | tag  |     rem = h_tag(key) >> w  |
//! +----------------+------+----------------------------+
//!                   `low half` (the CAS'd key field)
//! ```
//!
//! Because every hash kind admitted by config validation is a bijection
//! on `u32` ([`HashKind::invertible`]), the full key is reconstructed
//! exactly: `h = (rem << w) | bucket`, `key = invert(kind[tag], h)`.
//! Distinct keys in the same bucket under the same tag have distinct
//! hashes, hence distinct remainders — half-word equality remains exact
//! key equality, and the single-CAS publish protocol is untouched.
//!
//! The tag occupies the top two bits of the half and is at most 2 (the
//! family is capped at `d = 3` for this layout), so a live half can never
//! equal the `EMPTY_KEY` sentinel `0xFFFF_FFFF`.
//!
//! [`Layout::CompactQuotient`]: crate::core::config::Layout::CompactQuotient
//! [`HashKind::invertible`]: crate::hash::HashKind::invertible

use crate::hash::HashFamily;

/// Bit position of the candidate-index tag inside the stored half.
pub const TAG_SHIFT: u32 = 30;

/// Mask selecting the tag bits of a stored half.
pub const TAG_MASK: u32 = 0b11 << TAG_SHIFT;

/// Mask selecting the remainder bits of a stored half.
pub const REM_MASK: u32 = (1 << TAG_SHIFT) - 1;

/// Number of hash-index bits a bucket implies: `m` for buckets still
/// awaiting this round's split, `m + 1` for buckets already split
/// (`bucket < split_ptr`) and for their images (`bucket > index_mask`).
#[inline(always)]
pub fn width_of(bucket: u32, index_mask: u32, split_ptr: u32) -> u32 {
    let m = index_mask.count_ones();
    m + (bucket < split_ptr || bucket > index_mask) as u32
}

/// Quotient raw hash `raw` (from family function `cand`) for storage in
/// `bucket` under the given round state.
#[inline(always)]
pub fn encode_half(raw: u32, cand: usize, bucket: u32, index_mask: u32, split_ptr: u32) -> u32 {
    debug_assert!(cand < 3, "compact layout caps the family at d = 3");
    ((cand as u32) << TAG_SHIFT) | (raw >> width_of(bucket, index_mask, split_ptr))
}

/// Which hash function of the family produced a stored half.
#[inline(always)]
pub fn decode_tag(half: u32) -> usize {
    (half >> TAG_SHIFT) as usize
}

/// Reconstruct the full raw hash from a stored half and its bucket.
#[inline(always)]
pub fn decode_hash(half: u32, bucket: u32, index_mask: u32, split_ptr: u32) -> u32 {
    ((half & REM_MASK) << width_of(bucket, index_mask, split_ptr)) | bucket
}

/// Reconstruct the full key from a stored half and its bucket.
#[inline(always)]
pub fn decode_key(
    family: &HashFamily,
    half: u32,
    bucket: u32,
    index_mask: u32,
    split_ptr: u32,
) -> u32 {
    family.kinds()[decode_tag(half)].invert(decode_hash(half, bucket, index_mask, split_ptr))
}

/// Re-encode a stored half across a *split* of its bucket (width `w` →
/// `w + 1`): the remainder's low bit is the move decision (hash bit `m`)
/// and leaves the remainder. Returns `(moves_to_image, new_half)`.
#[inline(always)]
pub fn split_half(half: u32) -> (bool, u32) {
    let rem = half & REM_MASK;
    ((rem & 1) == 1, (half & TAG_MASK) | (rem >> 1))
}

/// Re-encode a stored half across a *merge* (width `w + 1` → `w`): the
/// decision bit — 1 if the entry lived in the split image, 0 in the base
/// bucket — re-enters as the remainder's low bit.
#[inline(always)]
pub fn merge_half(half: u32, from_image: bool) -> u32 {
    let rem = ((half & REM_MASK) << 1) | from_image as u32;
    debug_assert_eq!(rem & TAG_MASK, 0, "remainder overflow: bucket width below 2 bits");
    (half & TAG_MASK) | rem
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::packed::EMPTY_KEY;
    use crate::hash::HashKind;

    fn family() -> HashFamily {
        HashFamily::new(vec![HashKind::BitHash1, HashKind::BitHash2, HashKind::Murmur3])
    }

    #[test]
    fn width_tracks_round_state() {
        // m = 4 (mask 0xF), split_ptr = 3: buckets 0..3 and 16.. are split.
        assert_eq!(width_of(0, 0xF, 3), 5);
        assert_eq!(width_of(2, 0xF, 3), 5);
        assert_eq!(width_of(3, 0xF, 3), 4);
        assert_eq!(width_of(15, 0xF, 3), 4);
        assert_eq!(width_of(16, 0xF, 3), 5);
        assert_eq!(width_of(18, 0xF, 3), 5);
    }

    #[test]
    fn roundtrip_all_candidates_all_round_states() {
        let fam = family();
        for (index_mask, split_ptr) in [(0x3u32, 0u32), (0x3, 2), (0xFF, 0), (0xFF, 97)] {
            for key in (0..20_000u32).chain([u32::MAX, u32::MAX - 7]) {
                for cand in 0..fam.d() {
                    let raw = fam.raw(cand, key);
                    let b = HashFamily::address(raw, index_mask, split_ptr);
                    let half = encode_half(raw, cand, b, index_mask, split_ptr);
                    assert_ne!(half, EMPTY_KEY, "live half hit the empty sentinel");
                    assert_eq!(decode_tag(half), cand);
                    assert_eq!(decode_hash(half, b, index_mask, split_ptr), raw);
                    assert_eq!(decode_key(&fam, half, b, index_mask, split_ptr), key);
                }
            }
        }
    }

    #[test]
    fn split_then_merge_is_identity() {
        let fam = family();
        let (index_mask, split_ptr) = (0x3Fu32, 0u32); // m = 6, round start
        for key in 0..20_000u32 {
            for cand in 0..fam.d() {
                let raw = fam.raw(cand, key);
                let b = raw & index_mask;
                let half = encode_half(raw, cand, b, index_mask, split_ptr);
                let (moves, split) = split_half(half);
                // The decision bit is hash bit m — exactly the linear-hashing
                // stay-or-move rule.
                assert_eq!(moves, (raw >> 6) & 1 == 1);
                let b_after = if moves { b + index_mask + 1 } else { b };
                // Width of b_after once this bucket's split completes is m+1
                // (b < split_ptr' for stayers, b > mask for movers).
                assert_eq!(
                    decode_hash(split, b_after, index_mask, b + 1),
                    raw,
                    "split re-encode broke hash reconstruction"
                );
                assert_eq!(merge_half(split, moves), half, "merge must undo split");
            }
        }
    }

    #[test]
    fn quotient_survives_capacity_doubling() {
        // Pack→unpack identity for every candidate index across a full
        // doubling: every (pre-split bucket, post-split bucket) pair agrees
        // on the reconstructed key.
        let fam = family();
        let index_mask = 0x1Fu32; // m = 5
        let next_mask = (index_mask << 1) | 1;
        for key in 0..30_000u32 {
            for cand in 0..fam.d() {
                let raw = fam.raw(cand, key);
                let before = raw & index_mask;
                let after = raw & next_mask;
                let h0 = encode_half(raw, cand, before, index_mask, 0);
                assert_eq!(decode_key(&fam, h0, before, index_mask, 0), key);
                // After the doubling completes the round state is (next_mask, 0).
                let h1 = encode_half(raw, cand, after, next_mask, 0);
                assert_eq!(decode_key(&fam, h1, after, next_mask, 0), key);
                let (moves, split) = split_half(h0);
                assert_eq!(split, h1);
                assert_eq!(moves, after != before);
            }
        }
    }
}
