//! Workload generators for benchmarks, tests and examples.
//!
//! Reproduces the paper's evaluation workloads (§V): uniformly distributed
//! unique key-value pairs for the balanced bulk insert/query experiments,
//! and mixed insert:lookup:delete streams (e.g. 0.5:0.3:0.2, Fig. 8) for
//! the imbalanced experiment. Zipfian key streams are provided for skew
//! ablations beyond the paper.

use crate::core::packed::EMPTY_KEY;
use crate::core::rng::{Xoshiro256, Zipf};

/// One table operation with its operands.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Op {
    /// Insert or replace `key → value`.
    Insert { key: u32, value: u32 },
    /// Point lookup.
    Lookup { key: u32 },
    /// Remove `key`.
    Delete { key: u32 },
}

impl Op {
    /// The key this operation touches.
    pub fn key(&self) -> u32 {
        match *self {
            Op::Insert { key, .. } | Op::Lookup { key } | Op::Delete { key } => key,
        }
    }
}

/// Mixed-workload ratios (must sum to 1.0).
#[derive(Debug, Clone, Copy)]
pub struct Mix {
    /// Fraction of inserts.
    pub insert: f64,
    /// Fraction of lookups.
    pub lookup: f64,
    /// Fraction of deletes.
    pub delete: f64,
}

impl Mix {
    /// The paper's Fig. 8 imbalanced mix 0.5 : 0.3 : 0.2.
    pub const PAPER_IMBALANCED: Mix = Mix { insert: 0.5, lookup: 0.3, delete: 0.2 };
    /// Insert-only (bulk build).
    pub const INSERT_ONLY: Mix = Mix { insert: 1.0, lookup: 0.0, delete: 0.0 };
    /// Lookup-only (bulk query).
    pub const LOOKUP_ONLY: Mix = Mix { insert: 0.0, lookup: 1.0, delete: 0.0 };
}

/// `n` unique uniformly distributed keys (no EMPTY sentinel, no dups),
/// shuffled deterministically by `seed`.
pub fn unique_uniform_keys(n: usize, seed: u64) -> Vec<u32> {
    let mut rng = Xoshiro256::seeded(seed);
    // Draw-without-replacement via a Feistel-style permutation of a dense
    // range is overkill here; use a set-free approach: random odd stride
    // over the u32 ring guarantees uniqueness.
    let stride = (rng.next_u32() | 1).max(3);
    let start = rng.next_u32();
    let mut keys: Vec<u32> = (0..n as u64)
        .map(|i| start.wrapping_add((i as u32).wrapping_mul(stride)))
        .map(|k| if k == EMPTY_KEY { 0x7FFF_FFFF } else { k })
        .collect();
    rng.shuffle(&mut keys);
    keys
}

/// Bulk insert workload: `n` unique `(key, value)` pairs.
pub fn bulk_insert(n: usize, seed: u64) -> Vec<Op> {
    unique_uniform_keys(n, seed)
        .into_iter()
        .map(|key| Op::Insert { key, value: key.wrapping_mul(0x9E37) })
        .collect()
}

/// Bulk query workload over a previously inserted key set.
pub fn bulk_lookup(keys: &[u32]) -> Vec<Op> {
    keys.iter().map(|&key| Op::Lookup { key }).collect()
}

/// Mixed workload of `n` ops at the given `mix`. Lookups and deletes
/// target previously inserted keys (uniformly chosen); inserts use fresh
/// unique keys. Deterministic in `seed`.
pub fn mixed(n: usize, mix: Mix, seed: u64) -> Vec<Op> {
    assert!((mix.insert + mix.lookup + mix.delete - 1.0).abs() < 1e-9);
    let mut rng = Xoshiro256::seeded(seed);
    let fresh = unique_uniform_keys(n, seed ^ 0xDEAD_BEEF);
    let mut live: Vec<u32> = Vec::with_capacity(n);
    let mut ops = Vec::with_capacity(n);
    for key in fresh {
        let r = rng.f64();
        if r < mix.insert || live.is_empty() {
            ops.push(Op::Insert { key, value: key ^ 0x5555 });
            live.push(key);
        } else if r < mix.insert + mix.lookup {
            let target = live[rng.below(live.len() as u64) as usize];
            ops.push(Op::Lookup { key: target });
        } else {
            let idx = rng.below(live.len() as u64) as usize;
            let target = live.swap_remove(idx);
            ops.push(Op::Delete { key: target });
        }
    }
    ops
}

/// Zipf-skewed lookup stream over `universe` ranked keys.
pub fn zipf_lookups(n: usize, universe: &[u32], theta: f64, seed: u64) -> Vec<Op> {
    let z = Zipf::new(universe.len() as u64, theta);
    let mut rng = Xoshiro256::seeded(seed);
    (0..n).map(|_| Op::Lookup { key: universe[z.sample(&mut rng) as usize] }).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unique_keys_are_unique() {
        let keys = unique_uniform_keys(100_000, 7);
        assert_eq!(keys.len(), 100_000);
        let mut sorted = keys.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), 100_000, "duplicate keys generated");
        assert!(!keys.contains(&EMPTY_KEY));
    }

    #[test]
    fn deterministic_by_seed() {
        assert_eq!(unique_uniform_keys(1000, 1), unique_uniform_keys(1000, 1));
        assert_ne!(unique_uniform_keys(1000, 1), unique_uniform_keys(1000, 2));
        assert_eq!(mixed(1000, Mix::PAPER_IMBALANCED, 3), mixed(1000, Mix::PAPER_IMBALANCED, 3));
    }

    #[test]
    fn mixed_ratios_approximate_target() {
        let ops = mixed(100_000, Mix::PAPER_IMBALANCED, 11);
        let ins = ops.iter().filter(|o| matches!(o, Op::Insert { .. })).count() as f64;
        let luk = ops.iter().filter(|o| matches!(o, Op::Lookup { .. })).count() as f64;
        let del = ops.iter().filter(|o| matches!(o, Op::Delete { .. })).count() as f64;
        let n = ops.len() as f64;
        assert!((ins / n - 0.5).abs() < 0.02, "insert ratio {}", ins / n);
        assert!((luk / n - 0.3).abs() < 0.02, "lookup ratio {}", luk / n);
        assert!((del / n - 0.2).abs() < 0.02, "delete ratio {}", del / n);
    }

    #[test]
    fn mixed_deletes_target_live_keys() {
        // replaying a mixed stream against a reference map never deletes
        // or looks up a key that was not inserted first
        use std::collections::HashSet;
        let ops = mixed(20_000, Mix::PAPER_IMBALANCED, 5);
        let mut live: HashSet<u32> = HashSet::new();
        for op in &ops {
            match *op {
                Op::Insert { key, .. } => {
                    live.insert(key);
                }
                Op::Lookup { key } => assert!(live.contains(&key), "lookup of dead key"),
                Op::Delete { key } => assert!(live.remove(&key), "delete of dead key"),
            }
        }
    }

    #[test]
    fn zipf_lookups_hit_universe() {
        let universe = unique_uniform_keys(1000, 9);
        let ops = zipf_lookups(10_000, &universe, 0.99, 10);
        let set: std::collections::HashSet<u32> = universe.iter().copied().collect();
        for op in ops {
            assert!(set.contains(&op.key()));
        }
    }
}
