//! Workload generators for benchmarks, tests and examples.
//!
//! Reproduces the paper's evaluation workloads (§V): uniformly distributed
//! unique key-value pairs for the balanced bulk insert/query experiments,
//! and mixed insert:lookup:delete streams (e.g. 0.5:0.3:0.2, Fig. 8) for
//! the imbalanced experiment. Zipfian key streams are provided for skew
//! ablations beyond the paper.

use crate::core::packed::EMPTY_KEY;
use crate::core::rng::{Xoshiro256, Zipf};
use crate::native::table::InsertOutcome;

/// One table operation with its operands — the submission side of the
/// typed operation plane. Every variant yields exactly one [`OpResult`]
/// in submission order, through every execution path (direct table
/// calls, `ConcurrentMap` batches, `Backend::execute`, and the
/// coordinator's `Handle`/`Pipeline`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Op {
    /// Insert or replace `key → value`. Alias of [`Op::Upsert`] — kept
    /// as the historical name; both execute identically and yield
    /// [`OpResult::Upserted`].
    Insert { key: u32, value: u32 },
    /// Point lookup.
    Lookup { key: u32 },
    /// Remove `key`.
    Delete { key: u32 },
    /// Insert or replace `key → value`, reporting the previous value.
    Upsert { key: u32, value: u32 },
    /// Insert `key → value` only if the key is absent; never overwrites
    /// an existing value.
    InsertIfAbsent { key: u32, value: u32 },
    /// Replace the value of `key` only if it is present; absent keys are
    /// left absent.
    Update { key: u32, value: u32 },
    /// Conditional write: store `new` iff the current value of `key`
    /// equals `expected` (absent keys never match).
    Cas { key: u32, expected: u32, new: u32 },
    /// Read-modify-write: add `delta` (wrapping) to the value of `key`,
    /// creating the key with value `delta` when absent.
    FetchAdd { key: u32, delta: u32 },
}

impl Op {
    /// The key this operation touches.
    pub fn key(&self) -> u32 {
        match *self {
            Op::Insert { key, .. }
            | Op::Lookup { key }
            | Op::Delete { key }
            | Op::Upsert { key, .. }
            | Op::InsertIfAbsent { key, .. }
            | Op::Update { key, .. }
            | Op::Cas { key, .. }
            | Op::FetchAdd { key, .. } => key,
        }
    }

    /// `true` for every operation class that can mutate the table
    /// (everything except `Lookup`). Conditional writes count even when
    /// their condition ends up failing — callers that need conflict
    /// detection (the coordinator's cache) must be conservative.
    pub fn is_write(&self) -> bool {
        !matches!(self, Op::Lookup { .. })
    }
}

/// Typed result of one executed [`Op`], carried end-to-end in
/// submission order. This replaces the old type-segregated
/// `backend::BatchResult` (separate `lookups`/`deletes` vectors plus
/// aggregate insert counters) that callers had to re-correlate.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum OpResult {
    /// `Lookup`: the value, if the key was present.
    Value(Option<u32>),
    /// `Delete`: `true` if the key was present and removed.
    Deleted(bool),
    /// `Insert`/`Upsert`: which four-step path placed the write, and the
    /// value it replaced (`None` ⇒ the key was fresh).
    Upserted { outcome: InsertOutcome, old: Option<u32> },
    /// `InsertIfAbsent`: when the key was already present, `existing`
    /// holds its value and nothing was written (`outcome` is `None`);
    /// otherwise the insert landed via `outcome`.
    InsertedIfAbsent { outcome: Option<InsertOutcome>, existing: Option<u32> },
    /// `Update`: the previous value when the key was present (the write
    /// applied); `None` ⇒ absent, nothing written.
    Updated { old: Option<u32> },
    /// `Cas`: `ok` ⇔ `expected` matched and the swap applied; `actual`
    /// is the value observed before the op (`None` ⇒ key absent).
    Cas { ok: bool, actual: Option<u32> },
    /// `FetchAdd`: `old` is the pre-add value when the key existed;
    /// `None` ⇒ the key was created holding the delta (placed via
    /// `outcome`).
    FetchAdded { outcome: Option<InsertOutcome>, old: Option<u32> },
}

impl OpResult {
    /// The lookup payload, if this is a `Value` result.
    pub fn as_value(&self) -> Option<Option<u32>> {
        match *self {
            OpResult::Value(v) => Some(v),
            _ => None,
        }
    }

    /// The delete hit flag, if this is a `Deleted` result.
    pub fn as_deleted(&self) -> Option<bool> {
        match *self {
            OpResult::Deleted(hit) => Some(hit),
            _ => None,
        }
    }
}

/// Mixed-workload ratios (must sum to 1.0). The three paper classes
/// (`insert`/`lookup`/`delete`) are joined by the typed-plane RMW
/// classes (`upsert`/`cas`/`fetch_add`); generators that predate the
/// RMW plane ([`mixed`], [`zipf_mixed`]) assert the RMW fractions are
/// zero.
#[derive(Debug, Clone, Copy)]
pub struct Mix {
    /// Fraction of inserts.
    pub insert: f64,
    /// Fraction of lookups.
    pub lookup: f64,
    /// Fraction of deletes.
    pub delete: f64,
    /// Fraction of upserts (insert-or-replace returning the old value).
    pub upsert: f64,
    /// Fraction of compare-and-swap ops.
    pub cas: f64,
    /// Fraction of fetch-add ops.
    pub fetch_add: f64,
}

impl Mix {
    /// Build a paper-style three-class mix (RMW fractions zero).
    pub const fn classic(insert: f64, lookup: f64, delete: f64) -> Mix {
        Mix { insert, lookup, delete, upsert: 0.0, cas: 0.0, fetch_add: 0.0 }
    }

    /// The paper's Fig. 8 imbalanced mix 0.5 : 0.3 : 0.2.
    pub const PAPER_IMBALANCED: Mix = Mix::classic(0.5, 0.3, 0.2);
    /// Insert-only (bulk build).
    pub const INSERT_ONLY: Mix = Mix::classic(1.0, 0.0, 0.0);
    /// Lookup-only (bulk query).
    pub const LOOKUP_ONLY: Mix = Mix::classic(0.0, 1.0, 0.0);
    /// Read-heavy serving mix (fig10's skewed-cache scenario).
    pub const READ_HEAVY: Mix = Mix::classic(0.10, 0.85, 0.05);
    /// RMW-heavy mix for the typed operation plane (fig12): counters,
    /// dedup and optimistic-concurrency traffic dominate.
    pub const RMW_HEAVY: Mix = Mix {
        insert: 0.05,
        lookup: 0.20,
        delete: 0.05,
        upsert: 0.20,
        cas: 0.25,
        fetch_add: 0.25,
    };

    /// Sum of every class fraction (validated to 1.0 by the generators).
    pub fn total(&self) -> f64 {
        self.insert + self.lookup + self.delete + self.upsert + self.cas + self.fetch_add
    }

    /// Sum of the RMW-class fractions.
    pub fn rmw_total(&self) -> f64 {
        self.upsert + self.cas + self.fetch_add
    }
}

/// `n` unique uniformly distributed keys (no EMPTY sentinel, no dups),
/// shuffled deterministically by `seed`.
pub fn unique_uniform_keys(n: usize, seed: u64) -> Vec<u32> {
    let mut rng = Xoshiro256::seeded(seed);
    // Draw-without-replacement via a Feistel-style permutation of a dense
    // range is overkill here; use a set-free approach: random odd stride
    // over the u32 ring guarantees uniqueness.
    let stride = (rng.next_u32() | 1).max(3);
    let start = rng.next_u32();
    let mut keys = keys_from_stride(n, start, stride);
    rng.shuffle(&mut keys);
    keys
}

/// The odd-stride progression `start + i·stride (mod 2³²)` for `i < n`,
/// with the (at most one) `EMPTY_KEY` occurrence remapped to the
/// progression's element at index `n`. That substitute is the one choice
/// that provably preserves the no-duplicates guarantee: an odd stride
/// makes `i ↦ start + i·stride` injective over any window of `< 2³²`
/// indices, and index `n` lies outside `0..n`. (A fixed remap constant —
/// the old `0x7FFF_FFFF` — breaks the guarantee whenever the window also
/// produces that constant as a genuine element.) If the index-`n` element
/// were itself `EMPTY_KEY`, injectivity puts `EMPTY_KEY` outside the
/// window, so the substitute is never used in that case.
fn keys_from_stride(n: usize, start: u32, stride: u32) -> Vec<u32> {
    debug_assert_eq!(stride & 1, 1, "stride must be odd for uniqueness");
    debug_assert!(n < u32::MAX as usize, "window wider than the u32 ring");
    let substitute = start.wrapping_add((n as u32).wrapping_mul(stride));
    (0..n as u64)
        .map(|i| start.wrapping_add((i as u32).wrapping_mul(stride)))
        .map(|k| if k == EMPTY_KEY { substitute } else { k })
        .collect()
}

/// Bulk insert workload: `n` unique `(key, value)` pairs.
pub fn bulk_insert(n: usize, seed: u64) -> Vec<Op> {
    unique_uniform_keys(n, seed)
        .into_iter()
        .map(|key| Op::Insert { key, value: key.wrapping_mul(0x9E37) })
        .collect()
}

/// Bulk query workload over a previously inserted key set.
pub fn bulk_lookup(keys: &[u32]) -> Vec<Op> {
    keys.iter().map(|&key| Op::Lookup { key }).collect()
}

/// Mixed workload of `n` ops at the given `mix`. Lookups and deletes
/// target previously inserted keys (uniformly chosen); inserts use fresh
/// unique keys. Deterministic in `seed`.
pub fn mixed(n: usize, mix: Mix, seed: u64) -> Vec<Op> {
    assert!((mix.total() - 1.0).abs() < 1e-9);
    assert!(mix.rmw_total() < 1e-12, "mixed() is a three-class generator; use rmw_mixed()");
    let mut rng = Xoshiro256::seeded(seed);
    let fresh = unique_uniform_keys(n, seed ^ 0xDEAD_BEEF);
    let mut live: Vec<u32> = Vec::with_capacity(n);
    let mut ops = Vec::with_capacity(n);
    for key in fresh {
        let r = rng.f64();
        if r < mix.insert || live.is_empty() {
            ops.push(Op::Insert { key, value: key ^ 0x5555 });
            live.push(key);
        } else if r < mix.insert + mix.lookup {
            let target = live[rng.below(live.len() as u64) as usize];
            ops.push(Op::Lookup { key: target });
        } else {
            let idx = rng.below(live.len() as u64) as usize;
            let target = live.swap_remove(idx);
            ops.push(Op::Delete { key: target });
        }
    }
    ops
}

/// Zipf-skewed lookup stream over `universe` ranked keys.
pub fn zipf_lookups(n: usize, universe: &[u32], theta: f64, seed: u64) -> Vec<Op> {
    let z = Zipf::new(universe.len() as u64, theta);
    let mut rng = Xoshiro256::seeded(seed);
    (0..n).map(|_| Op::Lookup { key: universe[z.sample(&mut rng) as usize] }).collect()
}

/// Universe size backing a [`zipf_mixed`] stream of `n` ops — exposed so
/// drivers can pre-populate exactly the keys the stream will touch.
pub fn zipf_mixed_universe(n: usize, seed: u64) -> Vec<u32> {
    unique_uniform_keys((n / 8).max(64), seed ^ 0x5EED_CAFE)
}

/// Zipf-skewed *mixed* stream: op types drawn from `mix`, keys drawn by
/// Zipf(θ) rank over the [`zipf_mixed_universe`] churn set (rank 0
/// hottest; θ = 0 degenerates to a uniform mixed stream). Unlike
/// [`mixed`], lookups and deletes may target currently-absent keys — hot
/// keys are inserted, read, deleted and re-inserted repeatedly, the
/// serving-cache churn pattern the paper's §V streams never produce.
/// Every insert of a key carries a fresh op-index-derived value, so a
/// stale read surfaces as a value mismatch rather than a silent pass.
/// Deterministic in `seed`.
pub fn zipf_mixed(n: usize, mix: Mix, theta: f64, seed: u64) -> Vec<Op> {
    zipf_mixed_shift(n, mix, theta, 1, seed)
}

/// Phased hot-set-shift variant of [`zipf_mixed`]: the stream splits into
/// `phases` equal segments and the Zipf rank→key mapping rotates by
/// `universe/phases` ranks each segment, so the hot set *moves* — the
/// adversarial pattern for any cache whose eviction lags a popularity
/// shift.
pub fn zipf_mixed_shift(n: usize, mix: Mix, theta: f64, phases: usize, seed: u64) -> Vec<Op> {
    assert!((mix.total() - 1.0).abs() < 1e-9);
    assert!(mix.rmw_total() < 1e-12, "zipf_mixed is a three-class generator; use rmw_mixed()");
    assert!(phases >= 1, "at least one phase");
    let universe = zipf_mixed_universe(n, seed);
    let m = universe.len();
    let rotation = (m / phases).max(1);
    let per_phase = n.div_ceil(phases);
    let z = Zipf::new(m as u64, theta);
    let mut rng = Xoshiro256::seeded(seed);
    (0..n)
        .map(|i| {
            let phase = i / per_phase.max(1);
            let rank = z.sample(&mut rng) as usize;
            let key = universe[(rank + phase * rotation) % m];
            let r = rng.f64();
            if r < mix.insert {
                Op::Insert { key, value: key ^ (i as u32).rotate_left(13) ^ 0x9E37 }
            } else if r < mix.insert + mix.lookup {
                Op::Lookup { key }
            } else {
                Op::Delete { key }
            }
        })
        .collect()
}

/// Universe backing an [`rmw_mixed`] stream of `n` ops — exposed so
/// drivers can pre-populate (or size tables for) exactly the keys the
/// stream will touch.
pub fn rmw_universe(n: usize, seed: u64) -> Vec<u32> {
    unique_uniform_keys((n / 16).max(64), seed ^ 0x4D57_CAFE)
}

/// RMW-class mixed stream for the typed operation plane: op classes
/// drawn from the full six-class `mix`, keys drawn uniformly over the
/// [`rmw_universe`] churn set. The generator tracks a sequential model
/// of the table so conditional ops are meaningful: a `Cas` carries the
/// model's current value as `expected` ~80 % of the time (a hit when
/// replayed sequentially) and a deliberately stale value otherwise, and
/// the model applies exactly the plane's semantics (CAS writes iff
/// `expected` matches, fetch-add creates absent keys at `delta`).
/// Deterministic in `seed`; replaying against any correct sequential
/// implementation reproduces the model's results op for op.
pub fn rmw_mixed(n: usize, mix: Mix, seed: u64) -> Vec<Op> {
    assert!((mix.total() - 1.0).abs() < 1e-9);
    let universe = rmw_universe(n, seed);
    let m = universe.len() as u64;
    let mut rng = Xoshiro256::seeded(seed);
    let mut model: std::collections::HashMap<u32, u32> = std::collections::HashMap::new();
    let mut ops = Vec::with_capacity(n);
    for i in 0..n {
        let key = universe[rng.below(m) as usize];
        let fresh = (i as u32).rotate_left(11) ^ key ^ 0x5EED;
        let r = rng.f64();
        let t1 = mix.insert;
        let t2 = t1 + mix.upsert;
        let t3 = t2 + mix.cas;
        let t4 = t3 + mix.fetch_add;
        let t5 = t4 + mix.lookup;
        let op = if r < t1 {
            model.insert(key, fresh);
            Op::Insert { key, value: fresh }
        } else if r < t2 {
            model.insert(key, fresh);
            Op::Upsert { key, value: fresh }
        } else if r < t3 {
            // ~80 % of CAS ops carry the model's current value (a hit on
            // present keys); the rest race a stale expectation
            let current = model.get(&key).copied();
            let expected = match current {
                Some(v) if rng.f64() < 0.8 => v,
                _ => fresh ^ 0xA5A5,
            };
            if current == Some(expected) {
                model.insert(key, fresh);
            }
            Op::Cas { key, expected, new: fresh }
        } else if r < t4 {
            let delta = (rng.next_u32() & 0xFF) + 1;
            let e = model.entry(key).or_insert(0);
            // the plane creates absent keys at `delta`; the entry starts
            // at 0 here so the one wrapping_add below covers both cases
            *e = e.wrapping_add(delta);
            Op::FetchAdd { key, delta }
        } else if r < t5 {
            Op::Lookup { key }
        } else {
            model.remove(&key);
            Op::Delete { key }
        };
        ops.push(op);
    }
    ops
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unique_keys_are_unique() {
        let keys = unique_uniform_keys(100_000, 7);
        assert_eq!(keys.len(), 100_000);
        let mut sorted = keys.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), 100_000, "duplicate keys generated");
        assert!(!keys.contains(&EMPTY_KEY));
    }

    #[test]
    fn deterministic_by_seed() {
        assert_eq!(unique_uniform_keys(1000, 1), unique_uniform_keys(1000, 1));
        assert_ne!(unique_uniform_keys(1000, 1), unique_uniform_keys(1000, 2));
        assert_eq!(mixed(1000, Mix::PAPER_IMBALANCED, 3), mixed(1000, Mix::PAPER_IMBALANCED, 3));
    }

    #[test]
    fn mixed_ratios_approximate_target() {
        let ops = mixed(100_000, Mix::PAPER_IMBALANCED, 11);
        let ins = ops.iter().filter(|o| matches!(o, Op::Insert { .. })).count() as f64;
        let luk = ops.iter().filter(|o| matches!(o, Op::Lookup { .. })).count() as f64;
        let del = ops.iter().filter(|o| matches!(o, Op::Delete { .. })).count() as f64;
        let n = ops.len() as f64;
        assert!((ins / n - 0.5).abs() < 0.02, "insert ratio {}", ins / n);
        assert!((luk / n - 0.3).abs() < 0.02, "lookup ratio {}", luk / n);
        assert!((del / n - 0.2).abs() < 0.02, "delete ratio {}", del / n);
    }

    #[test]
    fn mixed_deletes_target_live_keys() {
        // replaying a mixed stream against a reference map never deletes
        // or looks up a key that was not inserted first
        use std::collections::HashSet;
        let ops = mixed(20_000, Mix::PAPER_IMBALANCED, 5);
        let mut live: HashSet<u32> = HashSet::new();
        for op in &ops {
            match *op {
                Op::Insert { key, .. } => {
                    live.insert(key);
                }
                Op::Lookup { key } => assert!(live.contains(&key), "lookup of dead key"),
                Op::Delete { key } => assert!(live.remove(&key), "delete of dead key"),
            }
        }
    }

    /// Inverse of an odd `a` modulo 2³² (Newton's iteration: correct to
    /// 3 bits at `x = a`, doubling per step).
    fn odd_inverse(a: u32) -> u32 {
        let mut x = a;
        for _ in 0..4 {
            x = x.wrapping_mul(2u32.wrapping_sub(a.wrapping_mul(x)));
        }
        x
    }

    #[test]
    fn empty_key_in_window_remaps_without_collision() {
        // Drive the progression helper through a window that contains
        // EMPTY_KEY directly: stride 5, EMPTY_KEY at index 7.
        let stride = 5u32;
        let start = EMPTY_KEY.wrapping_sub(7 * stride);
        let n = 100usize;
        let keys = keys_from_stride(n, start, stride);
        assert_eq!(keys.len(), n);
        assert!(!keys.contains(&EMPTY_KEY));
        // the substitute is the progression's index-n element, not a
        // constant that another window element could collide with
        assert_eq!(keys[7], start.wrapping_add(n as u32 * stride));
        let mut sorted = keys.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), n, "remap produced a duplicate");
    }

    #[test]
    fn seeded_empty_key_window_regression() {
        // Regression for the old fixed `EMPTY_KEY → 0x7FFF_FFFF` remap:
        // search (deterministically, via the stride's modular inverse)
        // for a seed whose derived (start, stride) place EMPTY_KEY inside
        // the window, then assert the public generator's guarantees hold
        // on exactly that seed.
        let n = 1usize << 16;
        let mut found = None;
        // hit probability is n/2³² ≈ 1/65536 per seed; 2M seeds make a
        // miss astronomically unlikely, and the scan is a few ms of
        // integer arithmetic
        for seed in 0..2_000_000u64 {
            let mut rng = Xoshiro256::seeded(seed);
            let stride = (rng.next_u32() | 1).max(3);
            let start = rng.next_u32();
            // index of EMPTY_KEY in the progression: (EMPTY_KEY - start) / stride
            let i0 = EMPTY_KEY.wrapping_sub(start).wrapping_mul(odd_inverse(stride));
            if (i0 as usize) < n {
                found = Some((seed, i0));
                break;
            }
        }
        let (seed, i0) = found.expect("no seed maps EMPTY_KEY into a 2^16 window");
        assert!((i0 as usize) < n, "search invariant");
        let keys = unique_uniform_keys(n, seed);
        assert_eq!(keys.len(), n);
        assert!(!keys.contains(&EMPTY_KEY), "sentinel leaked through the remap");
        let mut sorted = keys.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), n, "EMPTY_KEY remap collided with a window element");
    }

    #[test]
    fn zipf_mixed_is_deterministic_and_in_universe() {
        let ops = zipf_mixed(10_000, Mix::READ_HEAVY, 0.99, 42);
        assert_eq!(ops, zipf_mixed(10_000, Mix::READ_HEAVY, 0.99, 42));
        assert_ne!(ops, zipf_mixed(10_000, Mix::READ_HEAVY, 0.99, 43));
        let universe: std::collections::HashSet<u32> =
            zipf_mixed_universe(10_000, 42).into_iter().collect();
        for op in &ops {
            assert!(universe.contains(&op.key()), "key outside the churn universe");
        }
        // ratios approximate the mix
        let n = ops.len() as f64;
        let luk = ops.iter().filter(|o| matches!(o, Op::Lookup { .. })).count() as f64;
        assert!((luk / n - 0.85).abs() < 0.02, "lookup ratio {}", luk / n);
    }

    #[test]
    fn zipf_mixed_skew_concentrates_on_hot_keys() {
        use std::collections::HashMap;
        let ops = zipf_mixed(50_000, Mix::READ_HEAVY, 0.99, 7);
        let mut freq: HashMap<u32, usize> = HashMap::new();
        for op in &ops {
            *freq.entry(op.key()).or_default() += 1;
        }
        let mut counts: Vec<usize> = freq.values().copied().collect();
        counts.sort_unstable_by(|a, b| b.cmp(a));
        let top10: usize = counts.iter().take(10).sum();
        // θ=0.99 over a universe of n/8 keys puts ≈30% of the mass on the
        // top-10 ranks (Σ₁..₁₀ k^-0.99 / H_m); assert a safe 25% floor
        assert!(
            top10 * 4 > ops.len(),
            "θ=0.99: top-10 keys should carry > a quarter of the stream, got {top10}/{}",
            ops.len()
        );
        // θ = 0 spreads: the hottest key stays far below the skewed head
        let uni = zipf_mixed(50_000, Mix::READ_HEAVY, 0.0, 7);
        let mut f0: HashMap<u32, usize> = HashMap::new();
        for op in &uni {
            *f0.entry(op.key()).or_default() += 1;
        }
        let hottest_uniform = f0.values().copied().max().unwrap();
        assert!(hottest_uniform * 20 < top10, "θ=0 stream unexpectedly skewed");
    }

    #[test]
    fn hot_set_shift_moves_the_head() {
        use std::collections::HashMap;
        let phases = 4usize;
        let n = 40_000usize;
        let ops = zipf_mixed_shift(n, Mix::READ_HEAVY, 1.2, phases, 11);
        let per = n / phases;
        let hottest = |seg: &[Op]| -> u32 {
            let mut f: HashMap<u32, usize> = HashMap::new();
            for op in seg {
                *f.entry(op.key()).or_default() += 1;
            }
            f.into_iter().max_by_key(|&(_, c)| c).unwrap().0
        };
        let h0 = hottest(&ops[..per]);
        let h1 = hottest(&ops[per..2 * per]);
        assert_ne!(h0, h1, "hot set did not shift between phases");
    }

    #[test]
    fn zipf_lookups_hit_universe() {
        let universe = unique_uniform_keys(1000, 9);
        let ops = zipf_lookups(10_000, &universe, 0.99, 10);
        let set: std::collections::HashSet<u32> = universe.iter().copied().collect();
        for op in ops {
            assert!(set.contains(&op.key()));
        }
    }

    #[test]
    fn rmw_mixed_is_deterministic_and_in_universe() {
        let ops = rmw_mixed(10_000, Mix::RMW_HEAVY, 77);
        assert_eq!(ops, rmw_mixed(10_000, Mix::RMW_HEAVY, 77));
        assert_ne!(ops, rmw_mixed(10_000, Mix::RMW_HEAVY, 78));
        let universe: std::collections::HashSet<u32> =
            rmw_universe(10_000, 77).into_iter().collect();
        for op in &ops {
            assert!(universe.contains(&op.key()), "key outside the RMW universe");
        }
    }

    #[test]
    fn rmw_mixed_ratios_approximate_target() {
        let ops = rmw_mixed(100_000, Mix::RMW_HEAVY, 13);
        let frac = |pred: &dyn Fn(&Op) -> bool| -> f64 {
            ops.iter().filter(|o| pred(o)).count() as f64 / ops.len() as f64
        };
        assert!((frac(&|o| matches!(o, Op::Insert { .. })) - 0.05).abs() < 0.01);
        assert!((frac(&|o| matches!(o, Op::Upsert { .. })) - 0.20).abs() < 0.01);
        assert!((frac(&|o| matches!(o, Op::Cas { .. })) - 0.25).abs() < 0.01);
        assert!((frac(&|o| matches!(o, Op::FetchAdd { .. })) - 0.25).abs() < 0.01);
        assert!((frac(&|o| matches!(o, Op::Lookup { .. })) - 0.20).abs() < 0.01);
        assert!((frac(&|o| matches!(o, Op::Delete { .. })) - 0.05).abs() < 0.01);
    }

    #[test]
    fn rmw_mixed_cas_expectations_mostly_hit_sequentially() {
        // Replaying the stream against a sequential model, a meaningful
        // share of CAS ops must succeed (the generator aims ~80 % of CAS
        // ops at the model's live value) and a meaningful share must
        // fail — both arms of the conditional path get exercised.
        let ops = rmw_mixed(50_000, Mix::RMW_HEAVY, 21);
        let mut model: std::collections::HashMap<u32, u32> = std::collections::HashMap::new();
        let (mut cas_ok, mut cas_fail) = (0usize, 0usize);
        for op in &ops {
            match *op {
                Op::Insert { key, value } | Op::Upsert { key, value } => {
                    model.insert(key, value);
                }
                Op::Update { key, value } => {
                    if let Some(v) = model.get_mut(&key) {
                        *v = value;
                    }
                }
                Op::InsertIfAbsent { key, value } => {
                    model.entry(key).or_insert(value);
                }
                Op::Cas { key, expected, new } => {
                    if model.get(&key) == Some(&expected) {
                        model.insert(key, new);
                        cas_ok += 1;
                    } else {
                        cas_fail += 1;
                    }
                }
                Op::FetchAdd { key, delta } => {
                    let e = model.entry(key).or_insert(0);
                    *e = e.wrapping_add(delta);
                }
                Op::Lookup { .. } => {}
                Op::Delete { key } => {
                    model.remove(&key);
                }
            }
        }
        let total = (cas_ok + cas_fail) as f64;
        assert!(cas_ok as f64 / total > 0.5, "CAS hit rate {:.2}", cas_ok as f64 / total);
        assert!(cas_fail as f64 / total > 0.05, "CAS miss rate {:.2}", cas_fail as f64 / total);
    }

    #[test]
    fn classic_generators_reject_rmw_fractions() {
        let bad = Mix { lookup: 0.8, ..Mix::RMW_HEAVY };
        assert!(std::panic::catch_unwind(|| mixed(10, bad, 1)).is_err());
        assert!(std::panic::catch_unwind(|| zipf_mixed(10, bad, 0.9, 1)).is_err());
    }
}
