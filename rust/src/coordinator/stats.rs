//! Service-level statistics: throughput, latency, batch shapes, resize
//! activity — aggregated across workers.

use crate::core::histogram::Histogram;

/// Per-worker counters merged into a service view.
#[derive(Debug, Default, Clone)]
pub struct ServiceStats {
    /// Total operations completed.
    pub ops: u64,
    /// Dispatch windows executed.
    pub batches: u64,
    /// Entries inserted / replaced / stashed / deleted.
    pub inserted: u64,
    pub replaced: u64,
    pub stashed: u64,
    pub deleted: u64,
    /// Resize events (grow, shrink).
    pub grows: u64,
    pub shrinks: u64,
    /// Per-op latency in nanoseconds (request → reply, single-op path).
    pub latency_ns: Histogram,
    /// Batch size distribution.
    pub batch_sizes: Histogram,
}

impl ServiceStats {
    /// Merge another worker's stats into this aggregate.
    pub fn merge(&mut self, other: &ServiceStats) {
        self.ops += other.ops;
        self.batches += other.batches;
        self.inserted += other.inserted;
        self.replaced += other.replaced;
        self.stashed += other.stashed;
        self.deleted += other.deleted;
        self.grows += other.grows;
        self.shrinks += other.shrinks;
        self.latency_ns.merge(&other.latency_ns);
        self.batch_sizes.merge(&other.batch_sizes);
    }

    /// Mean batch size.
    pub fn mean_batch(&self) -> f64 {
        self.batch_sizes.mean()
    }

    /// Human summary line.
    pub fn summary(&self) -> String {
        format!(
            "ops={} batches={} mean_batch={:.1} inserted={} replaced={} stashed={} deleted={} grows={} shrinks={} latency[{}]",
            self.ops,
            self.batches,
            self.mean_batch(),
            self.inserted,
            self.replaced,
            self.stashed,
            self.deleted,
            self.grows,
            self.shrinks,
            self.latency_ns.summary(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn merge_adds_counters() {
        let mut a = ServiceStats::default();
        a.ops = 10;
        a.batches = 2;
        a.latency_ns.record(100);
        let mut b = ServiceStats::default();
        b.ops = 5;
        b.batches = 1;
        b.latency_ns.record(300);
        a.merge(&b);
        assert_eq!(a.ops, 15);
        assert_eq!(a.batches, 3);
        assert_eq!(a.latency_ns.count(), 2);
        assert!(a.summary().contains("ops=15"));
    }
}
