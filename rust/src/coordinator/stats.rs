//! Service-level statistics: throughput, latency, batch shapes, resize
//! activity — aggregated across workers.

use crate::core::histogram::Histogram;
use crate::native::table::InsertOutcome;
use crate::workload::OpResult;

/// Per-worker counters merged into a service view.
#[derive(Debug, Default, Clone)]
pub struct ServiceStats {
    /// Total operations completed.
    pub ops: u64,
    /// Dispatch windows executed.
    pub batches: u64,
    /// Insert-class placements by [`InsertOutcome`]: fresh WABC claims,
    /// in-place replaces, cuckoo-evicted placements, stash redirects —
    /// the full four-step attribution the old boolean reply discarded.
    pub inserted: u64,
    pub replaced: u64,
    pub evicted: u64,
    pub stashed: u64,
    pub deleted: u64,
    /// Typed RMW traffic: applied updates (write-if-present hits),
    /// CAS verdicts, and fetch-add completions.
    pub updates: u64,
    pub cas_succeeded: u64,
    pub cas_failed: u64,
    pub fetch_adds: u64,
    /// Resize events (grow, shrink).
    pub grows: u64,
    pub shrinks: u64,
    /// Hot-key cache traffic: lookups served from the cache, lookups
    /// that consulted the cache and missed (write-conflicted lookups
    /// bypass it and count as neither), per-key entries retired by
    /// writes, and wholesale flushes forced by a moved coherence stamp.
    pub cache_hits: u64,
    pub cache_misses: u64,
    pub cache_invalidations: u64,
    pub cache_flushes: u64,
    /// Shard-plane traffic: requests this worker forwarded to their
    /// owning shard (the sender raced a directory flip), partition
    /// moves this worker started and settled as the destination, keys
    /// it copied out of source shards, and ops it executed dual-table
    /// because their partition was mid-move.
    pub forwarded: u64,
    pub moves_started: u64,
    pub moves_completed: u64,
    pub keys_migrated: u64,
    pub moving_ops: u64,
    /// Wire-plane (network front door) accounting, populated by
    /// `net::NetServer` and zero for in-process use: connections
    /// accepted over the server's lifetime, connections turned away at
    /// the `max_connections` cap, connections live right now (a gauge,
    /// not a counter), bytes read from / written to sockets, RESP
    /// commands decoded, and malformed frames that closed a connection.
    pub net_connections_opened: u64,
    pub net_connections_rejected: u64,
    pub net_connections_active: u64,
    pub net_bytes_in: u64,
    pub net_bytes_out: u64,
    pub net_commands: u64,
    pub net_protocol_errors: u64,
    /// Per-command wire latency in nanoseconds (command submitted →
    /// reply rendered: ticket waits plus reply folding, excluding
    /// socket transmission).
    pub net_cmd_latency_ns: Histogram,
    /// Per-op latency in nanoseconds (request → completion: queue delay
    /// plus service time), recorded for the single-op *and* bulk paths.
    pub latency_ns: Histogram,
    /// Queue delay in nanoseconds (request enqueue → dispatch start),
    /// recorded for both paths; the pipelined plane's ring backlog
    /// shows up here rather than in service time.
    pub queue_delay_ns: Histogram,
    /// Requests standing in the plane when a window dispatched (waiting
    /// singles or bulk ops, plus the submission-ring backlog) — the
    /// pipelining depth the workers actually see.
    pub inflight_depth: Histogram,
    /// Batch size distribution.
    pub batch_sizes: Histogram,
}

impl ServiceStats {
    /// Fold one dispatch window's typed results into the counters —
    /// the per-outcome accounting the old lossy `bool` replies made
    /// impossible (ISSUE 5 satellite).
    pub fn record_results(&mut self, results: &[OpResult]) {
        for r in results {
            match *r {
                OpResult::Upserted { outcome, .. } => self.record_outcome(outcome),
                OpResult::InsertedIfAbsent { outcome: Some(o), .. } => self.record_outcome(o),
                OpResult::InsertedIfAbsent { outcome: None, .. } => {}
                OpResult::Updated { old: Some(_) } => self.updates += 1,
                OpResult::Updated { old: None } => {}
                OpResult::Cas { ok: true, .. } => self.cas_succeeded += 1,
                OpResult::Cas { ok: false, .. } => self.cas_failed += 1,
                OpResult::FetchAdded { outcome, .. } => {
                    self.fetch_adds += 1;
                    if let Some(o) = outcome {
                        self.record_outcome(o);
                    }
                }
                OpResult::Deleted(true) => self.deleted += 1,
                OpResult::Deleted(false) | OpResult::Value(_) => {}
            }
        }
    }

    fn record_outcome(&mut self, outcome: InsertOutcome) {
        match outcome {
            InsertOutcome::Inserted => self.inserted += 1,
            InsertOutcome::Replaced => self.replaced += 1,
            InsertOutcome::Evicted => self.evicted += 1,
            InsertOutcome::Stashed => self.stashed += 1,
        }
    }

    /// Merge another worker's stats into this aggregate.
    pub fn merge(&mut self, other: &ServiceStats) {
        self.ops += other.ops;
        self.batches += other.batches;
        self.inserted += other.inserted;
        self.replaced += other.replaced;
        self.evicted += other.evicted;
        self.stashed += other.stashed;
        self.deleted += other.deleted;
        self.updates += other.updates;
        self.cas_succeeded += other.cas_succeeded;
        self.cas_failed += other.cas_failed;
        self.fetch_adds += other.fetch_adds;
        self.grows += other.grows;
        self.shrinks += other.shrinks;
        self.cache_hits += other.cache_hits;
        self.cache_misses += other.cache_misses;
        self.cache_invalidations += other.cache_invalidations;
        self.cache_flushes += other.cache_flushes;
        self.forwarded += other.forwarded;
        self.moves_started += other.moves_started;
        self.moves_completed += other.moves_completed;
        self.keys_migrated += other.keys_migrated;
        self.moving_ops += other.moving_ops;
        self.net_connections_opened += other.net_connections_opened;
        self.net_connections_rejected += other.net_connections_rejected;
        self.net_connections_active += other.net_connections_active;
        self.net_bytes_in += other.net_bytes_in;
        self.net_bytes_out += other.net_bytes_out;
        self.net_commands += other.net_commands;
        self.net_protocol_errors += other.net_protocol_errors;
        self.net_cmd_latency_ns.merge(&other.net_cmd_latency_ns);
        self.latency_ns.merge(&other.latency_ns);
        self.queue_delay_ns.merge(&other.queue_delay_ns);
        self.inflight_depth.merge(&other.inflight_depth);
        self.batch_sizes.merge(&other.batch_sizes);
    }

    /// Hot-key cache hit rate over lookups that consulted the cache
    /// (0.0 while the cache is disabled or untouched).
    pub fn cache_hit_rate(&self) -> f64 {
        let total = self.cache_hits + self.cache_misses;
        if total == 0 {
            0.0
        } else {
            self.cache_hits as f64 / total as f64
        }
    }

    /// Mean batch size.
    pub fn mean_batch(&self) -> f64 {
        self.batch_sizes.mean()
    }

    /// Human summary line.
    pub fn summary(&self) -> String {
        let mut line = format!(
            "ops={} batches={} mean_batch={:.1} inserted={} replaced={} evicted={} stashed={} deleted={} rmw[upd={} cas={}/{} fadd={}] grows={} shrinks={} cache[hit={} miss={} rate={:.2} inv={} flush={}] shard[fwd={} moves={}/{} keys={} moving_ops={}] latency[{}] queue[{}] depth[mean={:.1} max={}]",
            self.ops,
            self.batches,
            self.mean_batch(),
            self.inserted,
            self.replaced,
            self.evicted,
            self.stashed,
            self.deleted,
            self.updates,
            self.cas_succeeded,
            self.cas_failed,
            self.fetch_adds,
            self.grows,
            self.shrinks,
            self.cache_hits,
            self.cache_misses,
            self.cache_hit_rate(),
            self.cache_invalidations,
            self.cache_flushes,
            self.forwarded,
            self.moves_completed,
            self.moves_started,
            self.keys_migrated,
            self.moving_ops,
            self.latency_ns.summary(),
            self.queue_delay_ns.summary(),
            self.inflight_depth.mean(),
            self.inflight_depth.max(),
        );
        // the wire plane only appears once a NetServer populated it
        if self.net_connections_opened > 0 || self.net_commands > 0 {
            line.push_str(&format!(
                " net[conns={}/{} rejected={} cmds={} in={}B out={}B proto_err={} cmd_lat[{}]]",
                self.net_connections_active,
                self.net_connections_opened,
                self.net_connections_rejected,
                self.net_commands,
                self.net_bytes_in,
                self.net_bytes_out,
                self.net_protocol_errors,
                self.net_cmd_latency_ns.summary(),
            ));
        }
        line
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn merge_adds_counters() {
        let mut a = ServiceStats::default();
        a.ops = 10;
        a.batches = 2;
        a.latency_ns.record(100);
        let mut b = ServiceStats::default();
        b.ops = 5;
        b.batches = 1;
        b.latency_ns.record(300);
        b.queue_delay_ns.record(40);
        b.inflight_depth.record(7);
        a.merge(&b);
        assert_eq!(a.ops, 15);
        assert_eq!(a.batches, 3);
        assert_eq!(a.latency_ns.count(), 2);
        assert_eq!(a.queue_delay_ns.count(), 1);
        assert_eq!(a.inflight_depth.max(), 7);
        assert!(a.summary().contains("ops=15"));
        assert!(a.summary().contains("queue["), "summary must surface queue delay");
    }

    #[test]
    fn record_results_attributes_outcomes() {
        use crate::native::table::InsertOutcome;
        let mut s = ServiceStats::default();
        s.record_results(&[
            OpResult::Upserted { outcome: InsertOutcome::Inserted, old: None },
            OpResult::Upserted { outcome: InsertOutcome::Replaced, old: Some(1) },
            OpResult::Upserted { outcome: InsertOutcome::Evicted, old: None },
            OpResult::Upserted { outcome: InsertOutcome::Stashed, old: None },
            OpResult::InsertedIfAbsent { outcome: Some(InsertOutcome::Inserted), existing: None },
            OpResult::InsertedIfAbsent { outcome: None, existing: Some(7) },
            OpResult::Updated { old: Some(3) },
            OpResult::Updated { old: None },
            OpResult::Cas { ok: true, actual: Some(3) },
            OpResult::Cas { ok: false, actual: None },
            OpResult::FetchAdded { outcome: None, old: Some(4) },
            OpResult::FetchAdded { outcome: Some(InsertOutcome::Inserted), old: None },
            OpResult::Deleted(true),
            OpResult::Deleted(false),
            OpResult::Value(Some(9)),
        ]);
        assert_eq!(s.inserted, 3, "claim + if-absent + fetch-add-create");
        assert_eq!(s.replaced, 1);
        assert_eq!(s.evicted, 1);
        assert_eq!(s.stashed, 1);
        assert_eq!(s.updates, 1);
        assert_eq!(s.cas_succeeded, 1);
        assert_eq!(s.cas_failed, 1);
        assert_eq!(s.fetch_adds, 2);
        assert_eq!(s.deleted, 1);
        let line = s.summary();
        assert!(line.contains("evicted=1"), "{line}");
        assert!(line.contains("rmw[upd=1 cas=1/1 fadd=2]"), "{line}");
        // merged aggregates keep the new counters
        let mut agg = ServiceStats::default();
        agg.merge(&s);
        agg.merge(&s);
        assert_eq!(agg.evicted, 2);
        assert_eq!(agg.cas_succeeded, 2);
        assert_eq!(agg.fetch_adds, 4);
        assert_eq!(agg.updates, 2);
    }

    #[test]
    fn shard_counters_merge_and_surface_in_summary() {
        let mut a = ServiceStats::default();
        a.forwarded = 3;
        a.moves_started = 2;
        a.moves_completed = 1;
        a.keys_migrated = 40;
        a.moving_ops = 9;
        let mut b = ServiceStats::default();
        b.forwarded = 1;
        b.moves_started = 1;
        b.moves_completed = 2;
        b.keys_migrated = 10;
        b.moving_ops = 1;
        a.merge(&b);
        assert_eq!(a.forwarded, 4);
        assert_eq!(a.moves_started, 3);
        assert_eq!(a.moves_completed, 3);
        assert_eq!(a.keys_migrated, 50);
        assert_eq!(a.moving_ops, 10);
        let line = a.summary();
        assert!(line.contains("shard[fwd=4 moves=3/3 keys=50 moving_ops=10]"), "{line}");
    }

    #[test]
    fn net_counters_merge_and_surface_only_when_populated() {
        let quiet = ServiceStats::default();
        assert!(
            !quiet.summary().contains("net["),
            "in-process stats must not render an empty wire section"
        );
        let mut a = ServiceStats::default();
        a.net_connections_opened = 4;
        a.net_connections_active = 2;
        a.net_bytes_in = 100;
        a.net_bytes_out = 300;
        a.net_commands = 50;
        a.net_cmd_latency_ns.record(1_000);
        let mut b = ServiceStats::default();
        b.net_connections_opened = 1;
        b.net_connections_rejected = 3;
        b.net_bytes_in = 10;
        b.net_commands = 5;
        b.net_protocol_errors = 2;
        b.net_cmd_latency_ns.record(9_000);
        a.merge(&b);
        assert_eq!(a.net_connections_opened, 5);
        assert_eq!(a.net_connections_rejected, 3);
        assert_eq!(a.net_connections_active, 2);
        assert_eq!(a.net_bytes_in, 110);
        assert_eq!(a.net_bytes_out, 300);
        assert_eq!(a.net_commands, 55);
        assert_eq!(a.net_protocol_errors, 2);
        assert_eq!(a.net_cmd_latency_ns.count(), 2);
        let line = a.summary();
        assert!(line.contains("net[conns=2/5 rejected=3 cmds=55"), "{line}");
    }

    #[test]
    fn cache_counters_merge_and_rate() {
        let mut a = ServiceStats::default();
        assert_eq!(a.cache_hit_rate(), 0.0, "untouched cache reads as 0");
        a.cache_hits = 30;
        a.cache_misses = 10;
        let mut b = ServiceStats::default();
        b.cache_hits = 10;
        b.cache_misses = 10;
        b.cache_invalidations = 4;
        b.cache_flushes = 1;
        a.merge(&b);
        assert_eq!(a.cache_hits, 40);
        assert_eq!(a.cache_misses, 20);
        assert_eq!(a.cache_invalidations, 4);
        assert_eq!(a.cache_flushes, 1);
        assert!((a.cache_hit_rate() - 40.0 / 60.0).abs() < 1e-12);
        assert!(a.summary().contains("cache[hit=40"));
    }
}
