//! The shard plane: a partition→shard directory consulted on the
//! routing path, plus the placement policy that pins workers (and the
//! structures they allocate) to CPUs.
//!
//! ## Directory
//!
//! Keys hash to one of `partitions_per_shard × shards` fixed routing
//! partitions; each partition has one directory entry naming its owner
//! shard. An entry is a single `AtomicU64` packed as
//! `[seq:32][src:16][dst:16]` and read with **one shared load** on every
//! route — the same seqlock discipline the table's `drain_epoch` uses:
//! an even sequence means the partition is settled on `src == dst`; an
//! odd sequence means it is *moving* from `src` to `dst`. Clients route
//! to `dst` in both states (new traffic lands on the incoming owner
//! immediately), and workers re-classify authoritatively at dispatch
//! time, so a stale client-side read can only cost a forward hop —
//! never a wrong-table execution.
//!
//! The default mapping assigns partition `p` to shard `p % shards`.
//! Because the partition count is a multiple of the shard count,
//! `hash % partitions % shards == hash % shards` — an untouched
//! directory reproduces the pre-shard-plane routing bit for bit, which
//! is what keeps a `shards = 1` (or never-resharded) coordinator
//! behaviorally identical to the single-table one.
//!
//! ## Placement
//!
//! [`Placement`] decides which CPUs each worker thread may run on:
//! round-robin over the online CPUs, or NUMA-node-aware when
//! `/sys/devices/system/node` exposes a topology (each worker is
//! allowed the full CPU set of its node, so the scheduler can still
//! balance within the node). Pinning happens inside the worker thread
//! *before* its backend factory runs, so the backend's allocations
//! first-touch on the pinned node. It is best-effort: an unsupported
//! platform or a refused syscall costs the placement hint, nothing else.

use crate::core::sync::atomic::{AtomicU64, Ordering};
use crate::hash::HashKind;
use crate::native::table::HiveTable;
use std::sync::Arc;

/// Who owns a partition right now (decoded from one directory load).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Ownership {
    /// Settled: every op on the partition executes on this shard.
    Settled(usize),
    /// Mid-move: `dst` is the single executor (serving ops dual-table
    /// against both shards' tables) while the partition's keys migrate
    /// out of `src`.
    Moving { src: usize, dst: usize },
}

/// Partition→shard directory: one packed seqlock word per partition.
pub struct ShardDirectory {
    entries: Box<[AtomicU64]>,
    shards: usize,
}

/// Pack a directory word: `[seq:32][src:16][dst:16]`. Public (hidden)
/// for the shard-directory test battery and the loom model.
#[doc(hidden)]
#[inline]
pub fn pack(seq: u32, src: usize, dst: usize) -> u64 {
    ((seq as u64) << 32) | ((src as u64 & 0xFFFF) << 16) | (dst as u64 & 0xFFFF)
}

/// Unpack a directory word into `(seq, src, dst)`.
#[doc(hidden)]
#[inline]
pub fn unpack(word: u64) -> (u32, usize, usize) {
    ((word >> 32) as u32, ((word >> 16) & 0xFFFF) as usize, (word & 0xFFFF) as usize)
}

impl ShardDirectory {
    /// Directory over `partitions` routing partitions and `shards`
    /// shards, with the identity-preserving default mapping
    /// `partition p → shard p % shards`.
    pub fn new(partitions: usize, shards: usize) -> ShardDirectory {
        assert!(shards >= 1, "a directory needs at least one shard");
        assert!(shards <= u16::MAX as usize, "shard index packs into 16 bits");
        assert!(
            partitions >= shards && partitions % shards == 0,
            "partition count must be a positive multiple of the shard count \
             (that multiple is what makes the default directory reproduce \
             plain modulo routing)"
        );
        let entries = (0..partitions).map(|p| AtomicU64::new(pack(0, p % shards, p % shards)));
        ShardDirectory { entries: entries.collect(), shards }
    }

    /// Routing partition count.
    pub fn partitions(&self) -> usize {
        self.entries.len()
    }

    /// Shard count.
    pub fn shards(&self) -> usize {
        self.shards
    }

    /// Routing partition of `key` — the same salted murmur the
    /// pre-shard-plane coordinator routed with, so shards stay balanced
    /// independently of the table's own bucket hashes.
    #[inline]
    pub fn partition_of(&self, key: u32) -> u32 {
        (HashKind::Murmur3.hash(key ^ 0x9E37_79B9) as usize % self.entries.len()) as u32
    }

    /// Decode a partition's current ownership (one shared load).
    #[inline]
    pub fn ownership(&self, partition: u32) -> Ownership {
        let (seq, src, dst) = unpack(self.entries[partition as usize].load(Ordering::Acquire));
        if seq & 1 == 0 {
            Ownership::Settled(dst)
        } else {
            Ownership::Moving { src, dst }
        }
    }

    /// Shard new traffic for `key` should be sent to: the settled owner,
    /// or the move destination while the partition is in flight.
    #[inline]
    pub fn route(&self, key: u32) -> usize {
        match self.ownership(self.partition_of(key)) {
            Ownership::Settled(s) => s,
            Ownership::Moving { dst, .. } => dst,
        }
    }

    /// Raw directory word for `partition` (one `Acquire` load). Public
    /// (hidden) so the shard-directory battery and the loom model can
    /// assert seq parity / torn-pair invariants directly.
    #[doc(hidden)]
    #[inline]
    pub fn entry_word(&self, partition: u32) -> u64 {
        self.entries[partition as usize].load(Ordering::Acquire)
    }

    /// Flip `partition` from settled-on-`src` to moving-toward-`dst`
    /// (seq goes odd). Fails when the entry is not settled on `src`
    /// anymore — e.g. a racing reshard won the partition first. Called
    /// only by the destination worker's thread (public-but-hidden so the
    /// concurrent settle/flip battery can drive the protocol directly).
    #[doc(hidden)]
    pub fn begin_move(&self, partition: u32, src: usize, dst: usize) -> bool {
        let entry = &self.entries[partition as usize];
        let cur = entry.load(Ordering::Acquire);
        let (seq, _, owner) = unpack(cur);
        if seq & 1 != 0 || owner != src {
            return false;
        }
        entry
            .compare_exchange(
                cur,
                pack(seq.wrapping_add(1), src, dst),
                Ordering::AcqRel,
                Ordering::Acquire,
            )
            .is_ok()
    }

    /// Settle a moving partition on its destination (seq goes even
    /// again). Called only by the destination worker's thread, after the
    /// last source-side key has migrated.
    #[doc(hidden)]
    pub fn finish_move(&self, partition: u32) -> bool {
        let entry = &self.entries[partition as usize];
        let cur = entry.load(Ordering::Acquire);
        let (seq, _, dst) = unpack(cur);
        if seq & 1 == 0 {
            return false;
        }
        entry
            .compare_exchange(
                cur,
                pack(seq.wrapping_add(1), dst, dst),
                Ordering::AcqRel,
                Ordering::Acquire,
            )
            .is_ok()
    }
}

/// What the workers of one coordinator share: the routing directory,
/// and — for native sharded coordinators — every shard's table, so a
/// move destination can execute dual-table ops against the source shard
/// and migrate its keys. Factory-built coordinators (whose backends may
/// not even be tables) get an empty table vector: their directory is
/// static and `Handle::reshard` reports an error.
pub(crate) struct ShardPlane {
    pub(crate) directory: ShardDirectory,
    pub(crate) tables: Vec<Arc<HiveTable>>,
}

/// Shard-plane configuration carried beside [`super::CoordinatorConfig`]
/// (which keeps its exact pre-shard field set — construction sites and
/// the service tests build it as a full struct literal).
#[derive(Debug, Clone)]
pub struct ShardPlan {
    /// Routing partitions per shard. More partitions mean finer-grained
    /// online resharding at the cost of a larger directory; the default
    /// (64) keeps the directory a few cache lines per shard.
    pub partitions_per_shard: usize,
    /// Worker-thread placement policy.
    pub placement: Placement,
}

impl Default for ShardPlan {
    fn default() -> Self {
        ShardPlan { partitions_per_shard: 64, placement: Placement::RoundRobin }
    }
}

/// Where worker threads (and, via first-touch, what they allocate) run.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Placement {
    /// No pinning: the OS scheduler places workers freely. This is what
    /// the pre-shard-plane coordinator did, and what the compatibility
    /// constructors keep doing.
    None,
    /// Worker `w` pinned to CPU `w % ncpus`.
    RoundRobin,
    /// Workers spread across NUMA nodes, each allowed its node's full
    /// CPU set; falls back to [`Placement::RoundRobin`] when no
    /// topology is detectable.
    NumaAware,
}

impl Placement {
    /// CPU set per worker (`None` = leave the thread unpinned).
    pub(crate) fn assign(self, workers: usize) -> Vec<Option<Vec<usize>>> {
        match self {
            Placement::None => vec![None; workers],
            Placement::RoundRobin => {
                let ncpu =
                    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
                (0..workers).map(|w| Some(vec![w % ncpu])).collect()
            }
            Placement::NumaAware => match Topology::detect() {
                Some(t) => {
                    (0..workers).map(|w| Some(t.nodes[w % t.nodes.len()].clone())).collect()
                }
                None => Placement::RoundRobin.assign(workers),
            },
        }
    }
}

/// NUMA topology: the CPU list of each online node, in node order.
#[derive(Debug, Clone)]
pub struct Topology {
    /// `nodes[i]` = CPUs of NUMA node `i` (non-empty).
    pub nodes: Vec<Vec<usize>>,
}

impl Topology {
    /// Detect the NUMA topology from sysfs. `None` when the platform
    /// has no `/sys/devices/system/node` (non-Linux, restricted
    /// container) or it parses to nothing.
    pub fn detect() -> Option<Topology> {
        let dir = std::fs::read_dir("/sys/devices/system/node").ok()?;
        let mut ids: Vec<usize> = dir
            .filter_map(|e| e.ok())
            .filter_map(|e| e.file_name().into_string().ok())
            .filter_map(|name| name.strip_prefix("node").and_then(|n| n.parse().ok()))
            .collect();
        ids.sort_unstable();
        let mut nodes = Vec::with_capacity(ids.len());
        for id in ids {
            let path = format!("/sys/devices/system/node/node{id}/cpulist");
            let Ok(list) = std::fs::read_to_string(path) else { continue };
            let cpus = parse_cpulist(list.trim());
            if !cpus.is_empty() {
                nodes.push(cpus);
            }
        }
        if nodes.is_empty() {
            None
        } else {
            Some(Topology { nodes })
        }
    }
}

/// Parse the kernel's cpulist format (`"0-3,8,10-11"`) into CPU ids.
/// Malformed pieces are skipped rather than failing the whole list.
fn parse_cpulist(list: &str) -> Vec<usize> {
    let mut cpus = Vec::new();
    for piece in list.split(',') {
        let piece = piece.trim();
        if piece.is_empty() {
            continue;
        }
        match piece.split_once('-') {
            Some((lo, hi)) => {
                if let (Ok(lo), Ok(hi)) = (lo.trim().parse::<usize>(), hi.trim().parse()) {
                    if lo <= hi && hi - lo < 4096 {
                        cpus.extend(lo..=hi);
                    }
                }
            }
            None => {
                if let Ok(c) = piece.parse() {
                    cpus.push(c);
                }
            }
        }
    }
    cpus
}

/// Pin the calling thread to `cpus`. Best-effort: returns whether the
/// kernel accepted the mask. CPUs above 1023 are ignored (one fixed
/// 128-byte mask keeps this allocation-free on the spawn path).
pub fn pin_current_thread(cpus: &[usize]) -> bool {
    let mut mask = [0u64; 16];
    let mut any = false;
    for &c in cpus {
        if c < 1024 {
            mask[c / 64] |= 1 << (c % 64);
            any = true;
        }
    }
    any && sched_setaffinity_self(&mask)
}

// `sched_setaffinity(0, size, mask)` by raw syscall — the crate has no
// libc dependency, and a failed call only loses a placement hint.
#[cfg(all(target_os = "linux", target_arch = "x86_64"))]
fn sched_setaffinity_self(mask: &[u64; 16]) -> bool {
    let ret: isize;
    unsafe {
        std::arch::asm!(
            "syscall",
            inlateout("rax") 203isize => ret, // __NR_sched_setaffinity
            in("rdi") 0usize,                 // pid 0 = calling thread
            in("rsi") std::mem::size_of_val(mask),
            in("rdx") mask.as_ptr(),
            lateout("rcx") _,
            lateout("r11") _,
            options(nostack),
        );
    }
    ret == 0
}

#[cfg(all(target_os = "linux", target_arch = "aarch64"))]
fn sched_setaffinity_self(mask: &[u64; 16]) -> bool {
    let ret: isize;
    unsafe {
        std::arch::asm!(
            "svc #0",
            in("x8") 122usize, // __NR_sched_setaffinity
            inlateout("x0") 0isize => ret,
            in("x1") std::mem::size_of_val(mask),
            in("x2") mask.as_ptr(),
            options(nostack),
        );
    }
    ret == 0
}

#[cfg(not(all(target_os = "linux", any(target_arch = "x86_64", target_arch = "aarch64"))))]
fn sched_setaffinity_self(_mask: &[u64; 16]) -> bool {
    false
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_directory_reproduces_modulo_routing() {
        // The identity the unmodified service tests rely on: with an
        // untouched directory, key → shard is exactly the old
        // murmur(key ^ salt) % workers.
        for shards in [1usize, 2, 3, 4, 8] {
            let dir = ShardDirectory::new(64 * shards, shards);
            for key in (0..20_000u32).step_by(7) {
                let legacy = HashKind::Murmur3.hash(key ^ 0x9E37_79B9) as usize % shards;
                assert_eq!(dir.route(key), legacy, "key {key} rerouted at {shards} shards");
            }
        }
    }

    #[test]
    fn move_lifecycle_flips_ownership() {
        let dir = ShardDirectory::new(8, 2);
        assert_eq!(dir.ownership(3), Ownership::Settled(1));
        assert!(dir.begin_move(3, 1, 0));
        assert_eq!(dir.ownership(3), Ownership::Moving { src: 1, dst: 0 });
        // moving partitions route to the destination
        assert!(!dir.begin_move(3, 1, 0), "double begin must fail");
        assert!(dir.finish_move(3));
        assert_eq!(dir.ownership(3), Ownership::Settled(0));
        assert!(!dir.finish_move(3), "settled partitions cannot finish");
        // and the partition can move back
        assert!(dir.begin_move(3, 0, 1));
        assert!(dir.finish_move(3));
        assert_eq!(dir.ownership(3), Ownership::Settled(1));
    }

    #[test]
    fn begin_move_requires_the_claimed_source() {
        let dir = ShardDirectory::new(8, 4);
        assert_eq!(dir.ownership(5), Ownership::Settled(1));
        assert!(!dir.begin_move(5, 0, 2), "stale source view must not flip the entry");
        assert_eq!(dir.ownership(5), Ownership::Settled(1));
    }

    #[test]
    fn routing_follows_a_live_move() {
        let dir = ShardDirectory::new(128, 2);
        // find a key in partition 0 (owner 0 by default)
        let key = (0..).find(|&k| dir.partition_of(k) == 0).unwrap();
        assert_eq!(dir.route(key), 0);
        assert!(dir.begin_move(0, 0, 1));
        assert_eq!(dir.route(key), 1, "new traffic must land on the destination");
        assert!(dir.finish_move(0));
        assert_eq!(dir.route(key), 1);
    }

    #[test]
    fn cpulist_parses_ranges_and_singles() {
        assert_eq!(parse_cpulist("0-3"), vec![0, 1, 2, 3]);
        assert_eq!(parse_cpulist("0,2,4"), vec![0, 2, 4]);
        assert_eq!(parse_cpulist("0-1,8-9"), vec![0, 1, 8, 9]);
        assert_eq!(parse_cpulist(" 5 "), vec![5]);
        assert_eq!(parse_cpulist(""), Vec::<usize>::new());
        assert_eq!(parse_cpulist("junk,3,x-y"), vec![3], "bad pieces are skipped");
    }

    #[test]
    fn placement_assigns_one_set_per_worker() {
        assert_eq!(Placement::None.assign(3), vec![None, None, None]);
        let rr = Placement::RoundRobin.assign(4);
        assert_eq!(rr.len(), 4);
        for set in &rr {
            assert_eq!(set.as_ref().map(Vec::len), Some(1), "round-robin pins one CPU");
        }
        // NumaAware always yields a full assignment (falls back to
        // round-robin without a detectable topology)
        let numa = Placement::NumaAware.assign(4);
        assert_eq!(numa.len(), 4);
        assert!(numa.iter().all(|s| s.as_ref().is_some_and(|v| !v.is_empty())));
    }

    #[test]
    fn pinning_is_best_effort_and_never_panics() {
        // Whatever the platform says, the call must return cleanly.
        let _ = pin_current_thread(&[0]);
        assert!(!pin_current_thread(&[]), "an empty CPU set cannot pin");
        assert!(!pin_current_thread(&[200_000]), "out-of-range CPUs are ignored");
    }
}
