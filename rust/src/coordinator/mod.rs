//! The L3 coordinator: a batching key-value service over pluggable
//! backends — the serving-layer packaging of the Hive table.
//!
//! Architecture (vLLM-router-style, thread-based):
//!
//! ```text
//!             Handle (clone-able, thread-safe)
//!                │  route(key) = murmur(key) % workers
//!     ┌──────────┼──────────────┐
//!     ▼          ▼              ▼
//!  worker 0   worker 1  ...  worker W-1       (std::thread + mpsc)
//!  [batcher]  [batcher]      [batcher]        size+deadline windows
//!     │          │              │
//!  [hot-key]  [hot-key]      [hot-key]        read-through CLOCK cache:
//!  [ cache ]  [ cache ]      [ cache ]        lookup hits skip the backend
//!     │          │              │
//!  Backend    Backend        Backend          native | xla | simt
//!     │          │              │
//!  resize-ctl per worker (load-factor watcher between batches)
//! ```
//!
//! Each worker owns one table shard; requests are routed by key hash, so
//! shards are disjoint and workers never contend. Within a dispatch
//! window the batcher groups by op type (legal for concurrent requests —
//! see `backend`). Between the batcher and the backend sits a per-worker
//! hot-key cache ([`cache::HotKeyCache`]): under skewed traffic the hot
//! head of the key distribution is served without an epoch pin or bucket
//! probe, and coherence is kept by per-key invalidation on every write
//! plus wholesale validation against the backend's coherence stamp
//! (reallocation epoch + stash-drain epoch — see `cache` module docs).
//! The resize controller runs the §IV-C policy between batches,
//! amortized across the service's lifetime — no global pauses.

pub mod batcher;
pub mod cache;
pub mod service;
pub mod stats;

pub use batcher::{BatchPolicy, Batcher};
pub use cache::HotKeyCache;
pub use service::{Coordinator, CoordinatorConfig, Handle};
pub use stats::ServiceStats;

/// Alias re-exported for the resize controller's event type.
pub mod resize_ctl {
    pub use crate::native::resize::ResizeEvent;
}
