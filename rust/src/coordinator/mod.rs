//! The L3 coordinator: a batching key-value service over pluggable
//! backends — the serving-layer packaging of the Hive table.
//!
//! Architecture (vLLM-router-style, thread-based):
//!
//! ```text
//!             Handle (clone-able, thread-safe)
//!                │  route(key) = murmur(key) % workers
//!     ┌──────────┼──────────────┐
//!     ▼          ▼              ▼
//!  worker 0   worker 1  ...  worker W-1       (std::thread + mpsc)
//!  [batcher]  [batcher]      [batcher]        size+deadline windows
//!     │          │              │
//!  Backend    Backend        Backend          native | xla | simt
//!     │          │              │
//!  resize-ctl per worker (load-factor watcher between batches)
//! ```
//!
//! Each worker owns one table shard; requests are routed by key hash, so
//! shards are disjoint and workers never contend. Within a dispatch
//! window the batcher groups by op type (legal for concurrent requests —
//! see `backend`). The resize controller runs the §IV-C policy between
//! batches, amortized across the service's lifetime — no global pauses.

pub mod batcher;
pub mod service;
pub mod stats;

pub use batcher::{BatchPolicy, Batcher};
pub use service::{Coordinator, CoordinatorConfig, Handle};
pub use stats::ServiceStats;

/// Alias re-exported for the resize controller's event type.
pub mod resize_ctl {
    pub use crate::native::resize::ResizeEvent;
}
