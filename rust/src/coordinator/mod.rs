//! The L3 coordinator: a batching key-value service over pluggable
//! backends — the serving-layer packaging of the Hive table.
//!
//! Architecture (pipelined request plane, thread-based):
//!
//! ```text
//!   client threads            Handle (clone-able, thread-safe)
//!   ──────────────            route(key) = murmur(key) % workers
//!   Pipeline: window of N     │
//!   completion tickets        │   blocking typed ops (insert/lookup/
//!   (submit ⇢ poll/wait,      │   delete/upsert/update/cas/fetch_add)
//!   Op in ⇒ OpResult out)     │   = a window-of-1 pipeline
//!              └──────────────┤
//!     ┌──────────┬────────────┴─┐
//!     ▼          ▼              ▼
//!  [sub ring] [sub ring]    [sub ring]        bounded MPSC submission
//!     │          │              │             rings (backpressure)
//!     ▼          ▼              ▼
//!  worker 0   worker 1  ...  worker W-1       (std::thread, drains its
//!  [batcher]  [batcher]      [batcher]        ring into size+deadline
//!     │          │              │             dispatch windows)
//!  [hot-key]  [hot-key]      [hot-key]        read-through CLOCK cache:
//!  [ cache ]  [ cache ]      [ cache ]        lookup hits skip the backend
//!     │          │              │
//!  Backend    Backend        Backend          native | xla | simt
//!     │          │              │
//!  resize-ctl per worker (load-factor watcher between batches)
//!     │          │              │
//!     └──────────┴──────────────┘
//!   completions published per dispatch window
//!   (one wakeup per client window, not one per op)
//! ```
//!
//! Each worker owns one table shard; requests are routed by key hash, so
//! shards are disjoint and workers never contend. Requests enter through
//! a bounded MPSC submission ring per worker ([`pipeline`]): a client
//! thread keeps up to N ops in flight via [`Pipeline`] completion
//! tickets instead of paying a blocking round-trip per op, and bulk
//! `Handle::submit` windows scatter to all shards up front and gather in
//! arrival order. Every request plane is *typed* end-to-end: a
//! [`crate::workload::Op`] goes in, its [`crate::workload::OpResult`]
//! comes back — previous values, CAS verdicts, and the four-step
//! `InsertOutcome` attribution included, in submission order. Within a
//! dispatch window the backend groups by op class (write classes before
//! lookups — legal for concurrent requests; see `backend`). Between the batcher
//! and the backend sits a per-worker hot-key cache
//! ([`cache::HotKeyCache`]): under skewed traffic the hot head of the
//! key distribution is served without an epoch pin or bucket probe, and
//! coherence is kept by per-key invalidation on every write class
//! (including `Update`/`Cas`/`FetchAdd` — applied CAS/Update results
//! repopulate the cache when they are the window's only write to the
//! key) plus wholesale validation against the backend's coherence stamp
//! (reallocation epoch + stash-drain epoch — see `cache` module docs).
//! The resize controller runs the §IV-C policy between batches,
//! amortized across the service's lifetime — no global pauses.
//!
//! Shutdown (or a worker death) can never strand a caller: queued
//! requests are drained with [`crate::core::error::HiveError::Shutdown`]
//! and in-flight tickets complete with the same error (see
//! `tests/test_service.rs`).

pub mod batcher;
pub mod cache;
pub mod pipeline;
pub mod service;
pub mod stats;

pub use batcher::{BatchPolicy, Batcher};
pub use cache::HotKeyCache;
pub use pipeline::{Pipeline, Ticket};
pub use service::{start_native, Coordinator, CoordinatorConfig, Handle};
pub use stats::ServiceStats;

/// Alias re-exported for the resize controller's event type.
pub mod resize_ctl {
    pub use crate::native::resize::ResizeEvent;
}
