//! The L3 coordinator: a batching key-value service over pluggable
//! backends — the serving-layer packaging of the Hive table.
//!
//! Architecture (sharded pipelined request plane, thread-based):
//!
//! ```text
//!   RESP clients (TCP)        ┌ net::NetServer — the network front door
//!   ──────────────            │ acceptor + per-connection reader/writer
//!   GET/SET/INCRBY/CAS/... ──►│ threads; each connection multiplexes its
//!   pipelined on one socket   │ pipelined commands onto one Pipeline
//!                             └──────────────┐ (see SERVING.md)
//!   client threads            Handle (clone-able, thread-safe)
//!   ──────────────            route(key): partition_of(key) ──┐
//!   Pipeline: window of N     │                               │
//!   completion tickets        │   blocking typed ops          ▼
//!   (submit ⇢ poll/wait,      │   = a window-of-1      [shard directory]
//!   Op in ⇒ OpResult out)     │   pipeline             partition → shard,
//!              └──────────────┤                        one seqlock word
//!     ┌──────────┬────────────┴─┐                      per partition
//!     ▼          ▼              ▼
//!  [sub ring] [sub ring]    [sub ring]        bounded MPSC submission
//!     │          │              │             rings (backpressure);
//!     ▼          ▼              ▼             workers forward misrouted
//!  shard 0    shard 1   ...  shard W-1        requests ring-to-ring
//!  [batcher]  [batcher]      [batcher]        (std::thread, optionally
//!     │          │              │             CPU/NUMA-pinned via
//!  [hot-key]  [hot-key]      [hot-key]        shard::Placement, drains
//!  [ cache ]  [ cache ]      [ cache ]        its ring into size+deadline
//!     │          │              │             dispatch windows)
//!  Backend    Backend        Backend          native | xla | simt —
//!     │          │              │             one table per shard: own
//!  resize-ctl per shard                       epoch domain, stash,
//!     │          │              │             coherence stamp, counters
//!     └──────────┴──────────────┘
//!   completions published per dispatch window
//!   (one wakeup per client window, not one per op)
//! ```
//!
//! Each worker owns one **shard**: an independent backend whose table has
//! its own epoch domain, overflow stash, coherence stamp and striped
//! counters, so cross-shard operations never share a cache line. Keys
//! hash into a fixed set of routing partitions and a directory of
//! partition→shard entries ([`shard::ShardDirectory`]) is consulted on
//! every routing decision — one seqlock-validated shared load, the same
//! discipline the table's `drain_epoch` uses. [`Handle::reshard`] moves
//! a partition between shards **online**: the destination worker flips
//! the directory entry (new traffic lands on it immediately), fences the
//! source worker's in-flight windows, serves the partition dual-table
//! while a background chunk loop copies the keys over, then settles the
//! entry — resharding under load never stops the world, mirroring how
//! intra-table resize migrates concurrently with ops. Worker threads are
//! placed by [`shard::Placement`]: unpinned, round-robin over CPUs, or
//! NUMA-node-aware when `/sys` exposes a topology (pinning runs before
//! the backend factory so allocations first-touch on the right node).
//!
//! Requests enter through a bounded MPSC submission ring per worker
//! ([`pipeline`]): a client thread keeps up to N ops in flight via
//! [`Pipeline`] completion tickets instead of paying a blocking
//! round-trip per op, and bulk `Handle::submit` windows scatter
//! per-shard sub-batches up front and gather replies in arrival order —
//! each reply carries the submission positions it resolves, so workers
//! may split or forward sub-windows mid-move and the gather still
//! reassembles exact submission order. Every request plane is *typed*
//! end-to-end: a [`crate::workload::Op`] goes in, its
//! [`crate::workload::OpResult`] comes back — previous values, CAS
//! verdicts, and the four-step `InsertOutcome` attribution included.
//! Within a dispatch window the backend groups by op class (write
//! classes before lookups — legal for concurrent requests; see
//! `backend`). Between the batcher and the backend sits a per-worker
//! hot-key cache ([`cache::HotKeyCache`]): under skewed traffic the hot
//! head of the key distribution is served without an epoch pin or bucket
//! probe, and coherence is kept by per-key invalidation on every write
//! class plus wholesale validation against the backend's coherence stamp
//! (see `cache` module docs); an inbound partition move clears the
//! destination's cache wholesale, and mid-move keys are never cached.
//! The resize controller runs the §IV-C policy between batches per
//! shard, amortized across the service's lifetime — no global pauses.
//!
//! Shutdown (or a worker death) can never strand a caller: queued
//! requests are drained with [`crate::core::error::HiveError::Shutdown`],
//! in-flight tickets complete with the same error, and so do pending
//! reshards and forwarded requests whose target ring died (see
//! `tests/test_service.rs` and `tests/test_migration.rs`). The network
//! front door ([`crate::net`]) inherits the same contract over the
//! wire: every connected RESP client gets a reply, a `-SHUTDOWN`
//! error, or a clean close in bounded time (`tests/test_net.rs`).

pub mod batcher;
pub mod cache;
pub mod pipeline;
pub mod service;
pub mod shard;
pub mod stats;

pub use batcher::{BatchPolicy, Batcher};
pub use cache::HotKeyCache;
pub use pipeline::{Pipeline, Ticket};
pub use service::{start_native, start_native_sharded, Coordinator, CoordinatorConfig, Handle};
pub use shard::{Ownership, Placement, ShardDirectory, ShardPlan, Topology};
pub use stats::ServiceStats;

/// Alias re-exported for the resize controller's event type.
pub mod resize_ctl {
    pub use crate::native::resize::ResizeEvent;
}
