//! The pipelined request plane: completion tickets, bounded per-client
//! in-flight windows, and the bounded MPSC submission ring the workers
//! drain.
//!
//! The pre-pipeline coordinator was a closed loop: every single-key
//! operation allocated a `sync_channel`, sent a request, and blocked on
//! the reply — one op in flight per client thread, one channel wakeup
//! per op. This module replaces that with three pieces:
//!
//! * **Completion slots** — a client-owned window of pre-allocated
//!   slots. Submitting an op reserves a slot and yields a [`Ticket`]
//!   (client side) plus a [`CompletionSlot`] (worker side). The ticket
//!   offers poll ([`Ticket::is_done`], [`Ticket::try_wait`]) and block
//!   ([`Ticket::wait`]) APIs; the slot is published exactly once by the
//!   worker — or by its `Drop` impl with [`HiveError::Shutdown`] if the
//!   worker dies or shuts down with the op in flight, so a blocked
//!   caller can never hang.
//! * **[`Pipeline`]** — a clone of the service handle plus a window of
//!   `depth` slots: one client thread keeps up to `depth` ops in flight
//!   across all shards. The old blocking `Handle` API is a window-of-1
//!   pipeline over the same machinery.
//! * **Submission ring** — a bounded MPSC queue ([`ring`]) replacing
//!   the per-worker unbounded channel. Workers drain it directly into
//!   the batcher; when the receiver dies, queued requests are dropped
//!   (firing their completion slots with `Shutdown`) and blocked
//!   senders are released.
//!
//! Completions are *batched*: the worker publishes a whole dispatch
//! window's results with [`publish_batch`] — one condvar wakeup per
//! client window per dispatch, not one channel wakeup per op.
//!
//! Ordering: ops a client keeps in flight simultaneously are
//! *concurrent* (same contract as ops sharing a dispatch window — see
//! `backend`). A caller that needs read-your-write ordering between two
//! ops must wait the first ticket before submitting the second.

use crate::coordinator::service::Handle;
use crate::core::error::{HiveError, Result};
use crate::workload::{Op, OpResult};
use std::collections::{HashSet, VecDeque};
use std::sync::mpsc::RecvTimeoutError;
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

// ---------------------------------------------------------------------------
// Completion windows: slots, tickets, worker-side publication.
// ---------------------------------------------------------------------------

/// One slot's lifecycle. `seq` (stored beside it) guards against a
/// stale ticket or completion handle touching a recycled slot.
enum SlotState {
    /// No op in flight.
    Free,
    /// Reserved and submitted; `abandoned` is set when the ticket was
    /// dropped without waiting, so the completion frees the slot
    /// directly instead of parking a result nobody will claim.
    Pending {
        /// Ticket dropped before the result arrived.
        abandoned: bool,
    },
    /// Result published, waiting for the ticket to claim it.
    Done(Result<OpResult>),
}

struct Slot {
    seq: u64,
    state: SlotState,
}

struct WindowState {
    slots: Vec<Slot>,
    free: Vec<usize>,
    inflight: usize,
}

/// Shared core of one client window: slot table + the two wakeup edges
/// (a completion arrived / a slot was vacated).
struct Window {
    state: Mutex<WindowState>,
    completed: Condvar,
    vacated: Condvar,
}

impl Window {
    fn with_depth(depth: usize) -> Arc<Window> {
        let depth = depth.max(1);
        let slots = (0..depth).map(|_| Slot { seq: 0, state: SlotState::Free }).collect();
        Arc::new(Window {
            state: Mutex::new(WindowState {
                slots,
                free: (0..depth).rev().collect(),
                inflight: 0,
            }),
            completed: Condvar::new(),
            vacated: Condvar::new(),
        })
    }

    /// Reserve one slot, blocking while the window is at full depth —
    /// this is the client-side flow control of the pipelined plane.
    fn reserve(this: &Arc<Window>) -> (Ticket, CompletionSlot) {
        let mut st = this.state.lock().unwrap();
        loop {
            if let Some(idx) = st.free.pop() {
                let slot = &mut st.slots[idx];
                slot.seq += 1;
                slot.state = SlotState::Pending { abandoned: false };
                let seq = slot.seq;
                st.inflight += 1;
                let ticket =
                    Ticket { window: Arc::clone(this), idx, seq, claimed: false };
                let done =
                    CompletionSlot { window: Arc::clone(this), idx, seq, fired: false };
                return (ticket, done);
            }
            st = this.vacated.wait(st).unwrap();
        }
    }
}

/// A standalone one-op window: the blocking `Handle` API is exactly
/// this — a window-of-1 pipeline.
pub(crate) fn one_shot() -> (Ticket, CompletionSlot) {
    Window::reserve(&Window::with_depth(1))
}

/// Client-side claim on one in-flight operation's result.
///
/// Obtained from [`Pipeline::submit`]. Poll with [`Ticket::is_done`] /
/// [`Ticket::try_wait`], or block with [`Ticket::wait`]. Dropping a
/// ticket abandons the op (the slot recycles once the worker
/// completes); the op itself still executes.
pub struct Ticket {
    window: Arc<Window>,
    idx: usize,
    seq: u64,
    claimed: bool,
}

impl Ticket {
    /// `true` once the worker has published this op's result (a
    /// subsequent [`Ticket::wait`] will not block).
    pub fn is_done(&self) -> bool {
        let st = self.window.state.lock().unwrap();
        let slot = &st.slots[self.idx];
        slot.seq == self.seq && matches!(slot.state, SlotState::Done(_))
    }

    /// Claim the result if it is ready; otherwise hand the ticket back.
    pub fn try_wait(self) -> std::result::Result<Result<OpResult>, Ticket> {
        if self.is_done() {
            Ok(self.wait())
        } else {
            Err(self)
        }
    }

    /// Block until the result is published, claim it, and vacate the
    /// slot. Returns `Err(HiveError::Shutdown)` — never hangs — when
    /// the service shut down or the owning worker died with this op in
    /// flight.
    pub fn wait(mut self) -> Result<OpResult> {
        let mut st = self.window.state.lock().unwrap();
        loop {
            if st.slots[self.idx].seq != self.seq {
                // Slot recycled out from under us — only reachable via
                // API misuse, but fail closed rather than claim a
                // stranger's result.
                self.claimed = true;
                return Err(HiveError::Shutdown);
            }
            let taken = std::mem::replace(&mut st.slots[self.idx].state, SlotState::Free);
            match taken {
                SlotState::Done(res) => {
                    st.free.push(self.idx);
                    st.inflight -= 1;
                    self.claimed = true;
                    drop(st);
                    self.window.vacated.notify_one();
                    return res;
                }
                other => st.slots[self.idx].state = other,
            }
            st = self.window.completed.wait(st).unwrap();
        }
    }

    /// Like [`Ticket::wait`], but give up at `deadline`: the ticket is
    /// handed back unclaimed (the op stays in flight; wait again or
    /// drop it). This is the bounded wait the network front door's
    /// drain path runs under — every shutdown-era wait must carry a
    /// deadline so no server thread can hang on a slow completion.
    pub fn wait_deadline(
        mut self,
        deadline: Instant,
    ) -> std::result::Result<Result<OpResult>, Ticket> {
        let mut st = self.window.state.lock().unwrap();
        loop {
            if st.slots[self.idx].seq != self.seq {
                self.claimed = true;
                return Ok(Err(HiveError::Shutdown));
            }
            let taken = std::mem::replace(&mut st.slots[self.idx].state, SlotState::Free);
            match taken {
                SlotState::Done(res) => {
                    st.free.push(self.idx);
                    st.inflight -= 1;
                    self.claimed = true;
                    drop(st);
                    self.window.vacated.notify_one();
                    return Ok(res);
                }
                other => st.slots[self.idx].state = other,
            }
            let now = Instant::now();
            if now >= deadline {
                drop(st);
                return Err(self);
            }
            let (guard, _timed_out) =
                self.window.completed.wait_timeout(st, deadline - now).unwrap();
            st = guard;
        }
    }
}

impl Drop for Ticket {
    fn drop(&mut self) {
        if self.claimed {
            return;
        }
        let mut st = self.window.state.lock().unwrap();
        if st.slots[self.idx].seq != self.seq {
            return;
        }
        let taken = std::mem::replace(&mut st.slots[self.idx].state, SlotState::Free);
        match taken {
            SlotState::Pending { .. } => {
                st.slots[self.idx].state = SlotState::Pending { abandoned: true };
            }
            SlotState::Done(_) => {
                st.free.push(self.idx);
                st.inflight -= 1;
                drop(st);
                self.window.vacated.notify_one();
            }
            SlotState::Free => {}
        }
    }
}

/// Worker-side obligation to publish one op's result.
///
/// Exactly-once: either the worker calls [`CompletionSlot::complete`]
/// (or the batched [`publish_batch`]), or the `Drop` impl publishes
/// `Err(HiveError::Shutdown)` — which is how callers blocked on tickets
/// are released when a request is dropped in a dying ring, a worker's
/// pending window is discarded, or a worker thread panics mid-dispatch.
pub(crate) struct CompletionSlot {
    window: Arc<Window>,
    idx: usize,
    seq: u64,
    fired: bool,
}

impl CompletionSlot {
    /// Publish and wake the window's waiters immediately.
    #[cfg(test)]
    pub(crate) fn complete(mut self, result: Result<OpResult>) {
        self.publish(result);
        self.window.completed.notify_all();
    }

    /// Publish without waking waiters; callers batch one notify per
    /// window via [`publish_batch`].
    fn publish(&mut self, result: Result<OpResult>) {
        if self.fired {
            return;
        }
        self.fired = true;
        let mut st = self.window.state.lock().unwrap();
        if st.slots[self.idx].seq != self.seq {
            return;
        }
        let taken = std::mem::replace(&mut st.slots[self.idx].state, SlotState::Free);
        match taken {
            SlotState::Pending { abandoned: false } => {
                st.slots[self.idx].state = SlotState::Done(result);
            }
            SlotState::Pending { abandoned: true } => {
                st.free.push(self.idx);
                st.inflight -= 1;
                drop(st);
                self.window.vacated.notify_one();
            }
            other => st.slots[self.idx].state = other,
        }
    }
}

impl Drop for CompletionSlot {
    fn drop(&mut self) {
        if self.fired {
            return;
        }
        self.publish(Err(HiveError::Shutdown));
        self.window.completed.notify_all();
    }
}

/// Publish a whole dispatch window's results with one wakeup per
/// distinct client window — the batched reply path that replaces one
/// channel wakeup per op.
pub(crate) fn publish_batch(entries: Vec<(CompletionSlot, Result<OpResult>)>) {
    // Dedup by window identity in O(n): blocking-API waiters each own a
    // one-shot window, so a dispatch full of singles has as many
    // windows as ops. The clone held in `windows` keeps every inserted
    // pointer alive, so addresses cannot be recycled mid-loop.
    let mut windows: Vec<Arc<Window>> = Vec::new();
    let mut seen: HashSet<usize> = HashSet::new();
    for (mut slot, result) in entries {
        slot.publish(result);
        if seen.insert(Arc::as_ptr(&slot.window) as usize) {
            windows.push(Arc::clone(&slot.window));
        }
    }
    for w in windows {
        w.completed.notify_all();
    }
}

// ---------------------------------------------------------------------------
// Pipeline: the client-facing windowed submission API.
// ---------------------------------------------------------------------------

/// A pipelined client session: up to `depth` single-key ops in flight
/// at once through one [`Handle`], completing out of band via
/// [`Ticket`]s.
///
/// ```no_run
/// # use hivehash::coordinator::{start_native, CoordinatorConfig};
/// # use hivehash::HiveConfig;
/// # let (coord, h) = start_native(CoordinatorConfig::default(), HiveConfig::default()).unwrap();
/// let pipe = h.pipeline(256);
/// let mut tickets = std::collections::VecDeque::new();
/// for k in 1..=10_000u32 {
///     if tickets.len() == 256 {
///         tickets.pop_front().unwrap().wait().unwrap();
///     }
///     tickets.push_back(pipe.insert(k, k * 2).unwrap());
/// }
/// for t in tickets {
///     t.wait().unwrap();
/// }
/// ```
///
/// Submission blocks once `depth` tickets are outstanding and resumes
/// as the caller retires them (wait / try_wait / drop), so a pipeline
/// can never queue unboundedly ahead of its consumer. Ops in flight
/// together are concurrent — wait a ticket before submitting a
/// dependent op.
pub struct Pipeline {
    handle: Handle,
    window: Arc<Window>,
    depth: usize,
}

impl Pipeline {
    pub(crate) fn new(handle: Handle, depth: usize) -> Pipeline {
        let depth = depth.max(1);
        Pipeline { handle, window: Window::with_depth(depth), depth }
    }

    /// The bounded in-flight window size.
    pub fn depth(&self) -> usize {
        self.depth
    }

    /// Ops currently in flight (submitted, ticket not yet retired).
    pub fn in_flight(&self) -> usize {
        self.window.state.lock().unwrap().inflight
    }

    /// Submit one op, blocking while `depth` tickets are outstanding.
    /// The returned ticket completes when the op's dispatch window
    /// executes on its shard.
    pub fn submit(&self, op: Op) -> Result<Ticket> {
        let (ticket, done) = Window::reserve(&self.window);
        self.handle.send_single(op, done)?;
        Ok(ticket)
    }

    /// Pipelined insert/replace; resolve via the ticket
    /// ([`OpResult::Upserted`]).
    pub fn insert(&self, key: u32, value: u32) -> Result<Ticket> {
        self.submit(Op::Insert { key, value })
    }

    /// Pipelined point lookup; resolve via the ticket.
    pub fn lookup(&self, key: u32) -> Result<Ticket> {
        self.submit(Op::Lookup { key })
    }

    /// Pipelined delete; resolve via the ticket.
    pub fn delete(&self, key: u32) -> Result<Ticket> {
        self.submit(Op::Delete { key })
    }

    /// Pipelined upsert; the ticket's [`OpResult::Upserted`] carries the
    /// previous value.
    pub fn upsert(&self, key: u32, value: u32) -> Result<Ticket> {
        self.submit(Op::Upsert { key, value })
    }

    /// Pipelined insert-if-absent; resolves to
    /// [`OpResult::InsertedIfAbsent`].
    pub fn insert_if_absent(&self, key: u32, value: u32) -> Result<Ticket> {
        self.submit(Op::InsertIfAbsent { key, value })
    }

    /// Pipelined write-if-present; resolves to [`OpResult::Updated`].
    pub fn update(&self, key: u32, value: u32) -> Result<Ticket> {
        self.submit(Op::Update { key, value })
    }

    /// Pipelined compare-and-swap; resolves to [`OpResult::Cas`].
    pub fn cas(&self, key: u32, expected: u32, new: u32) -> Result<Ticket> {
        self.submit(Op::Cas { key, expected, new })
    }

    /// Pipelined fetch-add; resolves to [`OpResult::FetchAdded`].
    pub fn fetch_add(&self, key: u32, delta: u32) -> Result<Ticket> {
        self.submit(Op::FetchAdd { key, delta })
    }
}

// ---------------------------------------------------------------------------
// Bounded MPSC submission ring.
// ---------------------------------------------------------------------------

struct RingState<T> {
    buf: VecDeque<T>,
    cap: usize,
    senders: usize,
    rx_alive: bool,
}

struct RingShared<T> {
    q: Mutex<RingState<T>>,
    not_empty: Condvar,
    not_full: Condvar,
}

/// Create a bounded MPSC submission ring of capacity `cap`.
pub(crate) fn ring<T>(cap: usize) -> (RingTx<T>, RingRx<T>) {
    let shared = Arc::new(RingShared {
        q: Mutex::new(RingState {
            buf: VecDeque::with_capacity(cap),
            cap: cap.max(1),
            senders: 1,
            rx_alive: true,
        }),
        not_empty: Condvar::new(),
        not_full: Condvar::new(),
    });
    (RingTx { shared: Arc::clone(&shared) }, RingRx { shared })
}

/// Producer half: clients and the coordinator push requests; `send`
/// blocks while the ring is full (backpressure toward the clients).
pub(crate) struct RingTx<T> {
    shared: Arc<RingShared<T>>,
}

impl<T> Clone for RingTx<T> {
    fn clone(&self) -> Self {
        self.shared.q.lock().unwrap().senders += 1;
        RingTx { shared: Arc::clone(&self.shared) }
    }
}

impl<T> Drop for RingTx<T> {
    fn drop(&mut self) {
        let mut q = self.shared.q.lock().unwrap();
        q.senders -= 1;
        let last = q.senders == 0;
        drop(q);
        if last {
            // wake the worker so it can observe disconnection
            self.shared.not_empty.notify_all();
        }
    }
}

/// Outcome of a non-blocking ring push ([`RingTx::try_send`]). The
/// cross-shard forwarding path must never block: two workers
/// blocking-sending into each other's full rings would deadlock the
/// plane, so full rings hand the value back for a later retry.
pub(crate) enum TrySend<T> {
    Sent,
    /// Ring full; retry later with the returned value.
    Full(T),
    /// Receiving worker gone; fail the returned request instead.
    Disconnected(T),
}

impl<T> RingTx<T> {
    /// Push one request, blocking while the ring is full. Returns the
    /// request back when the receiving worker is gone — dropping it
    /// then fires any completion slot it carries with `Shutdown`.
    pub(crate) fn send(&self, value: T) -> std::result::Result<(), T> {
        let mut q = self.shared.q.lock().unwrap();
        loop {
            if !q.rx_alive {
                return Err(value);
            }
            if q.buf.len() < q.cap {
                q.buf.push_back(value);
                drop(q);
                self.shared.not_empty.notify_one();
                return Ok(());
            }
            q = self.shared.not_full.wait(q).unwrap();
        }
    }

    /// Non-blocking push — the workers' cross-shard forwarding path.
    pub(crate) fn try_send(&self, value: T) -> TrySend<T> {
        let mut q = self.shared.q.lock().unwrap();
        if !q.rx_alive {
            return TrySend::Disconnected(value);
        }
        if q.buf.len() < q.cap {
            q.buf.push_back(value);
            drop(q);
            self.shared.not_empty.notify_one();
            return TrySend::Sent;
        }
        TrySend::Full(value)
    }
}

/// Consumer half, owned by exactly one worker thread. Dropping it
/// (worker exit *or panic*) drains queued requests — firing their
/// completion slots with `Shutdown` — and releases blocked senders.
pub(crate) struct RingRx<T> {
    shared: Arc<RingShared<T>>,
}

impl<T> RingRx<T> {
    /// Non-blocking pop — the worker's drain-into-the-batcher path.
    pub(crate) fn try_recv(&self) -> Option<T> {
        let mut q = self.shared.q.lock().unwrap();
        let v = q.buf.pop_front();
        if v.is_some() {
            drop(q);
            self.shared.not_full.notify_one();
        }
        v
    }

    /// Blocking pop with a deadline (the batcher's dispatch deadline).
    pub(crate) fn recv_timeout(&self, dur: Duration) -> std::result::Result<T, RecvTimeoutError> {
        let deadline = Instant::now() + dur;
        let mut q = self.shared.q.lock().unwrap();
        loop {
            if let Some(v) = q.buf.pop_front() {
                drop(q);
                self.shared.not_full.notify_one();
                return Ok(v);
            }
            if q.senders == 0 {
                return Err(RecvTimeoutError::Disconnected);
            }
            let now = Instant::now();
            if now >= deadline {
                return Err(RecvTimeoutError::Timeout);
            }
            let (guard, _timed_out) =
                self.shared.not_empty.wait_timeout(q, deadline - now).unwrap();
            q = guard;
        }
    }

    /// Requests queued right now (the worker samples this into the
    /// in-flight-depth stat at each dispatch).
    pub(crate) fn backlog(&self) -> usize {
        self.shared.q.lock().unwrap().buf.len()
    }
}

impl<T> Drop for RingRx<T> {
    fn drop(&mut self) {
        let mut q = self.shared.q.lock().unwrap();
        q.rx_alive = false;
        // Dropping queued requests fires their completion slots /
        // reply channels with Shutdown — nobody blocks on a dead ring.
        q.buf.clear();
        drop(q);
        self.shared.not_full.notify_all();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn one_shot_completes_and_unblocks_wait() {
        let (ticket, done) = one_shot();
        assert!(!ticket.is_done());
        let t = std::thread::spawn(move || done.complete(Ok(OpResult::Value(Some(7)))));
        assert_eq!(ticket.wait().unwrap(), OpResult::Value(Some(7)));
        t.join().unwrap();
    }

    #[test]
    fn dropping_completion_slot_fires_shutdown() {
        let (ticket, done) = one_shot();
        drop(done); // worker died with the op in flight
        assert_eq!(ticket.wait(), Err(HiveError::Shutdown));
    }

    #[test]
    fn try_wait_returns_ticket_until_done() {
        let (ticket, done) = one_shot();
        let ticket = match ticket.try_wait() {
            Err(t) => t,
            Ok(_) => panic!("result claimed before completion"),
        };
        done.complete(Ok(OpResult::Deleted(true)));
        assert!(ticket.is_done());
        match ticket.try_wait() {
            Ok(res) => assert_eq!(res.unwrap(), OpResult::Deleted(true)),
            Err(_) => panic!("done ticket not claimable"),
        }
    }

    #[test]
    fn wait_deadline_hands_the_ticket_back_then_claims() {
        let (ticket, done) = one_shot();
        let ticket = match ticket.wait_deadline(Instant::now() + Duration::from_millis(10)) {
            Err(t) => t,
            Ok(_) => panic!("deadline wait claimed an unpublished result"),
        };
        let t = std::thread::spawn(move || done.complete(Ok(OpResult::Value(Some(3)))));
        let res = ticket
            .wait_deadline(Instant::now() + Duration::from_secs(5))
            .expect("published result must be claimable before the deadline");
        assert_eq!(res.unwrap(), OpResult::Value(Some(3)));
        t.join().unwrap();
    }

    #[test]
    fn window_recycles_slots_at_bounded_depth() {
        let window = Window::with_depth(2);
        let (t1, d1) = Window::reserve(&window);
        let (t2, d2) = Window::reserve(&window);
        assert_eq!(window.state.lock().unwrap().inflight, 2);
        // a third reservation must block until a slot vacates
        let w2 = Arc::clone(&window);
        let reserver = std::thread::spawn(move || {
            let (t3, d3) = Window::reserve(&w2);
            d3.complete(Ok(OpResult::Deleted(true)));
            t3.wait().unwrap()
        });
        std::thread::sleep(Duration::from_millis(20));
        assert!(!reserver.is_finished(), "reserve must block at full depth");
        d1.complete(Ok(OpResult::Deleted(true)));
        t1.wait().unwrap(); // vacates a slot → reserver proceeds
        assert_eq!(reserver.join().unwrap(), OpResult::Deleted(true));
        d2.complete(Ok(OpResult::Deleted(true)));
        t2.wait().unwrap();
        assert_eq!(window.state.lock().unwrap().inflight, 0);
    }

    #[test]
    fn abandoned_ticket_recycles_on_completion() {
        let window = Window::with_depth(1);
        let (t1, d1) = Window::reserve(&window);
        drop(t1); // caller walked away
        d1.complete(Ok(OpResult::Value(None))); // completion frees the slot
        let (t2, d2) = Window::reserve(&window); // would deadlock if the slot leaked
        d2.complete(Ok(OpResult::Value(Some(1))));
        assert_eq!(t2.wait().unwrap(), OpResult::Value(Some(1)));
    }

    #[test]
    fn publish_batch_wakes_every_window_once() {
        let wa = Window::with_depth(4);
        let wb = Window::with_depth(4);
        let (ta1, da1) = Window::reserve(&wa);
        let (ta2, da2) = Window::reserve(&wa);
        let (tb1, db1) = Window::reserve(&wb);
        publish_batch(vec![
            (da1, Ok(OpResult::Value(Some(1)))),
            (da2, Ok(OpResult::Value(Some(2)))),
            (db1, Ok(OpResult::Value(Some(3)))),
        ]);
        assert_eq!(ta1.wait().unwrap(), OpResult::Value(Some(1)));
        assert_eq!(ta2.wait().unwrap(), OpResult::Value(Some(2)));
        assert_eq!(tb1.wait().unwrap(), OpResult::Value(Some(3)));
    }

    #[test]
    fn ring_is_fifo_and_bounded() {
        let (tx, rx) = ring::<u32>(2);
        tx.send(1).unwrap();
        tx.send(2).unwrap();
        // third send blocks until the worker pops
        let tx2 = tx.clone();
        let sender = std::thread::spawn(move || tx2.send(3).is_ok());
        std::thread::sleep(Duration::from_millis(20));
        assert!(!sender.is_finished(), "send must block on a full ring");
        assert_eq!(rx.try_recv(), Some(1));
        assert!(sender.join().unwrap());
        assert_eq!(rx.recv_timeout(Duration::from_secs(1)).unwrap(), 2);
        assert_eq!(rx.recv_timeout(Duration::from_secs(1)).unwrap(), 3);
        assert_eq!(rx.backlog(), 0);
        assert!(rx.try_recv().is_none());
    }

    #[test]
    fn try_send_never_blocks_and_reports_state() {
        let (tx, rx) = ring::<u32>(1);
        assert!(matches!(tx.try_send(1), TrySend::Sent));
        match tx.try_send(2) {
            TrySend::Full(v) => assert_eq!(v, 2, "full ring hands the value back"),
            _ => panic!("second push into a 1-slot ring must report Full"),
        }
        assert_eq!(rx.try_recv(), Some(1));
        assert!(matches!(tx.try_send(3), TrySend::Sent));
        drop(rx);
        match tx.try_send(4) {
            TrySend::Disconnected(v) => assert_eq!(v, 4),
            _ => panic!("push after rx death must report Disconnected"),
        }
    }

    #[test]
    fn ring_reports_timeout_then_disconnect() {
        let (tx, rx) = ring::<u32>(4);
        match rx.recv_timeout(Duration::from_millis(5)) {
            Err(RecvTimeoutError::Timeout) => {}
            other => panic!("expected timeout, got {other:?}"),
        }
        drop(tx);
        match rx.recv_timeout(Duration::from_millis(5)) {
            Err(RecvTimeoutError::Disconnected) => {}
            other => panic!("expected disconnect, got {other:?}"),
        }
    }

    #[test]
    fn dead_ring_releases_blocked_sender_and_returns_value() {
        let (tx, rx) = ring::<u32>(1);
        tx.send(1).unwrap();
        let tx2 = tx.clone();
        let blocked = std::thread::spawn(move || tx2.send(2));
        std::thread::sleep(Duration::from_millis(20));
        drop(rx); // worker died: queued 1 is dropped, sender released
        assert_eq!(blocked.join().unwrap(), Err(2));
        assert_eq!(tx.send(9), Err(9), "sends after rx death fail fast");
    }
}
