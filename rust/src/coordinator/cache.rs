//! Skew-adaptive hot-key cache for the coordinator's lookup fast path.
//!
//! Each worker owns one [`HotKeyCache`] in front of its backend shard: a
//! fixed-size, set-associative array of packed 64-bit `(key, value)`
//! words (`core::packed`) with per-set CLOCK eviction. Under a Zipf-
//! skewed stream the hot head of the key distribution pins itself into
//! the cache via the reference bits, and lookup hits skip the backend —
//! no epoch pin, no bucket probe, no candidate hashing.
//!
//! # Coherence
//!
//! The cache is only ever touched by its owning worker thread, which
//! also serializes every mutation of the shard, so coherence reduces to
//! two rules (enforced by `coordinator::service`, not here):
//!
//! 1. **Per-key invalidation** — each insert/delete executed by the
//!    worker retires the cached copy of that key before the window's
//!    results are published.
//! 2. **Wholesale validation** — before serving any hit, the worker
//!    compares the backend's coherence stamp ([`crate::backend::Backend::
//!    coherence_stamp`]; for the native table a fusion of the
//!    reallocation epoch and the stash-drain epoch) against the stamp
//!    the cache last validated under. A moved stamp drops every entry
//!    ([`HotKeyCache::validate`]), so entries cached across a physical
//!    reallocation or a stash drain — the windows where table state
//!    moves outside the worker's own op stream — can never be served.
//! 3. **Wholesale clear on partition move-in** — when this worker
//!    becomes the executor of a partition mid-move (`Handle::reshard`),
//!    keys of that partition briefly live in *another shard's* table,
//!    which the stamp of this worker's own backend cannot vouch for.
//!    The service clears the cache at move activation
//!    ([`HotKeyCache::clear`]) and never caches mid-move results, so a
//!    dual-table read can never be served stale from here.
//!
//! A backend that cannot produce a stamp (`None`) gets no cache at all.

use crate::core::packed::{pack, unpack_key, unpack_value, EMPTY_KEY, EMPTY_WORD};
use crate::core::rng::splitmix64;

/// Associativity: ways scanned per set. Eight packed words = one 64-byte
/// line of entries per set probe.
pub const CACHE_WAYS: usize = 8;

/// Per-worker read-through hot-key cache (see module docs).
#[derive(Debug)]
pub struct HotKeyCache {
    /// `sets × CACHE_WAYS` packed entry words; `EMPTY_WORD` = vacant.
    entries: Vec<u64>,
    /// CLOCK reference bits, parallel to `entries`.
    refbit: Vec<bool>,
    /// Per-set clock hands.
    hands: Vec<u8>,
    set_mask: usize,
    /// Backend coherence stamp the current contents were validated under.
    stamp: u64,
    len: usize,
}

impl HotKeyCache {
    /// Cache holding ~`capacity` entries (rounded so the set count is a
    /// power of two), coherent as of backend `stamp`.
    pub fn new(capacity: usize, stamp: u64) -> Self {
        let sets = (capacity.max(CACHE_WAYS) / CACHE_WAYS).next_power_of_two();
        HotKeyCache {
            entries: vec![EMPTY_WORD; sets * CACHE_WAYS],
            refbit: vec![false; sets * CACHE_WAYS],
            hands: vec![0; sets],
            set_mask: sets - 1,
            stamp,
            len: 0,
        }
    }

    /// First entry index of `key`'s set. The set hash is independent of
    /// the table's bucket family so a pathological bucket collision
    /// cannot also collapse the cache.
    #[inline]
    fn set_base(&self, key: u32) -> usize {
        let mut s = key as u64 ^ 0xA076_1D64_78BD_642F;
        (splitmix64(&mut s) as usize & self.set_mask) * CACHE_WAYS
    }

    /// Cached value of `key`, marking it recently used on a hit. The
    /// `EMPTY_KEY` sentinel is never cached — scanning for it would
    /// match every vacant way — so it always misses.
    pub fn get(&mut self, key: u32) -> Option<u32> {
        if key == EMPTY_KEY {
            return None;
        }
        let base = self.set_base(key);
        for w in 0..CACHE_WAYS {
            let word = self.entries[base + w];
            if unpack_key(word) == key {
                self.refbit[base + w] = true;
                return Some(unpack_value(word));
            }
        }
        None
    }

    /// Cached value of `key` without touching recency state (stats and
    /// test instrumentation; the serving path uses [`get`](Self::get)).
    pub fn peek(&self, key: u32) -> Option<u32> {
        if key == EMPTY_KEY {
            return None;
        }
        let base = self.set_base(key);
        for w in 0..CACHE_WAYS {
            let word = self.entries[base + w];
            if unpack_key(word) == key {
                return Some(unpack_value(word));
            }
        }
        None
    }

    /// Insert or update `key → value` (read-through fill). Evicts the
    /// set's first cold way (CLOCK) when the set is full.
    pub fn put(&mut self, key: u32, value: u32) {
        debug_assert_ne!(key, EMPTY_KEY, "sentinel is not cacheable");
        let base = self.set_base(key);
        let mut vacant = None;
        for w in 0..CACHE_WAYS {
            let word = self.entries[base + w];
            if unpack_key(word) == key {
                self.entries[base + w] = pack(key, value);
                self.refbit[base + w] = true;
                return;
            }
            if word == EMPTY_WORD && vacant.is_none() {
                vacant = Some(w);
            }
        }
        let w = match vacant {
            Some(w) => {
                self.len += 1;
                w
            }
            None => self.evict(base),
        };
        self.entries[base + w] = pack(key, value);
        self.refbit[base + w] = true;
    }

    /// CLOCK sweep within one set: clear reference bits from the hand
    /// until a cold way turns up (bounded by two revolutions).
    fn evict(&mut self, base: usize) -> usize {
        let set = base / CACHE_WAYS;
        loop {
            let w = self.hands[set] as usize;
            self.hands[set] = ((w + 1) % CACHE_WAYS) as u8;
            if self.refbit[base + w] {
                self.refbit[base + w] = false;
            } else {
                return w;
            }
        }
    }

    /// Drop `key`'s entry (a write retired it). Returns whether a copy
    /// was present. The `EMPTY_KEY` sentinel matches vacant ways, so it
    /// is rejected up front (it can never have been cached).
    pub fn invalidate(&mut self, key: u32) -> bool {
        if key == EMPTY_KEY {
            return false;
        }
        let base = self.set_base(key);
        for w in 0..CACHE_WAYS {
            if unpack_key(self.entries[base + w]) == key {
                self.entries[base + w] = EMPTY_WORD;
                self.refbit[base + w] = false;
                self.len -= 1;
                return true;
            }
        }
        false
    }

    /// Drop everything (wholesale invalidation).
    pub fn clear(&mut self) {
        self.entries.fill(EMPTY_WORD);
        self.refbit.fill(false);
        self.hands.fill(0);
        self.len = 0;
    }

    /// Wholesale validation against the backend's current coherence
    /// stamp: `true` means the contents remain servable; `false` means
    /// the stamp moved (reallocation or stash drain since the last
    /// window) and every entry was dropped.
    pub fn validate(&mut self, stamp: u64) -> bool {
        if stamp == self.stamp {
            return true;
        }
        self.stamp = stamp;
        self.clear();
        false
    }

    /// Live cached entries.
    pub fn len(&self) -> usize {
        self.len
    }

    /// `true` when nothing is cached.
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Total entry slots.
    pub fn capacity(&self) -> usize {
        self.entries.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn get_put_invalidate_roundtrip() {
        let mut c = HotKeyCache::new(1024, 0);
        assert_eq!(c.get(1), None);
        c.put(1, 100);
        c.put(2, 200);
        assert_eq!(c.get(1), Some(100));
        assert_eq!(c.get(2), Some(200));
        assert_eq!(c.len(), 2);
        // update in place
        c.put(1, 101);
        assert_eq!(c.get(1), Some(101));
        assert_eq!(c.len(), 2);
        assert!(c.invalidate(1));
        assert!(!c.invalidate(1));
        assert_eq!(c.get(1), None);
        assert_eq!(c.len(), 1);
    }

    #[test]
    fn validate_drops_on_stamp_move_only() {
        let mut c = HotKeyCache::new(64, 7);
        c.put(10, 1);
        assert!(c.validate(7), "same stamp must keep contents");
        assert_eq!(c.get(10), Some(1));
        assert!(!c.validate(8), "moved stamp must flush");
        assert_eq!(c.get(10), None);
        assert!(c.is_empty());
        assert!(c.validate(8), "stamp now current");
    }

    #[test]
    fn clock_evicts_cold_before_recent() {
        // capacity = one set of CACHE_WAYS ways: every key collides
        let mut c = HotKeyCache::new(CACHE_WAYS, 0);
        let keys: Vec<u32> = (1..=CACHE_WAYS as u32).collect();
        for &k in &keys {
            c.put(k, k * 10);
        }
        assert_eq!(c.len(), CACHE_WAYS);
        // the set is full; a new key sweeps the clock (clearing all the
        // insertion reference bits) and evicts the way at the hand
        c.put(100, 1000);
        assert_eq!(c.len(), CACHE_WAYS);
        assert_eq!(c.peek(100), Some(1000));
        // peek, not get: counting survivors must not set reference bits
        let survivors = keys.iter().filter(|&&k| c.peek(k).is_some()).count();
        assert_eq!(survivors, CACHE_WAYS - 1, "exactly one way evicted");
        // touch one survivor so its reference bit shields it through the
        // next sweep, then force another eviction
        let touched = keys.iter().copied().find(|&k| c.peek(k).is_some()).unwrap();
        assert_eq!(c.get(touched), Some(touched * 10));
        c.put(200, 2000);
        assert_eq!(c.peek(touched), Some(touched * 10), "recently-used way evicted");
        assert_eq!(c.peek(200), Some(2000));
        assert_eq!(c.len(), CACHE_WAYS);
    }

    #[test]
    fn sentinel_key_never_hits_or_underflows() {
        // EMPTY_KEY's low half equals a vacant word's key field: lookups
        // of the sentinel must not fabricate a hit from an empty way, and
        // invalidating it must not decrement len below zero.
        let mut c = HotKeyCache::new(64, 0);
        assert_eq!(c.get(EMPTY_KEY), None, "vacant way served as a sentinel hit");
        assert_eq!(c.peek(EMPTY_KEY), None);
        assert!(!c.invalidate(EMPTY_KEY), "sentinel invalidated a vacant way");
        assert_eq!(c.len(), 0);
        c.put(3, 30);
        assert!(!c.invalidate(EMPTY_KEY));
        assert_eq!(c.len(), 1);
        assert_eq!(c.get(3), Some(30));
    }

    #[test]
    fn capacity_rounds_to_power_of_two_sets() {
        let c = HotKeyCache::new(100, 0);
        assert_eq!(c.capacity(), 16 * CACHE_WAYS); // 100/8 = 12 → 16 sets
        let c = HotKeyCache::new(0, 0);
        assert_eq!(c.capacity(), CACHE_WAYS); // floor: one set
    }
}
