//! Dynamic batcher: size + deadline dispatch windows.
//!
//! Single-op requests accumulate until either `max_batch` ops are pending
//! or `deadline` has elapsed since the first op of the window — the
//! classic dynamic-batching policy of GPU serving systems (the analogue of
//! the paper's "batch of concurrent operations" kernel launches).

use crate::workload::Op;
use std::time::{Duration, Instant};

/// Batching policy knobs.
#[derive(Debug, Clone, Copy)]
pub struct BatchPolicy {
    /// Dispatch when this many ops are pending.
    pub max_batch: usize,
    /// ... or when the oldest pending op is this old.
    pub deadline: Duration,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy { max_batch: 4096, deadline: Duration::from_micros(200) }
    }
}

/// Accumulates ops into dispatch windows.
#[derive(Debug)]
pub struct Batcher {
    policy: BatchPolicy,
    pending: Vec<Op>,
    window_open: Option<Instant>,
}

impl Batcher {
    /// Empty batcher with `policy`.
    pub fn new(policy: BatchPolicy) -> Self {
        Batcher { policy, pending: Vec::with_capacity(policy.max_batch), window_open: None }
    }

    /// Add one op. Returns `true` if the window is now full (dispatch!).
    pub fn push(&mut self, op: Op) -> bool {
        self.push_at(op, Instant::now())
    }

    /// Add one op that was *enqueued* at `enqueued` (possibly before the
    /// worker picked it up). Returns `true` if the window is now full.
    ///
    /// The pipelined plane queues requests in a submission ring before
    /// the worker drains them, so a window's deadline runs from the
    /// first op's submission time — ring backlog counts against the
    /// dispatch deadline instead of silently extending it.
    pub fn push_at(&mut self, op: Op, enqueued: Instant) -> bool {
        if self.pending.is_empty() {
            self.window_open = Some(enqueued);
        }
        self.pending.push(op);
        self.pending.len() >= self.policy.max_batch
    }

    /// Number of pending ops.
    pub fn len(&self) -> usize {
        self.pending.len()
    }

    /// `true` if no ops are pending.
    pub fn is_empty(&self) -> bool {
        self.pending.is_empty()
    }

    /// `true` if the deadline expired for a non-empty window.
    pub fn deadline_expired(&self) -> bool {
        match self.window_open {
            Some(t) => !self.pending.is_empty() && t.elapsed() >= self.policy.deadline,
            None => false,
        }
    }

    /// Time left until the current window's deadline (for recv timeouts).
    pub fn time_to_deadline(&self) -> Option<Duration> {
        self.window_open.map(|t| self.policy.deadline.saturating_sub(t.elapsed()))
    }

    /// Take the current window, resetting the batcher.
    pub fn take(&mut self) -> Vec<Op> {
        self.window_open = None;
        std::mem::take(&mut self.pending)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dispatches_on_size() {
        let mut b = Batcher::new(BatchPolicy { max_batch: 3, deadline: Duration::from_secs(10) });
        assert!(!b.push(Op::Lookup { key: 1 }));
        assert!(!b.push(Op::Lookup { key: 2 }));
        assert!(b.push(Op::Lookup { key: 3 }), "third op fills the window");
        assert_eq!(b.take().len(), 3);
        assert!(b.is_empty());
    }

    #[test]
    fn dispatches_on_deadline() {
        let mut b = Batcher::new(BatchPolicy {
            max_batch: 1000,
            deadline: Duration::from_millis(5),
        });
        b.push(Op::Lookup { key: 1 });
        assert!(!b.deadline_expired());
        std::thread::sleep(Duration::from_millis(8));
        assert!(b.deadline_expired());
        assert_eq!(b.take().len(), 1);
        assert!(!b.deadline_expired(), "empty batcher has no deadline");
    }

    #[test]
    fn push_at_backdates_the_window_deadline() {
        let mut b = Batcher::new(BatchPolicy {
            max_batch: 1000,
            deadline: Duration::from_millis(5),
        });
        // an op that already sat in the submission ring past the
        // deadline makes the window immediately dispatchable
        b.push_at(Op::Lookup { key: 1 }, Instant::now() - Duration::from_millis(8));
        assert!(b.deadline_expired(), "ring backlog must count against the deadline");
        assert_eq!(b.time_to_deadline(), Some(Duration::ZERO));
        assert_eq!(b.take().len(), 1);
    }

    #[test]
    fn window_opens_on_first_op() {
        let mut b = Batcher::new(BatchPolicy::default());
        assert!(b.time_to_deadline().is_none());
        b.push(Op::Insert { key: 1, value: 1 });
        assert!(b.time_to_deadline().is_some());
    }
}
