//! The coordinator service: sharded worker pool, request router, and
//! the per-worker dispatch loop (batcher + backend + resize controller).
//!
//! Requests enter through the pipelined plane (`coordinator::pipeline`):
//! every worker owns a bounded MPSC submission ring which it drains
//! directly into its batcher, and single-op requests complete through
//! ticket/completion slots — one condvar publish per dispatch window
//! instead of one channel wakeup per op. The blocking `Handle` API is a
//! window-of-1 pipeline over the same plane.
//!
//! Workers are **shards**: each owns an independent backend (native: its
//! own `HiveTable` with its own epoch domain, stash, coherence stamp and
//! striped counters), so no cross-shard op ever shares a cache line.
//! Keys hash into the shard directory (`coordinator::shard`) — one
//! seqlock-validated shared load maps a key's partition to its owning
//! shard — and [`Handle::reshard`] moves a partition between shards
//! **online**: the destination worker fences the source, serves the
//! partition's traffic dual-table while it copies the keys over, then
//! settles the directory entry. Misrouted requests (a client raced a
//! directory flip) are forwarded worker-to-worker, never executed on the
//! wrong shard, so routing races cost a hop instead of correctness.
//!
//! Replies are typed end-to-end: every request — blocking single,
//! pipelined ticket, or bulk shard — resolves to the [`OpResult`] its
//! [`Op`] produced, in submission order. The old reply enum collapsed
//! insert outcomes to a `bool` and segregated results by type; the typed
//! plane carries previous values, CAS verdicts and the full four-step
//! [`InsertOutcome`] attribution all the way to the client (and into
//! [`ServiceStats`]).

use crate::backend::Backend;
use crate::coordinator::batcher::{BatchPolicy, Batcher};
use crate::coordinator::cache::HotKeyCache;
use crate::coordinator::pipeline::{self, CompletionSlot, Pipeline, RingRx, RingTx, TrySend};
use crate::coordinator::shard::{Ownership, Placement, ShardDirectory, ShardPlan, ShardPlane};
use crate::coordinator::stats::ServiceStats;
use crate::core::error::{HiveError, Result};
use crate::native::resize::ResizeEvent;
use crate::native::table::{HiveTable, InsertOutcome};
use crate::workload::{Op, OpResult};
use std::collections::{HashMap, VecDeque};
use std::sync::mpsc::{sync_channel, Receiver, RecvTimeoutError, Sender, SyncSender, TryRecvError};
use std::sync::{mpsc, Arc};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Coordinator configuration.
#[derive(Debug, Clone)]
pub struct CoordinatorConfig {
    /// Worker (shard) count.
    pub workers: usize,
    /// Dynamic batching policy per worker.
    pub batch: BatchPolicy,
    /// Run the resize controller every N dispatch windows.
    pub resize_check_every: u64,
    /// Per-worker hot-key cache entries (`0` disables the cache). Only
    /// backends that produce a coherence stamp get a cache; the rest
    /// execute every lookup. Cached results are observationally
    /// identical to uncached ones — lookups whose key is written in the
    /// same window bypass the cache, so every window linearizes exactly
    /// as the backend's grouped execution does.
    pub cache_capacity: usize,
    /// Per-worker submission ring capacity: the maximum number of
    /// requests queued ahead of a worker before senders block
    /// (backpressure toward the clients). Bounds memory and queue delay
    /// under overload.
    pub ring_capacity: usize,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        CoordinatorConfig {
            workers: 4,
            batch: BatchPolicy::default(),
            resize_check_every: 8,
            cache_capacity: 4096,
            ring_capacity: 4096,
        }
    }
}

/// A bulk sub-reply: the submission positions it resolves, and their
/// results (in the same order). Workers may split one sub-batch into
/// several replies while a partition move is in flight, so positions —
/// not worker indices — are what the gather keys on.
type BulkReply = (Vec<u32>, Result<Vec<OpResult>>);

enum Request {
    /// One single-key op; completes through its ticket's slot (with the
    /// op's typed [`OpResult`]) when the dispatch window it joins
    /// executes.
    Single { op: Op, enqueued: Instant, done: CompletionSlot },
    /// One pre-sharded bulk window; `positions[i]` is the submission
    /// index of `ops[i]`, carried along so forwarded or split sub-windows
    /// still land their results in the right slots.
    Bulk { ops: Vec<Op>, positions: Vec<u32>, enqueued: Instant, reply: Sender<BulkReply> },
    Stats { reply: SyncSender<ServiceStats> },
    Flush { reply: SyncSender<()> },
    /// Move one routing partition onto the receiving worker's shard,
    /// online. Queued behind any move already in flight there.
    Reshard { partition: u32, reply: Sender<Result<()>> },
    Shutdown,
}

/// The running service. Dropping it (or calling [`Coordinator::shutdown`])
/// joins all workers.
pub struct Coordinator {
    senders: Vec<RingTx<Request>>,
    handles: Vec<JoinHandle<()>>,
}

/// Clone-able client handle.
#[derive(Clone)]
pub struct Handle {
    senders: Arc<Vec<RingTx<Request>>>,
    plane: Arc<ShardPlane>,
}

impl Coordinator {
    /// Start the service: `factory(worker_index)` builds each worker's
    /// backend (one table shard per worker). The factory runs *inside*
    /// each worker thread — required because the XLA backend's PJRT
    /// client is not `Send`.
    ///
    /// Factory-built coordinators predate the shard plane: no tables are
    /// registered (the backends may not even be tables), so the
    /// directory stays static, no placement pinning runs, and
    /// [`Handle::reshard`] refuses. Behavior is identical to the
    /// pre-shard coordinator, which `tests/test_service.rs` pins down.
    pub fn start<F>(cfg: CoordinatorConfig, factory: F) -> Result<(Coordinator, Handle)>
    where
        F: Fn(usize) -> Result<Box<dyn Backend>> + Send + Sync + 'static,
    {
        let plan = ShardPlan { placement: Placement::None, ..ShardPlan::default() };
        Self::start_with_plan(cfg, plan, factory)
    }

    /// [`Coordinator::start`] with an explicit shard plan (placement
    /// policy + directory granularity). The plane still carries no
    /// tables — online resharding needs [`start_native_sharded`].
    pub fn start_with_plan<F>(
        cfg: CoordinatorConfig,
        plan: ShardPlan,
        factory: F,
    ) -> Result<(Coordinator, Handle)>
    where
        F: Fn(usize) -> Result<Box<dyn Backend>> + Send + Sync + 'static,
    {
        let partitions = plan.partitions_per_shard.max(1) * cfg.workers;
        let plane = Arc::new(ShardPlane {
            directory: ShardDirectory::new(partitions, cfg.workers),
            tables: Vec::new(),
        });
        Self::start_on_plane(cfg, plan, plane, factory)
    }

    /// Shared start path: spawn the workers over an existing shard
    /// plane. All rings are created up front so every worker can hold
    /// the full peer list for forwarding.
    pub(crate) fn start_on_plane<F>(
        cfg: CoordinatorConfig,
        plan: ShardPlan,
        plane: Arc<ShardPlane>,
        factory: F,
    ) -> Result<(Coordinator, Handle)>
    where
        F: Fn(usize) -> Result<Box<dyn Backend>> + Send + Sync + 'static,
    {
        assert!(cfg.workers >= 1);
        let factory = Arc::new(factory);
        let mut txs = Vec::with_capacity(cfg.workers);
        let mut rxs = Vec::with_capacity(cfg.workers);
        for _ in 0..cfg.workers {
            let (tx, rx) = pipeline::ring::<Request>(cfg.ring_capacity.max(1));
            txs.push(tx);
            rxs.push(rx);
        }
        let peers = Arc::new(txs);
        let cpu_sets = plan.placement.assign(cfg.workers);
        let mut handles = Vec::with_capacity(cfg.workers);
        for (w, (rx, cpus)) in rxs.into_iter().zip(cpu_sets).enumerate() {
            let (ready_tx, ready_rx) = sync_channel::<Result<()>>(1);
            let cfg_w = cfg.clone();
            let factory = Arc::clone(&factory);
            let peers_w = Arc::clone(&peers);
            let plane_w = Arc::clone(&plane);
            handles.push(
                std::thread::Builder::new()
                    .name(format!("hive-worker-{w}"))
                    .spawn(move || {
                        // Pin before the factory runs so the backend's
                        // allocations first-touch on the worker's node.
                        if let Some(cpus) = cpus {
                            let _ = crate::coordinator::shard::pin_current_thread(&cpus);
                        }
                        match factory(w) {
                            Ok(backend) => {
                                let _ = ready_tx.send(Ok(()));
                                worker_loop(w, rx, backend, cfg_w, peers_w, plane_w);
                            }
                            Err(e) => {
                                let _ = ready_tx.send(Err(e));
                            }
                        }
                    })
                    .expect("spawn worker"),
            );
            let ready = ready_rx.recv().unwrap_or(Err(HiveError::Shutdown));
            if let Err(e) = ready {
                // Already-running workers hold the peer senders, so their
                // rings never auto-disconnect — shut them down explicitly
                // before reporting the factory failure.
                for tx in peers.iter() {
                    let _ = tx.send(Request::Shutdown);
                }
                for h in handles {
                    let _ = h.join();
                }
                return Err(e);
            }
        }
        let coord = Coordinator { senders: peers.as_ref().clone(), handles };
        Ok((coord, Handle { senders: peers, plane }))
    }

    /// Stop all workers and join them. Requests still queued behind the
    /// shutdown marker (and ops in flight on a dead worker) complete
    /// with [`HiveError::Shutdown`] — blocked callers never hang.
    pub fn shutdown(mut self) {
        for tx in &self.senders {
            let _ = tx.send(Request::Shutdown);
        }
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
        self.senders.clear();
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        for tx in &self.senders {
            let _ = tx.send(Request::Shutdown);
        }
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

impl Handle {
    /// Worker shard for `key`: one seqlock-validated directory load maps
    /// the key's partition to its owner. With a settled default
    /// directory this reproduces the pre-shard murmur-modulo routing bit
    /// for bit; mid-move partitions route to the move destination.
    #[inline]
    fn route(&self, key: u32) -> usize {
        self.plane.directory.route(key)
    }

    /// Shard (worker) count.
    pub fn shards(&self) -> usize {
        self.senders.len()
    }

    /// Routing-partition count of the shard directory.
    pub fn partitions(&self) -> usize {
        self.plane.directory.partitions()
    }

    /// The directory partition `key` hashes into.
    pub fn partition_of(&self, key: u32) -> u32 {
        self.plane.directory.partition_of(key)
    }

    /// The shard currently responsible for `partition` (the destination
    /// while a move is in flight).
    pub fn shard_of(&self, partition: u32) -> usize {
        match self.plane.directory.ownership(partition) {
            Ownership::Settled(s) => s,
            Ownership::Moving { dst, .. } => dst,
        }
    }

    /// Move `partition` onto shard `dst` **online**: ops keep flowing
    /// while the destination worker fences the source, copies the
    /// partition's keys and settles the directory entry. Blocks until
    /// the move fully settles (or fails). Requires a native shard plane
    /// ([`start_native`] / [`start_native_sharded`]); factory-built
    /// coordinators have a static directory and report
    /// [`HiveError::Config`].
    pub fn reshard(&self, partition: u32, dst: usize) -> Result<()> {
        if partition as usize >= self.plane.directory.partitions() {
            return Err(HiveError::Config(format!(
                "partition {partition} out of range (directory has {})",
                self.plane.directory.partitions()
            )));
        }
        if dst >= self.senders.len() {
            return Err(HiveError::Config(format!(
                "destination shard {dst} out of range ({} shards)",
                self.senders.len()
            )));
        }
        let (tx, rx) = mpsc::channel();
        self.senders[dst]
            .send(Request::Reshard { partition, reply: tx })
            .map_err(|_| HiveError::Shutdown)?;
        rx.recv().map_err(|_| HiveError::Shutdown)?
    }

    /// Open a pipelined session over this handle: up to `depth`
    /// single-key ops in flight at once, completing out of band via
    /// [`crate::coordinator::pipeline::Ticket`]s.
    pub fn pipeline(&self, depth: usize) -> Pipeline {
        Pipeline::new(self.clone(), depth)
    }

    /// Route and submit one pipelined single op (the `Pipeline`
    /// submission path).
    pub(crate) fn send_single(&self, op: Op, done: CompletionSlot) -> Result<()> {
        self.senders[self.route(op.key())]
            .send(Request::Single { op, enqueued: Instant::now(), done })
            .map_err(|_| HiveError::Shutdown)
    }

    /// Blocking single op — a window-of-1 pipeline: reserve one
    /// completion slot, submit, wait the ticket for the typed result.
    fn single(&self, op: Op) -> Result<OpResult> {
        let (ticket, done) = pipeline::one_shot();
        self.senders[self.route(op.key())]
            .send(Request::Single { op, enqueued: Instant::now(), done })
            .map_err(|_| HiveError::Shutdown)?;
        ticket.wait()
    }

    fn unexpected(op: &str, got: OpResult) -> HiveError {
        HiveError::Runtime(format!("unexpected reply to {op}: {got:?}"))
    }

    /// Insert or replace `key → value`, reporting which four-step path
    /// placed it (the lossy `bool` of the pre-typed plane is gone).
    pub fn insert(&self, key: u32, value: u32) -> Result<InsertOutcome> {
        match self.single(Op::Insert { key, value })? {
            OpResult::Upserted { outcome, .. } => Ok(outcome),
            other => Err(Self::unexpected("insert", other)),
        }
    }

    /// Insert or replace, returning the placement outcome and previous
    /// value.
    pub fn upsert(&self, key: u32, value: u32) -> Result<(InsertOutcome, Option<u32>)> {
        match self.single(Op::Upsert { key, value })? {
            OpResult::Upserted { outcome, old } => Ok((outcome, old)),
            other => Err(Self::unexpected("upsert", other)),
        }
    }

    /// Insert only if absent; returns the existing value when present
    /// (`None` ⇒ this call inserted).
    pub fn insert_if_absent(&self, key: u32, value: u32) -> Result<Option<u32>> {
        match self.single(Op::InsertIfAbsent { key, value })? {
            OpResult::InsertedIfAbsent { existing, .. } => Ok(existing),
            other => Err(Self::unexpected("insert_if_absent", other)),
        }
    }

    /// Replace only if present; returns the previous value (`None` ⇒
    /// absent, nothing written).
    pub fn update(&self, key: u32, value: u32) -> Result<Option<u32>> {
        match self.single(Op::Update { key, value })? {
            OpResult::Updated { old } => Ok(old),
            other => Err(Self::unexpected("update", other)),
        }
    }

    /// Compare-and-swap: write `new` iff the current value equals
    /// `expected`. Returns `(ok, actual)`.
    pub fn cas(&self, key: u32, expected: u32, new: u32) -> Result<(bool, Option<u32>)> {
        match self.single(Op::Cas { key, expected, new })? {
            OpResult::Cas { ok, actual } => Ok((ok, actual)),
            other => Err(Self::unexpected("cas", other)),
        }
    }

    /// Add `delta` (wrapping) to the value of `key`, creating it at
    /// `delta` when absent. Returns the pre-add value (`None` ⇒ created).
    pub fn fetch_add(&self, key: u32, delta: u32) -> Result<Option<u32>> {
        match self.single(Op::FetchAdd { key, delta })? {
            OpResult::FetchAdded { old, .. } => Ok(old),
            other => Err(Self::unexpected("fetch_add", other)),
        }
    }

    /// Point lookup.
    pub fn lookup(&self, key: u32) -> Result<Option<u32>> {
        match self.single(Op::Lookup { key })? {
            OpResult::Value(v) => Ok(v),
            other => Err(Self::unexpected("lookup", other)),
        }
    }

    /// Delete `key`.
    pub fn delete(&self, key: u32) -> Result<bool> {
        match self.single(Op::Delete { key })? {
            OpResult::Deleted(hit) => Ok(hit),
            other => Err(Self::unexpected("delete", other)),
        }
    }

    /// Bulk insert/replace: shards by key and rides the workers' batched
    /// backend path (one epoch pin per shard window instead of one per
    /// op). One [`OpResult::Upserted`] per pair, in submission order.
    pub fn insert_batch(&self, pairs: &[(u32, u32)]) -> Result<Vec<OpResult>> {
        let ops: Vec<Op> =
            pairs.iter().map(|&(key, value)| Op::Insert { key, value }).collect();
        self.submit(&ops)
    }

    /// Bulk lookup in submission order, via the batched backend path.
    pub fn lookup_batch(&self, keys: &[u32]) -> Result<Vec<Option<u32>>> {
        let ops: Vec<Op> = keys.iter().map(|&key| Op::Lookup { key }).collect();
        Ok(self
            .submit(&ops)?
            .into_iter()
            .map(|r| r.as_value().expect("lookup op yields Value"))
            .collect())
    }

    /// Bulk delete in submission order, via the batched backend path.
    pub fn delete_batch(&self, keys: &[u32]) -> Result<Vec<bool>> {
        let ops: Vec<Op> = keys.iter().map(|&key| Op::Delete { key }).collect();
        Ok(self
            .submit(&ops)?
            .into_iter()
            .map(|r| r.as_deleted().expect("delete op yields Deleted"))
            .collect())
    }

    /// Submit a pre-batched workload: ops are sharded by key, executed on
    /// all workers, and the typed results are reassembled **in
    /// submission order** — one [`OpResult`] per op, whatever mix of
    /// classes the window carries.
    ///
    /// Sub-batches are scattered up front and their replies gathered in
    /// *arrival order* over one shared channel. A worker may split its
    /// sub-batch further (forwarding mid-move ops to their owner), so
    /// every reply carries the submission positions it resolves and the
    /// gather runs until all positions are filled.
    pub fn submit(&self, ops: &[Op]) -> Result<Vec<OpResult>> {
        if ops.is_empty() {
            return Ok(Vec::new());
        }
        let w = self.senders.len();
        let mut shards: Vec<(Vec<Op>, Vec<u32>)> = vec![(Vec::new(), Vec::new()); w];
        for (pos, op) in ops.iter().enumerate() {
            let r = self.route(op.key());
            shards[r].0.push(*op);
            shards[r].1.push(pos as u32);
        }
        let (tx, rx) = mpsc::channel::<BulkReply>();
        let enqueued = Instant::now();
        for (i, (shard, positions)) in shards.into_iter().enumerate() {
            if shard.is_empty() {
                continue;
            }
            self.senders[i]
                .send(Request::Bulk { ops: shard, positions, enqueued, reply: tx.clone() })
                .map_err(|_| HiveError::Shutdown)?;
        }
        drop(tx);
        let mut out: Vec<Option<OpResult>> = vec![None; ops.len()];
        let mut filled = 0usize;
        while filled < ops.len() {
            let (positions, res) = rx.recv().map_err(|_| HiveError::Shutdown)?;
            let results = res?;
            debug_assert_eq!(positions.len(), results.len(), "one result per position");
            for (pos, r) in positions.into_iter().zip(results) {
                let slot = &mut out[pos as usize];
                if slot.is_none() {
                    filled += 1;
                }
                *slot = Some(r);
            }
        }
        Ok(out.into_iter().map(|r| r.expect("every position filled")).collect())
    }

    /// Per-shard stats snapshots, indexed by shard. Scatter the request
    /// to every worker first, then gather, so one slow worker doesn't
    /// serialize the round-trips of the rest.
    pub fn stats_per_shard(&self) -> Result<Vec<ServiceStats>> {
        let mut rxs = Vec::with_capacity(self.senders.len());
        for tx in self.senders.iter() {
            let (rtx, rrx) = sync_channel(1);
            tx.send(Request::Stats { reply: rtx }).map_err(|_| HiveError::Shutdown)?;
            rxs.push(rrx);
        }
        rxs.into_iter().map(|rrx| rrx.recv().map_err(|_| HiveError::Shutdown)).collect()
    }

    /// Aggregate service stats: every shard's snapshot merged (counters
    /// add, histograms union) — not any single shard's view.
    pub fn stats(&self) -> Result<ServiceStats> {
        let mut agg = ServiceStats::default();
        for s in self.stats_per_shard()? {
            agg.merge(&s);
        }
        Ok(agg)
    }

    /// Flush all pending windows (barrier; used by tests/benches).
    /// Scatter-then-gather like [`Handle::stats`].
    pub fn flush(&self) -> Result<()> {
        let mut rxs = Vec::with_capacity(self.senders.len());
        for tx in self.senders.iter() {
            let (rtx, rrx) = sync_channel(1);
            tx.send(Request::Flush { reply: rtx }).map_err(|_| HiveError::Shutdown)?;
            rxs.push(rrx);
        }
        for rrx in rxs {
            rrx.recv().map_err(|_| HiveError::Shutdown)?;
        }
        Ok(())
    }
}

/// An op's routing classification against the shard directory, as seen
/// by the worker it arrived on.
enum RouteClass {
    /// This worker owns the key's partition — the normal fast path.
    Local,
    /// Another shard owns it (the sender raced a directory flip):
    /// forward to the owner, never execute here.
    Forward(usize),
    /// The key's partition is moving *to* this worker and the source is
    /// not fenced yet — park the op until the fence acks.
    Hold,
    /// The key's partition is moving to this worker and the source is
    /// quiesced (or the move was abandoned): execute dual-table.
    Dual { src: usize },
}

/// Requests parked while this worker fences the source of an inbound
/// partition move.
enum Held {
    Single { op: Op, enqueued: Instant, done: CompletionSlot },
    Bulk { ops: Vec<Op>, positions: Vec<u32>, enqueued: Instant, reply: Sender<BulkReply> },
}

/// Phase of the one inbound partition move a worker drives at a time.
enum MovePhase {
    /// Waiting for the source worker to execute a flush marker sent
    /// down its ring *after* the directory flip: once it acks, every
    /// window the source executed before the flip has retired, so the
    /// partition snapshot taken next is complete.
    Fencing { pending: Option<Request>, ack: Receiver<()> },
    /// Copying the partition's keys out of the source table, a bounded
    /// chunk per loop tick so inbound traffic keeps flowing in between.
    Migrating { keys: Vec<(u32, u32)>, next: usize },
}

struct MoveState {
    partition: u32,
    src: usize,
    reply: Sender<Result<()>>,
    held: Vec<Held>,
    phase: MovePhase,
}

/// Keys copied per migration tick — bounds how long a tick can starve
/// the ring while keeping per-key overhead amortized.
const MIGRATE_CHUNK: usize = 128;

/// One worker: owns a backend shard and the hot-key cache in front of
/// it, batches singles, executes bulks, runs the resize controller
/// between windows, forwards misrouted requests, and drives at most one
/// inbound partition move at a time.
struct Worker {
    index: usize,
    backend: Box<dyn Backend>,
    batcher: Batcher,
    /// Waiting singles, 1:1 (and in order) with the batcher's pending
    /// window — the typed results zip straight back onto the slots.
    waiting: Vec<(Instant, CompletionSlot)>,
    stats: ServiceStats,
    /// Read-through hot-key cache; `None` when disabled by config or
    /// when the backend cannot produce a coherence stamp.
    cache: Option<HotKeyCache>,
    cfg: CoordinatorConfig,
    /// Every worker's ring sender, for forwarding misrouted requests.
    peers: Arc<Vec<RingTx<Request>>>,
    plane: Arc<ShardPlane>,
    /// Forwards that hit a full peer ring, retried (non-blocking) once
    /// per loop tick. Blocking here could deadlock two workers
    /// forwarding into each other's full rings.
    forward_backlog: VecDeque<(usize, Request)>,
    active_move: Option<MoveState>,
    pending_moves: VecDeque<(u32, Sender<Result<()>>)>,
}

impl Worker {
    /// Execute one dispatch window through the cache + backend stack:
    /// wholesale-validate the cache against the backend's coherence
    /// stamp, serve lookup hits without touching the backend, execute
    /// the remainder, retire the window's written keys from the cache,
    /// then refill from results whose post-window value is knowable.
    ///
    /// Lookups whose key is *written in the same window* never consult
    /// the cache: the backend groups write classes before lookups, so
    /// serving such a lookup from the cache would observe the pre-window
    /// value where the uncached path observes the post-write one. Every
    /// op class except `Lookup` counts as a write here — `Cas` and
    /// `Update` may decline, but conservative bypass is always
    /// observationally identical to the uncached path (which the
    /// cross-path differential in `tests/test_cache.rs` pins down).
    ///
    /// Refill policy: backend lookup results always refill (they are
    /// post-window values). Of the write classes, an applied `Cas`
    /// (known new value) and an applied `Update` refill — but only when
    /// theirs is the window's *only* write to that key, otherwise a
    /// later class (e.g. a fetch-add grouped after the CAS) already
    /// moved the value past what the result shows.
    fn execute_window(&mut self, ops: &[Op]) -> Result<Vec<OpResult>> {
        self.stats.batches += 1;
        self.stats.ops += ops.len() as u64;
        self.stats.batch_sizes.record(ops.len() as u64);
        let Some(cache) = self.cache.as_mut() else {
            return self.backend.execute(ops);
        };
        let stamp = self.backend.coherence_stamp().expect("cached backend lost its stamp");
        if !cache.validate(stamp) {
            self.stats.cache_flushes += 1;
        }
        // Write-only window: nothing to serve, and refill would need the
        // written-once bookkeeping below for no benefit — execute and
        // retire the written keys' cached copies directly.
        if !ops.iter().any(|op| matches!(op, Op::Lookup { .. })) {
            let res = self.backend.execute(ops)?;
            for op in ops {
                if cache.invalidate(op.key()) {
                    self.stats.cache_invalidations += 1;
                }
            }
            return Ok(res);
        }
        // Writes per key: conflict bypass for same-window lookups and
        // the written-once guard for the refill pass.
        let mut writes: HashMap<u32, u32> = HashMap::new();
        for op in ops {
            if op.is_write() {
                *writes.entry(op.key()).or_default() += 1;
            }
        }
        // Serve lookup hits out of the cache; everything else (writes,
        // misses, write-conflicting lookups) goes to the backend.
        let mut slots: Vec<Option<OpResult>> = vec![None; ops.len()];
        let mut backend_ops: Vec<Op> = Vec::with_capacity(ops.len());
        let mut backend_idx: Vec<usize> = Vec::with_capacity(ops.len());
        for (i, op) in ops.iter().enumerate() {
            if let Op::Lookup { key } = *op {
                // write-conflicted lookups bypass the cache without
                // touching the hit/miss counters: they never consult it,
                // and counting them as misses would understate the hit
                // rate fig10 publishes
                if !writes.contains_key(&key) {
                    match cache.get(key) {
                        Some(v) => {
                            self.stats.cache_hits += 1;
                            slots[i] = Some(OpResult::Value(Some(v)));
                            continue;
                        }
                        None => self.stats.cache_misses += 1,
                    }
                }
            }
            backend_idx.push(i);
            backend_ops.push(*op);
        }
        let backend_res = if backend_ops.is_empty() {
            Vec::new()
        } else {
            self.backend.execute(&backend_ops)?
        };
        // Per-key invalidation: the window's writes retire cached copies
        // before any result is published.
        for key in writes.keys() {
            if cache.invalidate(*key) {
                self.stats.cache_invalidations += 1;
            }
        }
        // Scatter backend results into submission order and refill the
        // cache. Lookup values are post-window (write classes group
        // first); write-class refills obey the written-once guard.
        // Misses are never cached: absent keys churn fastest under
        // skewed delete/re-insert traffic.
        for (&i, res) in backend_idx.iter().zip(backend_res) {
            match (ops[i], res) {
                (Op::Lookup { key }, OpResult::Value(Some(v))) => cache.put(key, v),
                (Op::Cas { key, new, .. }, OpResult::Cas { ok: true, .. })
                    if writes.get(&key) == Some(&1) =>
                {
                    cache.put(key, new);
                }
                (Op::Update { key, value }, OpResult::Updated { old: Some(_) })
                    if writes.get(&key) == Some(&1) =>
                {
                    cache.put(key, value);
                }
                _ => {}
            }
            slots[i] = Some(res);
        }
        Ok(slots.into_iter().map(|r| r.expect("one result per op")).collect())
    }

    /// Flush the pending single-op window and publish every waiter's
    /// typed result in one batch — one wakeup per client window, not one
    /// per op. `backlog` is the submission-ring depth at dispatch time,
    /// folded into the in-flight depth stat.
    fn dispatch(&mut self, backlog: usize) {
        if self.batcher.is_empty() {
            return;
        }
        let ops = self.batcher.take();
        let started = Instant::now();
        self.stats.inflight_depth.record((self.waiting.len() + backlog) as u64);
        for (enq, _) in &self.waiting {
            self.stats
                .queue_delay_ns
                .record(started.saturating_duration_since(*enq).as_nanos() as u64);
        }
        match self.execute_window(&ops) {
            Ok(results) => {
                debug_assert_eq!(results.len(), self.waiting.len(), "one result per waiter");
                self.stats.record_results(&results);
                // completions in submission order, published as one batch
                let mut completions = Vec::with_capacity(self.waiting.len());
                for ((enq, done), res) in self.waiting.drain(..).zip(results) {
                    self.stats.latency_ns.record(enq.elapsed().as_nanos() as u64);
                    completions.push((done, Ok(res)));
                }
                pipeline::publish_batch(completions);
            }
            Err(e) => {
                let mut completions = Vec::with_capacity(self.waiting.len());
                for (_, done) in self.waiting.drain(..) {
                    completions.push((done, Err(e.clone())));
                }
                pipeline::publish_batch(completions);
            }
        }
        self.check_resize();
    }

    /// Resize controller between windows. The call still runs a full
    /// K-bucket migration batch synchronously on this worker thread,
    /// but with the epoch scheme other threads' operations (and other
    /// shards) proceed concurrently instead of blocking on a write
    /// guard. A resize that drains the stash or swaps the state pointer
    /// moves the coherence stamp, so the next window's wholesale
    /// validation flushes the cache.
    fn check_resize(&mut self) {
        if self.stats.batches % self.cfg.resize_check_every != 0 {
            return;
        }
        match self.backend.maybe_resize() {
            Ok(Some(ResizeEvent::Grew { .. })) => self.stats.grows += 1,
            Ok(Some(ResizeEvent::Shrank { .. })) => self.stats.shrinks += 1,
            _ => {}
        }
    }

    /// Classify one key against the shard directory (one shared load).
    fn classify(&self, key: u32) -> RouteClass {
        let p = self.plane.directory.partition_of(key);
        match self.plane.directory.ownership(p) {
            Ownership::Settled(s) if s == self.index => RouteClass::Local,
            Ownership::Settled(s) => RouteClass::Forward(s),
            Ownership::Moving { src, dst } if dst == self.index => {
                // Before the source acks the fence it may still be
                // executing pre-flip windows — running the op here too
                // would break the single-executor discipline, so it
                // parks. A moving entry with *no* matching active move
                // is an abandoned move (the source died mid-fence):
                // dual-table execution stays correct indefinitely.
                let fencing = matches!(
                    &self.active_move,
                    Some(m) if m.partition == p && matches!(m.phase, MovePhase::Fencing { .. })
                );
                if fencing {
                    RouteClass::Hold
                } else {
                    RouteClass::Dual { src }
                }
            }
            Ownership::Moving { dst, .. } => RouteClass::Forward(dst),
        }
    }

    fn handle_single(&mut self, op: Op, enqueued: Instant, done: CompletionSlot, backlog: usize) {
        match self.classify(op.key()) {
            RouteClass::Local => {
                self.waiting.push((enqueued, done));
                // The window's deadline runs from the op's submission,
                // so ring backlog counts against it. An expired window
                // is NOT dispatched mid-drain: it ships at the next
                // instant the ring is momentarily empty (the try_recv
                // None path in the loop) or at max_batch, whichever is
                // first. That bounds deadline overshoot to the in-hand
                // backlog while keeping the batch amortization the
                // plane exists for — dispatching per-op on an aged
                // backlog would collapse every window to size 1 exactly
                // under overload.
                if self.batcher.push_at(op, enqueued) {
                    self.dispatch(backlog);
                }
            }
            RouteClass::Forward(to) => {
                self.stats.forwarded += 1;
                self.push_forward(to, Request::Single { op, enqueued, done });
            }
            RouteClass::Hold => {
                let m = self.active_move.as_mut().expect("hold implies an active move");
                m.held.push(Held::Single { op, enqueued, done });
            }
            RouteClass::Dual { src } => self.moving_single(src, op, enqueued, done),
        }
    }

    fn handle_bulk(
        &mut self,
        ops: Vec<Op>,
        positions: Vec<u32>,
        enqueued: Instant,
        reply: Sender<BulkReply>,
        backlog: usize,
    ) {
        // Fast path: with the directory untouched (or this worker owning
        // every key) the whole sub-batch executes locally — exactly the
        // pre-shard bulk path, no splitting allocation.
        if ops.iter().all(|op| matches!(self.classify(op.key()), RouteClass::Local)) {
            return self.execute_bulk_local(ops, positions, enqueued, reply, backlog);
        }
        let mut local_ops = Vec::new();
        let mut local_pos = Vec::new();
        let mut held_ops = Vec::new();
        let mut held_pos = Vec::new();
        let mut moving: Vec<(Op, u32, usize)> = Vec::new();
        let mut fwd: HashMap<usize, (Vec<Op>, Vec<u32>)> = HashMap::new();
        for (op, pos) in ops.into_iter().zip(positions) {
            match self.classify(op.key()) {
                RouteClass::Local => {
                    local_ops.push(op);
                    local_pos.push(pos);
                }
                RouteClass::Forward(to) => {
                    let e = fwd.entry(to).or_default();
                    e.0.push(op);
                    e.1.push(pos);
                }
                RouteClass::Hold => {
                    held_ops.push(op);
                    held_pos.push(pos);
                }
                RouteClass::Dual { src } => moving.push((op, pos, src)),
            }
        }
        for (to, (ops, positions)) in fwd {
            self.stats.forwarded += ops.len() as u64;
            self.push_forward(to, Request::Bulk { ops, positions, enqueued, reply: reply.clone() });
        }
        if !held_ops.is_empty() {
            let m = self.active_move.as_mut().expect("hold implies an active move");
            m.held.push(Held::Bulk {
                ops: held_ops,
                positions: held_pos,
                enqueued,
                reply: reply.clone(),
            });
        }
        if !moving.is_empty() {
            self.moving_bulk(moving, enqueued, reply.clone());
        }
        if !local_ops.is_empty() {
            self.execute_bulk_local(local_ops, local_pos, enqueued, reply, backlog);
        }
    }

    /// The pre-shard bulk path: flush pending singles (window ordering),
    /// execute the sub-window, reply with its positions.
    fn execute_bulk_local(
        &mut self,
        ops: Vec<Op>,
        positions: Vec<u32>,
        enqueued: Instant,
        reply: Sender<BulkReply>,
        backlog: usize,
    ) {
        // flush pending singles first to preserve window ordering
        self.dispatch(backlog);
        let started = Instant::now();
        self.stats.queue_delay_ns.record_n(
            started.saturating_duration_since(enqueued).as_nanos() as u64,
            ops.len() as u64,
        );
        self.stats.inflight_depth.record((ops.len() + backlog) as u64);
        let res = self.execute_window(&ops);
        if let Ok(res) = &res {
            self.stats.record_results(res);
            self.stats
                .latency_ns
                .record_n(enqueued.elapsed().as_nanos() as u64, ops.len() as u64);
        }
        let _ = reply.send((positions, res));
        self.check_resize();
    }

    /// Execute one op whose partition is mid-move, against both the
    /// source and destination tables. Bypasses the batcher and the
    /// cache entirely — mid-move keys are never cached.
    fn execute_moving(&mut self, src: usize, op: &Op) -> Result<OpResult> {
        self.stats.moving_ops += 1;
        let s = Arc::clone(&self.plane.tables[src]);
        let d = Arc::clone(&self.plane.tables[self.index]);
        exec_dual(&s, &d, op)
    }

    fn moving_single(&mut self, src: usize, op: Op, enqueued: Instant, done: CompletionSlot) {
        let started = Instant::now();
        self.stats
            .queue_delay_ns
            .record(started.saturating_duration_since(enqueued).as_nanos() as u64);
        let res = self.execute_moving(src, &op);
        if let Ok(r) = &res {
            self.stats.record_results(std::slice::from_ref(r));
        }
        // bypasses execute_window, so account the op here
        self.stats.ops += 1;
        self.stats.latency_ns.record(enqueued.elapsed().as_nanos() as u64);
        pipeline::publish_batch(vec![(done, res)]);
    }

    fn moving_bulk(
        &mut self,
        items: Vec<(Op, u32, usize)>,
        enqueued: Instant,
        reply: Sender<BulkReply>,
    ) {
        let started = Instant::now();
        self.stats.queue_delay_ns.record_n(
            started.saturating_duration_since(enqueued).as_nanos() as u64,
            items.len() as u64,
        );
        let mut positions = Vec::with_capacity(items.len());
        let mut results = Vec::with_capacity(items.len());
        let mut failure: Option<HiveError> = None;
        for (op, pos, src) in items {
            positions.push(pos);
            if failure.is_none() {
                match self.execute_moving(src, &op) {
                    Ok(r) => results.push(r),
                    Err(e) => failure = Some(e),
                }
            }
        }
        let res = match failure {
            None => {
                self.stats.record_results(&results);
                self.stats.ops += results.len() as u64;
                self.stats
                    .latency_ns
                    .record_n(enqueued.elapsed().as_nanos() as u64, results.len() as u64);
                Ok(results)
            }
            Some(e) => Err(e),
        };
        let _ = reply.send((positions, res));
    }

    fn push_forward(&mut self, to: usize, req: Request) {
        match self.peers[to].try_send(req) {
            TrySend::Sent => {}
            TrySend::Full(req) => self.forward_backlog.push_back((to, req)),
            TrySend::Disconnected(req) => fail_request(req),
        }
    }

    /// Retry backlogged forwards, once each, without blocking.
    fn drain_forwards(&mut self) {
        for _ in 0..self.forward_backlog.len() {
            let (to, req) = self.forward_backlog.pop_front().expect("len-bounded");
            match self.peers[to].try_send(req) {
                TrySend::Sent => {}
                TrySend::Full(req) => self.forward_backlog.push_back((to, req)),
                TrySend::Disconnected(req) => fail_request(req),
            }
        }
    }

    /// Whether any shard-plane work needs loop ticks independent of ring
    /// arrivals (fence acks come on a side channel; migration and
    /// forward retries progress only here).
    fn has_plane_work(&self) -> bool {
        self.active_move.is_some()
            || !self.pending_moves.is_empty()
            || !self.forward_backlog.is_empty()
    }

    /// Drive the inbound move state machine one step: activate the next
    /// queued move when idle, then advance the fence or copy one
    /// bounded chunk. Every step is non-blocking, and the whole call is
    /// a no-op for workers with no plane work — i.e. for every
    /// never-resharded coordinator.
    fn poll_move(&mut self) {
        if self.active_move.is_none() {
            if let Some((partition, reply)) = self.pending_moves.pop_front() {
                self.activate_move(partition, reply);
            }
        }
        // Destructure the state so the phase data can move into the
        // phase handlers (which re-store the state when not done).
        let Some(MoveState { partition, src, reply, held, phase }) = self.active_move.take()
        else {
            return;
        };
        match phase {
            MovePhase::Fencing { pending, ack } => {
                self.poll_fence(partition, src, reply, held, pending, ack)
            }
            MovePhase::Migrating { keys, next } => {
                self.poll_migrate(partition, src, reply, held, keys, next)
            }
        }
    }

    fn activate_move(&mut self, partition: u32, reply: Sender<Result<()>>) {
        if partition as usize >= self.plane.directory.partitions() {
            let _ = reply
                .send(Err(HiveError::Config(format!("partition {partition} out of range"))));
            return;
        }
        let src = match self.plane.directory.ownership(partition) {
            Ownership::Settled(s) if s == self.index => {
                // already here — trivially done
                let _ = reply.send(Ok(()));
                return;
            }
            Ownership::Settled(s) => s,
            Ownership::Moving { .. } => {
                let _ = reply.send(Err(HiveError::Runtime(format!(
                    "partition {partition} is already mid-move"
                ))));
                return;
            }
        };
        if self.plane.tables.is_empty() {
            let _ = reply.send(Err(HiveError::Config(
                "online resharding requires a native shard plane (start_native / \
                 start_native_sharded); factory-built coordinators have a static directory"
                    .into(),
            )));
            return;
        }
        if !self.plane.directory.begin_move(partition, src, self.index) {
            let _ = reply.send(Err(HiveError::Runtime(format!(
                "partition {partition} changed hands mid-claim"
            ))));
            return;
        }
        self.stats.moves_started += 1;
        // The cache may hold keys from this partition's previous tenancy
        // on this shard; the move makes them live again through a table
        // this cache never observed — clear wholesale.
        if let Some(cache) = self.cache.as_mut() {
            cache.clear();
            self.stats.cache_flushes += 1;
        }
        let (ftx, frx) = sync_channel::<()>(1);
        self.active_move = Some(MoveState {
            partition,
            src,
            reply,
            held: Vec::new(),
            phase: MovePhase::Fencing {
                pending: Some(Request::Flush { reply: ftx }),
                ack: frx,
            },
        });
    }

    fn poll_fence(
        &mut self,
        partition: u32,
        src: usize,
        reply: Sender<Result<()>>,
        held: Vec<Held>,
        mut pending: Option<Request>,
        ack: Receiver<()>,
    ) {
        if let Some(req) = pending.take() {
            match self.peers[src].try_send(req) {
                TrySend::Sent => {}
                TrySend::Full(req) => pending = Some(req),
                TrySend::Disconnected(_) => {
                    // The source died before the fence landed. Leave the
                    // directory entry moving: dual-table execution stays
                    // correct indefinitely, the copy just never happens.
                    let _ = reply.send(Err(HiveError::Shutdown));
                    fail_held(held);
                    return;
                }
            }
        }
        if pending.is_none() {
            match ack.try_recv() {
                Ok(()) => {
                    // Fence acked: every window the source executed
                    // before the directory flip has retired, so this
                    // snapshot sees the partition completely.
                    let keys = self.partition_snapshot(src, partition);
                    self.active_move = Some(MoveState {
                        partition,
                        src,
                        reply,
                        held: Vec::new(),
                        phase: MovePhase::Migrating { keys, next: 0 },
                    });
                    self.drain_held(src, held);
                    return;
                }
                Err(TryRecvError::Empty) => {}
                Err(TryRecvError::Disconnected) => {
                    let _ = reply.send(Err(HiveError::Shutdown));
                    fail_held(held);
                    return;
                }
            }
        }
        self.active_move = Some(MoveState {
            partition,
            src,
            reply,
            held,
            phase: MovePhase::Fencing { pending, ack },
        });
    }

    fn poll_migrate(
        &mut self,
        partition: u32,
        src: usize,
        reply: Sender<Result<()>>,
        held: Vec<Held>,
        mut keys: Vec<(u32, u32)>,
        mut next: usize,
    ) {
        let src_t = Arc::clone(&self.plane.tables[src]);
        let dst_t = Arc::clone(&self.plane.tables[self.index]);
        let end = (next + MIGRATE_CHUNK).min(keys.len());
        while next < end {
            let (k, _) = keys[next];
            // Live re-check: a dual-table op may have deleted or
            // rewritten the key since the snapshot — copying the
            // snapshot value would resurrect it.
            if let Some(cur) = src_t.lookup(k) {
                if dst_t.insert_if_absent(k, cur).is_err() {
                    // destination full: nudge its resizer and retry the
                    // same key next tick
                    let _ = dst_t.maybe_resize();
                    break;
                }
                src_t.delete(k);
                self.stats.keys_migrated += 1;
            }
            next += 1;
        }
        if next >= keys.len() {
            // Re-snapshot before settling: a source-side lookup can
            // transiently miss mid stash-drain, stranding a key this
            // pass. The source set only shrinks post-fence (writes land
            // dual-side in the destination), so repeated passes
            // converge on empty.
            let snap = self.partition_snapshot(src, partition);
            if snap.is_empty() {
                let settled = self.plane.directory.finish_move(partition);
                debug_assert!(settled, "finish_move on an entry this worker claimed");
                self.stats.moves_completed += 1;
                let _ = reply.send(Ok(()));
                debug_assert!(held.is_empty(), "held ops drain at fence ack");
                fail_held(held); // defensive: never leak completion slots
                return;
            }
            keys = snap;
            next = 0;
        }
        self.active_move = Some(MoveState {
            partition,
            src,
            reply,
            held,
            phase: MovePhase::Migrating { keys, next },
        });
    }

    /// All keys of `partition` still living in shard `src`'s table.
    fn partition_snapshot(&self, src: usize, partition: u32) -> Vec<(u32, u32)> {
        self.plane.tables[src]
            .entries()
            .into_iter()
            .filter(|&(k, _)| self.plane.directory.partition_of(k) == partition)
            .collect()
    }

    /// Execute the ops parked behind the fence, dual-table, now that the
    /// source is quiesced.
    fn drain_held(&mut self, src: usize, held: Vec<Held>) {
        for h in held {
            match h {
                Held::Single { op, enqueued, done } => self.moving_single(src, op, enqueued, done),
                Held::Bulk { ops, positions, enqueued, reply } => {
                    let items: Vec<(Op, u32, usize)> =
                        ops.into_iter().zip(positions).map(|(op, pos)| (op, pos, src)).collect();
                    self.moving_bulk(items, enqueued, reply);
                }
            }
        }
    }

    /// Fail every outstanding plane obligation on shutdown: backlogged
    /// forwards, the active move, and any queued ones.
    fn abort_plane_work(&mut self) {
        for (_, req) in self.forward_backlog.drain(..) {
            fail_request(req);
        }
        if let Some(m) = self.active_move.take() {
            let _ = m.reply.send(Err(HiveError::Shutdown));
            fail_held(m.held);
        }
        for (_, reply) in self.pending_moves.drain(..) {
            let _ = reply.send(Err(HiveError::Shutdown));
        }
    }
}

/// Fail a request that can no longer reach a worker. Bulk replies must
/// be sent explicitly: the submitter holds other clones of the same
/// reply channel, so merely dropping this one would leave its gather
/// loop waiting on positions that never arrive.
fn fail_request(req: Request) {
    match req {
        Request::Bulk { positions, reply, .. } => {
            let _ = reply.send((positions, Err(HiveError::Shutdown)));
        }
        Request::Reshard { reply, .. } => {
            let _ = reply.send(Err(HiveError::Shutdown));
        }
        // Single/Stats/Flush: dropping the slot or sender fires Shutdown
        // on the waiting side.
        Request::Single { .. }
        | Request::Stats { .. }
        | Request::Flush { .. }
        | Request::Shutdown => {}
    }
}

fn fail_held(held: Vec<Held>) {
    for h in held {
        match h {
            // dropping the completion slot fires Shutdown
            Held::Single { .. } => {}
            Held::Bulk { positions, reply, .. } => {
                let _ = reply.send((positions, Err(HiveError::Shutdown)));
            }
        }
    }
}

/// Run one op against one table through the grouped batch path.
fn exec_one(t: &HiveTable, op: &Op) -> Result<OpResult> {
    Ok(t.execute_ops(std::slice::from_ref(op))?.remove(0))
}

/// Execute `op` for a key whose partition is mid-move from table `s`
/// (source) to `d` (destination): reads consult the destination first
/// and fall back to the source; writes land in the destination and
/// retire the source copy. The pair behaves as one logical table whose
/// authoritative copy drifts toward the destination — exactly what the
/// concurrent migration loop needs, since it only ever *removes* keys
/// from the source.
fn exec_dual(s: &HiveTable, d: &HiveTable, op: &Op) -> Result<OpResult> {
    match *op {
        Op::Lookup { key } => Ok(OpResult::Value(d.lookup(key).or_else(|| s.lookup(key)))),
        Op::Delete { key } => {
            let hit_d = d.delete(key);
            let hit_s = s.delete(key);
            Ok(OpResult::Deleted(hit_d || hit_s))
        }
        Op::Insert { key, value } | Op::Upsert { key, value } => {
            let s_old = s.lookup(key);
            let (outcome, d_old) = d.upsert(key, value)?;
            if s_old.is_some() {
                s.delete(key);
            }
            // a key living only source-side is logically present: the
            // destination's "Inserted" is a replace of that copy
            let outcome = if d_old.is_none() && s_old.is_some() {
                InsertOutcome::Replaced
            } else {
                outcome
            };
            Ok(OpResult::Upserted { outcome, old: d_old.or(s_old) })
        }
        Op::InsertIfAbsent { key, value } => match d.lookup(key).or_else(|| s.lookup(key)) {
            Some(v) => Ok(OpResult::InsertedIfAbsent { outcome: None, existing: Some(v) }),
            None => exec_one(d, &Op::InsertIfAbsent { key, value }),
        },
        Op::Update { key, value } => {
            if d.lookup(key).is_some() {
                return exec_one(d, op);
            }
            match s.lookup(key) {
                Some(old) => {
                    d.insert(key, value)?;
                    s.delete(key);
                    Ok(OpResult::Updated { old: Some(old) })
                }
                None => Ok(OpResult::Updated { old: None }),
            }
        }
        Op::Cas { key, expected, new } => {
            if d.lookup(key).is_some() {
                return exec_one(d, op);
            }
            match s.lookup(key) {
                Some(actual) if actual == expected => {
                    d.insert(key, new)?;
                    s.delete(key);
                    Ok(OpResult::Cas { ok: true, actual: Some(actual) })
                }
                Some(actual) => Ok(OpResult::Cas { ok: false, actual: Some(actual) }),
                None => exec_one(d, op),
            }
        }
        Op::FetchAdd { key, delta } => {
            if d.lookup(key).is_some() {
                return exec_one(d, op);
            }
            match s.lookup(key) {
                Some(old) => {
                    d.insert(key, old.wrapping_add(delta))?;
                    s.delete(key);
                    Ok(OpResult::FetchAdded { outcome: None, old: Some(old) })
                }
                None => exec_one(d, op),
            }
        }
    }
}

fn worker_loop(
    index: usize,
    rx: RingRx<Request>,
    backend: Box<dyn Backend>,
    cfg: CoordinatorConfig,
    peers: Arc<Vec<RingTx<Request>>>,
    plane: Arc<ShardPlane>,
) {
    let cache = if cfg.cache_capacity > 0 {
        backend.coherence_stamp().map(|s| HotKeyCache::new(cfg.cache_capacity, s))
    } else {
        None
    };
    let mut w = Worker {
        index,
        batcher: Batcher::new(cfg.batch),
        waiting: Vec::new(),
        stats: ServiceStats::default(),
        backend,
        cache,
        cfg,
        peers,
        plane,
        forward_backlog: VecDeque::new(),
        active_move: None,
        pending_moves: VecDeque::new(),
    };
    loop {
        // Plane work first: both are no-ops for a worker that never sees
        // a forward or a move (every pre-shard workload).
        w.drain_forwards();
        w.poll_move();
        // Drain the ring straight into the batcher: only sleep on the
        // dispatch deadline when no request is immediately available.
        let req = match rx.try_recv() {
            Some(r) => r,
            None => {
                if w.batcher.deadline_expired() {
                    w.dispatch(rx.backlog());
                    continue;
                }
                let mut timeout =
                    w.batcher.time_to_deadline().unwrap_or(Duration::from_millis(50));
                if w.has_plane_work() {
                    // Fence acks arrive on a side channel and migration
                    // chunks progress on this loop, not on ring
                    // arrivals — don't sleep long on an idle ring while
                    // a move is in flight.
                    timeout = timeout.min(Duration::from_micros(50));
                }
                match rx.recv_timeout(timeout) {
                    Ok(r) => r,
                    Err(RecvTimeoutError::Timeout) => {
                        if w.batcher.deadline_expired() {
                            w.dispatch(rx.backlog());
                        }
                        continue;
                    }
                    Err(RecvTimeoutError::Disconnected) => break,
                }
            }
        };
        match req {
            Request::Single { op, enqueued, done } => {
                w.handle_single(op, enqueued, done, rx.backlog());
            }
            Request::Bulk { ops, positions, enqueued, reply } => {
                w.handle_bulk(ops, positions, enqueued, reply, rx.backlog());
            }
            Request::Stats { reply } => {
                let _ = reply.send(w.stats.clone());
            }
            Request::Flush { reply } => {
                w.dispatch(rx.backlog());
                let _ = reply.send(());
            }
            Request::Reshard { partition, reply } => {
                w.pending_moves.push_back((partition, reply));
            }
            Request::Shutdown => {
                w.dispatch(rx.backlog());
                break;
            }
        }
    }
    w.abort_plane_work();
    // `rx` drops here: any request still queued behind the shutdown
    // marker is drained and its completion slot / reply channel fires
    // with `Shutdown` — same for `w.waiting` if the thread unwinds.
}

/// Shared-state convenience: a coordinator whose workers all use native
/// backends over table shards sized by `cfg`. Equivalent to
/// [`start_native_sharded`] with no thread placement — the historical
/// default, pinned down by the unmodified service tests.
pub fn start_native(
    coord_cfg: CoordinatorConfig,
    table_cfg: crate::core::config::HiveConfig,
) -> Result<(Coordinator, Handle)> {
    let plan = ShardPlan { placement: Placement::None, ..ShardPlan::default() };
    start_native_sharded(coord_cfg, plan, table_cfg)
}

/// Sharded native coordinator: one independent [`HiveTable`] per worker
/// (its own epoch domain, stash, coherence stamp and striped counters),
/// registered on the shard plane so partitions can move between shards
/// online via [`Handle::reshard`], with worker threads pinned per
/// `plan.placement`.
///
/// Tables are built up front on the calling thread — the plane needs
/// every shard's table before any worker can run a cross-shard move.
/// (First-touch locality of the *initial* arrays is therefore the
/// caller's; the arrays a shard grows into during resize are allocated
/// on its own pinned thread.)
pub fn start_native_sharded(
    coord_cfg: CoordinatorConfig,
    plan: ShardPlan,
    table_cfg: crate::core::config::HiveConfig,
) -> Result<(Coordinator, Handle)> {
    assert!(coord_cfg.workers >= 1);
    let mut tables = Vec::with_capacity(coord_cfg.workers);
    for _ in 0..coord_cfg.workers {
        tables.push(Arc::new(HiveTable::new(table_cfg.clone())?));
    }
    let partitions = plan.partitions_per_shard.max(1) * coord_cfg.workers;
    let plane = Arc::new(ShardPlane {
        directory: ShardDirectory::new(partitions, coord_cfg.workers),
        tables: tables.clone(),
    });
    let tables = Arc::new(tables);
    Coordinator::start_on_plane(coord_cfg, plan, plane, move |w| {
        Ok(Box::new(crate::backend::NativeBackend::shared(Arc::clone(&tables[w])))
            as Box<dyn Backend>)
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::config::HiveConfig;

    fn quick_cfg() -> CoordinatorConfig {
        CoordinatorConfig {
            workers: 2,
            batch: BatchPolicy { max_batch: 64, deadline: Duration::from_micros(100) },
            resize_check_every: 2,
            cache_capacity: 256,
            ring_capacity: 256,
        }
    }

    #[test]
    fn single_op_roundtrip() {
        let (coord, h) =
            start_native(quick_cfg(), HiveConfig::default().with_buckets(64)).unwrap();
        assert_eq!(h.insert(1, 100).unwrap(), InsertOutcome::Inserted);
        assert_eq!(h.insert(1, 101).unwrap(), InsertOutcome::Replaced);
        assert_eq!(h.lookup(1).unwrap(), Some(101));
        assert_eq!(h.lookup(2).unwrap(), None);
        assert!(h.delete(1).unwrap());
        assert!(!h.delete(1).unwrap());
        coord.shutdown();
    }

    #[test]
    fn typed_rmw_roundtrip_through_service() {
        let (coord, h) =
            start_native(quick_cfg(), HiveConfig::default().with_buckets(64)).unwrap();
        assert_eq!(h.upsert(5, 50).unwrap(), (InsertOutcome::Inserted, None));
        assert_eq!(h.upsert(5, 51).unwrap(), (InsertOutcome::Replaced, Some(50)));
        assert_eq!(h.insert_if_absent(5, 99).unwrap(), Some(51));
        assert_eq!(h.lookup(5).unwrap(), Some(51), "if-absent overwrote a present key");
        assert_eq!(h.update(6, 60).unwrap(), None);
        assert_eq!(h.lookup(6).unwrap(), None, "update created a key");
        assert_eq!(h.update(5, 52).unwrap(), Some(51));
        assert_eq!(h.cas(5, 99, 0).unwrap(), (false, Some(52)));
        assert_eq!(h.cas(5, 52, 53).unwrap(), (true, Some(52)));
        assert_eq!(h.fetch_add(5, 7).unwrap(), Some(53));
        assert_eq!(h.lookup(5).unwrap(), Some(60));
        assert_eq!(h.fetch_add(7, 3).unwrap(), None, "fetch_add must create absent keys");
        assert_eq!(h.lookup(7).unwrap(), Some(3));
        h.flush().unwrap();
        let s = h.stats().unwrap();
        assert_eq!(s.updates, 1, "{}", s.summary());
        assert_eq!(s.cas_succeeded, 1, "{}", s.summary());
        assert_eq!(s.cas_failed, 1, "{}", s.summary());
        assert_eq!(s.fetch_adds, 2, "{}", s.summary());
        assert!(s.replaced >= 1, "{}", s.summary());
        coord.shutdown();
    }

    #[test]
    fn bulk_submit_reassembles_in_order() {
        use crate::workload::Op;
        let (coord, h) =
            start_native(quick_cfg(), HiveConfig::default().with_buckets(64)).unwrap();
        let inserts: Vec<Op> =
            (1..=500u32).map(|k| Op::Insert { key: k, value: k * 2 }).collect();
        let r = h.submit(&inserts).unwrap();
        assert_eq!(r.len(), 500);
        assert!(r.iter().all(|x| matches!(x, OpResult::Upserted { old: None, .. })));
        let lookups: Vec<Op> = (1..=500u32).map(|k| Op::Lookup { key: k }).collect();
        let r = h.submit(&lookups).unwrap();
        assert_eq!(r.len(), 500);
        for (i, v) in r.iter().enumerate() {
            assert_eq!(
                v.as_value().unwrap(),
                Some((i as u32 + 1) * 2),
                "lookup {i} out of order"
            );
        }
        let deletes: Vec<Op> = (1..=250u32).map(|k| Op::Delete { key: k }).collect();
        let r = h.submit(&deletes).unwrap();
        assert!(r.iter().all(|x| *x == OpResult::Deleted(true)));
        coord.shutdown();
    }

    #[test]
    fn mixed_class_window_keeps_submission_order() {
        let (coord, h) =
            start_native(quick_cfg(), HiveConfig::default().with_buckets(64)).unwrap();
        let ops = vec![
            Op::FetchAdd { key: 1, delta: 5 },
            Op::Upsert { key: 2, value: 20 },
            Op::Lookup { key: 1 },
            Op::Cas { key: 2, expected: 20, new: 21 },
            Op::Delete { key: 3 },
            Op::Lookup { key: 2 },
        ];
        let r = h.submit(&ops).unwrap();
        assert_eq!(r.len(), ops.len());
        assert!(matches!(r[0], OpResult::FetchAdded { old: None, .. }));
        assert!(matches!(r[1], OpResult::Upserted { old: None, .. }));
        assert_eq!(r[2], OpResult::Value(Some(5)), "lookup groups after the fetch-add");
        assert_eq!(r[3], OpResult::Cas { ok: true, actual: Some(20) });
        assert_eq!(r[4], OpResult::Deleted(false));
        assert_eq!(r[5], OpResult::Value(Some(21)), "lookup groups after the cas");
        coord.shutdown();
    }

    #[test]
    fn handle_batch_api_roundtrip() {
        let (coord, h) =
            start_native(quick_cfg(), HiveConfig::default().with_buckets(64)).unwrap();
        let pairs: Vec<(u32, u32)> = (1..=300u32).map(|k| (k, k * 5)).collect();
        let r = h.insert_batch(&pairs).unwrap();
        assert_eq!(r.len(), 300);
        assert!(r.iter().all(|x| matches!(x, OpResult::Upserted { old: None, .. })));
        let keys: Vec<u32> = pairs.iter().map(|&(k, _)| k).collect();
        let vals = h.lookup_batch(&keys).unwrap();
        for (i, v) in vals.iter().enumerate() {
            assert_eq!(*v, Some((i as u32 + 1) * 5), "lookup {i}");
        }
        let hits = h.delete_batch(&keys[..100]).unwrap();
        assert!(hits.iter().all(|&d| d));
        let vals = h.lookup_batch(&keys[..100]).unwrap();
        assert!(vals.iter().all(Option::is_none));
        coord.shutdown();
    }

    #[test]
    fn stats_accumulate_and_service_survives_clients() {
        let (coord, h) =
            start_native(quick_cfg(), HiveConfig::default().with_buckets(64)).unwrap();
        let h2 = h.clone();
        let t = std::thread::spawn(move || {
            for k in 1..=200u32 {
                h2.insert(k, k).unwrap();
            }
        });
        t.join().unwrap();
        h.flush().unwrap();
        let s = h.stats().unwrap();
        assert_eq!(s.ops, 200);
        assert!(s.batches >= 1);
        assert_eq!(s.inserted + s.evicted + s.stashed, 200);
        coord.shutdown();
    }

    #[test]
    fn concurrent_clients_many_threads() {
        let (coord, h) =
            start_native(quick_cfg(), HiveConfig::default().with_buckets(256)).unwrap();
        let threads: Vec<_> = (0..8u32)
            .map(|t| {
                let h = h.clone();
                std::thread::spawn(move || {
                    for i in 0..250 {
                        let k = t * 10_000 + i + 1;
                        h.insert(k, k).unwrap();
                        assert_eq!(h.lookup(k).unwrap(), Some(k));
                    }
                })
            })
            .collect();
        for t in threads {
            t.join().unwrap();
        }
        coord.shutdown();
    }

    #[test]
    fn pipelined_window_keeps_ops_in_flight_and_completes() {
        let (coord, h) =
            start_native(quick_cfg(), HiveConfig::default().with_buckets(256)).unwrap();
        let pipe = h.pipeline(16);
        assert_eq!(pipe.depth(), 16);
        let mut tickets = std::collections::VecDeque::new();
        for k in 1..=400u32 {
            if tickets.len() == 16 {
                let t: crate::coordinator::pipeline::Ticket = tickets.pop_front().unwrap();
                match t.wait().unwrap() {
                    OpResult::Upserted { .. } => {}
                    other => panic!("unexpected reply {other:?}"),
                }
            }
            tickets.push_back(pipe.insert(k, k.wrapping_mul(3)).unwrap());
        }
        for t in tickets {
            t.wait().unwrap();
        }
        assert_eq!(pipe.in_flight(), 0);
        // everything the pipeline acked is visible to the blocking API
        for k in (1..=400u32).step_by(37) {
            assert_eq!(h.lookup(k).unwrap(), Some(k.wrapping_mul(3)));
        }
        coord.shutdown();
    }

    #[test]
    fn pipelined_rmw_tickets_resolve_typed() {
        let (coord, h) =
            start_native(quick_cfg(), HiveConfig::default().with_buckets(64)).unwrap();
        let pipe = h.pipeline(8);
        let t1 = pipe.fetch_add(1, 5).unwrap();
        let created = OpResult::FetchAdded { outcome: Some(InsertOutcome::Inserted), old: None };
        assert_eq!(t1.wait().unwrap(), created);
        let t2 = pipe.cas(1, 5, 6).unwrap();
        assert_eq!(t2.wait().unwrap(), OpResult::Cas { ok: true, actual: Some(5) });
        let t3 = pipe.update(1, 9).unwrap();
        assert_eq!(t3.wait().unwrap(), OpResult::Updated { old: Some(6) });
        let t4 = pipe.insert_if_absent(1, 0).unwrap();
        let present = OpResult::InsertedIfAbsent { outcome: None, existing: Some(9) };
        assert_eq!(t4.wait().unwrap(), present);
        let t5 = pipe.upsert(1, 11).unwrap();
        let replaced = OpResult::Upserted { outcome: InsertOutcome::Replaced, old: Some(9) };
        assert_eq!(t5.wait().unwrap(), replaced);
        coord.shutdown();
    }

    #[test]
    fn dropped_tickets_recycle_slots_and_ops_still_execute() {
        let (coord, h) =
            start_native(quick_cfg(), HiveConfig::default().with_buckets(256)).unwrap();
        let pipe = h.pipeline(4);
        // 64 fire-and-forget inserts through a depth-4 window: reserve
        // must recycle abandoned slots as completions land, or this
        // loop deadlocks (covered by the harness timeout)
        for k in 1..=64u32 {
            let _ = pipe.insert(k, k).unwrap();
        }
        h.flush().unwrap();
        for k in 1..=64u32 {
            assert_eq!(h.lookup(k).unwrap(), Some(k));
        }
        coord.shutdown();
    }

    #[test]
    fn queue_delay_and_latency_recorded_for_both_paths() {
        use crate::workload::Op;
        let (coord, h) =
            start_native(quick_cfg(), HiveConfig::default().with_buckets(64)).unwrap();
        h.insert(1, 1).unwrap(); // single path
        let ops: Vec<Op> = (10..100u32).map(|k| Op::Insert { key: k, value: k }).collect();
        h.submit(&ops).unwrap(); // bulk path
        h.flush().unwrap();
        let s = h.stats().unwrap();
        assert_eq!(s.latency_ns.count(), 91, "1 single + 90 bulk ops must record latency");
        assert_eq!(s.queue_delay_ns.count(), 91, "queue delay must cover both paths");
        assert!(s.inflight_depth.count() >= 2, "both dispatch paths sample depth");
        coord.shutdown();
    }

    #[test]
    fn cache_serves_repeat_lookups_and_stays_coherent() {
        let (coord, h) =
            start_native(quick_cfg(), HiveConfig::default().with_buckets(64)).unwrap();
        assert_eq!(h.insert(1, 100).unwrap(), InsertOutcome::Inserted);
        // first lookup fills, repeats hit
        for _ in 0..5 {
            assert_eq!(h.lookup(1).unwrap(), Some(100));
        }
        let s = h.stats().unwrap();
        assert!(s.cache_hits >= 3, "repeat lookups should hit: {}", s.summary());
        assert!(s.cache_misses >= 1, "first lookup must miss: {}", s.summary());
        // a replace retires the cached copy
        h.insert(1, 200).unwrap();
        assert_eq!(h.lookup(1).unwrap(), Some(200), "stale value served after replace");
        // a delete retires it again
        assert!(h.delete(1).unwrap());
        assert_eq!(h.lookup(1).unwrap(), None, "deleted key resurrected by the cache");
        let s = h.stats().unwrap();
        assert!(s.cache_invalidations >= 2, "writes must invalidate: {}", s.summary());
        coord.shutdown();
    }

    #[test]
    fn cache_disabled_when_capacity_zero() {
        let cfg = CoordinatorConfig { cache_capacity: 0, ..quick_cfg() };
        let (coord, h) = start_native(cfg, HiveConfig::default().with_buckets(64)).unwrap();
        h.insert(7, 70).unwrap();
        for _ in 0..5 {
            assert_eq!(h.lookup(7).unwrap(), Some(70));
        }
        let s = h.stats().unwrap();
        assert_eq!(s.cache_hits + s.cache_misses, 0, "disabled cache saw traffic");
        coord.shutdown();
    }

    #[test]
    fn window_with_write_conflict_matches_uncached_semantics() {
        use crate::workload::Op;
        // one worker so the whole window lands on one shard
        let cfg = CoordinatorConfig { workers: 1, ..quick_cfg() };
        let (coord, h) = start_native(cfg, HiveConfig::default().with_buckets(64)).unwrap();
        h.insert(5, 50).unwrap();
        assert_eq!(h.lookup(5).unwrap(), Some(50)); // now cached
        // window deletes 5 and looks it up: grouped execution (writes
        // before lookups) must observe the delete, not the cached copy
        let r = h.submit(&[Op::Delete { key: 5 }, Op::Lookup { key: 5 }]).unwrap();
        assert_eq!(r[0], OpResult::Deleted(true));
        assert_eq!(r[1], OpResult::Value(None), "cache leaked a pre-window value");
        // and a window that writes-then-reads sees the fresh value,
        // for the RMW classes too
        let r = h.submit(&[Op::Insert { key: 5, value: 55 }, Op::Lookup { key: 5 }]).unwrap();
        assert_eq!(r[1], OpResult::Value(Some(55)));
        let r = h.submit(&[Op::FetchAdd { key: 5, delta: 5 }, Op::Lookup { key: 5 }]).unwrap();
        assert_eq!(r[0], OpResult::FetchAdded { outcome: None, old: Some(55) });
        assert_eq!(r[1], OpResult::Value(Some(60)), "cache leaked across a fetch-add");
        coord.shutdown();
    }

    #[test]
    fn resize_controller_grows_under_load() {
        let cfg = CoordinatorConfig {
            workers: 1,
            batch: BatchPolicy { max_batch: 128, deadline: Duration::from_micros(50) },
            resize_check_every: 1,
            cache_capacity: 256,
            ring_capacity: 256,
        };
        let (coord, h) = start_native(cfg, HiveConfig::default().with_buckets(4)).unwrap();
        use crate::workload::Op;
        let ops: Vec<Op> = (1..=1000u32).map(|k| Op::Insert { key: k, value: k }).collect();
        for chunk in ops.chunks(100) {
            h.submit(chunk).unwrap();
        }
        let s = h.stats().unwrap();
        assert!(s.grows > 0, "expected resize under load: {}", s.summary());
        // all keys still present
        let lookups: Vec<Op> = (1..=1000u32).map(|k| Op::Lookup { key: k }).collect();
        let r = h.submit(&lookups).unwrap();
        assert!(r.iter().all(|v| matches!(v, OpResult::Value(Some(_)))));
        coord.shutdown();
    }

    #[test]
    fn sharded_start_roundtrips_across_plans() {
        let plan = ShardPlan { partitions_per_shard: 8, placement: Placement::None };
        let (coord, h) =
            start_native_sharded(quick_cfg(), plan, HiveConfig::default().with_buckets(64))
                .unwrap();
        assert_eq!(h.shards(), 2);
        assert_eq!(h.partitions(), 16);
        for k in 1..=300u32 {
            h.insert(k, k + 7).unwrap();
        }
        for k in 1..=300u32 {
            assert_eq!(h.lookup(k).unwrap(), Some(k + 7));
        }
        coord.shutdown();
    }

    #[test]
    fn reshard_moves_partitions_online_and_preserves_data() {
        let (coord, h) =
            start_native(quick_cfg(), HiveConfig::default().with_buckets(64)).unwrap();
        for k in 1..=500u32 {
            h.insert(k, k.wrapping_mul(3)).unwrap();
        }
        // sweep every partition onto shard 0, then spread them back
        for p in 0..h.partitions() as u32 {
            h.reshard(p, 0).unwrap();
        }
        for k in 1..=500u32 {
            assert_eq!(h.lookup(k).unwrap(), Some(k.wrapping_mul(3)), "key {k} lost moving in");
        }
        for p in 0..h.partitions() as u32 {
            h.reshard(p, p as usize % h.shards()).unwrap();
        }
        for k in 1..=500u32 {
            assert_eq!(h.lookup(k).unwrap(), Some(k.wrapping_mul(3)), "key {k} lost moving out");
        }
        h.flush().unwrap();
        let s = h.stats().unwrap();
        assert!(s.moves_completed >= 1, "{}", s.summary());
        assert!(s.keys_migrated > 0, "{}", s.summary());
        assert_eq!(s.moves_started, s.moves_completed, "{}", s.summary());
        coord.shutdown();
    }

    #[test]
    fn reshard_rejects_bad_arguments_and_factory_planes() {
        let (coord, h) =
            start_native(quick_cfg(), HiveConfig::default().with_buckets(64)).unwrap();
        assert!(h.reshard(u32::MAX, 0).is_err(), "out-of-range partition accepted");
        assert!(h.reshard(0, 99).is_err(), "out-of-range shard accepted");
        coord.shutdown();
        // factory-built coordinators have no table plane: cross-shard
        // moves must refuse rather than silently flip the directory
        let (coord, h) = Coordinator::start(quick_cfg(), |_w| {
            Ok(Box::new(crate::backend::NativeBackend::new(
                HiveConfig::default().with_buckets(64),
            )?) as Box<dyn Backend>)
        })
        .unwrap();
        let p = (0..h.partitions() as u32)
            .find(|&p| h.shard_of(p) != 1)
            .expect("some partition lives off shard 1");
        let err = h.reshard(p, 1).unwrap_err();
        assert!(matches!(err, HiveError::Config(_)), "got {err:?}");
        coord.shutdown();
    }

    #[test]
    fn misrouted_requests_are_forwarded_to_their_owner() {
        let (coord, h) =
            start_native(quick_cfg(), HiveConfig::default().with_buckets(64)).unwrap();
        h.insert(42, 1).unwrap();
        let owner = h.route(42);
        let wrong = (owner + 1) % h.shards();
        // inject directly into the wrong worker's ring, as a client
        // holding a stale routing decision across a directory flip would
        let (ticket, done) = pipeline::one_shot();
        h.senders[wrong]
            .send(Request::Single { op: Op::Lookup { key: 42 }, enqueued: Instant::now(), done })
            .map_err(|_| HiveError::Shutdown)
            .unwrap();
        assert_eq!(ticket.wait().unwrap(), OpResult::Value(Some(1)));
        h.flush().unwrap();
        let s = h.stats().unwrap();
        assert_eq!(s.forwarded, 1, "{}", s.summary());
        coord.shutdown();
    }

    #[test]
    fn per_shard_stats_sum_to_the_aggregate() {
        let (coord, h) =
            start_native(quick_cfg(), HiveConfig::default().with_buckets(64)).unwrap();
        for k in 1..=200u32 {
            h.insert(k, k).unwrap();
        }
        h.flush().unwrap();
        let per = h.stats_per_shard().unwrap();
        assert_eq!(per.len(), h.shards());
        assert!(per.iter().all(|s| s.ops > 0), "both shards saw traffic");
        let agg = h.stats().unwrap();
        assert_eq!(per.iter().map(|s| s.ops).sum::<u64>(), agg.ops);
        assert_eq!(per.iter().map(|s| s.batches).sum::<u64>(), agg.batches);
        coord.shutdown();
    }

    #[test]
    fn ops_race_a_live_reshard_without_loss() {
        let cfg = CoordinatorConfig {
            workers: 2,
            batch: BatchPolicy { max_batch: 64, deadline: Duration::from_micros(100) },
            resize_check_every: 2,
            cache_capacity: 256,
            ring_capacity: 256,
        };
        let (coord, h) =
            start_native(cfg, HiveConfig::default().with_buckets(128)).unwrap();
        for k in 1..=2000u32 {
            h.insert(k, k).unwrap();
        }
        let stop = Arc::new(std::sync::atomic::AtomicBool::new(false));
        let writers: Vec<_> = (0..2u32)
            .map(|t| {
                let h = h.clone();
                let stop = Arc::clone(&stop);
                std::thread::spawn(move || {
                    let mut i = 0u32;
                    while !stop.load(std::sync::atomic::Ordering::Relaxed) {
                        let k = (i % 2000) + 1;
                        if t == 0 {
                            h.upsert(k, k + 1).unwrap();
                        } else {
                            assert!(h.lookup(k).unwrap().is_some(), "key {k} vanished mid-move");
                        }
                        i = i.wrapping_add(1);
                    }
                })
            })
            .collect();
        // cycle every partition across both shards while traffic runs
        for round in 0..2usize {
            for p in 0..h.partitions() as u32 {
                h.reshard(p, (p as usize + round + 1) % h.shards()).unwrap();
            }
        }
        stop.store(true, std::sync::atomic::Ordering::Relaxed);
        for w in writers {
            w.join().unwrap();
        }
        for k in 1..=2000u32 {
            let v = h.lookup(k).unwrap();
            assert!(v == Some(k) || v == Some(k + 1), "key {k} has foreign value {v:?}");
        }
        let s = h.stats().unwrap();
        assert!(s.moves_completed > 0, "{}", s.summary());
        coord.shutdown();
    }
}
