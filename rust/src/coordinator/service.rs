//! The coordinator service: worker pool, request router, and the
//! per-worker dispatch loop (batcher + backend + resize controller).
//!
//! Requests enter through the pipelined plane (`coordinator::pipeline`):
//! every worker owns a bounded MPSC submission ring which it drains
//! directly into its batcher, and single-op requests complete through
//! ticket/completion slots — one condvar publish per dispatch window
//! instead of one channel wakeup per op. The blocking `Handle` API is a
//! window-of-1 pipeline over the same plane.
//!
//! Replies are typed end-to-end: every request — blocking single,
//! pipelined ticket, or bulk shard — resolves to the [`OpResult`] its
//! [`Op`] produced, in submission order. The old reply enum collapsed
//! insert outcomes to a `bool` and segregated results by type; the typed
//! plane carries previous values, CAS verdicts and the full four-step
//! [`InsertOutcome`] attribution all the way to the client (and into
//! [`ServiceStats`]).

use crate::backend::Backend;
use crate::coordinator::batcher::{BatchPolicy, Batcher};
use crate::coordinator::cache::HotKeyCache;
use crate::coordinator::pipeline::{self, CompletionSlot, Pipeline, RingRx, RingTx};
use crate::coordinator::stats::ServiceStats;
use crate::core::error::{HiveError, Result};
use crate::hash::HashKind;
use crate::native::resize::ResizeEvent;
use crate::native::table::InsertOutcome;
use crate::workload::{Op, OpResult};
use std::collections::HashMap;
use std::sync::mpsc::{sync_channel, RecvTimeoutError, Sender, SyncSender};
use std::sync::{mpsc, Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Coordinator configuration.
#[derive(Debug, Clone)]
pub struct CoordinatorConfig {
    /// Worker (shard) count.
    pub workers: usize,
    /// Dynamic batching policy per worker.
    pub batch: BatchPolicy,
    /// Run the resize controller every N dispatch windows.
    pub resize_check_every: u64,
    /// Per-worker hot-key cache entries (`0` disables the cache). Only
    /// backends that produce a coherence stamp get a cache; the rest
    /// execute every lookup. Cached results are observationally
    /// identical to uncached ones — lookups whose key is written in the
    /// same window bypass the cache, so every window linearizes exactly
    /// as the backend's grouped execution does.
    pub cache_capacity: usize,
    /// Per-worker submission ring capacity: the maximum number of
    /// requests queued ahead of a worker before senders block
    /// (backpressure toward the clients). Bounds memory and queue delay
    /// under overload.
    pub ring_capacity: usize,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        CoordinatorConfig {
            workers: 4,
            batch: BatchPolicy::default(),
            resize_check_every: 8,
            cache_capacity: 4096,
            ring_capacity: 4096,
        }
    }
}

enum Request {
    /// One single-key op; completes through its ticket's slot (with the
    /// op's typed [`OpResult`]) when the dispatch window it joins
    /// executes.
    Single { op: Op, enqueued: Instant, done: CompletionSlot },
    /// One pre-sharded bulk window; the reply is tagged with the worker
    /// index so the submitter can gather shards in arrival order.
    Bulk { ops: Vec<Op>, enqueued: Instant, reply: Sender<(usize, Result<Vec<OpResult>>)> },
    Stats { reply: SyncSender<ServiceStats> },
    Flush { reply: SyncSender<()> },
    Shutdown,
}

/// The running service. Dropping it (or calling [`Coordinator::shutdown`])
/// joins all workers.
pub struct Coordinator {
    senders: Vec<RingTx<Request>>,
    handles: Vec<JoinHandle<()>>,
}

/// Clone-able client handle.
#[derive(Clone)]
pub struct Handle {
    senders: Arc<Vec<RingTx<Request>>>,
}

impl Coordinator {
    /// Start the service: `factory(worker_index)` builds each worker's
    /// backend (one table shard per worker). The factory runs *inside*
    /// each worker thread — required because the XLA backend's PJRT
    /// client is not `Send`.
    pub fn start<F>(cfg: CoordinatorConfig, factory: F) -> Result<(Coordinator, Handle)>
    where
        F: Fn(usize) -> Result<Box<dyn Backend>> + Send + Sync + 'static,
    {
        assert!(cfg.workers >= 1);
        let factory = Arc::new(factory);
        let mut senders = Vec::with_capacity(cfg.workers);
        let mut handles = Vec::with_capacity(cfg.workers);
        for w in 0..cfg.workers {
            let (tx, rx) = pipeline::ring::<Request>(cfg.ring_capacity.max(1));
            let (ready_tx, ready_rx) = sync_channel::<Result<()>>(1);
            let cfg_w = cfg.clone();
            let factory = Arc::clone(&factory);
            handles.push(
                std::thread::Builder::new()
                    .name(format!("hive-worker-{w}"))
                    .spawn(move || match factory(w) {
                        Ok(backend) => {
                            let _ = ready_tx.send(Ok(()));
                            worker_loop(w, rx, backend, cfg_w);
                        }
                        Err(e) => {
                            let _ = ready_tx.send(Err(e));
                        }
                    })
                    .expect("spawn worker"),
            );
            ready_rx.recv().map_err(|_| HiveError::Shutdown)??;
            senders.push(tx);
        }
        let handle = Handle { senders: Arc::new(senders.clone()) };
        Ok((Coordinator { senders, handles }, handle))
    }

    /// Stop all workers and join them. Requests still queued behind the
    /// shutdown marker (and ops in flight on a dead worker) complete
    /// with [`HiveError::Shutdown`] — blocked callers never hang.
    pub fn shutdown(mut self) {
        for tx in &self.senders {
            let _ = tx.send(Request::Shutdown);
        }
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
        self.senders.clear();
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        for tx in &self.senders {
            let _ = tx.send(Request::Shutdown);
        }
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

impl Handle {
    /// Worker shard for `key` (murmur routing — independent of the
    /// table's own bucket hashes so shards stay balanced).
    #[inline]
    fn route(&self, key: u32) -> usize {
        (HashKind::Murmur3.hash(key ^ 0x9E3779B9) as usize) % self.senders.len()
    }

    /// Open a pipelined session over this handle: up to `depth`
    /// single-key ops in flight at once, completing out of band via
    /// [`crate::coordinator::pipeline::Ticket`]s.
    pub fn pipeline(&self, depth: usize) -> Pipeline {
        Pipeline::new(self.clone(), depth)
    }

    /// Route and submit one pipelined single op (the `Pipeline`
    /// submission path).
    pub(crate) fn send_single(&self, op: Op, done: CompletionSlot) -> Result<()> {
        self.senders[self.route(op.key())]
            .send(Request::Single { op, enqueued: Instant::now(), done })
            .map_err(|_| HiveError::Shutdown)
    }

    /// Blocking single op — a window-of-1 pipeline: reserve one
    /// completion slot, submit, wait the ticket for the typed result.
    fn single(&self, op: Op) -> Result<OpResult> {
        let (ticket, done) = pipeline::one_shot();
        self.senders[self.route(op.key())]
            .send(Request::Single { op, enqueued: Instant::now(), done })
            .map_err(|_| HiveError::Shutdown)?;
        ticket.wait()
    }

    fn unexpected(op: &str, got: OpResult) -> HiveError {
        HiveError::Runtime(format!("unexpected reply to {op}: {got:?}"))
    }

    /// Insert or replace `key → value`, reporting which four-step path
    /// placed it (the lossy `bool` of the pre-typed plane is gone).
    pub fn insert(&self, key: u32, value: u32) -> Result<InsertOutcome> {
        match self.single(Op::Insert { key, value })? {
            OpResult::Upserted { outcome, .. } => Ok(outcome),
            other => Err(Self::unexpected("insert", other)),
        }
    }

    /// Insert or replace, returning the placement outcome and previous
    /// value.
    pub fn upsert(&self, key: u32, value: u32) -> Result<(InsertOutcome, Option<u32>)> {
        match self.single(Op::Upsert { key, value })? {
            OpResult::Upserted { outcome, old } => Ok((outcome, old)),
            other => Err(Self::unexpected("upsert", other)),
        }
    }

    /// Insert only if absent; returns the existing value when present
    /// (`None` ⇒ this call inserted).
    pub fn insert_if_absent(&self, key: u32, value: u32) -> Result<Option<u32>> {
        match self.single(Op::InsertIfAbsent { key, value })? {
            OpResult::InsertedIfAbsent { existing, .. } => Ok(existing),
            other => Err(Self::unexpected("insert_if_absent", other)),
        }
    }

    /// Replace only if present; returns the previous value (`None` ⇒
    /// absent, nothing written).
    pub fn update(&self, key: u32, value: u32) -> Result<Option<u32>> {
        match self.single(Op::Update { key, value })? {
            OpResult::Updated { old } => Ok(old),
            other => Err(Self::unexpected("update", other)),
        }
    }

    /// Compare-and-swap: write `new` iff the current value equals
    /// `expected`. Returns `(ok, actual)`.
    pub fn cas(&self, key: u32, expected: u32, new: u32) -> Result<(bool, Option<u32>)> {
        match self.single(Op::Cas { key, expected, new })? {
            OpResult::Cas { ok, actual } => Ok((ok, actual)),
            other => Err(Self::unexpected("cas", other)),
        }
    }

    /// Add `delta` (wrapping) to the value of `key`, creating it at
    /// `delta` when absent. Returns the pre-add value (`None` ⇒ created).
    pub fn fetch_add(&self, key: u32, delta: u32) -> Result<Option<u32>> {
        match self.single(Op::FetchAdd { key, delta })? {
            OpResult::FetchAdded { old, .. } => Ok(old),
            other => Err(Self::unexpected("fetch_add", other)),
        }
    }

    /// Point lookup.
    pub fn lookup(&self, key: u32) -> Result<Option<u32>> {
        match self.single(Op::Lookup { key })? {
            OpResult::Value(v) => Ok(v),
            other => Err(Self::unexpected("lookup", other)),
        }
    }

    /// Delete `key`.
    pub fn delete(&self, key: u32) -> Result<bool> {
        match self.single(Op::Delete { key })? {
            OpResult::Deleted(hit) => Ok(hit),
            other => Err(Self::unexpected("delete", other)),
        }
    }

    /// Bulk insert/replace: shards by key and rides the workers' batched
    /// backend path (one epoch pin per shard window instead of one per
    /// op). One [`OpResult::Upserted`] per pair, in submission order.
    pub fn insert_batch(&self, pairs: &[(u32, u32)]) -> Result<Vec<OpResult>> {
        let ops: Vec<Op> =
            pairs.iter().map(|&(key, value)| Op::Insert { key, value }).collect();
        self.submit(&ops)
    }

    /// Bulk lookup in submission order, via the batched backend path.
    pub fn lookup_batch(&self, keys: &[u32]) -> Result<Vec<Option<u32>>> {
        let ops: Vec<Op> = keys.iter().map(|&key| Op::Lookup { key }).collect();
        Ok(self
            .submit(&ops)?
            .into_iter()
            .map(|r| r.as_value().expect("lookup op yields Value"))
            .collect())
    }

    /// Bulk delete in submission order, via the batched backend path.
    pub fn delete_batch(&self, keys: &[u32]) -> Result<Vec<bool>> {
        let ops: Vec<Op> = keys.iter().map(|&key| Op::Delete { key }).collect();
        Ok(self
            .submit(&ops)?
            .into_iter()
            .map(|r| r.as_deleted().expect("delete op yields Deleted"))
            .collect())
    }

    /// Submit a pre-batched workload: ops are sharded by key, executed on
    /// all workers, and the typed results are reassembled **in
    /// submission order** — one [`OpResult`] per op, whatever mix of
    /// classes the window carries.
    ///
    /// Shards are scattered up front and gathered in *arrival order*
    /// over one shared reply channel — a slow shard no longer blocks
    /// collection of the fast ones.
    pub fn submit(&self, ops: &[Op]) -> Result<Vec<OpResult>> {
        let w = self.senders.len();
        let mut shards: Vec<Vec<Op>> = vec![Vec::new(); w];
        let mut route_of: Vec<usize> = Vec::with_capacity(ops.len());
        for op in ops {
            let r = self.route(op.key());
            shards[r].push(*op);
            route_of.push(r);
        }
        let (tx, rx) = mpsc::channel::<(usize, Result<Vec<OpResult>>)>();
        let enqueued = Instant::now();
        let mut expected = 0usize;
        for (i, shard) in shards.into_iter().enumerate() {
            if shard.is_empty() {
                continue;
            }
            self.senders[i]
                .send(Request::Bulk { ops: shard, enqueued, reply: tx.clone() })
                .map_err(|_| HiveError::Shutdown)?;
            expected += 1;
        }
        drop(tx);
        let mut partials: Vec<Option<Vec<OpResult>>> = vec![None; w];
        for _ in 0..expected {
            let (i, res) = rx.recv().map_err(|_| HiveError::Shutdown)?;
            partials[i] = Some(res?);
        }
        // Reassemble in original submission order: each shard executed
        // its sub-window in shard-submission order, so one cursor per
        // shard walks every result exactly once.
        let mut cursor = vec![0usize; w];
        let mut merged = Vec::with_capacity(ops.len());
        for &r in &route_of {
            let p = partials[r].as_ref().expect("shard result");
            merged.push(p[cursor[r]]);
            cursor[r] += 1;
        }
        Ok(merged)
    }

    /// Aggregate service stats across workers: scatter the request to
    /// every worker first, then gather, so one slow worker doesn't
    /// serialize the round-trips of the rest.
    pub fn stats(&self) -> Result<ServiceStats> {
        let mut rxs = Vec::with_capacity(self.senders.len());
        for tx in self.senders.iter() {
            let (rtx, rrx) = sync_channel(1);
            tx.send(Request::Stats { reply: rtx }).map_err(|_| HiveError::Shutdown)?;
            rxs.push(rrx);
        }
        let mut agg = ServiceStats::default();
        for rrx in rxs {
            agg.merge(&rrx.recv().map_err(|_| HiveError::Shutdown)?);
        }
        Ok(agg)
    }

    /// Flush all pending windows (barrier; used by tests/benches).
    /// Scatter-then-gather like [`Handle::stats`].
    pub fn flush(&self) -> Result<()> {
        let mut rxs = Vec::with_capacity(self.senders.len());
        for tx in self.senders.iter() {
            let (rtx, rrx) = sync_channel(1);
            tx.send(Request::Flush { reply: rtx }).map_err(|_| HiveError::Shutdown)?;
            rxs.push(rrx);
        }
        for rrx in rxs {
            rrx.recv().map_err(|_| HiveError::Shutdown)?;
        }
        Ok(())
    }
}

/// One worker: owns a backend shard and the hot-key cache in front of
/// it, batches singles, executes bulks, runs the resize controller
/// between windows.
struct Worker {
    backend: Box<dyn Backend>,
    batcher: Batcher,
    /// Waiting singles, 1:1 (and in order) with the batcher's pending
    /// window — the typed results zip straight back onto the slots.
    waiting: Vec<(Instant, CompletionSlot)>,
    stats: ServiceStats,
    /// Read-through hot-key cache; `None` when disabled by config or
    /// when the backend cannot produce a coherence stamp.
    cache: Option<HotKeyCache>,
    cfg: CoordinatorConfig,
}

impl Worker {
    /// Execute one dispatch window through the cache + backend stack:
    /// wholesale-validate the cache against the backend's coherence
    /// stamp, serve lookup hits without touching the backend, execute
    /// the remainder, retire the window's written keys from the cache,
    /// then refill from results whose post-window value is knowable.
    ///
    /// Lookups whose key is *written in the same window* never consult
    /// the cache: the backend groups write classes before lookups, so
    /// serving such a lookup from the cache would observe the pre-window
    /// value where the uncached path observes the post-write one. Every
    /// op class except `Lookup` counts as a write here — `Cas` and
    /// `Update` may decline, but conservative bypass is always
    /// observationally identical to the uncached path (which the
    /// cross-path differential in `tests/test_cache.rs` pins down).
    ///
    /// Refill policy: backend lookup results always refill (they are
    /// post-window values). Of the write classes, an applied `Cas`
    /// (known new value) and an applied `Update` refill — but only when
    /// theirs is the window's *only* write to that key, otherwise a
    /// later class (e.g. a fetch-add grouped after the CAS) already
    /// moved the value past what the result shows.
    fn execute_window(&mut self, ops: &[Op]) -> Result<Vec<OpResult>> {
        self.stats.batches += 1;
        self.stats.ops += ops.len() as u64;
        self.stats.batch_sizes.record(ops.len() as u64);
        let Some(cache) = self.cache.as_mut() else {
            return self.backend.execute(ops);
        };
        let stamp = self.backend.coherence_stamp().expect("cached backend lost its stamp");
        if !cache.validate(stamp) {
            self.stats.cache_flushes += 1;
        }
        // Write-only window: nothing to serve, and refill would need the
        // written-once bookkeeping below for no benefit — execute and
        // retire the written keys' cached copies directly.
        if !ops.iter().any(|op| matches!(op, Op::Lookup { .. })) {
            let res = self.backend.execute(ops)?;
            for op in ops {
                if cache.invalidate(op.key()) {
                    self.stats.cache_invalidations += 1;
                }
            }
            return Ok(res);
        }
        // Writes per key: conflict bypass for same-window lookups and
        // the written-once guard for the refill pass.
        let mut writes: HashMap<u32, u32> = HashMap::new();
        for op in ops {
            if op.is_write() {
                *writes.entry(op.key()).or_default() += 1;
            }
        }
        // Serve lookup hits out of the cache; everything else (writes,
        // misses, write-conflicting lookups) goes to the backend.
        let mut slots: Vec<Option<OpResult>> = vec![None; ops.len()];
        let mut backend_ops: Vec<Op> = Vec::with_capacity(ops.len());
        let mut backend_idx: Vec<usize> = Vec::with_capacity(ops.len());
        for (i, op) in ops.iter().enumerate() {
            if let Op::Lookup { key } = *op {
                // write-conflicted lookups bypass the cache without
                // touching the hit/miss counters: they never consult it,
                // and counting them as misses would understate the hit
                // rate fig10 publishes
                if !writes.contains_key(&key) {
                    match cache.get(key) {
                        Some(v) => {
                            self.stats.cache_hits += 1;
                            slots[i] = Some(OpResult::Value(Some(v)));
                            continue;
                        }
                        None => self.stats.cache_misses += 1,
                    }
                }
            }
            backend_idx.push(i);
            backend_ops.push(*op);
        }
        let backend_res = if backend_ops.is_empty() {
            Vec::new()
        } else {
            self.backend.execute(&backend_ops)?
        };
        // Per-key invalidation: the window's writes retire cached copies
        // before any result is published.
        for key in writes.keys() {
            if cache.invalidate(*key) {
                self.stats.cache_invalidations += 1;
            }
        }
        // Scatter backend results into submission order and refill the
        // cache. Lookup values are post-window (write classes group
        // first); write-class refills obey the written-once guard.
        // Misses are never cached: absent keys churn fastest under
        // skewed delete/re-insert traffic.
        for (&i, res) in backend_idx.iter().zip(backend_res) {
            match (ops[i], res) {
                (Op::Lookup { key }, OpResult::Value(Some(v))) => cache.put(key, v),
                (Op::Cas { key, new, .. }, OpResult::Cas { ok: true, .. })
                    if writes.get(&key) == Some(&1) =>
                {
                    cache.put(key, new);
                }
                (Op::Update { key, value }, OpResult::Updated { old: Some(_) })
                    if writes.get(&key) == Some(&1) =>
                {
                    cache.put(key, value);
                }
                _ => {}
            }
            slots[i] = Some(res);
        }
        Ok(slots.into_iter().map(|r| r.expect("one result per op")).collect())
    }

    /// Flush the pending single-op window and publish every waiter's
    /// typed result in one batch — one wakeup per client window, not one
    /// per op. `backlog` is the submission-ring depth at dispatch time,
    /// folded into the in-flight depth stat.
    fn dispatch(&mut self, backlog: usize) {
        if self.batcher.is_empty() {
            return;
        }
        let ops = self.batcher.take();
        let started = Instant::now();
        self.stats.inflight_depth.record((self.waiting.len() + backlog) as u64);
        for (enq, _) in &self.waiting {
            self.stats
                .queue_delay_ns
                .record(started.saturating_duration_since(*enq).as_nanos() as u64);
        }
        match self.execute_window(&ops) {
            Ok(results) => {
                debug_assert_eq!(results.len(), self.waiting.len(), "one result per waiter");
                self.stats.record_results(&results);
                // completions in submission order, published as one batch
                let mut completions = Vec::with_capacity(self.waiting.len());
                for ((enq, done), res) in self.waiting.drain(..).zip(results) {
                    self.stats.latency_ns.record(enq.elapsed().as_nanos() as u64);
                    completions.push((done, Ok(res)));
                }
                pipeline::publish_batch(completions);
            }
            Err(e) => {
                let mut completions = Vec::with_capacity(self.waiting.len());
                for (_, done) in self.waiting.drain(..) {
                    completions.push((done, Err(e.clone())));
                }
                pipeline::publish_batch(completions);
            }
        }
        self.check_resize();
    }

    /// Resize controller between windows. The call still runs a full
    /// K-bucket migration batch synchronously on this worker thread,
    /// but with the epoch scheme other threads' operations (and other
    /// shards) proceed concurrently instead of blocking on a write
    /// guard. A resize that drains the stash or swaps the state pointer
    /// moves the coherence stamp, so the next window's wholesale
    /// validation flushes the cache.
    fn check_resize(&mut self) {
        if self.stats.batches % self.cfg.resize_check_every != 0 {
            return;
        }
        match self.backend.maybe_resize() {
            Ok(Some(ResizeEvent::Grew { .. })) => self.stats.grows += 1,
            Ok(Some(ResizeEvent::Shrank { .. })) => self.stats.shrinks += 1,
            _ => {}
        }
    }
}

fn worker_loop(
    index: usize,
    rx: RingRx<Request>,
    backend: Box<dyn Backend>,
    cfg: CoordinatorConfig,
) {
    let cache = if cfg.cache_capacity > 0 {
        backend.coherence_stamp().map(|s| HotKeyCache::new(cfg.cache_capacity, s))
    } else {
        None
    };
    let mut w = Worker {
        batcher: Batcher::new(cfg.batch),
        waiting: Vec::new(),
        stats: ServiceStats::default(),
        backend,
        cache,
        cfg,
    };
    loop {
        // Drain the ring straight into the batcher: only sleep on the
        // dispatch deadline when no request is immediately available.
        let req = match rx.try_recv() {
            Some(r) => r,
            None => {
                if w.batcher.deadline_expired() {
                    w.dispatch(rx.backlog());
                    continue;
                }
                let timeout = w.batcher.time_to_deadline().unwrap_or(Duration::from_millis(50));
                match rx.recv_timeout(timeout) {
                    Ok(r) => r,
                    Err(RecvTimeoutError::Timeout) => {
                        if w.batcher.deadline_expired() {
                            w.dispatch(rx.backlog());
                        }
                        continue;
                    }
                    Err(RecvTimeoutError::Disconnected) => break,
                }
            }
        };
        match req {
            Request::Single { op, enqueued, done } => {
                w.waiting.push((enqueued, done));
                // The window's deadline runs from the op's submission,
                // so ring backlog counts against it. An expired window
                // is NOT dispatched mid-drain: it ships at the next
                // instant the ring is momentarily empty (the try_recv
                // None path above) or at max_batch, whichever is first.
                // That bounds deadline overshoot to the in-hand backlog
                // while keeping the batch amortization the plane exists
                // for — dispatching per-op on an aged backlog would
                // collapse every window to size 1 exactly under
                // overload.
                if w.batcher.push_at(op, enqueued) {
                    w.dispatch(rx.backlog());
                }
            }
            Request::Bulk { ops, enqueued, reply } => {
                // flush pending singles first to preserve window ordering
                w.dispatch(rx.backlog());
                let started = Instant::now();
                w.stats.queue_delay_ns.record_n(
                    started.saturating_duration_since(enqueued).as_nanos() as u64,
                    ops.len() as u64,
                );
                w.stats.inflight_depth.record((ops.len() + rx.backlog()) as u64);
                let res = w.execute_window(&ops);
                if let Ok(res) = &res {
                    w.stats.record_results(res);
                    w.stats
                        .latency_ns
                        .record_n(enqueued.elapsed().as_nanos() as u64, ops.len() as u64);
                }
                let _ = reply.send((index, res));
                w.check_resize();
            }
            Request::Stats { reply } => {
                let _ = reply.send(w.stats.clone());
            }
            Request::Flush { reply } => {
                w.dispatch(rx.backlog());
                let _ = reply.send(());
            }
            Request::Shutdown => {
                w.dispatch(rx.backlog());
                break;
            }
        }
    }
    // `rx` drops here: any request still queued behind the shutdown
    // marker is drained and its completion slot / reply channel fires
    // with `Shutdown` — same for `w.waiting` if the thread unwinds.
}

/// Shared-state convenience: a coordinator whose workers all use native
/// backends over table shards sized by `cfg`.
pub fn start_native(
    coord_cfg: CoordinatorConfig,
    table_cfg: crate::core::config::HiveConfig,
) -> Result<(Coordinator, Handle)> {
    let table_cfg = Arc::new(Mutex::new(table_cfg));
    Coordinator::start(coord_cfg, move |_w| {
        let cfg = table_cfg.lock().unwrap().clone();
        Ok(Box::new(crate::backend::NativeBackend::new(cfg)?) as Box<dyn Backend>)
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::config::HiveConfig;

    fn quick_cfg() -> CoordinatorConfig {
        CoordinatorConfig {
            workers: 2,
            batch: BatchPolicy { max_batch: 64, deadline: Duration::from_micros(100) },
            resize_check_every: 2,
            cache_capacity: 256,
            ring_capacity: 256,
        }
    }

    #[test]
    fn single_op_roundtrip() {
        let (coord, h) =
            start_native(quick_cfg(), HiveConfig::default().with_buckets(64)).unwrap();
        assert_eq!(h.insert(1, 100).unwrap(), InsertOutcome::Inserted);
        assert_eq!(h.insert(1, 101).unwrap(), InsertOutcome::Replaced);
        assert_eq!(h.lookup(1).unwrap(), Some(101));
        assert_eq!(h.lookup(2).unwrap(), None);
        assert!(h.delete(1).unwrap());
        assert!(!h.delete(1).unwrap());
        coord.shutdown();
    }

    #[test]
    fn typed_rmw_roundtrip_through_service() {
        let (coord, h) =
            start_native(quick_cfg(), HiveConfig::default().with_buckets(64)).unwrap();
        assert_eq!(h.upsert(5, 50).unwrap(), (InsertOutcome::Inserted, None));
        assert_eq!(h.upsert(5, 51).unwrap(), (InsertOutcome::Replaced, Some(50)));
        assert_eq!(h.insert_if_absent(5, 99).unwrap(), Some(51));
        assert_eq!(h.lookup(5).unwrap(), Some(51), "if-absent overwrote a present key");
        assert_eq!(h.update(6, 60).unwrap(), None);
        assert_eq!(h.lookup(6).unwrap(), None, "update created a key");
        assert_eq!(h.update(5, 52).unwrap(), Some(51));
        assert_eq!(h.cas(5, 99, 0).unwrap(), (false, Some(52)));
        assert_eq!(h.cas(5, 52, 53).unwrap(), (true, Some(52)));
        assert_eq!(h.fetch_add(5, 7).unwrap(), Some(53));
        assert_eq!(h.lookup(5).unwrap(), Some(60));
        assert_eq!(h.fetch_add(7, 3).unwrap(), None, "fetch_add must create absent keys");
        assert_eq!(h.lookup(7).unwrap(), Some(3));
        h.flush().unwrap();
        let s = h.stats().unwrap();
        assert_eq!(s.updates, 1, "{}", s.summary());
        assert_eq!(s.cas_succeeded, 1, "{}", s.summary());
        assert_eq!(s.cas_failed, 1, "{}", s.summary());
        assert_eq!(s.fetch_adds, 2, "{}", s.summary());
        assert!(s.replaced >= 1, "{}", s.summary());
        coord.shutdown();
    }

    #[test]
    fn bulk_submit_reassembles_in_order() {
        use crate::workload::Op;
        let (coord, h) =
            start_native(quick_cfg(), HiveConfig::default().with_buckets(64)).unwrap();
        let inserts: Vec<Op> =
            (1..=500u32).map(|k| Op::Insert { key: k, value: k * 2 }).collect();
        let r = h.submit(&inserts).unwrap();
        assert_eq!(r.len(), 500);
        assert!(r.iter().all(|x| matches!(x, OpResult::Upserted { old: None, .. })));
        let lookups: Vec<Op> = (1..=500u32).map(|k| Op::Lookup { key: k }).collect();
        let r = h.submit(&lookups).unwrap();
        assert_eq!(r.len(), 500);
        for (i, v) in r.iter().enumerate() {
            assert_eq!(
                v.as_value().unwrap(),
                Some((i as u32 + 1) * 2),
                "lookup {i} out of order"
            );
        }
        let deletes: Vec<Op> = (1..=250u32).map(|k| Op::Delete { key: k }).collect();
        let r = h.submit(&deletes).unwrap();
        assert!(r.iter().all(|x| *x == OpResult::Deleted(true)));
        coord.shutdown();
    }

    #[test]
    fn mixed_class_window_keeps_submission_order() {
        let (coord, h) =
            start_native(quick_cfg(), HiveConfig::default().with_buckets(64)).unwrap();
        let ops = vec![
            Op::FetchAdd { key: 1, delta: 5 },
            Op::Upsert { key: 2, value: 20 },
            Op::Lookup { key: 1 },
            Op::Cas { key: 2, expected: 20, new: 21 },
            Op::Delete { key: 3 },
            Op::Lookup { key: 2 },
        ];
        let r = h.submit(&ops).unwrap();
        assert_eq!(r.len(), ops.len());
        assert!(matches!(r[0], OpResult::FetchAdded { old: None, .. }));
        assert!(matches!(r[1], OpResult::Upserted { old: None, .. }));
        assert_eq!(r[2], OpResult::Value(Some(5)), "lookup groups after the fetch-add");
        assert_eq!(r[3], OpResult::Cas { ok: true, actual: Some(20) });
        assert_eq!(r[4], OpResult::Deleted(false));
        assert_eq!(r[5], OpResult::Value(Some(21)), "lookup groups after the cas");
        coord.shutdown();
    }

    #[test]
    fn handle_batch_api_roundtrip() {
        let (coord, h) =
            start_native(quick_cfg(), HiveConfig::default().with_buckets(64)).unwrap();
        let pairs: Vec<(u32, u32)> = (1..=300u32).map(|k| (k, k * 5)).collect();
        let r = h.insert_batch(&pairs).unwrap();
        assert_eq!(r.len(), 300);
        assert!(r.iter().all(|x| matches!(x, OpResult::Upserted { old: None, .. })));
        let keys: Vec<u32> = pairs.iter().map(|&(k, _)| k).collect();
        let vals = h.lookup_batch(&keys).unwrap();
        for (i, v) in vals.iter().enumerate() {
            assert_eq!(*v, Some((i as u32 + 1) * 5), "lookup {i}");
        }
        let hits = h.delete_batch(&keys[..100]).unwrap();
        assert!(hits.iter().all(|&d| d));
        let vals = h.lookup_batch(&keys[..100]).unwrap();
        assert!(vals.iter().all(Option::is_none));
        coord.shutdown();
    }

    #[test]
    fn stats_accumulate_and_service_survives_clients() {
        let (coord, h) =
            start_native(quick_cfg(), HiveConfig::default().with_buckets(64)).unwrap();
        let h2 = h.clone();
        let t = std::thread::spawn(move || {
            for k in 1..=200u32 {
                h2.insert(k, k).unwrap();
            }
        });
        t.join().unwrap();
        h.flush().unwrap();
        let s = h.stats().unwrap();
        assert_eq!(s.ops, 200);
        assert!(s.batches >= 1);
        assert_eq!(s.inserted + s.evicted + s.stashed, 200);
        coord.shutdown();
    }

    #[test]
    fn concurrent_clients_many_threads() {
        let (coord, h) =
            start_native(quick_cfg(), HiveConfig::default().with_buckets(256)).unwrap();
        let threads: Vec<_> = (0..8u32)
            .map(|t| {
                let h = h.clone();
                std::thread::spawn(move || {
                    for i in 0..250 {
                        let k = t * 10_000 + i + 1;
                        h.insert(k, k).unwrap();
                        assert_eq!(h.lookup(k).unwrap(), Some(k));
                    }
                })
            })
            .collect();
        for t in threads {
            t.join().unwrap();
        }
        coord.shutdown();
    }

    #[test]
    fn pipelined_window_keeps_ops_in_flight_and_completes() {
        let (coord, h) =
            start_native(quick_cfg(), HiveConfig::default().with_buckets(256)).unwrap();
        let pipe = h.pipeline(16);
        assert_eq!(pipe.depth(), 16);
        let mut tickets = std::collections::VecDeque::new();
        for k in 1..=400u32 {
            if tickets.len() == 16 {
                let t: crate::coordinator::pipeline::Ticket = tickets.pop_front().unwrap();
                match t.wait().unwrap() {
                    OpResult::Upserted { .. } => {}
                    other => panic!("unexpected reply {other:?}"),
                }
            }
            tickets.push_back(pipe.insert(k, k.wrapping_mul(3)).unwrap());
        }
        for t in tickets {
            t.wait().unwrap();
        }
        assert_eq!(pipe.in_flight(), 0);
        // everything the pipeline acked is visible to the blocking API
        for k in (1..=400u32).step_by(37) {
            assert_eq!(h.lookup(k).unwrap(), Some(k.wrapping_mul(3)));
        }
        coord.shutdown();
    }

    #[test]
    fn pipelined_rmw_tickets_resolve_typed() {
        let (coord, h) =
            start_native(quick_cfg(), HiveConfig::default().with_buckets(64)).unwrap();
        let pipe = h.pipeline(8);
        let t1 = pipe.fetch_add(1, 5).unwrap();
        let created = OpResult::FetchAdded { outcome: Some(InsertOutcome::Inserted), old: None };
        assert_eq!(t1.wait().unwrap(), created);
        let t2 = pipe.cas(1, 5, 6).unwrap();
        assert_eq!(t2.wait().unwrap(), OpResult::Cas { ok: true, actual: Some(5) });
        let t3 = pipe.update(1, 9).unwrap();
        assert_eq!(t3.wait().unwrap(), OpResult::Updated { old: Some(6) });
        let t4 = pipe.insert_if_absent(1, 0).unwrap();
        let present = OpResult::InsertedIfAbsent { outcome: None, existing: Some(9) };
        assert_eq!(t4.wait().unwrap(), present);
        let t5 = pipe.upsert(1, 11).unwrap();
        let replaced = OpResult::Upserted { outcome: InsertOutcome::Replaced, old: Some(9) };
        assert_eq!(t5.wait().unwrap(), replaced);
        coord.shutdown();
    }

    #[test]
    fn dropped_tickets_recycle_slots_and_ops_still_execute() {
        let (coord, h) =
            start_native(quick_cfg(), HiveConfig::default().with_buckets(256)).unwrap();
        let pipe = h.pipeline(4);
        // 64 fire-and-forget inserts through a depth-4 window: reserve
        // must recycle abandoned slots as completions land, or this
        // loop deadlocks (covered by the harness timeout)
        for k in 1..=64u32 {
            let _ = pipe.insert(k, k).unwrap();
        }
        h.flush().unwrap();
        for k in 1..=64u32 {
            assert_eq!(h.lookup(k).unwrap(), Some(k));
        }
        coord.shutdown();
    }

    #[test]
    fn queue_delay_and_latency_recorded_for_both_paths() {
        use crate::workload::Op;
        let (coord, h) =
            start_native(quick_cfg(), HiveConfig::default().with_buckets(64)).unwrap();
        h.insert(1, 1).unwrap(); // single path
        let ops: Vec<Op> = (10..100u32).map(|k| Op::Insert { key: k, value: k }).collect();
        h.submit(&ops).unwrap(); // bulk path
        h.flush().unwrap();
        let s = h.stats().unwrap();
        assert_eq!(s.latency_ns.count(), 91, "1 single + 90 bulk ops must record latency");
        assert_eq!(s.queue_delay_ns.count(), 91, "queue delay must cover both paths");
        assert!(s.inflight_depth.count() >= 2, "both dispatch paths sample depth");
        coord.shutdown();
    }

    #[test]
    fn cache_serves_repeat_lookups_and_stays_coherent() {
        let (coord, h) =
            start_native(quick_cfg(), HiveConfig::default().with_buckets(64)).unwrap();
        assert_eq!(h.insert(1, 100).unwrap(), InsertOutcome::Inserted);
        // first lookup fills, repeats hit
        for _ in 0..5 {
            assert_eq!(h.lookup(1).unwrap(), Some(100));
        }
        let s = h.stats().unwrap();
        assert!(s.cache_hits >= 3, "repeat lookups should hit: {}", s.summary());
        assert!(s.cache_misses >= 1, "first lookup must miss: {}", s.summary());
        // a replace retires the cached copy
        h.insert(1, 200).unwrap();
        assert_eq!(h.lookup(1).unwrap(), Some(200), "stale value served after replace");
        // a delete retires it again
        assert!(h.delete(1).unwrap());
        assert_eq!(h.lookup(1).unwrap(), None, "deleted key resurrected by the cache");
        let s = h.stats().unwrap();
        assert!(s.cache_invalidations >= 2, "writes must invalidate: {}", s.summary());
        coord.shutdown();
    }

    #[test]
    fn cache_disabled_when_capacity_zero() {
        let cfg = CoordinatorConfig { cache_capacity: 0, ..quick_cfg() };
        let (coord, h) = start_native(cfg, HiveConfig::default().with_buckets(64)).unwrap();
        h.insert(7, 70).unwrap();
        for _ in 0..5 {
            assert_eq!(h.lookup(7).unwrap(), Some(70));
        }
        let s = h.stats().unwrap();
        assert_eq!(s.cache_hits + s.cache_misses, 0, "disabled cache saw traffic");
        coord.shutdown();
    }

    #[test]
    fn window_with_write_conflict_matches_uncached_semantics() {
        use crate::workload::Op;
        // one worker so the whole window lands on one shard
        let cfg = CoordinatorConfig { workers: 1, ..quick_cfg() };
        let (coord, h) = start_native(cfg, HiveConfig::default().with_buckets(64)).unwrap();
        h.insert(5, 50).unwrap();
        assert_eq!(h.lookup(5).unwrap(), Some(50)); // now cached
        // window deletes 5 and looks it up: grouped execution (writes
        // before lookups) must observe the delete, not the cached copy
        let r = h.submit(&[Op::Delete { key: 5 }, Op::Lookup { key: 5 }]).unwrap();
        assert_eq!(r[0], OpResult::Deleted(true));
        assert_eq!(r[1], OpResult::Value(None), "cache leaked a pre-window value");
        // and a window that writes-then-reads sees the fresh value,
        // for the RMW classes too
        let r = h.submit(&[Op::Insert { key: 5, value: 55 }, Op::Lookup { key: 5 }]).unwrap();
        assert_eq!(r[1], OpResult::Value(Some(55)));
        let r = h.submit(&[Op::FetchAdd { key: 5, delta: 5 }, Op::Lookup { key: 5 }]).unwrap();
        assert_eq!(r[0], OpResult::FetchAdded { outcome: None, old: Some(55) });
        assert_eq!(r[1], OpResult::Value(Some(60)), "cache leaked across a fetch-add");
        coord.shutdown();
    }

    #[test]
    fn resize_controller_grows_under_load() {
        let cfg = CoordinatorConfig {
            workers: 1,
            batch: BatchPolicy { max_batch: 128, deadline: Duration::from_micros(50) },
            resize_check_every: 1,
            cache_capacity: 256,
            ring_capacity: 256,
        };
        let (coord, h) = start_native(cfg, HiveConfig::default().with_buckets(4)).unwrap();
        use crate::workload::Op;
        let ops: Vec<Op> = (1..=1000u32).map(|k| Op::Insert { key: k, value: k }).collect();
        for chunk in ops.chunks(100) {
            h.submit(chunk).unwrap();
        }
        let s = h.stats().unwrap();
        assert!(s.grows > 0, "expected resize under load: {}", s.summary());
        // all keys still present
        let lookups: Vec<Op> = (1..=1000u32).map(|k| Op::Lookup { key: k }).collect();
        let r = h.submit(&lookups).unwrap();
        assert!(r.iter().all(|v| matches!(v, OpResult::Value(Some(_)))));
        coord.shutdown();
    }
}
