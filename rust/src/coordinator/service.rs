//! The coordinator service: worker pool, request router, and the
//! per-worker dispatch loop (batcher + backend + resize controller).

use crate::backend::{Backend, BatchResult};
use crate::coordinator::batcher::{BatchPolicy, Batcher};
use crate::coordinator::stats::ServiceStats;
use crate::core::error::{HiveError, Result};
use crate::hash::HashKind;
use crate::native::resize::ResizeEvent;
use crate::workload::Op;
use std::sync::mpsc::{sync_channel, Receiver, RecvTimeoutError, Sender, SyncSender};
use std::sync::{mpsc, Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Coordinator configuration.
#[derive(Debug, Clone)]
pub struct CoordinatorConfig {
    /// Worker (shard) count.
    pub workers: usize,
    /// Dynamic batching policy per worker.
    pub batch: BatchPolicy,
    /// Run the resize controller every N dispatch windows.
    pub resize_check_every: u64,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        CoordinatorConfig {
            workers: 4,
            batch: BatchPolicy::default(),
            resize_check_every: 8,
        }
    }
}

/// A reply to one single-key operation.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum SingleReply {
    /// Insert outcome: true ⇒ newly inserted, false ⇒ replaced.
    Inserted(bool),
    /// Lookup result.
    Value(Option<u32>),
    /// Delete hit flag.
    Deleted(bool),
    /// Operation failed (e.g. table + stash full).
    Failed(String),
}

enum Request {
    Single { op: Op, enqueued: Instant, reply: SyncSender<SingleReply> },
    Bulk { ops: Vec<Op>, reply: SyncSender<Result<BatchResult>> },
    Stats { reply: SyncSender<ServiceStats> },
    Flush { reply: SyncSender<()> },
    Shutdown,
}

/// The running service. Dropping it (or calling [`Coordinator::shutdown`])
/// joins all workers.
pub struct Coordinator {
    senders: Vec<Sender<Request>>,
    handles: Vec<JoinHandle<()>>,
}

/// Clone-able client handle.
#[derive(Clone)]
pub struct Handle {
    senders: Arc<Vec<Sender<Request>>>,
}

impl Coordinator {
    /// Start the service: `factory(worker_index)` builds each worker's
    /// backend (one table shard per worker). The factory runs *inside*
    /// each worker thread — required because the XLA backend's PJRT
    /// client is not `Send`.
    pub fn start<F>(cfg: CoordinatorConfig, factory: F) -> Result<(Coordinator, Handle)>
    where
        F: Fn(usize) -> Result<Box<dyn Backend>> + Send + Sync + 'static,
    {
        assert!(cfg.workers >= 1);
        let factory = Arc::new(factory);
        let mut senders = Vec::with_capacity(cfg.workers);
        let mut handles = Vec::with_capacity(cfg.workers);
        for w in 0..cfg.workers {
            let (tx, rx) = mpsc::channel::<Request>();
            let (ready_tx, ready_rx) = sync_channel::<Result<()>>(1);
            let cfg_w = cfg.clone();
            let factory = Arc::clone(&factory);
            handles.push(
                std::thread::Builder::new()
                    .name(format!("hive-worker-{w}"))
                    .spawn(move || match factory(w) {
                        Ok(backend) => {
                            let _ = ready_tx.send(Ok(()));
                            worker_loop(rx, backend, cfg_w);
                        }
                        Err(e) => {
                            let _ = ready_tx.send(Err(e));
                        }
                    })
                    .expect("spawn worker"),
            );
            ready_rx.recv().map_err(|_| HiveError::Shutdown)??;
            senders.push(tx);
        }
        let handle = Handle { senders: Arc::new(senders.clone()) };
        Ok((Coordinator { senders, handles }, handle))
    }

    /// Stop all workers and join them.
    pub fn shutdown(mut self) {
        for tx in &self.senders {
            let _ = tx.send(Request::Shutdown);
        }
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
        self.senders.clear();
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        for tx in &self.senders {
            let _ = tx.send(Request::Shutdown);
        }
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

impl Handle {
    /// Worker shard for `key` (murmur routing — independent of the
    /// table's own bucket hashes so shards stay balanced).
    #[inline]
    fn route(&self, key: u32) -> usize {
        (HashKind::Murmur3.hash(key ^ 0x9E3779B9) as usize) % self.senders.len()
    }

    fn single(&self, worker: usize, op: Op) -> Result<SingleReply> {
        let (tx, rx) = sync_channel(1);
        self.senders[worker]
            .send(Request::Single { op, enqueued: Instant::now(), reply: tx })
            .map_err(|_| HiveError::Shutdown)?;
        rx.recv().map_err(|_| HiveError::Shutdown)
    }

    /// Insert or replace `key → value`.
    pub fn insert(&self, key: u32, value: u32) -> Result<bool> {
        match self.single(self.route(key), Op::Insert { key, value })? {
            SingleReply::Inserted(new) => Ok(new),
            SingleReply::Failed(msg) => Err(HiveError::Runtime(msg)),
            other => Err(HiveError::Runtime(format!("unexpected reply {other:?}"))),
        }
    }

    /// Point lookup.
    pub fn lookup(&self, key: u32) -> Result<Option<u32>> {
        match self.single(self.route(key), Op::Lookup { key })? {
            SingleReply::Value(v) => Ok(v),
            SingleReply::Failed(msg) => Err(HiveError::Runtime(msg)),
            other => Err(HiveError::Runtime(format!("unexpected reply {other:?}"))),
        }
    }

    /// Delete `key`.
    pub fn delete(&self, key: u32) -> Result<bool> {
        match self.single(self.route(key), Op::Delete { key })? {
            SingleReply::Deleted(hit) => Ok(hit),
            SingleReply::Failed(msg) => Err(HiveError::Runtime(msg)),
            other => Err(HiveError::Runtime(format!("unexpected reply {other:?}"))),
        }
    }

    /// Bulk insert/replace: shards by key and rides the workers' batched
    /// backend path (one epoch pin per shard window instead of one per
    /// op). Returns the merged batch counters.
    pub fn insert_batch(&self, pairs: &[(u32, u32)]) -> Result<BatchResult> {
        let ops: Vec<Op> =
            pairs.iter().map(|&(key, value)| Op::Insert { key, value }).collect();
        self.submit(&ops)
    }

    /// Bulk lookup in submission order, via the batched backend path.
    pub fn lookup_batch(&self, keys: &[u32]) -> Result<Vec<Option<u32>>> {
        let ops: Vec<Op> = keys.iter().map(|&key| Op::Lookup { key }).collect();
        Ok(self.submit(&ops)?.lookups)
    }

    /// Bulk delete in submission order, via the batched backend path.
    pub fn delete_batch(&self, keys: &[u32]) -> Result<Vec<bool>> {
        let ops: Vec<Op> = keys.iter().map(|&key| Op::Delete { key }).collect();
        Ok(self.submit(&ops)?.deletes)
    }

    /// Submit a pre-batched workload: ops are sharded by key, executed on
    /// all workers, and the per-class results are reassembled in
    /// submission order.
    pub fn submit(&self, ops: &[Op]) -> Result<BatchResult> {
        let w = self.senders.len();
        let mut shards: Vec<Vec<Op>> = vec![Vec::new(); w];
        let mut route_of: Vec<usize> = Vec::with_capacity(ops.len());
        for op in ops {
            let r = self.route(op.key());
            shards[r].push(*op);
            route_of.push(r);
        }
        let mut rxs = Vec::with_capacity(w);
        for (i, shard) in shards.into_iter().enumerate() {
            if shard.is_empty() {
                rxs.push(None);
                continue;
            }
            let (tx, rx) = sync_channel(1);
            self.senders[i]
                .send(Request::Bulk { ops: shard, reply: tx })
                .map_err(|_| HiveError::Shutdown)?;
            rxs.push(Some(rx));
        }
        let mut partials: Vec<Option<BatchResult>> = Vec::with_capacity(w);
        for rx in rxs {
            match rx {
                None => partials.push(None),
                Some(rx) => partials.push(Some(rx.recv().map_err(|_| HiveError::Shutdown)??)),
            }
        }
        // Reassemble lookups/deletes in original submission order.
        let mut luk_cursor = vec![0usize; w];
        let mut del_cursor = vec![0usize; w];
        let mut merged = BatchResult::default();
        for p in partials.iter().flatten() {
            merged.inserted += p.inserted;
            merged.replaced += p.replaced;
            merged.stashed += p.stashed;
        }
        for (op, &r) in ops.iter().zip(&route_of) {
            match op {
                Op::Lookup { .. } => {
                    let p = partials[r].as_ref().expect("shard result");
                    merged.lookups.push(p.lookups[luk_cursor[r]]);
                    luk_cursor[r] += 1;
                }
                Op::Delete { .. } => {
                    let p = partials[r].as_ref().expect("shard result");
                    merged.deletes.push(p.deletes[del_cursor[r]]);
                    del_cursor[r] += 1;
                }
                Op::Insert { .. } => {}
            }
        }
        Ok(merged)
    }

    /// Aggregate service stats across workers.
    pub fn stats(&self) -> Result<ServiceStats> {
        let mut agg = ServiceStats::default();
        for tx in self.senders.iter() {
            let (rtx, rrx) = sync_channel(1);
            tx.send(Request::Stats { reply: rtx }).map_err(|_| HiveError::Shutdown)?;
            agg.merge(&rrx.recv().map_err(|_| HiveError::Shutdown)?);
        }
        Ok(agg)
    }

    /// Flush all pending windows (barrier; used by tests/benches).
    pub fn flush(&self) -> Result<()> {
        for tx in self.senders.iter() {
            let (rtx, rrx) = sync_channel(1);
            tx.send(Request::Flush { reply: rtx }).map_err(|_| HiveError::Shutdown)?;
            rrx.recv().map_err(|_| HiveError::Shutdown)?;
        }
        Ok(())
    }
}

/// One worker: owns a backend shard, batches singles, executes bulks,
/// runs the resize controller between windows.
fn worker_loop(rx: Receiver<Request>, mut backend: Box<dyn Backend>, cfg: CoordinatorConfig) {
    let mut batcher = Batcher::new(cfg.batch);
    let mut waiting: Vec<(Instant, SyncSender<SingleReply>, Op)> = Vec::new();
    let mut stats = ServiceStats::default();

    let dispatch = |backend: &mut Box<dyn Backend>,
                    batcher: &mut Batcher,
                    waiting: &mut Vec<(Instant, SyncSender<SingleReply>, Op)>,
                    stats: &mut ServiceStats| {
        if batcher.is_empty() {
            return;
        }
        let ops = batcher.take();
        stats.batches += 1;
        stats.ops += ops.len() as u64;
        stats.batch_sizes.record(ops.len() as u64);
        match backend.execute(&ops) {
            Ok(res) => {
                stats.inserted += res.inserted as u64;
                stats.replaced += res.replaced as u64;
                stats.stashed += res.stashed as u64;
                stats.deleted += res.deletes.iter().filter(|&&d| d).count() as u64;
                // replies in class order
                let mut luk = res.lookups.into_iter();
                let mut del = res.deletes.into_iter();
                for (enq, reply, op) in waiting.drain(..) {
                    stats.latency_ns.record(enq.elapsed().as_nanos() as u64);
                    let msg = match op {
                        Op::Insert { .. } => SingleReply::Inserted(true),
                        Op::Lookup { .. } => SingleReply::Value(luk.next().flatten()),
                        Op::Delete { .. } => SingleReply::Deleted(del.next().unwrap_or(false)),
                    };
                    let _ = reply.send(msg);
                }
            }
            Err(e) => {
                for (_, reply, _) in waiting.drain(..) {
                    let _ = reply.send(SingleReply::Failed(e.to_string()));
                }
            }
        }
        // Resize controller between windows. The call still runs a full
        // K-bucket migration batch synchronously on this worker thread,
        // but with the epoch scheme other threads' operations (and other
        // shards) proceed concurrently instead of blocking on a write
        // guard.
        if stats.batches % cfg.resize_check_every == 0 {
            match backend.maybe_resize() {
                Ok(Some(ResizeEvent::Grew { .. })) => stats.grows += 1,
                Ok(Some(ResizeEvent::Shrank { .. })) => stats.shrinks += 1,
                _ => {}
            }
        }
    };

    loop {
        let timeout =
            batcher.time_to_deadline().unwrap_or(Duration::from_millis(50));
        match rx.recv_timeout(timeout) {
            Ok(Request::Single { op, enqueued, reply }) => {
                waiting.push((enqueued, reply, op));
                if batcher.push(op) {
                    dispatch(&mut backend, &mut batcher, &mut waiting, &mut stats);
                }
            }
            Ok(Request::Bulk { ops, reply }) => {
                // flush pending singles first to preserve window ordering
                dispatch(&mut backend, &mut batcher, &mut waiting, &mut stats);
                stats.batches += 1;
                stats.ops += ops.len() as u64;
                stats.batch_sizes.record(ops.len() as u64);
                let res = backend.execute(&ops);
                if let Ok(res) = &res {
                    stats.inserted += res.inserted as u64;
                    stats.replaced += res.replaced as u64;
                    stats.stashed += res.stashed as u64;
                    stats.deleted += res.deletes.iter().filter(|&&d| d).count() as u64;
                }
                let _ = reply.send(res);
                if stats.batches % cfg.resize_check_every == 0 {
                    match backend.maybe_resize() {
                        Ok(Some(ResizeEvent::Grew { .. })) => stats.grows += 1,
                        Ok(Some(ResizeEvent::Shrank { .. })) => stats.shrinks += 1,
                        _ => {}
                    }
                }
            }
            Ok(Request::Stats { reply }) => {
                let _ = reply.send(stats.clone());
            }
            Ok(Request::Flush { reply }) => {
                dispatch(&mut backend, &mut batcher, &mut waiting, &mut stats);
                let _ = reply.send(());
            }
            Ok(Request::Shutdown) => {
                dispatch(&mut backend, &mut batcher, &mut waiting, &mut stats);
                break;
            }
            Err(RecvTimeoutError::Timeout) => {
                if batcher.deadline_expired() {
                    dispatch(&mut backend, &mut batcher, &mut waiting, &mut stats);
                }
            }
            Err(RecvTimeoutError::Disconnected) => break,
        }
    }
}

/// Shared-state convenience: a coordinator whose workers all use native
/// backends over table shards sized by `cfg`.
pub fn start_native(
    coord_cfg: CoordinatorConfig,
    table_cfg: crate::core::config::HiveConfig,
) -> Result<(Coordinator, Handle)> {
    let table_cfg = Arc::new(Mutex::new(table_cfg));
    Coordinator::start(coord_cfg, move |_w| {
        let cfg = table_cfg.lock().unwrap().clone();
        Ok(Box::new(crate::backend::NativeBackend::new(cfg)?) as Box<dyn Backend>)
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::config::HiveConfig;

    fn quick_cfg() -> CoordinatorConfig {
        CoordinatorConfig {
            workers: 2,
            batch: BatchPolicy { max_batch: 64, deadline: Duration::from_micros(100) },
            resize_check_every: 2,
        }
    }

    #[test]
    fn single_op_roundtrip() {
        let (coord, h) =
            start_native(quick_cfg(), HiveConfig::default().with_buckets(64)).unwrap();
        assert!(h.insert(1, 100).unwrap());
        assert_eq!(h.lookup(1).unwrap(), Some(100));
        assert_eq!(h.lookup(2).unwrap(), None);
        assert!(h.delete(1).unwrap());
        assert!(!h.delete(1).unwrap());
        coord.shutdown();
    }

    #[test]
    fn bulk_submit_reassembles_in_order() {
        use crate::workload::Op;
        let (coord, h) =
            start_native(quick_cfg(), HiveConfig::default().with_buckets(64)).unwrap();
        let inserts: Vec<Op> =
            (1..=500u32).map(|k| Op::Insert { key: k, value: k * 2 }).collect();
        let r = h.submit(&inserts).unwrap();
        assert_eq!(r.inserted, 500);
        let lookups: Vec<Op> = (1..=500u32).map(|k| Op::Lookup { key: k }).collect();
        let r = h.submit(&lookups).unwrap();
        assert_eq!(r.lookups.len(), 500);
        for (i, v) in r.lookups.iter().enumerate() {
            assert_eq!(*v, Some((i as u32 + 1) * 2), "lookup {i} out of order");
        }
        let deletes: Vec<Op> = (1..=250u32).map(|k| Op::Delete { key: k }).collect();
        let r = h.submit(&deletes).unwrap();
        assert!(r.deletes.iter().all(|&d| d));
        coord.shutdown();
    }

    #[test]
    fn handle_batch_api_roundtrip() {
        let (coord, h) =
            start_native(quick_cfg(), HiveConfig::default().with_buckets(64)).unwrap();
        let pairs: Vec<(u32, u32)> = (1..=300u32).map(|k| (k, k * 5)).collect();
        let r = h.insert_batch(&pairs).unwrap();
        assert_eq!(r.inserted, 300);
        let keys: Vec<u32> = pairs.iter().map(|&(k, _)| k).collect();
        let vals = h.lookup_batch(&keys).unwrap();
        for (i, v) in vals.iter().enumerate() {
            assert_eq!(*v, Some((i as u32 + 1) * 5), "lookup {i}");
        }
        let hits = h.delete_batch(&keys[..100]).unwrap();
        assert!(hits.iter().all(|&d| d));
        let vals = h.lookup_batch(&keys[..100]).unwrap();
        assert!(vals.iter().all(Option::is_none));
        coord.shutdown();
    }

    #[test]
    fn stats_accumulate_and_service_survives_clients() {
        let (coord, h) =
            start_native(quick_cfg(), HiveConfig::default().with_buckets(64)).unwrap();
        let h2 = h.clone();
        let t = std::thread::spawn(move || {
            for k in 1..=200u32 {
                h2.insert(k, k).unwrap();
            }
        });
        t.join().unwrap();
        h.flush().unwrap();
        let s = h.stats().unwrap();
        assert_eq!(s.ops, 200);
        assert!(s.batches >= 1);
        assert_eq!(s.inserted, 200);
        coord.shutdown();
    }

    #[test]
    fn concurrent_clients_many_threads() {
        let (coord, h) =
            start_native(quick_cfg(), HiveConfig::default().with_buckets(256)).unwrap();
        let threads: Vec<_> = (0..8u32)
            .map(|t| {
                let h = h.clone();
                std::thread::spawn(move || {
                    for i in 0..250 {
                        let k = t * 10_000 + i + 1;
                        h.insert(k, k).unwrap();
                        assert_eq!(h.lookup(k).unwrap(), Some(k));
                    }
                })
            })
            .collect();
        for t in threads {
            t.join().unwrap();
        }
        coord.shutdown();
    }

    #[test]
    fn resize_controller_grows_under_load() {
        let cfg = CoordinatorConfig {
            workers: 1,
            batch: BatchPolicy { max_batch: 128, deadline: Duration::from_micros(50) },
            resize_check_every: 1,
        };
        let (coord, h) = start_native(cfg, HiveConfig::default().with_buckets(4)).unwrap();
        use crate::workload::Op;
        let ops: Vec<Op> = (1..=1000u32).map(|k| Op::Insert { key: k, value: k }).collect();
        for chunk in ops.chunks(100) {
            h.submit(chunk).unwrap();
        }
        let s = h.stats().unwrap();
        assert!(s.grows > 0, "expected resize under load: {}", s.summary());
        // all keys still present
        let lookups: Vec<Op> = (1..=1000u32).map(|k| Op::Lookup { key: k }).collect();
        let r = h.submit(&lookups).unwrap();
        assert!(r.lookups.iter().all(Option::is_some));
        coord.shutdown();
    }
}
