//! The RESP TCP server: bounded acceptor, per-connection reader/writer
//! thread pair, pipelined command multiplexing onto the coordinator's
//! ticket plane, and deadline-bounded graceful shutdown.
//!
//! ## Threading model (std-only — no async runtime)
//!
//! * **Acceptor** — one thread polling a nonblocking listener (std has
//!   no accept timeout; a 1 ms poll keeps shutdown responsive). It
//!   enforces [`NetConfig::max_connections`]: over-cap clients get
//!   `-ERR max number of clients reached` and an immediate close.
//! * **Reader** (one per connection) — reads with a short
//!   `set_read_timeout` so it can observe shutdown, feeds the
//!   incremental RESP parser, decodes commands, submits their ops onto
//!   the connection's [`Pipeline`] (depth = [`NetConfig::pipeline_depth`];
//!   `Pipeline::submit` blocks at full depth, which is the per-connection
//!   in-flight bound), and enqueues the pending reply into a bounded
//!   FIFO ring toward the writer.
//! * **Writer** (one per connection) — pops replies in submission
//!   order, waits each command's tickets, renders the RESP reply, and
//!   writes it with `set_write_timeout` (per-fd nonblocking would break
//!   the blocking reader sharing the socket, so bounded-blocking writes
//!   are the backpressure primitive: a slow client stalls its writer,
//!   the reply ring fills, the reader stops reading, and the kernel
//!   closes the TCP window).
//!
//! ## Ordering
//!
//! Replies are written strictly in submission order (FIFO ring). Ops
//! in flight together on the coordinator are concurrent, so the reader
//! additionally serializes *same-key* commands: before submitting a
//! command touching key `k` it waits the connection's completion
//! watermark past the last command that touched `k`. Disjoint-key
//! commands pipeline freely; a same-key burst degrades toward closed
//! loop — this is what gives each connection read-your-write ordering
//! (`SET k v` then `GET k` pipelined returns `v`).
//!
//! ## Shutdown
//!
//! [`NetServer::shutdown`] stops the acceptor, then every connection
//! drains: readers stop consuming input, writers keep resolving
//! tickets until [`NetConfig::drain_deadline`], after which remaining
//! replies become `-SHUTDOWN` errors and the socket closes. The
//! exactly-once completion machinery guarantees every ticket fires
//! (worker death publishes `Shutdown`), so no client and no server
//! thread can hang: every wait in this module is deadline-bounded.

use crate::coordinator::pipeline::{ring, RingRx, RingTx};
use crate::coordinator::{Handle, Pipeline, ServiceStats, Ticket};
use crate::core::error::{HiveError, Result};
use crate::core::histogram::Histogram;
use crate::net::command::{render_reply, Command, ReplyShape};
use crate::net::resp::{Frame, Parser};
use std::collections::HashMap;
use std::io::{ErrorKind, Read, Write};
use std::net::{Shutdown as SockShutdown, SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::RecvTimeoutError;
use std::sync::{Arc, Condvar, Mutex};
use std::thread::{self, JoinHandle};
use std::time::{Duration, Instant};

/// How often blocked loops re-check the stop flag.
const POLL: Duration = Duration::from_millis(50);

/// Network server configuration.
#[derive(Debug, Clone)]
pub struct NetConfig {
    /// Bind address; use port 0 to let the OS pick (see
    /// [`NetServer::local_addr`]).
    pub addr: String,
    /// Accepted-connection cap; clients beyond it are turned away with
    /// an error reply.
    pub max_connections: usize,
    /// Per-connection in-flight op window (the `Pipeline` depth): how
    /// many ops one connection keeps outstanding before its reader
    /// blocks.
    pub pipeline_depth: usize,
    /// Graceful-shutdown budget: how long writers keep draining
    /// in-flight tickets before remaining replies become `-SHUTDOWN`.
    pub drain_deadline: Duration,
}

impl Default for NetConfig {
    fn default() -> Self {
        NetConfig {
            addr: "127.0.0.1:0".into(),
            max_connections: 1024,
            pipeline_depth: 256,
            drain_deadline: Duration::from_secs(1),
        }
    }
}

/// One queued reply, in submission order.
enum ReplyItem {
    /// Answered without touching the data plane (PING, errors, INFO).
    Ready(Frame),
    /// Waiting on submitted ops; the writer waits the tickets and
    /// folds results via the shape.
    Pending { shape: ReplyShape, tickets: Vec<Ticket>, submitted: Instant },
    /// Flush everything before this marker, then close (QUIT,
    /// protocol errors).
    CloseAfterFlush,
}

/// Per-connection reader↔writer shared state: the completion watermark
/// (count of ticket-bearing replies fully resolved) the reader uses to
/// serialize same-key commands.
struct ConnShared {
    done: Mutex<u64>,
    advanced: Condvar,
    writer_dead: AtomicBool,
}

/// Server-wide shared state and counters.
struct ServerShared {
    cfg: NetConfig,
    handle: Handle,
    port: u16,
    started: Instant,
    stop: AtomicBool,
    /// Set (before `stop`) by shutdown: when writers may stop waiting
    /// tickets and start answering `-SHUTDOWN`.
    drain_until: Mutex<Option<Instant>>,
    conns: Mutex<Vec<JoinHandle<()>>>,
    opened: AtomicU64,
    rejected: AtomicU64,
    active: AtomicUsize,
    bytes_in: AtomicU64,
    bytes_out: AtomicU64,
    commands: AtomicU64,
    protocol_errors: AtomicU64,
    /// Per-command wire latency (submit → reply rendered), merged from
    /// each connection's local histogram on connection close.
    latency: Mutex<Histogram>,
}

impl ServerShared {
    fn stopping(&self) -> bool {
        self.stop.load(Ordering::Acquire)
    }

    /// `true` once the graceful-drain budget is spent: stop waiting
    /// tickets, answer `-SHUTDOWN`.
    fn past_drain_deadline(&self) -> bool {
        if !self.stopping() {
            return false;
        }
        match *self.drain_until.lock().unwrap() {
            Some(t) => Instant::now() >= t,
            None => false,
        }
    }

    /// Snapshot the wire-plane counters into the `net_*` fields of a
    /// [`ServiceStats`].
    fn net_stats(&self) -> ServiceStats {
        let mut s = ServiceStats::default();
        s.net_connections_opened = self.opened.load(Ordering::Relaxed);
        s.net_connections_rejected = self.rejected.load(Ordering::Relaxed);
        s.net_connections_active = self.active.load(Ordering::Relaxed) as u64;
        s.net_bytes_in = self.bytes_in.load(Ordering::Relaxed);
        s.net_bytes_out = self.bytes_out.load(Ordering::Relaxed);
        s.net_commands = self.commands.load(Ordering::Relaxed);
        s.net_protocol_errors = self.protocol_errors.load(Ordering::Relaxed);
        s.net_cmd_latency_ns = self.latency.lock().unwrap().clone();
        s
    }

    /// Render the INFO reply: redis-shaped sections over the merged
    /// coordinator + wire stats.
    fn render_info(&self) -> String {
        let net = self.net_stats();
        let uptime = self.started.elapsed();
        let cps = if uptime.as_secs_f64() > 0.0 {
            net.net_commands as f64 / uptime.as_secs_f64()
        } else {
            0.0
        };
        let coord = match self.handle.stats() {
            Ok(s) => s.summary(),
            Err(e) => format!("unavailable: {e}"),
        };
        let lat = &net.net_cmd_latency_ns;
        format!(
            "# Server\r\nhive_version:0.1.0\r\ntcp_port:{}\r\nuptime_in_seconds:{}\r\n\
             # Clients\r\nconnected_clients:{}\r\nrejected_connections:{}\r\n\
             # Stats\r\ntotal_connections_received:{}\r\ntotal_commands_processed:{}\r\n\
             instantaneous_ops_per_sec:{:.0}\r\ntotal_net_input_bytes:{}\r\n\
             total_net_output_bytes:{}\r\nprotocol_errors:{}\r\n\
             # Latency\r\ncmd_p50_ns:{}\r\ncmd_p99_ns:{}\r\ncmd_p999_ns:{}\r\n\
             # Hive\r\ncoordinator:{}\r\n",
            self.port,
            uptime.as_secs(),
            net.net_connections_active,
            net.net_connections_rejected,
            net.net_connections_opened,
            net.net_commands,
            cps,
            net.net_bytes_in,
            net.net_bytes_out,
            net.net_protocol_errors,
            lat.quantile(0.50),
            lat.quantile(0.99),
            lat.quantile(0.999),
            coord,
        )
    }
}

/// A running RESP server bound to a coordinator [`Handle`].
///
/// The server does not own the coordinator: start one with
/// [`start_native_sharded`](crate::coordinator::start_native_sharded)
/// (or any factory), pass its handle here, and shut the server down
/// *before* the coordinator for clean `-SHUTDOWN`-free drains — though
/// either order is safe (a dead coordinator fails submits with
/// `Shutdown`, which connections answer and close on).
pub struct NetServer {
    shared: Arc<ServerShared>,
    local: SocketAddr,
    acceptor: Option<JoinHandle<()>>,
}

impl NetServer {
    /// Bind and start accepting. Returns once the listener is live, so
    /// `local_addr` is immediately connectable.
    pub fn start(cfg: NetConfig, handle: Handle) -> Result<NetServer> {
        let listener = TcpListener::bind(&cfg.addr)
            .map_err(|e| HiveError::Config(format!("bind {}: {e}", cfg.addr)))?;
        let local = listener
            .local_addr()
            .map_err(|e| HiveError::Config(format!("local_addr: {e}")))?;
        listener
            .set_nonblocking(true)
            .map_err(|e| HiveError::Config(format!("set_nonblocking: {e}")))?;
        let shared = Arc::new(ServerShared {
            cfg,
            handle,
            port: local.port(),
            started: Instant::now(),
            stop: AtomicBool::new(false),
            drain_until: Mutex::new(None),
            conns: Mutex::new(Vec::new()),
            opened: AtomicU64::new(0),
            rejected: AtomicU64::new(0),
            active: AtomicUsize::new(0),
            bytes_in: AtomicU64::new(0),
            bytes_out: AtomicU64::new(0),
            commands: AtomicU64::new(0),
            protocol_errors: AtomicU64::new(0),
            latency: Mutex::new(Histogram::new()),
        });
        let shared2 = Arc::clone(&shared);
        let acceptor = thread::Builder::new()
            .name("hive-net-accept".into())
            .spawn(move || acceptor_loop(listener, shared2))
            .map_err(|e| HiveError::Runtime(format!("spawn acceptor: {e}")))?;
        Ok(NetServer { shared, local, acceptor: Some(acceptor) })
    }

    /// The bound address (resolves port 0 binds).
    pub fn local_addr(&self) -> SocketAddr {
        self.local
    }

    /// Wire-plane stats snapshot (`net_*` fields of [`ServiceStats`];
    /// merge with `Handle::stats()` for the full service view).
    pub fn stats(&self) -> ServiceStats {
        self.shared.net_stats()
    }

    /// Graceful shutdown: stop accepting, drain every connection's
    /// in-flight tickets up to the drain deadline, answer `-SHUTDOWN`
    /// past it, close all sockets, join all threads. Bounded time;
    /// idempotent via `Drop`.
    pub fn shutdown(mut self) {
        self.do_shutdown();
    }

    fn do_shutdown(&mut self) {
        // deadline first, then the flag: a writer that sees `stop` must
        // also see a concrete drain deadline.
        {
            let mut d = self.shared.drain_until.lock().unwrap();
            if d.is_none() {
                *d = Some(Instant::now() + self.shared.cfg.drain_deadline);
            }
        }
        self.shared.stop.store(true, Ordering::Release);
        if let Some(a) = self.acceptor.take() {
            let _ = a.join();
        }
        let conns = std::mem::take(&mut *self.shared.conns.lock().unwrap());
        for c in conns {
            let _ = c.join();
        }
    }
}

impl Drop for NetServer {
    fn drop(&mut self) {
        self.do_shutdown();
    }
}

fn acceptor_loop(listener: TcpListener, shared: Arc<ServerShared>) {
    loop {
        if shared.stopping() {
            return;
        }
        match listener.accept() {
            Ok((stream, _peer)) => {
                // prune finished connections so churn doesn't grow the
                // join list unboundedly
                shared.conns.lock().unwrap().retain(|h| !h.is_finished());
                if shared.active.load(Ordering::Relaxed) >= shared.cfg.max_connections {
                    shared.rejected.fetch_add(1, Ordering::Relaxed);
                    reject(stream);
                    continue;
                }
                shared.opened.fetch_add(1, Ordering::Relaxed);
                shared.active.fetch_add(1, Ordering::Relaxed);
                let shared2 = Arc::clone(&shared);
                match thread::Builder::new()
                    .name("hive-net-conn".into())
                    .spawn(move || {
                        connection(stream, &shared2);
                        shared2.active.fetch_sub(1, Ordering::Relaxed);
                    }) {
                    Ok(h) => shared.conns.lock().unwrap().push(h),
                    Err(_) => {
                        shared.active.fetch_sub(1, Ordering::Relaxed);
                    }
                }
            }
            // nonblocking accept: nothing pending — poll the stop flag
            Err(ref e) if e.kind() == ErrorKind::WouldBlock => thread::sleep(Duration::from_millis(1)),
            Err(_) => thread::sleep(Duration::from_millis(1)),
        }
    }
}

/// Turn away an over-cap client with a best-effort error reply.
fn reject(stream: TcpStream) {
    let _ = stream.set_nonblocking(false);
    let _ = stream.set_write_timeout(Some(POLL));
    let mut s = stream;
    let _ = s.write_all(b"-ERR max number of clients reached\r\n");
    let _ = s.shutdown(SockShutdown::Both);
}

/// One connection: runs the reader loop on this thread, the writer on
/// a sibling, and joins the writer before returning.
fn connection(stream: TcpStream, shared: &Arc<ServerShared>) {
    // BSD-family accept() inherits the listener's nonblocking flag
    // (Linux does not); the reader/writer loops want blocking sockets
    // with read/write timeouts.
    let _ = stream.set_nonblocking(false);
    let _ = stream.set_nodelay(true);
    let Ok(wstream) = stream.try_clone() else { return };
    let (tx, rx) = ring::<ReplyItem>(shared.cfg.pipeline_depth.max(16) + 16);
    let conn = Arc::new(ConnShared {
        done: Mutex::new(0),
        advanced: Condvar::new(),
        writer_dead: AtomicBool::new(false),
    });
    let pipe = shared.handle.pipeline(shared.cfg.pipeline_depth);
    let writer = {
        let conn = Arc::clone(&conn);
        let shared = Arc::clone(shared);
        thread::Builder::new()
            .name("hive-net-write".into())
            .spawn(move || writer_loop(rx, wstream, &conn, &shared))
    };
    let Ok(writer) = writer else { return };
    reader_loop(stream, tx, &pipe, &conn, shared);
    // tx dropped above → the writer drains the queued replies, then
    // observes disconnection and exits.
    let _ = writer.join();
}

/// Wait the connection's completion watermark up to `need` — the
/// same-key serialization barrier. Returns `false` when the connection
/// is dying and the reader should stop.
fn wait_watermark(conn: &ConnShared, need: u64, shared: &ServerShared) -> bool {
    let mut done = conn.done.lock().unwrap();
    while *done < need {
        if shared.stopping() || conn.writer_dead.load(Ordering::Acquire) {
            return false;
        }
        let (g, _) = conn.advanced.wait_timeout(done, POLL).unwrap();
        done = g;
    }
    true
}

fn reader_loop(
    mut sock: TcpStream,
    tx: RingTx<ReplyItem>,
    pipe: &Pipeline,
    conn: &ConnShared,
    shared: &ServerShared,
) {
    let _ = sock.set_read_timeout(Some(POLL));
    let mut parser = Parser::new();
    let mut buf = [0u8; 16 * 1024];
    // ticket-bearing replies submitted so far; the watermark counts the
    // same replies resolved, and `last_touch` maps key → the last reply
    // index that touched it.
    let mut submitted: u64 = 0;
    let mut last_touch: HashMap<u32, u64> = HashMap::new();
    'conn: loop {
        if shared.stopping() {
            break;
        }
        // drain every complete frame currently buffered (pipelining)
        loop {
            let frame = match parser.try_next() {
                Ok(Some(f)) => f,
                Ok(None) => break,
                Err(pe) => {
                    shared.protocol_errors.fetch_add(1, Ordering::Relaxed);
                    let _ = tx.send(ReplyItem::Ready(Frame::Error(format!("ERR {pe}"))));
                    let _ = tx.send(ReplyItem::CloseAfterFlush);
                    break 'conn;
                }
            };
            shared.commands.fetch_add(1, Ordering::Relaxed);
            let cmd = match Command::parse(&frame) {
                Ok(c) => c,
                Err(msg) => {
                    if tx.send(ReplyItem::Ready(Frame::Error(msg))).is_err() {
                        break 'conn;
                    }
                    continue;
                }
            };
            let ready = match &cmd {
                Command::Ping { msg: None } => Some(Frame::Simple("PONG".into())),
                Command::Ping { msg: Some(m) } => Some(Frame::Bulk(m.clone())),
                Command::CommandProbe => Some(Frame::Array(Vec::new())),
                Command::Info => Some(Frame::Bulk(shared.render_info().into_bytes())),
                Command::Quit => {
                    let _ = tx.send(ReplyItem::Ready(Frame::Simple("OK".into())));
                    let _ = tx.send(ReplyItem::CloseAfterFlush);
                    break 'conn;
                }
                _ => None,
            };
            if let Some(frame) = ready {
                if tx.send(ReplyItem::Ready(frame)).is_err() {
                    break 'conn;
                }
                continue;
            }
            let Some((ops, shape)) = cmd.to_ops() else { continue };
            // same-key barrier: dependent commands wait their
            // predecessor's completion (read-your-write per connection)
            let keys = cmd.keys();
            if let Some(need) = keys.iter().filter_map(|k| last_touch.get(k).copied()).max() {
                if !wait_watermark(conn, need, shared) {
                    break 'conn;
                }
            }
            let t0 = Instant::now();
            let mut tickets = Vec::with_capacity(ops.len());
            for op in ops {
                match pipe.submit(op) {
                    Ok(t) => tickets.push(t),
                    Err(e) => {
                        // coordinator gone mid-command: answer and close
                        drop(tickets);
                        let _ = tx.send(ReplyItem::Ready(crate::net::command::render_reply(
                            &shape,
                            &[Err(e)],
                        )));
                        let _ = tx.send(ReplyItem::CloseAfterFlush);
                        break 'conn;
                    }
                }
            }
            submitted += 1;
            for k in keys {
                last_touch.insert(k, submitted);
            }
            if last_touch.len() > 4096 {
                let wm = *conn.done.lock().unwrap();
                last_touch.retain(|_, &mut idx| idx > wm);
            }
            if tx.send(ReplyItem::Pending { shape, tickets, submitted: t0 }).is_err() {
                break 'conn;
            }
        }
        match sock.read(&mut buf) {
            Ok(0) => break, // clean EOF
            Ok(n) => {
                shared.bytes_in.fetch_add(n as u64, Ordering::Relaxed);
                parser.feed(&buf[..n]);
            }
            Err(ref e)
                if e.kind() == ErrorKind::WouldBlock
                    || e.kind() == ErrorKind::TimedOut
                    || e.kind() == ErrorKind::Interrupted => {}
            Err(_) => break,
        }
    }
}

/// Wait one ticket with the drain deadline in force. Exactly-once
/// completion bounds the normal path; the drain deadline bounds the
/// shutdown path.
fn resolve_ticket(mut ticket: Ticket, shared: &ServerShared) -> Result<crate::workload::OpResult> {
    loop {
        if shared.past_drain_deadline() {
            return Err(HiveError::Shutdown);
        }
        match ticket.wait_deadline(Instant::now() + POLL) {
            Ok(res) => return res,
            Err(back) => ticket = back,
        }
    }
}

fn writer_loop(
    rx: RingRx<ReplyItem>,
    mut sock: TcpStream,
    conn: &ConnShared,
    shared: &ServerShared,
) {
    let _ = sock.set_write_timeout(Some(POLL));
    let mut out: Vec<u8> = Vec::with_capacity(4096);
    let mut latency = Histogram::new();
    loop {
        match rx.recv_timeout(POLL) {
            Ok(ReplyItem::Ready(frame)) => {
                out.clear();
                frame.encode_into(&mut out);
                if !write_all_bounded(&mut sock, &out, shared) {
                    break;
                }
            }
            Ok(ReplyItem::Pending { shape, tickets, submitted }) => {
                let results: Vec<Result<crate::workload::OpResult>> =
                    tickets.into_iter().map(|t| resolve_ticket(t, shared)).collect();
                let frame = render_reply(&shape, &results);
                latency.record(submitted.elapsed().as_nanos() as u64);
                // advance the watermark before writing: the results are
                // resolved, so a same-key successor may submit while
                // this reply travels the socket
                {
                    let mut d = conn.done.lock().unwrap();
                    *d += 1;
                }
                conn.advanced.notify_all();
                out.clear();
                frame.encode_into(&mut out);
                if !write_all_bounded(&mut sock, &out, shared) {
                    break;
                }
            }
            Ok(ReplyItem::CloseAfterFlush) | Err(RecvTimeoutError::Disconnected) => break,
            Err(RecvTimeoutError::Timeout) => {}
        }
    }
    conn.writer_dead.store(true, Ordering::Release);
    conn.advanced.notify_all();
    let _ = sock.shutdown(SockShutdown::Both);
    shared.latency.lock().unwrap().merge(&latency);
}

/// Write the whole buffer with bounded blocking. Retries timeouts
/// (that is the backpressure stall) until the drain deadline passes
/// during shutdown; any real error fails the connection.
fn write_all_bounded(sock: &mut TcpStream, buf: &[u8], shared: &ServerShared) -> bool {
    let mut off = 0;
    while off < buf.len() {
        match sock.write(&buf[off..]) {
            Ok(0) => return false,
            Ok(n) => {
                off += n;
                shared.bytes_out.fetch_add(n as u64, Ordering::Relaxed);
            }
            Err(ref e)
                if e.kind() == ErrorKind::WouldBlock
                    || e.kind() == ErrorKind::TimedOut
                    || e.kind() == ErrorKind::Interrupted =>
            {
                if shared.past_drain_deadline() {
                    return false;
                }
            }
            Err(_) => return false,
        }
    }
    true
}
