//! Incremental RESP2 frame parser and encoder.
//!
//! RESP2 is the Redis serialization protocol: five frame types keyed by
//! the first byte (`+` simple string, `-` error, `:` integer, `$` bulk
//! string, `*` array), each line terminated by CRLF. Clients send
//! commands as arrays of bulk strings (or legacy space-separated
//! *inline* commands); servers reply with any frame type.
//!
//! The parser here is *incremental*: bytes arrive from a TCP stream in
//! arbitrary torn chunks ([`Parser::feed`]), and [`Parser::try_next`]
//! either yields one complete frame, reports that the buffered prefix
//! is still incomplete (`Ok(None)` — feed more bytes), or rejects a
//! malformed prefix with a [`ProtoError`] the connection turns into an
//! `-ERR Protocol error` reply before closing. A frame is consumed from
//! the buffer only when it parses completely, so a torn read never
//! loses or duplicates bytes, and many pipelined frames in one read
//! drain with repeated `try_next` calls.
//!
//! Hostile input is bounded: bulk payloads over [`MAX_BULK`], arrays
//! over [`MAX_ARRAY`] elements, nesting over [`MAX_DEPTH`], and inline
//! lines over [`MAX_INLINE`] are protocol errors, so a client cannot
//! make the server buffer unboundedly by promising a huge frame.

use std::fmt;

/// Upper bound on one bulk-string payload (16 MiB).
pub const MAX_BULK: usize = 16 << 20;
/// Upper bound on one array's element count.
pub const MAX_ARRAY: usize = 1 << 20;
/// Upper bound on array nesting depth (commands are flat arrays;
/// replies nest at most arrays-of-bulks).
pub const MAX_DEPTH: usize = 4;
/// Upper bound on one inline-command line.
pub const MAX_INLINE: usize = 64 << 10;

/// One RESP2 frame.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Frame {
    /// `+OK\r\n`
    Simple(String),
    /// `-ERR message\r\n`
    Error(String),
    /// `:42\r\n`
    Int(i64),
    /// `$3\r\nfoo\r\n`
    Bulk(Vec<u8>),
    /// `$-1\r\n` — the nil bulk (missing value).
    NullBulk,
    /// `*2\r\n<frame><frame>`
    Array(Vec<Frame>),
    /// `*-1\r\n` — the nil array.
    NullArray,
}

impl Frame {
    /// Encode this frame onto `out` in RESP2 wire form.
    pub fn encode_into(&self, out: &mut Vec<u8>) {
        match self {
            Frame::Simple(s) => {
                out.push(b'+');
                out.extend_from_slice(s.as_bytes());
                out.extend_from_slice(b"\r\n");
            }
            Frame::Error(s) => {
                out.push(b'-');
                out.extend_from_slice(s.as_bytes());
                out.extend_from_slice(b"\r\n");
            }
            Frame::Int(i) => {
                out.push(b':');
                out.extend_from_slice(i.to_string().as_bytes());
                out.extend_from_slice(b"\r\n");
            }
            Frame::Bulk(b) => {
                out.push(b'$');
                out.extend_from_slice(b.len().to_string().as_bytes());
                out.extend_from_slice(b"\r\n");
                out.extend_from_slice(b);
                out.extend_from_slice(b"\r\n");
            }
            Frame::NullBulk => out.extend_from_slice(b"$-1\r\n"),
            Frame::Array(items) => {
                out.push(b'*');
                out.extend_from_slice(items.len().to_string().as_bytes());
                out.extend_from_slice(b"\r\n");
                for item in items {
                    item.encode_into(out);
                }
            }
            Frame::NullArray => out.extend_from_slice(b"*-1\r\n"),
        }
    }

    /// Encode to a fresh buffer.
    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::new();
        self.encode_into(&mut out);
        out
    }

    /// A command frame (`*N` of bulks) from string arguments — the
    /// client-side convenience the bench and tests use.
    pub fn command<S: AsRef<[u8]>>(args: &[S]) -> Frame {
        Frame::Array(args.iter().map(|a| Frame::Bulk(a.as_ref().to_vec())).collect())
    }
}

/// A malformed frame. The message is suitable for an
/// `-ERR Protocol error: ...` reply.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ProtoError(pub String);

impl fmt::Display for ProtoError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "Protocol error: {}", self.0)
    }
}

fn proto<T>(msg: impl Into<String>) -> ParseStep<T> {
    Err(ProtoError(msg.into()))
}

/// Internal parse outcome: `Ok(Some(v))` parsed, `Ok(None)` needs more
/// bytes, `Err` malformed.
type ParseStep<T> = std::result::Result<Option<T>, ProtoError>;

/// Incremental RESP2 parser over a growable byte buffer.
#[derive(Default)]
pub struct Parser {
    buf: Vec<u8>,
    /// Consumed prefix length; compacted lazily so repeated torn reads
    /// do not shift the buffer on every frame.
    pos: usize,
}

impl Parser {
    pub fn new() -> Parser {
        Parser::default()
    }

    /// Append freshly read bytes.
    pub fn feed(&mut self, bytes: &[u8]) {
        self.compact();
        self.buf.extend_from_slice(bytes);
    }

    /// Unconsumed bytes currently buffered.
    pub fn buffered(&self) -> usize {
        self.buf.len() - self.pos
    }

    fn compact(&mut self) {
        if self.pos > 0 && (self.pos >= self.buf.len() || self.pos > 4096) {
            self.buf.drain(..self.pos);
            self.pos = 0;
        }
    }

    /// Try to parse one complete frame from the buffered bytes.
    ///
    /// `Ok(None)` means the prefix is a valid but incomplete frame —
    /// nothing is consumed; feed more bytes and retry. `Ok(Some(f))`
    /// consumes exactly that frame. `Err` means the prefix can never
    /// become a valid frame; the connection should report and close.
    pub fn try_next(&mut self) -> ParseStep<Frame> {
        let mut cur = self.pos;
        match parse_frame(&self.buf, &mut cur, 0)? {
            Some(frame) => {
                self.pos = cur;
                self.compact();
                Ok(Some(frame))
            }
            None => Ok(None),
        }
    }
}

/// Find the next CRLF at or after `*cur`; return the line body and
/// advance past the terminator.
fn take_line<'a>(buf: &'a [u8], cur: &mut usize, limit: usize) -> ParseStep<&'a [u8]> {
    let start = *cur;
    let mut i = start;
    while i + 1 < buf.len() {
        if buf[i] == b'\r' && buf[i + 1] == b'\n' {
            *cur = i + 2;
            return Ok(Some(&buf[start..i]));
        }
        if buf[i] == b'\n' {
            return proto("expected \\r\\n line terminator");
        }
        i += 1;
        if i - start > limit {
            return proto("line too long");
        }
    }
    if buf.len() - start > limit {
        return proto("line too long");
    }
    Ok(None)
}

/// Parse a decimal i64 with optional leading `-` (RESP length/integer
/// lines). Rejects empty bodies and non-digit bytes.
fn parse_int(body: &[u8]) -> std::result::Result<i64, ProtoError> {
    let (neg, digits) = match body.split_first() {
        Some((b'-', rest)) => (true, rest),
        _ => (false, body),
    };
    if digits.is_empty() {
        return Err(ProtoError("empty integer".into()));
    }
    let mut v: i64 = 0;
    for &b in digits {
        if !b.is_ascii_digit() {
            return Err(ProtoError("invalid integer byte".into()));
        }
        v = v
            .checked_mul(10)
            .and_then(|v| v.checked_add((b - b'0') as i64))
            .ok_or_else(|| ProtoError("integer out of range".into()))?;
    }
    Ok(if neg { -v } else { v })
}

fn parse_frame(buf: &[u8], cur: &mut usize, depth: usize) -> ParseStep<Frame> {
    if depth > MAX_DEPTH {
        return proto("nesting too deep");
    }
    let Some(&first) = buf.get(*cur) else {
        return Ok(None);
    };
    match first {
        b'+' | b'-' | b':' => {
            *cur += 1;
            let Some(body) = take_line(buf, cur, MAX_INLINE)? else {
                return Ok(None);
            };
            match first {
                b'+' => Ok(Some(Frame::Simple(String::from_utf8_lossy(body).into_owned()))),
                b'-' => Ok(Some(Frame::Error(String::from_utf8_lossy(body).into_owned()))),
                _ => Ok(Some(Frame::Int(parse_int(body)?))),
            }
        }
        b'$' => {
            *cur += 1;
            let Some(body) = take_line(buf, cur, 32)? else {
                return Ok(None);
            };
            let len = parse_int(body)?;
            if len == -1 {
                return Ok(Some(Frame::NullBulk));
            }
            if len < 0 || len as usize > MAX_BULK {
                return proto("invalid bulk length");
            }
            let len = len as usize;
            if buf.len() < *cur + len + 2 {
                return Ok(None);
            }
            let payload = buf[*cur..*cur + len].to_vec();
            if &buf[*cur + len..*cur + len + 2] != b"\r\n" {
                return proto("bulk payload not CRLF-terminated");
            }
            *cur += len + 2;
            Ok(Some(Frame::Bulk(payload)))
        }
        b'*' => {
            *cur += 1;
            let Some(body) = take_line(buf, cur, 32)? else {
                return Ok(None);
            };
            let n = parse_int(body)?;
            if n == -1 {
                return Ok(Some(Frame::NullArray));
            }
            if n < 0 || n as usize > MAX_ARRAY {
                return proto("invalid array length");
            }
            let mut items = Vec::with_capacity((n as usize).min(64));
            for _ in 0..n {
                match parse_frame(buf, cur, depth + 1)? {
                    Some(f) => items.push(f),
                    None => return Ok(None),
                }
            }
            Ok(Some(Frame::Array(items)))
        }
        _ => parse_inline(buf, cur),
    }
}

/// Legacy inline command: a bare line of whitespace-separated words,
/// e.g. `PING\r\n` typed into netcat. Parsed into the same
/// array-of-bulks shape as a regular command frame.
fn parse_inline(buf: &[u8], cur: &mut usize) -> ParseStep<Frame> {
    let Some(body) = take_line(buf, cur, MAX_INLINE)? else {
        return Ok(None);
    };
    let words: Vec<Frame> = body
        .split(|&b| b == b' ' || b == b'\t')
        .filter(|w| !w.is_empty())
        .map(|w| Frame::Bulk(w.to_vec()))
        .collect();
    if words.is_empty() {
        // Empty line between inline commands: tolerated, parse on.
        return parse_frame(buf, cur, 0);
    }
    Ok(Some(Frame::Array(words)))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse_all(input: &[u8]) -> Vec<Frame> {
        let mut p = Parser::new();
        p.feed(input);
        let mut frames = Vec::new();
        while let Some(f) = p.try_next().unwrap() {
            frames.push(f);
        }
        frames
    }

    #[test]
    fn round_trips_every_frame_type() {
        let frames = vec![
            Frame::Simple("OK".into()),
            Frame::Error("ERR boom".into()),
            Frame::Int(-42),
            Frame::Bulk(b"hello".to_vec()),
            Frame::Bulk(Vec::new()),
            Frame::NullBulk,
            Frame::Array(vec![Frame::Bulk(b"GET".to_vec()), Frame::Int(7)]),
            Frame::NullArray,
            Frame::Array(Vec::new()),
        ];
        let mut wire = Vec::new();
        for f in &frames {
            f.encode_into(&mut wire);
        }
        assert_eq!(parse_all(&wire), frames);
    }

    #[test]
    fn byte_at_a_time_feed_yields_identical_frames() {
        let wire = {
            let mut w = Vec::new();
            Frame::command(&["SET", "17", "34"]).encode_into(&mut w);
            Frame::command(&["GET", "17"]).encode_into(&mut w);
            Frame::Simple("OK".into()).encode_into(&mut w);
            w
        };
        let whole = parse_all(&wire);
        let mut p = Parser::new();
        let mut torn = Vec::new();
        for &b in &wire {
            p.feed(&[b]);
            while let Some(f) = p.try_next().unwrap() {
                torn.push(f);
            }
        }
        assert_eq!(torn, whole);
        assert_eq!(p.buffered(), 0);
    }

    #[test]
    fn incomplete_prefixes_consume_nothing() {
        let mut p = Parser::new();
        for prefix in ["*", "*2\r", "*2\r\n$3\r\nGE", "*2\r\n$3\r\nGET\r\n$2\r\n17\r"] {
            let mut q = Parser::new();
            q.feed(prefix.as_bytes());
            assert_eq!(q.try_next().unwrap(), None, "prefix {prefix:?} must be incomplete");
            assert_eq!(q.buffered(), prefix.len(), "incomplete parse must not consume");
        }
        p.feed(b"*1\r\n$4\r\nPING\r\n");
        assert_eq!(
            p.try_next().unwrap().unwrap(),
            Frame::Array(vec![Frame::Bulk(b"PING".to_vec())])
        );
    }

    #[test]
    fn inline_commands_parse_like_arrays() {
        let frames = parse_all(b"PING\r\n  SET   5 6\r\n\r\nGET 5\r\n");
        assert_eq!(frames.len(), 3);
        assert_eq!(frames[0], Frame::command(&["PING"]));
        assert_eq!(frames[1], Frame::command(&["SET", "5", "6"]));
        assert_eq!(frames[2], Frame::command(&["GET", "5"]));
    }

    #[test]
    fn malformed_frames_are_protocol_errors() {
        for bad in [
            b"$abc\r\n".as_slice(),
            b"$-2\r\n",
            b"*-3\r\n",
            b":\r\n",
            b":12a\r\n",
            b"$3\r\nfooXY",          // payload not CRLF-terminated
            b"*1\r\n*1\r\n*1\r\n*1\r\n*1\r\n*1\r\n:1\r\n", // too deep
            b"PING\nX",              // bare \n terminator
        ] {
            let mut p = Parser::new();
            p.feed(bad);
            let mut res = p.try_next();
            // walk frames until the malformed one surfaces
            while let Ok(Some(_)) = res {
                res = p.try_next();
            }
            assert!(res.is_err(), "input {bad:?} must be rejected");
        }
    }

    #[test]
    fn oversized_promises_are_rejected_not_buffered() {
        let mut p = Parser::new();
        p.feed(format!("${}\r\n", MAX_BULK + 1).as_bytes());
        assert!(p.try_next().is_err(), "oversized bulk promise must fail fast");
        let mut p = Parser::new();
        p.feed(format!("*{}\r\n", MAX_ARRAY + 1).as_bytes());
        assert!(p.try_next().is_err(), "oversized array promise must fail fast");
    }

    #[test]
    fn pipelined_burst_drains_in_order() {
        let mut wire = Vec::new();
        for k in 0..100u32 {
            Frame::command(&["SET".to_string(), k.to_string(), (k * 2).to_string()])
                .encode_into(&mut wire);
        }
        let frames = parse_all(&wire);
        assert_eq!(frames.len(), 100);
        for (k, f) in frames.iter().enumerate() {
            let Frame::Array(items) = f else { panic!("not an array") };
            assert_eq!(items[1], Frame::Bulk(k.to_string().into_bytes()));
        }
    }
}
