//! Command decode and reply rendering: RESP frames ⇄ the typed
//! `Op`/`OpResult` plane.
//!
//! Keys and values travel the wire as decimal `u32` strings (the table
//! stores 32-bit pairs; anything non-numeric or ≥ [`EMPTY_KEY`] is an
//! immediate `-ERR` without touching the table). Each command maps to
//! zero or more [`Op`]s:
//!
//! | command                | ops                      | reply |
//! |------------------------|--------------------------|-------|
//! | `GET k`                | `Lookup`                 | bulk value or nil |
//! | `SET k v`              | `Upsert`                 | `+OK` |
//! | `SETNX k v`            | `InsertIfAbsent`         | `:1` inserted / `:0` exists |
//! | `DEL k [k ...]`        | one `Delete` per key     | `:removed` |
//! | `INCRBY k n` / `INCR k`| `FetchAdd` (wrapping u32)| `:new_value` |
//! | `CAS k expected new`   | `Cas`                    | `:1` swapped / `:0` actual differs |
//! | `MGET k [k ...]`       | one `Lookup` per key     | array of bulk/nil |
//! | `MSET k v [k v ...]`   | one `Upsert` per pair    | `+OK` |
//! | `PING [msg]`           | —                        | `+PONG` / bulk echo |
//! | `INFO`                 | — (control-plane stats)  | bulk info text |
//!
//! Multi-key commands submit all their ops into the connection's
//! pipeline window and fold the completed results into one reply, so a
//! 100-key `MGET` enjoys the same in-flight overlap as 100 pipelined
//! `GET`s.

use crate::core::error::HiveError;
use crate::core::packed::EMPTY_KEY;
use crate::net::resp::Frame;
use crate::workload::{Op, OpResult};

/// One decoded client command.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Command {
    Get { key: u32 },
    Set { key: u32, value: u32 },
    SetNx { key: u32, value: u32 },
    Del { keys: Vec<u32> },
    IncrBy { key: u32, delta: u32 },
    Cas { key: u32, expected: u32, new: u32 },
    MGet { keys: Vec<u32> },
    MSet { pairs: Vec<(u32, u32)> },
    Ping { msg: Option<Vec<u8>> },
    Info,
    /// `COMMAND` handshake probe (redis-cli sends it on connect);
    /// answered with an empty array.
    CommandProbe,
    Quit,
}

/// How a command's completed op results fold into one RESP reply.
/// Carried beside the submitted tickets; consumed by the writer.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ReplyShape {
    Get,
    Set,
    SetNx,
    Del,
    /// Reply is the post-add value, reconstructed from the returned
    /// previous value plus this delta.
    IncrBy { delta: u32 },
    Cas,
    MGet,
    MSet,
}

fn ascii_upper(name: &[u8]) -> String {
    name.iter().map(|b| (*b as char).to_ascii_uppercase()).collect()
}

fn wrong_arity(cmd: &str) -> String {
    format!("ERR wrong number of arguments for '{}' command", cmd.to_ascii_lowercase())
}

/// Parse one decimal u32 wire argument (key or value). `EMPTY_KEY`
/// (`u32::MAX`) is reserved by the table and rejected here so one bad
/// key cannot poison a shared dispatch window.
fn parse_u32(arg: &[u8], what: &str) -> Result<u32, String> {
    std::str::from_utf8(arg)
        .ok()
        .and_then(|s| s.parse::<u32>().ok())
        .filter(|&v| !(what == "key" && v == EMPTY_KEY))
        .ok_or_else(|| format!("ERR {what} is not a valid integer ({what}s are decimal u32)"))
}

fn parse_key(arg: &[u8]) -> Result<u32, String> {
    parse_u32(arg, "key")
}

fn parse_value(arg: &[u8]) -> Result<u32, String> {
    parse_u32(arg, "value")
}

/// `INCRBY` deltas are signed on the wire (redis semantics); the table
/// adds mod 2³² so a negative delta is its two's-complement image.
fn parse_delta(arg: &[u8]) -> Result<u32, String> {
    std::str::from_utf8(arg)
        .ok()
        .and_then(|s| s.parse::<i64>().ok())
        .filter(|d| (-(u32::MAX as i64)..=u32::MAX as i64).contains(d))
        .map(|d| d as u32)
        .ok_or_else(|| "ERR value is not an integer or out of range".to_string())
}

impl Command {
    /// Decode a parsed RESP frame into a command, or an error-reply
    /// text (without the leading `-`).
    pub fn parse(frame: &Frame) -> Result<Command, String> {
        let Frame::Array(items) = frame else {
            return Err("ERR Protocol error: expected command array".into());
        };
        let mut args: Vec<&[u8]> = Vec::with_capacity(items.len());
        for item in items {
            match item {
                Frame::Bulk(b) => args.push(b),
                _ => return Err("ERR Protocol error: expected bulk string argument".into()),
            }
        }
        let Some((name, rest)) = args.split_first() else {
            return Err("ERR Protocol error: empty command".into());
        };
        let name = ascii_upper(name);
        match (name.as_str(), rest.len()) {
            ("GET", 1) => Ok(Command::Get { key: parse_key(rest[0])? }),
            ("SET", 2) => {
                Ok(Command::Set { key: parse_key(rest[0])?, value: parse_value(rest[1])? })
            }
            ("SETNX", 2) => {
                Ok(Command::SetNx { key: parse_key(rest[0])?, value: parse_value(rest[1])? })
            }
            ("DEL", n) if n >= 1 => Ok(Command::Del {
                keys: rest.iter().map(|a| parse_key(a)).collect::<Result<_, _>>()?,
            }),
            ("INCRBY", 2) => {
                Ok(Command::IncrBy { key: parse_key(rest[0])?, delta: parse_delta(rest[1])? })
            }
            ("INCR", 1) => Ok(Command::IncrBy { key: parse_key(rest[0])?, delta: 1 }),
            ("DECR", 1) => Ok(Command::IncrBy { key: parse_key(rest[0])?, delta: 1u32.wrapping_neg() }),
            ("CAS", 3) => Ok(Command::Cas {
                key: parse_key(rest[0])?,
                expected: parse_value(rest[1])?,
                new: parse_value(rest[2])?,
            }),
            ("MGET", n) if n >= 1 => Ok(Command::MGet {
                keys: rest.iter().map(|a| parse_key(a)).collect::<Result<_, _>>()?,
            }),
            ("MSET", n) if n >= 2 && n % 2 == 0 => Ok(Command::MSet {
                pairs: rest
                    .chunks(2)
                    .map(|p| Ok((parse_key(p[0])?, parse_value(p[1])?)))
                    .collect::<Result<_, String>>()?,
            }),
            ("PING", 0) => Ok(Command::Ping { msg: None }),
            ("PING", 1) => Ok(Command::Ping { msg: Some(rest[0].to_vec()) }),
            ("INFO", _) => Ok(Command::Info),
            ("COMMAND", _) => Ok(Command::CommandProbe),
            ("QUIT", 0) => Ok(Command::Quit),
            ("GET" | "SET" | "SETNX" | "DEL" | "INCRBY" | "INCR" | "DECR" | "CAS" | "MGET"
            | "MSET" | "PING" | "QUIT", _) => Err(wrong_arity(&name)),
            _ => Err(format!("ERR unknown command '{name}'")),
        }
    }

    /// The typed ops this command submits, plus the reply fold. `None`
    /// for control commands (`PING`/`INFO`/`COMMAND`/`QUIT`) answered
    /// without touching the data plane.
    pub fn to_ops(&self) -> Option<(Vec<Op>, ReplyShape)> {
        match self {
            Command::Get { key } => Some((vec![Op::Lookup { key: *key }], ReplyShape::Get)),
            Command::Set { key, value } => {
                Some((vec![Op::Upsert { key: *key, value: *value }], ReplyShape::Set))
            }
            Command::SetNx { key, value } => Some((
                vec![Op::InsertIfAbsent { key: *key, value: *value }],
                ReplyShape::SetNx,
            )),
            Command::Del { keys } => Some((
                keys.iter().map(|&key| Op::Delete { key }).collect(),
                ReplyShape::Del,
            )),
            Command::IncrBy { key, delta } => Some((
                vec![Op::FetchAdd { key: *key, delta: *delta }],
                ReplyShape::IncrBy { delta: *delta },
            )),
            Command::Cas { key, expected, new } => Some((
                vec![Op::Cas { key: *key, expected: *expected, new: *new }],
                ReplyShape::Cas,
            )),
            Command::MGet { keys } => Some((
                keys.iter().map(|&key| Op::Lookup { key }).collect(),
                ReplyShape::MGet,
            )),
            Command::MSet { pairs } => Some((
                pairs.iter().map(|&(key, value)| Op::Upsert { key, value }).collect(),
                ReplyShape::MSet,
            )),
            Command::Ping { .. } | Command::Info | Command::CommandProbe | Command::Quit => None,
        }
    }

    /// Keys this command touches — the reader serializes same-key
    /// pipelined commands on these (read-your-write per connection).
    pub fn keys(&self) -> Vec<u32> {
        match self {
            Command::Get { key }
            | Command::Set { key, .. }
            | Command::SetNx { key, .. }
            | Command::IncrBy { key, .. }
            | Command::Cas { key, .. } => vec![*key],
            Command::Del { keys } | Command::MGet { keys } => keys.clone(),
            Command::MSet { pairs } => pairs.iter().map(|&(k, _)| k).collect(),
            Command::Ping { .. } | Command::Info | Command::CommandProbe | Command::Quit => {
                Vec::new()
            }
        }
    }
}

/// Map an op error to the RESP error text (sans leading `-`).
fn error_reply(e: &HiveError) -> Frame {
    match e {
        HiveError::Shutdown => Frame::Error("SHUTDOWN server is shutting down".into()),
        other => Frame::Error(format!("ERR {other}")),
    }
}

fn bulk_u32(v: u32) -> Frame {
    Frame::Bulk(v.to_string().into_bytes())
}

/// Fold a command's completed op results into its RESP reply.
///
/// Every result corresponds positionally to the ops from
/// [`Command::to_ops`]. Any op error yields an error reply for the
/// whole command (first error wins), matching the all-or-nothing shape
/// of the typed plane's batch errors.
pub fn render_reply(shape: &ReplyShape, results: &[crate::core::error::Result<OpResult>]) -> Frame {
    if let Some(Err(e)) = results.iter().find(|r| r.is_err()) {
        return error_reply(e);
    }
    let ok = |i: usize| results[i].as_ref().unwrap();
    match shape {
        ReplyShape::Get => match ok(0) {
            OpResult::Value(Some(v)) => bulk_u32(*v),
            OpResult::Value(None) => Frame::NullBulk,
            other => unexpected(other),
        },
        ReplyShape::Set | ReplyShape::MSet => {
            for r in results {
                if !matches!(r.as_ref().unwrap(), OpResult::Upserted { .. }) {
                    return unexpected(r.as_ref().unwrap());
                }
            }
            Frame::Simple("OK".into())
        }
        ReplyShape::SetNx => match ok(0) {
            OpResult::InsertedIfAbsent { existing: None, .. } => Frame::Int(1),
            OpResult::InsertedIfAbsent { existing: Some(_), .. } => Frame::Int(0),
            other => unexpected(other),
        },
        ReplyShape::Del => {
            let mut removed = 0i64;
            for r in results {
                match r.as_ref().unwrap() {
                    OpResult::Deleted(true) => removed += 1,
                    OpResult::Deleted(false) => {}
                    other => return unexpected(other),
                }
            }
            Frame::Int(removed)
        }
        ReplyShape::IncrBy { delta } => match ok(0) {
            // absent key: fetch_add creates it holding `delta`
            OpResult::FetchAdded { old, .. } => {
                Frame::Int(old.map_or(*delta, |o| o.wrapping_add(*delta)) as i64)
            }
            other => unexpected(other),
        },
        ReplyShape::Cas => match ok(0) {
            OpResult::Cas { ok: true, .. } => Frame::Int(1),
            OpResult::Cas { ok: false, .. } => Frame::Int(0),
            other => unexpected(other),
        },
        ReplyShape::MGet => Frame::Array(
            results
                .iter()
                .map(|r| match r.as_ref().unwrap() {
                    OpResult::Value(Some(v)) => bulk_u32(*v),
                    OpResult::Value(None) => Frame::NullBulk,
                    other => unexpected(other),
                })
                .collect(),
        ),
    }
}

fn unexpected(r: &OpResult) -> Frame {
    // Reaching this means the coordinator returned a result class that
    // does not match the submitted op — surface it instead of lying.
    Frame::Error(format!("ERR internal: unexpected result {r:?}"))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::native::table::InsertOutcome;

    fn cmd(args: &[&str]) -> Result<Command, String> {
        Command::parse(&Frame::command(args))
    }

    #[test]
    fn parses_the_full_command_set() {
        assert_eq!(cmd(&["get", "7"]).unwrap(), Command::Get { key: 7 });
        assert_eq!(cmd(&["SET", "7", "9"]).unwrap(), Command::Set { key: 7, value: 9 });
        assert_eq!(cmd(&["SeTnX", "1", "2"]).unwrap(), Command::SetNx { key: 1, value: 2 });
        assert_eq!(cmd(&["DEL", "1", "2", "3"]).unwrap(), Command::Del { keys: vec![1, 2, 3] });
        assert_eq!(cmd(&["INCRBY", "5", "10"]).unwrap(), Command::IncrBy { key: 5, delta: 10 });
        assert_eq!(cmd(&["INCR", "5"]).unwrap(), Command::IncrBy { key: 5, delta: 1 });
        assert_eq!(
            cmd(&["DECR", "5"]).unwrap(),
            Command::IncrBy { key: 5, delta: 1u32.wrapping_neg() }
        );
        assert_eq!(
            cmd(&["INCRBY", "5", "-3"]).unwrap(),
            Command::IncrBy { key: 5, delta: 3u32.wrapping_neg() }
        );
        assert_eq!(
            cmd(&["CAS", "5", "1", "2"]).unwrap(),
            Command::Cas { key: 5, expected: 1, new: 2 }
        );
        assert_eq!(cmd(&["MGET", "1", "2"]).unwrap(), Command::MGet { keys: vec![1, 2] });
        assert_eq!(
            cmd(&["MSET", "1", "10", "2", "20"]).unwrap(),
            Command::MSet { pairs: vec![(1, 10), (2, 20)] }
        );
        assert_eq!(cmd(&["PING"]).unwrap(), Command::Ping { msg: None });
        assert_eq!(
            cmd(&["PING", "hi"]).unwrap(),
            Command::Ping { msg: Some(b"hi".to_vec()) }
        );
        assert_eq!(cmd(&["INFO"]).unwrap(), Command::Info);
        assert_eq!(cmd(&["QUIT"]).unwrap(), Command::Quit);
    }

    #[test]
    fn rejects_bad_arity_unknown_names_and_bad_integers() {
        assert!(cmd(&["GET"]).unwrap_err().contains("wrong number of arguments"));
        assert!(cmd(&["SET", "1"]).unwrap_err().contains("wrong number of arguments"));
        assert!(cmd(&["MSET", "1", "2", "3"]).unwrap_err().contains("wrong number"));
        assert!(cmd(&["FLUSHALL"]).unwrap_err().contains("unknown command 'FLUSHALL'"));
        assert!(cmd(&["GET", "abc"]).unwrap_err().contains("not a valid integer"));
        assert!(cmd(&["SET", "1", "-2"]).unwrap_err().contains("not a valid integer"));
        assert!(cmd(&["INCRBY", "1", "zzz"]).unwrap_err().contains("not an integer"));
        // EMPTY_KEY is reserved by the table
        assert!(cmd(&["GET", &EMPTY_KEY.to_string()]).is_err());
        // non-array and non-bulk-arg frames are protocol errors
        assert!(Command::parse(&Frame::Int(1)).unwrap_err().contains("Protocol error"));
        assert!(Command::parse(&Frame::Array(vec![Frame::Int(1)]))
            .unwrap_err()
            .contains("Protocol error"));
    }

    #[test]
    fn ops_mapping_matches_the_table() {
        let (ops, shape) = cmd(&["DEL", "1", "2"]).unwrap().to_ops().unwrap();
        assert_eq!(ops, vec![Op::Delete { key: 1 }, Op::Delete { key: 2 }]);
        assert_eq!(shape, ReplyShape::Del);
        let (ops, _) = cmd(&["MSET", "1", "10", "2", "20"]).unwrap().to_ops().unwrap();
        assert_eq!(
            ops,
            vec![Op::Upsert { key: 1, value: 10 }, Op::Upsert { key: 2, value: 20 }]
        );
        assert!(cmd(&["PING"]).unwrap().to_ops().is_none());
        assert_eq!(cmd(&["MSET", "1", "10", "2", "20"]).unwrap().keys(), vec![1, 2]);
    }

    #[test]
    fn renders_replies_per_shape() {
        let get = |r| render_reply(&ReplyShape::Get, &[Ok(r)]);
        assert_eq!(get(OpResult::Value(Some(9))), Frame::Bulk(b"9".to_vec()));
        assert_eq!(get(OpResult::Value(None)), Frame::NullBulk);
        assert_eq!(
            render_reply(
                &ReplyShape::Set,
                &[Ok(OpResult::Upserted { outcome: InsertOutcome::Inserted, old: None })]
            ),
            Frame::Simple("OK".into())
        );
        assert_eq!(
            render_reply(
                &ReplyShape::Del,
                &[Ok(OpResult::Deleted(true)), Ok(OpResult::Deleted(false)), Ok(OpResult::Deleted(true))]
            ),
            Frame::Int(2)
        );
        assert_eq!(
            render_reply(
                &ReplyShape::IncrBy { delta: 5 },
                &[Ok(OpResult::FetchAdded { outcome: None, old: Some(7) })]
            ),
            Frame::Int(12)
        );
        assert_eq!(
            render_reply(
                &ReplyShape::IncrBy { delta: 5 },
                &[Ok(OpResult::FetchAdded { outcome: Some(InsertOutcome::Inserted), old: None })]
            ),
            Frame::Int(5)
        );
        // wrapping subtraction: 3 + (-5 as u32) ≡ 2³² - 2
        assert_eq!(
            render_reply(
                &ReplyShape::IncrBy { delta: 5u32.wrapping_neg() },
                &[Ok(OpResult::FetchAdded { outcome: None, old: Some(3) })]
            ),
            Frame::Int((3u32.wrapping_sub(5)) as i64)
        );
        assert_eq!(
            render_reply(&ReplyShape::Cas, &[Ok(OpResult::Cas { ok: true, actual: Some(1) })]),
            Frame::Int(1)
        );
        assert_eq!(
            render_reply(
                &ReplyShape::MGet,
                &[Ok(OpResult::Value(Some(1))), Ok(OpResult::Value(None))]
            ),
            Frame::Array(vec![Frame::Bulk(b"1".to_vec()), Frame::NullBulk])
        );
        // any error fails the whole command; Shutdown gets its own code
        assert_eq!(
            render_reply(
                &ReplyShape::Del,
                &[Ok(OpResult::Deleted(true)), Err(HiveError::Shutdown)]
            ),
            Frame::Error("SHUTDOWN server is shutting down".into())
        );
    }
}
