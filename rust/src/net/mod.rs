//! The network front door: a RESP2-compatible TCP server over the
//! pipelined coordinator plane.
//!
//! ```text
//!   redis-cli / memtier / any RESP2 client
//!        │ TCP (pipelined commands)
//!        ▼
//!   ┌────────────────────────────────────────────────┐
//!   │ net::NetServer                                 │
//!   │  acceptor ──► per-connection reader / writer   │
//!   │   reader: resp::Parser ─► command::Command     │
//!   │           ─► Op(s) ─► Pipeline::submit         │
//!   │   writer: Ticket::wait ─► OpResult(s)          │
//!   │           ─► command::render_reply ─► socket   │
//!   └────────────────────────────────────────────────┘
//!        │ completion tickets (bounded window)
//!        ▼
//!   coordinator::Handle → sharded workers → HiveTable
//! ```
//!
//! The three submodules split along the wire/meaning/mechanics axes:
//!
//! * [`resp`] — the RESP2 frame grammar: an incremental parser
//!   tolerant of torn reads and pipelined bursts, and the encoder.
//! * [`command`] — the command set (`GET`/`SET`/`SETNX`/`DEL`/
//!   `INCRBY`/`CAS`/`MGET`/`MSET`/`PING`/`INFO`) and its two-way
//!   mapping onto the typed `Op`/`OpResult` plane.
//! * [`server`] — threads, sockets, backpressure, same-key ordering,
//!   stats, and deadline-bounded graceful shutdown.
//!
//! `SERVING.md` at the repo root documents the externally visible
//! contract: command semantics, pipelining and ordering guarantees,
//! backpressure behavior, and what shutdown promises a live client.

pub mod command;
pub mod resp;
pub mod server;

pub use command::Command;
pub use resp::{Frame, Parser, ProtoError};
pub use server::{NetConfig, NetServer};
