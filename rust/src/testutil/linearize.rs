//! History recording + Wing–Gong linearizability checking over the typed
//! [`Op`]/[`OpResult`] plane.
//!
//! A concurrent run records, per worker thread, each operation's
//! *invocation* and *response* instants (ticks of one shared atomic
//! counter — a total order consistent with real time, since the
//! invocation tick is taken before the call and the response tick after
//! it returns). [`check`] then searches for a witness: a single
//! sequential order of all operations that (a) respects real time — an
//! operation that responded before another was invoked must come first —
//! and (b) replays correctly against the sequential specification, a
//! fold over `BTreeMap<u32, u32>` with exactly the semantics the typed
//! result plane documents.
//!
//! The search is the Wing–Gong algorithm with Lowe's memoization: pick
//! any *minimal* remaining operation (one invoked before every remaining
//! response) whose recorded result matches the spec state, apply it,
//! recurse; prune revisited `(linearized-set, state)` pairs. That is
//! exponential in the worst case, so we exploit the Herlihy–Wing
//! locality theorem: every `Op` touches exactly one key, a history is
//! linearizable iff each per-key subhistory is, and per-key subhistories
//! stay small when tests spread load over a bounded key set. Each
//! subhistory is capped at 128 operations (the memo mask is a `u128`);
//! [`check`] reports oversized keys as an error rather than silently
//! sampling.
//!
//! Results are compared under the same normalization the differential
//! suite (`tests/test_ops.rs`) uses: the placement detail of
//! [`InsertOutcome`](crate::native::table::InsertOutcome) (direct /
//! evicted / stashed) is representation, not semantics, so only the
//! result class, the observed previous value, and the effect flag are
//! matched.

use crate::workload::{Op, OpResult};
use std::collections::{BTreeMap, HashMap, HashSet};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

/// One completed operation in a recorded history.
#[derive(Debug, Clone, Copy)]
pub struct Entry {
    /// Recording thread (diagnostic only — the checker uses ticks).
    pub thread: usize,
    pub op: Op,
    pub result: OpResult,
    /// Tick taken immediately before the call was issued.
    pub inv: u64,
    /// Tick taken immediately after the call returned.
    pub res: u64,
}

/// Shared tick source for one recorded run.
#[derive(Default)]
pub struct Recorder {
    clock: AtomicU64,
}

impl Recorder {
    pub fn new() -> Arc<Recorder> {
        Arc::new(Recorder::default())
    }
}

/// Per-thread event log. Owned by exactly one worker thread, merged into
/// a [`History`] after joining, so recording itself never contends on
/// anything but the tick counter.
pub struct ThreadLog {
    recorder: Arc<Recorder>,
    thread: usize,
    entries: Vec<Entry>,
}

impl ThreadLog {
    pub fn new(recorder: &Arc<Recorder>, thread: usize) -> ThreadLog {
        ThreadLog { recorder: Arc::clone(recorder), thread, entries: Vec::new() }
    }

    /// Run `f` (which must perform `op` against the system under test)
    /// between two ticks and log the completed operation.
    pub fn record(&mut self, op: Op, f: impl FnOnce() -> OpResult) -> OpResult {
        let inv = self.recorder.clock.fetch_add(1, Ordering::SeqCst);
        let result = f();
        let res = self.recorder.clock.fetch_add(1, Ordering::SeqCst);
        self.entries.push(Entry { thread: self.thread, op, result, inv, res });
        result
    }
}

/// A complete multi-threaded history.
pub struct History {
    pub entries: Vec<Entry>,
}

impl History {
    pub fn from_logs(logs: Vec<ThreadLog>) -> History {
        let mut entries: Vec<Entry> = logs.into_iter().flat_map(|l| l.entries).collect();
        entries.sort_by_key(|e| e.inv);
        History { entries }
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }
}

/// Why a history failed the check.
pub enum Violation {
    /// No legal sequential witness exists for this key's subhistory.
    NotLinearizable { key: u32, subhistory: Vec<Entry> },
    /// A per-key subhistory exceeded the checker's 128-op bound; the
    /// recording test must spread its ops over more keys.
    TooLarge { key: u32, len: usize },
}

impl std::fmt::Debug for Violation {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Violation::TooLarge { key, len } => {
                write!(f, "subhistory for key {key} has {len} ops (checker bound is 128)")
            }
            Violation::NotLinearizable { key, subhistory } => {
                writeln!(f, "no linearization exists for key {key}; subhistory:")?;
                for e in subhistory {
                    writeln!(
                        f,
                        "  t{:<2} [{:>6},{:>6}] {:?} -> {:?}",
                        e.thread, e.inv, e.res, e.op, e.result
                    )?;
                }
                Ok(())
            }
        }
    }
}

/// Result-class + observable-effect normalization (mirrors the
/// differential suite): `(class, observed old/actual value, effect)`.
type Norm = (u8, Option<u32>, bool);

fn norm(r: &OpResult) -> Norm {
    match *r {
        OpResult::Value(v) => (0, v, false),
        OpResult::Deleted(hit) => (1, None, hit),
        OpResult::Upserted { old, .. } => (2, old, true),
        OpResult::InsertedIfAbsent { existing, .. } => (3, existing, existing.is_none()),
        OpResult::Updated { old } => (4, old, old.is_some()),
        OpResult::Cas { ok, actual } => (5, actual, ok),
        OpResult::FetchAdded { old, .. } => (6, old, old.is_none()),
    }
}

/// The sequential specification: fold one op into the model map and
/// return its normalized result.
pub fn spec_apply(map: &mut BTreeMap<u32, u32>, op: &Op) -> Norm {
    match *op {
        Op::Insert { key, value } | Op::Upsert { key, value } => (2, map.insert(key, value), true),
        Op::Lookup { key } => (0, map.get(&key).copied(), false),
        Op::Delete { key } => (1, None, map.remove(&key).is_some()),
        Op::InsertIfAbsent { key, value } => {
            let existing = map.get(&key).copied();
            if existing.is_none() {
                map.insert(key, value);
            }
            (3, existing, existing.is_none())
        }
        Op::Update { key, value } => {
            let old = map.get(&key).copied();
            if old.is_some() {
                map.insert(key, value);
            }
            (4, old, old.is_some())
        }
        Op::Cas { key, expected, new } => {
            let actual = map.get(&key).copied();
            let ok = actual == Some(expected);
            if ok {
                map.insert(key, new);
            }
            (5, actual, ok)
        }
        Op::FetchAdd { key, delta } => {
            let old = map.get(&key).copied();
            map.insert(key, old.unwrap_or(0).wrapping_add(delta));
            (6, old, old.is_none())
        }
    }
}

/// Wing–Gong search over one key's subhistory (≤ 128 ops). `start` is
/// the key's initial value (always `None` in our tests — tables start
/// empty and pre-population is recorded too when it matters).
fn linearizable_key(key: u32, ops: &[Entry], start: Option<u32>) -> bool {
    let n = ops.len();
    debug_assert!(n <= 128);
    let norms: Vec<Norm> = ops.iter().map(|e| norm(&e.result)).collect();
    let full: u128 = if n == 128 { u128::MAX } else { (1u128 << n) - 1 };
    let mut seen: HashSet<(u128, Option<u32>)> = HashSet::new();
    // Explicit DFS stack: (done-mask, key state). Recomputing candidate
    // sets per pop keeps the frame small; histories here are short.
    let mut stack: Vec<(u128, Option<u32>)> = vec![(0, start)];
    while let Some((done, state)) = stack.pop() {
        if done == full {
            return true;
        }
        if !seen.insert((done, state)) {
            continue;
        }
        // Earliest response among remaining ops: a remaining op may be
        // linearized next only if it was invoked before that response
        // (otherwise some remaining op wholly precedes it in real time).
        let mut min_res = u64::MAX;
        for (i, e) in ops.iter().enumerate() {
            if done & (1u128 << i) == 0 {
                min_res = min_res.min(e.res);
            }
        }
        for (i, e) in ops.iter().enumerate() {
            if done & (1u128 << i) != 0 || e.inv > min_res {
                continue;
            }
            let mut map = BTreeMap::new();
            if let Some(v) = state {
                map.insert(key, v);
            }
            if spec_apply(&mut map, &e.op) == norms[i] {
                stack.push((done | (1u128 << i), map.get(&key).copied()));
            }
        }
    }
    false
}

/// Check a recorded history for linearizability against the sequential
/// `BTreeMap` spec. Decomposes per key (Herlihy–Wing locality — every
/// `Op` touches exactly one key).
pub fn check(history: &History) -> Result<(), Violation> {
    let mut by_key: HashMap<u32, Vec<Entry>> = HashMap::new();
    for e in &history.entries {
        by_key.entry(e.op.key()).or_default().push(*e);
    }
    let mut keys: Vec<u32> = by_key.keys().copied().collect();
    keys.sort_unstable();
    for key in keys {
        let sub = &by_key[&key];
        if sub.len() > 128 {
            return Err(Violation::TooLarge { key, len: sub.len() });
        }
        if !linearizable_key(key, sub, None) {
            return Err(Violation::NotLinearizable { key, subhistory: sub.clone() });
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn entry(thread: usize, op: Op, result: OpResult, inv: u64, res: u64) -> Entry {
        Entry { thread, op, result, inv, res }
    }

    fn upserted(old: Option<u32>) -> OpResult {
        OpResult::Upserted { outcome: crate::native::table::InsertOutcome::Inserted, old }
    }

    #[test]
    fn accepts_sequential_history() {
        let h = History {
            entries: vec![
                entry(0, Op::Insert { key: 1, value: 10 }, upserted(None), 0, 1),
                entry(0, Op::Lookup { key: 1 }, OpResult::Value(Some(10)), 2, 3),
                entry(0, Op::Delete { key: 1 }, OpResult::Deleted(true), 4, 5),
                entry(0, Op::Lookup { key: 1 }, OpResult::Value(None), 6, 7),
            ],
        };
        check(&h).unwrap();
    }

    #[test]
    fn accepts_overlap_that_requires_reordering() {
        // The lookup overlaps the insert and already observes its value:
        // legal only because the insert may linearize first despite
        // responding later.
        let h = History {
            entries: vec![
                entry(0, Op::Insert { key: 1, value: 10 }, upserted(None), 0, 5),
                entry(1, Op::Lookup { key: 1 }, OpResult::Value(Some(10)), 1, 2),
            ],
        };
        check(&h).unwrap();
    }

    #[test]
    fn rejects_lost_update() {
        // Two non-overlapping fetch-adds both claiming old == None: the
        // second must have observed the first.
        let h = History {
            entries: vec![
                entry(
                    0,
                    Op::FetchAdd { key: 1, delta: 1 },
                    OpResult::FetchAdded { outcome: None, old: None },
                    0,
                    1,
                ),
                entry(
                    1,
                    Op::FetchAdd { key: 1, delta: 1 },
                    OpResult::FetchAdded { outcome: None, old: None },
                    2,
                    3,
                ),
            ],
        };
        assert!(matches!(check(&h), Err(Violation::NotLinearizable { key: 1, .. })));
    }

    #[test]
    fn rejects_stale_read_after_response() {
        // Insert fully responded before the lookup was invoked, yet the
        // lookup missed: no witness order can explain it.
        let h = History {
            entries: vec![
                entry(0, Op::Insert { key: 7, value: 70 }, upserted(None), 0, 1),
                entry(1, Op::Lookup { key: 7 }, OpResult::Value(None), 2, 3),
            ],
        };
        assert!(matches!(check(&h), Err(Violation::NotLinearizable { key: 7, .. })));
    }

    #[test]
    fn cross_key_histories_decompose() {
        // A bad key must be reported even when other keys are clean.
        let h = History {
            entries: vec![
                entry(0, Op::Insert { key: 1, value: 10 }, upserted(None), 0, 1),
                entry(0, Op::Lookup { key: 2 }, OpResult::Value(Some(9)), 2, 3),
            ],
        };
        assert!(matches!(check(&h), Err(Violation::NotLinearizable { key: 2, .. })));
    }

    #[test]
    fn rejects_oversized_subhistory() {
        let entries: Vec<Entry> = (0..129)
            .map(|i| {
                entry(0, Op::Lookup { key: 1 }, OpResult::Value(None), 2 * i as u64, 2 * i as u64 + 1)
            })
            .collect();
        assert!(matches!(check(&History { entries }), Err(Violation::TooLarge { key: 1, len: 129 })));
    }
}
