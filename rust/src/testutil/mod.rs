//! Test-harness utilities shared by the integration suites (see
//! `TESTING.md` for the full verification-tier inventory).
//!
//! * [`linearize`] — history recorder + Wing–Gong linearizability checker
//!   over the typed [`crate::workload::Op`]/[`crate::workload::OpResult`]
//!   plane.
//! * [`seed`] — `HIVE_TEST_SEED` plumbing, so every randomized suite
//!   reproduces from the CI seed-matrix line alone.

pub mod linearize;
pub mod seed;
