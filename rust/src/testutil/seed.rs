//! `HIVE_TEST_SEED` plumbing.
//!
//! Every randomized test derives its generator state from the one
//! environment knob the CI seed matrix sweeps, so a failure line like
//! `HIVE_TEST_SEED=2` is a complete reproduction recipe. Suites that need
//! several independent streams derive them with [`stream`] instead of
//! hardcoding unrelated literals.

/// The base seed: `HIVE_TEST_SEED` when set and parseable, else `default`
/// (each suite keeps its own historical default so unseeded local runs
/// stay byte-identical to pre-harness behaviour).
pub fn test_seed(default: u64) -> u64 {
    std::env::var("HIVE_TEST_SEED").ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

/// Derive an independent deterministic stream from `(base, salt)` — one
/// splitmix64 round, the standard seeding finalizer for xoshiro-family
/// generators. Distinct salts give effectively uncorrelated streams of
/// the same base seed.
pub fn stream(base: u64, salt: u64) -> u64 {
    let mut z = base ^ salt.wrapping_mul(0x9E37_79B9_7F4A_7C15);
    z = z.wrapping_add(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn streams_are_deterministic_and_distinct() {
        assert_eq!(stream(1, 0), stream(1, 0));
        assert_ne!(stream(1, 0), stream(1, 1));
        assert_ne!(stream(1, 0), stream(2, 0));
    }
}
