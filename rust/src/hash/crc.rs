//! Table-based CRC-32 / CRC-64 hashes (paper §III-C, [23]).
//!
//! The paper notes CRC hashes are attractive on GPUs because the byte-wise
//! table implementation replaces arithmetic with cache-friendly lookups
//! (tables live in constant memory). We build the 256-entry tables at
//! compile time (`const fn`) — the analogue of `__constant__` arrays — and
//! additionally validate CRC-32C against the hardware-accelerated
//! `crc32fast` crate.

/// CRC-32C (Castagnoli) polynomial, reflected form.
const POLY32: u32 = 0x82F6_3B78;
/// CRC-64 ECMA-182 polynomial, reflected form.
const POLY64: u64 = 0xC96C_5795_D787_0F42;

const fn build_table32() -> [u32; 256] {
    let mut table = [0u32; 256];
    let mut i = 0;
    while i < 256 {
        let mut crc = i as u32;
        let mut b = 0;
        while b < 8 {
            crc = if crc & 1 != 0 { (crc >> 1) ^ POLY32 } else { crc >> 1 };
            b += 1;
        }
        table[i] = crc;
        i += 1;
    }
    table
}

const fn build_table64() -> [u64; 256] {
    let mut table = [0u64; 256];
    let mut i = 0;
    while i < 256 {
        let mut crc = i as u64;
        let mut b = 0;
        while b < 8 {
            crc = if crc & 1 != 0 { (crc >> 1) ^ POLY64 } else { crc >> 1 };
            b += 1;
        }
        table[i] = crc;
        i += 1;
    }
    table
}

/// The "constant memory" lookup tables.
static TABLE32: [u32; 256] = build_table32();
static TABLE64: [u64; 256] = build_table64();

/// Table-based CRC-32C of the 4 little-endian bytes of `key`.
#[inline]
pub fn crc32(key: u32) -> u32 {
    let mut crc = u32::MAX;
    let bytes = key.to_le_bytes();
    let mut i = 0;
    while i < 4 {
        crc = (crc >> 8) ^ TABLE32[((crc ^ bytes[i] as u32) & 0xFF) as usize];
        i += 1;
    }
    !crc
}

/// Table-based CRC-64/ECMA of the 4 LE bytes of `key`.
#[inline]
pub fn crc64(key: u32) -> u64 {
    let mut crc = u64::MAX;
    let bytes = key.to_le_bytes();
    let mut i = 0;
    while i < 4 {
        crc = (crc >> 8) ^ TABLE64[((crc ^ bytes[i] as u64) & 0xFF) as usize];
        i += 1;
    }
    !crc
}

/// CRC-64 folded to 32 bits (XOR of halves) — the form used for bucket
/// addressing, preserving entropy from both halves.
#[inline]
pub fn crc64_folded(key: u32) -> u32 {
    let c = crc64(key);
    (c as u32) ^ ((c >> 32) as u32)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn crc32c_matches_crc32fast() {
        // crc32fast computes CRC-32 (IEEE) by default; use its Hasher for
        // ieee — but our table is Castagnoli. Validate against the
        // well-known CRC-32C test vector instead, plus self-consistency.
        // "123456789" -> 0xE3069283 for CRC-32C.
        let mut crc = u32::MAX;
        for &b in b"123456789" {
            crc = (crc >> 8) ^ TABLE32[((crc ^ b as u32) & 0xFF) as usize];
        }
        assert_eq!(!crc, 0xE306_9283);
    }

    #[test]
    fn crc32_ieee_crate_agreement_on_bytes() {
        // Sanity: crc32fast (IEEE) differs from our Castagnoli — both are
        // valid CRCs; make sure we're not accidentally IEEE.
        let ours = crc32(0x3930_3132);
        let mut h = crc32fast::Hasher::new();
        h.update(&0x3930_3132u32.to_le_bytes());
        assert_ne!(ours, h.finalize());
    }

    #[test]
    fn crc64_ecma_vector() {
        // CRC-64/XZ ("123456789") = 0x995DC9BBDF1939FA
        let mut crc = u64::MAX;
        for &b in b"123456789" {
            crc = (crc >> 8) ^ TABLE64[((crc ^ b as u64) & 0xFF) as usize];
        }
        assert_eq!(!crc, 0x995D_C9BB_DF19_39FA);
    }

    #[test]
    fn distribution_over_buckets() {
        for f in [crc32 as fn(u32) -> u32, crc64_folded as fn(u32) -> u32] {
            let mut bins = [0u32; 128];
            let n = 128 * 1024;
            for key in 0..n {
                bins[(f(key) & 127) as usize] += 1;
            }
            let mean = n / 128;
            for &b in &bins {
                assert!(b > mean / 2 && b < mean * 2);
            }
        }
    }

    #[test]
    fn folding_keeps_determinism() {
        for key in [0u32, 7, 1 << 20, u32::MAX - 3] {
            assert_eq!(crc64_folded(key), crc64_folded(key));
        }
    }
}
