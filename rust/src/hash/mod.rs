//! Hash functions and the bucket-addressing policy (paper §III-C).
//!
//! The paper evaluates six non-cryptographic functions — BitHash1, BitHash2
//! (GPU-oriented Jenkins/Wang-style bit mixers), MurmurHash, CityHash, and
//! table-based CRC-32 / CRC-64 — and adopts the `BitHash1 & BitHash2` pair
//! as the default two-function cuckoo family (Fig. 5).
//!
//! Bucket addressing is *linear hashing*: the table exposes `index_mask`
//! (2^m − 1) and `split_ptr`; a hash is first reduced with `index_mask`, and
//! buckets below `split_ptr` (already split this round) are re-reduced with
//! the next round's mask (§IV-C).

pub mod bithash;
pub mod murmur;
pub mod city;
pub mod crc;
pub mod stats;

pub use bithash::{bithash1, bithash2};
pub use city::city32;
pub use murmur::murmur3_32;

/// Identifies one hash function of the evaluated family.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum HashKind {
    /// Thomas-Wang-style 32-bit mixer (paper Listing 1, `BitHash1`).
    BitHash1,
    /// Bob-Jenkins-style 6-shift mixer (paper Listing 1, `BitHash2`).
    BitHash2,
    /// MurmurHash3 32-bit finalizer-based integer hash.
    Murmur3,
    /// CityHash-style 32-bit integer hash.
    City32,
    /// Table-based CRC-32 (Castagnoli polynomial).
    Crc32,
    /// Table-based CRC-64 (ECMA polynomial), folded to 32 bits.
    Crc64,
}

impl HashKind {
    /// All kinds in the order the paper's Fig. 3 lists them.
    pub const ALL: [HashKind; 6] = [
        HashKind::Crc32,
        HashKind::Crc64,
        HashKind::City32,
        HashKind::Murmur3,
        HashKind::BitHash1,
        HashKind::BitHash2,
    ];

    /// Hash a 32-bit key to 32 bits of mixed output.
    #[inline]
    pub fn hash(self, key: u32) -> u32 {
        match self {
            HashKind::BitHash1 => bithash::bithash1(key),
            HashKind::BitHash2 => bithash::bithash2(key),
            HashKind::Murmur3 => murmur::murmur3_32(key),
            HashKind::City32 => city::city32(key),
            HashKind::Crc32 => crc::crc32(key),
            HashKind::Crc64 => crc::crc64_folded(key),
        }
    }

    /// Whether [`HashKind::invert`] exists for this function. The bit
    /// mixers and Murmur3 are compositions of bijections on u32; the
    /// byte-folding CityHash and the CRC folds are not invertible, so the
    /// quotiented compact layout (which must reconstruct keys from stored
    /// remainders) rejects them at config validation.
    #[inline]
    pub fn invertible(self) -> bool {
        matches!(self, HashKind::BitHash1 | HashKind::BitHash2 | HashKind::Murmur3)
    }

    /// Exact inverse of [`HashKind::hash`] for the invertible kinds.
    ///
    /// # Panics
    /// Panics for the non-invertible kinds (`City32`, `Crc32`, `Crc64`);
    /// config validation keeps those away from any caller.
    #[inline]
    pub fn invert(self, h: u32) -> u32 {
        match self {
            HashKind::BitHash1 => bithash::bithash1_inv(h),
            HashKind::BitHash2 => bithash::bithash2_inv(h),
            HashKind::Murmur3 => murmur::murmur3_32_inv(h),
            _ => panic!("{self:?} is not invertible"),
        }
    }

    /// Parse a lowercase name (config files / CLI).
    pub fn parse(s: &str) -> Option<HashKind> {
        Some(match s {
            "bithash1" => HashKind::BitHash1,
            "bithash2" => HashKind::BitHash2,
            "murmur3" | "murmur" => HashKind::Murmur3,
            "city32" | "city" => HashKind::City32,
            "crc32" => HashKind::Crc32,
            "crc64" => HashKind::Crc64,
            _ => return None,
        })
    }

    /// Display name used in benchmark tables.
    pub fn name(self) -> &'static str {
        match self {
            HashKind::BitHash1 => "BitHash1",
            HashKind::BitHash2 => "BitHash2",
            HashKind::Murmur3 => "MurmurHash",
            HashKind::City32 => "CityHash",
            HashKind::Crc32 => "CRC32",
            HashKind::Crc64 => "CRC64",
        }
    }
}

/// An ordered family of `d` hash functions (d = 2 by default) plus the
/// linear-hashing address reduction.
#[derive(Debug, Clone)]
pub struct HashFamily {
    kinds: Vec<HashKind>,
}

impl HashFamily {
    /// Build from an ordered list of kinds (`d = kinds.len()`).
    pub fn new(kinds: Vec<HashKind>) -> Self {
        assert!(!kinds.is_empty());
        HashFamily { kinds }
    }

    /// The paper's default family: BitHash1 & BitHash2.
    pub fn default_pair() -> Self {
        HashFamily::new(vec![HashKind::BitHash1, HashKind::BitHash2])
    }

    /// Number of hash functions `d`.
    #[inline]
    pub fn d(&self) -> usize {
        self.kinds.len()
    }

    /// Raw 32-bit hash of `key` under function `i`.
    #[inline]
    pub fn raw(&self, i: usize, key: u32) -> u32 {
        self.kinds[i].hash(key)
    }

    /// Kinds in order.
    pub fn kinds(&self) -> &[HashKind] {
        &self.kinds
    }

    /// Linear-hashing bucket address for hash `h`:
    /// `b = h & index_mask; if b < split_ptr { b = h & next_mask }`.
    #[inline(always)]
    pub fn address(h: u32, index_mask: u32, split_ptr: u32) -> u32 {
        let b = h & index_mask;
        if b < split_ptr {
            h & ((index_mask << 1) | 1)
        } else {
            b
        }
    }

    /// Candidate bucket for `key` under function `i` with the current
    /// linear-hashing round state.
    #[inline]
    pub fn bucket(&self, i: usize, key: u32, index_mask: u32, split_ptr: u32) -> u32 {
        Self::address(self.raw(i, key), index_mask, split_ptr)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_kinds_are_deterministic() {
        for kind in HashKind::ALL {
            for key in [0u32, 1, 42, 0xDEAD_BEEF, u32::MAX - 1] {
                assert_eq!(kind.hash(key), kind.hash(key), "{kind:?} not deterministic");
            }
        }
    }

    #[test]
    fn kinds_differ_from_each_other() {
        // A fixed key should hash differently under (almost) all kinds.
        let key = 0x1234_5678;
        let hashes: Vec<u32> = HashKind::ALL.iter().map(|k| k.hash(key)).collect();
        let mut uniq = hashes.clone();
        uniq.sort_unstable();
        uniq.dedup();
        assert_eq!(uniq.len(), hashes.len(), "hash kinds collide on {key:#x}: {hashes:?}");
    }

    #[test]
    fn parse_roundtrip() {
        for kind in HashKind::ALL {
            let lower = kind.name().to_ascii_lowercase();
            let token = match kind {
                HashKind::Murmur3 => "murmur3".to_string(),
                HashKind::City32 => "city32".to_string(),
                _ => lower,
            };
            assert_eq!(HashKind::parse(&token), Some(kind));
        }
        assert_eq!(HashKind::parse("sha256"), None);
    }

    #[test]
    fn invertible_kinds_roundtrip_via_dispatch() {
        for kind in HashKind::ALL {
            if !kind.invertible() {
                continue;
            }
            for key in (0..100_000u32).chain([u32::MAX, u32::MAX - 1, 0x8000_0000]) {
                assert_eq!(kind.invert(kind.hash(key)), key, "{kind:?} at {key:#x}");
            }
        }
    }

    #[test]
    fn linear_address_before_and_after_split() {
        // Round m=2 (mask=3). Buckets 0..split_ptr use the next mask (7).
        let h = 0b101u32; // raw address 1 under mask 3, 5 under mask 7
        assert_eq!(HashFamily::address(h, 3, 0), 1);
        assert_eq!(HashFamily::address(h, 3, 2), 5); // bucket 1 < split_ptr 2 -> rehash
        let h2 = 0b110u32; // address 2 under mask 3 — not yet split
        assert_eq!(HashFamily::address(h2, 3, 2), 2);
    }

    #[test]
    fn addresses_stay_in_logical_range() {
        let fam = HashFamily::default_pair();
        let index_mask = 0xF; // m=4 -> 16 base buckets
        for split_ptr in [0u32, 3, 8, 15] {
            let logical = (index_mask + 1) + split_ptr;
            for key in 0..10_000u32 {
                for i in 0..fam.d() {
                    let b = fam.bucket(i, key, index_mask, split_ptr);
                    assert!(
                        b < logical,
                        "bucket {b} out of range (logical {logical}, sp {split_ptr})"
                    );
                }
            }
        }
    }

    #[test]
    fn split_invariant_rehash_lands_on_src_or_partner() {
        // For any key addressed to b < split_ptr, the next-round address is
        // either b (stay) or b + 2^m (move) — the linear-hashing invariant
        // the split migration relies on.
        let index_mask = 0x3F; // m=6
        for key in 0..50_000u32 {
            let h = HashKind::BitHash1.hash(key);
            let b = h & index_mask;
            let next = h & ((index_mask << 1) | 1);
            assert!(next == b || next == b + index_mask + 1);
        }
    }
}
