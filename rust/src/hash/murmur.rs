//! MurmurHash3 for 32-bit integer keys (paper §III-C, [21]).
//!
//! For a fixed 4-byte input the full MurmurHash3_x86_32 reduces to one
//! block round plus the fmix32 finalizer; we implement exactly that (seed
//! 0), matching the reference implementation on 4-byte little-endian input.

/// MurmurHash3_x86_32 of the 4 little-endian bytes of `key`, seed 0.
#[inline(always)]
pub const fn murmur3_32(key: u32) -> u32 {
    const C1: u32 = 0xcc9e_2d51;
    const C2: u32 = 0x1b87_3593;
    // body: one 4-byte block
    let mut k1 = key.wrapping_mul(C1);
    k1 = k1.rotate_left(15);
    k1 = k1.wrapping_mul(C2);
    let mut h1 = 0u32 ^ k1;
    h1 = h1.rotate_left(13);
    h1 = h1.wrapping_mul(5).wrapping_add(0xe654_6b64);
    // tail: none; finalize with len = 4
    h1 ^= 4;
    fmix32(h1)
}

/// Murmur3 fmix32 finalizer — also useful standalone as a cheap mixer.
#[inline(always)]
pub const fn fmix32(mut h: u32) -> u32 {
    h ^= h >> 16;
    h = h.wrapping_mul(0x85eb_ca6b);
    h ^= h >> 13;
    h = h.wrapping_mul(0xc2b2_ae35);
    h ^= h >> 16;
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reference_vectors() {
        // Vectors computed with the canonical MurmurHash3_x86_32
        // implementation over 4-byte LE input, seed 0.
        assert_eq!(murmur3_32(0), 0x2362_f9de);
        assert_eq!(murmur3_32(1), 0xfbf1_402a);
    }

    #[test]
    fn fmix32_bijective_spot_check() {
        // fmix32 is a bijection on u32; sample-based injectivity check.
        use std::collections::HashSet;
        let mut seen = HashSet::new();
        for key in 0..100_000u32 {
            assert!(seen.insert(fmix32(key)), "fmix32 collision at {key}");
        }
    }

    #[test]
    fn distribution_over_buckets() {
        let mut bins = [0u32; 128];
        let n = 128 * 1024;
        for key in 0..n {
            bins[(murmur3_32(key) & 127) as usize] += 1;
        }
        let mean = n / 128;
        for &b in &bins {
            assert!(b > mean / 2 && b < mean * 2);
        }
    }
}
