//! MurmurHash3 for 32-bit integer keys (paper §III-C, [21]).
//!
//! For a fixed 4-byte input the full MurmurHash3_x86_32 reduces to one
//! block round plus the fmix32 finalizer; we implement exactly that (seed
//! 0), matching the reference implementation on 4-byte little-endian input.

/// MurmurHash3_x86_32 of the 4 little-endian bytes of `key`, seed 0.
#[inline(always)]
pub const fn murmur3_32(key: u32) -> u32 {
    const C1: u32 = 0xcc9e_2d51;
    const C2: u32 = 0x1b87_3593;
    // body: one 4-byte block
    let mut k1 = key.wrapping_mul(C1);
    k1 = k1.rotate_left(15);
    k1 = k1.wrapping_mul(C2);
    let mut h1 = 0u32 ^ k1;
    h1 = h1.rotate_left(13);
    h1 = h1.wrapping_mul(5).wrapping_add(0xe654_6b64);
    // tail: none; finalize with len = 4
    h1 ^= 4;
    fmix32(h1)
}

/// Murmur3 fmix32 finalizer — also useful standalone as a cheap mixer.
#[inline(always)]
pub const fn fmix32(mut h: u32) -> u32 {
    h ^= h >> 16;
    h = h.wrapping_mul(0x85eb_ca6b);
    h ^= h >> 13;
    h = h.wrapping_mul(0xc2b2_ae35);
    h ^= h >> 16;
    h
}

/// Exact inverse of [`fmix32`] (xor-shifts and odd multiplies are all
/// bijections on u32).
pub const fn fmix32_inv(mut h: u32) -> u32 {
    use super::bithash::{inv_odd, unshift_xor_right};
    h = unshift_xor_right(h, 16);
    h = h.wrapping_mul(inv_odd(0xc2b2_ae35));
    h = unshift_xor_right(h, 13);
    h = h.wrapping_mul(inv_odd(0x85eb_ca6b));
    unshift_xor_right(h, 16)
}

/// Exact inverse of [`murmur3_32`]: for fixed 4-byte input every stage
/// (block multiply, rotate, `5*h + c`, the finalizer) is a bijection.
pub const fn murmur3_32_inv(h: u32) -> u32 {
    use super::bithash::inv_odd;
    const C1: u32 = 0xcc9e_2d51;
    const C2: u32 = 0x1b87_3593;
    let mut h1 = fmix32_inv(h);
    h1 ^= 4;
    h1 = h1.wrapping_sub(0xe654_6b64).wrapping_mul(inv_odd(5));
    let mut k1 = h1.rotate_right(13); // h1 started as 0 ^ k1
    k1 = k1.wrapping_mul(inv_odd(C2));
    k1 = k1.rotate_right(15);
    k1.wrapping_mul(inv_odd(C1))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reference_vectors() {
        // Vectors computed with the canonical MurmurHash3_x86_32
        // implementation over 4-byte LE input, seed 0.
        assert_eq!(murmur3_32(0), 0x2362_f9de);
        assert_eq!(murmur3_32(1), 0xfbf1_402a);
    }

    #[test]
    fn fmix32_bijective_spot_check() {
        // fmix32 is a bijection on u32; sample-based injectivity check.
        use std::collections::HashSet;
        let mut seen = HashSet::new();
        for key in 0..100_000u32 {
            assert!(seen.insert(fmix32(key)), "fmix32 collision at {key}");
        }
    }

    #[test]
    fn inverse_roundtrips() {
        let samples = (0..200_000u32)
            .chain((0..64).map(|i| u32::MAX - i))
            .chain((0..4096).map(|i| i.wrapping_mul(0x9e37_79b9)));
        for key in samples {
            assert_eq!(murmur3_32_inv(murmur3_32(key)), key, "murmur3 at {key:#x}");
            assert_eq!(fmix32_inv(fmix32(key)), key, "fmix32 at {key:#x}");
        }
    }

    #[test]
    fn distribution_over_buckets() {
        let mut bins = [0u32; 128];
        let n = 128 * 1024;
        for key in 0..n {
            bins[(murmur3_32(key) & 127) as usize] += 1;
        }
        let mean = n / 128;
        for &b in &bins {
            assert!(b > mean / 2 && b < mean * 2);
        }
    }
}
