//! Uniform-hashing occupancy theory and the Collision Speedup Ratio (CSR)
//! — paper §III-C, Theorem 1 and Fig. 3.
//!
//! For `n` keys thrown uniformly into `m` buckets:
//!
//! * `Pr[L_b = k] = C(n,k) (1/m)^k (1 - 1/m)^(n-k)`
//! * `E[Y] = n - m (1 - (1 - 1/m)^n)` where `Y = Σ_b (L_b - 1)+`
//! * `Pr[collision for key] = 1 - (1 - 1/m)^(n-1)`
//!
//! `CSR = E[Y] / Y_observed`: 1 ⇒ perfectly uniform; >1 ⇒ better spread
//! than uniform; <1 ⇒ excess clustering.

use super::HashKind;

/// Expected total collisions `E[Y] = n - m(1 - (1 - 1/m)^n)` (Theorem 1).
pub fn expected_collisions(n: u64, m: u64) -> f64 {
    let n_f = n as f64;
    let m_f = m as f64;
    // (1 - 1/m)^n via exp/ln for numerical stability at large n, m.
    let p_empty = (n_f * (1.0 - 1.0 / m_f).ln()).exp();
    n_f - m_f * (1.0 - p_empty)
}

/// Expected number of empty buckets `m (1 - 1/m)^n ≈ m e^{-λ}`.
pub fn expected_empty(n: u64, m: u64) -> f64 {
    let m_f = m as f64;
    m_f * ((n as f64) * (1.0 - 1.0 / m_f).ln()).exp()
}

/// Per-key collision probability `1 - (1 - 1/m)^(n-1)` (Theorem 1).
pub fn collision_probability(n: u64, m: u64) -> f64 {
    1.0 - ((n.saturating_sub(1)) as f64 * (1.0 - 1.0 / m as f64).ln()).exp()
}

/// Poisson approximation of `E[Y] ≈ n²/(2m)` valid for `n ≪ m`.
pub fn expected_collisions_poisson(n: u64, m: u64) -> f64 {
    (n as f64) * (n as f64) / (2.0 * m as f64)
}

/// Observed collisions `Y = Σ_b (L_b - 1)+` given per-bucket loads.
pub fn observed_collisions(loads: &[u32]) -> u64 {
    loads.iter().map(|&l| (l as u64).saturating_sub(1)).sum()
}

/// Bucket loads of hashing `keys` into `m` buckets with `kind` (reduction
/// is `h % m`, matching the paper's Listing 1).
pub fn bucket_loads(kind: HashKind, keys: impl Iterator<Item = u32>, m: usize) -> Vec<u32> {
    let mut loads = vec![0u32; m];
    for k in keys {
        loads[(kind.hash(k) as usize) % m] += 1;
    }
    loads
}

/// One Fig. 3 measurement: CSR of `kind` for `n` sequential unique keys
/// into `m` buckets.
pub fn csr(kind: HashKind, keys: impl Iterator<Item = u32>, m: usize, n: u64) -> f64 {
    let loads = bucket_loads(kind, keys, m);
    let observed = observed_collisions(&loads);
    if observed == 0 {
        // No observed collisions: CSR is undefined/infinite; report the
        // expectation scaled by 1 observation floor as the paper's plot
        // effectively clips — callers treat >= 1 as "uniform or better".
        return f64::INFINITY;
    }
    expected_collisions(n, m as u64) / observed as f64
}

/// Chi-square statistic of the load distribution against uniform — a
/// secondary uniformity measure used in tests.
pub fn chi_square(loads: &[u32], n: u64) -> f64 {
    let m = loads.len() as f64;
    let exp = n as f64 / m;
    loads.iter().map(|&l| (l as f64 - exp).powi(2) / exp).sum()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn expectation_limits() {
        // n = 1: no collisions possible.
        assert!(expected_collisions(1, 100) < 1e-9);
        // n >> m: E[Y] -> n - m (every bucket nonempty).
        let e = expected_collisions(1_000_000, 10);
        assert!((e - (1_000_000.0 - 10.0)).abs() < 1.0);
    }

    #[test]
    fn poisson_approx_matches_exact_at_low_load() {
        let n = 1000;
        let m = 1_000_000;
        let exact = expected_collisions(n, m);
        let approx = expected_collisions_poisson(n, m);
        assert!((exact - approx).abs() / exact.max(1e-9) < 0.01, "{exact} vs {approx}");
    }

    #[test]
    fn observed_collisions_counts_extra_occupants() {
        assert_eq!(observed_collisions(&[0, 1, 1, 1]), 0);
        assert_eq!(observed_collisions(&[3, 0, 1]), 2);
        assert_eq!(observed_collisions(&[2, 2, 2]), 3);
    }

    #[test]
    fn collision_probability_monotone_in_n() {
        let m = 1024;
        let mut last = 0.0;
        for n in [1u64, 2, 16, 256, 4096] {
            let p = collision_probability(n, m);
            assert!((0.0..=1.0).contains(&p));
            assert!(p >= last);
            last = p;
        }
    }

    #[test]
    fn good_hashes_have_csr_near_one() {
        // Fig. 3's qualitative claim: with enough keys every evaluated hash
        // converges to CSR ~ 1 (within a factor ~2 here; the bench measures
        // the precise curves).
        let m = 1 << 12;
        let n = 1u64 << 16;
        for kind in HashKind::ALL {
            let c = csr(kind, 0..n as u32, m, n);
            assert!(c > 0.5 && c < 2.0, "{kind:?} CSR {c}");
        }
    }

    #[test]
    fn identity_hash_has_terrible_csr_shape() {
        // Sanity for the metric itself: sequential keys into m buckets via
        // identity (h = key) yields zero collisions for n <= m (CSR = inf,
        // "better than uniform" — the clustering artifact the paper notes
        // for deterministic hashes at low load), but striding by m yields
        // all-collisions (CSR << 1).
        let m = 1024usize;
        let n = 512u64;
        let loads = {
            let mut l = vec![0u32; m];
            for i in 0..n as u32 {
                l[((i * m as u32) as usize) % m] += 1; // all to bucket 0
            }
            l
        };
        let obs = observed_collisions(&loads);
        assert_eq!(obs, n - 1);
        let c = expected_collisions(n, m as u64) / obs as f64;
        assert!(c < 0.5, "CSR {c} should show excess collisions");
    }

    #[test]
    fn chi_square_uniform_vs_skewed() {
        let n = 10_000u64;
        let uniform: Vec<u32> = vec![10; 1000];
        let mut skewed = vec![0u32; 1000];
        skewed[0] = n as u32;
        assert!(chi_square(&uniform, n) < 1.0);
        assert!(chi_square(&skewed, n) > 1000.0);
    }
}
