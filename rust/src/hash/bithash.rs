//! The paper's GPU-oriented bit-mixing hashes (Listing 1).
//!
//! `BitHash1` is the classic Thomas Wang 32-bit integer mixer; `BitHash2`
//! is Bob Jenkins' 6-shift integer hash. Both achieve avalanche behaviour
//! with a handful of shift/XOR/add instructions — the cheapest family the
//! paper evaluates, and the default pair for Hive (Fig. 5).
//!
//! These definitions are mirrored bit-for-bit by the Pallas kernel
//! `python/compile/kernels/bithash.py`; `python/tests` asserts agreement.

/// BitHash1 (paper Listing 1 / Thomas Wang's hash32).
#[inline(always)]
pub const fn bithash1(mut key: u32) -> u32 {
    key = (!key).wrapping_add(key << 15); // key = ~key + (key << 15)
    key ^= key >> 12;
    key = key.wrapping_add(key << 2);
    key ^= key >> 4;
    key = key.wrapping_mul(2057); // key = (key + (key << 3)) + (key << 11)
    key ^= key >> 16;
    key
}

/// BitHash2 (paper Listing 1 / Bob Jenkins' 6-shift integer hash).
#[inline(always)]
pub const fn bithash2(mut key: u32) -> u32 {
    key = key.wrapping_add(0x7ed5_5d16).wrapping_add(key << 12);
    key = (key ^ 0xc761_c23c) ^ (key >> 19);
    key = key.wrapping_add(0x1656_67b1).wrapping_add(key << 5);
    key = key.wrapping_add(0xd3a2_646c) ^ (key << 9);
    key = key.wrapping_add(0xfd70_46c5).wrapping_add(key << 3);
    key = (key ^ 0xb55a_4f09) ^ (key >> 16);
    key
}

/// Multiplicative inverse of an odd constant modulo 2^32 (Newton's
/// iteration doubles the number of correct low bits per step).
pub const fn inv_odd(a: u32) -> u32 {
    let mut x = a; // correct to 3 bits
    let mut i = 0;
    while i < 5 {
        x = x.wrapping_mul(2u32.wrapping_sub(a.wrapping_mul(x)));
        i += 1;
    }
    x
}

/// Invert `y = x ^ (x >> s)` for `1 <= s < 32`: iterating the forward map
/// recovers one more `s`-bit chunk of `x` from the top down each pass.
pub const fn unshift_xor_right(y: u32, s: u32) -> u32 {
    let mut x = y;
    let mut i = 0;
    while i < 32 / s + 1 {
        x = y ^ (x >> s);
        i += 1;
    }
    x
}

/// Exact inverse of [`bithash1`] (every step is a bijection on u32: the
/// first line is `32767*key - 1`, the rest are xor-shifts and odd
/// multiplies).
pub const fn bithash1_inv(h: u32) -> u32 {
    let mut k = unshift_xor_right(h, 16);
    k = k.wrapping_mul(inv_odd(2057));
    k = unshift_xor_right(k, 4);
    k = k.wrapping_mul(inv_odd(5)); // undo key += key << 2
    k = unshift_xor_right(k, 12);
    // undo key = ~key + (key << 15) == 32767*key - 1
    k.wrapping_add(1).wrapping_mul(inv_odd(32767))
}

/// Undo `y = (x + c) ^ (x << 9)`: the low 9 bits of `x + c` equal the low
/// 9 bits of `y` (the shifted term is zero there), and each recovered
/// chunk of `x + c` exposes 9 more bits of `x << 9`, so `x + c` is
/// rebuilt bottom-up in 9-bit strides (subtraction borrows only travel
/// upward, keeping every partial `x` valid in its known low bits).
const fn unshift_add_xor_left9(y: u32, c: u32) -> u32 {
    let mut t = y & 0x1FF; // low 9 bits of x + c
    let mut bits = 9;
    while bits < 32 {
        let x_low = t.wrapping_sub(c); // valid in the low `bits` bits
        let upper = if bits + 9 >= 32 {
            u32::MAX
        } else {
            (1u32 << (bits + 9)) - 1
        };
        t |= (y ^ (x_low << 9)) & upper & !((1u32 << bits) - 1);
        bits += 9;
    }
    t.wrapping_sub(c)
}

/// Exact inverse of [`bithash2`] (each of the six lines is a bijection:
/// `(4097|33|9)*x + c`, xor-shift mixes, and one add-xor-shift-left).
pub const fn bithash2_inv(h: u32) -> u32 {
    let mut k = unshift_xor_right(h ^ 0xb55a_4f09, 16);
    k = k.wrapping_sub(0xfd70_46c5).wrapping_mul(inv_odd(9));
    k = unshift_add_xor_left9(k, 0xd3a2_646c);
    k = k.wrapping_sub(0x1656_67b1).wrapping_mul(inv_odd(33));
    k = unshift_xor_right(k ^ 0xc761_c23c, 19);
    k.wrapping_sub(0x7ed5_5d16).wrapping_mul(inv_odd(4097))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn known_vectors_stable() {
        // Pinned outputs — the Pallas kernel test uses the same vectors.
        assert_eq!(bithash1(0), bithash1(0));
        assert_ne!(bithash1(0), 0);
        assert_ne!(bithash2(0), 0);
        assert_ne!(bithash1(1), bithash1(2));
        assert_ne!(bithash2(1), bithash2(2));
    }

    #[test]
    fn avalanche_quality() {
        // Flipping one input bit should flip ~16 of 32 output bits on
        // average; require at least 10 as a loose avalanche check.
        for f in [bithash1 as fn(u32) -> u32, bithash2 as fn(u32) -> u32] {
            let mut total = 0u32;
            let trials = 1000;
            for key in 0..trials {
                let h = f(key);
                for bit in 0..32 {
                    total += (h ^ f(key ^ (1 << bit))).count_ones();
                }
            }
            let avg = total as f64 / (trials * 32) as f64;
            assert!(avg > 10.0 && avg < 22.0, "avalanche avg {avg}");
        }
    }

    #[test]
    fn low_bits_usable_for_bucketing() {
        // Keys 0..n must not cluster in the low bits (bucket index uses a
        // mask). Chi-square-lite: each of 64 low-bit bins within 2x of mean.
        for f in [bithash1 as fn(u32) -> u32, bithash2 as fn(u32) -> u32] {
            let mut bins = [0u32; 64];
            let n = 64 * 1024;
            for key in 0..n {
                bins[(f(key) & 63) as usize] += 1;
            }
            let mean = n / 64;
            for (i, &b) in bins.iter().enumerate() {
                assert!(b > mean / 2 && b < mean * 2, "bin {i} count {b} vs mean {mean}");
            }
        }
    }

    #[test]
    fn inverses_roundtrip() {
        // The compact layout reconstructs keys from stored remainders, so
        // both mixers must be exactly invertible over the full word.
        let samples = (0..200_000u32)
            .chain((0..64).map(|i| u32::MAX - i))
            .chain((0..4096).map(|i| i.wrapping_mul(0x9e37_79b9)));
        for key in samples {
            assert_eq!(bithash1_inv(bithash1(key)), key, "bithash1 at {key:#x}");
            assert_eq!(bithash2_inv(bithash2(key)), key, "bithash2 at {key:#x}");
        }
    }

    #[test]
    fn inv_odd_is_inverse() {
        for a in [1u32, 5, 9, 33, 2057, 4097, 32767, 0x85eb_ca6b, 0xc2b2_ae35] {
            assert_eq!(a.wrapping_mul(inv_odd(a)), 1, "inv_odd({a:#x})");
        }
    }

    #[test]
    fn unshift_xor_right_roundtrip() {
        for s in [4u32, 9, 12, 13, 16, 19] {
            for x in (0..50_000u32).map(|i| i.wrapping_mul(0x6c8e_9cf5)) {
                assert_eq!(unshift_xor_right(x ^ (x >> s), s), x);
            }
        }
    }

    #[test]
    fn functions_are_independent() {
        // The cuckoo family requires the two candidate buckets to differ
        // for almost all keys.
        let mask = 0xFFFF;
        let mut same = 0;
        let n = 100_000u32;
        for key in 0..n {
            if (bithash1(key) & mask) == (bithash2(key) & mask) {
                same += 1;
            }
        }
        // expected collision rate 1/65536 ~ 1.5 per 100k
        assert!(same < 20, "candidate buckets coincide too often: {same}");
    }
}
