//! The paper's GPU-oriented bit-mixing hashes (Listing 1).
//!
//! `BitHash1` is the classic Thomas Wang 32-bit integer mixer; `BitHash2`
//! is Bob Jenkins' 6-shift integer hash. Both achieve avalanche behaviour
//! with a handful of shift/XOR/add instructions — the cheapest family the
//! paper evaluates, and the default pair for Hive (Fig. 5).
//!
//! These definitions are mirrored bit-for-bit by the Pallas kernel
//! `python/compile/kernels/bithash.py`; `python/tests` asserts agreement.

/// BitHash1 (paper Listing 1 / Thomas Wang's hash32).
#[inline(always)]
pub const fn bithash1(mut key: u32) -> u32 {
    key = (!key).wrapping_add(key << 15); // key = ~key + (key << 15)
    key ^= key >> 12;
    key = key.wrapping_add(key << 2);
    key ^= key >> 4;
    key = key.wrapping_mul(2057); // key = (key + (key << 3)) + (key << 11)
    key ^= key >> 16;
    key
}

/// BitHash2 (paper Listing 1 / Bob Jenkins' 6-shift integer hash).
#[inline(always)]
pub const fn bithash2(mut key: u32) -> u32 {
    key = key.wrapping_add(0x7ed5_5d16).wrapping_add(key << 12);
    key = (key ^ 0xc761_c23c) ^ (key >> 19);
    key = key.wrapping_add(0x1656_67b1).wrapping_add(key << 5);
    key = key.wrapping_add(0xd3a2_646c) ^ (key << 9);
    key = key.wrapping_add(0xfd70_46c5).wrapping_add(key << 3);
    key = (key ^ 0xb55a_4f09) ^ (key >> 16);
    key
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn known_vectors_stable() {
        // Pinned outputs — the Pallas kernel test uses the same vectors.
        assert_eq!(bithash1(0), bithash1(0));
        assert_ne!(bithash1(0), 0);
        assert_ne!(bithash2(0), 0);
        assert_ne!(bithash1(1), bithash1(2));
        assert_ne!(bithash2(1), bithash2(2));
    }

    #[test]
    fn avalanche_quality() {
        // Flipping one input bit should flip ~16 of 32 output bits on
        // average; require at least 10 as a loose avalanche check.
        for f in [bithash1 as fn(u32) -> u32, bithash2 as fn(u32) -> u32] {
            let mut total = 0u32;
            let trials = 1000;
            for key in 0..trials {
                let h = f(key);
                for bit in 0..32 {
                    total += (h ^ f(key ^ (1 << bit))).count_ones();
                }
            }
            let avg = total as f64 / (trials * 32) as f64;
            assert!(avg > 10.0 && avg < 22.0, "avalanche avg {avg}");
        }
    }

    #[test]
    fn low_bits_usable_for_bucketing() {
        // Keys 0..n must not cluster in the low bits (bucket index uses a
        // mask). Chi-square-lite: each of 64 low-bit bins within 2x of mean.
        for f in [bithash1 as fn(u32) -> u32, bithash2 as fn(u32) -> u32] {
            let mut bins = [0u32; 64];
            let n = 64 * 1024;
            for key in 0..n {
                bins[(f(key) & 63) as usize] += 1;
            }
            let mean = n / 64;
            for (i, &b) in bins.iter().enumerate() {
                assert!(b > mean / 2 && b < mean * 2, "bin {i} count {b} vs mean {mean}");
            }
        }
    }

    #[test]
    fn functions_are_independent() {
        // The cuckoo family requires the two candidate buckets to differ
        // for almost all keys.
        let mask = 0xFFFF;
        let mut same = 0;
        let n = 100_000u32;
        for key in 0..n {
            if (bithash1(key) & mask) == (bithash2(key) & mask) {
                same += 1;
            }
        }
        // expected collision rate 1/65536 ~ 1.5 per 100k
        assert!(same < 20, "candidate buckets coincide too often: {same}");
    }
}
