//! CityHash-style hash for 32-bit integer keys (paper §III-C, [22]).
//!
//! CityHash32 over a fixed 4-byte input follows the `Hash32Len0to4` path:
//! a byte-wise fold with the Murmur constants followed by fmix. We
//! implement that path directly (it is what the paper's GPU kernel would
//! evaluate for a 4-byte key).

use super::murmur::fmix32;

const C1: u32 = 0xcc9e_2d51;

/// CityHash32's `Hash32Len0to4` specialized to the 4 LE bytes of `key`.
#[inline(always)]
pub const fn city32(key: u32) -> u32 {
    let len: u32 = 4;
    let mut b: u32 = 0;
    let mut c: u32 = 9;
    // byte-wise fold, little-endian byte order
    let bytes = key.to_le_bytes();
    let mut i = 0;
    while i < 4 {
        let v = bytes[i] as i8 as i32 as u32; // sign-extended like the C++ `signed char`
        b = b.wrapping_mul(C1).wrapping_add(v);
        c ^= b;
        i += 1;
    }
    fmix32(mur(c, mur(b, mur(len, c))))
}

/// CityHash's `Mur` helper: a Murmur-style combine of `a` into `h`.
#[inline(always)]
const fn mur(mut a: u32, mut h: u32) -> u32 {
    const C2: u32 = 0x1b87_3593;
    a = a.wrapping_mul(C1);
    a = a.rotate_right(17);
    a = a.wrapping_mul(C2);
    h ^= a;
    h = h.rotate_right(19);
    h.wrapping_mul(5).wrapping_add(0xe654_6b64)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_and_nontrivial() {
        assert_eq!(city32(42), city32(42));
        assert_ne!(city32(0), city32(1));
        assert_ne!(city32(0), 0);
    }

    #[test]
    fn differs_from_murmur() {
        use super::super::murmur::murmur3_32;
        let mut differing = 0;
        for key in 0..1000u32 {
            if city32(key) != murmur3_32(key) {
                differing += 1;
            }
        }
        assert_eq!(differing, 1000);
    }

    #[test]
    fn distribution_over_buckets() {
        let mut bins = [0u32; 128];
        let n = 128 * 1024;
        for key in 0..n {
            bins[(city32(key) & 127) as usize] += 1;
        }
        let mean = n / 128;
        for &b in &bins {
            assert!(b > mean / 2 && b < mean * 2);
        }
    }
}
