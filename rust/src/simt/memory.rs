//! Global memory with cache-line transaction accounting.
//!
//! GPU DRAM traffic is issued in 128-byte cache-line transactions [19]; a
//! warp's 32 loads coalesce into as few transactions as the distinct lines
//! they touch. This model is the backbone of the paper's layout argument:
//! a packed 256 B bucket probe costs *two* transactions, an SoA probe
//! costs *four* (two key lines + two value lines), a slab traversal costs
//! two *per hop* plus the pointer line.
//!
//! `GlobalMem` stores 64-bit words and counts, per named region:
//! * warp transactions (distinct 128 B lines per warp access),
//! * atomic RMWs (CAS / fetch_and / fetch_or / exchange),
//! * total words moved.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};

/// Bytes per cache-line transaction (L1/L2 line on modern NVIDIA parts).
pub const LINE_BYTES: usize = 128;
/// 64-bit words per cache line.
pub const WORDS_PER_LINE: usize = LINE_BYTES / 8;

/// Traffic counters for one memory region.
#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
pub struct MemStats {
    /// 128-byte line transactions issued by warp-wide accesses.
    pub transactions: u64,
    /// Atomic RMW operations (each also a transaction on real hardware,
    /// counted separately to expose contention).
    pub atomics: u64,
    /// Total 64-bit words loaded or stored.
    pub words: u64,
}

impl MemStats {
    /// Sum of two stat blocks.
    pub fn merged(self, other: MemStats) -> MemStats {
        MemStats {
            transactions: self.transactions + other.transactions,
            atomics: self.atomics + other.atomics,
            words: self.words + other.words,
        }
    }
}

#[derive(Debug, Default)]
struct RegionCounters {
    transactions: AtomicU64,
    atomics: AtomicU64,
    words: AtomicU64,
}

/// A named allocation in simulated global memory (64-bit words).
pub struct Region {
    data: Vec<AtomicU64>,
    counters: RegionCounters,
    name: &'static str,
}

impl Region {
    fn new(name: &'static str, len: usize, init: u64) -> Self {
        Region {
            data: (0..len).map(|_| AtomicU64::new(init)).collect(),
            counters: RegionCounters::default(),
            name,
        }
    }

    /// Region length in words.
    pub fn len(&self) -> usize {
        self.data.len()
    }

    /// `true` if the region has no words.
    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// Count the distinct 128 B lines touched by word indices `idxs`.
    fn lines_touched(idxs: &[usize]) -> u64 {
        let mut lines: Vec<usize> = idxs.iter().map(|&i| i / WORDS_PER_LINE).collect();
        lines.sort_unstable();
        lines.dedup();
        lines.len() as u64
    }

    /// Warp-coalesced load of `N` words (one per lane). Counts the distinct
    /// cache lines as transactions — a contiguous aligned 32-word load is
    /// the paper's "two aligned 128-byte memory transactions".
    pub fn warp_load<const N: usize>(&self, idxs: [usize; N]) -> [u64; N] {
        self.counters.transactions.fetch_add(Self::lines_touched(&idxs), Ordering::Relaxed);
        self.counters.words.fetch_add(N as u64, Ordering::Relaxed);
        let mut out = [0u64; N];
        for (o, &i) in out.iter_mut().zip(idxs.iter()) {
            *o = self.data[i].load(Ordering::Acquire);
        }
        out
    }

    /// Load without traffic accounting — models a value the warp already
    /// holds in registers (e.g. rows cached by an earlier coalesced load:
    /// "each slot is fetched exactly once", §III-F).
    pub fn load_uncounted(&self, idx: usize) -> u64 {
        self.data[idx].load(Ordering::Acquire)
    }

    /// Single-lane scalar load (e.g. lane 0 reading the free mask): one
    /// transaction.
    pub fn load(&self, idx: usize) -> u64 {
        self.counters.transactions.fetch_add(1, Ordering::Relaxed);
        self.counters.words.fetch_add(1, Ordering::Relaxed);
        self.data[idx].load(Ordering::Acquire)
    }

    /// Single-lane store: one transaction.
    pub fn store(&self, idx: usize, value: u64) {
        self.counters.transactions.fetch_add(1, Ordering::Relaxed);
        self.counters.words.fetch_add(1, Ordering::Relaxed);
        self.data[idx].store(value, Ordering::Release);
    }

    /// Warp-coalesced store of `N` lanes.
    pub fn warp_store<const N: usize>(&self, idxs: [usize; N], values: [u64; N]) {
        self.counters.transactions.fetch_add(Self::lines_touched(&idxs), Ordering::Relaxed);
        self.counters.words.fetch_add(N as u64, Ordering::Relaxed);
        for (&i, &v) in idxs.iter().zip(values.iter()) {
            self.data[i].store(v, Ordering::Release);
        }
    }

    /// Atomic compare-and-swap (64-bit, the packed-KV publish primitive).
    pub fn cas(&self, idx: usize, expected: u64, new: u64) -> Result<u64, u64> {
        self.counters.atomics.fetch_add(1, Ordering::Relaxed);
        self.data[idx]
            .compare_exchange(expected, new, Ordering::AcqRel, Ordering::Acquire)
            .map_err(|v| v)
    }

    /// Atomic fetch-AND (the WABC claim primitive on the free mask).
    pub fn fetch_and(&self, idx: usize, mask: u64) -> u64 {
        self.counters.atomics.fetch_add(1, Ordering::Relaxed);
        self.data[idx].fetch_and(mask, Ordering::AcqRel)
    }

    /// Atomic fetch-OR (free-bit publication on delete).
    pub fn fetch_or(&self, idx: usize, mask: u64) -> u64 {
        self.counters.atomics.fetch_add(1, Ordering::Relaxed);
        self.data[idx].fetch_or(mask, Ordering::AcqRel)
    }

    /// Atomic fetch-add (stash tail reservation).
    pub fn fetch_add(&self, idx: usize, v: u64) -> u64 {
        self.counters.atomics.fetch_add(1, Ordering::Relaxed);
        self.data[idx].fetch_add(v, Ordering::AcqRel)
    }

    /// Atomic exchange.
    pub fn swap(&self, idx: usize, v: u64) -> u64 {
        self.counters.atomics.fetch_add(1, Ordering::Relaxed);
        self.data[idx].swap(v, Ordering::AcqRel)
    }

    /// Point-in-time traffic counters.
    pub fn stats(&self) -> MemStats {
        MemStats {
            transactions: self.counters.transactions.load(Ordering::Relaxed),
            atomics: self.counters.atomics.load(Ordering::Relaxed),
            words: self.counters.words.load(Ordering::Relaxed),
        }
    }

    /// Region name.
    pub fn name(&self) -> &'static str {
        self.name
    }
}

/// Simulated global memory: a set of named regions.
#[derive(Default)]
pub struct GlobalMem {
    regions: BTreeMap<&'static str, Region>,
}

impl GlobalMem {
    /// Empty memory.
    pub fn new() -> Self {
        Self::default()
    }

    /// Allocate a region of `len` 64-bit words initialized to `init`.
    pub fn alloc(&mut self, name: &'static str, len: usize, init: u64) -> &Region {
        self.regions.insert(name, Region::new(name, len, init));
        &self.regions[name]
    }

    /// Access a region by name.
    pub fn region(&self, name: &'static str) -> &Region {
        &self.regions[name]
    }

    /// Aggregate traffic across all regions.
    pub fn total_stats(&self) -> MemStats {
        self.regions.values().fold(MemStats::default(), |acc, r| acc.merged(r.stats()))
    }

    /// Per-region traffic, in name order.
    pub fn stats_by_region(&self) -> Vec<(&'static str, MemStats)> {
        self.regions.iter().map(|(&n, r)| (n, r.stats())).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn coalesced_bucket_probe_is_two_transactions() {
        let mut mem = GlobalMem::new();
        mem.alloc("buckets", 1024, 0);
        let r = mem.region("buckets");
        // 32 consecutive aligned words = 256 B = exactly 2 lines.
        let idxs: [usize; 32] = std::array::from_fn(|i| 64 + i);
        r.warp_load(idxs);
        assert_eq!(r.stats().transactions, 2);
        assert_eq!(r.stats().words, 32);
    }

    #[test]
    fn scattered_probe_amplifies_transactions() {
        let mut mem = GlobalMem::new();
        mem.alloc("buckets", 1 << 16, 0);
        let r = mem.region("buckets");
        // 32 words spread one per line: 32 transactions.
        let idxs: [usize; 32] = std::array::from_fn(|i| i * WORDS_PER_LINE);
        r.warp_load(idxs);
        assert_eq!(r.stats().transactions, 32);
    }

    #[test]
    fn unaligned_probe_touches_three_lines() {
        let mut mem = GlobalMem::new();
        mem.alloc("b", 1024, 0);
        let r = mem.region("b");
        // Misaligned 32-word window straddles 3 lines — the case bucket
        // alignment avoids ("any probe touches at most two cache lines").
        let idxs: [usize; 32] = std::array::from_fn(|i| 8 + i);
        r.warp_load(idxs);
        assert_eq!(r.stats().transactions, 3);
    }

    #[test]
    fn atomics_are_counted() {
        let mut mem = GlobalMem::new();
        mem.alloc("m", 8, u64::MAX);
        let r = mem.region("m");
        assert_eq!(r.fetch_and(0, !(1 << 5)), u64::MAX);
        assert_eq!(r.fetch_or(0, 1 << 5), u64::MAX & !(1 << 5));
        assert!(r.cas(1, u64::MAX, 42).is_ok());
        assert!(r.cas(1, u64::MAX, 43).is_err());
        assert_eq!(r.stats().atomics, 4);
    }

    #[test]
    fn cas_returns_current_on_failure() {
        let mut mem = GlobalMem::new();
        mem.alloc("m", 1, 7);
        let r = mem.region("m");
        assert_eq!(r.cas(0, 9, 10), Err(7));
        assert_eq!(r.cas(0, 7, 10), Ok(7));
        assert_eq!(r.load(0), 10);
    }

    #[test]
    fn region_totals_aggregate() {
        let mut mem = GlobalMem::new();
        mem.alloc("a", 64, 0);
        mem.alloc("b", 64, 0);
        mem.region("a").load(0);
        mem.region("b").store(0, 1);
        mem.region("b").fetch_add(1, 1);
        let total = mem.total_stats();
        assert_eq!(total.transactions, 2);
        assert_eq!(total.atomics, 1);
        let by = mem.stats_by_region();
        assert_eq!(by.len(), 2);
        assert_eq!(by[0].0, "a");
    }
}
