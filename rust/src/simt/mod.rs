//! SIMT warp simulator — the substrate substituting for CUDA hardware
//! (DESIGN.md §2).
//!
//! The paper's protocols are *warp-cooperative*: 32 lanes execute in
//! lockstep, exchange predicates with `__ballot_sync`, broadcast registers
//! with `__shfl_sync`, and elect winners with `__ffs`. This module models
//! exactly that execution shape in Rust:
//!
//! * [`warp`] — the lockstep lane vector and the warp intrinsics;
//! * [`memory`] — global memory with 128-byte cache-line *transaction*
//!   accounting (the quantity GPU memory coalescing optimizes) and counted
//!   atomic RMWs;
//! * [`clock`] — a cycle cost model (transactions, atomics, intrinsics)
//!   used for the Fig. 9 per-step time breakdown;
//! * [`sched`] — a seeded interleaving scheduler that runs many logical
//!   warps against shared memory in a randomized but reproducible order,
//!   standing in for the GPU's warp scheduler.
//!
//! The simulator is *behaviourally* faithful (same protocol steps, same
//! atomics, same transaction counts per protocol action) rather than
//! timing-faithful; EXPERIMENTS.md reports the derived shapes, not absolute
//! GPU numbers.

pub mod warp;
pub mod memory;
pub mod clock;
pub mod sched;

pub use clock::{CostModel, CycleClock};
pub use memory::{GlobalMem, MemStats};
pub use warp::{Warp, LANES};
