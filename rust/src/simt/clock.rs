//! Cycle cost model for the simulator.
//!
//! The paper times insertion steps with `clock64()` at warp granularity
//! (§V-D). Our simulator has no hardware clock, so we charge each protocol
//! action a latency drawn from public Ada-generation figures:
//!
//! * global-memory transaction (L2 miss): ~400 cycles
//! * atomic RMW (L2-resident): ~40 cycles on top of its transaction
//! * warp intrinsic (ballot/shfl/ffs): ~2 cycles
//! * ALU/hash evaluation: ~10 cycles per BitHash-style mixer
//! * lock spin iteration: ~20 cycles
//!
//! Absolute values matter less than *ratios* — Fig. 9 plots percentage
//! shares, which depend only on relative costs. The model is configurable
//! so the ablation benches can test sensitivity.

/// Per-action cycle costs (defaults approximate an RTX 4090 at 2.52 GHz).
#[derive(Debug, Clone, Copy)]
pub struct CostModel {
    /// One 128-byte global-memory transaction.
    pub transaction: u64,
    /// One atomic RMW (in addition to its transaction).
    pub atomic: u64,
    /// One warp intrinsic (ballot / shfl / ffs / popc).
    pub intrinsic: u64,
    /// One hash-function evaluation.
    pub hash: u64,
    /// One lock acquire/release pair.
    pub lock: u64,
}

impl Default for CostModel {
    fn default() -> Self {
        CostModel { transaction: 400, atomic: 40, intrinsic: 2, hash: 10, lock: 80 }
    }
}

/// Accumulates cycles for one logical warp's current operation.
#[derive(Debug, Default, Clone)]
pub struct CycleClock {
    cycles: u64,
}

impl CycleClock {
    /// Zeroed clock.
    pub fn new() -> Self {
        Self::default()
    }

    /// Charge `n` memory transactions.
    #[inline]
    pub fn charge_transactions(&mut self, model: &CostModel, n: u64) {
        self.cycles += model.transaction * n;
    }

    /// Charge one atomic RMW (transaction + RMW overhead).
    #[inline]
    pub fn charge_atomic(&mut self, model: &CostModel) {
        self.cycles += model.transaction + model.atomic;
    }

    /// Charge `n` warp intrinsics.
    #[inline]
    pub fn charge_intrinsics(&mut self, model: &CostModel, n: u64) {
        self.cycles += model.intrinsic * n;
    }

    /// Charge `n` hash evaluations.
    #[inline]
    pub fn charge_hash(&mut self, model: &CostModel, n: u64) {
        self.cycles += model.hash * n;
    }

    /// Charge a lock acquire/release pair.
    #[inline]
    pub fn charge_lock(&mut self, model: &CostModel) {
        self.cycles += model.lock;
    }

    /// Total cycles charged.
    pub fn cycles(&self) -> u64 {
        self.cycles
    }

    /// Reset to zero, returning the previous total.
    pub fn take(&mut self) -> u64 {
        std::mem::take(&mut self.cycles)
    }
}

/// Convert cycles to seconds at the paper's nominal 2.52 GHz boost clock.
pub fn cycles_to_seconds(cycles: u64) -> f64 {
    cycles as f64 / 2.52e9
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn charges_accumulate() {
        let m = CostModel::default();
        let mut c = CycleClock::new();
        c.charge_transactions(&m, 2); // 800
        c.charge_atomic(&m); // +440
        c.charge_intrinsics(&m, 3); // +6
        c.charge_hash(&m, 2); // +20
        c.charge_lock(&m); // +80
        assert_eq!(c.cycles(), 800 + 440 + 6 + 20 + 80);
        assert_eq!(c.take(), 1346);
        assert_eq!(c.cycles(), 0);
    }

    #[test]
    fn seconds_conversion() {
        assert!((cycles_to_seconds(2_520_000_000) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn memory_dominates_intrinsics() {
        // The model must preserve the paper's key ratio: protocol cost is
        // dominated by memory transactions, not warp intrinsics.
        let m = CostModel::default();
        assert!(m.transaction > 50 * m.intrinsic);
    }
}
