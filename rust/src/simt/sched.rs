//! Seeded warp scheduler.
//!
//! The GPU hardware scheduler interleaves ready warps in an order the
//! programmer cannot control; correctness of the paper's protocols must
//! hold under *any* interleaving. The simulator approximates this with a
//! reproducible randomized interleaving at operation granularity: each
//! logical warp owns a stream of operations, and the scheduler repeatedly
//! picks a random non-empty stream to advance. (Within one operation the
//! protocol's atomics provide the linearization points, exactly as on the
//! GPU where one kernel's atomic sequence interleaves with other warps'.)

use crate::core::rng::Xoshiro256;

/// Reproducible randomized interleaver over per-warp operation streams.
#[derive(Debug)]
pub struct Scheduler {
    rng: Xoshiro256,
}

impl Scheduler {
    /// Scheduler with a fixed seed — identical seeds replay identical
    /// interleavings (used by the failure-injection tests).
    pub fn new(seed: u64) -> Self {
        Scheduler { rng: Xoshiro256::seeded(seed) }
    }

    /// Flatten `streams` (one per warp) into a single randomized execution
    /// order, tagging each item with its warp id. Order within one warp is
    /// preserved (program order); order across warps is random.
    pub fn interleave<T>(&mut self, streams: Vec<Vec<T>>) -> Vec<(usize, T)> {
        let total: usize = streams.iter().map(Vec::len).sum();
        let mut iters: Vec<std::vec::IntoIter<T>> =
            streams.into_iter().map(Vec::into_iter).collect();
        let mut live: Vec<usize> = (0..iters.len()).filter(|&i| iters[i].len() > 0).collect();
        let mut out = Vec::with_capacity(total);
        while !live.is_empty() {
            let pick = self.rng.below(live.len() as u64) as usize;
            let warp = live[pick];
            match iters[warp].next() {
                Some(item) => out.push((warp, item)),
                None => unreachable!(),
            }
            if iters[warp].len() == 0 {
                live.swap_remove(pick);
            }
        }
        out
    }

    /// Round-robin interleaving (the GPU's fair-scheduler extreme; used to
    /// bound behaviour from the other side in tests).
    pub fn round_robin<T>(streams: Vec<Vec<T>>) -> Vec<(usize, T)> {
        let total: usize = streams.iter().map(Vec::len).sum();
        let mut iters: Vec<std::vec::IntoIter<T>> =
            streams.into_iter().map(Vec::into_iter).collect();
        let mut out = Vec::with_capacity(total);
        loop {
            let mut progressed = false;
            for (warp, it) in iters.iter_mut().enumerate() {
                if let Some(item) = it.next() {
                    out.push((warp, item));
                    progressed = true;
                }
            }
            if !progressed {
                break;
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preserves_program_order_within_warp() {
        let mut s = Scheduler::new(1);
        let streams: Vec<Vec<u32>> = (0..4).map(|w| (0..100).map(|i| w * 1000 + i).collect()).collect();
        let order = s.interleave(streams);
        assert_eq!(order.len(), 400);
        for w in 0..4usize {
            let seq: Vec<u32> =
                order.iter().filter(|(id, _)| *id == w).map(|&(_, v)| v).collect();
            let expect: Vec<u32> = (0..100).map(|i| w as u32 * 1000 + i).collect();
            assert_eq!(seq, expect, "warp {w} reordered");
        }
    }

    #[test]
    fn same_seed_same_interleaving() {
        let streams = || (0..8).map(|w| (0..50).map(|i| (w, i)).collect()).collect::<Vec<Vec<_>>>();
        let a = Scheduler::new(42).interleave(streams());
        let b = Scheduler::new(42).interleave(streams());
        assert_eq!(a, b);
        let c = Scheduler::new(43).interleave(streams());
        assert_ne!(a, c);
    }

    #[test]
    fn round_robin_is_fair() {
        let streams: Vec<Vec<u32>> = vec![vec![1, 2], vec![10, 20], vec![100, 200]];
        let order = Scheduler::round_robin(streams);
        assert_eq!(
            order,
            vec![(0, 1), (1, 10), (2, 100), (0, 2), (1, 20), (2, 200)]
        );
    }

    #[test]
    fn handles_uneven_and_empty_streams() {
        let mut s = Scheduler::new(7);
        let order = s.interleave(vec![vec![1u32], vec![], vec![2, 3, 4, 5]]);
        assert_eq!(order.len(), 5);
    }
}
