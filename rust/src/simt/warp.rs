//! The lockstep warp: 32 lanes and the CUDA warp-level intrinsics the
//! paper's protocols are written in ([24]).
//!
//! A warp-cooperative routine is expressed as straight-line Rust over
//! `[T; 32]` lane vectors; the intrinsics translate directly:
//!
//! | CUDA                    | here                       |
//! |-------------------------|----------------------------|
//! | `__ballot_sync(pred)`   | [`Warp::ballot`]           |
//! | `__shfl_sync(v, src)`   | [`Warp::shfl`]             |
//! | `__ffs(mask)`           | [`first_set`]              |
//! | `__popc(mask)`          | `u32::count_ones`          |
//! | `popc(mask & ((1<<lane)-1))` (prefix rank) | [`Warp::prefix_rank`] |

/// Lanes per warp — fixed at 32 on every NVIDIA architecture the paper
/// targets, and equal to the paper's bucket slot count by design.
pub const LANES: usize = 32;

/// A logical warp. Carries its id (for scheduling/diagnostics) and counts
/// the intrinsic operations it executes (fed to the cycle cost model).
#[derive(Debug, Clone)]
pub struct Warp {
    /// Warp id within the launched "grid".
    pub id: usize,
    /// Number of warp-level intrinsic operations executed.
    pub intrinsic_ops: u64,
}

impl Warp {
    /// A fresh warp with the given id.
    pub fn new(id: usize) -> Self {
        Warp { id, intrinsic_ops: 0 }
    }

    /// `__ballot_sync`: aggregate one predicate per lane into a 32-bit mask
    /// (bit i ⇔ lane i's predicate).
    #[inline]
    pub fn ballot(&mut self, preds: [bool; LANES]) -> u32 {
        self.intrinsic_ops += 1;
        let mut mask = 0u32;
        for (i, &p) in preds.iter().enumerate() {
            if p {
                mask |= 1 << i;
            }
        }
        mask
    }

    /// `__shfl_sync`: broadcast lane `src`'s register to every lane.
    /// (Returns the scalar; in lockstep Rust all lanes share it.)
    #[inline]
    pub fn shfl<T: Copy>(&mut self, values: &[T; LANES], src: usize) -> T {
        self.intrinsic_ops += 1;
        values[src]
    }

    /// Broadcast of an already-scalar value (shfl from an elected winner) —
    /// counted like a shuffle, returns the value unchanged.
    #[inline]
    pub fn broadcast<T>(&mut self, value: T) -> T {
        self.intrinsic_ops += 1;
        value
    }

    /// Prefix rank of `lane` within `mask`: `popc(mask & ((1<<lane)-1))` —
    /// the compaction rank used by the split/merge migration (§IV-C1).
    #[inline]
    pub fn prefix_rank(&mut self, mask: u32, lane: usize) -> u32 {
        self.intrinsic_ops += 1;
        (mask & ((1u32 << lane) - 1)).count_ones()
    }

    /// Per-lane map helper: evaluate `f` on every lane index, producing a
    /// lane vector (the SIMT "each lane computes" idiom).
    #[inline]
    pub fn lanes<T, F: FnMut(usize) -> T>(mut f: F) -> [T; LANES]
    where
        T: Copy + Default,
    {
        let mut out = [T::default(); LANES];
        for (i, slot) in out.iter_mut().enumerate() {
            *slot = f(i);
        }
        out
    }
}

/// `__ffs`-style first-set-bit election: index of the lowest set bit, or
/// `None` if the mask is empty. (CUDA `__ffs` returns 1-based; we return a
/// 0-based lane index which is what every call site wants.)
#[inline]
pub fn first_set(mask: u32) -> Option<usize> {
    if mask == 0 {
        None
    } else {
        Some(mask.trailing_zeros() as usize)
    }
}

/// Select the index of the `n`-th (0-based) set bit of `mask` — the
/// `select_nth_one` prefix-rank mapping from the merge phase (§IV-C2).
#[inline]
pub fn select_nth_one(mask: u32, n: u32) -> Option<usize> {
    let mut m = mask;
    let mut seen = 0;
    while m != 0 {
        let i = m.trailing_zeros();
        if seen == n {
            return Some(i as usize);
        }
        seen += 1;
        m &= m - 1;
    }
    None
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ballot_collects_lane_predicates() {
        let mut w = Warp::new(0);
        let preds = Warp::lanes(|i| i % 3 == 0);
        let mask = w.ballot(preds);
        for i in 0..LANES {
            assert_eq!(mask & (1 << i) != 0, i % 3 == 0);
        }
        assert_eq!(w.intrinsic_ops, 1);
    }

    #[test]
    fn shfl_broadcasts() {
        let mut w = Warp::new(0);
        let vals = Warp::lanes(|i| (i * 10) as u64);
        assert_eq!(w.shfl(&vals, 0), 0);
        assert_eq!(w.shfl(&vals, 31), 310);
    }

    #[test]
    fn first_set_elects_lowest() {
        assert_eq!(first_set(0), None);
        assert_eq!(first_set(0b1000), Some(3));
        assert_eq!(first_set(u32::MAX), Some(0));
        assert_eq!(first_set(0x8000_0000), Some(31));
    }

    #[test]
    fn prefix_rank_is_exclusive_popcount() {
        let mut w = Warp::new(0);
        let mask = 0b1011_0110u32;
        assert_eq!(w.prefix_rank(mask, 0), 0);
        assert_eq!(w.prefix_rank(mask, 2), 1); // one set bit below lane 2
        assert_eq!(w.prefix_rank(mask, 7), 4);
        assert_eq!(w.prefix_rank(mask, 31), mask.count_ones());
    }

    #[test]
    fn select_nth_one_matches_rank() {
        let mask = 0b1010_1100u32;
        assert_eq!(select_nth_one(mask, 0), Some(2));
        assert_eq!(select_nth_one(mask, 1), Some(3));
        assert_eq!(select_nth_one(mask, 2), Some(5));
        assert_eq!(select_nth_one(mask, 3), Some(7));
        assert_eq!(select_nth_one(mask, 4), None);
        assert_eq!(select_nth_one(0, 0), None);
    }

    #[test]
    fn rank_and_select_are_inverse() {
        let mut w = Warp::new(0);
        let mask = 0xDEAD_BEEFu32;
        for lane in 0..LANES {
            if mask & (1 << lane) != 0 {
                let r = w.prefix_rank(mask, lane);
                assert_eq!(select_nth_one(mask, r), Some(lane));
            }
        }
    }
}
