//! Hive hash table on the SIMT simulator — lane-accurate Algorithms 1–4.
//!
//! Where [`crate::native`] maps the paper's protocols onto OS threads for
//! real-concurrency throughput, this module executes them *as written*:
//! every ballot, shuffle, elected winner, coalesced 32-lane bucket load and
//! single-CAS publish happens exactly as in the paper, against the
//! transaction-counting memory of [`crate::simt`]. It produces the paper's
//! microarchitectural measurements:
//!
//! * per-step cycle breakdown of insertion (Fig. 9),
//! * eviction-lock usage rate (<0.85 %, §III-B),
//! * memory transactions / atomics per operation (the coalescing argument
//!   of §III-A), including the WABC-off ablation.

pub mod table;
pub mod baselines;

pub use baselines::{SimCost, SimDyCuckoo, SimSlab, SimWarpCore};
pub use table::{SimHive, SimHiveConfig, StepBreakdown};
